//! Regenerates Fig 3: the C++ Poisson app on Edison at 24/48/96/192
//! ranks under native / Shifter+system-MPI / Shifter+container-MPI.
//! Expected shape: (a) ≈ (b) everywhere; (c) comparable on one node and
//! divergent (solve-dominated) across nodes, off-scale at 192.
mod common;

fn main() {
    common::run_figure_bench("fig3");
}
