//! Micro-benchmarks of the simulator's hot paths (the §Perf targets).
//!
//! The figure benches measure *virtual* time; this bench measures the
//! *simulator's own* throughput: DES primitives, hashing, the halo
//! exchange data plane, the communication cost model, the import
//! replay, and raw PJRT dispatch. Before/after numbers for the
//! performance pass live in EXPERIMENTS.md §Perf.

mod common;

use harbor::cluster::{launch, MachineSpec};
use harbor::container::image::{FileEntry, Layer};
use harbor::des::{Duration, EventQueue, FifoResource, VirtualTime};
use harbor::fem::grid::{exchange_halos, Decomp, LocalField};
use harbor::mpi::Comm;
use harbor::net::{Fabric, FabricKind};
use harbor::pyimport::{replay, ModuleGraph};
use harbor::runtime::{artifacts_available, Engine, TensorBuf};

use common::time_it;

fn main() {
    println!("== micro: DES substrate ==");
    time_it("event queue push+pop (1k events)", || {
        let mut q = EventQueue::new();
        for i in 0..1000u64 {
            q.push(VirtualTime::ZERO + Duration::from_nanos(i % 97), i);
        }
        while q.pop().is_some() {}
    });
    time_it("fifo resource 1k submissions", || {
        let mut r = FifoResource::new(16);
        for i in 0..1000u64 {
            r.submit(
                VirtualTime::ZERO + Duration::from_nanos(i),
                Duration::from_micros(100),
            );
        }
    });

    println!("== micro: container substrate ==");
    let files: Vec<FileEntry> = (0..200)
        .map(|i| FileEntry {
            path: format!("/usr/lib/f{i}.so"),
            bytes: 10_000 + i as u64,
        })
        .collect();
    time_it("layer derive (sha256, 200-file manifest)", || {
        let l = Layer::derive(None, "RUN apt-get install petsc", files.clone());
        std::hint::black_box(l.id);
    });

    println!("== micro: MPI cost model ==");
    let machine = MachineSpec::edison();
    let alloc = launch(&machine, 192).unwrap();
    let decomp = Decomp::new(192, 32);
    let msgs = decomp.halo_messages(decomp.face_bytes());
    time_it("comm.exchange 192-rank halo msg list", || {
        let mut comm = Comm::new(alloc.clone(), Fabric::by_kind(FabricKind::Aries));
        comm.exchange(&msgs);
        std::hint::black_box(comm.max_clock());
    });
    time_it("allreduce x100, 192 ranks", || {
        let mut comm = Comm::new(alloc.clone(), Fabric::by_kind(FabricKind::Aries));
        for _ in 0..100 {
            comm.allreduce(8);
        }
    });

    println!("== micro: halo-exchange data plane (real f32 faces) ==");
    let d8 = Decomp::new(8, 32);
    let ws = launch(&MachineSpec::workstation(), 8).unwrap();
    let mut fields: Vec<LocalField> = (0..8)
        .map(|r| {
            LocalField::from_interior(
                32,
                &(0..32 * 32 * 32).map(|i| (i + r) as f32).collect::<Vec<_>>(),
            )
        })
        .collect();
    time_it("exchange_halos 8 ranks x 32³ blocks", || {
        let mut comm = Comm::new(ws.clone(), Fabric::shared_mem());
        exchange_halos(&d8, &mut fields, &mut comm);
    });

    println!("== micro: import replay ==");
    let graph = ModuleGraph::fenics_stack();
    let alloc24 = launch(&machine, 24).unwrap();
    time_it("pyimport replay, 24 ranks x fenics stack", || {
        let mut fs = harbor::fs::ParallelFs::edison(1);
        let rep = replay(&graph, &alloc24, &mut fs, VirtualTime::ZERO);
        std::hint::black_box(rep.wall);
    });

    println!("== micro: PJRT dispatch ==");
    if artifacts_available() {
        let mut engine = Engine::open_default().unwrap();
        engine.warm("dot_L4096").unwrap();
        let a = TensorBuf::new(vec![4096], vec![1.0; 4096]);
        time_it("engine.execute dot_L4096 (dispatch+copy)", || {
            let out = engine.execute("dot_L4096", &[a.clone(), a.clone()]).unwrap();
            std::hint::black_box(out[0].data[0]);
        });
        engine.warm("cg_apdot_p3d_n32").unwrap();
        let p = TensorBuf::zeros(vec![34, 34, 34]);
        time_it("engine.execute cg_apdot_p3d_n32", || {
            let out = engine.execute("cg_apdot_p3d_n32", &[p.clone()]).unwrap();
            std::hint::black_box(out[1].data[0]);
        });
    } else {
        println!("  (skipped: artifacts not built)");
    }

    println!("== micro: end-to-end simulation throughput ==");
    let table = harbor::runtime::CalibrationTable::builtin_fallback();
    time_it("fig3 cell: 96-rank modeled app run", || {
        let mut exec = harbor::fem::exec::Exec::Modeled { table: &table };
        let b = harbor::workload::run_poisson_app(
            harbor::platform::Platform::Native,
            &mut exec,
            &harbor::workload::AppConfig::cpp(96, 1),
        )
        .unwrap();
        std::hint::black_box(b.total());
    });
}
