//! Micro-benchmarks of the simulator's hot paths (the §Perf targets).
//!
//! The figure benches measure *virtual* time; this bench measures the
//! *simulator's own* throughput: DES primitives, hashing, the halo
//! exchange data plane, the communication cost model (per-rank and
//! class-batched), the import replay, and raw PJRT dispatch.
//! Before/after numbers for the performance pass live in EXPERIMENTS.md
//! §Perf, and every run merges its ns/iter into `BENCH_micro.json`.

mod common;

use harbor::cluster::{launch, MachineSpec};
use harbor::container::image::{FileEntry, Layer};
use harbor::des::{Duration, EventQueue, FifoResource, VirtualTime};
use harbor::fem::grid::{exchange_halos, Decomp, LocalField};
use harbor::mpi::Comm;
use harbor::net::{Fabric, FabricKind};
use harbor::pyimport::{replay, replay_batched, ModuleGraph};
use harbor::runtime::{artifacts_available, Engine, TensorBuf};

use common::{record_bench, time_rec};

fn main() {
    let mut rec: Vec<(String, f64)> = Vec::new();

    println!("== micro: DES substrate ==");
    time_rec(&mut rec, "event_queue_1k", "event queue push+pop (1k events)", || {
        let mut q = EventQueue::new();
        for i in 0..1000u64 {
            q.push(VirtualTime::ZERO + Duration::from_nanos(i % 97), i);
        }
        while q.pop().is_some() {}
    });
    time_rec(
        &mut rec,
        "event_queue_1k_prealloc",
        "event queue push+pop (1k events, with_capacity)",
        || {
            let mut q = EventQueue::with_capacity(1000);
            for i in 0..1000u64 {
                q.push(VirtualTime::ZERO + Duration::from_nanos(i % 97), i);
            }
            while q.pop().is_some() {}
        },
    );
    time_rec(
        &mut rec,
        "event_queue_1k_batch",
        "event queue push_batch+pop (1k events)",
        || {
            let mut q = EventQueue::with_capacity(1000);
            q.push_batch(
                (0..1000u64)
                    .map(|i| (VirtualTime::ZERO + Duration::from_nanos(i % 97), i))
                    .collect(),
            );
            while q.pop().is_some() {}
        },
    );
    time_rec(&mut rec, "fifo_1k", "fifo resource 1k submissions", || {
        let mut r = FifoResource::new(16);
        for i in 0..1000u64 {
            r.submit(
                VirtualTime::ZERO + Duration::from_nanos(i),
                Duration::from_micros(100),
            );
        }
    });
    time_rec(&mut rec, "fifo_burst_24x", "fifo resource 1k clients as 42 bursts of 24", || {
        let mut r = FifoResource::new(16);
        for i in 0..42u64 {
            r.submit_many(
                VirtualTime::ZERO + Duration::from_nanos(i),
                Duration::from_micros(100),
                24,
            );
        }
    });

    println!("== micro: container substrate ==");
    let files: Vec<FileEntry> = (0..200)
        .map(|i| FileEntry {
            path: format!("/usr/lib/f{i}.so"),
            bytes: 10_000 + i as u64,
        })
        .collect();
    time_rec(&mut rec, "layer_derive", "layer derive (sha256, 200-file manifest)", || {
        let l = Layer::derive(None, "RUN apt-get install petsc", files.clone());
        std::hint::black_box(l.id);
    });

    println!("== micro: MPI cost model ==");
    let machine = MachineSpec::edison();
    let alloc = launch(&machine, 192).unwrap();
    let decomp = Decomp::new(192, 32);
    let msgs = decomp.halo_messages(decomp.face_bytes());
    {
        // same shape as the batched pair below (exchange + allreduce per
        // iteration, Comm construction hoisted) so the two ns/iter values
        // in BENCH_micro.json are directly comparable
        let mut comm = Comm::new(alloc.clone(), Fabric::by_kind(FabricKind::Aries));
        time_rec(
            &mut rec,
            "exchange_192",
            "exchange + allreduce, 192 ranks (per-rank)",
            || {
                comm.exchange(&msgs);
                comm.allreduce(8);
                std::hint::black_box(comm.max_clock());
            },
        );
    }
    {
        let mut comm = Comm::new(alloc.clone(), Fabric::by_kind(FabricKind::Aries));
        comm.set_classes(decomp.rank_classes(comm.allocation()));
        let pattern = decomp.halo_pattern_for(&comm, decomp.face_bytes());
        // + allreduce: resynchronises so every iteration takes the
        // batched path (this is exactly one CG phase pair)
        time_rec(
            &mut rec,
            "exchange_uniform_192",
            "exchange_uniform + allreduce, 192 ranks (batched)",
            || {
                comm.exchange_uniform(&pattern);
                comm.allreduce(8);
                std::hint::black_box(comm.max_clock());
            },
        );
    }
    {
        // the scale point the per-rank path cannot reach in figure time
        let ranks = 12288;
        let alloc_big = launch(&machine, ranks).unwrap();
        let decomp_big = Decomp::new(ranks, 32);
        let mut comm = Comm::new(alloc_big, Fabric::by_kind(FabricKind::Aries));
        comm.set_classes(decomp_big.rank_classes(comm.allocation()));
        let pattern = decomp_big.halo_pattern_for(&comm, decomp_big.face_bytes());
        println!(
            "  (12288 ranks collapse to {} classes)",
            comm.classes().unwrap().len()
        );
        time_rec(
            &mut rec,
            "exchange_uniform_12288",
            "exchange_uniform + allreduce, 12288 ranks (batched)",
            || {
                comm.exchange_uniform(&pattern);
                comm.allreduce(8);
                std::hint::black_box(comm.max_clock());
            },
        );
        time_rec(
            &mut rec,
            "rank_classes_12288",
            "decomp.rank_classes 12288 ranks (setup, once per job)",
            || {
                let d = Decomp::new(12288, 32);
                let a = launch(&machine, 12288).unwrap();
                std::hint::black_box(d.rank_classes(&a).len());
            },
        );
    }
    time_rec(&mut rec, "allreduce_100x192", "allreduce x100, 192 ranks", || {
        let mut comm = Comm::new(alloc.clone(), Fabric::by_kind(FabricKind::Aries));
        for _ in 0..100 {
            comm.allreduce(8);
        }
    });

    println!("== micro: halo-exchange data plane (real f32 faces) ==");
    let d8 = Decomp::new(8, 32);
    let ws = launch(&MachineSpec::workstation(), 8).unwrap();
    let mut fields: Vec<LocalField> = (0..8)
        .map(|r| {
            LocalField::from_interior(
                32,
                &(0..32 * 32 * 32).map(|i| (i + r) as f32).collect::<Vec<_>>(),
            )
        })
        .collect();
    time_rec(&mut rec, "exchange_halos_8x32", "exchange_halos 8 ranks x 32³ blocks", || {
        let mut comm = Comm::new(ws.clone(), Fabric::shared_mem());
        exchange_halos(&d8, &mut fields, &mut comm);
    });

    println!("== micro: import replay ==");
    let graph = ModuleGraph::fenics_stack();
    let alloc24 = launch(&machine, 24).unwrap();
    time_rec(&mut rec, "replay_24", "pyimport replay, 24 ranks x fenics stack", || {
        let mut fs = harbor::fs::ParallelFs::edison(1);
        let rep = replay(&graph, &alloc24, &mut fs, VirtualTime::ZERO);
        std::hint::black_box(rep.wall);
    });
    time_rec(
        &mut rec,
        "replay_batched_24",
        "pyimport replay_batched, 24 ranks x fenics stack",
        || {
            let mut fs = harbor::fs::ParallelFs::edison(1);
            let rep = replay_batched(&graph, &alloc24, &mut fs, VirtualTime::ZERO);
            std::hint::black_box(rep.wall);
        },
    );

    println!("== micro: PJRT dispatch ==");
    if artifacts_available() {
        let mut engine = Engine::open_default().unwrap();
        engine.warm("dot_L4096").unwrap();
        let a = TensorBuf::new(vec![4096], vec![1.0; 4096]);
        time_rec(&mut rec, "pjrt_dot", "engine.execute dot_L4096 (dispatch+copy)", || {
            let out = engine.execute("dot_L4096", &[a.clone(), a.clone()]).unwrap();
            std::hint::black_box(out[0].data[0]);
        });
        engine.warm("cg_apdot_p3d_n32").unwrap();
        let p = TensorBuf::zeros(vec![34, 34, 34]);
        time_rec(&mut rec, "pjrt_apdot", "engine.execute cg_apdot_p3d_n32", || {
            let out = engine.execute("cg_apdot_p3d_n32", &[p.clone()]).unwrap();
            std::hint::black_box(out[1].data[0]);
        });
    } else {
        println!("  (skipped: artifacts not built)");
    }

    println!("== micro: end-to-end simulation throughput ==");
    let table = harbor::runtime::CalibrationTable::builtin_fallback();
    time_rec(&mut rec, "fig3_cell_96", "fig3 cell: 96-rank modeled app run (batched)", || {
        let mut exec = harbor::fem::exec::Exec::Modeled { table: &table };
        let b = harbor::workload::run_poisson_app(
            harbor::platform::Platform::Native,
            &mut exec,
            &harbor::workload::AppConfig::cpp(96, 1),
        )
        .unwrap();
        std::hint::black_box(b.total());
    });
    time_rec(
        &mut rec,
        "fig3_cell_96_per_rank",
        "fig3 cell: 96-rank modeled app run (per-rank)",
        || {
            let mut exec = harbor::fem::exec::Exec::Modeled { table: &table };
            let b = harbor::workload::run_poisson_app(
                harbor::platform::Platform::Native,
                &mut exec,
                &harbor::workload::AppConfig::cpp(96, 1).per_rank(),
            )
            .unwrap();
            std::hint::black_box(b.total());
        },
    );

    record_bench(&rec);
}
