//! Node-class collapsing bench: how many equivalence classes a fleet
//! deploy actually materialises, and what a collapsed deploy costs in
//! wall time, across the full `fig1-scale` node sweep.
//!
//! The collapsed engine ([`ClassFleet`]) prices a deploy in
//! O(classes × layers) events. The interesting empirical fact is that
//! the class count is driven by the peer fan-out wave structure — it
//! grows with log(nodes), not nodes — so the million-node row costs
//! about the same as the 16k row. Two key families land in
//! `BENCH_micro.json`:
//!
//! * `fleet_classes_{N}` — peak class count during a cold deploy onto
//!   `N` nodes (the curve CI plots against node count);
//! * `fleet_classes_events_{N}` — calendar-queue events the collapsed
//!   deploy scheduled (vs `N × layers` for the per-node walk);
//! * `fleet_classes_deploy_{N}_ns_per_iter` — wall time per collapsed
//!   cold deploy at N ∈ {16384, 262144, 1048576}.

mod common;

use harbor::config::SCALE_NODES;
use harbor::container::{ClassFleet, FleetConfig};
use harbor::coordinator::fleet_registry;

use common::{record_bench, time_rec};

/// Image reference the fleet pulls (same as the fig1-scale scenario).
const REFERENCE: &str = "quay.io/fenicsproject/stable:2016.1.0r1";

/// Node counts for the ns/op timing rows.
const TIMED_NODES: [usize; 3] = [16_384, 262_144, 1_048_576];

fn main() {
    let mut rec: Vec<(String, f64)> = Vec::new();

    println!("== node-class collapsing: class count vs node count ==");
    for &nodes in &SCALE_NODES {
        let mut sharded = fleet_registry(REFERENCE).expect("fleet registry");
        let mut fleet = ClassFleet::new(FleetConfig::hpc(nodes));
        let cold = fleet.deploy(&mut sharded, REFERENCE).expect("cold deploy");
        let node_events = cold.queue.pushes;
        println!(
            "  {nodes:>7} nodes: {:>3} peak classes, {:>5} class events \
             ({} node-equivalent), {} classes after re-merge",
            fleet.peak_classes(),
            fleet.class_events(),
            node_events,
            fleet.class_count(),
        );
        rec.push((format!("fleet_classes_{nodes}"), fleet.peak_classes() as f64));
        rec.push((
            format!("fleet_classes_events_{nodes}"),
            fleet.class_events() as f64,
        ));
    }

    println!("== node-class collapsing: collapsed cold-deploy wall time ==");
    for &nodes in &TIMED_NODES {
        // the registry is rebuilt outside the timed closure; each
        // iteration deploys a fresh (cold) fleet through it, so the
        // measured cost is the collapsed deploy itself
        let mut sharded = fleet_registry(REFERENCE).expect("fleet registry");
        time_rec(
            &mut rec,
            &format!("fleet_classes_deploy_{nodes}"),
            &format!("collapsed cold deploy, {nodes} nodes"),
            || {
                let mut fleet = ClassFleet::new(FleetConfig::hpc(nodes));
                fleet.deploy(&mut sharded, REFERENCE).expect("cold deploy");
            },
        );
    }

    record_bench(&rec);
}
