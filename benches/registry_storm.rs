//! Registry-storm bench: the open-loop heavy-tailed pull/push storm
//! against the registry front door, recorded into `BENCH_micro.json`.
//!
//! Recorded keys (the percentile cells are the 4-shard frontends):
//!
//! * `storm_p50_s` / `storm_p99_s` / `storm_p999_s` — warmup-trimmed
//!   blob pull latency percentiles at offered load 0.90x (just under
//!   the knee);
//! * `storm_sat_p99_s` — the same p99 at offered load 1.20x, past the
//!   saturation knee;
//! * `storm_knee_ratio` — p99(1.20x) / p99(0.25x): how hard the tail
//!   diverges across the knee (the saturation signature);
//! * `storm_delivered_mbps` — delivered payload throughput of the
//!   0.90x cell;
//! * `storm_chaos_p99_s` / `storm_chaos_p999_s` — tail latency of the
//!   0.90x cell replayed under the seeded shard-fault schedule
//!   (intensity 0.4);
//! * `storm_chaos_availability` — delivered/offered session fraction
//!   of that chaos cell;
//! * `storm_determinism_ok` — 1.0 iff the full figure set renders
//!   byte-identically under `--jobs 1` and `--jobs 4` (the CI
//!   determinism gate fails on anything else);
//! * `storm_wall_s` — wall time of the serial regeneration (the §Perf
//!   trajectory).

mod common;

use std::time::Instant;

use harbor::bench::{Figure, Row};
use harbor::config::ExperimentConfig;
use harbor::coordinator::Coordinator;

use common::record_bench;

fn render_all(figs: &[Figure]) -> String {
    figs.iter().map(|f| f.render()).collect::<Vec<_>>().join("\n")
}

fn row<'a>(fig: &'a Figure, needle: &str) -> &'a Row {
    fig.rows
        .iter()
        .find(|r| r.label.contains(needle))
        .unwrap_or_else(|| panic!("no row matching `{needle}` in `{}`", fig.title))
}

fn part(r: &Row, key: &str) -> f64 {
    r.breakdown
        .iter()
        .find(|(k, _)| k == key)
        .map(|&(_, v)| v)
        .unwrap_or_else(|| panic!("row `{}` carries no `{key}` breakdown", r.label))
}

fn main() {
    let mut rec: Vec<(String, f64)> = Vec::new();
    let cfg = ExperimentConfig::paper_default("registry-storm").expect("registered default");
    println!(
        "== registry storm: shards {:?}, open-loop offered-load sweep ==",
        cfg.nodes
    );

    let t0 = Instant::now();
    let serial = Coordinator::new().with_jobs(1).run(&cfg).expect("registry-storm runs");
    let wall = t0.elapsed().as_secs_f64();
    for f in &serial {
        println!("{}", f.render());
    }

    // determinism gate: the whole matrix again on 4 workers must
    // render byte-for-byte the same figures
    let parallel = Coordinator::new()
        .with_jobs(4)
        .run(&cfg)
        .expect("registry-storm runs (4 jobs)");
    let deterministic = render_all(&serial) == render_all(&parallel);
    if !deterministic {
        eprintln!("  WARNING: --jobs 1 and --jobs 4 renders differ");
    }

    let [lat_fig, sat_fig] = &serial[..] else {
        panic!("registry-storm assembles two figures, got {}", serial.len());
    };
    let knee = row(lat_fig, "4 shard(s), load 0.90x");
    let past = row(lat_fig, "4 shard(s), load 1.20x");
    let calm = row(lat_fig, "4 shard(s), load 0.25x");
    let p99 = knee.stats.mean();
    let sat_p99 = past.stats.mean();
    let knee_ratio = sat_p99 / calm.stats.mean().max(f64::MIN_POSITIVE);
    let delivered = row(sat_fig, "4 shard(s), load 0.90x").stats.mean();
    let chaos = row(lat_fig, "chaos 0.4");
    let chaos_avail = part(row(sat_fig, "chaos 0.4"), "availability");

    println!(
        "  4 shards: p50 {:.3} s / p99 {p99:.3} s / p999 {:.3} s at 0.90x; \
         p99 {sat_p99:.3} s past the knee (x{knee_ratio:.1} over 0.25x); \
         {delivered:.1} MB/s delivered; computed in {wall:.3} s (deterministic: {deterministic})",
        part(knee, "p50 s"),
        part(knee, "p999 s"),
    );
    println!(
        "  chaos 0.4: p99 {:.3} s / p999 {:.3} s, availability {chaos_avail:.4}",
        chaos.stats.mean(),
        part(chaos, "p999 s"),
    );

    rec.push(("storm_p50_s".into(), part(knee, "p50 s")));
    rec.push(("storm_p99_s".into(), p99));
    rec.push(("storm_p999_s".into(), part(knee, "p999 s")));
    rec.push(("storm_sat_p99_s".into(), sat_p99));
    rec.push(("storm_knee_ratio".into(), knee_ratio));
    rec.push(("storm_delivered_mbps".into(), delivered));
    rec.push(("storm_chaos_p99_s".into(), chaos.stats.mean()));
    rec.push(("storm_chaos_p999_s".into(), part(chaos, "p999 s")));
    rec.push(("storm_chaos_availability".into(), chaos_avail));
    rec.push((
        "storm_determinism_ok".into(),
        if deterministic { 1.0 } else { 0.0 },
    ));
    rec.push(("storm_wall_s".into(), wall));
    record_bench(&rec);
}
