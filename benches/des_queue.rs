//! Heap-vs-calendar event-queue comparison (the §Perf queue row).
//!
//! Drives the calendar `EventQueue` and the retained `HeapEventQueue`
//! reference through the same deterministic workload — batch-fill with
//! LCG-spaced timestamps, a *hold* phase (pop one, push one just past
//! the moving horizon: the steady state of a DES), then a full drain —
//! at 1e3 / 1e6 / 1e7 events, and records ns per event operation into
//! `BENCH_micro.json` as `queue_{heap,cal}_{n}_ns_per_iter`, plus the
//! large-size ratio `queue_speedup_1e7_x`.  CI fails if any of these
//! stays null (or goes missing) after the bench step.

mod common;

use std::time::Instant;

use harbor::des::{Duration, EventQueue, HeapEventQueue, VirtualTime};

use common::record_bench;

/// Deterministic 64-bit LCG (Knuth MMIX constants) so both queues see
/// byte-identical workloads without pulling an RNG into the bench.
struct Lcg(u64);

impl Lcg {
    fn draw(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0 >> 11
    }
}

/// The two queues expose identical inherent APIs; this local trait lets
/// one workload drive both.
trait Queue {
    fn push(&mut self, t: VirtualTime, v: u64);
    fn push_batch(&mut self, batch: Vec<(VirtualTime, u64)>);
    fn pop(&mut self) -> Option<(VirtualTime, u64)>;
}

impl Queue for EventQueue<u64> {
    fn push(&mut self, t: VirtualTime, v: u64) {
        EventQueue::push(self, t, v);
    }
    fn push_batch(&mut self, batch: Vec<(VirtualTime, u64)>) {
        EventQueue::push_batch(self, batch);
    }
    fn pop(&mut self) -> Option<(VirtualTime, u64)> {
        EventQueue::pop(self)
    }
}

impl Queue for HeapEventQueue<u64> {
    fn push(&mut self, t: VirtualTime, v: u64) {
        HeapEventQueue::push(self, t, v);
    }
    fn push_batch(&mut self, batch: Vec<(VirtualTime, u64)>) {
        HeapEventQueue::push_batch(self, batch);
    }
    fn pop(&mut self) -> Option<(VirtualTime, u64)> {
        HeapEventQueue::pop(self)
    }
}

/// Fill + hold + drain; returns the number of event operations.
fn workload<Q: Queue>(q: &mut Q, n: u64, spacing: u64) -> u64 {
    let mut rng = Lcg(0x5eed ^ n);
    let mut ops = 0u64;
    // fill in 64-event batches (the fan-out-wave shape)
    let mut filled = 0u64;
    while filled < n {
        let k = 64.min(n - filled);
        let batch: Vec<(VirtualTime, u64)> = (0..k)
            .map(|i| {
                let t = VirtualTime::ZERO + Duration::from_nanos(rng.draw() % (n * spacing));
                (t, filled + i)
            })
            .collect();
        q.push_batch(batch);
        filled += k;
        ops += k;
    }
    // hold: steady-state pop/push around the advancing horizon
    for _ in 0..n {
        let (t, v) = q.pop().expect("hold phase pops a full queue");
        q.push(t + Duration::from_nanos(rng.draw() % spacing + 1), v);
        ops += 2;
    }
    // drain, asserting the determinism contract on the way out
    let mut last = VirtualTime::ZERO;
    while let Some((t, _)) = q.pop() {
        assert!(t >= last, "pop order regressed");
        last = t;
        ops += 1;
    }
    ops
}

/// Time `run_once` (repeating small workloads until ~0.2 s) and record
/// ns per event operation under `<key>_ns_per_iter`.
fn measure(
    rec: &mut Vec<(String, f64)>,
    key: &str,
    label: &str,
    mut run_once: impl FnMut() -> u64,
) -> f64 {
    let t0 = Instant::now();
    let mut ops = run_once();
    while t0.elapsed().as_secs_f64() < 0.2 && ops < 10_000_000 {
        ops += run_once();
    }
    let ns = t0.elapsed().as_nanos() as f64 / ops as f64;
    println!("  {label:44} {ns:>9.1} ns/op  ({ops} ops)");
    rec.push((format!("{key}_ns_per_iter"), ns));
    ns
}

fn main() {
    let mut rec: Vec<(String, f64)> = Vec::new();
    println!("== des_queue: calendar EventQueue vs HeapEventQueue reference ==");

    let sizes: [(u64, &str); 3] = [(1_000, "1e3"), (1_000_000, "1e6"), (10_000_000, "1e7")];
    let mut speedup_1e7 = 0.0f64;
    for (n, tag) in sizes {
        let heap_ns = measure(
            &mut rec,
            &format!("queue_heap_{tag}"),
            &format!("heap  fill+hold+drain, {tag} events"),
            || {
                let mut q: HeapEventQueue<u64> = HeapEventQueue::with_capacity(n as usize);
                workload(&mut q, n, 1_000)
            },
        );
        let cal_ns = measure(
            &mut rec,
            &format!("queue_cal_{tag}"),
            &format!("calendar fill+hold+drain, {tag} events"),
            || {
                let mut q: EventQueue<u64> = EventQueue::with_capacity(n as usize);
                workload(&mut q, n, 1_000)
            },
        );
        println!("    heap/calendar at {tag}: {:.2}x", heap_ns / cal_ns);
        if n == 10_000_000 {
            speedup_1e7 = heap_ns / cal_ns;
        }
    }
    rec.push(("queue_speedup_1e7_x".into(), speedup_1e7));

    // one geometry snapshot, so "how to read des::stats" (docs/DES.md)
    // has a live example in every CI log
    let mut q: EventQueue<u64> = EventQueue::new();
    let mut rng = Lcg(7);
    for i in 0..65_536u64 {
        q.push(VirtualTime::ZERO + Duration::from_nanos(rng.draw() % 1_000_000_000), i);
    }
    println!("  calendar stats @64k events: {}", q.stats().render());

    record_bench(&rec);
}
