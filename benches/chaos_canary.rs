//! Chaos-canary bench: the rolling canary upgrade of the 16k-node
//! fleet under seeded fault injection, recorded into
//! `BENCH_micro.json`.
//!
//! Recorded keys:
//!
//! * `chaos_calm_virt_s` / `chaos_storm_virt_s` — virtual upgrade
//!   makespan of the fault-free control cell vs the intensity-0.8 cell
//!   (both under the `hpc` retry policy);
//! * `chaos_availability` — fleet availability over the stormy
//!   upgrade (`1 - downtime / (nodes × span)`);
//! * `chaos_wasted_mb` / `chaos_retries` — WAN/fabric megabytes lost
//!   to drop windows, timeouts and dead receivers, and the transfer
//!   re-attempts the retry machinery scheduled;
//! * `chaos_determinism_ok` — 1.0 iff the full figure set renders
//!   byte-identically under `--jobs 1` and `--jobs 4` (the CI
//!   determinism gate fails on anything else);
//! * `chaos_wall_s` — wall time of the serial regeneration (the
//!   §Perf trajectory).

mod common;

use std::time::Instant;

use harbor::bench::{Figure, Row};
use harbor::config::ExperimentConfig;
use harbor::coordinator::Coordinator;

use common::record_bench;

fn render_all(figs: &[Figure]) -> String {
    figs.iter().map(|f| f.render()).collect::<Vec<_>>().join("\n")
}

fn row<'a>(fig: &'a Figure, needle: &str) -> &'a Row {
    fig.rows
        .iter()
        .find(|r| r.label.contains(needle))
        .unwrap_or_else(|| panic!("no row matching `{needle}` in `{}`", fig.title))
}

fn main() {
    let mut rec: Vec<(String, f64)> = Vec::new();
    let cfg = ExperimentConfig::paper_default("chaos-canary").expect("registered default");
    println!(
        "== chaos canary: {} nodes, intensity x retry-policy sweep ==",
        cfg.nodes[0]
    );

    let t0 = Instant::now();
    let serial = Coordinator::new().with_jobs(1).run(&cfg).expect("chaos-canary runs");
    let wall = t0.elapsed().as_secs_f64();
    for f in &serial {
        println!("{}", f.render());
    }

    // determinism gate: the whole matrix again on 4 workers must
    // render byte-for-byte the same figures
    let parallel = Coordinator::new().with_jobs(4).run(&cfg).expect("chaos-canary runs (4 jobs)");
    let deterministic = render_all(&serial) == render_all(&parallel);
    if !deterministic {
        eprintln!("  WARNING: --jobs 1 and --jobs 4 renders differ");
    }

    let [make_fig, avail_fig, waste_fig] = &serial[..] else {
        panic!("chaos-canary assembles three figures, got {}", serial.len());
    };
    let calm = row(make_fig, "intensity 0.0, hpc");
    let storm = row(make_fig, "intensity 0.8, hpc");
    let retries = storm
        .breakdown
        .iter()
        .find(|(k, _)| k == "retries")
        .map(|&(_, v)| v)
        .expect("makespan rows carry a retries breakdown");

    println!(
        "  calm {:.3} s -> storm {:.3} s virtual; availability {:.4}, \
         {:.1} MB re-sent, {} retries; computed in {wall:.3} s (deterministic: {deterministic})",
        calm.stats.mean(),
        storm.stats.mean(),
        row(avail_fig, "intensity 0.8, hpc").stats.mean(),
        row(waste_fig, "intensity 0.8, hpc").stats.mean(),
        retries as u64,
    );

    rec.push(("chaos_calm_virt_s".into(), calm.stats.mean()));
    rec.push(("chaos_storm_virt_s".into(), storm.stats.mean()));
    rec.push((
        "chaos_availability".into(),
        row(avail_fig, "intensity 0.8, hpc").stats.mean(),
    ));
    rec.push((
        "chaos_wasted_mb".into(),
        row(waste_fig, "intensity 0.8, hpc").stats.mean(),
    ));
    rec.push(("chaos_retries".into(), retries));
    rec.push((
        "chaos_determinism_ok".into(),
        if deterministic { 1.0 } else { 0.0 },
    ));
    rec.push(("chaos_wall_s".into(), wall));
    record_bench(&rec);
}
