//! Regenerates Fig 4: the Python app on Edison at 24/48/96 ranks,
//! native vs Shifter+system-MPI. Expected shape: per-phase compute
//! equal; native total dominated by the import phase, growing with rank
//! count and more variable (MDS contention noise).
mod common;

fn main() {
    common::run_figure_bench("fig4");
}
