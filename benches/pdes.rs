//! Conservative parallel DES bench: serial pop stream vs lookahead
//! domains, at fleet scale.
//!
//! The workload is an open-loop arrival stream (the shape of the
//! fig1-scale fan-out waves and the registry-storm front door): `N`
//! events spread uniformly over ten WAN-lookahead windows, each event
//! carrying a fixed chunk of per-event work (an FNV mixing loop — a
//! stand-in for pricing a deploy hop).  The serial row folds the work
//! over [`EventQueue`]'s pop stream; the domain rows drain a
//! [`PartitionedQueue`] window-by-window with the per-event work running
//! inside the domain threads ([`PartitionedQueue::drain_fold_hash`]).
//!
//! Keys landed in `BENCH_micro.json` (CI-gated non-null):
//!
//! * `pdes_serial_{16k,256k,1m}_ns_per_iter` — serial fold wall time;
//! * `pdes_domains_{16k,256k,1m}_ns_per_iter` — 4-domain drain;
//! * `pdes_speedup_{16k,256k,1m}_x` — serial / domains ratio
//!   (acceptance bar: > 1 on the 256k row);
//! * `pdes_cross_msg_rate` — cross-domain share of pushes at 4 domains;
//! * `pdes_determinism_ok` — 1.0 iff the domain digests for
//!   D ∈ {1, 2, 4} are byte-identical to the serial digest;
//! * `pdes_wall_s` — total bench wall time.

mod common;

use std::time::Instant;

use harbor::des::{EventQueue, PartitionedQueue, SimRng, VirtualTime};
use harbor::net::wan_lookahead;

use common::{record_bench, time_rec};

/// Node counts for the timing rows (the fig1-scale sweep's top end).
const TIMED: [(usize, &str); 3] = [(16_384, "16k"), (262_144, "256k"), (1_048_576, "1m")];

/// FNV mixing rounds per event — the simulated per-event pricing work.
const WORK_ROUNDS: u64 = 64;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv_fold(acc: u64, value: u64) -> u64 {
    let mut h = acc;
    for byte in value.to_le_bytes() {
        h ^= byte as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// The per-event work: a fixed FNV mixing loop over the event payload.
fn price(t: VirtualTime, ev: &u64) -> u64 {
    let mut h = FNV_OFFSET ^ t.0;
    for round in 0..WORK_ROUNDS {
        h = fnv_fold(h, ev.wrapping_add(round));
    }
    h
}

/// `n` events spread uniformly over ten lookahead windows: domain =
/// node index, payload = a seeded per-event word.
fn workload(n: usize) -> Vec<(usize, VirtualTime, u64)> {
    let span = 10 * wan_lookahead().0;
    let mut rng = SimRng::new(42, "pdes-bench");
    (0..n)
        .map(|node| {
            let t = VirtualTime(rng.uniform(0.0, span as f64) as u64);
            (node, t, (node as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15))
        })
        .collect()
}

/// Serial reference: fold `price` over the [`EventQueue`] pop stream.
fn serial_digest(events: &[(usize, VirtualTime, u64)]) -> u64 {
    let mut q: EventQueue<u64> = EventQueue::with_capacity(events.len());
    q.push_batch(events.iter().map(|&(_, t, ev)| (t, ev)).collect());
    let mut digest = FNV_OFFSET;
    while let Some((t, ev)) = q.pop() {
        digest = fnv_fold(digest, price(t, &ev));
    }
    digest
}

/// Domain path: drain a [`PartitionedQueue`] with the work inside the
/// domain threads. Returns the digest (byte-compared against serial).
fn domain_digest(events: &[(usize, VirtualTime, u64)], domains: usize) -> u64 {
    let mut q: PartitionedQueue<u64> =
        PartitionedQueue::new(domains, wan_lookahead(), events.len());
    q.push_batch(events.to_vec());
    q.drain_fold_hash(price)
}

fn main() {
    let t0 = Instant::now();
    let mut rec: Vec<(String, f64)> = Vec::new();

    println!("== conservative parallel DES: serial vs lookahead domains ==");
    for &(n, tag) in &TIMED {
        let events = workload(n);
        let serial_ns = time_rec(
            &mut rec,
            &format!("pdes_serial_{tag}"),
            &format!("serial pop-stream fold, {n} events"),
            || {
                std::hint::black_box(serial_digest(&events));
            },
        );
        let domains_ns = time_rec(
            &mut rec,
            &format!("pdes_domains_{tag}"),
            &format!("4-domain window drain, {n} events"),
            || {
                std::hint::black_box(domain_digest(&events, 4));
            },
        );
        let speedup = serial_ns / domains_ns;
        println!("  {n:>8} events: {speedup:.2}x serial/domains");
        rec.push((format!("pdes_speedup_{tag}_x"), speedup));
    }

    // determinism + cross-domain traffic, measured untimed at 256k
    let events = workload(262_144);
    let reference = serial_digest(&events);
    let mut ok = true;
    for d in [1usize, 2, 4] {
        let digest = domain_digest(&events, d);
        if digest != reference {
            eprintln!("[pdes] digest diverged at {d} domains: {digest:#x} vs {reference:#x}");
            ok = false;
        }
    }
    let mut q: PartitionedQueue<u64> = PartitionedQueue::new(4, wan_lookahead(), events.len());
    q.push_batch(events.clone());
    q.drain_fold_hash(price);
    let stats = q.pdes_stats();
    println!(
        "  determinism {} | {}",
        if ok { "ok" } else { "DIVERGED" },
        stats.render()
    );
    rec.push(("pdes_determinism_ok".into(), if ok { 1.0 } else { 0.0 }));
    rec.push(("pdes_cross_msg_rate".into(), stats.cross_rate()));
    rec.push(("pdes_wall_s".into(), t0.elapsed().as_secs_f64()));

    record_bench(&rec);
}
