//! Regenerates Fig 5: HPGMG-FE throughput (DOF/s, higher is better).
//! 5a — 16-core workstation, docker/rkt/native: native wins by ~3%
//! (AVX on tuned loops). 5b — Edison at 192 ranks, native vs Shifter:
//! parity at larger problem sizes.
mod common;

fn main() {
    common::run_figure_bench("fig5a");
    common::run_figure_bench("fig5b");
}
