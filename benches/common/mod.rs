//! Shared bench plumbing (the offline stand-in for criterion's harness).
//!
//! Each figure bench regenerates its figure through the coordinator,
//! prints the paper-style ASCII rendering, writes the JSON report next
//! to `target/criterion/`-style output, and reports the wall time of
//! the regeneration itself (the simulator's own performance, tracked in
//! EXPERIMENTS.md §Perf).
//!
//! Every measurement is also merged into a machine-readable
//! `BENCH_micro.json` (override the path with `BENCH_MICRO_PATH`):
//! ns/iter per micro substrate plus figure-regeneration wall times, so
//! the perf trajectory is tracked across PRs rather than living only in
//! scrollback.

use std::time::Instant;

use harbor::config::ExperimentConfig;
use harbor::coordinator::Coordinator;
use harbor::util::json::{self, Value};

/// Where the machine-readable bench record accumulates.
#[allow(dead_code)]
pub fn bench_json_path() -> std::path::PathBuf {
    std::env::var_os("BENCH_MICRO_PATH")
        .map(Into::into)
        .unwrap_or_else(|| "BENCH_micro.json".into())
}

/// Merge `(key, value)` pairs into the bench record. Existing keys are
/// overwritten, everything else is preserved, and the file stays sorted
/// (`util::json` objects are BTreeMaps) so diffs across PRs are stable.
#[allow(dead_code)]
pub fn record_bench(entries: &[(String, f64)]) {
    let path = bench_json_path();
    let mut obj = std::fs::read_to_string(&path)
        .ok()
        .and_then(|text| json::parse(&text).ok())
        .and_then(|v| v.as_obj().cloned())
        .unwrap_or_default();
    for (k, v) in entries {
        obj.insert(k.clone(), Value::Num(*v));
    }
    let out = Value::Obj(obj);
    if let Err(e) = std::fs::write(&path, out.to_pretty()) {
        eprintln!("[bench] could not write {}: {e}", path.display());
    } else {
        eprintln!("[bench] merged {} entries into {}", entries.len(), path.display());
    }
}

#[allow(dead_code)]
pub fn run_figure_bench(figure: &str) {
    let cfg = ExperimentConfig::paper_default(figure).expect("known figure");
    let coordinator = Coordinator::new();
    eprintln!(
        "[bench:{figure}] reps={} seed={} calibration={}",
        cfg.reps, cfg.seed, coordinator.table.source
    );

    // timed regeneration (what `cargo bench` measures)
    let t0 = Instant::now();
    let figs = coordinator.run(&cfg).expect("figure runs");
    let elapsed = t0.elapsed();

    for f in &figs {
        println!("{}", f.render());
    }

    let out_dir = std::path::Path::new("target/figure-reports");
    std::fs::create_dir_all(out_dir).ok();
    let json = Value::Arr(figs.iter().map(|f| f.to_json()).collect());
    let path = out_dir.join(format!("{figure}.json"));
    std::fs::write(&path, json.to_pretty()).ok();

    println!(
        "[bench:{figure}] regenerated {} figure(s) in {:.3}s (report: {})",
        figs.len(),
        elapsed.as_secs_f64(),
        path.display()
    );
    record_bench(&[(
        format!("{figure}_regen_wall_s"),
        elapsed.as_secs_f64(),
    )]);
}

/// Tiny timing helper for the micro benches: runs `f` in batches until
/// ~0.2 s elapsed, returns ns/iter.
#[allow(dead_code)]
pub fn time_it<F: FnMut()>(label: &str, mut f: F) -> f64 {
    // warm-up
    for _ in 0..3 {
        f();
    }
    let mut iters: u64 = 0;
    let t0 = Instant::now();
    while t0.elapsed().as_secs_f64() < 0.2 {
        f();
        iters += 1;
    }
    let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    println!("  {label:44} {:>12.0} ns/iter  ({iters} iters)", ns);
    ns
}

/// [`time_it`] that also records `ns/iter` under `key` in
/// `BENCH_micro.json` via the provided collector.
#[allow(dead_code)]
pub fn time_rec<F: FnMut()>(
    out: &mut Vec<(String, f64)>,
    key: &str,
    label: &str,
    f: F,
) -> f64 {
    let ns = time_it(label, f);
    out.push((format!("{key}_ns_per_iter"), ns));
    ns
}
