//! Shared bench plumbing (the offline stand-in for criterion's harness).
//!
//! Each figure bench regenerates its figure through the coordinator,
//! prints the paper-style ASCII rendering, writes the JSON report next
//! to `target/criterion/`-style output, and reports the wall time of
//! the regeneration itself (the simulator's own performance, tracked in
//! EXPERIMENTS.md §Perf).

use std::time::Instant;

use harbor::config::ExperimentConfig;
use harbor::coordinator::Coordinator;
use harbor::util::json::Value;

#[allow(dead_code)]
pub fn run_figure_bench(figure: &str) {
    let cfg = ExperimentConfig::paper_default(figure).expect("known figure");
    let coordinator = Coordinator::new();
    eprintln!(
        "[bench:{figure}] reps={} seed={} calibration={}",
        cfg.reps, cfg.seed, coordinator.table.source
    );

    // timed regeneration (what `cargo bench` measures)
    let t0 = Instant::now();
    let figs = coordinator.run(&cfg).expect("figure runs");
    let elapsed = t0.elapsed();

    for f in &figs {
        println!("{}", f.render());
    }

    let out_dir = std::path::Path::new("target/figure-reports");
    std::fs::create_dir_all(out_dir).ok();
    let json = Value::Arr(figs.iter().map(|f| f.to_json()).collect());
    let path = out_dir.join(format!("{figure}.json"));
    std::fs::write(&path, json.to_pretty()).ok();

    println!(
        "[bench:{figure}] regenerated {} figure(s) in {:.3}s (report: {})",
        figs.len(),
        elapsed.as_secs_f64(),
        path.display()
    );
}

/// Tiny timing helper for the micro benches: runs `f` in batches until
/// ~0.2 s elapsed, returns ns/iter.
#[allow(dead_code)]
pub fn time_it<F: FnMut()>(label: &str, mut f: F) -> f64 {
    // warm-up
    for _ in 0..3 {
        f();
    }
    let mut iters: u64 = 0;
    let t0 = Instant::now();
    while t0.elapsed().as_secs_f64() < 0.2 {
        f();
        iters += 1;
    }
    let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    println!("  {label:44} {:>12.0} ns/iter  ({iters} iters)", ns);
    ns
}
