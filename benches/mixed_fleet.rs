//! Mixed-fleet bench: the co-tenant interference matrix through the
//! scenario registry, recording the checkpoint-slowdown trajectory and
//! the runner's parallel speedup into `BENCH_micro.json`.
//!
//! Recorded per rank count `R`:
//!
//! * `mixed_slowdown_{R}x` — C++ checkpoint write time next to a native
//!   Python tenant, relative to solo (virtual time; the model's claim);
//! * `mixed_cell_{R}_wall_s` — wall time of one native co-scheduled
//!   cell (the simulator's own performance).
//!
//! Plus `matrix_jobs_speedup_x`: fig2 regenerated serially vs with
//! available parallelism — same figures bit-for-bit, less wall clock.

mod common;

use std::time::Instant;

use harbor::config::ExperimentConfig;
use harbor::coordinator::Coordinator;
use harbor::platform::Platform;
use harbor::runtime::CalibrationTable;
use harbor::scenario::MatrixRunner;
use harbor::workload::{run_mixed_fleet, MixedConfig};

use common::record_bench;

fn main() {
    let mut rec: Vec<(String, f64)> = Vec::new();

    println!("== mixed-fleet: co-tenant interference on the shared Lustre ==");
    for ranks in [24usize, 96] {
        let t0 = Instant::now();
        let report = run_mixed_fleet(&MixedConfig::new(ranks, 42, Some(Platform::Native)))
            .expect("mixed cell");
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "  {ranks:>3}+{ranks:<3} ranks: checkpoint {:.4}s vs solo {:.4}s \
             ({:.1}x), import {:.2}s, {} MDS RPCs, computed in {wall:.3}s",
            report.cpp_io,
            report.cpp_io_solo,
            report.slowdown(),
            report.import_wall,
            report.mds_served,
        );
        rec.push((format!("mixed_slowdown_{ranks}x"), report.slowdown()));
        rec.push((format!("mixed_cell_{ranks}_wall_s"), wall));
    }

    // full scenario through the registry (figures to stdout), then the
    // matrix runner's own speedup on an embarrassingly parallel figure
    let cfg = ExperimentConfig::paper_default("mixed-fleet").expect("known scenario");
    let figs = Coordinator::with_table(CalibrationTable::builtin_fallback())
        .with_jobs(MatrixRunner::available_jobs())
        .run(&cfg)
        .expect("mixed-fleet scenario");
    for f in &figs {
        println!("{}", f.render());
    }

    let fig2 = ExperimentConfig::paper_default("fig2").expect("fig2");
    let serial_coord = Coordinator::with_table(CalibrationTable::builtin_fallback());
    let t0 = Instant::now();
    let serial = serial_coord.run(&fig2).expect("fig2 serial");
    let serial_wall = t0.elapsed().as_secs_f64();
    let jobs = MatrixRunner::available_jobs();
    let par_coord = Coordinator::with_table(CalibrationTable::builtin_fallback()).with_jobs(jobs);
    let t1 = Instant::now();
    let parallel = par_coord.run(&fig2).expect("fig2 parallel");
    let par_wall = t1.elapsed().as_secs_f64();
    assert_eq!(
        serial.iter().map(|f| f.render()).collect::<String>(),
        parallel.iter().map(|f| f.render()).collect::<String>(),
        "--jobs must not change the figures"
    );
    let speedup = if par_wall > 0.0 { serial_wall / par_wall } else { 1.0 };
    println!(
        "[bench:mixed_fleet] fig2 matrix: serial {serial_wall:.3}s, \
         {jobs} jobs {par_wall:.3}s ({speedup:.2}x, bit-identical)"
    );
    rec.push(("matrix_jobs_speedup_x".into(), speedup));

    record_bench(&rec);
}
