//! Regenerates Fig 2: four single-process FEniCS tests on the 16-core
//! workstation across docker / rkt / native / VM (5 reps, error bars).
//! Expected shape: docker ≈ rkt ≈ native (<1%); VM ≈ +15%.
mod common;

fn main() {
    common::run_figure_bench("fig2");
}
