//! Paper-scale Fig 3/4 regeneration (1536 / 12288 / 98304 ranks) on the
//! rank-class batched engine, plus the acceptance measurement: the
//! batched-vs-per-rank wall-clock ratio for a Fig 4 cell at 12288 ranks
//! (recorded as `fig4_speedup_12288x` in `BENCH_micro.json`; the bar is
//! ≥ 10×). The per-rank baseline at 98304 ranks is not run — that is
//! the point.
//!
//! `FIG34_SCALE_FULL=1` also regenerates the full scale sweeps through
//! the coordinator (a few minutes of simulated-Edison figures).

mod common;

use std::time::Instant;

use harbor::config::{ExperimentConfig, SCALE_RANKS};
use harbor::coordinator::Coordinator;
use harbor::fem::exec::Exec;
use harbor::platform::Platform;
use harbor::runtime::CalibrationTable;
use harbor::workload::{run_poisson_app, AppConfig};

use common::record_bench;

fn cell_wall(python: bool, ranks: usize, batched: bool, table: &CalibrationTable) -> f64 {
    let t0 = Instant::now();
    let cfg = if python {
        AppConfig::python(ranks, 42)
    } else {
        AppConfig::cpp(ranks, 42)
    };
    let cfg = if batched { cfg } else { cfg.per_rank() };
    let mut exec = Exec::Modeled { table };
    let b = run_poisson_app(Platform::Native, &mut exec, &cfg).expect("app run");
    std::hint::black_box(b.total());
    t0.elapsed().as_secs_f64()
}

fn main() {
    let table = CalibrationTable::builtin_fallback();
    let mut rec: Vec<(String, f64)> = Vec::new();

    println!("== fig 3/4 cells on the batched engine ==");
    for &ranks in &SCALE_RANKS {
        let cpp = cell_wall(false, ranks, true, &table);
        println!("  fig3 cell {ranks:>6} ranks (batched):  {cpp:8.3} s");
        rec.push((format!("fig3_cell_{ranks}_batched_s"), cpp));
        let py = cell_wall(true, ranks, true, &table);
        println!("  fig4 cell {ranks:>6} ranks (batched):  {py:8.3} s");
        rec.push((format!("fig4_cell_{ranks}_batched_s"), py));
    }

    println!("== acceptance: batched vs per-rank at 12288 ranks ==");
    let batched = cell_wall(true, 12288, true, &table);
    let per_rank = cell_wall(true, 12288, false, &table);
    let speedup = per_rank / batched;
    println!(
        "  fig4 cell 12288 ranks: batched {batched:.3} s, per-rank {per_rank:.3} s => {speedup:.1}x"
    );
    rec.push(("fig4_cell_12288_per_rank_s".into(), per_rank));
    rec.push(("fig4_speedup_12288x".into(), speedup));
    if speedup < 10.0 {
        eprintln!("  WARNING: speedup below the 10x acceptance bar");
    }

    if std::env::var_os("FIG34_SCALE_FULL").is_some() {
        for figure in ["fig3", "fig4"] {
            let cfg = ExperimentConfig::paper_scale(figure).expect("scale config");
            let t0 = Instant::now();
            let figs = Coordinator::with_table(CalibrationTable::builtin_fallback())
                .run(&cfg)
                .expect("scale sweep");
            let wall = t0.elapsed().as_secs_f64();
            for f in &figs {
                println!("{}", f.render());
            }
            println!("[bench:{figure}-scale] full sweep in {wall:.3} s");
            rec.push((format!("{figure}_scale_sweep_wall_s"), wall));
        }
    }

    record_bench(&rec);
}
