//! Fleet-scale deployment bench: pull-makespan vs node count for the
//! `fig1-scale` sweep (64 → 1 048 576 nodes), cold and warm, recorded
//! into `BENCH_micro.json`.
//!
//! The sweep runs on the node-class collapsed engine ([`ClassFleet`]),
//! which prices a deploy in O(classes × layers) events instead of
//! O(nodes × layers) — that is what makes the 262 144 and 1 048 576
//! rows feasible inside a bench run. Every row at or below 16 384
//! nodes is cross-checked against the per-node reference walk
//! ([`Fleet`]): the two reports must render byte-identically, so the
//! big rows inherit the reference semantics from the small ones.
//!
//! Two kinds of numbers are recorded per fleet size `N`:
//!
//! * `fig1_cold_{N}_virt_s` / `fig1_warm_{N}_virt_s` — the *virtual*
//!   pull makespan the distribution model predicts (deterministic);
//! * `fig1_deploy_{N}_wall_s` — the wall time the simulator needs to
//!   compute the cold+warm pair (the simulator's own performance, the
//!   §Perf trajectory).
//!
//! The warm/cold ratio is also recorded as `fig1_warm_cold_ratio`; the
//! acceptance bar is < 0.10.

mod common;

use std::time::Instant;

use harbor::config::SCALE_NODES;
use harbor::container::{ClassFleet, Fleet, FleetConfig};
use harbor::coordinator::fleet_registry;

use common::record_bench;

/// Largest fleet the per-node reference walk is asked to reproduce for
/// the golden cross-check (the walk is O(nodes × layers), so this is a
/// wall-time budget, not a correctness limit).
const GOLDEN_CEILING: usize = 16_384;

fn main() {
    let reference = "quay.io/fenicsproject/stable:2016.1.0r1";
    let mut rec: Vec<(String, f64)> = Vec::new();
    let mut worst_ratio = 0.0f64;

    println!("== fig 1 at fleet scale: pull makespan vs node count ==");
    for &nodes in &SCALE_NODES {
        let t0 = Instant::now();
        let mut sharded = fleet_registry(reference).expect("fleet registry");
        let mut fleet = ClassFleet::new(FleetConfig::hpc(nodes));
        let cold = fleet.deploy(&mut sharded, reference).expect("cold deploy");
        let peak_classes = fleet.peak_classes();
        let warm = fleet.deploy(&mut sharded, reference).expect("warm deploy");
        let wall = t0.elapsed().as_secs_f64();

        if nodes <= GOLDEN_CEILING {
            let mut ref_sharded = fleet_registry(reference).expect("fleet registry");
            let mut ref_fleet = Fleet::new(FleetConfig::hpc(nodes));
            let ref_cold = ref_fleet
                .deploy(&mut ref_sharded, reference)
                .expect("reference cold deploy");
            let ref_warm = ref_fleet
                .deploy(&mut ref_sharded, reference)
                .expect("reference warm deploy");
            assert_eq!(
                cold.render(),
                ref_cold.render(),
                "collapsed cold deploy diverged from per-node reference at {nodes} nodes"
            );
            assert_eq!(
                warm.render(),
                ref_warm.render(),
                "collapsed warm deploy diverged from per-node reference at {nodes} nodes"
            );
        }

        let ratio = warm.makespan.as_secs_f64() / cold.makespan.as_secs_f64();
        worst_ratio = worst_ratio.max(ratio);
        println!(
            "  {nodes:>7} nodes: cold {:>9} (WAN {:>6.1} MB, intra {:>9.1} MB), \
             warm {:>9}, ratio {ratio:.5}, {:>3} peak classes, computed in {wall:.3} s",
            cold.makespan,
            cold.wan_bytes as f64 / 1e6,
            cold.intra_bytes as f64 / 1e6,
            warm.makespan,
            peak_classes,
        );
        println!("           scheduler: {}", cold.queue.render());
        rec.push((format!("fig1_cold_{nodes}_virt_s"), cold.makespan.as_secs_f64()));
        rec.push((format!("fig1_warm_{nodes}_virt_s"), warm.makespan.as_secs_f64()));
        rec.push((format!("fig1_deploy_{nodes}_wall_s"), wall));
        rec.push((format!("fig1_queue_hwm_{nodes}"), cold.queue.depth_hwm as f64));
    }

    println!("  worst warm/cold ratio: {worst_ratio:.5} (bar: < 0.10)");
    rec.push(("fig1_warm_cold_ratio".into(), worst_ratio));
    if worst_ratio >= 0.10 {
        eprintln!("  WARNING: warm-cache makespan above the 10% acceptance bar");
    }

    record_bench(&rec);
}
