//! Build-farm bench: the §4.3 `ARCH_OPT` variant matrix on 1..16 CI
//! workers, cold and warm, recorded into `BENCH_micro.json`.
//!
//! Three kinds of numbers are recorded:
//!
//! * `build_farm_cold_{W}_virt_s` / `build_farm_warm_{W}_virt_s` — the
//!   *virtual* farm makespan the DES predicts (deterministic), plus
//!   `build_farm_wall_{W}_s`, the wall time the simulator needs for
//!   the cold+warm pair (the §Perf trajectory);
//! * `build_cache_cold_hit_rate` / `build_cache_warm_hit_rate` and
//!   `build_wan_cold_mb` — the shared-cache economics of the matrix
//!   (warm must be 1.0 and 0 MB respectively);
//! * `build_dag_plan_ns_per_iter` / `build_warm_build_ns_per_iter` —
//!   ns/iter micro numbers for parsing+planning a multi-stage file and
//!   for a fully-cached rebuild (the simulator's own hot path).
//!
//! `build_farm_speedup_16x` (cold 1-worker / cold 16-worker) and
//! `build_farm_warm_cold_ratio` (acceptance bar: < 0.10) summarise the
//! figure.

mod common;

use std::time::Instant;

use harbor::config::FARM_WORKERS;
use harbor::container::{BuildGraph, Builder, Buildfile, LayerStore};
use harbor::scenario::build_farm::{BuildFarm, FarmConfig, variant_buildfile, variant_matrix};

use common::{record_bench, time_rec};

fn main() {
    let mut rec: Vec<(String, f64)> = Vec::new();
    let jobs = variant_matrix().expect("variant matrix parses");

    println!("== build farm: {}-variant ARCH_OPT matrix ==", jobs.len());
    let mut cold_by_workers: Vec<(usize, f64)> = Vec::new();
    let mut worst_ratio = 0.0f64;
    for &workers in &FARM_WORKERS {
        let t0 = Instant::now();
        let mut farm = BuildFarm::new(FarmConfig::ci(workers));
        let cold = farm.run_pass(&jobs).expect("cold pass");
        let warm = farm.run_pass(&jobs).expect("warm pass");
        let wall = t0.elapsed().as_secs_f64();

        let ratio = warm.makespan.as_secs_f64() / cold.makespan.as_secs_f64();
        worst_ratio = worst_ratio.max(ratio);
        println!(
            "  {workers:>2} workers: cold {:>9} (hit rate {:.0}%, WAN {:>6.1} MB, \
             gc {:>6.1} MB), warm {:>9} (hit rate {:.0}%), computed in {wall:.3} s",
            cold.makespan,
            cold.build_hit_rate() * 100.0,
            cold.wan_bytes as f64 / 1e6,
            cold.gc_bytes as f64 / 1e6,
            warm.makespan,
            warm.build_hit_rate() * 100.0,
        );
        println!("      scheduler: {}", cold.queue.render());
        cold_by_workers.push((workers, cold.makespan.as_secs_f64()));
        rec.push((format!("build_farm_cold_{workers}_virt_s"), cold.makespan.as_secs_f64()));
        rec.push((format!("build_farm_warm_{workers}_virt_s"), warm.makespan.as_secs_f64()));
        rec.push((format!("build_farm_wall_{workers}_s"), wall));
        if workers == FARM_WORKERS[0] {
            rec.push(("build_cache_cold_hit_rate".into(), cold.build_hit_rate()));
            rec.push(("build_cache_warm_hit_rate".into(), warm.build_hit_rate()));
            rec.push(("build_wan_cold_mb".into(), cold.wan_bytes as f64 / 1e6));
        }
    }

    let speedup = match (cold_by_workers.first(), cold_by_workers.last()) {
        (Some(&(_, serial)), Some(&(_, widest))) if widest > 0.0 => serial / widest,
        _ => 0.0,
    };
    println!("  cold farm speedup 1 -> 16 workers: {speedup:.2}x");
    println!("  worst warm/cold ratio: {worst_ratio:.5} (bar: < 0.10)");
    rec.push(("build_farm_speedup_16x".into(), speedup));
    rec.push(("build_farm_warm_cold_ratio".into(), worst_ratio));
    if worst_ratio >= 0.10 {
        eprintln!("  WARNING: warm-cache makespan above the 10% acceptance bar");
    }

    println!("== builder hot paths ==");
    let (app, pkgs) = harbor::scenario::build_farm::APPS[0];
    let text = variant_buildfile(app, pkgs, "haswell");
    time_rec(&mut rec, "build_dag_plan", "parse + plan 4-stage buildfile", || {
        let bf = Buildfile::parse(&text).expect("variant parses");
        std::hint::black_box(BuildGraph::plan(&bf));
    });
    let bf = Buildfile::parse(&text).expect("variant parses");
    let mut warm_builder = Builder::new();
    let mut store = LayerStore::new();
    warm_builder.build(&bf, "warm:1", &mut store).expect("prime the cache");
    time_rec(&mut rec, "build_warm_build", "fully-cached 4-stage rebuild", || {
        std::hint::black_box(warm_builder.build(&bf, "warm:1", &mut store).expect("warm"));
    });

    record_bench(&rec);
}
