//! Version-churn bench: the package-resolver tier end to end,
//! recorded into `BENCH_micro.json`.
//!
//! Recorded keys:
//!
//! * `resolve_fenics_ns_per_iter` — one cold resolution of the §2.2
//!   FEniCS stack manifest (17 packages) against the builtin index;
//! * `resolve_churn_invalidation_pct` — share of cold layers rebuilt
//!   after a numpy patch bump across the 4-arch variant matrix (the
//!   widest frontier in the stack);
//! * `resolve_frontier_ok` — 1.0 iff the stages the builder actually
//!   rebuilt equal the lockfile diff's predicted frontier, with the
//!   terminal stage re-linked (the invalidation contract);
//! * `resolve_determinism_ok` — 1.0 iff `version-churn` and
//!   `dep-storm` render byte-identically under `--jobs 1` and
//!   `--jobs 4` (the CI determinism gate fails on anything else);
//! * `resolve_wall_s` — wall time of both serial regenerations (the
//!   §Perf trajectory).

mod common;

use std::time::Instant;

use harbor::bench::{Figure, Row};
use harbor::config::ExperimentConfig;
use harbor::container::resolve::{
    emit_stack_buildfile, fenics_index, fenics_manifest, rebuilt_packages, resolve,
    terminal_rebuilt, Lockfile, STACK_BASE,
};
use harbor::container::{Builder, Buildfile, LayerStore};
use harbor::coordinator::Coordinator;
use harbor::scenario::build_farm::ARCHES;

use common::{record_bench, time_rec};

fn render_all(figs: &[Figure]) -> String {
    figs.iter().map(|f| f.render()).collect::<Vec<_>>().join("\n")
}

fn row<'a>(fig: &'a Figure, needle: &str) -> &'a Row {
    fig.rows
        .iter()
        .find(|r| r.label.contains(needle))
        .unwrap_or_else(|| panic!("no row matching `{needle}` in `{}`", fig.title))
}

fn part(r: &Row, key: &str) -> f64 {
    r.breakdown
        .iter()
        .find(|(k, _)| k == key)
        .map(|&(_, v)| v)
        .unwrap_or_else(|| panic!("row `{}` carries no `{key}` breakdown", r.label))
}

/// Direct frontier check against the builder (the same contract the
/// scenario cells assert, measured here without the scenario harness):
/// bump numpy, rebuild every arch variant on a fork of the cold cache,
/// and compare the rebuilt package stages to the lockfile prediction.
fn frontier_check() -> f64 {
    let mut index = fenics_index();
    let manifest = fenics_manifest();
    let lock1 = Lockfile::from_resolution(&resolve(&manifest, &index, 0).unwrap(), &index);
    let mut builder = Builder::new();
    let mut store = LayerStore::new();
    for arch in ARCHES {
        let text = emit_stack_buildfile(&manifest, &lock1, STACK_BASE, Some(arch)).unwrap();
        let bf = Buildfile::parse(&text).unwrap();
        builder.build(&bf, &format!("bench/{arch}:cold"), &mut store).unwrap();
    }
    index.bump_patch("numpy").expect("numpy is indexed");
    let lock2 = Lockfile::from_resolution(&resolve(&manifest, &index, 0).unwrap(), &index);
    let frontier = lock1.diff(&lock2).rebuild_frontier(&lock2);
    for arch in ARCHES {
        let text = emit_stack_buildfile(&manifest, &lock2, STACK_BASE, Some(arch)).unwrap();
        let bf = Buildfile::parse(&text).unwrap();
        let mut fork = builder.fork();
        let warm = fork.build(&bf, &format!("bench/{arch}:warm"), &mut store).unwrap();
        if rebuilt_packages(&bf, &warm) != frontier || !terminal_rebuilt(&warm) {
            eprintln!("  WARNING: {arch} rebuilt set diverged from the predicted frontier");
            return 0.0;
        }
    }
    1.0
}

fn main() {
    let mut rec: Vec<(String, f64)> = Vec::new();
    println!("== version churn: resolver micro + scenario regeneration ==");

    let index = fenics_index();
    let manifest = fenics_manifest();
    time_rec(&mut rec, "resolve_fenics", "resolve fenics-stack (17 pkgs)", || {
        let res = resolve(&manifest, &index, 0).unwrap();
        std::hint::black_box(&res);
    });

    let frontier_ok = frontier_check();

    let churn_cfg = ExperimentConfig::paper_default("version-churn").expect("registered");
    let storm_cfg = ExperimentConfig::paper_default("dep-storm").expect("registered");
    let t0 = Instant::now();
    let churn = Coordinator::new().with_jobs(1).run(&churn_cfg).expect("version-churn runs");
    let storm = Coordinator::new().with_jobs(1).run(&storm_cfg).expect("dep-storm runs");
    let wall = t0.elapsed().as_secs_f64();
    for f in churn.iter().chain(storm.iter()) {
        println!("{}", f.render());
    }

    // determinism gate: both scenarios again on 4 workers must render
    // byte-for-byte the same figures
    let churn4 = Coordinator::new().with_jobs(4).run(&churn_cfg).expect("version-churn (4 jobs)");
    let storm4 = Coordinator::new().with_jobs(4).run(&storm_cfg).expect("dep-storm (4 jobs)");
    let deterministic =
        render_all(&churn) == render_all(&churn4) && render_all(&storm) == render_all(&storm4);
    if !deterministic {
        eprintln!("  WARNING: --jobs 1 and --jobs 4 renders differ");
    }

    let churn_fig = churn.first().expect("version-churn assembles a figure");
    let numpy = row(churn_fig, "bump numpy");
    let invalidation = part(numpy, "invalidation %");
    println!(
        "  bump numpy: {:.1}% of cold layers rebuilt over {} stage frontier in {:.1} virtual s; \
         computed in {wall:.3} s (frontier ok: {frontier_ok}, deterministic: {deterministic})",
        invalidation,
        part(numpy, "frontier stages"),
        numpy.stats.mean(),
    );

    rec.push(("resolve_churn_invalidation_pct".into(), invalidation));
    rec.push(("resolve_frontier_ok".into(), frontier_ok));
    rec.push((
        "resolve_determinism_ok".into(),
        if deterministic { 1.0 } else { 0.0 },
    ));
    rec.push(("resolve_wall_s".into(), wall));
    record_bench(&rec);
}
