# AOT export: lower every L2 entry point to HLO *text* + a manifest.
#
# Interchange format is HLO text, NOT serialized HloModuleProto:
# jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
# pinned xla_extension (0.5.1) rejects (`proto.id() <= INT_MAX`); the text
# parser reassigns ids and round-trips cleanly.  Lowered with
# return_tuple=True, so the Rust side unwraps a tuple even for single
# outputs.  (See /opt/xla-example/load_hlo and its README.)
#
# This script is the ONLY place Python touches the build: `make artifacts`
# runs it once; the Rust binary is self-contained afterwards.
#
# Usage:  python -m compile.aot --out ../artifacts [--only name1,name2]

import argparse
import hashlib
import json
import os
import sys
import time

import jax

from . import model
from .kernels import ref  # noqa: F401  (import check: oracle must stay in sync)


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_entry(name, fn, specs, out_dir):
    t0 = time.time()
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    outs = jax.eval_shape(fn, *specs)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    meta = {
        "name": name,
        "file": f"{name}.hlo.txt",
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
        "inputs": [
            {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
        ],
        "outputs": [
            {"shape": list(o.shape), "dtype": str(o.dtype)} for o in outs
        ],
        "elapsed_s": round(time.time() - t0, 3),
    }
    return meta


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", default="", help="comma-separated entry filter")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    only = {s for s in args.only.split(",") if s}
    entries = {
        k: v for k, v in model.ENTRIES.items() if not only or k in only
    }
    manifest = {"format": "hlo-text/return-tuple", "entries": []}
    for name, (fn, specs) in sorted(entries.items()):
        meta = export_entry(name, fn, specs, args.out)
        manifest["entries"].append(meta)
        print(f"  [aot] {name:28s} {meta['elapsed_s']:6.2f}s", file=sys.stderr)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['entries'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
