# Pure-jnp correctness oracles for every Pallas kernel in this package.
#
# These are the ground truth used by pytest (and, transitively, by the Rust
# integration tests, which compare the distributed solve against a hash of
# the single-domain solution computed from these functions).
#
# Conventions (shared with the Pallas kernels and the Rust `fem` module):
#   * Scalar fields carry a one-cell halo ring: a local (nz, ny, nx)
#     interior is stored as (nz+2, ny+2, nx+2).  Physical (Dirichlet)
#     boundaries hold zeros in the halo; interior halos are filled by the
#     (simulated) MPI exchange before any stencil application.
#   * Vector fields (elasticity) have a leading component axis: shape
#     (3, nz+2, ny+2, nx+2).
#   * All stencils are the standard second-order finite-difference /
#     lowest-order FEM lumped operators on a uniform grid with spacing h.
#     We work with the *scaled* operator A = -h^2 * Laplacian so that
#     matrix entries are O(1) regardless of resolution (this is what the
#     exported HLO computes; the h^2 scaling of the RHS happens at
#     assembly time).

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Laplacians (scaled: A u = -h^2 lap(u), i.e. 6u - sum(neighbours) in 3D)
# ---------------------------------------------------------------------------

def laplace2d_apply(u_halo):
    """A u for the 5-point 2D Laplacian. u_halo: (ny+2, nx+2) -> (ny, nx)."""
    c = u_halo[1:-1, 1:-1]
    return (
        4.0 * c
        - u_halo[:-2, 1:-1]
        - u_halo[2:, 1:-1]
        - u_halo[1:-1, :-2]
        - u_halo[1:-1, 2:]
    )


def laplace3d_apply(u_halo):
    """A u for the 7-point 3D Laplacian. u_halo: (nz+2, ny+2, nx+2) -> (nz, ny, nx)."""
    c = u_halo[1:-1, 1:-1, 1:-1]
    return (
        6.0 * c
        - u_halo[:-2, 1:-1, 1:-1]
        - u_halo[2:, 1:-1, 1:-1]
        - u_halo[1:-1, :-2, 1:-1]
        - u_halo[1:-1, 2:, 1:-1]
        - u_halo[1:-1, 1:-1, :-2]
        - u_halo[1:-1, 1:-1, 2:]
    )


# ---------------------------------------------------------------------------
# Linear elasticity (vector Lamé operator, scaled by -h^2)
#
#   (A u)_i = -h^2 [ mu * lap(u_i) + (lam + mu) * d_i (div u) ]
#
# discretised with central differences; the mixed second derivatives use
# the standard 4-point cross stencil.
# ---------------------------------------------------------------------------

def _d2(u, axis):
    """h^2 * second derivative along `axis` for a halo-padded 3D array."""
    sl = [slice(1, -1)] * 3
    lo = list(sl)
    hi = list(sl)
    lo[axis] = slice(0, -2)
    hi[axis] = slice(2, None)
    return u[tuple(lo)] + u[tuple(hi)] - 2.0 * u[tuple(sl)]


def _dxy(u, ax_a, ax_b):
    """4h^2 * mixed second derivative d^2 u / (d ax_a d ax_b), halo-padded."""
    idx = [slice(1, -1)] * 3

    def shifted(da, db):
        s = list(idx)
        s[ax_a] = slice(2, None) if da == 1 else slice(0, -2)
        s[ax_b] = slice(2, None) if db == 1 else slice(0, -2)
        return u[tuple(s)]

    return shifted(1, 1) - shifted(1, -1) - shifted(-1, 1) + shifted(-1, -1)


def elasticity3d_apply(u_halo, mu=1.0, lam=1.0):
    """A u for the scaled Lamé operator. u_halo: (3, nz+2, ny+2, nx+2)."""
    comps = []
    for i in range(3):
        # mu * lap(u_i)  (h^2-scaled)
        lap_i = _d2(u_halo[i], 0) + _d2(u_halo[i], 1) + _d2(u_halo[i], 2)
        # (lam + mu) * d_i div(u): d_i d_j u_j
        grad_div = jnp.zeros_like(lap_i)
        for j in range(3):
            if i == j:
                grad_div = grad_div + _d2(u_halo[j], i)
            else:
                grad_div = grad_div + 0.25 * _dxy(u_halo[j], i, j)
        comps.append(-(mu * lap_i + (lam + mu) * grad_div))
    return jnp.stack(comps)


ELAST_DIAG = 6.0 + 2.0  # diagonal of the scaled Lamé operator (mu=lam=1)


# ---------------------------------------------------------------------------
# Smoothers and grid transfer (geometric multigrid building blocks)
# ---------------------------------------------------------------------------

DIAG3D = 6.0  # diagonal of the scaled 7-point operator


def jacobi3d(u_halo, f, omega=2.0 / 3.0):
    """One weighted-Jacobi sweep. Returns the updated *interior* (nz,ny,nx)."""
    r = f - laplace3d_apply(u_halo)
    return u_halo[1:-1, 1:-1, 1:-1] + (omega / DIAG3D) * r


def residual3d(u_halo, f):
    """r = f - A u on the interior."""
    return f - laplace3d_apply(u_halo)


def restrict3d(r):
    """Full-weighting restriction (2n,2n,2n) -> (n,n,n) by 2x2x2 averaging.

    Cell-centred full weighting: coarse cell = mean of its 8 fine children.
    """
    n2 = r.shape[0]
    n = n2 // 2
    return r.reshape(n, 2, n, 2, n, 2).mean(axis=(1, 3, 5))


def prolong3d(e):
    """Cell-centred trilinear prolongation (n,n,n) -> (2n,2n,2n).

    Per axis: fine(2j)   = 0.75 c_j + 0.25 c_{j-1},
              fine(2j+1) = 0.75 c_j + 0.25 c_{j+1},
    with zero (Dirichlet) ghosts outside the domain.  Paired with
    full-weighting restriction and a 4x residual scaling (the (2h/h)^2
    factor of the *scaled* operator), this gives the standard convergent
    cell-centred V-cycle (asymptotic factor ~0.45 with nu=2 Jacobi).
    """

    def interp(a, axis):
        sl = lambda s: tuple(
            s if d == axis else slice(None) for d in range(a.ndim)
        )
        c = a[sl(slice(1, -1))]
        lo = a[sl(slice(0, -2))]
        hi = a[sl(slice(2, None))]
        even = 0.75 * c + 0.25 * lo
        odd = 0.75 * c + 0.25 * hi
        st = jnp.stack([even, odd], axis=axis + 1)
        shp = list(c.shape)
        shp[axis] *= 2
        return st.reshape(shp)

    out = e
    for ax in range(3):
        pad_width = [(1, 1) if d == ax else (0, 0) for d in range(3)]
        out = interp(jnp.pad(out, pad_width), ax)
    return out


def restrict3d_tri(r_halo):
    """Variational restriction R = P^T / 8 for the trilinear P:
    (2n+2)^3 halo-padded fine residual -> n^3 coarse.

    Per axis: c_j = (0.25 f_{2j-1} + 0.75 f_{2j} + 0.75 f_{2j+1}
    + 0.25 f_{2j+2}) / 2 (indices in halo-padded coordinates).  Using
    the transpose of the prolongation makes the coarse-grid correction
    (quasi-)variational — the plain 8-mean restriction paired with
    trilinear P over-corrects and the V-cycle diverges on deep ladders.
    """
    out = r_halo
    for ax in range(3):
        m = out.shape[ax] - 2
        sl = lambda s: tuple(s if d == ax else slice(None) for d in range(out.ndim))
        a = out[sl(slice(0, m, 2))]
        b = out[sl(slice(1, m + 1, 2))]
        c = out[sl(slice(2, m + 2, 2))]
        d = out[sl(slice(3, None, 2))]
        out = (0.25 * a + 0.75 * b + 0.75 * c + 0.25 * d) / 2.0
    return out


def prolong3d_halo(e_halo):
    """Trilinear prolongation with supplied ghosts: (n+2)^3 -> (2n)^3.

    Each axis pass consumes that axis's ghost layer.  With a zero-padded
    input this equals `prolong3d` exactly; with exchanged halos it
    interpolates across block interfaces (the distributed ladder).
    """

    def interp(a, axis):
        sl = lambda s: tuple(
            s if d == axis else slice(None) for d in range(a.ndim)
        )
        c = a[sl(slice(1, -1))]
        lo = a[sl(slice(0, -2))]
        hi = a[sl(slice(2, None))]
        st = jnp.stack([0.75 * c + 0.25 * lo, 0.75 * c + 0.25 * hi], axis=axis + 1)
        shp = list(c.shape)
        shp[axis] *= 2
        return st.reshape(shp)

    out = e_halo
    for ax in range(3):
        out = interp(out, ax)
    return out


RESID_COARSE_SCALE = 4.0  # (2h)^2 / h^2 for the h^2-scaled operator


# ---------------------------------------------------------------------------
# BLAS-1 helpers (what the fused CG-step kernels must match)
# ---------------------------------------------------------------------------

def dot(a, b):
    return jnp.vdot(a, b)


def axpy(alpha, x, y):
    return alpha * x + y


# ---------------------------------------------------------------------------
# Whole-problem references (used by model-level tests and by the Rust
# integration tests through saved oracle values)
# ---------------------------------------------------------------------------

def pad_halo3d(u):
    return jnp.pad(u, 1)


def pad_halo2d(u):
    return jnp.pad(u, 1)


def cg_solve3d(f, tol=1e-6, maxiter=500):
    """Single-domain CG for the scaled 3D Poisson operator. Returns (u, iters)."""
    u = jnp.zeros_like(f)
    r = f
    p = r
    rr = dot(r, r)
    f_norm = max(float(jnp.sqrt(dot(f, f))), 1e-30)
    it = 0
    while it < maxiter and float(jnp.sqrt(rr)) > tol * f_norm:
        ap = laplace3d_apply(pad_halo3d(p))
        alpha = rr / dot(p, ap)
        u = u + alpha * p
        r = r - alpha * ap
        rr_new = dot(r, r)
        p = r + (rr_new / rr) * p
        rr = rr_new
        it += 1
    return u, it


def vcycle3d(u, f, nu=2, min_n=4):
    """One geometric-multigrid V-cycle on the scaled 3D Poisson operator.

    u, f: (n, n, n) interiors with zero Dirichlet halo. Recursion at trace
    time (sizes halve until min_n), Jacobi smoothing, exact-ish coarse
    solve by extra sweeps.
    """
    n = u.shape[0]
    if n <= min_n:
        for _ in range(8 * nu):
            u = jacobi3d(pad_halo3d(u), f)
        return u
    for _ in range(nu):
        u = jacobi3d(pad_halo3d(u), f)
    r = residual3d(pad_halo3d(u), f)
    rc = RESID_COARSE_SCALE * restrict3d_tri(jnp.pad(r, 1))
    ec = vcycle3d(jnp.zeros_like(rc), rc, nu=nu, min_n=min_n)
    u = u + prolong3d(ec)
    for _ in range(nu):
        u = jacobi3d(pad_halo3d(u), f)
    return u


def dense_poisson2d(n):
    """Dense matrix of the scaled 5-point operator on an n x n interior grid."""
    t = 2.0 * jnp.eye(n) - jnp.eye(n, k=1) - jnp.eye(n, k=-1)
    i = jnp.eye(n)
    return jnp.kron(t, i) + jnp.kron(i, t)


def lu_solve2d(f):
    """Direct solve of the 2D scaled Poisson problem; f, result: (n, n)."""
    n = f.shape[0]
    a = dense_poisson2d(n)
    u = jnp.linalg.solve(a, f.reshape(-1))
    return u.reshape(n, n)


def manufactured_rhs3d(n_global, origin, n_local, h):
    """RHS f = h^2 * source for u_exact = sin(pi x) sin(pi y) sin(pi z).

    origin: (iz, iy, ix) global index of this rank's first interior cell.
    Cell-centred coordinates: x_i = (i + 0.5) * h.
    """
    import numpy as np

    iz, iy, ix = origin
    z = (np.arange(iz, iz + n_local) + 0.5) * h
    y = (np.arange(iy, iy + n_local) + 0.5) * h
    x = (np.arange(ix, ix + n_local) + 0.5) * h
    zz, yy, xx = np.meshgrid(z, y, x, indexing="ij")
    src = 3.0 * np.pi**2 * np.sin(np.pi * xx) * np.sin(np.pi * yy) * np.sin(np.pi * zz)
    return jnp.asarray(h * h * src, dtype=jnp.float32)
