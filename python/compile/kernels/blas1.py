# L1 Pallas kernels: fused BLAS-1 fragments of the CG iteration.
#
# CG's non-stencil work is bandwidth-bound vector arithmetic.  Fusing the
# solution/residual update with the local reduction (x' = x + a p,
# r' = r - a Ap, rr = <r', r'>) means each vector is streamed through
# VMEM exactly once per iteration — the same fusion FEniCS gets from
# PETSc's VecAXPY/VecDot pipelining on the paper's testbeds.

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .stencil import INTERPRET


def _dot_kernel(a_ref, b_ref, o_ref):
    o_ref[0] = jnp.sum(a_ref[...] * b_ref[...])


def dot(a, b):
    """<a, b> over flat f32 vectors; returns shape-(1,) partial sum."""
    return pl.pallas_call(
        _dot_kernel,
        out_shape=jax.ShapeDtypeStruct((1,), a.dtype),
        interpret=INTERPRET,
    )(a, b)


def _axpy_kernel(alpha_ref, x_ref, y_ref, o_ref):
    o_ref[...] = alpha_ref[0] * x_ref[...] + y_ref[...]


def axpy(alpha, x, y):
    """alpha * x + y; alpha is a shape-(1,) array."""
    return pl.pallas_call(
        _axpy_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=INTERPRET,
    )(alpha, x, y)


def _cg_update_kernel(alpha_ref, x_ref, r_ref, p_ref, ap_ref, xo_ref, ro_ref, rro_ref):
    a = alpha_ref[0]
    xo_ref[...] = x_ref[...] + a * p_ref[...]
    rn = r_ref[...] - a * ap_ref[...]
    ro_ref[...] = rn
    rro_ref[0] = jnp.sum(rn * rn)


def cg_update(alpha, x, r, p, ap):
    """Fused CG update: (x + a p, r - a Ap, <r', r'>). Flat vectors."""
    n = x.shape[0]
    return pl.pallas_call(
        _cg_update_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n,), x.dtype),
            jax.ShapeDtypeStruct((n,), x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype),
        ),
        interpret=INTERPRET,
    )(alpha, x, r, p, ap)


def _cg_pupdate_kernel(beta_ref, r_ref, p_ref, o_ref):
    o_ref[...] = r_ref[...] + beta_ref[0] * p_ref[...]


def cg_pupdate(beta, r, p):
    """p' = r + beta * p. Flat vectors."""
    return pl.pallas_call(
        _cg_pupdate_kernel,
        out_shape=jax.ShapeDtypeStruct(p.shape, p.dtype),
        interpret=INTERPRET,
    )(beta, r, p)
