# L1 Pallas kernels: fused weighted-Jacobi smoother and residual.
#
# The multigrid smoother is the inner loop of both the Fig 2 "Poisson AMG"
# substitute (CG + geometric-multigrid preconditioner) and the HPGMG-FE
# benchmark (Fig 5).  Fusing residual + update into one kernel keeps the
# slab resident in VMEM for both the stencil read and the axpy write —
# that fusion is exactly the optimisation HPGMG's reference implementation
# performs with its "fused smooth" loops.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .stencil import INTERPRET, _pick_bz

DIAG3D = 6.0  # diagonal of the scaled 7-point operator


def _jacobi3d_kernel(u_ref, f_ref, o_ref, *, bz, omega):
    i = pl.program_id(0)
    s = u_ref[pl.dslice(i * bz, bz + 2), :, :]
    fb = f_ref[pl.dslice(i * bz, bz), :, :]
    c = s[1:-1, 1:-1, 1:-1]
    au = (
        6.0 * c
        - s[:-2, 1:-1, 1:-1]
        - s[2:, 1:-1, 1:-1]
        - s[1:-1, :-2, 1:-1]
        - s[1:-1, 2:, 1:-1]
        - s[1:-1, 1:-1, :-2]
        - s[1:-1, 1:-1, 2:]
    )
    o_ref[pl.dslice(i * bz, bz), :, :] = c + (omega / DIAG3D) * (fb - au)


def jacobi3d(u_halo, f, omega=2.0 / 3.0, *, vmem_budget_cells=1 << 20):
    """Fused weighted-Jacobi sweep: returns updated interior (nz, ny, nx)."""
    nzp, nyp, nxp = u_halo.shape
    nz, ny, nx = nzp - 2, nyp - 2, nxp - 2
    bz = _pick_bz(nz, vmem_budget_cells // 2, nyp * nxp)
    return pl.pallas_call(
        functools.partial(_jacobi3d_kernel, bz=bz, omega=omega),
        out_shape=jax.ShapeDtypeStruct((nz, ny, nx), u_halo.dtype),
        grid=(nz // bz,),
        interpret=INTERPRET,
    )(u_halo, f)


def _residual3d_kernel(u_ref, f_ref, o_ref, *, bz):
    i = pl.program_id(0)
    s = u_ref[pl.dslice(i * bz, bz + 2), :, :]
    fb = f_ref[pl.dslice(i * bz, bz), :, :]
    c = s[1:-1, 1:-1, 1:-1]
    au = (
        6.0 * c
        - s[:-2, 1:-1, 1:-1]
        - s[2:, 1:-1, 1:-1]
        - s[1:-1, :-2, 1:-1]
        - s[1:-1, 2:, 1:-1]
        - s[1:-1, 1:-1, :-2]
        - s[1:-1, 1:-1, 2:]
    )
    o_ref[pl.dslice(i * bz, bz), :, :] = fb - au


def residual3d(u_halo, f, *, vmem_budget_cells=1 << 20):
    """r = f - A u on the interior (nz, ny, nx)."""
    nzp, nyp, nxp = u_halo.shape
    nz, ny, nx = nzp - 2, nyp - 2, nxp - 2
    bz = _pick_bz(nz, vmem_budget_cells // 2, nyp * nxp)
    return pl.pallas_call(
        functools.partial(_residual3d_kernel, bz=bz),
        out_shape=jax.ShapeDtypeStruct((nz, ny, nx), u_halo.dtype),
        grid=(nz // bz,),
        interpret=INTERPRET,
    )(u_halo, f)
