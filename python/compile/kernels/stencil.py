# L1 Pallas kernels: stencil operator applications.
#
# The computational hot-spot of every workload in the paper's evaluation
# (Poisson CG/AMG, elasticity, HPGMG) is the application of a constant-
# coefficient stencil to a halo-padded block.  These kernels express that
# hot-spot as Pallas kernels that stream z-slabs through VMEM-sized tiles:
# the input block for grid step i is the slab [i*bz, i*bz + bz + 2) of the
# halo-padded array (one halo ring kept resident), the output block is the
# interior slab [i*bz, i*bz + bz).
#
# interpret=True everywhere: this session's PJRT backend is CPU; real-TPU
# lowering would emit a Mosaic custom-call the CPU plugin cannot execute.
# The BlockSpec/tiling structure is still the real one — see
# DESIGN.md §10 for the VMEM/MXU accounting.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT: interpret mode is mandatory (see module docstring)


def _pick_bz(nz: int, budget_cells: int, plane: int) -> int:
    """Largest slab depth whose (bz+2)-deep input tile fits the cell budget."""
    bz = max(1, min(nz, budget_cells // max(plane, 1) - 2))
    while nz % bz != 0:
        bz -= 1
    return max(bz, 1)


# ---------------------------------------------------------------------------
# 7-point 3D Laplacian:  out = 6*c - sum(face neighbours)
# ---------------------------------------------------------------------------

def _laplace3d_kernel(u_ref, o_ref, *, bz):
    i = pl.program_id(0)
    # Load one z-slab plus its two halo planes; y/x halos are in the slab.
    s = u_ref[pl.dslice(i * bz, bz + 2), :, :]
    c = s[1:-1, 1:-1, 1:-1]
    lap = (
        6.0 * c
        - s[:-2, 1:-1, 1:-1]
        - s[2:, 1:-1, 1:-1]
        - s[1:-1, :-2, 1:-1]
        - s[1:-1, 2:, 1:-1]
        - s[1:-1, 1:-1, :-2]
        - s[1:-1, 1:-1, 2:]
    )
    o_ref[pl.dslice(i * bz, bz), :, :] = lap


def laplace3d_apply(u_halo, *, vmem_budget_cells=1 << 20):
    """A u for the scaled 7-point operator. u_halo: (nz+2, ny+2, nx+2)."""
    nzp, nyp, nxp = u_halo.shape
    nz, ny, nx = nzp - 2, nyp - 2, nxp - 2
    bz = _pick_bz(nz, vmem_budget_cells, nyp * nxp)
    return pl.pallas_call(
        functools.partial(_laplace3d_kernel, bz=bz),
        out_shape=jax.ShapeDtypeStruct((nz, ny, nx), u_halo.dtype),
        grid=(nz // bz,),
        interpret=INTERPRET,
    )(u_halo)


# ---------------------------------------------------------------------------
# 5-point 2D Laplacian (whole-array kernel; 2D problems are small)
# ---------------------------------------------------------------------------

def _laplace2d_kernel(u_ref, o_ref):
    s = u_ref[...]
    c = s[1:-1, 1:-1]
    o_ref[...] = 4.0 * c - s[:-2, 1:-1] - s[2:, 1:-1] - s[1:-1, :-2] - s[1:-1, 2:]


def laplace2d_apply(u_halo):
    """A u for the scaled 5-point operator. u_halo: (ny+2, nx+2)."""
    nyp, nxp = u_halo.shape
    return pl.pallas_call(
        _laplace2d_kernel,
        out_shape=jax.ShapeDtypeStruct((nyp - 2, nxp - 2), u_halo.dtype),
        interpret=INTERPRET,
    )(u_halo)


# ---------------------------------------------------------------------------
# Lamé (linear elasticity) operator, vector field (3, nz+2, ny+2, nx+2).
# Fused kernel: all three output components computed from one resident
# slab of all three input components (9 stencil passes share loads).
# ---------------------------------------------------------------------------

def _elast3d_kernel(u_ref, o_ref, *, bz, mu, lam):
    i = pl.program_id(0)
    s = u_ref[:, pl.dslice(i * bz, bz + 2), :, :]  # (3, bz+2, ny+2, nx+2)

    def d2(a, axis):
        sl = [slice(1, -1)] * 3
        lo, hi = list(sl), list(sl)
        lo[axis] = slice(0, -2)
        hi[axis] = slice(2, None)
        return a[tuple(lo)] + a[tuple(hi)] - 2.0 * a[tuple(sl)]

    def dxy(a, ax_a, ax_b):
        def shifted(da, db):
            sl = [slice(1, -1)] * 3
            sl[ax_a] = slice(2, None) if da == 1 else slice(0, -2)
            sl[ax_b] = slice(2, None) if db == 1 else slice(0, -2)
            return a[tuple(sl)]

        return shifted(1, 1) - shifted(1, -1) - shifted(-1, 1) + shifted(-1, -1)

    outs = []
    for ci in range(3):
        lap_i = d2(s[ci], 0) + d2(s[ci], 1) + d2(s[ci], 2)
        grad_div = d2(s[ci], ci)
        for cj in range(3):
            if cj != ci:
                grad_div = grad_div + 0.25 * dxy(s[cj], ci, cj)
        outs.append(-(mu * lap_i + (lam + mu) * grad_div))
    o_ref[:, pl.dslice(i * bz, bz), :, :] = jnp.stack(outs)


def elasticity3d_apply(u_halo, mu=1.0, lam=1.0, *, vmem_budget_cells=1 << 20):
    """A u for the scaled Lamé operator. u_halo: (3, nz+2, ny+2, nx+2)."""
    _, nzp, nyp, nxp = u_halo.shape
    nz, ny, nx = nzp - 2, nyp - 2, nxp - 2
    bz = _pick_bz(nz, vmem_budget_cells // 3, nyp * nxp)
    return pl.pallas_call(
        functools.partial(_elast3d_kernel, bz=bz, mu=mu, lam=lam),
        out_shape=jax.ShapeDtypeStruct((3, nz, ny, nx), u_halo.dtype),
        grid=(nz // bz,),
        interpret=INTERPRET,
    )(u_halo)
