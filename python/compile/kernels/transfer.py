# L1 Pallas kernels: multigrid grid-transfer operators.
#
# Cell-centred full-weighting restriction (mean of 8 fine children) and
# cell-centred trilinear prolongation (Dirichlet ghosts).
# Whole-array kernels: transfer operands are at most the fine-level block,
# and the coarse side is 8x smaller, so a single VMEM-resident tile
# suffices for every level of the HPGMG ladder we export (<= 64^3 local).

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .stencil import INTERPRET


def _restrict3d_kernel(r_ref, o_ref):
    r = r_ref[...]
    n = r.shape[0] // 2
    o_ref[...] = r.reshape(n, 2, n, 2, n, 2).mean(axis=(1, 3, 5))


def restrict3d(r):
    """Full-weighting (8-mean) restriction (2n)^3 -> n^3."""
    n = r.shape[0] // 2
    return pl.pallas_call(
        _restrict3d_kernel,
        out_shape=jax.ShapeDtypeStruct((n, n, n), r.dtype),
        interpret=INTERPRET,
    )(r)


def _restrict3d_tri_kernel(r_ref, o_ref):
    out = r_ref[...]
    for ax in range(3):
        m = out.shape[ax] - 2
        sl = lambda s: tuple(s if d == ax else slice(None) for d in range(out.ndim))
        a = out[sl(slice(0, m, 2))]
        b = out[sl(slice(1, m + 1, 2))]
        c = out[sl(slice(2, m + 2, 2))]
        d = out[sl(slice(3, None, 2))]
        out = (0.25 * a + 0.75 * b + 0.75 * c + 0.25 * d) / 2.0
    o_ref[...] = out


def restrict3d_tri(r_halo):
    """Variational restriction R = P^T / 8 (transpose of the trilinear
    prolongation): halo-padded (2n+2)^3 fine residual -> n^3 coarse.
    The halo carries neighbour residuals at block interfaces (zeros at
    physical boundaries), so the distributed restriction equals the
    global one."""
    n = (r_halo.shape[0] - 2) // 2
    return pl.pallas_call(
        _restrict3d_tri_kernel,
        out_shape=jax.ShapeDtypeStruct((n, n, n), r_halo.dtype),
        interpret=INTERPRET,
    )(r_halo)


def _interp_axis(a, axis):
    """One axis of cell-centred trilinear interpolation; `a` has ghosts
    along `axis`: fine(2j) = .75 c_j + .25 c_{j-1}, fine(2j+1) = .75 c_j +
    .25 c_{j+1}."""
    sl = lambda s: tuple(s if d == axis else slice(None) for d in range(a.ndim))
    c = a[sl(slice(1, -1))]
    lo = a[sl(slice(0, -2))]
    hi = a[sl(slice(2, None))]
    st = jnp.stack([0.75 * c + 0.25 * lo, 0.75 * c + 0.25 * hi], axis=axis + 1)
    shp = list(c.shape)
    shp[axis] *= 2
    return st.reshape(shp)


def _prolong3d_halo_kernel(e_ref, o_ref):
    # input is fully halo-padded (n+2)^3; each axis pass consumes that
    # axis's ghost layer: (m, ...) -> (2(m-2), ...)
    out = e_ref[...]
    for ax in range(3):
        out = _interp_axis(out, ax)
    o_ref[...] = out


def prolong3d_halo(e_halo):
    """Cell-centred trilinear prolongation with *supplied* ghosts:
    (n+2)^3 -> (2n)^3.

    In the distributed multigrid ladder the ghosts come from the halo
    exchange of the coarse correction — interpolating with real
    neighbour values (instead of zeros) at block interfaces is what
    keeps the V-cycle factor grid-independent across ranks.  (Edge and
    corner ghosts are not exchanged and enter as whatever the caller
    padded; the resulting perturbation lives on O(n) cells per block
    versus O(n^2) for faces.)
    """
    n = e_halo.shape[0] - 2
    return pl.pallas_call(
        _prolong3d_halo_kernel,
        out_shape=jax.ShapeDtypeStruct((2 * n, 2 * n, 2 * n), e_halo.dtype),
        interpret=INTERPRET,
    )(e_halo)


def prolong3d(e):
    """Cell-centred trilinear prolongation n^3 -> (2n)^3 with zero
    (Dirichlet) ghosts — the single-domain case."""
    return prolong3d_halo(jnp.pad(e, 1))
