# L1 Pallas kernels + pure-jnp oracle (ref.py).
from . import blas1, ref, smoother, stencil, transfer  # noqa: F401
