# L2: JAX compute graphs for the FEM workload suite, composed from the
# Pallas kernels in `kernels/` and AOT-exported (by `aot.py`) as HLO text
# for the Rust coordinator.
#
# Every function here is a *per-rank local* computation: distributed
# structure (halo exchange, allreduce) lives in Rust (`harbor::mpi`,
# `harbor::fem`).  Each exported entry point therefore takes halo-padded
# local blocks and returns local partials, so the HLO is identical whether
# the rank is one of 1 or one of 192.
#
# Entry-point registry: `ENTRIES` maps artifact name -> (fn, arg specs).
# `aot.py` lowers each entry with jax.jit(...).lower(*specs), converts to
# HLO *text* (see aot.py for why text, not serialized proto) and writes
# artifacts/<name>.hlo.txt plus a manifest consumed by `harbor::runtime`.

import functools

import jax
import jax.numpy as jnp

from .kernels import blas1, smoother, stencil, transfer

F32 = jnp.float32


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


# ---------------------------------------------------------------------------
# CG building blocks (Poisson 3D and elasticity 3D)
# ---------------------------------------------------------------------------

def cg_apdot_p3d(p_halo):
    """Ap = A p (7-point), plus the local partial <p, Ap>.

    p_halo: (n+2, n+2, n+2) halo-padded search direction.
    Returns (Ap flat (n^3,), pAp partial (1,)).
    """
    ap = stencil.laplace3d_apply(p_halo)
    apf = ap.reshape(-1)
    pf = p_halo[1:-1, 1:-1, 1:-1].reshape(-1)
    return apf, blas1.dot(pf, apf)


def cg_apdot_el3d(u_halo):
    """Lamé-operator apply + local <p, Ap>. u_halo: (3, n+2, n+2, n+2)."""
    ap = stencil.elasticity3d_apply(u_halo)
    apf = ap.reshape(-1)
    pf = u_halo[:, 1:-1, 1:-1, 1:-1].reshape(-1)
    return apf, blas1.dot(pf, apf)


def cg_update(alpha, x, r, p, ap):
    """Fused (x + a p, r - a Ap, local <r',r'>) on flat vectors."""
    return blas1.cg_update(alpha, x, r, p, ap)


def cg_pupdate(beta, r, p):
    """p' = r + beta p on flat vectors."""
    return (blas1.cg_pupdate(beta, r, p),)


def dot2(a, b):
    """Standalone local partial dot (used for <r,z> in preconditioned CG)."""
    return (blas1.dot(a, b),)


# ---------------------------------------------------------------------------
# RHS assembly (manufactured solution, cell-centred coordinates)
# ---------------------------------------------------------------------------

def assemble_rhs3d(origin, h, *, n):
    """f = h^2 * 3 pi^2 sin(pi x) sin(pi y) sin(pi z) on the local block.

    origin: (3,) f32 global index of this rank's first interior cell
    (iz, iy, ix); h: (1,) f32 grid spacing. Returns flat (n^3,).
    """
    iz = jax.lax.broadcasted_iota(F32, (n, n, n), 0) + origin[0]
    iy = jax.lax.broadcasted_iota(F32, (n, n, n), 1) + origin[1]
    ix = jax.lax.broadcasted_iota(F32, (n, n, n), 2) + origin[2]
    pi = jnp.float32(jnp.pi)
    x = (ix + 0.5) * h[0]
    y = (iy + 0.5) * h[0]
    z = (iz + 0.5) * h[0]
    src = 3.0 * pi * pi * jnp.sin(pi * x) * jnp.sin(pi * y) * jnp.sin(pi * z)
    return ((h[0] * h[0] * src).reshape(-1),)


# ---------------------------------------------------------------------------
# Dense LU direct solve (Fig 2 "Poisson LU", 2D)
# ---------------------------------------------------------------------------

def lu_poisson2d(f, *, n):
    """Assemble the dense scaled 5-point matrix in-graph and solve A u = f
    by in-graph Gauss-Jordan elimination.

    Matches the paper's 'Poisson LU' workstation test: the reported time
    includes factorisation, which dominates (O(N^3)).

    NB: `jnp.linalg.solve` lowers to a typed-FFI LAPACK custom call that
    the pinned xla_extension (0.5.1) cannot execute, so the elimination
    is written out as a `fori_loop` of masked rank-1 updates — pure HLO.
    Pivot-free is fine: the scaled 5-point matrix is a symmetric
    M-matrix (diagonally dominant).
    """
    nn = n * n
    t = 2.0 * jnp.eye(n, dtype=F32) - jnp.eye(n, k=1, dtype=F32) - jnp.eye(n, k=-1, dtype=F32)
    i = jnp.eye(n, dtype=F32)
    a = jnp.kron(t, i) + jnp.kron(i, t)
    ab = jnp.concatenate([a, f.reshape(-1, 1)], axis=1)  # (nn, nn+1)

    def step(k, ab):
        col = ab[:, k] / ab[k, k]
        mask = (jnp.arange(nn) != k).astype(F32)
        return ab - jnp.outer(mask * col, ab[k])

    ab = jax.lax.fori_loop(0, nn, step, ab)
    u = ab[:, nn] / jnp.diagonal(ab[:, :nn])
    return (u.reshape(n, n),)


# ---------------------------------------------------------------------------
# Geometric multigrid (single-domain: Fig 2 "Poisson AMG" substitute)
# ---------------------------------------------------------------------------

def _pad(u):
    return jnp.pad(u, 1)


def _vcycle(u, f, nu, min_n):
    n = u.shape[0]
    if n <= min_n:
        for _ in range(8 * nu):
            u = smoother.jacobi3d(_pad(u), f)
        return u
    for _ in range(nu):
        u = smoother.jacobi3d(_pad(u), f)
    r = smoother.residual3d(_pad(u), f)
    # 4x: the (2h)^2/h^2 factor of the h^2-scaled operator on the coarse
    # grid; variational (P^T) restriction keeps the correction stable on
    # deep ladders (see kernels/transfer.py).
    rc = 4.0 * transfer.restrict3d_tri(_pad(r))
    ec = _vcycle(jnp.zeros_like(rc), rc, nu, min_n)
    u = u + transfer.prolong3d(ec)
    for _ in range(nu):
        u = smoother.jacobi3d(_pad(u), f)
    return u


def precond_vcycle(r, *, n, nu=2, min_n=4):
    """z = M^{-1} r via one V-cycle from zero. Flat in, flat out."""
    z = _vcycle(jnp.zeros((n, n, n), F32), r.reshape(n, n, n), nu, min_n)
    return (z.reshape(-1),)


# ---------------------------------------------------------------------------
# HPGMG-FE ladder (distributed; one entry per level operation)
# ---------------------------------------------------------------------------

def smooth3d(u_halo, f):
    """One fused weighted-Jacobi sweep on the local block."""
    return (smoother.jacobi3d(u_halo, f),)


def resid3d(u_halo, f):
    """Local residual r = f - A u."""
    return (smoother.residual3d(u_halo, f),)


def restrict3d(r_halo):
    """Residual restriction to the next-coarser block: variational
    (trilinear-transpose) weights over the halo-padded fine residual,
    including the 4x (2h/h)^2 rescaling of the h^2-scaled operator."""
    return (4.0 * transfer.restrict3d_tri(r_halo),)


def prolong_add3d(u_fine, e_halo):
    """Coarse-grid correction: u += P e, with the coarse correction
    supplied halo-padded ((n+2)^3) so interpolation at block interfaces
    uses the neighbours' values (filled by the Rust halo exchange)."""
    return (u_fine + transfer.prolong3d_halo(e_halo),)


def coarse_solve3d(f, *, n, sweeps=48):
    """Bottom-of-ladder solve by heavy Jacobi smoothing (n is tiny)."""
    u = jnp.zeros((n, n, n), F32)
    for _ in range(sweeps):
        u = smoother.jacobi3d(_pad(u), f)
    return (u,)


def norm2(a):
    """Local partial sum of squares (for residual norms)."""
    return (blas1.dot(a, a),)


# ---------------------------------------------------------------------------
# Entry-point registry: artifact name -> (callable, [arg specs])
#
# Local block sizes: Poisson CG at n in {16, 32}; elasticity at n = 16;
# HPGMG ladder 32 -> 16 -> 8 -> 4; 2D LU at n = 32; flat-vector entries at
# L in {4096, 32768, 12288 (= 3 * 16^3)}.
# ---------------------------------------------------------------------------

CG_SIZES = (16, 32)
EL_N = 16
LU_N = 32
GMG_N = 32
LADDER = (32, 16, 8, 4)
FLAT_SIZES = (16 ** 3, 32 ** 3, 3 * 16 ** 3)


def build_entries():
    e = {}
    for n in CG_SIZES:
        e[f"cg_apdot_p3d_n{n}"] = (cg_apdot_p3d, [_spec(n + 2, n + 2, n + 2)])
        e[f"assemble_rhs3d_n{n}"] = (
            functools.partial(assemble_rhs3d, n=n),
            [_spec(3), _spec(1)],
        )
    e[f"cg_apdot_el3d_n{EL_N}"] = (
        cg_apdot_el3d,
        [_spec(3, EL_N + 2, EL_N + 2, EL_N + 2)],
    )
    for ell in FLAT_SIZES:
        e[f"cg_update_L{ell}"] = (
            cg_update,
            [_spec(1), _spec(ell), _spec(ell), _spec(ell), _spec(ell)],
        )
        e[f"cg_pupdate_L{ell}"] = (cg_pupdate, [_spec(1), _spec(ell), _spec(ell)])
        e[f"dot_L{ell}"] = (dot2, [_spec(ell), _spec(ell)])
    e[f"lu_poisson2d_n{LU_N}"] = (
        functools.partial(lu_poisson2d, n=LU_N),
        [_spec(LU_N, LU_N)],
    )
    e[f"precond_vcycle_n{GMG_N}"] = (
        functools.partial(precond_vcycle, n=GMG_N),
        [_spec(GMG_N ** 3)],
    )
    for n in LADDER:
        e[f"smooth3d_n{n}"] = (smooth3d, [_spec(n + 2, n + 2, n + 2), _spec(n, n, n)])
        e[f"resid3d_n{n}"] = (resid3d, [_spec(n + 2, n + 2, n + 2), _spec(n, n, n)])
        e[f"norm2_n{n}"] = (lambda a: norm2(a.reshape(-1)), [_spec(n, n, n)])
    for n in LADDER[:-1]:
        e[f"restrict3d_n{n}"] = (restrict3d, [_spec(n + 2, n + 2, n + 2)])
    for n in LADDER[1:]:
        e[f"prolong_add3d_n{n}"] = (
            prolong_add3d,
            [_spec(2 * n, 2 * n, 2 * n), _spec(n + 2, n + 2, n + 2)],
        )
    e[f"coarse_solve3d_n{LADDER[-1]}"] = (
        functools.partial(coarse_solve3d, n=LADDER[-1]),
        [_spec(LADDER[-1], LADDER[-1], LADDER[-1])],
    )
    return e


ENTRIES = build_entries()
