# Kernel-vs-oracle correctness: every Pallas kernel must match the pure-jnp
# reference in ref.py.  This is the CORE correctness signal of the L1 layer;
# the Rust integration tests build on it transitively.

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import blas1, ref, smoother, stencil, transfer

RTOL = 1e-4
ATOL = 1e-5


def rand(shape, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


# ---------------------------------------------------------------------------
# Stencils
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [4, 8, 16, 32])
def test_laplace3d_matches_ref(n):
    u = rand((n + 2, n + 2, n + 2), seed=n)
    np.testing.assert_allclose(
        stencil.laplace3d_apply(u), ref.laplace3d_apply(u), rtol=RTOL, atol=ATOL
    )


@pytest.mark.parametrize("shape", [(4, 4), (8, 16), (32, 32), (5, 7)])
def test_laplace2d_matches_ref(shape):
    u = rand((shape[0] + 2, shape[1] + 2), seed=shape[0])
    np.testing.assert_allclose(
        stencil.laplace2d_apply(u), ref.laplace2d_apply(u), rtol=RTOL, atol=ATOL
    )


def test_laplace3d_nonuniform_block():
    # nz not divisible by the default slab: _pick_bz must still tile exactly.
    u = rand((9, 6, 10), seed=3)
    np.testing.assert_allclose(
        stencil.laplace3d_apply(u, vmem_budget_cells=200),
        ref.laplace3d_apply(u),
        rtol=RTOL,
        atol=ATOL,
    )


def test_laplace3d_tiling_invariance():
    # The answer must not depend on the chosen slab depth.
    u = rand((18, 18, 18), seed=7)
    full = stencil.laplace3d_apply(u, vmem_budget_cells=1 << 24)
    tiny = stencil.laplace3d_apply(u, vmem_budget_cells=18 * 18 * 3)
    np.testing.assert_allclose(full, tiny, rtol=RTOL, atol=ATOL)


def test_laplace3d_constant_field_is_zero():
    # A constant field has zero Laplacian in the interior (away from the
    # boundary ring, where the zero halo bites).
    u = jnp.ones((10, 10, 10))
    out = stencil.laplace3d_apply(u)
    np.testing.assert_allclose(out[1:-1, 1:-1, 1:-1], 0.0, atol=ATOL)


@pytest.mark.parametrize("n", [4, 8, 16])
def test_elasticity3d_matches_ref(n):
    u = rand((3, n + 2, n + 2, n + 2), seed=n)
    np.testing.assert_allclose(
        stencil.elasticity3d_apply(u), ref.elasticity3d_apply(u), rtol=RTOL, atol=ATOL
    )


def test_elasticity3d_lame_params():
    u = rand((3, 6, 6, 6), seed=5)
    got = stencil.elasticity3d_apply(u, mu=2.5, lam=0.7)
    want = ref.elasticity3d_apply(u, mu=2.5, lam=0.7)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_elasticity3d_symmetry():
    # The Dirichlet Lamé operator is symmetric on interior dofs:
    # <Au, v> == <u, Av> with zero halos.
    ui = rand((3, 6, 6, 6), seed=11)
    vi = rand((3, 6, 6, 6), seed=12)
    pad = lambda a: jnp.pad(a, ((0, 0), (1, 1), (1, 1), (1, 1)))
    au = stencil.elasticity3d_apply(pad(ui))
    av = stencil.elasticity3d_apply(pad(vi))
    lhs = jnp.vdot(au, vi)
    rhs = jnp.vdot(ui, av)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Smoother / residual
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [4, 8, 16])
def test_jacobi3d_matches_ref(n):
    u = rand((n + 2, n + 2, n + 2), seed=n)
    f = rand((n, n, n), seed=n + 100)
    np.testing.assert_allclose(
        smoother.jacobi3d(u, f), ref.jacobi3d(u, f), rtol=RTOL, atol=ATOL
    )


@pytest.mark.parametrize("n", [4, 8, 16, 32])
def test_residual3d_matches_ref(n):
    u = rand((n + 2, n + 2, n + 2), seed=n)
    f = rand((n, n, n), seed=n + 100)
    np.testing.assert_allclose(
        smoother.residual3d(u, f), ref.residual3d(u, f), rtol=RTOL, atol=ATOL
    )


def test_jacobi3d_fixed_point():
    # If A u == f the smoother must leave u unchanged.
    n = 8
    u = rand((n + 2, n + 2, n + 2), seed=42)
    f = ref.laplace3d_apply(u)
    out = smoother.jacobi3d(u, f)
    np.testing.assert_allclose(out, u[1:-1, 1:-1, 1:-1], rtol=RTOL, atol=ATOL)


def test_jacobi3d_reduces_error():
    # Smoothing from zero must reduce the residual norm for a Poisson RHS.
    n = 16
    f = jnp.ones((n, n, n))
    u = jnp.zeros((n, n, n))
    r0 = float(jnp.linalg.norm(f))
    for _ in range(5):
        u = smoother.jacobi3d(jnp.pad(u, 1), f)
    r5 = float(jnp.linalg.norm(ref.residual3d(jnp.pad(u, 1), f)))
    assert r5 < r0


# ---------------------------------------------------------------------------
# Grid transfer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [4, 8, 16])
def test_restrict3d_matches_ref(n):
    r = rand((2 * n, 2 * n, 2 * n), seed=n)
    np.testing.assert_allclose(
        transfer.restrict3d(r), ref.restrict3d(r), rtol=RTOL, atol=ATOL
    )


@pytest.mark.parametrize("n", [2, 4, 8])
def test_prolong3d_matches_ref(n):
    e = rand((n, n, n), seed=n)
    np.testing.assert_allclose(
        transfer.prolong3d(e), ref.prolong3d(e), rtol=RTOL, atol=ATOL
    )


@pytest.mark.parametrize("n", [2, 4, 8])
def test_prolong3d_halo_matches_ref(n):
    e = rand((n + 2, n + 2, n + 2), seed=n + 50)
    np.testing.assert_allclose(
        transfer.prolong3d_halo(e), ref.prolong3d_halo(e), rtol=RTOL, atol=ATOL
    )


def test_prolong3d_halo_zero_pad_equals_plain():
    e = rand((4, 4, 4), seed=77)
    np.testing.assert_allclose(
        transfer.prolong3d_halo(jnp.pad(e, 1)),
        transfer.prolong3d(e),
        rtol=RTOL,
        atol=ATOL,
    )


def test_prolong_constant_interior():
    # Trilinear prolongation reproduces constants away from the Dirichlet
    # boundary ring (where the zero ghosts bite).
    e = jnp.full((4, 4, 4), 2.0)
    out = transfer.prolong3d(e)
    np.testing.assert_allclose(out[2:-2, 2:-2, 2:-2], 2.0, rtol=RTOL)


def test_prolong_linear_exact_interior():
    # Trilinear prolongation is exact on (cell-centred) linear functions
    # in the interior.
    n = 4
    xc = (jnp.arange(n) + 0.5) * 2.0  # coarse centres, h_c = 2
    e = jnp.broadcast_to(xc[:, None, None], (n, n, n)).astype(jnp.float32)
    out = transfer.prolong3d(e)
    xf = (jnp.arange(2 * n) + 0.5) * 1.0
    want = jnp.broadcast_to(xf[:, None, None], (2 * n, 2 * n, 2 * n))
    np.testing.assert_allclose(
        out[2:-2, 2:-2, 2:-2], want[2:-2, 2:-2, 2:-2], rtol=1e-3, atol=1e-4
    )


def test_restrict_constant_preserved():
    r = jnp.full((8, 8, 8), 3.25)
    np.testing.assert_allclose(transfer.restrict3d(r), 3.25, rtol=RTOL)


# ---------------------------------------------------------------------------
# BLAS-1 / fused CG fragments
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 256, 4096])
def test_dot_matches_ref(n):
    a, b = rand((n,), 1), rand((n,), 2)
    np.testing.assert_allclose(
        blas1.dot(a, b)[0], ref.dot(a, b), rtol=1e-3, atol=1e-3
    )


@pytest.mark.parametrize("n", [8, 1024])
def test_axpy_matches_ref(n):
    a = jnp.asarray([1.7], dtype=jnp.float32)
    x, y = rand((n,), 3), rand((n,), 4)
    np.testing.assert_allclose(
        blas1.axpy(a, x, y), ref.axpy(1.7, x, y), rtol=RTOL, atol=ATOL
    )


def test_cg_update_matches_composition():
    n = 512
    alpha = jnp.asarray([0.37], dtype=jnp.float32)
    x, r, p, ap = (rand((n,), s) for s in (1, 2, 3, 4))
    x2, r2, rr = blas1.cg_update(alpha, x, r, p, ap)
    np.testing.assert_allclose(x2, x + 0.37 * p, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(r2, r - 0.37 * ap, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(rr[0], ref.dot(r2, r2), rtol=1e-3, atol=1e-3)


def test_cg_pupdate_matches_composition():
    n = 512
    beta = jnp.asarray([0.81], dtype=jnp.float32)
    r, p = rand((n,), 5), rand((n,), 6)
    np.testing.assert_allclose(
        blas1.cg_pupdate(beta, r, p), r + 0.81 * p, rtol=RTOL, atol=ATOL
    )


# ---------------------------------------------------------------------------
# Hypothesis shape/dtype sweeps (cheap sizes only; interpret mode is slow)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    nz=st.integers(2, 10),
    ny=st.integers(2, 10),
    nx=st.integers(2, 10),
    seed=st.integers(0, 2**16),
)
def test_laplace3d_hypothesis(nz, ny, nx, seed):
    u = rand((nz + 2, ny + 2, nx + 2), seed=seed)
    np.testing.assert_allclose(
        stencil.laplace3d_apply(u), ref.laplace3d_apply(u), rtol=RTOL, atol=1e-4
    )


@settings(max_examples=20, deadline=None)
@given(
    ny=st.integers(1, 24),
    nx=st.integers(1, 24),
    seed=st.integers(0, 2**16),
)
def test_laplace2d_hypothesis(ny, nx, seed):
    u = rand((ny + 2, nx + 2), seed=seed)
    np.testing.assert_allclose(
        stencil.laplace2d_apply(u), ref.laplace2d_apply(u), rtol=RTOL, atol=1e-4
    )


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 2048), seed=st.integers(0, 2**16))
def test_dot_hypothesis(n, seed):
    a, b = rand((n,), seed), rand((n,), seed + 1)
    np.testing.assert_allclose(
        blas1.dot(a, b)[0], ref.dot(a, b), rtol=2e-3, atol=2e-3
    )


@settings(max_examples=10, deadline=None)
@given(
    dtype=st.sampled_from([jnp.float32, jnp.float64]),
    n=st.integers(2, 8),
    seed=st.integers(0, 2**16),
)
def test_laplace3d_dtypes(dtype, n, seed):
    if dtype == jnp.float64 and not jax.config.jax_enable_x64:
        dtype = jnp.float32  # x64 disabled: degrade to f32 (still a valid case)
    u = rand((n + 2, n + 2, n + 2), seed=seed, dtype=dtype)
    got = stencil.laplace3d_apply(u)
    assert got.dtype == u.dtype
    np.testing.assert_allclose(got, ref.laplace3d_apply(u), rtol=RTOL, atol=1e-4)
