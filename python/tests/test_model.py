# Model-level tests: the L2 entry points that get AOT-exported must be
# numerically correct (vs ref.py whole-problem oracles) and shape-stable
# (the manifest the Rust runtime consumes is generated from these shapes).

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


# ---------------------------------------------------------------------------
# CG fragments
# ---------------------------------------------------------------------------

def test_cg_apdot_p3d():
    n = 8
    p = rand((n + 2, n + 2, n + 2), 1)
    ap, pap = model.cg_apdot_p3d(p)
    want = ref.laplace3d_apply(p).reshape(-1)
    np.testing.assert_allclose(ap, want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        pap[0],
        ref.dot(p[1:-1, 1:-1, 1:-1].reshape(-1), want),
        rtol=1e-3,
        atol=1e-3,
    )


def test_cg_apdot_el3d():
    n = 6
    u = rand((3, n + 2, n + 2, n + 2), 2)
    ap, pap = model.cg_apdot_el3d(u)
    want = ref.elasticity3d_apply(u).reshape(-1)
    np.testing.assert_allclose(ap, want, rtol=1e-4, atol=1e-4)


def test_full_cg_via_model_fragments():
    # Drive a complete CG solve using ONLY the exported fragments, exactly
    # as the Rust fem::cg driver does, and compare to the oracle solver.
    n = 8
    f = ref.manufactured_rhs3d(n, (0, 0, 0), n, 1.0 / n).reshape(-1)
    x = jnp.zeros_like(f)
    r = f
    p = f
    rr = float(ref.dot(r, r))
    for _ in range(200):
        ap, pap = model.cg_apdot_p3d(jnp.pad(p.reshape(n, n, n), 1))
        alpha = jnp.asarray([rr / float(pap[0])], dtype=jnp.float32)
        x, r, rr_new = model.cg_update(alpha, x, r, p, ap)
        rr_new = float(rr_new[0])
        if np.sqrt(rr_new) < 1e-5:
            break
        beta = jnp.asarray([rr_new / rr], dtype=jnp.float32)
        (p,) = model.cg_pupdate(beta, r, p)
        rr = rr_new
    u_oracle, _ = ref.cg_solve3d(f.reshape(n, n, n), tol=1e-8)
    np.testing.assert_allclose(
        x.reshape(n, n, n), u_oracle, rtol=5e-3, atol=5e-4
    )


# ---------------------------------------------------------------------------
# Assembly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("origin", [(0, 0, 0), (8, 0, 4)])
def test_assemble_rhs3d(origin):
    n, ng = 8, 16
    h = 1.0 / ng
    (f,) = model.assemble_rhs3d(
        jnp.asarray(origin, dtype=jnp.float32),
        jnp.asarray([h], dtype=jnp.float32),
        n=n,
    )
    want = ref.manufactured_rhs3d(ng, origin, n, h).reshape(-1)
    np.testing.assert_allclose(f, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Direct solve + multigrid
# ---------------------------------------------------------------------------

def test_lu_poisson2d():
    n = 16
    f = rand((n, n), 3)
    (u,) = model.lu_poisson2d(f, n=n)
    want = ref.lu_solve2d(f)
    np.testing.assert_allclose(u, want, rtol=1e-2, atol=1e-3)
    # and the solve really inverts the operator:
    au = ref.laplace2d_apply(jnp.pad(u, 1))
    np.testing.assert_allclose(au, f, rtol=1e-2, atol=1e-2)


def test_vcycle_reduces_residual():
    n = 16
    f = ref.manufactured_rhs3d(n, (0, 0, 0), n, 1.0 / n)
    u = jnp.zeros((n, n, n), jnp.float32)
    r0 = float(jnp.linalg.norm(f))
    u = model._vcycle(u, f, nu=2, min_n=4)
    r1 = float(jnp.linalg.norm(ref.residual3d(jnp.pad(u, 1), f)))
    u = model._vcycle(u, f, nu=2, min_n=4)
    r2 = float(jnp.linalg.norm(ref.residual3d(jnp.pad(u, 1), f)))
    assert r1 < 0.7 * r0, (r0, r1)  # first cycle from zero guess is weakest
    assert r2 < 0.5 * r1, (r1, r2)


def test_precond_vcycle_is_spd_like():
    # A usable CG preconditioner must at minimum satisfy <r, M r> > 0.
    n = model.GMG_N
    r = rand((n**3,), 7)
    (z,) = model.precond_vcycle(r, n=n)
    assert float(jnp.vdot(r, z)) > 0.0


def test_vcycle_matches_ref_vcycle():
    n = 8
    f = rand((n, n, n), 9)
    u0 = rand((n, n, n), 10)
    got = model._vcycle(u0, f, nu=1, min_n=4)
    want = ref.vcycle3d(u0, f, nu=1, min_n=4)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# HPGMG ladder entries
# ---------------------------------------------------------------------------

def test_smooth_resid_roundtrip():
    n = 8
    u = rand((n + 2, n + 2, n + 2), 11)
    f = rand((n, n, n), 12)
    (s,) = model.smooth3d(u, f)
    np.testing.assert_allclose(s, ref.jacobi3d(u, f), rtol=1e-4, atol=1e-4)
    (r,) = model.resid3d(u, f)
    np.testing.assert_allclose(r, ref.residual3d(u, f), rtol=1e-4, atol=1e-4)


def test_prolong_add_zero_halo_matches_single_domain():
    n = 4
    u = rand((2 * n, 2 * n, 2 * n), 13)
    e = rand((n, n, n), 14)
    (got,) = model.prolong_add3d(u, jnp.pad(e, 1))
    np.testing.assert_allclose(
        got, u + ref.prolong3d(e), rtol=1e-4, atol=1e-4
    )


def test_prolong_add_uses_supplied_halo():
    n = 4
    u = jnp.zeros((2 * n, 2 * n, 2 * n), jnp.float32)
    e_halo = rand((n + 2, n + 2, n + 2), 15)
    (got,) = model.prolong_add3d(u, e_halo)
    np.testing.assert_allclose(
        got, ref.prolong3d_halo(e_halo), rtol=1e-4, atol=1e-4
    )
    # and it differs from the zero-ghost result near the faces
    (zero,) = model.prolong_add3d(
        u, jnp.pad(e_halo[1:-1, 1:-1, 1:-1], 1)
    )
    assert not np.allclose(got, zero)


def test_coarse_solve_accuracy():
    # The bottom solve must essentially invert A on the tiny grid.
    n = 4
    u_true = rand((n, n, n), 15)
    f = ref.laplace3d_apply(jnp.pad(u_true, 1))
    (u,) = model.coarse_solve3d(f, n=n)
    r = float(jnp.linalg.norm(ref.residual3d(jnp.pad(u, 1), f)))
    assert r < 0.05 * float(jnp.linalg.norm(f))


# ---------------------------------------------------------------------------
# Registry / export sanity
# ---------------------------------------------------------------------------

def test_entry_registry_complete():
    names = set(model.ENTRIES)
    for n in model.CG_SIZES:
        assert f"cg_apdot_p3d_n{n}" in names
        assert f"assemble_rhs3d_n{n}" in names
    for ell in model.FLAT_SIZES:
        assert f"cg_update_L{ell}" in names
    for n in model.LADDER:
        assert f"smooth3d_n{n}" in names
    assert f"lu_poisson2d_n{model.LU_N}" in names
    assert f"precond_vcycle_n{model.GMG_N}" in names


def test_entries_traceable_and_shapes():
    # every entry must trace with its declared specs and yield static shapes
    for name, (fn, specs) in model.ENTRIES.items():
        outs = jax.eval_shape(fn, *specs)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        for o in outs:
            assert all(int(d) > 0 for d in o.shape) or o.shape == (), name
