//! Quickstart: the paper's §2.2 walk-through, end to end.
//!
//! Builds the paper's example image (Ubuntu + SciPy) from a Buildfile,
//! tags it, starts a container from it, execs a command, and shows the
//! layered-filesystem properties (content hashes, caching, dedup) the
//! paper highlights.
//!
//! Run with: `cargo run --release --example quickstart`

use harbor::container::runtime::{by_kind, RuntimeKind};
use harbor::container::{Builder, Buildfile, Container, LayerStore, Registry};
use harbor::des::VirtualTime;

const SCIPY_BUILDFILE: &str = r#"
# The paper's §2.2 example, verbatim structure
FROM ubuntu:16.04
USER root
RUN apt-get -y update && \
 apt-get -y upgrade && \
 apt-get -y install python-scipy && \
 rm -rf /var/lib/apt/lists/* /tmp/* /var/tmp/*
"#;

fn main() -> anyhow::Result<()> {
    println!("== 1. docker build . ==");
    let bf = Buildfile::parse(SCIPY_BUILDFILE)?;
    let mut store = LayerStore::new();
    let mut builder = Builder::new();
    let report = builder.build(&bf, "scipy-image:latest", &mut store)?;
    println!(
        "built image {} ({} layers, {} MB, simulated build {})",
        report.image.id,
        report.image.layers.len(),
        report.image.size_bytes(&store) / 1_000_000,
        report.build_time
    );

    println!("\n== 2. rebuild: every layer comes from the cache ==");
    let again = builder.build(&bf, "scipy-image:latest", &mut store)?;
    println!(
        "cache hits: {} / {} (same content hash: {})",
        again.layers_cached,
        again.image.layers.len(),
        again.image.id == report.image.id
    );
    assert_eq!(again.layers_built, 0);

    println!("\n== 3. push / pull through a registry ==");
    let mut registry = Registry::new();
    registry.push(&report.image, &store)?;
    let mut laptop = LayerStore::new();
    let (pulled, pull) = registry.pull("scipy-image:latest", &mut laptop)?;
    println!(
        "pulled {}: {} layers, {} MB in {}",
        pulled.reference,
        pull.layers_transferred,
        pull.bytes_transferred / 1_000_000,
        pull.time
    );

    println!("\n== 4. docker run -ti scipy-image python ==");
    let docker = by_kind(RuntimeKind::Docker);
    let start_cost = docker.startup_overhead(&pulled);
    let mut c = Container::create(1, pulled.id.clone(), VirtualTime::ZERO);
    c.start(VirtualTime::ZERO + start_cost)?;
    c.exec("python -c 'import scipy; print(scipy.__version__)'")?;
    c.exit(
        0,
        VirtualTime::ZERO + start_cost + harbor::des::Duration::from_millis(900),
    )?;
    println!(
        "container {} ran `{}` (startup {start_cost}, total {})",
        c.id,
        c.exec_log[0],
        c.runtime().unwrap()
    );

    println!("\n== 5. a second image FROM the same base dedups in the store ==");
    // a different CI job (fresh builder, no layer cache) pushes into the
    // same store: content addressing dedups the shared base physically
    let bf2 = Buildfile::parse("FROM ubuntu:16.04\nRUN apt-get -y install python-numpy")?;
    let before = store.physical_bytes();
    Builder::new().build(&bf2, "numpy-image:latest", &mut store)?;
    println!(
        "added {} MB physically (base shared); store dedup ratio {:.2}x",
        (store.physical_bytes() - before) / 1_000_000,
        store.dedup_ratio()
    );

    println!("\nquickstart OK");
    Ok(())
}
