//! The Fig 1 pipeline: Dockerfile → cloud build → registry → pull on a
//! laptop and on the HPC machine — plus what an incremental change
//! costs (the §3.4 workflow: "making small configuration changes
//! requires changing just one file").
//!
//! Run with: `cargo run --release --example image_pipeline`

use harbor::container::{Builder, Buildfile, LayerStore, Registry};
use harbor::coordinator::{deploy_pipeline, FENICS_BUILDFILE};

fn main() -> anyhow::Result<()> {
    println!("== Fig 1: build -> push -> pull on every platform ==\n");
    let trace = deploy_pipeline()?;
    print!("{}", trace.render());

    println!("\n== incremental change: one extra directive ==");
    // The CI builder keeps its layer cache between commits; a new
    // directive at the end rebuilds only itself.
    let mut builder = Builder::new();
    let mut ci = LayerStore::new();
    let v1 = builder.build(
        &Buildfile::parse(FENICS_BUILDFILE)?,
        "quay.io/fenicsproject/stable:2016.1.0r1",
        &mut ci,
    )?;
    let changed = format!("{FENICS_BUILDFILE}RUN pip install matplotlib\n");
    let v2 = builder.build(
        &Buildfile::parse(&changed)?,
        "quay.io/fenicsproject/stable:2016.2.0.dev0",
        &mut ci,
    )?;
    println!(
        "v1: {} layers built; v2 (one-line change): {} built, {} cached",
        v1.layers_built, v2.layers_built, v2.layers_cached
    );

    println!("\n== users pull the update: only new layers move ==");
    let mut registry = Registry::new();
    registry.push(&v1.image, &ci)?;
    registry.push(&v2.image, &ci)?;
    let mut user = LayerStore::new();
    let (_, first) = registry.pull("quay.io/fenicsproject/stable:2016.1.0r1", &mut user)?;
    let (_, update) = registry.pull("quay.io/fenicsproject/stable:2016.2.0.dev0", &mut user)?;
    println!(
        "initial pull: {} MB in {}\nupdate pull:  {} MB in {} ({} layers reused)",
        first.bytes_transferred / 1_000_000,
        first.time,
        update.bytes_transferred / 1_000_000,
        update.time,
        update.layers_reused,
    );
    assert!(update.bytes_transferred < first.bytes_transferred / 5);

    println!("\nimage_pipeline OK");
    Ok(())
}
