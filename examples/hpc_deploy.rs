//! Deploying to the HPC machine (§3.3): pull the image with
//! `shifterimg pull`, resolve the MPI library per configuration, and run
//! the C++ test program at several scales under all three Fig 3
//! configurations — showing the ABI-injection crossover live.
//!
//! Run with: `cargo run --release --example hpc_deploy`

use harbor::cluster::MachineSpec;
use harbor::container::{LayerStore, Registry};
use harbor::container::RuntimeKind;
use harbor::fem::exec::Exec;
use harbor::mpi::AbiResolver;
use harbor::platform::Platform;
use harbor::runtime::CalibrationTable;
use harbor::workload::{fenics_image, run_poisson_app, AppConfig};

fn main() -> anyhow::Result<()> {
    let edison = MachineSpec::edison();

    println!("== shifterimg pull (ahead of the job, §3.3) ==");
    let (image, store) = fenics_image();
    let mut registry = Registry::new();
    registry.push(&image, &store)?;
    let mut gateway = LayerStore::new();
    let (_, pull) = registry.pull(&image.reference, &mut gateway)?;
    println!(
        "pulled {} onto {}: {} MB in {} (flattened for loop-mount)\n",
        image.reference,
        edison.name,
        pull.bytes_transferred / 1_000_000,
        pull.time
    );

    println!("== MPI resolution per configuration (§4.2) ==");
    for (label, inject) in [("with LD_LIBRARY_PATH injection", true), ("without", false)] {
        let res = AbiResolver {
            machine: &edison,
            runtime: RuntimeKind::Shifter,
            inject_host_mpi: inject,
        }
        .resolve();
        println!("{label}:");
        for s in &res.steps {
            println!("    {s}");
        }
        println!("    => {:?}\n", res.fabric);
    }

    println!("== srun -n N shifter ./demo_poisson (C++ driver) ==");
    let table = CalibrationTable::load_or_default(None);
    println!(
        "{:>6}  {:>12}  {:>20}  {:>23}",
        "ranks", "native [s]", "shifter+sysMPI [s]", "shifter+contMPI [s]"
    );
    for ranks in [24usize, 48, 96, 192] {
        let mut row = Vec::new();
        for platform in Platform::edison_cpp_set() {
            let mut exec = Exec::Modeled { table: &table };
            let b = run_poisson_app(platform, &mut exec, &AppConfig::cpp(ranks, 42))?;
            row.push(b.total());
        }
        println!(
            "{ranks:>6}  {:>12.3}  {:>20.3}  {:>23.3}",
            row[0], row[1], row[2]
        );
    }
    println!(
        "\nnative ≈ shifter+system-MPI at every scale; the container-MPI\n\
         column explodes once the job spans >1 node (24 cores/node) —\n\
         exactly Fig 3's (a)/(b)/(c) pattern."
    );
    Ok(())
}
