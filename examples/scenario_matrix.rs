//! Plugging a custom scenario into the registry.
//!
//! The scenario engine makes "a new experiment" a registry entry: an
//! implementation of `Scenario` (expand → run one cell → assemble),
//! registered on a `Coordinator`, run through the same deterministic
//! parallel matrix runner as the paper's figures.  This example adds a
//! *startup-overhead sweep* — container cold-start cost per runtime
//! across repetitions, a number the built-in figures fold into other
//! phases — and runs it next to a built-in scenario with `--jobs`-style
//! parallelism.
//!
//! Run with: `cargo run --release --example scenario_matrix`

use anyhow::Result;

use harbor::bench::{Figure, RowSet};
use harbor::cluster::MachineSpec;
use harbor::config::ExperimentConfig;
use harbor::coordinator::Coordinator;
use harbor::des::{Duration, LatencyHistogram};
use harbor::platform::Platform;
use harbor::runtime::CalibrationTable;
use harbor::scenario::{Cell, CellResult, Scenario, SimContext};
use harbor::workload::RunSetup;

/// Container start-up overhead per platform — the walkthrough scenario
/// from docs/ARCHITECTURE.md §5.
struct StartupSweep;

#[derive(Debug, Clone, Copy)]
struct StartupCell {
    platform_idx: usize,
    platform: Platform,
    rep: usize,
}

const PLATFORMS: [Platform; 4] = [
    Platform::Native,
    Platform::Docker,
    Platform::Rkt,
    Platform::Vm,
];

impl Scenario for StartupSweep {
    fn name(&self) -> &'static str {
        "startup-sweep"
    }

    fn describe(&self) -> &'static str {
        "container cold-start overhead per runtime (workstation image)"
    }

    fn default_config(&self) -> Result<ExperimentConfig> {
        ExperimentConfig::paper_default("fig2")
    }

    // 1. expand: one cell per (platform, rep) — cells must be
    //    independent; anything mutable is built inside run_cell
    fn cells(&self, cfg: &ExperimentConfig) -> Result<Vec<Cell>> {
        let mut cells = Vec::new();
        for (platform_idx, &platform) in PLATFORMS.iter().enumerate() {
            for rep in 0..cfg.reps {
                cells.push(Cell::new(
                    format!("startup {} / rep {rep}", platform.label()),
                    StartupCell {
                        platform_idx,
                        platform,
                        rep,
                    },
                ));
            }
        }
        Ok(cells)
    }

    // 2. run one cell: the runner hands back our payload plus a stable
    //    per-cell seed derived from the (scenario, cell-index) hash
    fn run_cell(&self, ctx: &SimContext<'_>, cell: &Cell) -> Result<CellResult> {
        let c: &StartupCell = cell.payload()?;
        let seed = cell.id.seed(ctx.cfg.seed);
        let setup = RunSetup::new(MachineSpec::workstation(), c.platform, 1, seed);
        Ok(CellResult::value(setup.startup().as_secs_f64()))
    }

    // 3. assemble: the runner hands back the executed cells and their
    //    results, aligned in cell-id order (never completion order);
    //    RowSet keeps the rows order-independent
    fn assemble(
        &self,
        _ctx: &SimContext<'_>,
        cells: &[Cell],
        rows: Vec<CellResult>,
    ) -> Result<Vec<Figure>> {
        let mut set = RowSet::new();
        for (cell, r) in cells.iter().zip(&rows) {
            let c: &StartupCell = cell.payload()?;
            set.add_sample(
                c.platform_idx as u64,
                c.platform.label(),
                c.rep as u64,
                r.primary(),
            );
        }
        let mut fig = Figure::new(
            "Startup sweep — container cold-start overhead",
            "start time [s]",
            false,
        );
        for row in set.into_rows() {
            fig.push(row);
        }
        fig.note("native starts free; the VM pays boot + hypervisor setup");
        // the des-level percentile estimator is reusable from any
        // scenario: deterministic log-spaced bins, no sorting, and the
        // same numbers at every --jobs setting (registry-storm builds
        // its whole latency figure on this)
        let mut hist = LatencyHistogram::new();
        for r in &rows {
            hist.record(Duration::from_secs_f64(r.primary()));
        }
        fig.note(format!("all-platform {}", hist.render()));
        Ok(vec![fig])
    }
}

fn main() -> Result<()> {
    let mut coordinator =
        Coordinator::with_table(CalibrationTable::builtin_fallback()).with_jobs(4);
    coordinator.registry_mut().register(Box::new(StartupSweep));

    println!("registered scenarios:");
    for (name, describe) in coordinator.registry().table() {
        println!("  {name:14} {describe}");
    }
    println!();

    // the custom scenario, through the same runner as the figures
    let cfg = ExperimentConfig {
        figure: "startup-sweep".into(),
        reps: 5,
        ..ExperimentConfig::paper_default("fig2")?
    };
    for fig in coordinator.run(&cfg)? {
        println!("{}", fig.render());
    }

    // and a built-in one, to show both share the machinery
    let fig2 = ExperimentConfig {
        reps: 2,
        ..ExperimentConfig::paper_default("fig2")?
    };
    for fig in coordinator.run(&fig2)? {
        println!("{}", fig.render());
    }
    Ok(())
}
