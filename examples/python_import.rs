//! The "Python import problem" (§4.2, Fig 4), isolated.
//!
//! Replays a FEniCS-scale `import` on every rank against (a) the native
//! Lustre model and (b) the Shifter loop-mounted image, across rank
//! counts — the mechanism behind Fig 4's native-vs-container gap, plus
//! the paper's ">30 minutes at ~1000 ranks" anecdote.
//!
//! Run with: `cargo run --release --example python_import`

use harbor::cluster::{launch, MachineSpec};
use harbor::des::VirtualTime;
use harbor::fs::{ImageFs, ParallelFs};
use harbor::pyimport::{replay, ModuleGraph};

fn main() -> anyhow::Result<()> {
    let edison = MachineSpec::edison();
    let graph = ModuleGraph::fenics_stack();
    println!(
        "import set: {} module files, {} metadata ops per rank\n",
        graph.total_files(),
        graph.total_meta_ops()
    );

    println!("{:>6}  {:>14}  {:>14}  {:>8}", "ranks", "native [s]", "shifter [s]", "speedup");
    for ranks in [24usize, 48, 96, 192, 384, 960] {
        let alloc = launch(&edison, ranks)?;

        let mut lustre = ParallelFs::edison(1);
        let native = replay(&graph, &alloc, &mut lustre, VirtualTime::ZERO).wall;

        let mut image = ImageFs::new(1_200_000_000, ParallelFs::edison(2));
        let shifter = replay(&graph, &alloc, &mut image, VirtualTime::ZERO).wall;

        println!(
            "{ranks:>6}  {:>14.2}  {:>14.2}  {:>7.0}x",
            native.as_secs_f64(),
            shifter.as_secs_f64(),
            native.as_secs_f64() / shifter.as_secs_f64()
        );
    }

    println!(
        "\nthe shifter side pays one image fetch per node, then page-cache\n\
         hits; the native side serialises every rank's lookups at the MDS\n\
         (compare the paper's '>30 minutes at 1000 processes' anecdote)."
    );
    Ok(())
}
