//! End-to-end driver: every layer of the stack composing on a real
//! workload, with real numerics.
//!
//! 1. Build the FEniCS image from its Buildfile, push, pull on both
//!    machine models (the Fig 1 pipeline).
//! 2. Run the distributed Poisson solve at 8 real MPI ranks with
//!    **actual PJRT execution** of the AOT JAX/Pallas artifacts — RHS
//!    assembled by the `assemble_rhs3d` kernel, halo exchange moving
//!    real face data, CG scalars reduced across ranks — and verify the
//!    solution against the analytic manufactured solution
//!    u = sin(πx)sin(πy)sin(πz).
//! 3. Switch to the calibrated execution mode and run the full Fig 3
//!    matrix at 24–192 ranks, printing the paper-style table.
//!
//! This is the run recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run with: `cargo run --release --example end_to_end`

use harbor::cluster::{launch, MachineSpec};
use harbor::coordinator::deploy_pipeline;
use harbor::fem::cg::{distributed_cg, CgConfig};
use harbor::fem::exec::{ComputeScale, Exec};
use harbor::fem::grid::Decomp;
use harbor::mpi::Comm;
use harbor::net::Fabric;
use harbor::platform::Platform;
use harbor::runtime::{CalibrationTable, Engine, TensorBuf};
use harbor::workload::{run_poisson_app, AppConfig};

fn main() -> anyhow::Result<()> {
    // ---- 1. deployment pipeline -----------------------------------------
    println!("== [1/3] image pipeline ==");
    let trace = deploy_pipeline()?;
    print!("{}", trace.render());

    // ---- 2. real-numerics distributed solve ------------------------------
    println!("\n== [2/3] 8-rank distributed CG, real PJRT numerics ==");
    let mut engine = Engine::open_default()?;
    let ranks = 8usize;
    let n = 16usize; // 2x2x2 blocks of 16³ -> global 32³
    let decomp = Decomp::new(ranks, n);
    let n_global = decomp.n_global()[0];
    let h = 1.0f32 / n_global as f32;
    println!(
        "decomp: {} ranks as {:?} blocks of {n}³ (global {n_global}³, h = {h:.4})",
        ranks, decomp.dims
    );

    // assemble the RHS on every rank through the AOT kernel
    let mut exec = Exec::Real { engine: &mut engine };
    let machine = MachineSpec::workstation();
    let mut comm = Comm::new(launch(&machine, ranks)?, Fabric::shared_mem());
    let mut scale = ComputeScale::none();
    let mut rhs = Vec::with_capacity(ranks);
    for r in 0..ranks {
        let o = decomp.origin(r);
        let origin = TensorBuf::new(vec![3], vec![o[0] as f32, o[1] as f32, o[2] as f32]);
        let out = exec
            .call(&mut comm, &mut scale, r, "assemble_rhs3d_n16", &[origin, TensorBuf::scalar1(h)])?
            .unwrap();
        rhs.push(out[0].data.clone());
    }

    let cfg = CgConfig {
        tol: 1e-5,
        max_iters: 400,
        ..CgConfig::default()
    };
    let outcome = distributed_cg(&mut exec, &mut comm, &mut scale, &decomp, &rhs, &cfg)?;
    let rel = outcome.rel_residual.unwrap();
    println!(
        "CG converged in {} iterations, relative residual {rel:.2e} (virtual wall {})",
        outcome.iters,
        comm.max_clock()
    );
    assert!(rel < 1e-4, "CG failed to converge: {rel}");

    // verify against the analytic manufactured solution
    let solution = outcome.solution.unwrap();
    let pi = std::f64::consts::PI;
    let mut max_err = 0.0f64;
    let mut max_u = 0.0f64;
    for r in 0..ranks {
        let o = decomp.origin(r);
        for z in 0..n {
            for y in 0..n {
                for x in 0..n {
                    let xx = (o[2] + x) as f64 * h as f64 + 0.5 * h as f64;
                    let yy = (o[1] + y) as f64 * h as f64 + 0.5 * h as f64;
                    let zz = (o[0] + z) as f64 * h as f64 + 0.5 * h as f64;
                    let exact = (pi * xx).sin() * (pi * yy).sin() * (pi * zz).sin();
                    let got = solution[r][(z * n + y) * n + x] as f64;
                    max_err = max_err.max((got - exact).abs());
                    max_u = max_u.max(exact.abs());
                }
            }
        }
    }
    let rel_err = max_err / max_u;
    println!(
        "max error vs analytic u = sin(pi x)sin(pi y)sin(pi z): {:.3}% of max|u|",
        rel_err * 100.0
    );
    // second-order FD at 32³: O(h²) ≈ (π h)² / something — a few percent
    assert!(rel_err < 0.05, "discretisation error out of range: {rel_err}");
    println!("real-numerics check PASSED (PJRT calls: {})", engine.calls);

    // ---- 3. calibrated Fig 3 matrix ---------------------------------------
    println!("\n== [3/3] Fig 3 matrix, calibrated mode, 24-192 ranks ==");
    let table = CalibrationTable::load_or_default(Some(&mut engine));
    println!("calibration source: {}", table.source);
    println!(
        "{:>6}  {:>12}  {:>20}  {:>23}",
        "ranks", "native [s]", "shifter+sysMPI [s]", "shifter+contMPI [s]"
    );
    for ranks in [24usize, 48, 96, 192] {
        let mut row = Vec::new();
        for platform in Platform::edison_cpp_set() {
            let mut exec = Exec::Modeled { table: &table };
            let b = run_poisson_app(platform, &mut exec, &AppConfig::cpp(ranks, 42))?;
            row.push(b.total());
        }
        println!(
            "{ranks:>6}  {:>12.3}  {:>20.3}  {:>23.3}",
            row[0], row[1], row[2]
        );
        // the paper's shape, asserted:
        let near = (row[1] - row[0]).abs() / row[0];
        assert!(near < 0.10, "shifter+sysMPI diverged from native: {near}");
        if ranks > 24 {
            assert!(row[2] > 2.0 * row[0], "container MPI should blow up off-node");
        }
    }

    println!("\nend_to_end OK — all three layers composed on a real workload");
    Ok(())
}
