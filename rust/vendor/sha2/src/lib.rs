//! Vendored minimal SHA-256.
//!
//! A drop-in subset of the `sha2` crate's API ([`Digest`] + [`Sha256`]),
//! implemented from the FIPS 180-4 specification with no dependencies,
//! so the workspace builds without network access to crates.io.  Only
//! what `harbor` uses is provided: `new` / `update` / `finalize` /
//! `digest`, with `finalize` returning the raw 32-byte digest.

/// Streaming-hash interface (the subset of `sha2::Digest` harbor uses).
pub trait Digest {
    /// Fresh hasher state.
    fn new() -> Self;
    /// Absorb `data` into the hash state.
    fn update(&mut self, data: impl AsRef<[u8]>);
    /// Consume the hasher and return the 32-byte digest.
    fn finalize(self) -> [u8; 32];
    /// One-shot convenience: hash `data` in a single call.
    fn digest(data: impl AsRef<[u8]>) -> [u8; 32]
    where
        Self: Sized,
    {
        let mut h = Self::new();
        h.update(data);
        h.finalize()
    }
}

/// Round constants: fractional parts of the cube roots of the first 64
/// primes (FIPS 180-4 §4.2.2).
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Initial state: fractional parts of the square roots of the first 8
/// primes (FIPS 180-4 §5.3.3).
const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

/// SHA-256 streaming hasher.
#[derive(Clone)]
pub struct Sha256 {
    state: [u32; 8],
    /// Partial input block awaiting a full 64 bytes.
    buf: [u8; 64],
    buf_len: usize,
    /// Total message length in bytes (for the length suffix).
    total_len: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Sha256 {
            state: H0,
            buf: [0u8; 64],
            buf_len: 0,
            total_len: 0,
        }
    }
}

impl Sha256 {
    /// One FIPS 180-4 §6.2.2 compression of a 64-byte block.
    fn compress(state: &mut [u32; 8], block: &[u8]) {
        debug_assert_eq!(block.len(), 64);
        let mut w = [0u32; 64];
        for (i, word) in w.iter_mut().take(16).enumerate() {
            *word = u32::from_be_bytes([
                block[4 * i],
                block[4 * i + 1],
                block[4 * i + 2],
                block[4 * i + 3],
            ]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = *state;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        state[0] = state[0].wrapping_add(a);
        state[1] = state[1].wrapping_add(b);
        state[2] = state[2].wrapping_add(c);
        state[3] = state[3].wrapping_add(d);
        state[4] = state[4].wrapping_add(e);
        state[5] = state[5].wrapping_add(f);
        state[6] = state[6].wrapping_add(g);
        state[7] = state[7].wrapping_add(h);
    }
}

impl Digest for Sha256 {
    fn new() -> Self {
        Self::default()
    }

    fn update(&mut self, data: impl AsRef<[u8]>) {
        let mut input = data.as_ref();
        self.total_len += input.len() as u64;
        // top up a partial block first
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(input.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&input[..take]);
            self.buf_len += take;
            input = &input[take..];
            if self.buf_len == 64 {
                let buf = self.buf;
                Self::compress(&mut self.state, &buf);
                self.buf_len = 0;
            }
        }
        // whole blocks straight from the input
        while input.len() >= 64 {
            Self::compress(&mut self.state, &input[..64]);
            input = &input[64..];
        }
        // stash the tail
        if !input.is_empty() {
            self.buf[..input.len()].copy_from_slice(input);
            self.buf_len = input.len();
        }
    }

    fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total_len * 8;
        // pad: 0x80, zeros to 56 mod 64, then the 64-bit big-endian length
        let mut pad = [0u8; 72];
        pad[0] = 0x80;
        let pad_len = if self.buf_len < 56 {
            56 - self.buf_len
        } else {
            120 - self.buf_len
        };
        pad[pad_len..pad_len + 8].copy_from_slice(&bit_len.to_be_bytes());
        self.update(&pad[..pad_len + 8]);
        debug_assert_eq!(self.buf_len, 0);
        let mut out = [0u8; 32];
        for (i, word) in self.state.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&word.to_be_bytes());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn empty_vector() {
        assert_eq!(
            hex(&Sha256::digest(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
    }

    #[test]
    fn abc_vector() {
        assert_eq!(
            hex(&Sha256::digest("abc".as_bytes())),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn two_block_vector() {
        // FIPS 180-4 example: 448-bit message crossing one block
        assert_eq!(
            hex(&Sha256::digest(
                "abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq".as_bytes()
            )),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn incremental_equals_oneshot() {
        let mut h = Sha256::new();
        h.update("hello ".as_bytes());
        h.update("world".as_bytes());
        // also exercise block-boundary buffering
        let mut h2 = Sha256::new();
        for chunk in "hello world".as_bytes().chunks(1) {
            h2.update(chunk);
        }
        let oneshot = Sha256::digest("hello world".as_bytes());
        assert_eq!(h.finalize(), oneshot);
        assert_eq!(h2.finalize(), oneshot);
    }

    #[test]
    fn long_input_crosses_many_blocks() {
        let data = vec![0xabu8; 1000];
        let mut h = Sha256::new();
        h.update(&data[..100]);
        h.update(&data[100..477]);
        h.update(&data[477..]);
        assert_eq!(h.finalize(), Sha256::digest(&data[..]));
    }
}
