//! Integration: the six figure-shape claims of DESIGN.md §6, asserted
//! programmatically over the full coordinator path (deterministic
//! builtin calibration so CI does not depend on machine speed).

use harbor::config::ExperimentConfig;
use harbor::coordinator::Coordinator;
use harbor::runtime::CalibrationTable;

fn coordinator() -> Coordinator {
    Coordinator::with_table(CalibrationTable::builtin_fallback())
}

fn mean(figs: &[harbor::bench::Figure], fig_idx: usize, label: &str) -> f64 {
    figs[fig_idx]
        .rows
        .iter()
        .find(|r| r.label == label)
        .unwrap_or_else(|| panic!("no row `{label}`"))
        .stats
        .mean()
}

#[test]
fn fig2_docker_rkt_native_within_one_percentish_vm_fifteen() {
    let cfg = ExperimentConfig {
        reps: 3,
        ..ExperimentConfig::paper_default("fig2").unwrap()
    };
    let figs = coordinator().run(&cfg).unwrap();
    assert_eq!(figs.len(), 4);
    for (i, fig) in figs.iter().enumerate() {
        let native = mean(&figs, i, "native");
        let docker = mean(&figs, i, "docker");
        let rkt = mean(&figs, i, "rkt");
        let vm = mean(&figs, i, "vm");
        assert!(
            (docker - native).abs() / native < 0.05,
            "{}: docker vs native",
            fig.title
        );
        assert!((rkt - native).abs() / native < 0.05, "{}: rkt", fig.title);
        let vm_ratio = vm / native;
        assert!(
            (1.05..1.35).contains(&vm_ratio),
            "{}: vm/native = {vm_ratio:.3}",
            fig.title
        );
    }
}

#[test]
fn fig3_native_equals_shifter_system_mpi_and_container_mpi_diverges() {
    let cfg = ExperimentConfig {
        reps: 2,
        ..ExperimentConfig::paper_default("fig3").unwrap()
    };
    let figs = coordinator().run(&cfg).unwrap();
    assert_eq!(figs.len(), 4); // 24, 48, 96, 192

    for (i, &ranks) in [24usize, 48, 96, 192].iter().enumerate() {
        let native = mean(&figs, i, "native");
        let sys = mean(&figs, i, "shifter (system MPI)");
        let cont = mean(&figs, i, "shifter (container MPI)");
        assert!(
            (sys - native).abs() / native < 0.10,
            "ranks {ranks}: system-MPI shifter should match native"
        );
        if ranks == 24 {
            // single node: container MPI survives
            assert!(cont / native < 1.5, "ranks 24: container MPI ok on-node");
        } else {
            assert!(
                cont / native > 2.0,
                "ranks {ranks}: container MPI should blow up, got {:.2}x",
                cont / native
            );
        }
    }
    // ... and the divergence grows with scale
    let r48 = mean(&figs, 1, "shifter (container MPI)") / mean(&figs, 1, "native");
    let r192 = mean(&figs, 3, "shifter (container MPI)") / mean(&figs, 3, "native");
    assert!(r192 > r48, "divergence should grow: {r48:.2} -> {r192:.2}");
}

#[test]
fn fig4_native_python_dominated_by_import_and_more_variable() {
    let cfg = ExperimentConfig {
        reps: 3,
        ..ExperimentConfig::paper_default("fig4").unwrap()
    };
    let figs = coordinator().run(&cfg).unwrap();
    assert_eq!(figs.len(), 3); // 24, 48, 96

    for (i, &ranks) in [24usize, 48, 96].iter().enumerate() {
        let native_row = figs[i].rows.iter().find(|r| r.label == "native").unwrap();
        let shifter_row = figs[i]
            .rows
            .iter()
            .find(|r| r.label == "shifter (system MPI)")
            .unwrap();
        let native = native_row.stats.mean();
        let shifter = shifter_row.stats.mean();
        assert!(
            native > 1.5 * shifter,
            "ranks {ranks}: native total should dominate (import)"
        );
        // per-phase compute must still match
        let phase = |row: &harbor::bench::Row, name: &str| {
            row.breakdown
                .iter()
                .find(|(k, _)| k == name)
                .map(|(_, v)| *v)
                .unwrap()
        };
        let solve_gap = (phase(native_row, "solve") - phase(shifter_row, "solve")).abs()
            / phase(native_row, "solve");
        assert!(solve_gap < 0.15, "ranks {ranks}: solve phases differ {solve_gap:.3}");
        assert!(phase(native_row, "import") > 5.0 * phase(shifter_row, "import"));
        // native is also more variable (MDS noise)
        assert!(
            native_row.stats.cv() >= shifter_row.stats.cv(),
            "ranks {ranks}: native cv {} < shifter cv {}",
            native_row.stats.cv(),
            shifter_row.stats.cv()
        );
    }

    // the import gap grows with rank count
    let native_24 = mean(&figs, 0, "native");
    let native_96 = mean(&figs, 2, "native");
    assert!(native_96 > 2.0 * native_24);
}

#[test]
fn fig5a_native_wins_by_single_digit_percent() {
    let cfg = ExperimentConfig {
        reps: 3,
        sizes: vec![0],
        ..ExperimentConfig::paper_default("fig5a").unwrap()
    };
    let figs = coordinator().run(&cfg).unwrap();
    let native = mean(&figs, 0, "native");
    let docker = mean(&figs, 0, "docker");
    let rkt = mean(&figs, 0, "rkt");
    for (name, t) in [("docker", docker), ("rkt", rkt)] {
        let gap = (native - t) / native;
        assert!(
            (0.0..0.08).contains(&gap),
            "{name}: expected small native win, gap {gap:.4}"
        );
    }
}

#[test]
fn fig5b_shifter_parity_at_large_sizes() {
    let cfg = ExperimentConfig {
        reps: 3,
        sizes: vec![0],
        ..ExperimentConfig::paper_default("fig5b").unwrap()
    };
    let figs = coordinator().run(&cfg).unwrap();
    let native = mean(&figs, 0, "native");
    let shifter = mean(&figs, 0, "shifter (system MPI)");
    let gap = (native - shifter).abs() / native;
    assert!(gap < 0.08, "fig5b parity violated: {gap:.4}");
}

#[test]
fn error_bars_are_populated() {
    let cfg = ExperimentConfig {
        reps: 4,
        ..ExperimentConfig::paper_default("fig2").unwrap()
    };
    let figs = coordinator().run(&cfg).unwrap();
    for fig in &figs {
        for row in &fig.rows {
            assert_eq!(row.stats.n(), 4);
            // jitter produces non-identical samples on compute tests
            if !fig.title.contains("IO") {
                assert!(row.stats.std() > 0.0, "{}/{}", fig.title, row.label);
            }
        }
    }
}

#[test]
fn json_reports_parse_back() {
    let cfg = ExperimentConfig {
        reps: 1,
        ranks: vec![24],
        ..ExperimentConfig::paper_default("fig3").unwrap()
    };
    let figs = coordinator().run(&cfg).unwrap();
    for f in figs {
        let v = harbor::util::json::parse(&f.to_json().to_pretty()).unwrap();
        assert_eq!(v.get("unit").as_str(), Some("run time [s]"));
        assert!(!v.get("rows").as_arr().unwrap().is_empty());
    }
}
