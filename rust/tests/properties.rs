//! Property-based invariant tests (via `harbor::util::proptest`).
//!
//! Each property runs hundreds of randomly generated cases with a
//! reproducing seed reported on failure.

use harbor::cluster::{launch, MachineSpec};
use harbor::container::image::{FileEntry, Layer};
use harbor::container::LayerStore;
use harbor::des::{Duration, EventQueue, FifoResource, VirtualTime};
use harbor::fem::grid::{factor3, opposite, Decomp, LocalField};
use harbor::mpi::Comm;
use harbor::net::{Fabric, FabricKind};
use harbor::util::json::{parse, Value};
use harbor::util::proptest::{run, Gen};

#[test]
fn prop_event_queue_pops_sorted_and_fifo_stable() {
    run("event-queue-order", 200, |g: &mut Gen| {
        let n = g.usize_in(1, 200);
        let mut q = EventQueue::new();
        let mut items = Vec::new();
        for i in 0..n {
            let t = VirtualTime::ZERO + Duration::from_nanos(g.u64_in(0, 50)); // many ties
            q.push(t, i);
            items.push((t, i));
        }
        let mut last: Option<(VirtualTime, usize)> = None;
        while let Some((t, i)) = q.pop() {
            if let Some((lt, li)) = last {
                if t < lt {
                    return Err(format!("time went backwards: {lt:?} -> {t:?}"));
                }
                if t == lt {
                    // FIFO among equal timestamps: push index must increase
                    if i < li {
                        return Err(format!("FIFO violated at {t:?}: {li} then {i}"));
                    }
                }
            }
            last = Some((t, i));
        }
        Ok(())
    });
}

#[test]
fn prop_fifo_resource_conserves_and_orders() {
    run("fifo-resource", 200, |g: &mut Gen| {
        let servers = g.usize_in(1, 8);
        let mut r = FifoResource::new(servers);
        let n = g.usize_in(1, 100);
        let mut total = Duration::ZERO;
        let mut completions = Vec::new();
        let mut arrival = VirtualTime::ZERO;
        for _ in 0..n {
            arrival = arrival + Duration::from_nanos(g.u64_in(0, 1000));
            let service = Duration::from_nanos(g.u64_in(1, 10_000));
            total += service;
            let done = r.submit(arrival, service);
            if done < arrival + service {
                return Err("completed before arrival + service".into());
            }
            completions.push(done);
        }
        if r.busy_time() != total {
            return Err("busy time != sum of service".into());
        }
        // utilisation bound: makespan * servers >= busy time
        let makespan = completions.iter().max().unwrap().as_secs_f64();
        if makespan * servers as f64 + 1e-12 < total.as_secs_f64() {
            return Err("impossible utilisation".into());
        }
        Ok(())
    });
}

#[test]
fn prop_layer_store_content_addressing() {
    run("layer-cas", 150, |g: &mut Gen| {
        let mut store = LayerStore::new();
        let n_layers = g.usize_in(1, 20);
        for _ in 0..n_layers {
            let directive = format!("RUN {}", g.ident(10));
            let files: Vec<FileEntry> = (0..g.usize_in(0, 5))
                .map(|i| FileEntry {
                    path: format!("/f{i}"),
                    bytes: g.u64_in(1, 10_000),
                })
                .collect();
            let a = Layer::derive(None, &directive, files.clone());
            let b = Layer::derive(None, &directive, files);
            if a.id != b.id {
                return Err("same content, different hash".into());
            }
            store.insert(a.clone());
            let was_new = store.insert(b);
            if was_new {
                return Err("duplicate content stored twice".into());
            }
        }
        if store.dedup_ratio() < 1.0 {
            return Err("dedup ratio < 1".into());
        }
        if store.physical_bytes() > store.logical_bytes() {
            return Err("physical > logical".into());
        }
        Ok(())
    });
}

#[test]
fn prop_factor3_products_and_sortedness() {
    run("factor3", 300, |g: &mut Gen| {
        let p = g.usize_in(1, 512);
        let f = factor3(p);
        if f.iter().product::<usize>() != p {
            return Err(format!("{p}: product {:?}", f));
        }
        if !(f[0] <= f[1] && f[1] <= f[2]) {
            return Err(format!("{p}: not sorted {f:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_decomp_neighbors_mutual_and_message_list_symmetric() {
    run("decomp-neighbors", 100, |g: &mut Gen| {
        let ranks = g.usize_in(1, 64);
        let d = Decomp::new(ranks, 8);
        for r in 0..ranks {
            for (dir, nb) in d.neighbors(r).into_iter().enumerate() {
                if let Some(nb) = nb {
                    if d.neighbors(nb)[opposite(dir)] != Some(r) {
                        return Err(format!("rank {r} dir {dir}: not mutual"));
                    }
                }
            }
        }
        // message list: every (a -> b) has a matching (b -> a)
        let msgs = d.halo_messages(1);
        for &(a, b, _) in &msgs {
            if !msgs.iter().any(|&(x, y, _)| x == b && y == a) {
                return Err(format!("asymmetric messages {a}->{b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_halo_exchange_conserves_data() {
    // what rank A's face sends is exactly what rank B's halo receives
    run("halo-conservation", 60, |g: &mut Gen| {
        let ranks = *g.choose(&[2usize, 4, 8]);
        let n = 4;
        let d = Decomp::new(ranks, n);
        let mut fields: Vec<LocalField> = (0..ranks)
            .map(|r| {
                let interior: Vec<f32> = (0..n * n * n)
                    .map(|i| (r * 1000 + i) as f32 + g.f64_in(0.0, 1.0) as f32)
                    .collect();
                LocalField::from_interior(n, &interior)
            })
            .collect();
        let faces_before: Vec<Vec<Vec<f32>>> = (0..ranks)
            .map(|r| (0..6).map(|dir| fields[r].face(dir)).collect())
            .collect();
        let m = MachineSpec::workstation();
        let mut comm = Comm::new(launch(&m, ranks).unwrap(), Fabric::shared_mem());
        harbor::fem::grid::exchange_halos(&d, &mut fields, &mut comm);
        for r in 0..ranks {
            for (dir, nb) in d.neighbors(r).into_iter().enumerate() {
                if let Some(nb) = nb {
                    // my halo in `dir` must now hold nb's pre-exchange face
                    // toward opposite(dir); compare via a probe field that
                    // has ONLY that halo plane set
                    let mut probe = LocalField::zeros(n);
                    probe.set_halo(dir, &faces_before[nb][opposite(dir)]);
                    let np = n + 2;
                    for idx in 0..np * np * np {
                        if probe.data[idx] != 0.0 && probe.data[idx] != fields[r].data[idx] {
                            return Err(format!("rank {r} dir {dir}: halo mismatch"));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_comm_collectives_monotone_and_synchronising() {
    run("comm-collectives", 80, |g: &mut Gen| {
        let machine = MachineSpec::edison();
        let ranks = g.usize_in(2, 96);
        let kind = *g.choose(&[FabricKind::Aries, FabricKind::TcpEthernet]);
        let mut comm = Comm::new(launch(&machine, ranks).unwrap(), Fabric::by_kind(kind));
        // random per-rank head start
        for r in 0..ranks {
            comm.advance(r, Duration::from_nanos(g.u64_in(0, 1_000_000)));
        }
        let before = comm.max_clock();
        let small = g.u64_in(1, 64);
        comm.allreduce(small);
        let after_small = comm.max_clock();
        if after_small <= before {
            return Err("allreduce did not advance time".into());
        }
        for r in 0..ranks {
            if comm.clock(r) != after_small {
                return Err("allreduce did not synchronise".into());
            }
        }
        // bigger payload costs at least as much
        let mut comm2 = Comm::new(launch(&machine, ranks).unwrap(), Fabric::by_kind(kind));
        let mut comm3 = Comm::new(launch(&machine, ranks).unwrap(), Fabric::by_kind(kind));
        comm2.allreduce(small);
        comm3.allreduce(small * 1000);
        if comm3.max_clock() < comm2.max_clock() {
            return Err("allreduce cost not monotone in bytes".into());
        }
        Ok(())
    });
}

#[test]
fn prop_json_round_trip_fuzz() {
    fn gen_value(g: &mut Gen, depth: usize) -> Value {
        match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
            0 => Value::Null,
            1 => Value::Bool(g.bool()),
            2 => Value::Num((g.f64_in(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => Value::Str(g.ident(12)),
            4 => Value::Arr((0..g.usize_in(0, 4)).map(|_| gen_value(g, depth - 1)).collect()),
            _ => Value::Obj(
                (0..g.usize_in(0, 4))
                    .map(|_| (g.ident(8), gen_value(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    run("json-round-trip", 300, |g: &mut Gen| {
        let v = gen_value(g, 3);
        let compact = parse(&v.to_string()).map_err(|e| e.to_string())?;
        let pretty = parse(&v.to_pretty()).map_err(|e| e.to_string())?;
        if compact != v || pretty != v {
            return Err(format!("round trip changed value: {v:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_launch_placement_block_invariants() {
    run("placement", 200, |g: &mut Gen| {
        let machine = MachineSpec::edison();
        let ranks = g.usize_in(1, 400);
        let alloc = launch(&machine, ranks).map_err(|e| e.to_string())?;
        // block placement: node ids are non-decreasing and dense
        let mut last = 0;
        for &n in &alloc.node_of {
            if n < last {
                return Err("node ids decrease".into());
            }
            if n > last + 1 {
                return Err("node ids skip".into());
            }
            last = last.max(n);
        }
        if alloc.nodes_used != last + 1 {
            return Err("nodes_used wrong".into());
        }
        // no node hosts more ranks than cores
        for node in 0..alloc.nodes_used {
            if alloc.ranks_on_node(node).count() > machine.cores_per_node {
                return Err(format!("node {node} oversubscribed"));
            }
        }
        Ok(())
    });
}
