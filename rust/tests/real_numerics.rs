//! Integration: the distributed solvers with REAL PJRT numerics.
//!
//! These tests are the ground-truth anchor of the whole simulation: the
//! same drivers the figures use, executed with actual AOT-kernel
//! numerics at small rank counts, verified against analytic solutions.
//! They skip (with a note) if `make artifacts` has not run.

use harbor::cluster::{launch, MachineSpec};
use harbor::fem::cg::{distributed_cg, estimate_cg_iters, precond_cg_single, CgConfig};
use harbor::fem::exec::{ComputeScale, Exec};
use harbor::fem::gmg::{vcycles, GmgConfig};
use harbor::fem::grid::Decomp;
use harbor::mpi::Comm;
use harbor::net::Fabric;
use harbor::runtime::{artifacts_available, Engine, TensorBuf};

fn engine() -> Option<Engine> {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::open_default().unwrap())
}

fn comm(ranks: usize) -> Comm {
    Comm::new(
        launch(&MachineSpec::workstation(), ranks).unwrap(),
        Fabric::shared_mem(),
    )
}

/// Assemble the manufactured RHS on every rank via the AOT kernel.
fn assemble(engine: &mut Engine, decomp: &Decomp, n: usize) -> Vec<Vec<f32>> {
    let h = 1.0f32 / decomp.n_global()[0] as f32;
    let mut exec = Exec::Real { engine };
    let mut c = comm(decomp.ranks());
    let mut scale = ComputeScale::none();
    (0..decomp.ranks())
        .map(|r| {
            let o = decomp.origin(r);
            let origin = TensorBuf::new(vec![3], vec![o[0] as f32, o[1] as f32, o[2] as f32]);
            exec.call(
                &mut c,
                &mut scale,
                r,
                &format!("assemble_rhs3d_n{n}"),
                &[origin, TensorBuf::scalar1(h)],
            )
            .unwrap()
            .unwrap()[0]
                .data
                .clone()
        })
        .collect()
}

fn analytic_max_err(decomp: &Decomp, n: usize, solution: &[Vec<f32>]) -> f64 {
    let h = 1.0 / decomp.n_global()[0] as f64;
    let pi = std::f64::consts::PI;
    let mut max_err = 0.0f64;
    for r in 0..decomp.ranks() {
        let o = decomp.origin(r);
        for z in 0..n {
            for y in 0..n {
                for x in 0..n {
                    let exact = ((o[2] + x) as f64 * h + 0.5 * h).mul_add(0.0, 0.0)
                        + (pi * ((o[2] + x) as f64 + 0.5) * h).sin()
                            * (pi * ((o[1] + y) as f64 + 0.5) * h).sin()
                            * (pi * ((o[0] + z) as f64 + 0.5) * h).sin();
                    let got = solution[r][(z * n + y) * n + x] as f64;
                    max_err = max_err.max((got - exact).abs());
                }
            }
        }
    }
    max_err
}

#[test]
fn distributed_cg_8_ranks_matches_analytic_solution() {
    let Some(mut engine) = engine() else { return };
    let n = 16;
    let decomp = Decomp::new(8, n); // 2x2x2 -> global 32³
    let rhs = assemble(&mut engine, &decomp, n);

    let mut exec = Exec::Real { engine: &mut engine };
    let mut c = comm(8);
    let mut scale = ComputeScale::none();
    let out = distributed_cg(
        &mut exec,
        &mut c,
        &mut scale,
        &decomp,
        &rhs,
        &CgConfig {
            tol: 1e-5,
            ..CgConfig::default()
        },
    )
    .unwrap();
    assert!(out.rel_residual.unwrap() < 1e-4);
    let err = analytic_max_err(&decomp, n, out.solution.as_ref().unwrap());
    assert!(err < 0.05, "discretisation error {err}");
    // virtual time advanced (compute + halo + allreduce all charged)
    assert!(c.max_clock().as_secs_f64() > 0.0);
    assert!(c.stats().allreduces >= out.iters as u64);
}

#[test]
fn decomposition_invariance_1_vs_8_ranks() {
    // the SAME global problem solved on 1 rank (32³ block) and on
    // 8 ranks (16³ blocks) must give the same solution — the strongest
    // possible test of the halo-exchange + distributed-reduction path
    let Some(mut engine) = engine() else { return };

    let d1 = Decomp::new(1, 32);
    let rhs1 = assemble(&mut engine, &d1, 32);
    let mut exec = Exec::Real { engine: &mut engine };
    let out1 = distributed_cg(
        &mut exec,
        &mut comm(1),
        &mut ComputeScale::none(),
        &d1,
        &rhs1,
        &CgConfig {
            tol: 1e-6,
            ..CgConfig::default()
        },
    )
    .unwrap();

    let d8 = Decomp::new(8, 16);
    let rhs8 = assemble(&mut engine, &d8, 16);
    let mut exec = Exec::Real { engine: &mut engine };
    let out8 = distributed_cg(
        &mut exec,
        &mut comm(8),
        &mut ComputeScale::none(),
        &d8,
        &rhs8,
        &CgConfig {
            tol: 1e-6,
            ..CgConfig::default()
        },
    )
    .unwrap();

    // compare the 8-rank solution against the single-domain one
    let sol1 = &out1.solution.unwrap()[0]; // 32³ row-major
    let sol8 = out8.solution.unwrap();
    let n = 16;
    let mut max_diff = 0.0f32;
    for r in 0..8 {
        let o = d8.origin(r);
        for z in 0..n {
            for y in 0..n {
                for x in 0..n {
                    let global = ((o[0] + z) * 32 + (o[1] + y)) * 32 + (o[2] + x);
                    let diff = (sol8[r][(z * n + y) * n + x] - sol1[global]).abs();
                    max_diff = max_diff.max(diff);
                }
            }
        }
    }
    assert!(max_diff < 5e-4, "1-rank vs 8-rank solutions differ by {max_diff}");
}

#[test]
fn cg_iteration_estimate_matches_real_runs() {
    let Some(mut engine) = engine() else { return };
    let n = 16;
    let decomp = Decomp::new(8, n);
    let rhs = assemble(&mut engine, &decomp, n);
    let mut exec = Exec::Real { engine: &mut engine };
    let out = distributed_cg(
        &mut exec,
        &mut comm(8),
        &mut ComputeScale::none(),
        &decomp,
        &rhs,
        &CgConfig {
            tol: 1e-5,
            ..CgConfig::default()
        },
    )
    .unwrap();
    let est = estimate_cg_iters(decomp.n_global()[0], 1e-5);
    let real = out.iters;
    let ratio = est as f64 / real as f64;
    assert!(
        (0.5..2.0).contains(&ratio),
        "estimate {est} vs real {real} (ratio {ratio:.2})"
    );
}

#[test]
fn multigrid_vcycles_reduce_residual_distributed() {
    let Some(mut engine) = engine() else { return };
    let decomp = Decomp::new(8, 32);
    let rhs = assemble(&mut engine, &decomp, 32);
    let mut exec = Exec::Real { engine: &mut engine };
    let out = vcycles(
        &mut exec,
        &mut comm(8),
        &mut ComputeScale::none(),
        &decomp,
        &rhs,
        &GmgConfig {
            nu: 2,
            cycles: 5,
            fine_level: 0,
        },
    )
    .unwrap();
    let h = &out.residual_history;
    assert_eq!(h.len(), 5);
    // monotone decrease, overall at least ~10x over 5 cycles (the
    // block-local coarse solve weakens the classic factor; see DESIGN)
    for w in h.windows(2) {
        assert!(w[1] < w[0] * 1.001, "residual did not decrease: {h:?}");
    }
    assert!(h[4] < h[0] / 10.0, "too-slow V-cycle convergence: {h:?}");
}

#[test]
fn preconditioned_cg_converges_much_faster_than_plain() {
    let Some(mut engine) = engine() else { return };
    let d = Decomp::new(1, 32);
    let rhs = assemble(&mut engine, &d, 32);

    let mut exec = Exec::Real { engine: &mut engine };
    let plain = distributed_cg(
        &mut exec,
        &mut comm(1),
        &mut ComputeScale::none(),
        &d,
        &rhs,
        &CgConfig {
            tol: 1e-5,
            ..CgConfig::default()
        },
    )
    .unwrap();

    let mut exec = Exec::Real { engine: &mut engine };
    let pcg = precond_cg_single(
        &mut exec,
        &mut comm(1),
        &mut ComputeScale::none(),
        &rhs[0],
        1e-5,
        100,
        0,
    )
    .unwrap();

    assert!(pcg.rel_residual.unwrap() < 1e-4);
    assert!(
        pcg.iters * 3 < plain.iters,
        "PCG {} iters vs CG {} — preconditioner not helping",
        pcg.iters,
        plain.iters
    );
}

#[test]
fn elasticity_cg_converges_real() {
    let Some(mut engine) = engine() else { return };
    let n = 16;
    let d = Decomp::new(1, n);
    // smooth RHS for the vector problem
    let rhs: Vec<Vec<f32>> = vec![(0..3 * n * n * n)
        .map(|i| {
            let phase = i as f32 * 0.001;
            phase.sin() * 0.1
        })
        .collect()];
    let mut exec = Exec::Real { engine: &mut engine };
    let out = distributed_cg(
        &mut exec,
        &mut comm(1),
        &mut ComputeScale::none(),
        &d,
        &rhs,
        &CgConfig {
            tol: 1e-5,
            elasticity: true,
            max_iters: 1500,
            ..CgConfig::default()
        },
    )
    .unwrap();
    assert!(
        out.rel_residual.unwrap() < 1e-4,
        "elasticity CG residual {:?} after {} iters",
        out.rel_residual,
        out.iters
    );
}
