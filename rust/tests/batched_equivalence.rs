//! Property tests: the rank-class batched engine is `VirtualTime`-
//! identical to the per-rank reference path on randomized
//! decompositions (the tentpole invariant of the batching refactor).
//!
//! Three layers are exercised:
//!   * `Comm::exchange_uniform` vs `Comm::exchange` on the same halo
//!     phase from a uniform entry state;
//!   * modeled `distributed_cg` / `vcycles` on a batched vs a plain
//!     communicator (jitter on — the single-draw-per-phase semantics
//!     must keep the paths in lockstep, and GMG additionally exercises
//!     the transparent fallback mid-cycle);
//!   * `replay` vs `replay_batched` on the image-mounted filesystem,
//!     where the per-node burst is exact, and on the contended parallel
//!     filesystem, where it must stay inside the per-burst noise band
//!     while conserving MDS accounting.

use harbor::cluster::{launch, MachineSpec};
use harbor::des::{Duration, VirtualTime};
use harbor::fem::cg::{distributed_cg, CgConfig};
use harbor::fem::exec::{ComputeScale, Exec};
use harbor::fem::gmg::{vcycles, GmgConfig};
use harbor::fem::grid::Decomp;
use harbor::fs::{ImageFs, ParallelFs};
use harbor::mpi::Comm;
use harbor::net::{Fabric, FabricKind};
use harbor::pyimport::{replay, replay_batched, ModuleGraph};
use harbor::runtime::CalibrationTable;
use harbor::util::proptest::{run, Gen};

fn comm_pair(ranks: usize, kind: FabricKind, decomp: &Decomp) -> (Comm, Comm) {
    let m = MachineSpec::edison();
    let mut batched = Comm::new(launch(&m, ranks).unwrap(), Fabric::by_kind(kind));
    let per_rank = Comm::new(launch(&m, ranks).unwrap(), Fabric::by_kind(kind));
    assert!(batched.set_classes(decomp.rank_classes(batched.allocation())));
    (batched, per_rank)
}

fn pick_fabric(g: &mut Gen) -> FabricKind {
    *g.choose(&[FabricKind::Aries, FabricKind::TcpEthernet, FabricKind::SharedMem])
}

#[test]
fn prop_exchange_uniform_bit_identical_from_uniform_entry() {
    run("exchange-uniform-equivalence", 150, |g: &mut Gen| {
        let ranks = g.usize_in(1, 220);
        let kind = pick_fabric(g);
        let bytes = g.u64_in(0, 1 << 20);
        let head_start = Duration::from_nanos(g.u64_in(0, 1_000_000_000));
        let decomp = Decomp::new(ranks, 8);
        let (mut b, mut p) = comm_pair(ranks, kind, &decomp);
        b.advance_uniform(head_start);
        p.advance_uniform(head_start);
        let pattern = decomp.halo_pattern_for(&b, bytes);
        b.exchange_uniform(&pattern);
        p.exchange(&decomp.halo_messages(bytes));
        for r in 0..ranks {
            if b.clock(r) != p.clock(r) {
                return Err(format!(
                    "ranks {ranks} {kind:?} bytes {bytes}: rank {r} {:?} != {:?}",
                    b.clock(r),
                    p.clock(r)
                ));
            }
        }
        if !b.is_batched() {
            return Err("uniform-entry exchange should not fall back".into());
        }
        let (bs, ps) = (b.stats(), p.stats());
        if bs.p2p_messages != ps.p2p_messages || bs.p2p_bytes != ps.p2p_bytes {
            return Err("stats diverged".into());
        }
        Ok(())
    });
}

#[test]
fn prop_modeled_cg_bit_identical_with_jitter() {
    run("modeled-cg-equivalence", 40, |g: &mut Gen| {
        let ranks = g.usize_in(1, 200);
        let kind = *g.choose(&[FabricKind::Aries, FabricKind::TcpEthernet]);
        let seed = g.u64_in(0, 1 << 20);
        let iters = g.usize_in(1, 12);
        let decomp = Decomp::new(ranks, 16);
        let cfg = CgConfig {
            modeled_iters: iters,
            ..CgConfig::default()
        };
        let table = CalibrationTable::builtin_fallback();
        let go = |batched: bool| {
            let m = MachineSpec::edison();
            let mut comm = Comm::new(launch(&m, ranks).unwrap(), Fabric::by_kind(kind));
            if batched {
                comm.set_classes(decomp.rank_classes(comm.allocation()));
            }
            let mut scale = ComputeScale::new(1.0, 1.0, seed, 0.015);
            distributed_cg(
                &mut Exec::Modeled { table: &table },
                &mut comm,
                &mut scale,
                &decomp,
                &[],
                &cfg,
            )
            .unwrap();
            (0..ranks).map(|r| comm.clock(r)).collect::<Vec<_>>()
        };
        if go(true) != go(false) {
            return Err(format!("ranks {ranks} {kind:?} seed {seed}: clocks diverged"));
        }
        Ok(())
    });
}

#[test]
fn prop_modeled_gmg_bit_identical_through_fallback() {
    run("modeled-gmg-equivalence", 15, |g: &mut Gen| {
        let ranks = *g.choose(&[2usize, 8, 27, 48, 96]);
        let seed = g.u64_in(0, 1 << 20);
        let nu = g.usize_in(1, 3);
        let decomp = Decomp::new(ranks, 32);
        let table = CalibrationTable::builtin_fallback();
        let go = |batched: bool| {
            let m = MachineSpec::edison();
            let mut comm =
                Comm::new(launch(&m, ranks).unwrap(), Fabric::by_kind(FabricKind::Aries));
            if batched {
                comm.set_classes(decomp.rank_classes(comm.allocation()));
            }
            let mut scale = ComputeScale::new(1.0, 1.0, seed, 0.015);
            vcycles(
                &mut Exec::Modeled { table: &table },
                &mut comm,
                &mut scale,
                &decomp,
                &[],
                &GmgConfig { nu, cycles: 2, ..Default::default() },
            )
            .unwrap();
            (0..ranks).map(|r| comm.clock(r)).collect::<Vec<_>>()
        };
        if go(true) != go(false) {
            return Err(format!("ranks {ranks} nu {nu} seed {seed}: clocks diverged"));
        }
        Ok(())
    });
}

#[test]
fn prop_replay_batched_exact_on_image_fs() {
    run("replay-imagefs-equivalence", 25, |g: &mut Gen| {
        let ranks = g.usize_in(1, 120);
        let modules = g.usize_in(1, 60);
        let seed = g.u64_in(0, 1000);
        let start = VirtualTime::ZERO + Duration::from_nanos(g.u64_in(0, 1_000_000));
        let m = MachineSpec::edison();
        let alloc = launch(&m, ranks).unwrap();
        let graph = ModuleGraph::small(modules);
        let mut a = ImageFs::new(1_200_000_000, ParallelFs::edison(seed));
        let mut b = ImageFs::new(1_200_000_000, ParallelFs::edison(seed));
        let per_rank = replay(&graph, &alloc, &mut a, start);
        let batched = replay_batched(&graph, &alloc, &mut b, start);
        if per_rank.rank_done != batched.rank_done {
            return Err(format!("ranks {ranks} modules {modules}: rank_done diverged"));
        }
        if per_rank.wall != batched.wall {
            return Err("wall diverged".into());
        }
        Ok(())
    });
}

#[test]
fn prop_replay_batched_tracks_parallel_fs() {
    run("replay-parallelfs-band", 10, |g: &mut Gen| {
        let ranks = *g.choose(&[24usize, 48, 96]);
        let modules = g.usize_in(20, 80);
        let seed = g.u64_in(0, 1000);
        let m = MachineSpec::edison();
        let alloc = launch(&m, ranks).unwrap();
        let graph = ModuleGraph::small(modules);
        let mut a = ParallelFs::edison(seed);
        let mut b = ParallelFs::edison(seed);
        let per_rank = replay(&graph, &alloc, &mut a, VirtualTime::ZERO);
        let batched = replay_batched(&graph, &alloc, &mut b, VirtualTime::ZERO);
        // the burst occupies identical MDS handler time
        if a.mds_served() != b.mds_served() {
            return Err(format!("served {} vs {}", a.mds_served(), b.mds_served()));
        }
        let ratio = batched.wall.as_secs_f64() / per_rank.wall.as_secs_f64();
        if !(0.3..3.0).contains(&ratio) {
            return Err(format!(
                "ranks {ranks} modules {modules} seed {seed}: wall ratio {ratio:.3}"
            ));
        }
        Ok(())
    });
}
