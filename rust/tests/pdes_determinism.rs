//! Integration: the conservative parallel DES keeps the determinism
//! contract at scenario level.
//!
//! `--domains N` partitions each cell's event queue into lookahead
//! domains (`harbor::des::pdes`); the contract is that the partitioning
//! is a *pure parallelism knob* — every figure renders byte-identically
//! for any domain count, composed with any `--jobs` worker count.  The
//! unit and property layers pin the pop stream itself
//! (`des::pdes::tests`, `tests/queue_equivalence.rs`); this suite pins
//! the scenarios that schedule through [`CellQueue`]: the fleet deploy
//! engines (`fig1-scale`), the front-door protocol tier
//! (`registry-storm`) and the CI build farm (`build-farm`).
//! `ci/render_diff.sh` enforces the same sweep on the release binary.
//!
//! [`CellQueue`]: harbor::des::CellQueue

use harbor::bench::Figure;
use harbor::config::ExperimentConfig;
use harbor::coordinator::Coordinator;
use harbor::runtime::CalibrationTable;

fn coordinator(jobs: usize) -> Coordinator {
    Coordinator::with_table(CalibrationTable::builtin_fallback()).with_jobs(jobs)
}

fn render_all(figs: &[Figure]) -> String {
    figs.iter().map(|f| f.render()).collect::<Vec<_>>().join("\n")
}

/// Render `scenario` with `domains` lookahead domains on `jobs` matrix
/// workers, over a test-sized cell set.
fn render(scenario: &str, nodes: Vec<usize>, domains: usize, jobs: usize) -> String {
    let mut cfg = ExperimentConfig::paper_default(scenario).expect("registered default");
    cfg.nodes = nodes;
    cfg.domains = domains;
    render_all(&coordinator(jobs).run(&cfg).expect(scenario))
}

fn assert_domain_invariant(scenario: &str, nodes: Vec<usize>) {
    let reference = render(scenario, nodes.clone(), 1, 1);
    assert!(!reference.is_empty(), "`{scenario}` rendered nothing");
    for domains in [2usize, 4] {
        for jobs in [1usize, 4] {
            assert_eq!(
                render(scenario, nodes.clone(), domains, jobs),
                reference,
                "`{scenario}` must render byte-identically at \
                 --domains {domains} --jobs {jobs}"
            );
        }
    }
}

#[test]
fn fig1_scale_renders_identically_across_domains() {
    // both engines: 4 nodes rides Fleet-per-node sizes, 64 exercises
    // the collapsed ClassFleet path through the same CellQueue
    assert_domain_invariant("fig1-scale", vec![4, 64]);
}

#[test]
fn registry_storm_renders_identically_across_domains() {
    assert_domain_invariant("registry-storm", vec![2]);
}

#[test]
fn build_farm_renders_identically_across_domains() {
    assert_domain_invariant("build-farm", vec![4]);
}

#[test]
fn chaos_canary_renders_identically_across_domains() {
    // faulted deploys under retries — the late-push (preemption) path
    assert_domain_invariant("chaos-canary", vec![128]);
}
