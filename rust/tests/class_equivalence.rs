//! Integration: the node-class collapsed engine is *exact*, not an
//! approximation.
//!
//! `ClassFleet` prices a deploy in O(classes × layers) events; the
//! contract is that its [`FleetReport`]s render byte-identically to
//! the per-node [`Fleet`] walk — same makespans, same WAN/intra/retry
//! accounting, same fault reactions — for any seed, fleet size and
//! fault intensity. This suite sweeps that product space, checks the
//! byte-conservation invariant over class multiplicities, pins the
//! coordinator-level equivalence across `--jobs`, and round-trips the
//! `NodeSet` run algebra the class splitter is built on.

use harbor::bench::Figure;
use harbor::config::ExperimentConfig;
use harbor::container::{ClassFleet, Fleet, FleetConfig, FleetReport, NodeSet, RetryPolicy};
use harbor::coordinator::{fleet_registry, Coordinator};
use harbor::des::{Duration, FaultConfig, FaultSchedule, SimRng};
use harbor::runtime::CalibrationTable;

/// Image reference every deployment pulls (same as fig1-scale).
const REFERENCE: &str = "quay.io/fenicsproject/stable:2016.1.0r1";

/// Fault-window horizon for generated schedules.
const HORIZON: Duration = Duration(60_000_000_000);

fn conserved(report: &FleetReport) {
    assert_eq!(
        report.total_bytes(),
        report.cache.bytes_inserted + report.retried_bytes,
        "byte conservation violated in `{}`: {} moved != {} admitted + {} re-sent",
        report.reference,
        report.total_bytes(),
        report.cache.bytes_inserted,
        report.retried_bytes,
    );
}

/// Run the same seeded faulted deploy through both engines and demand
/// byte-identical renders plus matching semantic counters.
fn check_equivalent(nodes: usize, seed: u64, intensity: f64) {
    let config = FleetConfig::hpc(nodes);
    let policy = RetryPolicy::hpc();
    let fault_cfg = FaultConfig::new(nodes, 4, HORIZON, intensity);

    let run = |collapsed: bool| -> (FleetReport, f64) {
        let mut sharded = fleet_registry(REFERENCE).expect("fleet registry");
        let schedule =
            FaultSchedule::generate(&fault_cfg, &mut SimRng::new(seed, "fault-schedule"));
        sharded.apply_faults(&schedule);
        let mut jitter = SimRng::new(seed, "retry-jitter");
        let report = if collapsed {
            let mut fleet = ClassFleet::new(config.clone());
            let r = fleet
                .deploy_with_faults(
                    &mut sharded,
                    REFERENCE,
                    0..nodes,
                    &schedule,
                    &policy,
                    &mut jitter,
                )
                .expect("collapsed deploy");
            // class multiplicities must still tile the fleet exactly,
            // dead or alive, after the post-deploy re-merge
            let covered: u64 = fleet.classes().iter().map(|c| c.multiplicity()).sum();
            assert_eq!(covered, nodes as u64, "classes must partition the fleet");
            r
        } else {
            let mut fleet = Fleet::new(config.clone());
            fleet
                .deploy_with_faults(
                    &mut sharded,
                    REFERENCE,
                    0..nodes,
                    &schedule,
                    &policy,
                    &mut jitter,
                )
                .expect("per-node deploy")
        };
        // one post-deploy draw: equal bits proves both engines consumed
        // the jitter stream the same number of times
        (report, jitter.uniform(0.0, 1.0))
    };

    let (reference, ref_draw) = run(false);
    let (collapsed, col_draw) = run(true);

    let ctx = format!("nodes={nodes} seed={seed} intensity={intensity}");
    assert_eq!(
        collapsed.render(),
        reference.render(),
        "collapsed render diverged ({ctx})"
    );
    assert_eq!(collapsed.makespan, reference.makespan, "makespan ({ctx})");
    assert_eq!(collapsed.wan_bytes, reference.wan_bytes, "wan bytes ({ctx})");
    assert_eq!(collapsed.intra_bytes, reference.intra_bytes, "intra bytes ({ctx})");
    assert_eq!(collapsed.retried_bytes, reference.retried_bytes, "retried bytes ({ctx})");
    assert_eq!(collapsed.retries, reference.retries, "retries ({ctx})");
    assert_eq!(collapsed.failovers, reference.failovers, "failovers ({ctx})");
    assert_eq!(
        collapsed.permanently_failed, reference.permanently_failed,
        "permanently failed ({ctx})"
    );
    assert_eq!(collapsed.cache, reference.cache, "cache accounting ({ctx})");
    assert_eq!(collapsed.fault, reference.fault, "fault accounting ({ctx})");
    assert_eq!(
        collapsed.queue.pushes, reference.queue.pushes,
        "node-equivalent event count ({ctx})"
    );
    assert_eq!(
        collapsed.queue.depth_hwm, reference.queue.depth_hwm,
        "queue high-water mark ({ctx})"
    );
    assert_eq!(
        col_draw.to_bits(),
        ref_draw.to_bits(),
        "jitter stream position diverged ({ctx})"
    );
    conserved(&collapsed);
    conserved(&reference);
}

#[test]
fn collapsed_matches_per_node_across_seeds_at_512() {
    for seed in 0..8u64 {
        for &intensity in &[0.0, 0.4, 1.0] {
            check_equivalent(512, seed, intensity);
        }
    }
}

#[test]
fn collapsed_matches_per_node_across_seeds_at_4096() {
    // the bigger size exercises deeper fan-out waves (more chunk
    // classes) with the same seeds; 8 seeds x 3 intensities
    for seed in 0..8u64 {
        for &intensity in &[0.0, 0.4, 1.0] {
            check_equivalent(4096, seed, intensity);
        }
    }
}

fn render_all(figs: &[Figure]) -> String {
    figs.iter().map(|f| f.render()).collect::<Vec<_>>().join("\n")
}

#[test]
fn fig1_scale_renders_identically_across_engines_and_jobs() {
    // the coordinator-level golden diff the CI gate runs at 4096 nodes:
    // collapsed (default) and per-rank reference, serial and --jobs 4,
    // must all render the same figures
    let mut cfg = ExperimentConfig::paper_default("fig1-scale").expect("registered default");
    cfg.nodes = vec![64, 512];
    let mut renders = Vec::new();
    for batched in [true, false] {
        for jobs in [1, 4] {
            cfg.batched = batched;
            let figs = Coordinator::with_table(CalibrationTable::builtin_fallback())
                .with_jobs(jobs)
                .run(&cfg)
                .expect("fig1-scale runs");
            renders.push((batched, jobs, render_all(&figs)));
        }
    }
    let (_, _, golden) = &renders[0];
    for (batched, jobs, render) in &renders {
        assert_eq!(
            render, golden,
            "fig1-scale render diverged at batched={batched} jobs={jobs}"
        );
    }
}

#[test]
fn node_set_split_and_merge_round_trips() {
    // the splitter's run algebra: carving singletons and ranges out of
    // a fleet-wide run and unioning the pieces back must preserve the
    // multiplicity sum and reproduce the original set exactly
    let full = NodeSet::from_range(0..1000);
    let mut rest = full.clone();
    let low = rest.split_below(137);
    assert_eq!(low.len() + rest.len(), full.len());
    assert!(low.iter().all(|n| n < 137));
    assert!(rest.iter().all(|n| n >= 137));

    let mut pieces = vec![low, rest];
    for node in [0, 136, 137, 499, 998, 999] {
        let from = pieces
            .iter_mut()
            .find(|p| p.contains(node))
            .expect("node still covered");
        assert!(from.remove(node));
        pieces.push(NodeSet::singleton(node));
    }
    assert_eq!(pieces.iter().map(NodeSet::len).sum::<usize>(), full.len());

    let mut merged = NodeSet::from_range(0..0);
    for p in &pieces {
        merged.union(p);
    }
    assert_eq!(merged, full, "split pieces must union back to the fleet");
    merged.subtract(&full);
    assert!(merged.is_empty());
}
