//! Integration: the scenario engine's contracts.
//!
//! * `--jobs N` is bit-identical to serial for every registered
//!   scenario (the tentpole's acceptance bar);
//! * the `(scenario, cell-index)` seed hash is pinned, so cell seeds
//!   can never drift silently;
//! * the migrated figures render byte-identically to the pre-refactor
//!   coordinator (golden comparison against the legacy loops, inlined
//!   here verbatim);
//! * `mixed-fleet` runs end-to-end through the registry.

use harbor::bench::{repeat, Figure, Row};
use harbor::config::ExperimentConfig;
use harbor::container::{Fleet, FleetConfig};
use harbor::coordinator::{fleet_registry, Coordinator};
use harbor::fem::exec::Exec;
use harbor::metrics::Stats;
use harbor::platform::Platform;
use harbor::runtime::CalibrationTable;
use harbor::scenario::{cell_seed, CellId, ScenarioRegistry};
use harbor::workload::{run_fig2, run_hpgmg, run_poisson_app, AppConfig, Fig2Test, HpgmgConfig};

fn coordinator(jobs: usize) -> Coordinator {
    Coordinator::with_table(CalibrationTable::builtin_fallback()).with_jobs(jobs)
}

/// A configuration small enough to run every scenario in test time.
fn small_config(name: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_default(name).expect("registered default");
    cfg.reps = cfg.reps.min(2);
    if cfg.ranks.len() > 2 {
        cfg.ranks.truncate(2);
    }
    if cfg.sizes.len() > 1 {
        cfg.sizes.truncate(1);
    }
    if !cfg.nodes.is_empty() {
        cfg.nodes = vec![4, 16];
    }
    cfg
}

fn render_all(figs: &[Figure]) -> String {
    figs.iter().map(|f| f.render()).collect::<Vec<_>>().join("\n")
}

#[test]
fn every_scenario_is_jobs_invariant() {
    for name in ScenarioRegistry::builtin().names() {
        let cfg = small_config(name);
        let serial = coordinator(1).run(&cfg).expect(name);
        let parallel = coordinator(8).run(&cfg).expect(name);
        assert_eq!(
            render_all(&serial),
            render_all(&parallel),
            "`{name}` must render byte-identically under --jobs 8"
        );
        assert!(!serial.is_empty(), "`{name}` produced no figures");
    }
}

#[test]
fn cell_seed_hash_is_pinned() {
    // FNV-1a over scenario name + little-endian cell index, xor base —
    // computed independently; a change here silently reseeds every
    // post-refactor scenario, so these values are load-bearing
    assert_eq!(cell_seed(42, "fig2", 0), 0xb1f55e8092dc09af);
    assert_eq!(cell_seed(42, "fig2", 1), 0x92fa977787ecbf4e);
    assert_eq!(cell_seed(42, "mixed-fleet", 3), 0x38d64a01c80c72f8);
    assert_eq!(cell_seed(0, "fig5b", 7), 0x6743fd06a158fda1);
    let id = CellId {
        scenario: "mixed-fleet",
        index: 3,
    };
    assert_eq!(id.seed(42), 0x38d64a01c80c72f8);
}

#[test]
fn fig2_matches_the_legacy_coordinator_loop() {
    // the pre-refactor Coordinator::fig2, inlined verbatim
    let table = CalibrationTable::builtin_fallback();
    let cfg = ExperimentConfig {
        reps: 3,
        ..ExperimentConfig::paper_default("fig2").unwrap()
    };
    let mut legacy = Vec::new();
    for test in Fig2Test::ALL {
        let mut fig = Figure::new(
            format!("Fig 2 — {} (workstation)", test.label()),
            "run time [s]",
            false,
        );
        for platform in Platform::workstation_set() {
            let stats = repeat(cfg.reps, |rep| {
                let mut exec = Exec::Modeled { table: &table };
                run_fig2(test, platform, &mut exec, cfg.seed + rep as u64)
                    .expect("fig2 run")
                    .as_secs_f64()
            });
            fig.push(Row::new(platform.label(), stats));
        }
        fig.note(format!("calibration: {}", table.source));
        legacy.push(fig);
    }

    let through_registry = coordinator(4).run(&cfg).unwrap();
    assert_eq!(render_all(&legacy), render_all(&through_registry));
}

#[test]
fn fig3_matches_the_legacy_coordinator_loop() {
    // the pre-refactor Coordinator::fig3, inlined verbatim (rep-0
    // breakdown, per-ranks figures, off-scale note)
    let table = CalibrationTable::builtin_fallback();
    let cfg = ExperimentConfig {
        reps: 2,
        ranks: vec![24, 192],
        ..ExperimentConfig::paper_default("fig3").unwrap()
    };
    let mut legacy = Vec::new();
    for &ranks in &cfg.ranks {
        let mut fig = Figure::new(
            format!("Fig 3 — C++ benchmark, Edison, {ranks} MPI processes"),
            "run time [s]",
            false,
        );
        for platform in Platform::edison_cpp_set() {
            let mut breakdown_acc: Vec<(String, f64)> = Vec::new();
            let stats = repeat(cfg.reps, |rep| {
                let mut exec = Exec::Modeled { table: &table };
                let mut app = AppConfig::cpp(ranks, cfg.seed + rep as u64);
                app.batched = cfg.batched;
                let b = run_poisson_app(platform, &mut exec, &app).expect("fig3 run");
                if rep == 0 {
                    breakdown_acc = b
                        .phase_names()
                        .iter()
                        .map(|p| (p.clone(), b.get(p)))
                        .collect();
                }
                b.total()
            });
            fig.push(Row::new(platform.label(), stats).with_breakdown(breakdown_acc));
        }
        if ranks > 96 {
            fig.note("container-MPI bar is off-scale in the paper (truncated x-axis)");
        }
        legacy.push(fig);
    }

    let through_registry = coordinator(4).run(&cfg).unwrap();
    assert_eq!(render_all(&legacy), render_all(&through_registry));
}

#[test]
fn fig5b_matches_the_legacy_coordinator_loop() {
    // the pre-refactor Coordinator::fig5 (Edison half), inlined verbatim
    let table = CalibrationTable::builtin_fallback();
    let cfg = ExperimentConfig {
        reps: 2,
        sizes: vec![2, 1],
        ..ExperimentConfig::paper_default("fig5b").unwrap()
    };
    let platforms = vec![Platform::Native, Platform::ShifterSystemMpi];
    let mut legacy = Vec::new();
    for &size in &cfg.sizes {
        let ranks = cfg.ranks[0];
        let dofs_per_rank = harbor::fem::gmg::LADDER[size].pow(3);
        let mut fig = Figure::new(
            format!("Fig 5b — Edison, 192 cores: HPGMG-FE, {dofs_per_rank} DOF/rank"),
            "DOF/s",
            true,
        );
        for &platform in &platforms {
            let stats = repeat(cfg.reps, |rep| {
                let mut exec = Exec::Modeled { table: &table };
                let mut hc = HpgmgConfig::edison(size, cfg.seed + rep as u64);
                hc.ranks = ranks;
                hc.batched = cfg.batched;
                run_hpgmg(platform, &mut exec, &hc)
                    .expect("hpgmg run")
                    .dofs_per_second
            });
            fig.push(Row::new(platform.label(), stats));
        }
        legacy.push(fig);
    }

    let through_registry = coordinator(4).run(&cfg).unwrap();
    assert_eq!(render_all(&legacy), render_all(&through_registry));
}

#[test]
fn fig4_matches_the_legacy_coordinator_loop() {
    // the pre-refactor Coordinator::fig4, inlined verbatim
    let table = CalibrationTable::builtin_fallback();
    let cfg = ExperimentConfig {
        reps: 2,
        ranks: vec![24, 96],
        ..ExperimentConfig::paper_default("fig4").unwrap()
    };
    let mut legacy = Vec::new();
    for &ranks in &cfg.ranks {
        let mut fig = Figure::new(
            format!("Fig 4 — Python benchmark, Edison, {ranks} MPI processes"),
            "run time [s]",
            false,
        );
        for platform in Platform::edison_python_set() {
            let mut breakdown_acc: Vec<(String, f64)> = Vec::new();
            let stats = repeat(cfg.reps, |rep| {
                let mut exec = Exec::Modeled { table: &table };
                let mut app = AppConfig::python(ranks, cfg.seed + rep as u64);
                app.batched = cfg.batched;
                let b = run_poisson_app(platform, &mut exec, &app).expect("fig4 run");
                if rep == 0 {
                    breakdown_acc = b
                        .phase_names()
                        .iter()
                        .map(|p| (p.clone(), b.get(p)))
                        .collect();
                }
                b.total()
            });
            fig.push(Row::new(platform.label(), stats).with_breakdown(breakdown_acc));
        }
        fig.note("native total dominated by the Python import phase (MDS contention)");
        legacy.push(fig);
    }

    let through_registry = coordinator(4).run(&cfg).unwrap();
    assert_eq!(render_all(&legacy), render_all(&through_registry));
}

#[test]
fn fig5a_matches_the_legacy_coordinator_loop() {
    // the pre-refactor Coordinator::fig5 (workstation half), inlined
    // verbatim
    let table = CalibrationTable::builtin_fallback();
    let cfg = ExperimentConfig {
        reps: 2,
        sizes: vec![2, 1],
        ..ExperimentConfig::paper_default("fig5a").unwrap()
    };
    let platforms = vec![Platform::Docker, Platform::Rkt, Platform::Native];
    let mut legacy = Vec::new();
    for &size in &cfg.sizes {
        let ranks = cfg.ranks[0];
        let dofs_per_rank = harbor::fem::gmg::LADDER[size].pow(3);
        let mut fig = Figure::new(
            format!("Fig 5a — 16-core workstation: HPGMG-FE, {dofs_per_rank} DOF/rank"),
            "DOF/s",
            true,
        );
        for &platform in &platforms {
            let stats = repeat(cfg.reps, |rep| {
                let mut exec = Exec::Modeled { table: &table };
                let mut hc = HpgmgConfig::workstation(size, cfg.seed + rep as u64);
                hc.ranks = ranks;
                hc.batched = cfg.batched;
                run_hpgmg(platform, &mut exec, &hc)
                    .expect("hpgmg run")
                    .dofs_per_second
            });
            fig.push(Row::new(platform.label(), stats));
        }
        legacy.push(fig);
    }

    let through_registry = coordinator(4).run(&cfg).unwrap();
    assert_eq!(render_all(&legacy), render_all(&through_registry));
}

#[test]
fn fig1_scale_matches_the_legacy_coordinator_loop() {
    // the pre-refactor Coordinator::fig1_scale, inlined verbatim
    let cfg = ExperimentConfig {
        nodes: vec![4, 16],
        ..ExperimentConfig::paper_default("fig1-scale").unwrap()
    };
    let reference = "quay.io/fenicsproject/stable:2016.1.0r1";
    let mut cold_fig = Figure::new(
        "Fig 1 at fleet scale — cold pull makespan",
        "makespan [s]",
        false,
    );
    let mut warm_fig = Figure::new(
        "Fig 1 at fleet scale — warm re-deploy makespan",
        "makespan [s]",
        false,
    );
    let mut worst_ratio = 0.0f64;
    for &n in &cfg.nodes {
        let mut sharded = fleet_registry(reference).unwrap();
        let mut fleet = Fleet::new(FleetConfig::hpc(n));
        let cold = fleet.deploy(&mut sharded, reference).unwrap();
        let warm = fleet.deploy(&mut sharded, reference).unwrap();
        worst_ratio = worst_ratio.max(warm.makespan.as_secs_f64() / cold.makespan.as_secs_f64());
        cold_fig.push(
            Row::new(
                format!("{n} nodes"),
                Stats::from_samples(vec![cold.makespan.as_secs_f64()]),
            )
            .with_breakdown(vec![
                ("wan MB".into(), cold.wan_bytes as f64 / 1e6),
                ("intra MB".into(), cold.intra_bytes as f64 / 1e6),
            ]),
        );
        warm_fig.push(
            Row::new(
                format!("{n} nodes"),
                Stats::from_samples(vec![warm.makespan.as_secs_f64()]),
            )
            .with_breakdown(vec![("cache hit rate".into(), warm.cache.hit_rate())]),
        );
    }
    cold_fig.note(
        "each unique layer crosses the WAN once (4 shards), then peer fan-out \
         (arity 2) over the Aries fabric",
    );
    warm_fig.note(format!(
        "warm/cold makespan ratio {worst_ratio:.5} (acceptance bar: < 0.10)"
    ));
    let legacy = vec![cold_fig, warm_fig];

    let through_registry = coordinator(4).run(&cfg).unwrap();
    assert_eq!(render_all(&legacy), render_all(&through_registry));
}

#[test]
fn fig4_figures_keep_their_import_shape_through_the_registry() {
    let cfg = ExperimentConfig {
        reps: 2,
        ranks: vec![24, 96],
        ..ExperimentConfig::paper_default("fig4").unwrap()
    };
    let figs = coordinator(4).run(&cfg).unwrap();
    assert_eq!(figs.len(), 2);
    for fig in &figs {
        let native = fig.rows.iter().find(|r| r.label == "native").unwrap();
        let shifter = fig
            .rows
            .iter()
            .find(|r| r.label == "shifter (system MPI)")
            .unwrap();
        assert!(native.stats.mean() > 1.5 * shifter.stats.mean());
        assert!(!native.breakdown.is_empty(), "rep-0 breakdown attached");
        assert_eq!(native.stats.n(), 2);
    }
}

#[test]
fn mixed_fleet_runs_end_to_end_through_the_registry() {
    let cfg = ExperimentConfig {
        reps: 2,
        ranks: vec![48],
        ..ExperimentConfig::paper_default("mixed-fleet").unwrap()
    };
    let figs = coordinator(4).run(&cfg).unwrap();
    assert_eq!(figs.len(), 1, "one figure per rank count");
    let fig = &figs[0];
    assert_eq!(fig.rows.len(), 3, "solo + native + shifter rows");
    let solo = &fig.rows[0];
    let native = &fig.rows[1];
    let shifter = &fig.rows[2];
    assert!(
        native.stats.mean() > 1.5 * solo.stats.mean(),
        "native co-tenant must slow the checkpoint: {} vs {}",
        native.stats.mean(),
        solo.stats.mean()
    );
    // the containerised co-tenant's import never touches the shared
    // Lustre, so the checkpoint write is bit-identical to solo
    for (a, b) in solo.stats.samples.iter().zip(&shifter.stats.samples) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    assert!(fig.notes[0].contains("slows the checkpoint"));
    // breakdown carries the interference diagnostics
    assert!(native.breakdown.iter().any(|(k, _)| k == "python import [s]"));
}

#[test]
fn mixed_fleet_cells_use_the_stable_hash_not_rep_seeds() {
    // same config, different base seed: every cell reseeds (the hash
    // folds the base in), so the noisy native rows move while the
    // figure shape stays
    let mut cfg = ExperimentConfig {
        reps: 1,
        ranks: vec![24],
        ..ExperimentConfig::paper_default("mixed-fleet").unwrap()
    };
    let a = coordinator(1).run(&cfg).unwrap();
    cfg.seed = 43;
    let b = coordinator(1).run(&cfg).unwrap();
    let native_mean = |figs: &[Figure]| figs[0].rows[1].stats.mean();
    assert_ne!(native_mean(&a).to_bits(), native_mean(&b).to_bits());
}

#[test]
fn registry_errors_and_listing_stay_live() {
    let c = coordinator(1);
    let names = c.registry().names();
    assert!(names.contains(&"mixed-fleet"));
    assert_eq!(names.len(), c.registry().table().len());
    let bad = ExperimentConfig {
        figure: "figX".into(),
        ..ExperimentConfig::paper_default("fig2").unwrap()
    };
    let err = c.run(&bad).unwrap_err().to_string();
    for name in names {
        assert!(err.contains(name));
    }
}
