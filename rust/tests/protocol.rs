//! Integration: the registry front-door protocol tier's contracts.
//!
//! * resume-after-disconnect conserves bytes on *any* fault schedule:
//!   every session satisfies `wire == acked + resent`, a delivered
//!   session acknowledged exactly its `total_bytes` (never more — an
//!   acked range is never re-sent), and re-sent bytes appear only
//!   where chunks were actually lost;
//! * the session schedule is deterministic: the same seed reproduces
//!   the [`FrontDoorReport`] field for field, and the registry-storm
//!   matrix renders byte-identically across `--jobs 1` and `--jobs 4`;
//! * a zero-intensity schedule is bit-identical to the fault-free run
//!   and leaves the retry-jitter RNG stream untouched;
//! * the edge cache short-circuits repeat pulls without touching the
//!   WAN, and its hits are visible in the report.

use harbor::config::ExperimentConfig;
use harbor::container::image::FileEntry;
use harbor::container::{
    FrontDoor, Layer, Registry, RetryPolicy, SessionRequest, ShardedRegistry, TransferKind,
};
use harbor::coordinator::Coordinator;
use harbor::des::{Duration, FaultConfig, FaultSchedule, SimRng, VirtualTime};
use harbor::runtime::CalibrationTable;
use harbor::util::proptest::{run, Gen};

/// A content-addressed blob of `bytes` for the catalogue.
fn blob(tag: &str, bytes: u64) -> Layer {
    let files = vec![FileEntry {
        path: format!("/{tag}"),
        bytes,
    }];
    Layer::derive(None, tag, files)
}

/// A front door over `shards` frontends serving `layers`.
fn front(layers: &[Layer], shards: usize) -> FrontDoor {
    let mut registry = Registry::new();
    for l in layers {
        registry.layers.insert(l.clone());
    }
    FrontDoor::new(ShardedRegistry::new(registry, shards))
}

/// A randomized open-loop pull/push request stream over `layers`.
fn request_stream(g: &mut Gen, layers: &[Layer]) -> Vec<SessionRequest> {
    let mut requests = Vec::new();
    let mut at = VirtualTime::ZERO;
    for _ in 0..g.usize_in(4, 24) {
        at += Duration::from_secs_f64(g.f64_in(0.0, 2.0));
        let l = &layers[g.usize_in(0, layers.len() - 1)];
        if g.bool() {
            requests.push(SessionRequest::push(at, l.clone()));
        } else {
            requests.push(SessionRequest::pull(at, l.id.clone()));
        }
    }
    requests
}

#[test]
fn prop_resume_conserves_bytes_on_any_fault_schedule() {
    run("protocol-byte-conservation", 60, |g: &mut Gen| {
        let shards = g.usize_in(1, 4);
        let layers: Vec<Layer> = (0..g.usize_in(1, 6))
            .map(|i| blob(&format!("blob-{i}"), g.u64_in(1, 96_000_000)))
            .collect();
        let seed = g.u64_in(0, u64::MAX / 2);
        let cfg = FaultConfig::new(4, shards, Duration::from_secs_f64(40.0), 1.0);
        let schedule = FaultSchedule::generate(&cfg, &mut SimRng::new(seed, "fault-schedule"));
        let mut fd = front(&layers, shards)
            .with_chunk_bytes(g.u64_in(1_000_000, 32_000_000))
            .with_policy(RetryPolicy::hpc());
        fd.apply_faults(schedule);
        let requests = request_stream(g, &layers);
        let n = requests.len() as u64;
        let mut jitter = SimRng::new(seed, "retry-jitter");
        let (sessions, report) = fd.run(requests, Some(&mut jitter));

        for s in &sessions {
            if s.wire_bytes != s.acked_bytes + s.resent_bytes {
                return Err(format!(
                    "session {}: wire {} != acked {} + resent {}",
                    s.id, s.wire_bytes, s.acked_bytes, s.resent_bytes
                ));
            }
            if s.acked_bytes > s.total_bytes {
                return Err(format!("session {}: over-acknowledged", s.id));
            }
            if s.delivered && !s.cache_hit && s.acked_bytes != s.total_bytes {
                return Err(format!(
                    "session {}: delivered {} of {} bytes",
                    s.id, s.acked_bytes, s.total_bytes
                ));
            }
            if (s.resent_bytes > 0) != (s.drops > 0) {
                return Err(format!(
                    "session {}: resent bytes without drops (or vice versa)",
                    s.id
                ));
            }
            let a = s.availability();
            if !(0.0..=1.0).contains(&a) {
                return Err(format!("session {}: availability {a} out of range", s.id));
            }
            if s.delivered && a != 1.0 {
                return Err(format!("session {}: delivered but availability {a}", s.id));
            }
        }
        if report.availability.count() != n {
            return Err(format!(
                "availability histogram scored {} of {n} sessions",
                report.availability.count()
            ));
        }
        if report.wire_bytes != report.payload_bytes + report.resent_bytes {
            return Err(format!(
                "run: wire {} != payload {} + resent {}",
                report.wire_bytes, report.payload_bytes, report.resent_bytes
            ));
        }
        if report.delivered + report.failed != n || report.sessions != n {
            return Err("a session vanished from the report".into());
        }
        Ok(())
    });
}

#[test]
fn same_seed_reproduces_the_report_field_for_field() {
    let layers: Vec<Layer> = (0..4).map(|i| blob(&format!("b{i}"), 40_000_000 + i)).collect();
    let arm = || {
        let cfg = FaultConfig::new(4, 2, Duration::from_secs_f64(30.0), 0.8);
        let schedule = FaultSchedule::generate(&cfg, &mut SimRng::new(11, "fault-schedule"));
        let mut fd = front(&layers, 2)
            .with_chunk_bytes(8_000_000)
            .with_policy(RetryPolicy::hpc());
        fd.apply_faults(schedule);
        let mut g = SimRng::new(5, "arrivals");
        let mut at = VirtualTime::ZERO;
        let requests: Vec<SessionRequest> = (0..32)
            .map(|_| {
                at += Duration::from_secs_f64(g.uniform(0.0, 1.0));
                let l = &layers[g.index(layers.len())];
                if g.uniform(0.0, 1.0) < 0.2 {
                    SessionRequest::push(at, l.clone())
                } else {
                    SessionRequest::pull(at, l.id.clone())
                }
            })
            .collect();
        let mut jitter = SimRng::new(7, "retry-jitter");
        fd.run(requests, Some(&mut jitter))
    };
    let (sessions_a, report_a) = arm();
    let (sessions_b, report_b) = arm();
    assert_eq!(sessions_a, sessions_b, "session outcomes must be reproducible");
    assert_eq!(report_a, report_b, "reports must match field for field");
    assert_eq!(report_a.render(), report_b.render());
    // sessions are numbered in request order, and the ids are stable
    for (i, s) in sessions_a.iter().enumerate() {
        assert_eq!(s.id.0, i as u64);
        assert_eq!(format!("{}", s.id), format!("{}", sessions_b[i].id));
    }
}

#[test]
fn registry_storm_matrix_renders_identically_across_jobs() {
    let cfg = ExperimentConfig {
        nodes: vec![2],
        ..ExperimentConfig::paper_default("registry-storm").unwrap()
    };
    let run = |jobs| {
        Coordinator::with_table(CalibrationTable::builtin_fallback())
            .with_jobs(jobs)
            .run(&cfg)
            .unwrap()
            .iter()
            .map(|f| f.render())
            .collect::<Vec<_>>()
            .join("\n")
    };
    let serial = run(1);
    assert_eq!(serial, run(4), "--jobs must not change a single byte");
    assert!(serial.contains("p99"), "the latency figure reports percentiles");
}

#[test]
fn zero_intensity_run_is_bit_identical_to_fault_free_with_rng_untouched() {
    let layers: Vec<Layer> = (0..3).map(|i| blob(&format!("z{i}"), 64_000_000)).collect();
    let requests = |layers: &[Layer]| -> Vec<SessionRequest> {
        let mut out = Vec::new();
        for (i, l) in layers.iter().enumerate() {
            let at = VirtualTime::ZERO + Duration::from_secs_f64(i as f64 * 0.5);
            out.push(SessionRequest::pull(at, l.id.clone()));
            out.push(SessionRequest::push(at + Duration::from_millis(100), l.clone()));
        }
        out
    };

    // arm A: zero-intensity schedule, full retry policy, jitter armed
    let cfg = FaultConfig::new(4, 2, Duration::from_secs_f64(60.0), 0.0);
    let zero = FaultSchedule::generate(&cfg, &mut SimRng::new(3, "fault-schedule"));
    assert!(zero.is_empty(), "zero intensity must inject nothing");
    let mut fd_a = front(&layers, 2).with_policy(RetryPolicy::hpc());
    fd_a.apply_faults(zero);
    let mut rng_a = SimRng::new(99, "retry-jitter");
    let (sessions_a, report_a) = fd_a.run(requests(&layers), Some(&mut rng_a));

    // arm B: no schedule at all, no-retry policy, no rng — a fault-free
    // run may depend on none of them
    let mut fd_b = front(&layers, 2).with_policy(RetryPolicy::none());
    let (sessions_b, report_b) = fd_b.run(requests(&layers), None);

    assert_eq!(sessions_a, sessions_b, "zero-intensity sessions must be bit-identical");
    assert_eq!(report_a, report_b, "zero-intensity reports must be bit-identical");
    assert_eq!(report_a.render(), report_b.render());
    assert_eq!(report_a.failed, 0);
    assert_eq!(report_a.resent_bytes, 0, "nothing is re-sent without faults");

    // the jitter stream still sits at its seed position
    let mut fresh = SimRng::new(99, "retry-jitter");
    assert_eq!(
        rng_a.uniform(0.0, 1.0).to_bits(),
        fresh.uniform(0.0, 1.0).to_bits(),
        "a fault-free run must not consult the rng"
    );
}

#[test]
fn availability_percentiles_separate_calm_from_chaotic_runs() {
    let layers: Vec<Layer> = (0..4).map(|i| blob(&format!("a{i}"), 56_000_000)).collect();
    let pulls = |n: usize| -> Vec<SessionRequest> {
        (0..n)
            .map(|i| {
                let at = VirtualTime::ZERO + Duration::from_secs_f64(i as f64 * 0.7);
                SessionRequest::pull(at, layers[i % layers.len()].id.clone())
            })
            .collect()
    };

    // fault-free: every session delivers every byte, so every
    // percentile — including the worst — reads exactly 1.0 (the
    // estimator clamps to the exact observed extremes)
    let mut calm = front(&layers, 2);
    let (_, calm_report) = calm.run(pulls(16), None);
    assert_eq!(calm_report.availability.count(), calm_report.sessions);
    assert_eq!(calm_report.availability.quantile(0.01).as_secs_f64(), 1.0);
    assert_eq!(calm_report.availability.quantile(0.50).as_secs_f64(), 1.0);
    assert_eq!(calm_report.availability.min().as_secs_f64(), 1.0);

    // chaotic arm with a starved retry budget: some sessions abandon
    // mid-transfer, and the histogram's floor drops below 1.0 by
    // exactly the worst per-session fraction
    let chaotic_arm = || {
        let cfg = FaultConfig::new(6, 2, Duration::from_secs_f64(45.0), 1.0);
        let schedule = FaultSchedule::generate(&cfg, &mut SimRng::new(21, "fault-schedule"));
        let mut fd = front(&layers, 2)
            .with_chunk_bytes(4_000_000)
            .with_policy(RetryPolicy::none());
        fd.apply_faults(schedule);
        fd.run(pulls(32), None)
    };
    let (sessions, report) = chaotic_arm();
    assert_eq!(report.availability.count(), report.sessions);
    if report.failed > 0 {
        let worst = sessions
            .iter()
            .map(|s| s.availability())
            .fold(f64::INFINITY, f64::min);
        assert!(worst < 1.0, "a failed session kept full availability");
        let floor = report.availability.min().as_secs_f64();
        assert!(
            (floor - worst).abs() < 1e-6,
            "histogram floor {floor} != worst session {worst}"
        );
        assert!(report.availability.quantile(0.01) <= report.availability.quantile(0.50));
    }
    // the new field participates in report equality/determinism
    let (_, report_b) = chaotic_arm();
    assert_eq!(report, report_b);
}

#[test]
fn edge_cache_keeps_repeat_pulls_off_the_wan() {
    let l = blob("hot", 48_000_000);
    let mut fd = front(std::slice::from_ref(&l), 2).with_edge_cache(u64::MAX);
    let pulls: Vec<SessionRequest> = (0..5)
        .map(|i| {
            let at = VirtualTime::ZERO + Duration::from_secs_f64(i as f64 * 10.0);
            SessionRequest::pull(at, l.id.clone())
        })
        .collect();
    let (sessions, report) = fd.run(pulls, None);
    assert!(sessions[0].delivered && !sessions[0].cache_hit);
    assert!(sessions[1..].iter().all(|s| s.delivered && s.cache_hit));
    assert_eq!(report.cache_hits, 4);
    assert_eq!(report.hit_bytes, 4 * l.bytes);
    assert_eq!(report.wire_bytes, l.bytes, "the blob crossed the WAN exactly once");
    // the cache hits are orders of magnitude faster than the WAN pull
    assert!(sessions[1].latency() < sessions[0].latency());
}
