//! Oracle-and-golden tests for the package-resolver tier.
//!
//! Three layers of defence around `container::resolve`:
//!
//! 1. **Property suite vs brute-force oracles** — semver ordering is
//!    checked against plain tuple comparison, range intersection
//!    against membership over an enumerated version universe, and the
//!    caret/tilde sugar against its textbook definition.  The oracles
//!    are deliberately naive: they re-derive the answer a slow way the
//!    implementation never uses.
//! 2. **Determinism** — the same manifest and index must produce
//!    byte-identical lockfiles under eight different resolver seeds,
//!    and the resolver-driven scenarios must render byte-identically
//!    under `--jobs 1` and `--jobs 4` (CI diffs the same invariant on
//!    the real binary).
//! 3. **Goldens** — the §2.2 FEniCS stack's manifest, lockfile, and
//!    emitted sandybridge buildfile are committed under
//!    `tests/golden/` and diffed byte-for-byte; every emitted
//!    buildfile must round-trip losslessly through
//!    `Buildfile::canonical`.

use harbor::container::resolve::{
    emit_stack_buildfile, fenics_index, fenics_manifest, resolve, Lockfile, Manifest, Range,
    ResolveError, Version, STACK_BASE,
};
use harbor::container::Buildfile;
use harbor::config::ExperimentConfig;
use harbor::coordinator::Coordinator;
use harbor::runtime::CalibrationTable;
use harbor::scenario::build_farm::ARCHES;
use harbor::util::proptest::{run, Gen};

const GOLDEN_MANIFEST: &str = include_str!("golden/fenics.manifest");
const GOLDEN_LOCK: &str = include_str!("golden/fenics.lock");
const GOLDEN_BUILDFILE: &str = include_str!("golden/fenics-sandybridge.buildfile");

/// Every version with components in `0..=2` — small enough to
/// enumerate, rich enough that caret/tilde/intersection edge cases
/// (zero majors, equal bounds) all occur.
fn universe() -> Vec<Version> {
    let mut all = Vec::with_capacity(27);
    for major in 0..3 {
        for minor in 0..3 {
            for patch in 0..3 {
                all.push(Version::new(major, minor, patch));
            }
        }
    }
    all
}

fn gen_version(g: &mut Gen) -> Version {
    Version::new(g.u64_in(0, 2), g.u64_in(0, 2), g.u64_in(0, 2))
}

fn gen_range(g: &mut Gen) -> Range {
    match g.usize_in(0, 4) {
        0 => Range::any(),
        1 => Range::exact(gen_version(g)),
        2 => Range::caret(gen_version(g)),
        3 => Range::tilde(gen_version(g)),
        // raw interval, possibly empty (hi may sit at or below lo)
        _ => Range {
            lo: gen_version(g),
            hi: Some(gen_version(g)),
        },
    }
}

#[test]
fn semver_order_matches_the_tuple_oracle_and_round_trips() {
    run("semver-order-round-trip", 500, |g| {
        let a = gen_version(g);
        let b = gen_version(g);
        let oracle = (a.major, a.minor, a.patch).cmp(&(b.major, b.minor, b.patch));
        if a.cmp(&b) != oracle {
            return Err(format!("{a} vs {b}: order disagrees with the tuple oracle"));
        }
        let back: Version = a
            .to_string()
            .parse()
            .map_err(|e| format!("reparse {a}: {e}"))?;
        if back != a {
            return Err(format!("{a} printed and reparsed as {back}"));
        }
        Ok(())
    });
}

#[test]
fn range_display_reparses_to_the_same_interval() {
    run("range-display-round-trip", 500, |g| {
        let r = gen_range(g);
        let back = Range::parse(&r.to_string()).map_err(|e| format!("reparse `{r}`: {e}"))?;
        if back != r {
            return Err(format!("`{r}` reparsed as `{back}`"));
        }
        Ok(())
    });
}

#[test]
fn range_intersection_matches_the_membership_oracle() {
    let all = universe();
    run("range-intersection-oracle", 500, |g| {
        let a = gen_range(g);
        let b = gen_range(g);
        let both = a.intersect(&b);
        for &v in &all {
            let oracle = a.contains(v) && b.contains(v);
            if both.contains(v) != oracle {
                return Err(format!(
                    "({a}) ∩ ({b}) = ({both}) wrong at {v}: oracle {oracle}"
                ));
            }
        }
        if both.is_empty() && all.iter().any(|&v| both.contains(v)) {
            return Err(format!("({both}) claims empty but has members"));
        }
        Ok(())
    });
}

#[test]
fn caret_tilde_and_exact_match_their_definitions() {
    let all = universe();
    run("range-sugar-oracle", 300, |g| {
        let v = gen_version(g);
        for &u in &all {
            // ~v: same major.minor, at least v
            let tilde_oracle = u.major == v.major && u.minor == v.minor && u >= v;
            if Range::tilde(v).contains(u) != tilde_oracle {
                return Err(format!("~{v} wrong at {u}"));
            }
            // ^v: compatible with v — nothing left of the leftmost
            // nonzero component may move
            let caret_oracle = if v.major > 0 {
                u.major == v.major && u >= v
            } else if v.minor > 0 {
                u.major == 0 && u.minor == v.minor && u >= v
            } else {
                u == v
            };
            if Range::caret(v).contains(u) != caret_oracle {
                return Err(format!("^{v} wrong at {u}"));
            }
            if Range::exact(v).contains(u) != (u == v) {
                return Err(format!("={v} wrong at {u}"));
            }
        }
        // the sugar spellings parse to the constructors
        for (text, want) in [
            (format!("^{v}"), Range::caret(v)),
            (format!("~{v}"), Range::tilde(v)),
            (format!("={v}"), Range::exact(v)),
            (format!("{v}"), Range::exact(v)),
        ] {
            let got = Range::parse(&text).map_err(|e| format!("`{text}`: {e}"))?;
            if got != want {
                return Err(format!("`{text}` parsed as `{got}`, want `{want}`"));
            }
        }
        Ok(())
    });
}

#[test]
fn resolution_is_byte_identical_across_eight_seeds() {
    let index = fenics_index();
    let manifest = fenics_manifest();
    let reference =
        Lockfile::from_resolution(&resolve(&manifest, &index, 0).unwrap(), &index).canonical();
    for seed in [1, 2, 3, 7, 42, 1234, 0xdead_beef, u64::MAX] {
        let lock = Lockfile::from_resolution(&resolve(&manifest, &index, seed).unwrap(), &index);
        assert_eq!(
            lock.canonical(),
            reference,
            "seed {seed} changed the lockfile bytes"
        );
    }
}

#[test]
fn resolver_conflicts_carry_their_constraint_context() {
    let index = fenics_index();
    // openmpi pinned to 2.x at the root collides with the PETSc
    // chain's ^1.10.0 pulled in through dolfin
    let manifest = Manifest::new("clash", Version::new(1, 0, 0))
        .with_dep("dolfin", "~2016.1.0")
        .unwrap()
        .with_dep("openmpi", "^2.0.0")
        .unwrap();
    match resolve(&manifest, &index, 0) {
        Err(ResolveError::Conflict { name, constraints }) => {
            assert_eq!(name, "openmpi");
            assert!(
                constraints.len() >= 2,
                "both sides of the conflict must be reported: {constraints:?}"
            );
            let text = ResolveError::Conflict { name, constraints }.to_string();
            assert!(text.contains("openmpi"), "{text}");
        }
        other => panic!("expected a conflict, got {other:?}"),
    }
}

#[test]
fn golden_manifest_parses_to_the_paper_stack() {
    let parsed = Manifest::parse(GOLDEN_MANIFEST).expect("golden manifest parses");
    assert_eq!(parsed, fenics_manifest(), "golden manifest is the §2.2 stack");
    // canonicalisation is a fixed point (ranges desugar to intervals)
    let canonical = parsed.canonical();
    let reparsed = Manifest::parse(&canonical).unwrap();
    assert_eq!(reparsed.canonical(), canonical);
}

#[test]
fn golden_lockfile_bytes_match_resolution() {
    let index = fenics_index();
    let manifest = Manifest::parse(GOLDEN_MANIFEST).unwrap();
    let lock = Lockfile::from_resolution(&resolve(&manifest, &index, 42).unwrap(), &index);
    assert_eq!(
        lock.canonical(),
        GOLDEN_LOCK,
        "resolved lockfile drifted from tests/golden/fenics.lock"
    );
    // the committed bytes themselves are canonical
    let parsed = Lockfile::parse(GOLDEN_LOCK).expect("golden lockfile parses");
    assert_eq!(parsed.canonical(), GOLDEN_LOCK);
}

#[test]
fn golden_buildfile_bytes_match_emission() {
    let index = fenics_index();
    let manifest = Manifest::parse(GOLDEN_MANIFEST).unwrap();
    let lock = Lockfile::parse(GOLDEN_LOCK).unwrap();
    let emitted =
        emit_stack_buildfile(&manifest, &lock, STACK_BASE, Some("sandybridge")).unwrap();
    assert_eq!(
        emitted, GOLDEN_BUILDFILE,
        "emitted buildfile drifted from tests/golden/fenics-sandybridge.buildfile"
    );
    // and the same lockfile reached through resolution emits the same
    let lock2 = Lockfile::from_resolution(&resolve(&manifest, &index, 7).unwrap(), &index);
    let emitted2 =
        emit_stack_buildfile(&manifest, &lock2, STACK_BASE, Some("sandybridge")).unwrap();
    assert_eq!(emitted2, GOLDEN_BUILDFILE);
}

#[test]
fn every_emitted_buildfile_round_trips_through_canonical() {
    let index = fenics_index();
    let manifest = fenics_manifest();
    let lock = Lockfile::from_resolution(&resolve(&manifest, &index, 0).unwrap(), &index);
    let variants: Vec<Option<&str>> =
        std::iter::once(None).chain(ARCHES.iter().map(|&a| Some(a))).collect();
    for arch in variants {
        let emitted = emit_stack_buildfile(&manifest, &lock, STACK_BASE, arch).unwrap();
        let bf = Buildfile::parse(&emitted)
            .unwrap_or_else(|e| panic!("emitted buildfile ({arch:?}) must parse: {e}"));
        assert_eq!(
            bf.canonical(),
            emitted,
            "emission ({arch:?}) is not canonical-lossless"
        );
        // one stage per pinned package plus the terminal stage
        assert_eq!(bf.stage_count(), lock.packages.len() + 1);
    }
}

fn coordinator(jobs: usize) -> Coordinator {
    Coordinator::with_table(CalibrationTable::builtin_fallback()).with_jobs(jobs)
}

#[test]
fn resolver_scenarios_render_identically_across_jobs() {
    for (name, nodes) in [("version-churn", vec![]), ("dep-storm", vec![8, 24])] {
        let mut cfg = ExperimentConfig::paper_default(name).unwrap();
        if !nodes.is_empty() {
            cfg.nodes = nodes;
        }
        let serial = coordinator(1).run(&cfg).expect(name);
        let parallel = coordinator(4).run(&cfg).expect(name);
        let render = |figs: &[harbor::bench::Figure]| {
            figs.iter().map(|f| f.render()).collect::<Vec<_>>().join("\n")
        };
        assert_eq!(
            render(&serial),
            render(&parallel),
            "`{name}` must render byte-identically under --jobs 4"
        );
    }
}
