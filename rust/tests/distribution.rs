//! Property tests for the fleet distribution tier (sharded registry +
//! node-local caches + DES-scheduled concurrent pulls).
//!
//! The load-bearing invariants:
//!
//! * **Byte conservation under peer fan-out** — a cold fleet pull moves
//!   each unique layer across the WAN exactly once (through its owning
//!   shard) and fans it out intra-cluster to the remaining `N - 1`
//!   nodes, so `total = unique_bytes + unique_bytes × (N - 1)`.
//! * **Warm re-deploys are free** — with every layer cached on every
//!   node, a re-deploy transfers zero registry bytes and zero
//!   intra-cluster bytes.
//! * **Direct mode pays per node** — the no-dedup baseline moves
//!   `unique_bytes × N` over the WAN and nothing intra-cluster.
//! * **Sharding changes timing, not accounting** — a DES-scheduled
//!   sharded pull reports the same layers/bytes as the flat model.
//! * **Bounded caches respect capacity** — after any deploy, every node
//!   cache fits its capacity unless a single oversized layer is the
//!   sole resident.

use harbor::container::{
    Builder, Buildfile, FanOut, Fleet, FleetConfig, LayerStore, Registry, ShardedRegistry,
};
use harbor::des::VirtualTime;
use harbor::util::proptest::{run, Gen};

/// Build a randomized image (random base, 1–4 RUN layers, a mix of
/// package installs and zero-byte shell layers) and publish it.
/// Returns the loaded registry plus the image's byte and layer counts.
fn random_registry(g: &mut Gen, tag: &str) -> (Registry, u64, usize) {
    let bases = ["ubuntu:16.04", "alpine:3.4", "phusion/baseimage:0.9.19"];
    let mut text = format!("FROM {}\n", g.choose(&bases));
    for _ in 0..g.usize_in(1, 4) {
        if g.bool() {
            text.push_str(&format!("RUN apt-get -y install {}\n", g.ident(8)));
        } else {
            text.push_str(&format!("RUN echo {}\n", g.ident(8)));
        }
    }
    let mut store = LayerStore::new();
    let image = Builder::new()
        .build(&Buildfile::parse(&text).unwrap(), tag, &mut store)
        .unwrap()
        .image;
    let bytes = image.size_bytes(&store);
    let layers = image.layers.len();
    let mut reg = Registry::new();
    reg.push(&image, &store).unwrap();
    (reg, bytes, layers)
}

#[test]
fn prop_peer_fleet_bytes_conserved_and_warm_is_free() {
    run("peer-bytes-conservation", 60, |g: &mut Gen| {
        let (reg, bytes, layers) = random_registry(g, "p:1");
        let n = g.usize_in(1, 48);
        let shards = g.usize_in(1, 8);
        let arity = g.usize_in(1, 4);
        let mut sharded = ShardedRegistry::new(reg, shards);
        let mut cfg = FleetConfig::hpc(n);
        cfg.fan_out = FanOut::Peer { arity };
        let mut fleet = Fleet::new(cfg);

        let cold = fleet.deploy(&mut sharded, "p:1").map_err(|e| e.to_string())?;
        if cold.wan_transfers != layers {
            return Err(format!(
                "each unique layer must cross the WAN once: {} != {layers}",
                cold.wan_transfers
            ));
        }
        if cold.wan_bytes != bytes {
            return Err(format!("WAN bytes {} != image bytes {bytes}", cold.wan_bytes));
        }
        let expect_intra = bytes * (n as u64 - 1);
        if cold.intra_bytes != expect_intra {
            return Err(format!(
                "intra-cluster fan-out bytes {} != {expect_intra} (n={n})",
                cold.intra_bytes
            ));
        }
        if cold.total_bytes() != bytes * n as u64 {
            return Err("total moved bytes must equal image bytes × nodes".into());
        }
        if cold.cache.misses != (n * layers) as u64 || cold.cache.hits != 0 {
            return Err(format!(
                "cold wave accounting: {} misses, {} hits",
                cold.cache.misses, cold.cache.hits
            ));
        }

        let warm = fleet.deploy(&mut sharded, "p:1").map_err(|e| e.to_string())?;
        if warm.wan_bytes != 0 || warm.intra_bytes != 0 || warm.wan_transfers != 0 {
            return Err(format!(
                "warm re-deploy must transfer zero registry bytes: wan {} intra {}",
                warm.wan_bytes, warm.intra_bytes
            ));
        }
        if warm.cache.hits != (n * layers) as u64 || warm.cache.misses != 0 {
            return Err("warm wave must be all cache hits".into());
        }
        if warm.makespan >= cold.makespan {
            return Err(format!(
                "warm makespan {} must be under cold {}",
                warm.makespan, cold.makespan
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_direct_fleet_pays_wan_per_node() {
    run("direct-bytes", 40, |g: &mut Gen| {
        let (reg, bytes, layers) = random_registry(g, "d:1");
        let n = g.usize_in(1, 24);
        let shards = g.usize_in(1, 8);
        let mut sharded = ShardedRegistry::new(reg, shards);
        let mut cfg = FleetConfig::hpc(n);
        cfg.fan_out = FanOut::Direct;
        let mut fleet = Fleet::new(cfg);
        let cold = fleet.deploy(&mut sharded, "d:1").map_err(|e| e.to_string())?;
        if cold.wan_bytes != bytes * n as u64 {
            return Err(format!(
                "direct mode moves the image once per node: {} != {}",
                cold.wan_bytes,
                bytes * n as u64
            ));
        }
        if cold.wan_transfers != layers * n || cold.intra_bytes != 0 {
            return Err("direct mode has no intra-cluster traffic".into());
        }
        // and a second deploy is still free: the caches don't care how
        // the bytes arrived
        let warm = fleet.deploy(&mut sharded, "d:1").map_err(|e| e.to_string())?;
        if warm.total_bytes() != 0 {
            return Err("warm re-deploy after direct pull must be free".into());
        }
        Ok(())
    });
}

#[test]
fn prop_sharded_pull_keeps_flat_accounting() {
    run("sharded-pull-accounting", 60, |g: &mut Gen| {
        let (reg, bytes, layers) = random_registry(g, "s:1");
        // flat model first
        let (_, flat) = reg.pull("s:1", &mut LayerStore::new()).map_err(|e| e.to_string())?;
        // same catalogue behind shard frontends
        let mut sharded = ShardedRegistry::new(reg, g.usize_in(1, 8));
        let mut dest = LayerStore::new();
        let (_, des) = sharded
            .pull_at(VirtualTime::ZERO, "s:1", &mut dest)
            .map_err(|e| e.to_string())?;
        if des.bytes_transferred != flat.bytes_transferred || des.bytes_transferred != bytes {
            return Err(format!(
                "sharded pull moved {} bytes, flat moved {} (image {bytes})",
                des.bytes_transferred, flat.bytes_transferred
            ));
        }
        if des.layers_transferred != flat.layers_transferred || des.layers_transferred != layers {
            return Err("sharded pull must transfer the same layer set".into());
        }
        if dest.len() != layers {
            return Err("destination store must hold the full image".into());
        }
        Ok(())
    });
}

#[test]
fn prop_bounded_caches_respect_capacity() {
    run("cache-capacity", 40, |g: &mut Gen| {
        let (reg, bytes, _) = random_registry(g, "c:1");
        let n = g.usize_in(1, 16);
        // capacity strictly under the image size, so something must evict
        let capacity = g.u64_in(1, bytes.max(2) - 1);
        let mut sharded = ShardedRegistry::new(reg, 4);
        let mut cfg = FleetConfig::hpc(n);
        cfg.cache_capacity_bytes = capacity;
        let mut fleet = Fleet::new(cfg);
        fleet.deploy(&mut sharded, "c:1").map_err(|e| e.to_string())?;
        for (node, cache) in fleet.caches().iter().enumerate() {
            if cache.used_bytes() > capacity && cache.len() > 1 {
                return Err(format!(
                    "node {node} cache holds {} bytes > capacity {capacity} with {} layers",
                    cache.used_bytes(),
                    cache.len()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_shard_assignment_is_stable_and_total() {
    run("shard-stability", 40, |g: &mut Gen| {
        let (reg, _, _) = random_registry(g, "h:1");
        let shards = g.usize_in(1, 8);
        let sharded = ShardedRegistry::new(reg, shards);
        let ids: Vec<_> = sharded.registry().layers.ids().cloned().collect();
        for id in &ids {
            let s = sharded.shard_of(id);
            if s >= shards {
                return Err(format!("layer mapped to shard {s} of {shards}"));
            }
            if s != sharded.shard_of(id) {
                return Err("shard assignment must be deterministic".into());
            }
        }
        Ok(())
    });
}
