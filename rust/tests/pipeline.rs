//! Integration: the deployment pipeline + container lifecycle across the
//! module boundaries (builder → store → registry → runtimes → cluster).

use harbor::cluster::{launch, MachineSpec};
use harbor::container::runtime::{by_kind, FsPolicy};
use harbor::container::{Builder, Buildfile, Container, LayerStore, Registry, RuntimeKind};
use harbor::coordinator::{deploy_pipeline, FENICS_BUILDFILE};
use harbor::des::{Duration, VirtualTime};
use harbor::fs::{FileSystem, FsOp, ImageFs, ParallelFs};
use harbor::pyimport::{replay, ModuleGraph};

#[test]
fn full_pipeline_build_push_pull_run() {
    let trace = deploy_pipeline().unwrap();
    assert!(trace.layers_built >= 5);
    assert_eq!(trace.targets.len(), 2);

    // now rebuild the same thing independently and check the pulled
    // image would be byte-identical (content addressing end to end)
    let bf = Buildfile::parse(FENICS_BUILDFILE).unwrap();
    let mut store = LayerStore::new();
    let report = Builder::new()
        .build(&bf, "quay.io/fenicsproject/stable:2016.1.0r1", &mut store)
        .unwrap();
    assert_eq!(report.image.id.0, trace.image_id);
}

#[test]
fn incremental_image_update_transfers_only_new_layers() {
    let mut builder = Builder::new();
    let mut ci = LayerStore::new();
    let v1 = builder
        .build(
            &Buildfile::parse(FENICS_BUILDFILE).unwrap(),
            "stable:1",
            &mut ci,
        )
        .unwrap();
    let changed = format!("{FENICS_BUILDFILE}RUN pip install matplotlib\n");
    let v2 = builder
        .build(&Buildfile::parse(&changed).unwrap(), "stable:2", &mut ci)
        .unwrap();
    assert_eq!(v2.layers_built, 1, "only the new directive builds");

    let mut registry = Registry::new();
    registry.push(&v1.image, &ci).unwrap();
    registry.push(&v2.image, &ci).unwrap();
    let mut user = LayerStore::new();
    let (_, first) = registry.pull("stable:1", &mut user).unwrap();
    let (_, update) = registry.pull("stable:2", &mut user).unwrap();
    assert!(update.bytes_transferred < first.bytes_transferred / 5);
    assert_eq!(update.layers_reused, v1.image.layers.len());
}

#[test]
fn container_lifecycle_through_runtime_overheads() {
    let bf = Buildfile::parse("FROM ubuntu:16.04\nENTRYPOINT ./demo_poisson").unwrap();
    let mut store = LayerStore::new();
    let image = Builder::new().build(&bf, "demo:1", &mut store).unwrap().image;

    for kind in [RuntimeKind::Docker, RuntimeKind::Rkt, RuntimeKind::Shifter, RuntimeKind::Vm] {
        let rt = by_kind(kind);
        let start = rt.startup_overhead(&image);
        let mut c = Container::create(1, image.id.clone(), VirtualTime::ZERO);
        c.start(VirtualTime::ZERO + start).unwrap();
        c.exec("./demo_poisson").unwrap();
        c.write_scratch(1024);
        c.exit(0, VirtualTime::ZERO + start + Duration::from_millis(100))
            .unwrap();
        assert_eq!(c.runtime().unwrap(), Duration::from_millis(100));
    }
}

#[test]
fn shifter_fs_policy_wires_into_import_replay() {
    // the pieces figure 4 is made of, glued manually across modules
    let machine = MachineSpec::edison();
    let alloc = launch(&machine, 48).unwrap();
    let graph = ModuleGraph::fenics_stack();

    let rt = by_kind(RuntimeKind::Shifter);
    assert_eq!(rt.fs_policy(), FsPolicy::ImageMount);
    let mut shifter_fs = ImageFs::new(1_200_000_000, ParallelFs::edison(1));
    let shifter = replay(&graph, &alloc, &mut shifter_fs, VirtualTime::ZERO).wall;

    let native_rt = by_kind(RuntimeKind::Native);
    assert_eq!(native_rt.fs_policy(), FsPolicy::Host);
    let mut lustre = ParallelFs::edison(2);
    let native = replay(&graph, &alloc, &mut lustre, VirtualTime::ZERO).wall;

    assert!(native.as_secs_f64() > 3.0 * shifter.as_secs_f64());
}

#[test]
fn image_writes_are_read_only_and_go_to_scratch() {
    // Shifter images are read-only: writes route to the backing store
    let mut fs = ImageFs::new(500_000_000, ParallelFs::edison(3));
    let read_done = fs.submit(VirtualTime::ZERO, 0, FsOp::Read { bytes: 1 << 20 });
    let write_done = fs.submit(read_done, 0, FsOp::Write { bytes: 1 << 20 });
    // the write pays parallel-FS cost, not page-cache cost
    assert!((write_done - read_done) > Duration::from_micros(50));
}

#[test]
fn thousand_rank_import_anecdote() {
    // §4.2: ">30 minutes to import ... with 1000 processes" on some
    // systems. Our Lustre model at 960 ranks lands in the same order
    // of magnitude — and the container does it in seconds.
    let machine = MachineSpec::edison();
    let alloc = launch(&machine, 960).unwrap();
    let graph = ModuleGraph::fenics_stack();

    let mut lustre = ParallelFs::edison(4);
    let native = replay(&graph, &alloc, &mut lustre, VirtualTime::ZERO).wall;
    assert!(
        native.as_secs_f64() > 300.0,
        "native import at 960 ranks should take minutes, got {native}"
    );

    let mut image = ImageFs::new(1_200_000_000, ParallelFs::edison(5));
    let contained = replay(&graph, &alloc, &mut image, VirtualTime::ZERO).wall;
    assert!(
        contained.as_secs_f64() < 30.0,
        "containerised import should take seconds, got {contained}"
    );
}
