//! Integration suite for the multi-stage build DAG and the
//! `build-farm` scenario:
//!
//! * multi-stage parse / canonical round-trips (including the real
//!   variant matrix the farm builds);
//! * diamond stage graphs: planning, wave schedule, and build output;
//! * `COPY --from` cache invalidation when the source stage changes;
//! * non-terminal stage pruning and the store GC that collects it;
//! * `build-farm` renders byte-identically under `--jobs N` and is
//!   listed by the scenario registry (what `harbor bench --list`
//!   prints);
//! * resolver-driven invalidation: a single-version bump in the
//!   package index rebuilds exactly the lockfile-predicted frontier
//!   across the full arch variant matrix.

use harbor::bench::Figure;
use harbor::config::ExperimentConfig;
use harbor::container::resolve::{
    emit_stack_buildfile, fenics_index, fenics_manifest, rebuilt_packages, resolve,
    terminal_rebuilt, Lockfile, STACK_BASE,
};
use harbor::container::{BuildGraph, Builder, Buildfile, LayerStore};
use harbor::coordinator::Coordinator;
use harbor::runtime::CalibrationTable;
use harbor::scenario::ScenarioRegistry;
use harbor::scenario::build_farm::{
    APPS, ARCHES, BuildFarm, FarmConfig, variant_buildfile, variant_matrix,
};
use harbor::scenario::version_churn::BUMP_TARGETS;

fn render_all(figs: &[Figure]) -> String {
    figs.iter().map(|f| f.render()).collect::<Vec<_>>().join("\n")
}

#[test]
fn variant_matrix_parses_and_round_trips() {
    let jobs = variant_matrix().unwrap();
    assert_eq!(jobs.len(), APPS.len() * ARCHES.len());
    for (tag, bf) in &jobs {
        assert!(tag.starts_with("local/"));
        assert_eq!(bf.stage_count(), 4, "{tag} is a 4-stage file");
        // canonical() is lossless: reparsing the canonical lines
        // reproduces the parsed directives exactly
        let canon: Vec<String> = bf.directives.iter().map(|d| d.canonical()).collect();
        let back = Buildfile::parse(&canon.join("\n")).unwrap();
        assert_eq!(&back, bf, "{tag} round-trips through canonical()");
    }
}

#[test]
fn variant_stages_form_a_chain_into_a_pruned_runtime_image() {
    let (app, pkgs) = APPS[0];
    let bf = Buildfile::parse(&variant_buildfile(app, pkgs, "haswell")).unwrap();
    let g = BuildGraph::plan(&bf);
    // toolchain <- deps <- build, and the final stage reads build+deps
    assert_eq!(g.deps(0), &[] as &[usize]);
    assert_eq!(g.deps(1), &[0]);
    assert_eq!(g.deps(2), &[1]);
    assert_eq!(g.deps(3), &[1, 2]);
    assert_eq!(g.schedule(), vec![vec![0], vec![1], vec![2], vec![3]]);
    let mut store = LayerStore::new();
    let r = Builder::new().build(&bf, "v:1", &mut store).unwrap();
    assert_eq!(r.stages_built, 4);
    // runtime image: ubuntu base + 2 COPYs + ARCH_OPT; builder layers pruned
    assert_eq!(r.image.layers.len(), 4);
    assert!(r.image.arch_optimized, "final stage carries ARCH_OPT");
    assert!(store.len() > r.image.layers.len(), "pruned layers stay in the store");
    let in_image = |id: &harbor::container::LayerId| r.image.layers.contains(id);
    let pruned = store.ids().filter(|id| !in_image(id)).count();
    assert_eq!(pruned, store.len() - r.image.layers.len());
}

#[test]
fn diamond_graph_schedules_by_wave_and_prunes() {
    let text = "\
FROM ubuntu:16.04 AS common
RUN apt-get install gcc
FROM common AS left
RUN make -j left
FROM common AS right
RUN make -j right
FROM alpine:3.4
COPY --from=left /usr/local/l /opt/l
COPY --from=right /usr/local/r /opt/r
";
    let bf = Buildfile::parse(text).unwrap();
    let g = BuildGraph::plan(&bf);
    assert_eq!(g.schedule(), vec![vec![0], vec![1, 2], vec![3]]);
    assert!(g.is_needed(0) && g.is_needed(1) && g.is_needed(2) && g.is_needed(3));
    let mut store = LayerStore::new();
    let r = Builder::new().build(&bf, "d:1", &mut store).unwrap();
    // both branches share the common stage: its 2 layers built once
    assert_eq!(r.layers_built, 2 + 1 + 1 + 3);
    assert_eq!(r.image.layers.len(), 3, "alpine + two COPY layers");
    // the parallel branches overlap on the critical path
    assert!(r.critical_path < r.build_time);
}

#[test]
fn copy_from_invalidates_across_arch_variants_but_shares_prefixes() {
    let (app, pkgs) = APPS[0];
    let mut builder = Builder::new();
    let mut store = LayerStore::new();
    let a = Buildfile::parse(&variant_buildfile(app, pkgs, ARCHES[0])).unwrap();
    let b = Buildfile::parse(&variant_buildfile(app, pkgs, ARCHES[1])).unwrap();
    let ra = builder.build(&a, "a:1", &mut store).unwrap();
    let rb = builder.build(&b, "b:1", &mut store).unwrap();
    // second arch: toolchain + deps stages (3 layers) and the runtime
    // base hit the cache; the arch-specific make, both COPYs (their
    // --from digests changed), and ARCH_OPT rebuild
    assert_eq!(rb.layers_cached, 4, "shared prefix + runtime base cached");
    assert_eq!(rb.layers_built, 4, "arch make + 2 COPYs + ARCH_OPT rebuilt");
    assert_ne!(ra.image.id, rb.image.id);
    // identical rebuild of the first variant: fully cached
    let ra2 = builder.build(&a, "a:2", &mut store).unwrap();
    assert_eq!(ra2.layers_built, 0);
    assert_eq!(ra2.image.layers, ra.image.layers);
}

#[test]
fn farm_cold_pass_shares_the_cache_and_warm_pass_is_nearly_free() {
    let jobs = variant_matrix().unwrap();
    let mut farm = BuildFarm::new(FarmConfig::ci(1));
    let cold = farm.run_pass(&jobs).unwrap();
    let warm = farm.run_pass(&jobs).unwrap();
    assert_eq!(cold.jobs, jobs.len());
    assert_eq!(cold.images_pushed, jobs.len());
    // serial cold farm: later variants hit the toolchain/deps stages
    assert!(cold.build_hit_rate() > 0.3, "hit rate {}", cold.build_hit_rate());
    assert!(cold.wan_bytes > 0);
    assert!(cold.gc_bytes > 0, "non-terminal stage layers are collected");
    // warm: everything cached, nothing crosses the WAN; cache hits
    // re-materialize the GC'd builder-stage blobs into the store (the
    // builder self-heals missing blobs) and the pass-end GC collects
    // exactly that set again
    assert_eq!(warm.layers_built, 0);
    assert!((warm.build_hit_rate() - 1.0).abs() < 1e-12);
    assert_eq!(warm.wan_bytes, 0);
    assert_eq!(warm.wan_transfers, 0);
    assert_eq!(warm.gc_layers, cold.gc_layers);
    let ratio = warm.makespan.as_secs_f64() / cold.makespan.as_secs_f64();
    assert!(ratio < 0.10, "warm/cold ratio {ratio} above the acceptance bar");
    // one completion event per job went through the calendar queue
    assert_eq!(cold.queue.pushes, jobs.len() as u64);
    assert_eq!(cold.queue.pops, cold.queue.pushes);
}

#[test]
fn gc_survives_cache_hits_on_collected_builder_stages() {
    // pass 1 builds a full variant, so its toolchain/deps layers are
    // GC'd as non-terminal; pass 2 pushes the deps image ITSELF — its
    // terminal chain is exactly pass 1's collected prefix, resolved
    // entirely from cache.  The builder must re-materialize those
    // blobs into the store (cache entries hold full layers) so the
    // push succeeds instead of dangling.
    let (app, pkgs) = APPS[0];
    let text = variant_buildfile(app, pkgs, ARCHES[0]);
    let variant = Buildfile::parse(&text).unwrap();
    // the first two stages of the variant, verbatim, as their own file
    let deps_text = text.lines().take(4).collect::<Vec<_>>().join("\n");
    let deps_only = Buildfile::parse(&deps_text).unwrap();
    assert_eq!(deps_only.stage_count(), 2);

    let mut farm = BuildFarm::new(FarmConfig::ci(2));
    let first = farm.run_pass(&[("local/app:v1".to_string(), variant)]).unwrap();
    assert!(first.gc_layers > 0, "builder stages were collected");
    let second = farm.run_pass(&[("local/deps:v1".to_string(), deps_only)]).unwrap();
    assert_eq!(second.layers_built, 0, "terminal chain came from cache");
    assert_eq!(second.images_pushed, 1);
    assert!(second.wan_bytes > 0, "resurrected blobs still cross the WAN");
}

#[test]
fn wider_farms_are_faster_but_share_less_cold_cache() {
    let jobs = variant_matrix().unwrap();
    let run = |workers: usize| {
        let mut farm = BuildFarm::new(FarmConfig::ci(workers));
        farm.run_pass(&jobs).unwrap()
    };
    let serial = run(1);
    let wide = run(16);
    assert!(
        wide.makespan < serial.makespan,
        "16 workers ({}) must beat 1 ({})",
        wide.makespan,
        serial.makespan
    );
    // concurrency costs cache sharing: jobs started before their
    // peers' commits cannot hit those peers' cache entries
    assert!(wide.build_hit_rate() <= serial.build_hit_rate());
    assert!(wide.layers_built >= serial.layers_built);
    // whatever was built, the same set of images got pushed
    assert_eq!(wide.images_pushed, serial.images_pushed);
}

#[test]
fn build_farm_renders_byte_identically_under_jobs() {
    let mut cfg = ExperimentConfig::paper_default("build-farm").unwrap();
    cfg.nodes = vec![1, 4];
    let run = |jobs: usize| {
        Coordinator::with_table(CalibrationTable::builtin_fallback())
            .with_jobs(jobs)
            .run(&cfg)
            .unwrap()
    };
    let serial = render_all(&run(1));
    let parallel = render_all(&run(4));
    assert_eq!(serial, parallel, "build-farm must be --jobs invariant");
    assert!(serial.contains("Build farm — cold pass makespan"));
    assert!(serial.contains("4 workers"));
    assert!(serial.contains("warm/cold makespan ratio"));
}

#[test]
fn version_bump_invalidates_exactly_the_predicted_frontier() {
    // For every churn target and every arch variant: bump one package
    // in the index, re-resolve, and check that the set of package
    // stages the builder actually rebuilds equals the lockfile diff's
    // predicted frontier — no over-invalidation (unrelated stages stay
    // cached) and no under-invalidation (every dependent rebuilds).
    for target in BUMP_TARGETS {
        let mut index = fenics_index();
        let manifest = fenics_manifest();
        let lock1 =
            Lockfile::from_resolution(&resolve(&manifest, &index, 0).unwrap(), &index);
        let mut builder = Builder::new();
        let mut store = LayerStore::new();
        for arch in ARCHES {
            let text = emit_stack_buildfile(&manifest, &lock1, STACK_BASE, Some(arch)).unwrap();
            let bf = Buildfile::parse(&text).unwrap();
            builder.build(&bf, &format!("local/{target}-{arch}:cold"), &mut store).unwrap();
        }
        let bumped = index.bump_patch(target).expect("target is in the index");
        assert!(bumped > lock1.packages[target].version, "bump moves {target} forward");
        let lock2 =
            Lockfile::from_resolution(&resolve(&manifest, &index, 0).unwrap(), &index);
        let frontier = lock1.diff(&lock2).rebuild_frontier(&lock2);
        assert!(frontier.contains(target), "{target} itself is on the frontier");
        for arch in ARCHES {
            let text = emit_stack_buildfile(&manifest, &lock2, STACK_BASE, Some(arch)).unwrap();
            let bf = Buildfile::parse(&text).unwrap();
            // fork per arch so one variant's rebuilds cannot warm
            // another variant's cache mid-measurement
            let mut fork = builder.fork();
            let warm = fork.build(&bf, &format!("local/{target}-{arch}:warm"), &mut store).unwrap();
            let rebuilt = rebuilt_packages(&bf, &warm);
            assert_eq!(
                rebuilt, frontier,
                "bump {target} on {arch}: rebuilt stages must equal the predicted frontier"
            );
            assert!(
                terminal_rebuilt(&warm),
                "bump {target} on {arch}: the terminal stage re-links the stack"
            );
            assert!(warm.stages_skipped > 0, "unrelated stages stayed cached");
        }
    }
}

#[test]
fn build_farm_is_listed_by_the_registry() {
    // `harbor bench --list` prints ScenarioRegistry::table(); the
    // scenario must be there with a non-empty description
    let registry = ScenarioRegistry::builtin();
    let table = registry.table();
    let row = table.iter().find(|(name, _)| *name == "build-farm");
    let (_, describe) = row.expect("build-farm registered");
    assert!(describe.contains("ARCH_OPT"));
    assert!(registry.get("build-farm").is_some());
    let cfg = registry.get("build-farm").unwrap().default_config().unwrap();
    assert_eq!(cfg.figure, "build-farm");
    assert!(!cfg.nodes.is_empty());
}
