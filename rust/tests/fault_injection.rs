//! Integration: the fault-injection layer's contracts.
//!
//! * the same seed generates a byte-identical [`FaultSchedule`], and
//!   the chaos-canary matrix renders byte-identically across `--jobs`;
//! * a zero-intensity schedule reproduces the fault-free deploy
//!   reports **bit-for-bit** (the chaos layer is free when unused);
//! * under heavy chaos every scope node ends either deployed or
//!   reported permanently failed — nothing is silently lost — and the
//!   byte-conservation invariant extends to `retried_bytes`;
//! * a retry storm against a never-ending drop window terminates with
//!   permanent failures instead of hanging.

use std::ops::Range;

use harbor::config::ExperimentConfig;
use harbor::container::{Fleet, FleetConfig, FleetReport, RetryPolicy, ShardedRegistry};
use harbor::coordinator::Coordinator;
use harbor::des::{Duration, Fault, FaultConfig, FaultSchedule, SimRng, VirtualTime};
use harbor::runtime::CalibrationTable;
use harbor::scenario::chaos_canary::{
    canary_registry, canary_ring, ChaosCanary, V1_REFERENCE, V2_REFERENCE,
};
use harbor::scenario::{CellId, Scenario, SimContext};

fn schedule(nodes: usize, intensity: f64, seed: u64) -> FaultSchedule {
    let cfg = FaultConfig::new(nodes, 4, Duration::from_secs_f64(60.0), intensity);
    FaultSchedule::generate(&cfg, &mut SimRng::new(seed, "fault-schedule"))
}

/// One ring of the rolling upgrade (unwrapping keeps call sites
/// readable; a deploy error is a test failure either way).
fn upgrade(
    fleet: &mut Fleet,
    registry: &mut ShardedRegistry,
    scope: Range<usize>,
    sched: &FaultSchedule,
    policy: &RetryPolicy,
    rng: &mut SimRng,
) -> FleetReport {
    fleet
        .deploy_with_faults(registry, V2_REFERENCE, scope, sched, policy, rng)
        .unwrap()
}

#[test]
fn same_seed_generates_a_byte_identical_schedule() {
    let a = schedule(256, 0.8, 7);
    let b = schedule(256, 0.8, 7);
    assert_eq!(a.events(), b.events());
    assert_eq!(a.len(), b.len());
    // a different seed rolls different chaos
    let c = schedule(256, 0.8, 8);
    assert_ne!(a.events(), c.events());
    // and zero intensity injects nothing at any seed
    assert!(schedule(256, 0.0, 7).is_empty());
}

#[test]
fn chaos_matrix_renders_identically_across_jobs() {
    let cfg = ExperimentConfig {
        nodes: vec![16],
        ..ExperimentConfig::paper_default("chaos-canary").unwrap()
    };
    let run = |jobs| {
        Coordinator::with_table(CalibrationTable::builtin_fallback())
            .with_jobs(jobs)
            .run(&cfg)
            .unwrap()
            .iter()
            .map(|f| f.render())
            .collect::<Vec<_>>()
            .join("\n")
    };
    let serial = run(1);
    assert_eq!(serial, run(4), "--jobs must not change a single byte");
    assert_eq!(serial, run(1), "re-running must not change a single byte");
}

#[test]
fn zero_intensity_rolling_upgrade_is_bit_identical_to_fault_free() {
    let nodes = 32;
    let ring = canary_ring(nodes);
    let empty = FaultSchedule::none();

    // arm A: the chaos path with an empty schedule and the full retry
    // policy (jitter armed but never drawn)
    let mut reg_a = canary_registry().unwrap();
    let mut fleet_a = Fleet::new(FleetConfig::hpc(nodes));
    fleet_a.deploy(&mut reg_a, V1_REFERENCE).unwrap();
    reg_a.apply_faults(&empty);
    let hpc = RetryPolicy::hpc();
    let mut rng_a = SimRng::new(99, "retry-jitter");
    let a1 = upgrade(&mut fleet_a, &mut reg_a, 0..ring, &empty, &hpc, &mut rng_a);
    let a2 = upgrade(&mut fleet_a, &mut reg_a, ring..nodes, &empty, &hpc, &mut rng_a);

    // arm B: the same rings under the no-retry policy and a different
    // rng — a fault-free run may not depend on either
    let mut reg_b = canary_registry().unwrap();
    let mut fleet_b = Fleet::new(FleetConfig::hpc(nodes));
    fleet_b.deploy(&mut reg_b, V1_REFERENCE).unwrap();
    let none = RetryPolicy::none();
    let mut rng_b = SimRng::new(12345, "other-stream");
    let b1 = upgrade(&mut fleet_b, &mut reg_b, 0..ring, &empty, &none, &mut rng_b);
    let b2 = upgrade(&mut fleet_b, &mut reg_b, ring..nodes, &empty, &none, &mut rng_b);

    assert_eq!(a1, b1, "canary ring reports must be bit-identical");
    assert_eq!(a2, b2, "rest ring reports must be bit-identical");
    assert_eq!(a1.render(), b1.render());
    // the untouched rng still sits at its seed position
    let mut fresh = SimRng::new(99, "retry-jitter");
    assert_eq!(
        rng_a.uniform(0.0, 1.0).to_bits(),
        fresh.uniform(0.0, 1.0).to_bits()
    );
    // and the fault tail never appears in a fault-free render
    assert!(!a1.render().contains("retry(ies)"));
    assert_eq!(a1.fault, Default::default());
}

#[test]
fn zero_intensity_cell_matches_a_hand_rolled_fault_free_upgrade() {
    let cfg = ExperimentConfig {
        nodes: vec![32],
        ..ExperimentConfig::paper_default("chaos-canary").unwrap()
    };
    let table = CalibrationTable::builtin_fallback();
    let ctx = SimContext {
        cfg: &cfg,
        table: &table,
    };
    let scenario = ChaosCanary;
    let mut cells = scenario.cells(&cfg).unwrap();
    for (i, c) in cells.iter_mut().enumerate() {
        c.id = CellId {
            scenario: "chaos-canary",
            index: i,
        };
    }
    // expansion order: intensity outer, policy inner — cell 1 is
    // (intensity 0.0, hpc)
    assert!(cells[1].label.contains("intensity 0.0") && cells[1].label.contains("hpc"));
    let r = scenario.run_cell(&ctx, &cells[1]).unwrap();

    // hand-rolled fault-free rolling upgrade over the same rings
    let nodes = 32;
    let ring = canary_ring(nodes);
    let mut reg = canary_registry().unwrap();
    let mut fleet = Fleet::new(FleetConfig::hpc(nodes));
    fleet.deploy(&mut reg, V1_REFERENCE).unwrap();
    let empty = FaultSchedule::none();
    let none = RetryPolicy::none();
    let mut rng = SimRng::new(0, "unused");
    let canary = upgrade(&mut fleet, &mut reg, 0..ring, &empty, &none, &mut rng);
    let rest = upgrade(&mut fleet, &mut reg, ring..nodes, &empty, &none, &mut rng);
    let span = (rest.started_at + rest.makespan).since(canary.started_at);

    assert_eq!(r.values[0].to_bits(), span.as_secs_f64().to_bits());
    assert_eq!(r.values[1], 1.0, "fault-free availability is exactly 1");
    assert_eq!(r.values[2], 0.0, "no bytes wasted");
    assert_eq!(r.values[3], 0.0, "no retries");
}

#[test]
fn no_scope_node_is_orphaned_and_bytes_stay_conserved_under_chaos() {
    for seed in 0..8u64 {
        let nodes = 32;
        let ring = canary_ring(nodes);
        let mut reg = canary_registry().unwrap();
        let mut fleet = Fleet::new(FleetConfig::hpc(nodes));
        fleet.deploy(&mut reg, V1_REFERENCE).unwrap();
        let sched = schedule(nodes, 1.0, seed).shifted(fleet.now());
        reg.apply_faults(&sched);
        let mut rng = SimRng::new(seed, "retry-jitter");
        let policy = RetryPolicy::hpc();
        let canary = upgrade(&mut fleet, &mut reg, 0..ring, &sched, &policy, &mut rng);
        let rest = upgrade(&mut fleet, &mut reg, ring..nodes, &sched, &policy, &mut rng);
        for (label, r, scope) in [("canary", &canary, ring), ("rest", &rest, nodes - ring)] {
            assert_eq!(
                r.containers_started + r.permanently_failed,
                scope,
                "seed {seed}: every {label} node must end deployed or permanently failed"
            );
            assert_eq!(
                r.total_bytes(),
                r.cache.bytes_inserted + r.retried_bytes,
                "seed {seed}: {label} ring broke byte conservation"
            );
        }
    }
}

#[test]
fn retry_storm_against_a_total_drop_window_terminates() {
    let nodes = 4;
    let mut reg = canary_registry().unwrap();
    let mut fleet = Fleet::new(FleetConfig::hpc(nodes));
    fleet.deploy(&mut reg, V1_REFERENCE).unwrap();
    // one drop window swallowing every WAN transfer forever
    let sched = FaultSchedule::from_events(vec![(
        VirtualTime(0),
        Fault::TransferDrop {
            until: VirtualTime(u64::MAX),
        },
    )]);
    reg.apply_faults(&sched);
    let policy = RetryPolicy::hpc();
    let mut rng = SimRng::new(1, "retry-jitter");
    let r = upgrade(&mut fleet, &mut reg, 0..nodes, &sched, &policy, &mut rng);
    // the hotpatch layer can never cross the WAN: the seeding attempts
    // exhaust the retry budget and every node is given up on
    assert_eq!(r.permanently_failed, nodes);
    assert_eq!(r.containers_started, 0);
    assert_eq!(r.wan_transfers as u32, policy.max_attempts);
    assert_eq!(r.wan_bytes, r.retried_bytes, "every WAN byte was wasted");
    assert_eq!(r.total_bytes(), r.cache.bytes_inserted + r.retried_bytes);
    assert!(r.fault.retries > 0 && r.fault.transfers_dropped > 0);
    assert!(r.render().contains("permanently failed"));
}
