//! Differential properties for the calendar-queue scheduler.
//!
//! The calendar `EventQueue` replaced the `BinaryHeap` queue under
//! every scenario, and the golden figure tests only catch divergence
//! the figures happen to exercise — so this suite drives the calendar
//! and the retained [`HeapEventQueue`] reference through *identical*
//! randomized workloads (dense bursts, heavy timestamp ties, sparse
//! far-future outliers, batch pushes, interleaved push/pop drains) and
//! requires the pop streams to match event for event.  A `FifoResource`
//! property pins the reworked server-token station to a linear-scan
//! model of the original implementation, and a bounded-Pareto stream
//! replays the registry-storm arrival process (bursts plus a sparse
//! heavy tail in one schedule) against the heap reference.
//!
//! The partitioned-queue properties pin the conservative parallel DES
//! ([`PartitionedQueue`]) to the serial calendar the same way: for any
//! domain count — including empty domains, everything in one domain,
//! and cross-domain ties at the lookahead horizon — the `(time, seq)`
//! pop stream must match the serial queue event for event.

use harbor::des::{
    Duration, EventQueue, FifoResource, HeapEventQueue, PartitionedQueue, VirtualTime,
};
use harbor::util::proptest::{run, Gen};

fn t(ns: u64) -> VirtualTime {
    VirtualTime::ZERO + Duration::from_nanos(ns)
}

/// Timestamps drawn from regimes the calendar geometry must survive:
/// heavy ties, dense ns-scale spacing, sparse second-scale spacing,
/// and far-future outliers whole years past everything else.
fn random_time(g: &mut Gen) -> VirtualTime {
    match g.usize_in(0, 3) {
        0 => t(g.u64_in(0, 3)),
        1 => t(g.u64_in(0, 10_000)),
        2 => t(g.u64_in(0, 1_000_000_000)),
        _ => t(g.u64_in(1_000_000_000_000, 2_000_000_000_000)),
    }
}

#[test]
fn prop_calendar_pop_order_equals_heap_reference() {
    run("calendar-vs-heap", 300, |g: &mut Gen| {
        let mut cal = EventQueue::new();
        let mut heap = HeapEventQueue::new();
        let ops = g.usize_in(1, 120);
        let mut next_id = 0usize;
        for _ in 0..ops {
            match g.usize_in(0, 3) {
                0 | 1 => {
                    let time = random_time(g);
                    cal.push(time, next_id);
                    heap.push(time, next_id);
                    next_id += 1;
                }
                2 => {
                    let k = g.usize_in(0, 40);
                    let batch: Vec<(VirtualTime, usize)> =
                        (0..k).map(|i| (random_time(g), next_id + i)).collect();
                    next_id += k;
                    cal.push_batch(batch.clone());
                    heap.push_batch(batch);
                }
                _ => {
                    let (a, b) = (cal.pop(), heap.pop());
                    if a != b {
                        return Err(format!("pop diverged: calendar {a:?} vs heap {b:?}"));
                    }
                }
            }
            if cal.len() != heap.len() {
                return Err(format!("len diverged: {} vs {}", cal.len(), heap.len()));
            }
            if cal.peek_time() != heap.peek_time() {
                return Err(format!(
                    "peek diverged: {:?} vs {:?}",
                    cal.peek_time(),
                    heap.peek_time()
                ));
            }
        }
        // full drain must agree to the very last event
        loop {
            let (a, b) = (cal.pop(), heap.pop());
            if a != b {
                return Err(format!("drain diverged: {a:?} vs {b:?}"));
            }
            if a.is_none() {
                return Ok(());
            }
        }
    });
}

#[test]
fn prop_heavy_ties_keep_fifo_order_across_push_paths() {
    // all events share a handful of timestamps; FIFO order must hold
    // exactly whether events arrived singly or in batches
    run("calendar-ties", 200, |g: &mut Gen| {
        let mut cal = EventQueue::new();
        let mut heap = HeapEventQueue::new();
        let mut next_id = 0usize;
        for _ in 0..g.usize_in(1, 8) {
            let stamp = t(g.u64_in(0, 2));
            if g.bool() {
                let k = g.usize_in(1, 64);
                let batch: Vec<(VirtualTime, usize)> =
                    (0..k).map(|i| (stamp, next_id + i)).collect();
                next_id += k;
                cal.push_batch(batch.clone());
                heap.push_batch(batch);
            } else {
                cal.push(stamp, next_id);
                heap.push(stamp, next_id);
                next_id += 1;
            }
        }
        loop {
            let (a, b) = (cal.pop(), heap.pop());
            if a != b {
                return Err(format!("tie order diverged: {a:?} vs {b:?}"));
            }
            if a.is_none() {
                return Ok(());
            }
        }
    });
}

#[test]
fn prop_stats_conserve_counts() {
    run("queue-stats", 150, |g: &mut Gen| {
        let mut q = EventQueue::new();
        let (mut pushed, mut popped) = (0u64, 0u64);
        for _ in 0..g.usize_in(1, 100) {
            if g.bool() {
                q.push(random_time(g), ());
                pushed += 1;
            } else if q.pop().is_some() {
                popped += 1;
            }
        }
        let s = q.stats();
        if s.pushes != pushed || s.pops != popped {
            return Err(format!(
                "counter drift: {}/{} vs {pushed}/{popped}",
                s.pushes, s.pops
            ));
        }
        if s.depth != q.len() || s.pushes - s.pops != s.depth as u64 {
            return Err(format!("depth {} inconsistent with counters", s.depth));
        }
        if s.depth_hwm < s.depth {
            return Err("high-water mark below current depth".into());
        }
        if s.occupied_buckets > s.buckets || (s.depth > 0 && s.occupied_buckets == 0) {
            return Err(format!(
                "bucket occupancy {}/{} impossible at depth {}",
                s.occupied_buckets, s.buckets, s.depth
            ));
        }
        Ok(())
    });
}

/// The registry-storm arrival process: bounded-Pareto inter-arrival
/// gaps spanning two orders of magnitude push dense bursts *and* a
/// sparse far tail through the same calendar, interleaved with
/// service-completion events and concurrent drains — the geometry
/// adaptation must stay event-for-event identical to the heap.
#[test]
fn prop_heavy_tailed_open_loop_stream_matches_heap() {
    run("calendar-pareto-storm", 150, |g: &mut Gen| {
        let mut cal = EventQueue::new();
        let mut heap = HeapEventQueue::new();
        let alpha = 1.5;
        let span: f64 = 100.0;
        let tail = 1.0 - span.powf(-alpha);
        let mean_gap_ns = g.u64_in(10, 1_000_000);
        let mut now = 0u64;
        let mut next_id = 0usize;
        for _ in 0..g.usize_in(1, 400) {
            // open-loop arrival: the next session opens a Pareto gap on
            let gap = (1.0 - g.f64_in(0.0, 1.0) * tail).powf(-1.0 / alpha);
            now += (gap * mean_gap_ns as f64) as u64;
            cal.push(t(now), next_id);
            heap.push(t(now), next_id);
            next_id += 1;
            // its chunk completion re-enters the schedule further out
            if g.bool() {
                let done = now + g.u64_in(0, 10 * mean_gap_ns);
                cal.push(t(done), next_id);
                heap.push(t(done), next_id);
                next_id += 1;
            }
            if g.bool() {
                let (a, b) = (cal.pop(), heap.pop());
                if a != b {
                    return Err(format!("storm pop diverged: {a:?} vs {b:?}"));
                }
            }
        }
        loop {
            let (a, b) = (cal.pop(), heap.pop());
            if a != b {
                return Err(format!("storm drain diverged: {a:?} vs {b:?}"));
            }
            if a.is_none() {
                return Ok(());
            }
        }
    });
}

/// The partitioned queue must reproduce the serial pop stream for any
/// domain count and any routing, under interleaved pushes, batches and
/// pops — including pushes that land inside already-drained windows.
#[test]
fn prop_partitioned_pop_stream_matches_the_serial_queue() {
    run("partitioned-vs-serial", 200, |g: &mut Gen| {
        let domains = [1usize, 2, 3, 8][g.usize_in(0, 3)];
        let lookahead = Duration::from_nanos(g.u64_in(0, 1_000_000));
        let mut part = PartitionedQueue::new(domains, lookahead, 64);
        let mut serial = EventQueue::new();
        let mut next_id = 0usize;
        for _ in 0..g.usize_in(1, 120) {
            match g.usize_in(0, 3) {
                0 | 1 => {
                    let time = random_time(g);
                    // over-range domain indices exercise the modulo wrap
                    let d = g.usize_in(0, domains * 2);
                    part.push(d, time, next_id);
                    serial.push(time, next_id);
                    next_id += 1;
                }
                2 => {
                    let k = g.usize_in(0, 40);
                    let batch: Vec<(usize, VirtualTime, usize)> = (0..k)
                        .map(|i| (g.usize_in(0, domains), random_time(g), next_id + i))
                        .collect();
                    next_id += k;
                    serial.push_batch(batch.iter().map(|&(_, tt, ev)| (tt, ev)).collect());
                    part.push_batch(batch);
                }
                _ => {
                    let (a, b) = (part.pop(), serial.pop());
                    if a != b {
                        return Err(format!(
                            "pop diverged at {domains} domain(s): {a:?} vs {b:?}"
                        ));
                    }
                }
            }
            if part.len() != serial.len() {
                return Err(format!("len diverged: {} vs {}", part.len(), serial.len()));
            }
            if part.peek_time() != serial.peek_time() {
                return Err(format!(
                    "peek diverged: {:?} vs {:?}",
                    part.peek_time(),
                    serial.peek_time()
                ));
            }
        }
        loop {
            let (a, b) = (part.pop(), serial.pop());
            if a != b {
                return Err(format!("drain diverged: {a:?} vs {b:?}"));
            }
            if a.is_none() {
                return Ok(());
            }
        }
    });
}

/// Cross-domain timestamp ties sitting exactly on the lookahead
/// horizon are where a sloppy merge would reorder: the global push
/// sequence must break them identically to the serial queue.
#[test]
fn partitioned_cross_domain_ties_at_the_lookahead_horizon_stay_fifo() {
    let lookahead = Duration::from_nanos(100);
    // domain 0 anchors the window at t=0, so the first horizon is
    // exactly t=100: ties at 100 across three domains, one event just
    // past it, and a second anchor tie at t=0
    let pushes: &[(usize, u64)] = &[(0, 0), (1, 100), (2, 100), (0, 100), (3, 101), (1, 0)];
    let mut serial = EventQueue::new();
    for (i, &(_, ns)) in pushes.iter().enumerate() {
        serial.push(t(ns), i);
    }
    let reference: Vec<_> = std::iter::from_fn(|| serial.pop()).collect();
    for domains in [2usize, 3, 4, 8] {
        let mut q = PartitionedQueue::new(domains, lookahead, pushes.len());
        for (i, &(d, ns)) in pushes.iter().enumerate() {
            q.push(d, t(ns), i);
        }
        let got: Vec<_> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(got, reference, "domains={domains}");
    }
}

/// Degenerate routings — every event in one domain, the rest
/// permanently idle — must still match the serial stream, with the
/// idle domains contributing only null messages.
#[test]
fn prop_skewed_domain_routings_match_serial() {
    run("partitioned-skew", 150, |g: &mut Gen| {
        let domains = [2usize, 3, 8][g.usize_in(0, 2)];
        let hot = g.usize_in(0, domains - 1);
        let lookahead = Duration::from_nanos(g.u64_in(0, 10_000));
        let mut part = PartitionedQueue::new(domains, lookahead, 64);
        let mut serial = EventQueue::new();
        for id in 0..g.usize_in(1, 150) {
            let time = random_time(g);
            part.push(hot, time, id);
            serial.push(time, id);
        }
        let mut popped = false;
        loop {
            let (a, b) = (part.pop(), serial.pop());
            if a != b {
                return Err(format!("skewed drain diverged: {a:?} vs {b:?}"));
            }
            if a.is_none() {
                break;
            }
            popped = true;
        }
        let s = part.pdes_stats();
        if popped && s.null_msgs < (domains - 1) as u64 {
            return Err(format!(
                "idle domains must null-message every window: {} < {}",
                s.null_msgs,
                domains - 1
            ));
        }
        Ok(())
    });
}

/// The pre-calendar `FifoResource` kept a plain `Vec<VirtualTime>` of
/// server free instants and linear-scanned for the minimum; the
/// token-queue rework must be observably identical to it.
fn model_submit(
    free_at: &mut [VirtualTime],
    arrival: VirtualTime,
    service: Duration,
) -> VirtualTime {
    let idx = free_at
        .iter()
        .enumerate()
        .min_by_key(|&(i, &free)| (free, i))
        .map(|(i, _)| i)
        .expect("at least one server");
    let start = free_at[idx].max(arrival);
    let done = start + service;
    free_at[idx] = done;
    done
}

#[test]
fn prop_fifo_resource_matches_the_linear_scan_model() {
    run("fifo-vs-linear-scan", 200, |g: &mut Gen| {
        let servers = g.usize_in(1, 8);
        let mut real = FifoResource::new(servers);
        let mut free_at = vec![VirtualTime::ZERO; servers];
        let mut arrival = VirtualTime::ZERO;
        for _ in 0..g.usize_in(1, 60) {
            arrival += Duration::from_nanos(g.u64_in(0, 100_000));
            let service = Duration::from_nanos(g.u64_in(1, 50_000));
            if g.bool() {
                let done = real.submit(arrival, service);
                let model = model_submit(&mut free_at, arrival, service);
                if done != model {
                    return Err(format!("submit: {done:?} vs model {model:?}"));
                }
            } else {
                let count = g.u64_in(0, 20) as u32;
                let done = real.submit_many(arrival, service, count);
                let mut model = arrival;
                for _ in 0..count {
                    model = model.max(model_submit(&mut free_at, arrival, service));
                }
                if done != model {
                    return Err(format!("submit_many({count}): {done:?} vs model {model:?}"));
                }
            }
            let model_min = free_at.iter().copied().min().expect("non-empty");
            if real.next_free() != model_min {
                return Err(format!(
                    "next_free: {:?} vs model {model_min:?}",
                    real.next_free()
                ));
            }
        }
        Ok(())
    });
}
