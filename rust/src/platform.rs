//! Execution platforms — the columns of the paper's figures.
//!
//! A [`Platform`] bundles a container runtime choice with the MPI
//! deployment decision; it is the unit the experiment matrix iterates
//! over (Fig 2: native/docker/rkt/vm; Fig 3: native/shifter+system-MPI/
//! shifter+container-MPI; Figs 4, 5: subsets of the same).


use crate::container::RuntimeKind;

/// One column of a figure: how the program is executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Platform {
    /// Bare-metal build, system libraries.
    Native,
    /// Docker runtime, container libraries.
    Docker,
    /// rkt runtime, container libraries.
    Rkt,
    /// Docker inside a VirtualBox-style VM (the 2016 macOS/Windows path).
    Vm,
    /// Shifter with the host (Cray) MPI injected via the MPICH ABI.
    ShifterSystemMpi,
    /// Shifter with the container's own MPICH (TCP fallback off-node).
    ShifterContainerMpi,
}

impl Platform {
    /// The runtime adapter that instantiates this platform.
    pub fn runtime_kind(self) -> RuntimeKind {
        match self {
            Platform::Native => RuntimeKind::Native,
            Platform::Docker => RuntimeKind::Docker,
            Platform::Rkt => RuntimeKind::Rkt,
            Platform::Vm => RuntimeKind::Vm,
            Platform::ShifterSystemMpi | Platform::ShifterContainerMpi => RuntimeKind::Shifter,
        }
    }

    /// Whether the host MPI library is injected (§4.2's LD_LIBRARY_PATH
    /// trick). Native "injection" is trivially true: it links the system
    /// MPI at build time.
    pub fn inject_host_mpi(self) -> bool {
        matches!(self, Platform::Native | Platform::ShifterSystemMpi)
    }

    /// Figure-2 platform set (workstation, single process).
    pub fn workstation_set() -> [Platform; 4] {
        [
            Platform::Docker,
            Platform::Rkt,
            Platform::Native,
            Platform::Vm,
        ]
    }

    /// Figure-3 platform set (Edison, MPI).
    pub fn edison_cpp_set() -> [Platform; 3] {
        [
            Platform::Native,
            Platform::ShifterSystemMpi,
            Platform::ShifterContainerMpi,
        ]
    }

    /// Figure-4 platform set (Edison, Python).
    pub fn edison_python_set() -> [Platform; 2] {
        [Platform::Native, Platform::ShifterSystemMpi]
    }

    /// Short label used in reports (matches the paper's legends).
    pub fn label(self) -> &'static str {
        match self {
            Platform::Native => "native",
            Platform::Docker => "docker",
            Platform::Rkt => "rkt",
            Platform::Vm => "vm",
            Platform::ShifterSystemMpi => "shifter (system MPI)",
            Platform::ShifterContainerMpi => "shifter (container MPI)",
        }
    }

    /// Is this a containerised platform (anything but native)?
    pub fn containerised(self) -> bool {
        self != Platform::Native
    }
}

impl std::fmt::Display for Platform {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}

impl std::str::FromStr for Platform {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(Platform::Native),
            "docker" => Ok(Platform::Docker),
            "rkt" => Ok(Platform::Rkt),
            "vm" => Ok(Platform::Vm),
            "shifter" | "shifter-system-mpi" => Ok(Platform::ShifterSystemMpi),
            "shifter-container-mpi" => Ok(Platform::ShifterContainerMpi),
            other => Err(format!("unknown platform `{other}`")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_kind_mapping() {
        assert_eq!(Platform::Native.runtime_kind(), RuntimeKind::Native);
        assert_eq!(
            Platform::ShifterSystemMpi.runtime_kind(),
            RuntimeKind::Shifter
        );
        assert_eq!(
            Platform::ShifterContainerMpi.runtime_kind(),
            RuntimeKind::Shifter
        );
    }

    #[test]
    fn injection_policy() {
        assert!(Platform::Native.inject_host_mpi());
        assert!(Platform::ShifterSystemMpi.inject_host_mpi());
        assert!(!Platform::ShifterContainerMpi.inject_host_mpi());
        assert!(!Platform::Docker.inject_host_mpi());
    }

    #[test]
    fn figure_sets_match_the_paper() {
        assert_eq!(Platform::workstation_set().len(), 4);
        assert_eq!(Platform::edison_cpp_set().len(), 3);
        assert_eq!(Platform::edison_python_set().len(), 2);
    }

    #[test]
    fn parse_round_trip() {
        for p in [
            Platform::Native,
            Platform::Docker,
            Platform::Rkt,
            Platform::Vm,
        ] {
            assert_eq!(p.label().parse::<Platform>().unwrap(), p);
        }
        assert_eq!(
            "shifter-container-mpi".parse::<Platform>().unwrap(),
            Platform::ShifterContainerMpi
        );
        assert!("qemu".parse::<Platform>().is_err());
    }

    #[test]
    fn containerised_flag() {
        assert!(!Platform::Native.containerised());
        assert!(Platform::Docker.containerised());
    }
}
