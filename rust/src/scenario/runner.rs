//! Deterministic parallel matrix runner.
//!
//! Expands a scenario into cells and executes them across
//! `std::thread::scope` workers.  Determinism comes from two
//! properties, both enforced structurally rather than by luck:
//!
//! * **cells share nothing** — every cell builds its own RNG streams,
//!   filesystems, and communicators from `(config, cell id)`, so the
//!   interleaving of workers cannot influence any cell's numbers;
//! * **assembly is keyed, not ordered** — results land in a slot vector
//!   indexed by cell id and are handed to `Scenario::assemble` in
//!   expansion order, whatever order workers finished in.
//!
//! Together these make `--jobs 8` bit-identical to `--jobs 1`
//! (`tests/scenario_matrix.rs` asserts the rendered figures match byte
//! for byte for every registered scenario).

use anyhow::Result;

use crate::bench::Figure;
use crate::config::ExperimentConfig;
use crate::runtime::CalibrationTable;

use super::{Cell, CellId, CellResult, Scenario, SimContext};

/// Executes a scenario's cell matrix across a fixed number of worker
/// threads.
#[derive(Debug, Clone, Copy)]
pub struct MatrixRunner {
    jobs: usize,
}

impl MatrixRunner {
    /// A runner with `jobs` workers (clamped to at least one).
    pub fn new(jobs: usize) -> Self {
        MatrixRunner { jobs: jobs.max(1) }
    }

    /// A serial runner (the library default: no surprise threads).
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// The machine's available parallelism (the CLI's `--jobs` default).
    pub fn available_jobs() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }

    /// The configured worker count.
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Expand `scenario` under `cfg`, execute every cell, and assemble
    /// the figures.  Output is bit-identical regardless of the worker
    /// count.
    pub fn run(
        &self,
        scenario: &dyn Scenario,
        cfg: &ExperimentConfig,
        table: &CalibrationTable,
    ) -> Result<Vec<Figure>> {
        let mut cells = scenario.cells(cfg)?;
        for (i, cell) in cells.iter_mut().enumerate() {
            cell.id = CellId {
                scenario: scenario.name(),
                index: i,
            };
        }
        let ctx = SimContext { cfg, table };
        let slots = self.run_cells(scenario, &ctx, &cells)?;
        scenario.assemble(&ctx, &cells, slots)
    }

    /// Execute the cells into id-ordered results.
    fn run_cells(
        &self,
        scenario: &dyn Scenario,
        ctx: &SimContext<'_>,
        cells: &[Cell],
    ) -> Result<Vec<CellResult>> {
        let n = cells.len();
        let jobs = self.jobs.min(n.max(1));
        let mut slots: Vec<Option<Result<CellResult>>> = Vec::new();
        slots.resize_with(n, || None);

        if jobs <= 1 {
            for (i, cell) in cells.iter().enumerate() {
                slots[i] = Some(scenario.run_cell(ctx, cell));
            }
        } else {
            // strided work split: worker w owns cells w, w+jobs, ... —
            // static, deterministic, and queue-free.  Cell costs within
            // one scenario are near-uniform, so striding also balances.
            std::thread::scope(|s| {
                let handles: Vec<_> = (0..jobs)
                    .map(|w| {
                        s.spawn(move || {
                            let mut out = Vec::new();
                            let mut i = w;
                            while i < n {
                                out.push((i, scenario.run_cell(ctx, &cells[i])));
                                i += jobs;
                            }
                            out
                        })
                    })
                    .collect();
                for h in handles {
                    for (i, r) in h.join().expect("matrix worker panicked") {
                        slots[i] = Some(r);
                    }
                }
            });
        }

        slots
            .into_iter()
            .enumerate()
            .map(|(i, slot)| {
                let mut r = slot.expect("every cell has a slot")?;
                r.cell = i;
                Ok(r)
            })
            .collect()
    }
}

impl Default for MatrixRunner {
    fn default() -> Self {
        Self::serial()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::cell_seed;

    /// A scenario whose cells record their own (id, seed) — enough to
    /// prove the runner's ordering and seeding contracts without any
    /// simulation behind it.
    struct Probe {
        cells: usize,
    }

    impl Scenario for Probe {
        fn name(&self) -> &'static str {
            "probe"
        }
        fn describe(&self) -> &'static str {
            "runner contract probe"
        }
        fn default_config(&self) -> Result<ExperimentConfig> {
            ExperimentConfig::paper_default("fig2")
        }
        fn cells(&self, _cfg: &ExperimentConfig) -> Result<Vec<Cell>> {
            Ok((0..self.cells).map(|i| Cell::new(format!("cell {i}"), i)).collect())
        }
        fn run_cell(&self, ctx: &SimContext<'_>, cell: &Cell) -> Result<CellResult> {
            let i = *cell.payload::<usize>()?;
            assert_eq!(cell.id.index, i, "runner must assign ids in expansion order");
            assert_eq!(cell.id.scenario, "probe");
            Ok(CellResult::values(vec![
                i as f64,
                cell.id.seed(ctx.cfg.seed) as f64,
            ]))
        }
        fn assemble(
            &self,
            ctx: &SimContext<'_>,
            cells: &[Cell],
            rows: Vec<CellResult>,
        ) -> Result<Vec<Figure>> {
            // rows arrive in cell-id order, aligned with the executed
            // cells and seeded from the stable hash, independent of
            // worker interleaving
            assert_eq!(cells.len(), rows.len());
            for (i, (cell, r)) in cells.iter().zip(&rows).enumerate() {
                assert_eq!(cell.id.index, i);
                assert_eq!(r.cell, i);
                assert_eq!(r.values[0] as usize, i);
                assert_eq!(r.values[1], cell_seed(ctx.cfg.seed, "probe", i) as f64);
            }
            let mut fig = Figure::new("probe", "id", false);
            for r in &rows {
                fig.push(crate::bench::Row::new(
                    format!("cell {}", r.cell),
                    crate::metrics::Stats::from_samples(r.values.clone()),
                ));
            }
            Ok(vec![fig])
        }
    }

    #[test]
    fn parallel_runs_match_serial_bit_for_bit() {
        let table = CalibrationTable::builtin_fallback();
        let probe = Probe { cells: 23 };
        let cfg = probe.default_config().unwrap();
        let serial = MatrixRunner::serial().run(&probe, &cfg, &table).unwrap();
        for jobs in [2usize, 7, 64] {
            let par = MatrixRunner::new(jobs).run(&probe, &cfg, &table).unwrap();
            assert_eq!(serial.len(), par.len());
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.render(), b.render(), "jobs={jobs}");
            }
        }
    }

    #[test]
    fn zero_jobs_clamps_to_one_and_empty_matrix_is_fine() {
        let table = CalibrationTable::builtin_fallback();
        let probe = Probe { cells: 0 };
        let cfg = probe.default_config().unwrap();
        let figs = MatrixRunner::new(0).run(&probe, &cfg, &table).unwrap();
        assert_eq!(figs.len(), 1);
        assert!(figs[0].rows.is_empty());
    }

    #[test]
    fn available_jobs_is_positive() {
        assert!(MatrixRunner::available_jobs() >= 1);
    }
}
