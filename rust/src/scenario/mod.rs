//! The pluggable scenario engine.
//!
//! A [`Scenario`] is one entry of the evaluation matrix: it expands an
//! [`ExperimentConfig`] into independent [`Cell`]s (the unit of
//! execution — one platform × ranks × size × rep point), runs a single
//! cell in isolation, and assembles the per-cell results back into
//! paper-style [`Figure`]s.  The split is what makes the matrix
//! parallelisable: cells share nothing, so the
//! [`MatrixRunner`](runner::MatrixRunner) can execute them across
//! worker threads and still produce bit-identical figures — assembly is
//! keyed on cell ids, never on completion order.
//!
//! All of the paper's figures (`fig1-scale`, `fig2`, `fig3`, `fig4`,
//! `fig5a`, `fig5b`) live here as scenario modules, next to scenarios
//! the paper discusses but never measures (`mixed-fleet`,
//! `build-farm`, `chaos-canary`, `registry-storm`, `version-churn`,
//! `dep-storm`).  Adding a new
//! experiment is a
//! [`ScenarioRegistry::register`] call away — the walkthrough lives in
//! `docs/ARCHITECTURE.md` §5.

pub mod build_farm;
pub mod chaos_canary;
pub mod dep_storm;
pub mod fig1_scale;
pub mod fig2;
pub mod fig34;
pub mod fig5;
pub mod mixed_fleet;
pub mod registry_storm;
pub mod runner;
pub mod version_churn;

pub use runner::MatrixRunner;

use std::any::Any;

use anyhow::Result;

use crate::bench::Figure;
use crate::config::ExperimentConfig;
use crate::fem::exec::Exec;
use crate::runtime::CalibrationTable;

/// Stable identity of one cell: which scenario expanded it and its
/// index in that expansion.  The identity — not the execution order —
/// is what seeds the cell's RNG streams and keys row assembly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CellId {
    /// Name of the scenario that expanded the cell.
    pub scenario: &'static str,
    /// Position in the scenario's cell expansion.
    pub index: usize,
}

impl CellId {
    /// Derive the cell's deterministic RNG seed from the experiment's
    /// base seed: FNV-1a over the scenario name and the little-endian
    /// cell index, folded with `base`.  Stable across runs, platforms,
    /// and `--jobs` settings; pinned by `tests/scenario_matrix.rs`.
    ///
    /// The five migrated paper figures keep their historical per-rep
    /// seeds (`cfg.seed + rep`, recorded in each cell's payload at
    /// expansion time) so their output stays bit-identical to the
    /// pre-refactor coordinator; new scenarios should draw from this
    /// hash instead — independent streams that cannot collide across
    /// scenarios or cells.
    pub fn seed(&self, base: u64) -> u64 {
        cell_seed(base, self.scenario, self.index)
    }
}

/// The FNV-1a `(scenario, cell-index)` seed derivation behind
/// [`CellId::seed`], usable before a [`Cell`] exists: the hash of the
/// scenario name and the little-endian index, folded with `base`.
pub fn cell_seed(base: u64, scenario: &str, index: usize) -> u64 {
    crate::util::rng::fnv1a(scenario.bytes().chain((index as u64).to_le_bytes())) ^ base
}

/// One independent point of a scenario's evaluation matrix.
///
/// The payload is scenario-private (each scenario downcasts its own
/// type back out in `run_cell`), so new scenarios plug in without
/// touching any shared enum.
pub struct Cell {
    /// Identity within the expansion (assigned by the runner).
    pub id: CellId,
    /// Human-readable cell description (diagnostics, error messages).
    pub label: String,
    payload: Box<dyn Any + Send + Sync>,
}

impl std::fmt::Debug for Cell {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Cell")
            .field("id", &self.id)
            .field("label", &self.label)
            .finish_non_exhaustive()
    }
}

impl Cell {
    /// A cell carrying a scenario-private `payload`.  The id is filled
    /// in by the runner when the expansion is enumerated.
    pub fn new<T: Any + Send + Sync>(label: impl Into<String>, payload: T) -> Self {
        Cell {
            id: CellId {
                scenario: "",
                index: 0,
            },
            label: label.into(),
            payload: Box::new(payload),
        }
    }

    /// Borrow the payload back as `T` (the type the owning scenario
    /// stored); errors if a foreign cell is handed to the wrong
    /// scenario.
    pub fn payload<T: Any>(&self) -> Result<&T> {
        self.payload.downcast_ref::<T>().ok_or_else(|| {
            anyhow::anyhow!(
                "cell `{}` carries a foreign payload (expected {})",
                self.label,
                std::any::type_name::<T>()
            )
        })
    }
}

/// Everything a cell needs to execute: the experiment config and the
/// calibration table driving modeled execution.  Shared read-only
/// across runner workers.
#[derive(Debug, Clone, Copy)]
pub struct SimContext<'a> {
    /// The experiment configuration being expanded.
    pub cfg: &'a ExperimentConfig,
    /// Calibration table for modeled execution costs.
    pub table: &'a CalibrationTable,
}

impl<'a> SimContext<'a> {
    /// A fresh modeled executor over the context's calibration table
    /// (one per cell — `Exec::Modeled` is stateless, so per-cell
    /// construction is free and keeps cells independent).
    pub fn exec(&self) -> Exec<'a> {
        Exec::Modeled { table: self.table }
    }
}

/// One cell's measured output, keyed by cell id for assembly.
#[derive(Debug, Clone)]
pub struct CellResult {
    /// Index of the cell that produced this result (assigned by the
    /// runner; assembly keys on this, never on completion order).
    pub cell: usize,
    /// Measured values; the meaning and count are scenario-specific
    /// (one run time, a cold/warm makespan pair, ...).
    pub values: Vec<f64>,
    /// Labelled secondary numbers (phase breakdowns, byte counts).
    pub breakdown: Vec<(String, f64)>,
}

impl CellResult {
    /// A single-value result.
    pub fn value(v: f64) -> Self {
        Self::values(vec![v])
    }

    /// A multi-value result.
    pub fn values(values: Vec<f64>) -> Self {
        CellResult {
            cell: 0,
            values,
            breakdown: Vec::new(),
        }
    }

    /// Attach a labelled breakdown.
    pub fn with_breakdown(mut self, breakdown: Vec<(String, f64)>) -> Self {
        self.breakdown = breakdown;
        self
    }

    /// The first (usually only) measured value.
    pub fn primary(&self) -> f64 {
        self.values.first().copied().unwrap_or(f64::NAN)
    }
}

/// One experiment family: a named expansion of the evaluation matrix.
///
/// Implementations must be stateless (`&self` everywhere) and `Sync` —
/// `run_cell` is called concurrently from runner workers.  Every
/// mutable thing a cell needs (RNG streams, filesystems, communicators)
/// is constructed inside `run_cell` from the context and the cell's
/// payload, which is what makes the matrix embarrassingly parallel and
/// the output independent of `--jobs`.
pub trait Scenario: Sync {
    /// Registry key and CLI name (`harbor bench <name>`).
    fn name(&self) -> &'static str;

    /// One-line description for `harbor bench --list` and the docs.
    fn describe(&self) -> &'static str;

    /// The scenario's default configuration (the paper's setup).
    fn default_config(&self) -> Result<ExperimentConfig> {
        ExperimentConfig::paper_default(self.name())
    }

    /// Expand `cfg` into independent cells, in deterministic order.
    /// Configuration validation belongs here — a bad config should fail
    /// before any cell runs.
    fn cells(&self, cfg: &ExperimentConfig) -> Result<Vec<Cell>>;

    /// Run one cell in isolation.  Must not depend on any other cell
    /// having run (no shared mutable state, no ordering assumptions).
    fn run_cell(&self, ctx: &SimContext<'_>, cell: &Cell) -> Result<CellResult>;

    /// Assemble per-cell results into rendered figures.  `cells` is the
    /// exact expansion the runner executed and `rows` its results, both
    /// in cell-id order (`cells[i]` produced `rows[i]`) — zip them to
    /// recover each result's coordinates; never re-expand.
    fn assemble(
        &self,
        ctx: &SimContext<'_>,
        cells: &[Cell],
        rows: Vec<CellResult>,
    ) -> Result<Vec<Figure>>;
}

/// The scenario catalogue: name → implementation, in registration
/// order.  The coordinator resolves `ExperimentConfig::figure` through
/// this, so the set of runnable experiments — and the names listed in
/// the "unknown figure" error — can never go stale.
pub struct ScenarioRegistry {
    entries: Vec<Box<dyn Scenario + Send + Sync>>,
}

impl ScenarioRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        ScenarioRegistry {
            entries: Vec::new(),
        }
    }

    /// Every built-in scenario: the paper's five figures plus the
    /// scenarios the paper discusses but never measures.
    pub fn builtin() -> Self {
        let mut r = Self::new();
        r.register(Box::new(fig1_scale::Fig1Scale));
        r.register(Box::new(fig2::Fig2));
        r.register(Box::new(fig34::Fig3));
        r.register(Box::new(fig34::Fig4));
        r.register(Box::new(fig5::Fig5 { workstation: true }));
        r.register(Box::new(fig5::Fig5 { workstation: false }));
        r.register(Box::new(mixed_fleet::MixedFleet));
        r.register(Box::new(build_farm::BuildFarmScenario));
        r.register(Box::new(chaos_canary::ChaosCanary));
        r.register(Box::new(registry_storm::RegistryStorm));
        r.register(Box::new(version_churn::VersionChurn));
        r.register(Box::new(dep_storm::DepStorm));
        r
    }

    /// Add a scenario.  Panics on a duplicate name — two scenarios
    /// answering to one CLI name is a programming error.
    pub fn register(&mut self, scenario: Box<dyn Scenario + Send + Sync>) {
        assert!(
            self.get(scenario.name()).is_none(),
            "scenario `{}` registered twice",
            scenario.name()
        );
        self.entries.push(scenario);
    }

    /// Look a scenario up by name.
    pub fn get(&self, name: &str) -> Option<&dyn Scenario> {
        self.entries
            .iter()
            .find(|s| s.name() == name)
            .map(|s| s.as_ref() as &dyn Scenario)
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|s| s.name()).collect()
    }

    /// `(name, description)` rows for `harbor bench --list` and the
    /// EXPERIMENTS.md figure index.
    pub fn table(&self) -> Vec<(&'static str, &'static str)> {
        self.entries.iter().map(|s| (s.name(), s.describe())).collect()
    }

    /// Iterate the registered scenarios in registration order.
    pub fn iter(&self) -> impl Iterator<Item = &dyn Scenario> {
        self.entries.iter().map(|s| s.as_ref() as &dyn Scenario)
    }

    /// Number of registered scenarios.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the registry is empty.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

impl Default for ScenarioRegistry {
    fn default() -> Self {
        Self::builtin()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_registry_has_all_figures_and_extras() {
        let r = ScenarioRegistry::builtin();
        assert_eq!(
            r.names(),
            vec![
                "fig1-scale",
                "fig2",
                "fig3",
                "fig4",
                "fig5a",
                "fig5b",
                "mixed-fleet",
                "build-farm",
                "chaos-canary",
                "registry-storm",
                "version-churn",
                "dep-storm"
            ]
        );
        assert!(r.get("fig2").is_some());
        assert!(r.get("fig9").is_none());
        assert_eq!(r.len(), 12);
        assert!(!r.is_empty());
    }

    #[test]
    fn every_builtin_has_a_default_config_and_description() {
        for s in ScenarioRegistry::builtin().iter() {
            let cfg = s.default_config().expect("default config");
            assert_eq!(cfg.figure, s.name());
            assert!(!s.describe().is_empty());
        }
    }

    #[test]
    #[should_panic(expected = "registered twice")]
    fn duplicate_registration_panics() {
        let mut r = ScenarioRegistry::builtin();
        r.register(Box::new(fig2::Fig2));
    }

    #[test]
    fn cell_payload_round_trips_and_rejects_foreign_types() {
        let cell = Cell::new("c", 42usize);
        assert_eq!(*cell.payload::<usize>().unwrap(), 42);
        assert!(cell.payload::<String>().is_err());
    }

    #[test]
    fn cell_seed_differs_by_scenario_and_index() {
        let a = cell_seed(42, "fig2", 0);
        let b = cell_seed(42, "fig2", 1);
        let c = cell_seed(42, "fig3", 0);
        assert_ne!(a, b);
        assert_ne!(a, c);
        // base folds in last, so the same cell under different base
        // seeds differs too
        assert_ne!(a, cell_seed(43, "fig2", 0));
        // and CellId::seed agrees with the free function
        let id = CellId {
            scenario: "fig2",
            index: 1,
        };
        assert_eq!(id.seed(42), b);
    }
}
