//! `build-farm` as a scenario: N CI workers building the §4.3
//! per-platform `ARCH_OPT` variant matrix against one shared layer
//! cache, pushing through the sharded registry.
//!
//! The paper's productivity argument (§2.2) rests on building the
//! FEniCS stack once as layered images; its §4.3 guidance implies a
//! *rebuild per host microarchitecture*.  At CI scale that is a build
//! farm: every (application × microarchitecture) variant is a
//! multi-stage buildfile whose early stages (toolchain, dependencies)
//! are shared across variants, so a shared content-addressed build
//! cache turns the matrix from `O(variants × stages)` work into
//! `O(distinct stages)`.
//!
//! The farm is a DES: worker-completion events go through one calendar
//! [`CellQueue`] (the initial wave enters as a `push_batch`; at
//! `--domains` > 1 completions are partitioned by worker index under
//! the WAN lookahead bound — see [`crate::des::pdes`]), each
//! build runs against a **fork** of the committed [`Builder`] cache
//! and is absorbed only at its completion instant (a build cannot hit
//! cache entries from builds that finish after it started), and each
//! finished image is pushed through a [`ShardedRegistry`] — blobs the
//! shared [`LayerCache`] already holds skip the WAN.  Between passes
//! the farm garbage-collects store layers no pushed image references
//! (the pruned non-terminal stages).
//!
//! Cell = one farm size; the cold pass vs the warm re-run of the same
//! matrix become the paper-style figure rows.
//!
//! [`CellQueue`]: crate::des::CellQueue

use std::collections::HashSet;

use anyhow::Result;

use crate::bench::{Figure, Row};
use crate::config::ExperimentConfig;
use crate::container::{
    BuildReport, Builder, Buildfile, CacheStats, LayerCache, LayerId, LayerStore, Registry,
    ShardedRegistry,
};
use crate::des::{CellQueue, Duration, QueueStats, VirtualTime};
use crate::metrics::Stats;
use crate::net::wan_lookahead;

use super::{Cell, CellResult, Scenario, SimContext};

/// Target microarchitectures the farm builds `ARCH_OPT` variants for
/// (the §4.3 "rebuild performance-critical binaries per host" axis).
pub const ARCHES: [&str; 4] = ["sandybridge", "haswell", "skylake", "knl"];

/// Application stacks the farm builds: (name, builder-stage packages).
pub const APPS: [(&str, &str); 3] = [
    ("poisson", "petsc"),
    ("hpgmg", "petsc hypre"),
    ("dolfin", "petsc slepc swig"),
];

/// The multi-stage buildfile of one (app, arch) variant: a toolchain
/// stage shared by every variant, a dependency stage shared by the
/// app's variants, an arch-specific compile stage, and a slim runtime
/// stage that copies the artifacts out and `ARCH_OPT`s the result —
/// the builder stages are pruned from the pushed image.
pub fn variant_buildfile(app: &str, pkgs: &str, arch: &str) -> String {
    format!(
        "FROM ubuntu:16.04 AS toolchain\n\
         RUN apt-get -y update && apt-get -y install build-essential gfortran cmake\n\
         FROM toolchain AS deps\n\
         RUN apt-get -y install {pkgs}\n\
         FROM deps AS build\n\
         RUN make -j ARCH={arch} {app}\n\
         FROM ubuntu:16.04\n\
         COPY --from=build /usr/local/{app} /opt/{app}\n\
         COPY --from=deps /usr/apt/config /opt/etc\n\
         ARCH_OPT\n\
         ENTRYPOINT /opt/{app}/bin/run --arch {arch}\n"
    )
}

/// The full variant matrix, in job order: `(tag, buildfile)` for every
/// application × microarchitecture pair.
pub fn variant_matrix() -> Result<Vec<(String, Buildfile)>> {
    let mut jobs = Vec::with_capacity(APPS.len() * ARCHES.len());
    for (app, pkgs) in APPS {
        for arch in ARCHES {
            let bf = Buildfile::parse(&variant_buildfile(app, pkgs, arch))
                .map_err(anyhow::Error::new)?;
            jobs.push((format!("local/{app}:{arch}"), bf));
        }
    }
    Ok(jobs)
}

/// Static description of a CI build farm.
#[derive(Debug, Clone)]
pub struct FarmConfig {
    /// Concurrent CI workers.
    pub workers: usize,
    /// Registry shard frontends the farm pushes through.
    pub shards: usize,
    /// Fixed per-job setup cost (checkout, context upload).
    pub setup: Duration,
    /// Per-directive cache-probe cost a build pays, hit or miss (what
    /// a fully warm build still costs).
    pub per_layer_probe: Duration,
    /// Lookahead domains for the completion scheduler (see
    /// [`crate::des::pdes`]): 1 runs the serial reference queue, more
    /// partitions completions by worker index under the WAN lookahead
    /// bound.  Renders are byte-identical for any value (`--domains`).
    pub domains: usize,
}

impl FarmConfig {
    /// A CI-fleet default: 4 registry shards, 500 ms job setup, 5 ms
    /// per-directive cache probe, serial scheduling.
    pub fn ci(workers: usize) -> Self {
        FarmConfig {
            workers,
            shards: 4,
            setup: Duration::from_millis(500),
            per_layer_probe: Duration::from_millis(5),
            domains: 1,
        }
    }
}

/// What one farm pass over a job matrix did.
#[derive(Debug, Clone)]
pub struct FarmPass {
    /// Jobs executed.
    pub jobs: usize,
    /// Span from the pass start until the last image was published.
    pub makespan: Duration,
    /// Layers built fresh across all jobs.
    pub layers_built: usize,
    /// Layers answered from the shared build cache.
    pub layers_cached: usize,
    /// Bytes pushed over the WAN (blob-cache misses only).
    pub wan_bytes: u64,
    /// WAN transfers performed.
    pub wan_transfers: usize,
    /// Shared blob-cache accounting for this pass only.
    pub cache: CacheStats,
    /// Calendar-queue counters of the pass's completion scheduler.
    pub queue: QueueStats,
    /// Images pushed to the registry.
    pub images_pushed: usize,
    /// Store layers garbage-collected after the pass (non-terminal
    /// stage layers no pushed image references).
    pub gc_layers: usize,
    /// Bytes freed by the garbage collection.
    pub gc_bytes: u64,
}

impl FarmPass {
    /// Build-cache hit rate: cached / (built + cached).
    pub fn build_hit_rate(&self) -> f64 {
        let total = self.layers_built + self.layers_cached;
        if total == 0 {
            0.0
        } else {
            self.layers_cached as f64 / total as f64
        }
    }
}

/// A CI build farm: a committed [`Builder`] cache, a shared
/// [`LayerStore`], a shared blob [`LayerCache`] in front of a
/// [`ShardedRegistry`], and a virtual clock that advances with each
/// [`run_pass`](BuildFarm::run_pass).
#[derive(Debug)]
pub struct BuildFarm {
    config: FarmConfig,
    builder: Builder,
    store: LayerStore,
    blob_cache: LayerCache,
    registry: ShardedRegistry,
    pushed: HashSet<LayerId>,
    clock: VirtualTime,
}

impl BuildFarm {
    /// A cold farm (empty caches) at virtual time zero.
    pub fn new(config: FarmConfig) -> Self {
        assert!(config.workers >= 1, "farm needs at least one worker");
        let registry = ShardedRegistry::new(Registry::new(), config.shards);
        BuildFarm {
            config,
            builder: Builder::new(),
            store: LayerStore::new(),
            blob_cache: LayerCache::unbounded(),
            registry,
            pushed: HashSet::new(),
            clock: VirtualTime::ZERO,
        }
    }

    /// The farm's configuration.
    pub fn config(&self) -> &FarmConfig {
        &self.config
    }

    /// The registry the farm pushes into.
    pub fn registry(&self) -> &ShardedRegistry {
        &self.registry
    }

    /// The shared layer store (after GC: pushed-image layers only).
    pub fn store(&self) -> &LayerStore {
        &self.store
    }

    /// The farm's virtual clock.
    pub fn now(&self) -> VirtualTime {
        self.clock
    }

    /// Run one pass over `jobs` on the farm's workers, in virtual
    /// time, and garbage-collect the store afterwards.  Passes share
    /// the build and blob caches — that is the point: the second pass
    /// over the same matrix is warm.
    pub fn run_pass(&mut self, jobs: &[(String, Buildfile)]) -> Result<FarmPass> {
        let t0 = self.clock;
        let workers = self.config.workers;
        let cache_before = self.blob_cache.stats();
        let mut queue: CellQueue<usize> =
            CellQueue::new(self.config.domains, wan_lookahead(), workers);
        let mut pending: Vec<Option<(Builder, BuildReport)>> =
            (0..workers).map(|_| None).collect();
        let mut next_job = 0usize;
        let mut finish = t0;
        let mut layers_built = 0usize;
        let mut layers_cached = 0usize;
        let mut wan_bytes = 0u64;
        let mut wan_transfers = 0usize;
        let mut images_pushed = 0usize;

        // initial wave: one job per idle worker, entering the calendar
        // queue as a single batch
        let mut batch = Vec::with_capacity(workers.min(jobs.len()));
        for worker in 0..workers.min(jobs.len()) {
            let done = self.start_job(&jobs[next_job], t0, worker, &mut pending)?;
            batch.push((worker, done, worker));
            next_job += 1;
        }
        queue.push_batch(batch);

        while let Some((now, worker)) = queue.pop() {
            // commit the worker's build: absorb its cache fork, then
            // push the image — blobs the shared cache holds skip the WAN
            let (fork, report) = pending[worker].take().expect("worker had a job");
            self.builder.absorb(fork);
            layers_built += report.layers_built;
            layers_cached += report.layers_cached;
            let mut publish = now;
            for id in self.blob_cache.filter_missing(&report.image.layers) {
                let blob = self.store.get(&id).expect("built layers are stored").blob();
                let done = self.registry.submit_transfer(now, &id, blob.bytes);
                wan_bytes += blob.bytes;
                wan_transfers += 1;
                publish = publish.max(done);
                self.blob_cache.admit(blob);
            }
            self.registry.push(&report.image, &self.store)?;
            self.pushed.extend(report.image.layers.iter().cloned());
            images_pushed += 1;
            finish = finish.max(publish);

            if next_job < jobs.len() {
                let done = self.start_job(&jobs[next_job], now, worker, &mut pending)?;
                queue.push(worker, done, worker);
                next_job += 1;
            }
        }

        let queue_stats = queue.stats();
        self.clock = finish;
        let pushed = std::mem::take(&mut self.pushed);
        let (gc_layers, gc_bytes) = self.store.retain(|id| pushed.contains(id));
        self.pushed = pushed;

        Ok(FarmPass {
            jobs: jobs.len(),
            makespan: finish.since(t0),
            layers_built,
            layers_cached,
            wan_bytes,
            wan_transfers,
            cache: self.blob_cache.stats().since(&cache_before),
            queue: queue_stats,
            images_pushed,
            gc_layers,
            gc_bytes,
        })
    }

    /// Start one job on `worker` at `now`: build against a fork of the
    /// committed cache (commit happens at completion) and return the
    /// completion instant — setup, the stage DAG's critical path (farm
    /// workers run independent stages concurrently), and the
    /// per-directive cache probes.
    fn start_job(
        &mut self,
        job: &(String, Buildfile),
        now: VirtualTime,
        worker: usize,
        pending: &mut [Option<(Builder, BuildReport)>],
    ) -> Result<VirtualTime> {
        let (tag, bf) = job;
        let mut fork = self.builder.fork();
        let report = fork.build(bf, tag, &mut self.store)?;
        let probes = (report.layers_built + report.layers_cached) as u64;
        let done = now
            + self.config.setup
            + report.critical_path
            + self.config.per_layer_probe * probes;
        pending[worker] = Some((fork, report));
        Ok(done)
    }
}

/// The CI build-farm scenario.
pub struct BuildFarmScenario;

/// One farm-size cell.
#[derive(Debug, Clone, Copy)]
struct FarmCell {
    workers: usize,
}

impl Scenario for BuildFarmScenario {
    fn name(&self) -> &'static str {
        "build-farm"
    }

    fn describe(&self) -> &'static str {
        "CI fleet building the §4.3 per-platform ARCH_OPT variant matrix \
         (multi-stage buildfiles) on 1-16 workers with one shared layer \
         cache, pushing through 4 registry shards; cold vs warm farm \
         makespan and cache-hit ratios"
    }

    fn cells(&self, cfg: &ExperimentConfig) -> Result<Vec<Cell>> {
        anyhow::ensure!(
            !cfg.nodes.is_empty(),
            "build-farm needs at least one worker count in `nodes`"
        );
        anyhow::ensure!(
            cfg.nodes.iter().all(|&n| n >= 1),
            "build-farm worker counts must be >= 1 (got {:?})",
            cfg.nodes
        );
        Ok(cfg
            .nodes
            .iter()
            .map(|&workers| {
                Cell::new(format!("build-farm {workers} workers"), FarmCell { workers })
            })
            .collect())
    }

    fn run_cell(&self, ctx: &SimContext<'_>, cell: &Cell) -> Result<CellResult> {
        let c: &FarmCell = cell.payload()?;
        let jobs = variant_matrix()?;
        let mut farm = BuildFarm::new(FarmConfig {
            domains: ctx.cfg.domains,
            ..FarmConfig::ci(c.workers)
        });
        let cold = farm.run_pass(&jobs)?;
        let warm = farm.run_pass(&jobs)?;
        // breakdown keys carry a structural "cold:"/"warm:" tag so
        // assembly routes them to the right figure (as fig1-scale does)
        Ok(CellResult::values(vec![
            cold.makespan.as_secs_f64(),
            warm.makespan.as_secs_f64(),
        ])
        .with_breakdown(vec![
            ("cold:build cache hit rate".into(), cold.build_hit_rate()),
            ("cold:wan MB".into(), cold.wan_bytes as f64 / 1e6),
            ("cold:gc MB".into(), cold.gc_bytes as f64 / 1e6),
            ("warm:build cache hit rate".into(), warm.build_hit_rate()),
            ("warm:wan MB".into(), warm.wan_bytes as f64 / 1e6),
        ]))
    }

    fn assemble(
        &self,
        ctx: &SimContext<'_>,
        _cells: &[Cell],
        rows: Vec<CellResult>,
    ) -> Result<Vec<Figure>> {
        let mut cold_fig = Figure::new(
            "Build farm — cold pass makespan (12-variant ARCH_OPT matrix)",
            "makespan [s]",
            false,
        );
        let mut warm_fig = Figure::new(
            "Build farm — warm re-run makespan (shared caches)",
            "makespan [s]",
            false,
        );
        let mut worst_ratio = 0.0f64;
        for r in &rows {
            let workers = ctx.cfg.nodes[r.cell];
            let (cold_s, warm_s) = (r.values[0], r.values[1]);
            worst_ratio = worst_ratio.max(warm_s / cold_s);
            let part = |prefix: &str| -> Vec<(String, f64)> {
                r.breakdown
                    .iter()
                    .filter_map(|(k, v)| k.strip_prefix(prefix).map(|k| (k.to_string(), *v)))
                    .collect()
            };
            cold_fig.push(
                Row::new(format!("{workers} workers"), Stats::from_samples(vec![cold_s]))
                    .with_breakdown(part("cold:")),
            );
            warm_fig.push(
                Row::new(format!("{workers} workers"), Stats::from_samples(vec![warm_s]))
                    .with_breakdown(part("warm:")),
            );
        }
        cold_fig.note(
            "shared toolchain/deps stages hit the farm-wide build cache; only \
             terminal-stage blobs cross the WAN (non-terminal stages pruned)",
        );
        warm_fig.note(format!(
            "warm/cold makespan ratio {worst_ratio:.5} (acceptance bar: < 0.10)"
        ));
        Ok(vec![cold_fig, warm_fig])
    }
}
