//! Fig 5 as scenarios: HPGMG-FE throughput on the workstation (5a) and
//! on Edison (5b), swept over problem sizes.
//!
//! Cell = (size, platform, rep); one figure per problem size, one row
//! per platform, DOF/s on the y-axis (higher is better) — bit-identical
//! to the pre-scenario coordinator.

use anyhow::Result;

use crate::bench::{Figure, RowSet};
use crate::config::{ExperimentConfig, MatrixPoint};
use crate::platform::Platform;
use crate::workload::{run_hpgmg, HpgmgConfig};

use super::{Cell, CellResult, Scenario, SimContext};

/// The Fig 5 scenario pair: `workstation == true` is 5a, else 5b.
pub struct Fig5 {
    /// 16-core workstation (5a) vs Edison at 192 cores (5b).
    pub workstation: bool,
}

/// One HPGMG cell.
#[derive(Debug, Clone, Copy)]
struct HpgmgCell {
    workstation: bool,
    ranks: usize,
    point: MatrixPoint,
}

impl Fig5 {
    fn platforms(&self) -> Vec<Platform> {
        if self.workstation {
            vec![Platform::Docker, Platform::Rkt, Platform::Native]
        } else {
            vec![Platform::Native, Platform::ShifterSystemMpi]
        }
    }
}

impl Scenario for Fig5 {
    fn name(&self) -> &'static str {
        if self.workstation {
            "fig5a"
        } else {
            "fig5b"
        }
    }

    fn describe(&self) -> &'static str {
        if self.workstation {
            "Fig 5a (§4) — HPGMG-FE throughput on the 16-core workstation"
        } else {
            "Fig 5b (§4) — HPGMG-FE throughput on Edison at 192 cores"
        }
    }

    fn cells(&self, cfg: &ExperimentConfig) -> Result<Vec<Cell>> {
        anyhow::ensure!(
            !cfg.ranks.is_empty(),
            "{} needs a rank count in `ranks`",
            self.name()
        );
        anyhow::ensure!(
            !cfg.sizes.is_empty(),
            "{} needs at least one problem-size index in `sizes`",
            self.name()
        );
        let ranks = cfg.ranks[0];
        Ok(cfg
            .expand(&self.platforms(), &[], &cfg.sizes)
            .into_iter()
            .map(|point| {
                Cell::new(
                    format!(
                        "{} size {} / {} / rep {}",
                        self.name(),
                        point.size,
                        point.platform.label(),
                        point.rep
                    ),
                    HpgmgCell {
                        workstation: self.workstation,
                        ranks,
                        point,
                    },
                )
            })
            .collect())
    }

    fn run_cell(&self, ctx: &SimContext<'_>, cell: &Cell) -> Result<CellResult> {
        let c: &HpgmgCell = cell.payload()?;
        let mut exec = ctx.exec();
        let mut hc = if c.workstation {
            HpgmgConfig::workstation(c.point.size, c.point.seed)
        } else {
            HpgmgConfig::edison(c.point.size, c.point.seed)
        };
        hc.ranks = c.ranks;
        hc.batched = ctx.cfg.batched;
        let result = run_hpgmg(c.point.platform, &mut exec, &hc)?;
        Ok(CellResult::value(result.dofs_per_second))
    }

    fn assemble(
        &self,
        ctx: &SimContext<'_>,
        cells: &[Cell],
        rows: Vec<CellResult>,
    ) -> Result<Vec<Figure>> {
        let mut sets: Vec<RowSet> = (0..ctx.cfg.sizes.len()).map(|_| RowSet::new()).collect();
        for (cell, r) in cells.iter().zip(&rows) {
            let c: &HpgmgCell = cell.payload()?;
            sets[c.point.size_idx].add_sample(
                c.point.platform_idx as u64,
                c.point.platform.label(),
                c.point.rep as u64,
                r.primary(),
            );
        }
        let which = if self.workstation {
            "5a — 16-core workstation"
        } else {
            "5b — Edison, 192 cores"
        };
        let mut figures = Vec::new();
        for (size_idx, set) in sets.into_iter().enumerate() {
            let size = ctx.cfg.sizes[size_idx];
            let dofs_per_rank = crate::fem::gmg::LADDER[size].pow(3);
            let mut fig = Figure::new(
                format!("Fig {which}: HPGMG-FE, {dofs_per_rank} DOF/rank"),
                "DOF/s",
                true,
            );
            for row in set.into_rows() {
                fig.push(row);
            }
            figures.push(fig);
        }
        Ok(figures)
    }
}
