//! `fig1-scale` as a scenario: the Fig 1 deployment phase at fleet
//! scale — one image pulled onto N nodes through the sharded registry,
//! cold and warm.
//!
//! Cell = one fleet size (each cell builds its own registry and fleet,
//! so cells stay independent); assembly produces the cold/warm figure
//! pair with the same breakdowns and notes the pre-scenario
//! coordinator emitted.

use anyhow::Result;

use crate::bench::{Figure, Row};
use crate::config::ExperimentConfig;
use crate::container::{DeployEngine, FleetConfig};
use crate::coordinator::fleet_registry;
use crate::metrics::Stats;
use crate::util::human;

use super::{Cell, CellResult, Scenario, SimContext};

/// The fleet-scale deployment scenario.
pub struct Fig1Scale;

/// One fleet-size cell.
#[derive(Debug, Clone, Copy)]
struct FleetCell {
    nodes: usize,
}

/// Image reference every fleet deployment pulls.
const REFERENCE: &str = "quay.io/fenicsproject/stable:2016.1.0r1";

impl Scenario for Fig1Scale {
    fn name(&self) -> &'static str {
        "fig1-scale"
    }

    fn describe(&self) -> &'static str {
        "Fig 1 workflow (§3.4) at fleet scale — one image pulled onto 64 to \
         1,048,576 nodes through 4 registry shards with node-local caches and \
         peer fan-out; cold pull vs warm re-deploy makespan (node-class \
         collapsed engine; --per-rank forces the per-node reference)"
    }

    fn cells(&self, cfg: &ExperimentConfig) -> Result<Vec<Cell>> {
        anyhow::ensure!(
            !cfg.nodes.is_empty(),
            "fig1-scale needs at least one fleet size in `nodes`"
        );
        anyhow::ensure!(
            cfg.nodes.iter().all(|&n| n >= 1),
            "fig1-scale fleet sizes must be >= 1 (got {:?})",
            cfg.nodes
        );
        Ok(cfg
            .nodes
            .iter()
            .map(|&nodes| {
                Cell::new(
                    format!("fig1-scale {} nodes", human::thousands(nodes as u64)),
                    FleetCell { nodes },
                )
            })
            .collect())
    }

    fn run_cell(&self, ctx: &SimContext<'_>, cell: &Cell) -> Result<CellResult> {
        let c: &FleetCell = cell.payload()?;
        let mut sharded = fleet_registry(REFERENCE)?;
        // batched (the default) = the collapsed node-class engine;
        // --per-rank opts into the per-node reference walk (feasible
        // up to the 16k rows, used by the CI golden-diff gate)
        let mut fleet = DeployEngine::new(
            FleetConfig {
                domains: ctx.cfg.domains,
                ..FleetConfig::hpc(c.nodes)
            },
            ctx.cfg.batched,
        );
        let cold = fleet.deploy(&mut sharded, REFERENCE)?;
        let warm = fleet.deploy(&mut sharded, REFERENCE)?;
        // breakdown keys carry a structural "cold:"/"warm:" tag so
        // assembly routes them to the right figure without guessing
        // from metric names; the prefix is stripped before rendering
        Ok(CellResult::values(vec![
            cold.makespan.as_secs_f64(),
            warm.makespan.as_secs_f64(),
        ])
        .with_breakdown(vec![
            ("cold:wan MB".into(), cold.wan_bytes as f64 / 1e6),
            ("cold:intra MB".into(), cold.intra_bytes as f64 / 1e6),
            ("warm:cache hit rate".into(), warm.cache.hit_rate()),
        ]))
    }

    fn assemble(
        &self,
        ctx: &SimContext<'_>,
        _cells: &[Cell],
        rows: Vec<CellResult>,
    ) -> Result<Vec<Figure>> {
        let mut cold_fig = Figure::new(
            "Fig 1 at fleet scale — cold pull makespan",
            "makespan [s]",
            false,
        );
        let mut warm_fig = Figure::new(
            "Fig 1 at fleet scale — warm re-deploy makespan",
            "makespan [s]",
            false,
        );
        let mut worst_ratio = 0.0f64;
        for r in &rows {
            let nodes = ctx.cfg.nodes[r.cell];
            let label = format!("{} nodes", human::thousands(nodes as u64));
            let (cold_s, warm_s) = (r.values[0], r.values[1]);
            worst_ratio = worst_ratio.max(warm_s / cold_s);
            let part = |prefix: &str| -> Vec<(String, f64)> {
                r.breakdown
                    .iter()
                    .filter_map(|(k, v)| k.strip_prefix(prefix).map(|k| (k.to_string(), *v)))
                    .collect()
            };
            cold_fig.push(
                Row::new(label.clone(), Stats::from_samples(vec![cold_s]))
                    .with_breakdown(part("cold:")),
            );
            warm_fig.push(
                Row::new(label, Stats::from_samples(vec![warm_s])).with_breakdown(part("warm:")),
            );
        }
        cold_fig.note(
            "each unique layer crosses the WAN once (4 shards), then peer fan-out \
             (arity 2) over the Aries fabric",
        );
        warm_fig.note(format!(
            "warm/cold makespan ratio {worst_ratio:.5} (acceptance bar: < 0.10)"
        ));
        Ok(vec![cold_fig, warm_fig])
    }
}
