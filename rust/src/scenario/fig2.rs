//! Fig 2 as a scenario: the four single-process workstation tests
//! across native / Docker / rkt / VM.
//!
//! Cell = (test, platform, rep); one figure per test, one row per
//! platform, `reps` samples per row.  Output is bit-identical to the
//! pre-scenario coordinator (same per-rep seeds, same nested order).

use anyhow::Result;

use crate::bench::{Figure, RowSet};
use crate::config::{ExperimentConfig, MatrixPoint};
use crate::platform::Platform;
use crate::workload::{run_fig2, Fig2Test};

use super::{Cell, CellResult, Scenario, SimContext};

/// The Fig 2 scenario.
pub struct Fig2;

/// One Fig 2 cell: which test, on which platform, which repetition.
#[derive(Debug, Clone, Copy)]
struct Fig2Cell {
    test_idx: usize,
    test: Fig2Test,
    point: MatrixPoint,
}

impl Scenario for Fig2 {
    fn name(&self) -> &'static str {
        "fig2"
    }

    fn describe(&self) -> &'static str {
        "Fig 2 (§4) — workstation benchmarks (Poisson LU/AMG, I/O, elasticity) \
         across native / Docker / rkt / VirtualBox"
    }

    fn cells(&self, cfg: &ExperimentConfig) -> Result<Vec<Cell>> {
        let mut cells = Vec::new();
        for (test_idx, &test) in Fig2Test::ALL.iter().enumerate() {
            for point in cfg.expand(&Platform::workstation_set(), &[], &[]) {
                cells.push(Cell::new(
                    format!(
                        "fig2 {} / {} / rep {}",
                        test.label(),
                        point.platform.label(),
                        point.rep
                    ),
                    Fig2Cell {
                        test_idx,
                        test,
                        point,
                    },
                ));
            }
        }
        Ok(cells)
    }

    fn run_cell(&self, ctx: &SimContext<'_>, cell: &Cell) -> Result<CellResult> {
        let c: &Fig2Cell = cell.payload()?;
        let mut exec = ctx.exec();
        let t = run_fig2(c.test, c.point.platform, &mut exec, c.point.seed)?;
        Ok(CellResult::value(t.as_secs_f64()))
    }

    fn assemble(
        &self,
        ctx: &SimContext<'_>,
        cells: &[Cell],
        rows: Vec<CellResult>,
    ) -> Result<Vec<Figure>> {
        let mut sets: Vec<RowSet> = (0..Fig2Test::ALL.len()).map(|_| RowSet::new()).collect();
        for (cell, r) in cells.iter().zip(&rows) {
            let c: &Fig2Cell = cell.payload()?;
            sets[c.test_idx].add_sample(
                c.point.platform_idx as u64,
                c.point.platform.label(),
                c.point.rep as u64,
                r.primary(),
            );
        }
        let mut figures = Vec::new();
        for (test, set) in Fig2Test::ALL.iter().zip(sets) {
            let mut fig = Figure::new(
                format!("Fig 2 — {} (workstation)", test.label()),
                "run time [s]",
                false,
            );
            for row in set.into_rows() {
                fig.push(row);
            }
            fig.note(format!("calibration: {}", ctx.table.source));
            figures.push(fig);
        }
        Ok(figures)
    }
}
