//! Figs 3 and 4 as scenarios: the Edison Poisson app (C++ and Python
//! drivers) swept over MPI rank counts.
//!
//! Cell = (ranks, platform, rep); one figure per rank count, one row
//! per platform with the repetition-0 phase breakdown attached —
//! exactly the pre-scenario coordinator's shape, bit for bit.

use anyhow::Result;

use crate::bench::{Figure, RowSet};
use crate::config::{ExperimentConfig, MatrixPoint};
use crate::platform::Platform;
use crate::workload::{run_poisson_app, AppConfig};

use super::{Cell, CellResult, Scenario, SimContext};

/// Fig 3: the C++ driver (no import phase).
pub struct Fig3;

/// Fig 4: the Python driver (the import problem).
pub struct Fig4;

/// One poisson-app cell.
#[derive(Debug, Clone, Copy)]
struct AppCell {
    python: bool,
    point: MatrixPoint,
}

fn app_cells(cfg: &ExperimentConfig, python: bool, platforms: &[Platform]) -> Result<Vec<Cell>> {
    anyhow::ensure!(
        !cfg.ranks.is_empty(),
        "fig{} needs at least one rank count in `ranks`",
        if python { 4 } else { 3 }
    );
    Ok(cfg
        .expand(platforms, &cfg.ranks, &[])
        .into_iter()
        .map(|point| {
            Cell::new(
                format!(
                    "fig{} ranks {} / {} / rep {}",
                    if python { 4 } else { 3 },
                    point.ranks,
                    point.platform.label(),
                    point.rep
                ),
                AppCell { python, point },
            )
        })
        .collect())
}

fn run_app_cell(ctx: &SimContext<'_>, cell: &Cell) -> Result<CellResult> {
    let c: &AppCell = cell.payload()?;
    let mut exec = ctx.exec();
    let mut app = if c.python {
        AppConfig::python(c.point.ranks, c.point.seed)
    } else {
        AppConfig::cpp(c.point.ranks, c.point.seed)
    };
    app.batched = ctx.cfg.batched;
    let b = run_poisson_app(c.point.platform, &mut exec, &app)?;
    let breakdown = b
        .phase_names()
        .iter()
        .map(|p| (p.clone(), b.get(p)))
        .collect();
    Ok(CellResult::value(b.total()).with_breakdown(breakdown))
}

fn assemble_app(
    ctx: &SimContext<'_>,
    cells: &[Cell],
    rows: Vec<CellResult>,
    title: impl Fn(usize) -> String,
    note: impl Fn(usize) -> Option<String>,
) -> Result<Vec<Figure>> {
    let mut sets: Vec<RowSet> = (0..ctx.cfg.ranks.len()).map(|_| RowSet::new()).collect();
    for (cell, r) in cells.iter().zip(&rows) {
        let c: &AppCell = cell.payload()?;
        let set = &mut sets[c.point.ranks_idx];
        set.add_sample(
            c.point.platform_idx as u64,
            c.point.platform.label(),
            c.point.rep as u64,
            r.primary(),
        );
        if c.point.rep == 0 {
            set.set_breakdown(c.point.platform_idx as u64, r.breakdown.clone());
        }
    }
    let mut figures = Vec::new();
    for (ranks_idx, set) in sets.into_iter().enumerate() {
        let ranks = ctx.cfg.ranks[ranks_idx];
        let mut fig = Figure::new(title(ranks), "run time [s]", false);
        for row in set.into_rows() {
            fig.push(row);
        }
        if let Some(n) = note(ranks) {
            fig.note(n);
        }
        figures.push(fig);
    }
    Ok(figures)
}

impl Scenario for Fig3 {
    fn name(&self) -> &'static str {
        "fig3"
    }

    fn describe(&self) -> &'static str {
        "Fig 3 (§4) — C++ Poisson solver on Edison at 24-192 ranks: native vs \
         Shifter+host-MPI vs container MPI (TCP fallback blow-up)"
    }

    fn cells(&self, cfg: &ExperimentConfig) -> Result<Vec<Cell>> {
        app_cells(cfg, false, &Platform::edison_cpp_set())
    }

    fn run_cell(&self, ctx: &SimContext<'_>, cell: &Cell) -> Result<CellResult> {
        run_app_cell(ctx, cell)
    }

    fn assemble(
        &self,
        ctx: &SimContext<'_>,
        cells: &[Cell],
        rows: Vec<CellResult>,
    ) -> Result<Vec<Figure>> {
        assemble_app(
            ctx,
            cells,
            rows,
            |ranks| format!("Fig 3 — C++ benchmark, Edison, {ranks} MPI processes"),
            |ranks| {
                (ranks > 96).then(|| {
                    "container-MPI bar is off-scale in the paper (truncated x-axis)".to_string()
                })
            },
        )
    }
}

impl Scenario for Fig4 {
    fn name(&self) -> &'static str {
        "fig4"
    }

    fn describe(&self) -> &'static str {
        "Fig 4 (§4) — Python Poisson on Edison: the import problem; containers \
         beat native via fewer metadata RPCs"
    }

    fn cells(&self, cfg: &ExperimentConfig) -> Result<Vec<Cell>> {
        app_cells(cfg, true, &Platform::edison_python_set())
    }

    fn run_cell(&self, ctx: &SimContext<'_>, cell: &Cell) -> Result<CellResult> {
        run_app_cell(ctx, cell)
    }

    fn assemble(
        &self,
        ctx: &SimContext<'_>,
        cells: &[Cell],
        rows: Vec<CellResult>,
    ) -> Result<Vec<Figure>> {
        assemble_app(
            ctx,
            cells,
            rows,
            |ranks| format!("Fig 4 — Python benchmark, Edison, {ranks} MPI processes"),
            |_| {
                Some(
                    "native total dominated by the Python import phase (MDS contention)"
                        .to_string(),
                )
            },
        )
    }
}
