//! `dep-storm`: a cold-resolve storm of N randomly drawn manifests,
//! resolved, fetched through one shared package cache, and built
//! through the CI farm.
//!
//! The paper's §2.2 productivity story is one curated stack; a real
//! registry serves *many* stack authors at once, each declaring a
//! different slice of the package universe.  This scenario generates N
//! root manifests over the FEniCS [`fenics_index`] universe (1–3 root
//! dependencies each, caret/tilde ranges anchored at published
//! versions), resolves them all, materialises every pinned package
//! through one shared content-addressed [`PackageCache`], and feeds the
//! emitted buildfiles through a [`BuildFarm`] pass — measuring what the
//! resolver tier amortises: package-cache hit rate, build-cache hit
//! rate, and the farm makespan for the whole storm.
//!
//! Manifests that cannot resolve (e.g. a root pinned to `openmpi 2.x`
//! colliding with the PETSc chain's `^1.10.0`) are counted, not
//! retried: conflict reporting is part of the resolver's contract and
//! the count is deterministic for a given cell seed.
//!
//! Cell = one storm size from `cfg.nodes` ([`STORM_MANIFESTS`] by
//! default).  Everything is seeded from
//! [`CellId::seed`](super::CellId::seed); the figure renders
//! byte-identically at every `--jobs` setting, which CI gates.
//!
//! [`STORM_MANIFESTS`]: crate::config::STORM_MANIFESTS

use std::collections::BTreeSet;

use anyhow::Result;

use crate::bench::{Figure, Row};
use crate::config::ExperimentConfig;
use crate::container::resolve::{
    emit_stack_buildfile, fenics_index, resolve, Dependency, Lockfile, Manifest, PackageCache,
    PackageIndex, Range, Version, STACK_BASE,
};
use crate::container::Buildfile;
use crate::des::SimRng;
use crate::metrics::Stats;

use super::build_farm::{BuildFarm, FarmConfig};
use super::{Cell, CellResult, Scenario, SimContext};

/// CI workers the storm's farm pass runs on (the farm-size sweep
/// belongs to `build-farm`; here the swept axis is the manifest count).
pub const STORM_WORKERS: usize = 4;

/// The cold-resolve storm scenario.
pub struct DepStorm;

/// Draw one random root manifest over `index`: 1–3 distinct root
/// dependencies, each a caret or tilde range anchored at a published
/// version of the package.
fn random_manifest(i: usize, index: &PackageIndex, rng: &mut SimRng) -> Manifest {
    let names = index.names();
    let mut manifest = Manifest::new(&format!("stack-{i:03}"), Version::new(1, 0, 0));
    let want = 1 + rng.index(3);
    let mut chosen: BTreeSet<&str> = BTreeSet::new();
    while chosen.len() < want {
        let name = names[rng.index(names.len())];
        if !chosen.insert(name) {
            continue;
        }
        let versions = index.versions(name);
        let anchor = versions[rng.index(versions.len())];
        let range = if rng.uniform(0.0, 1.0) < 0.5 {
            Range::caret(anchor)
        } else {
            Range::tilde(anchor)
        };
        manifest.deps.push(Dependency {
            name: name.to_string(),
            range,
        });
    }
    manifest
}

impl Scenario for DepStorm {
    fn name(&self) -> &'static str {
        "dep-storm"
    }

    fn describe(&self) -> &'static str {
        "cold-resolve storm: N randomly drawn manifests over the FEniCS \
         package universe, resolved and pinned, packages fetched through \
         one shared content-addressed cache, emitted buildfiles run \
         through a CI farm pass; reports resolve conflicts, cache hit \
         rates, and the storm makespan"
    }

    fn cells(&self, cfg: &ExperimentConfig) -> Result<Vec<Cell>> {
        anyhow::ensure!(
            !cfg.nodes.is_empty(),
            "dep-storm needs at least one manifest count in `nodes`"
        );
        anyhow::ensure!(
            cfg.nodes.iter().all(|&n| n >= 1),
            "dep-storm manifest counts must be >= 1 (got {:?})",
            cfg.nodes
        );
        Ok(cfg
            .nodes
            .iter()
            .map(|&n| Cell::new(format!("{n} manifests"), n))
            .collect())
    }

    fn run_cell(&self, ctx: &SimContext<'_>, cell: &Cell) -> Result<CellResult> {
        let n: usize = *cell.payload()?;
        let seed = cell.id.seed(ctx.cfg.seed);
        let index = fenics_index();
        let mut rng = SimRng::new(seed, "dep-storm-manifests");

        let mut packages = PackageCache::new();
        let mut jobs = Vec::with_capacity(n);
        let mut unresolvable = 0usize;
        let mut pinned_total = 0usize;
        for i in 0..n {
            let manifest = random_manifest(i, &index, &mut rng);
            match resolve(&manifest, &index, seed ^ i as u64) {
                Ok(res) => {
                    let lock = Lockfile::from_resolution(&res, &index);
                    for (name, p) in &lock.packages {
                        packages.fetch(name, p.version);
                    }
                    pinned_total += lock.packages.len();
                    let text = emit_stack_buildfile(&manifest, &lock, STACK_BASE, None)?;
                    let bf = Buildfile::parse(&text).map_err(anyhow::Error::new)?;
                    jobs.push((format!("local/{}", manifest.name), bf));
                }
                Err(_) => unresolvable += 1,
            }
        }
        anyhow::ensure!(
            !jobs.is_empty(),
            "a storm where nothing resolves builds nothing ({unresolvable}/{n} conflicts)"
        );

        let mut farm = BuildFarm::new(FarmConfig::ci(STORM_WORKERS));
        let pass = farm.run_pass(&jobs)?;
        let makespan = pass.makespan.as_secs_f64();

        Ok(
            CellResult::values(vec![makespan, jobs.len() as f64]).with_breakdown(vec![
                ("manifests".into(), n as f64),
                ("resolved".into(), jobs.len() as f64),
                ("unresolvable".into(), unresolvable as f64),
                ("packages pinned".into(), pinned_total as f64),
                ("pkg cache hit rate".into(), packages.hit_rate()),
                ("pkg blobs resident".into(), packages.len() as f64),
                ("pkg store dedup x".into(), packages.store().dedup_ratio()),
                ("farm layers built".into(), pass.layers_built as f64),
                ("farm layers cached".into(), pass.layers_cached as f64),
                ("build hit rate".into(), pass.build_hit_rate()),
                ("images pushed".into(), pass.images_pushed as f64),
                ("wan MB".into(), pass.wan_bytes as f64 / 1e6),
            ]),
        )
    }

    fn assemble(
        &self,
        _ctx: &SimContext<'_>,
        cells: &[Cell],
        rows: Vec<CellResult>,
    ) -> Result<Vec<Figure>> {
        let mut fig = Figure::new(
            "Dep storm — cold-resolve storm makespan through the CI farm",
            "farm makespan [virtual s]",
            false,
        );
        for r in &rows {
            fig.push(
                Row::new(cells[r.cell].label.clone(), Stats::from_samples(vec![r.values[0]]))
                    .with_breakdown(r.breakdown.clone()),
            );
        }
        fig.note(
            "manifests draw 1-3 caret/tilde root ranges over the FEniCS \
             universe; unresolvable draws are counted, not retried; the \
             shared package cache and build cache amortise the storm, so \
             makespan grows sublinearly in the manifest count",
        );
        Ok(vec![fig])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::CalibrationTable;
    use crate::scenario::CellId;

    fn run(n: usize, index: usize) -> CellResult {
        let cfg = ExperimentConfig::paper_default("dep-storm").unwrap();
        let table = CalibrationTable::builtin_fallback();
        let ctx = SimContext {
            cfg: &cfg,
            table: &table,
        };
        let mut cell = Cell::new(format!("{n} manifests"), n);
        cell.id = CellId {
            scenario: "dep-storm",
            index,
        };
        DepStorm.run_cell(&ctx, &cell).unwrap()
    }

    fn stat(r: &CellResult, key: &str) -> f64 {
        r.breakdown
            .iter()
            .find(|(k, _)| k == key)
            .map(|&(_, v)| v)
            .unwrap()
    }

    #[test]
    fn cells_follow_the_configured_manifest_counts() {
        let cfg = ExperimentConfig::paper_default("dep-storm").unwrap();
        let cells = DepStorm.cells(&cfg).unwrap();
        assert_eq!(cells.len(), cfg.nodes.len());
        assert!(cells[0].label.ends_with("manifests"));
        assert!(DepStorm
            .cells(&ExperimentConfig {
                nodes: vec![],
                ..cfg.clone()
            })
            .is_err());
        assert!(DepStorm
            .cells(&ExperimentConfig {
                nodes: vec![0],
                ..cfg
            })
            .is_err());
    }

    #[test]
    fn storm_cell_is_deterministic_and_mostly_resolves() {
        let a = run(16, 0);
        let b = run(16, 0);
        assert_eq!(a.values, b.values);
        assert_eq!(a.breakdown, b.breakdown);
        assert!(stat(&a, "resolved") >= 1.0);
        assert_eq!(stat(&a, "resolved") + stat(&a, "unresolvable"), 16.0);
        // 16 manifests over a 17-package universe share pins heavily
        assert!(stat(&a, "pkg cache hit rate") > 0.5, "{a:?}");
        assert!(a.values[0] > 0.0, "the farm pass takes virtual time");
    }

    #[test]
    fn bigger_storms_amortise_the_caches() {
        let small = run(16, 0);
        let big = run(64, 1);
        assert!(stat(&big, "pkg cache hit rate") > stat(&small, "pkg cache hit rate"));
        assert!(stat(&big, "build hit rate") > 0.5);
        // makespan grows sublinearly: 4x the manifests, well under 4x
        // the virtual time
        assert!(big.values[0] < 4.0 * small.values[0]);
    }
}
