//! `mixed-fleet` as a scenario: co-scheduled C++ and Python tenants
//! contending for the shared Lustre (the §4 discussion case the paper
//! never measures; see [`crate::workload::mixed`]).
//!
//! Cell = (ranks, co-tenancy configuration, rep); one figure per rank
//! count, one row per configuration, the C++ tenant's checkpoint-write
//! time on the y-axis.  This scenario post-dates the pre-refactor
//! coordinator, so its cells draw their seeds from the stable
//! [`cell_seed`](super::cell_seed) hash rather than the historical
//! `seed + rep` — keyed by `(ranks, rep)` and *shared across the three
//! co-tenancy rows*, so the rows of one repetition run against
//! identically-seeded filesystems and the containerised co-tenant's
//! checkpoint is bit-identical to the solo row's.

use anyhow::Result;

use crate::bench::{Figure, RowSet};
use crate::config::ExperimentConfig;
use crate::platform::Platform;
use crate::workload::mixed::{run_mixed_fleet, MixedConfig};

use super::{Cell, CellResult, Scenario, SimContext};

/// The co-scheduled-tenants scenario.
pub struct MixedFleet;

/// The co-tenancy configurations, in row order.
const COMBOS: [(&str, Option<Platform>); 3] = [
    ("C++ checkpoint, no co-tenant", None),
    ("∥ python tenant (native, shared Lustre)", Some(Platform::Native)),
    ("∥ python tenant (shifter, image-mounted)", Some(Platform::ShifterSystemMpi)),
];

/// One mixed-fleet cell.
#[derive(Debug, Clone, Copy)]
struct MixedCell {
    ranks_idx: usize,
    ranks: usize,
    combo: usize,
    rep: usize,
    /// Combo-independent stream seed: the three co-tenancy rows of one
    /// `(ranks, rep)` point share it, so the solo baseline and the
    /// containerised co-tenant run the identical op sequence on
    /// identically-seeded filesystems (the bit-identity the figure
    /// note claims).
    seed: u64,
}

impl Scenario for MixedFleet {
    fn name(&self) -> &'static str {
        "mixed-fleet"
    }

    fn describe(&self) -> &'static str {
        "co-scheduled C++ checkpoint writer and Python import storm contending \
         for the shared Lustre MDS (§4 discussion, unmeasured in the paper); \
         containerising the Python tenant returns the writer to solo time"
    }

    fn cells(&self, cfg: &ExperimentConfig) -> Result<Vec<Cell>> {
        anyhow::ensure!(
            !cfg.ranks.is_empty(),
            "mixed-fleet needs at least one rank count in `ranks`"
        );
        let mut cells = Vec::new();
        for (ranks_idx, &ranks) in cfg.ranks.iter().enumerate() {
            for (combo, (label, _)) in COMBOS.iter().enumerate() {
                for rep in 0..cfg.reps {
                    // seed keyed by (ranks, rep) only — NOT the cell
                    // index — so the three co-tenancy rows of one
                    // repetition are run-for-run comparable
                    let stream = ranks_idx * cfg.reps + rep;
                    let seed = super::cell_seed(cfg.seed, "mixed-fleet", stream);
                    cells.push(Cell::new(
                        format!("mixed-fleet {ranks} ranks / {label} / rep {rep}"),
                        MixedCell {
                            ranks_idx,
                            ranks,
                            combo,
                            rep,
                            seed,
                        },
                    ));
                }
            }
        }
        Ok(cells)
    }

    fn run_cell(&self, _ctx: &SimContext<'_>, cell: &Cell) -> Result<CellResult> {
        let c: &MixedCell = cell.payload()?;
        let (_, python) = COMBOS[c.combo];
        let mixed = MixedConfig::new(c.ranks, c.seed, python);
        let r = run_mixed_fleet(&mixed)?;
        Ok(CellResult::value(r.cpp_io).with_breakdown(vec![
            ("io solo [s]".into(), r.cpp_io_solo),
            ("python import [s]".into(), r.import_wall),
            ("slowdown ×".into(), r.slowdown()),
            ("mds rpcs".into(), r.mds_served as f64),
        ]))
    }

    fn assemble(
        &self,
        ctx: &SimContext<'_>,
        cells: &[Cell],
        rows: Vec<CellResult>,
    ) -> Result<Vec<Figure>> {
        let mut sets: Vec<RowSet> = (0..ctx.cfg.ranks.len()).map(|_| RowSet::new()).collect();
        for (cell, r) in cells.iter().zip(&rows) {
            let c: &MixedCell = cell.payload()?;
            let set = &mut sets[c.ranks_idx];
            set.add_sample(c.combo as u64, COMBOS[c.combo].0, c.rep as u64, r.primary());
            if c.rep == 0 {
                set.set_breakdown(c.combo as u64, r.breakdown.clone());
            }
        }
        let mut figures = Vec::new();
        for (ranks_idx, set) in sets.into_iter().enumerate() {
            let ranks = ctx.cfg.ranks[ranks_idx];
            let mut fig = Figure::new(
                format!("Mixed fleet — co-tenant interference, {ranks}+{ranks} ranks on Edison"),
                "checkpoint write time [s]",
                false,
            );
            let rows = set.into_rows();
            let slowdown = match (rows.first(), rows.get(1)) {
                (Some(solo), Some(native)) if solo.stats.mean() > 0.0 => {
                    native.stats.mean() / solo.stats.mean()
                }
                _ => 1.0,
            };
            for row in rows {
                fig.push(row);
            }
            fig.note(format!(
                "native python co-tenant slows the checkpoint {slowdown:.1}× via shared-MDS \
                 backlog; the image-mounted co-tenant leaves it bit-identical to solo"
            ));
            figures.push(fig);
        }
        Ok(figures)
    }
}
