//! `version-churn`: bump one pinned package and measure how much of
//! the shared `ARCH_OPT` variant matrix cache the bump invalidates.
//!
//! The paper's §2.2 stack pins dozens of package versions; §4.3 makes
//! every host microarchitecture its own build variant.  The operational
//! question a resolver answers is therefore *incremental*: when one
//! version moves, which of the `variants × stages` cells must rebuild?
//! The lockfile answers it before any build runs —
//! [`LockDiff::rebuild_frontier`] is exactly the set of package stages
//! whose cache keys change — and this scenario *asserts* that
//! prediction per cell: every variant is rebuilt against a fork of the
//! cold builder cache and the stages that actually cost time must equal
//! the predicted frontier (no over-invalidation, no under-invalidation).
//!
//! Cell = one bump target from [`BUMP_TARGETS`], chosen to span the
//! dependency depths of the FEniCS graph: `numpy` (a root of the
//! Python tier — widest frontier), `petsc` (the linear-algebra spine),
//! `sympy` (a leaf-ish chain into the form compilers), and `dolfin`
//! (the top — frontier of one).  The figure reports the rebuild cost
//! and the cache-invalidation percentage per target.
//!
//! Determinism: resolution is seed-invariant by construction (the
//! resolver's fixed point is order-free; `tests/resolver.rs` pins it),
//! the builder is deterministic, and cells share nothing — the figure
//! renders byte-identically at every `--jobs` setting, which CI gates.
//!
//! [`LockDiff::rebuild_frontier`]:
//!     crate::container::resolve::LockDiff::rebuild_frontier

use std::collections::BTreeSet;

use anyhow::Result;

use crate::bench::{Figure, Row};
use crate::config::ExperimentConfig;
use crate::container::resolve::{
    emit_stack_buildfile, fenics_index, fenics_manifest, rebuilt_packages, resolve,
    terminal_rebuilt, Lockfile, STACK_BASE,
};
use crate::container::{Builder, Buildfile, LayerStore};
use crate::metrics::Stats;

use super::build_farm::ARCHES;
use super::{Cell, CellResult, Scenario, SimContext};

/// The packages the churn sweep bumps, one cell each — spanning the
/// FEniCS graph from a wide-frontier root (`numpy`) to the top of the
/// stack (`dolfin`, frontier of one).
pub const BUMP_TARGETS: [&str; 4] = ["numpy", "petsc", "sympy", "dolfin"];

/// The single-dep-bump churn scenario.
pub struct VersionChurn;

impl Scenario for VersionChurn {
    fn name(&self) -> &'static str {
        "version-churn"
    }

    fn describe(&self) -> &'static str {
        "bump one pinned package of the resolved FEniCS stack and \
         rebuild the ARCH_OPT variant matrix against the warm cache; \
         asserts the lockfile-diff rebuild frontier equals the stages \
         actually rebuilt, per variant, and reports the invalidation %"
    }

    fn cells(&self, _cfg: &ExperimentConfig) -> Result<Vec<Cell>> {
        Ok(BUMP_TARGETS
            .iter()
            .map(|&pkg| Cell::new(format!("bump {pkg}"), pkg.to_string()))
            .collect())
    }

    fn run_cell(&self, ctx: &SimContext<'_>, cell: &Cell) -> Result<CellResult> {
        let target: &String = cell.payload()?;
        let seed = cell.id.seed(ctx.cfg.seed);
        let mut index = fenics_index();
        let manifest = fenics_manifest();

        // cold pass: resolve, emit every arch variant, build them all
        // against one shared builder cache (variants share the package
        // stages; only the terminal make/ARCH_OPT stage is per-arch)
        let res = resolve(&manifest, &index, seed).map_err(anyhow::Error::new)?;
        let lock = Lockfile::from_resolution(&res, &index);
        let mut builder = Builder::new();
        let mut store = LayerStore::new();
        let mut cold_built = 0usize;
        let mut cold_time = 0.0;
        for arch in ARCHES {
            let text = emit_stack_buildfile(&manifest, &lock, STACK_BASE, Some(arch))?;
            let bf = Buildfile::parse(&text).map_err(anyhow::Error::new)?;
            let cold = builder.build(&bf, &format!("churn/{arch}:cold"), &mut store)?;
            cold_built += cold.layers_built;
            cold_time += cold.critical_path.as_secs_f64();
        }

        // the bump: one patch release, re-resolve, predict the frontier
        let bumped = index
            .bump_patch(target)
            .ok_or_else(|| anyhow::anyhow!("bump target `{target}` not in the index"))?;
        let res2 = resolve(&manifest, &index, seed).map_err(anyhow::Error::new)?;
        let lock2 = Lockfile::from_resolution(&res2, &index);
        let diff = lock.diff(&lock2);
        let frontier = diff.rebuild_frontier(&lock2);
        anyhow::ensure!(
            frontier.contains(target),
            "a patch bump of `{target}` must land in its own frontier ({diff})"
        );

        // warm pass: every variant rebuilds against a *fork* of the
        // cold cache (variants stay independent, exactly like farm
        // workers), and the stages that actually cost time must equal
        // the predicted frontier — the scenario's core assertion
        let mut churn_built = 0usize;
        let mut churn_cached = 0usize;
        let mut rebuild_time = 0.0;
        for arch in ARCHES {
            let text = emit_stack_buildfile(&manifest, &lock2, STACK_BASE, Some(arch))?;
            let bf = Buildfile::parse(&text).map_err(anyhow::Error::new)?;
            let mut fork = builder.fork();
            let warm = fork.build(&bf, &format!("churn/{arch}:bumped"), &mut store)?;
            let rebuilt: BTreeSet<String> = rebuilt_packages(&bf, &warm);
            anyhow::ensure!(
                rebuilt == frontier,
                "{arch}: rebuilt stages {rebuilt:?} != predicted frontier {frontier:?}"
            );
            anyhow::ensure!(
                terminal_rebuilt(&warm),
                "{arch}: a non-empty frontier must rebuild the terminal stage"
            );
            churn_built += warm.layers_built;
            churn_cached += warm.layers_cached;
            rebuild_time += warm.critical_path.as_secs_f64();
        }

        let invalidation_pct = 100.0 * churn_built as f64 / cold_built.max(1) as f64;
        Ok(CellResult::values(vec![rebuild_time, invalidation_pct]).with_breakdown(vec![
            ("frontier stages".into(), frontier.len() as f64),
            ("packages pinned".into(), lock2.packages.len() as f64),
            ("bumped to patch".into(), bumped.patch as f64),
            ("cold layers built".into(), cold_built as f64),
            ("churn layers built".into(), churn_built as f64),
            ("churn layers cached".into(), churn_cached as f64),
            ("invalidation %".into(), invalidation_pct),
            ("cold build s".into(), cold_time),
            ("rebuild s".into(), rebuild_time),
            ("store dedup x".into(), store.dedup_ratio()),
        ]))
    }

    fn assemble(
        &self,
        _ctx: &SimContext<'_>,
        cells: &[Cell],
        rows: Vec<CellResult>,
    ) -> Result<Vec<Figure>> {
        let mut fig = Figure::new(
            "Version churn — rebuild cost of a one-package patch bump \
             across the ARCH_OPT variant matrix",
            "rebuild critical path [virtual s]",
            false,
        );
        for r in &rows {
            fig.push(
                Row::new(cells[r.cell].label.clone(), Stats::from_samples(vec![r.values[0]]))
                    .with_breakdown(r.breakdown.clone()),
            );
        }
        fig.note(
            "every cell asserts the lockfile-diff rebuild frontier equals \
             the stages the builder actually re-ran, per variant — no \
             over- or under-invalidation; `invalidation %` is bumped-pass \
             layers built over cold-pass layers built",
        );
        Ok(vec![fig])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::CalibrationTable;
    use crate::scenario::CellId;

    fn run(target: &str, index: usize) -> CellResult {
        let cfg = ExperimentConfig::paper_default("version-churn").unwrap();
        let table = CalibrationTable::builtin_fallback();
        let ctx = SimContext {
            cfg: &cfg,
            table: &table,
        };
        let mut cell = Cell::new(format!("bump {target}"), target.to_string());
        cell.id = CellId {
            scenario: "version-churn",
            index,
        };
        VersionChurn.run_cell(&ctx, &cell).unwrap()
    }

    #[test]
    fn cells_cover_the_bump_targets() {
        let cfg = ExperimentConfig::paper_default("version-churn").unwrap();
        let cells = VersionChurn.cells(&cfg).unwrap();
        assert_eq!(cells.len(), BUMP_TARGETS.len());
        assert_eq!(cells[0].label, "bump numpy");
        assert_eq!(cells[3].label, "bump dolfin");
    }

    #[test]
    fn frontier_width_tracks_dependency_depth() {
        let stat = |r: &CellResult, key: &str| {
            r.breakdown
                .iter()
                .find(|(k, _)| k == key)
                .map(|&(_, v)| v)
                .unwrap()
        };
        // numpy sits under half the stack; dolfin is the top of it
        let numpy = run("numpy", 0);
        let dolfin = run("dolfin", 3);
        assert_eq!(stat(&numpy, "frontier stages"), 8.0);
        assert_eq!(stat(&dolfin, "frontier stages"), 1.0);
        assert!(stat(&numpy, "invalidation %") > stat(&dolfin, "invalidation %"));
        assert!(numpy.values[0] > dolfin.values[0], "wider frontier costs more");
    }

    #[test]
    fn churn_cell_is_deterministic() {
        let a = run("petsc", 1);
        let b = run("petsc", 1);
        assert_eq!(a.values, b.values);
        assert_eq!(a.breakdown, b.breakdown);
    }
}
