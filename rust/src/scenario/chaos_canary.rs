//! `chaos-canary`: a rolling canary upgrade of the FEniCS fleet under
//! seeded fault injection.
//!
//! The paper's deployment story (§3.1's "pull everywhere" step) is
//! measured on a quiet cluster; production clusters are not quiet.
//! This scenario replays the same fleet deployment while a
//! deterministic [`FaultSchedule`] crashes nodes, takes registry
//! shards out, drops WAN transfers, and storms node caches — and the
//! distribution tier answers with the [`RetryPolicy`] machinery
//! (capped exponential backoff, shard failover, fan-out re-parenting).
//!
//! The shape is a *canary* upgrade: the fleet already runs release
//! `r1`; release `r2` (the same image plus one hotpatch layer, so only
//! the delta layer moves) is first rolled to a small canary ring, and
//! only if a majority of the ring survives does the rollout proceed to
//! the rest of the fleet.  Cells sweep fault intensity × retry policy;
//! the figures report tail makespan, fleet availability over the
//! upgrade, and the WAN/fabric bytes wasted on lost transfers.
//!
//! Determinism: every cell derives its fault-schedule and retry-jitter
//! streams from [`CellId::seed`](super::CellId::seed), so the matrix
//! is bit-identical across `--jobs` settings, and the
//! `intensity = 0.0` cells reproduce the fault-free deploy reports
//! bit-for-bit (pinned by `tests/fault_injection.rs`).

use anyhow::Result;

use crate::bench::{Figure, Row};
use crate::config::ExperimentConfig;
use crate::container::{
    Builder, Buildfile, DeployEngine, FleetConfig, FleetReport, LayerStore, Registry, RetryPolicy,
    ShardedRegistry,
};
use crate::coordinator::FENICS_BUILDFILE;
use crate::des::{Duration, FaultConfig, FaultSchedule, SimRng};
use crate::metrics::Stats;

use super::{Cell, CellResult, Scenario, SimContext};

/// The running release every node already holds when the upgrade
/// starts (the paper pipeline's reference).
pub const V1_REFERENCE: &str = "quay.io/fenicsproject/stable:2016.1.0r1";

/// The canary release being rolled out: `r1` plus one hotpatch layer,
/// so the upgrade moves only the delta layer.
pub const V2_REFERENCE: &str = "quay.io/fenicsproject/stable:2016.1.0r2";

/// Fault intensities the matrix sweeps (`0.0` = the fault-free
/// control cell, pinned bit-identical to
/// [`Fleet::deploy`](crate::container::Fleet::deploy)).
pub const INTENSITIES: [f64; 3] = [0.0, 0.4, 0.8];

/// Virtual window (from the upgrade start) the fault schedule is
/// generated within: 60 s.
const CHAOS_HORIZON: Duration = Duration(60_000_000_000);

/// The canary release's buildfile: the paper's FEniCS stack with one
/// hotpatch `RUN` layer appended, so `r2` shares every `r1` layer and
/// the rollout transfers only the delta.
pub fn canary_buildfile() -> String {
    format!("{FENICS_BUILDFILE}RUN apt-get -y install hotpatch-r2\n")
}

/// Build both releases into one store and publish them behind four
/// shard frontends — the registry side of the canary campaign.
pub fn canary_registry() -> Result<ShardedRegistry> {
    let mut store = LayerStore::new();
    let mut builder = Builder::new();
    let v1 = builder.build(&Buildfile::parse(FENICS_BUILDFILE)?, V1_REFERENCE, &mut store)?;
    let bf2 = Buildfile::parse(&canary_buildfile())?;
    let v2 = builder.build(&bf2, V2_REFERENCE, &mut store)?;
    let mut registry = Registry::new();
    registry.push(&v1.image, &store)?;
    registry.push(&v2.image, &store)?;
    Ok(ShardedRegistry::new(registry, 4))
}

/// Size of the canary ring for a fleet of `nodes`: 1/16th of the
/// fleet, at least one node.
pub fn canary_ring(nodes: usize) -> usize {
    (nodes / 16).max(1)
}

/// The retry policies the matrix sweeps: no retries at all (every
/// lost transfer is terminal) against the deployment-campaign default.
pub fn policies() -> [(&'static str, RetryPolicy); 2] {
    [("no-retry", RetryPolicy::none()), ("hpc", RetryPolicy::hpc())]
}

/// The chaos canary-upgrade scenario.
pub struct ChaosCanary;

/// One (fleet size × fault intensity × retry policy) cell.
#[derive(Debug, Clone, Copy)]
struct ChaosCell {
    nodes: usize,
    intensity: f64,
    policy_name: &'static str,
    policy: RetryPolicy,
}

impl ChaosCell {
    fn label(&self) -> String {
        format!(
            "{} nodes, intensity {:.1}, {}",
            self.nodes, self.intensity, self.policy_name
        )
    }
}

/// Byte conservation for one ring report: everything that crossed a
/// link either landed in a node cache or is accounted as re-sent.
/// Holds exactly for the unbounded caches [`FleetConfig::hpc`] uses.
fn ensure_conserved(report: &FleetReport) -> Result<()> {
    anyhow::ensure!(
        report.total_bytes() == report.cache.bytes_inserted + report.retried_bytes,
        "byte conservation violated in `{}`: {} moved != {} admitted + {} re-sent",
        report.reference,
        report.total_bytes(),
        report.cache.bytes_inserted,
        report.retried_bytes,
    );
    Ok(())
}

impl Scenario for ChaosCanary {
    fn name(&self) -> &'static str {
        "chaos-canary"
    }

    fn describe(&self) -> &'static str {
        "rolling canary upgrade (r1 -> r2, one hotpatch layer) on the \
         fleet under seeded fault injection: crashes, shard outages, \
         drop windows and cache storms vs retry/backoff/failover; \
         sweeps fault intensity x retry policy, reports tail makespan, \
         availability and wasted WAN bytes"
    }

    fn cells(&self, cfg: &ExperimentConfig) -> Result<Vec<Cell>> {
        anyhow::ensure!(
            !cfg.nodes.is_empty(),
            "chaos-canary needs at least one fleet size in `nodes`"
        );
        anyhow::ensure!(
            cfg.nodes.iter().all(|&n| n >= 2),
            "chaos-canary fleets need >= 2 nodes (a canary ring plus a \
             rest ring; got {:?})",
            cfg.nodes
        );
        let mut cells = Vec::with_capacity(cfg.nodes.len() * INTENSITIES.len() * 2);
        for &nodes in &cfg.nodes {
            for &intensity in &INTENSITIES {
                for (policy_name, policy) in policies() {
                    let c = ChaosCell {
                        nodes,
                        intensity,
                        policy_name,
                        policy,
                    };
                    cells.push(Cell::new(c.label(), c));
                }
            }
        }
        Ok(cells)
    }

    fn run_cell(&self, ctx: &SimContext<'_>, cell: &Cell) -> Result<CellResult> {
        let c: &ChaosCell = cell.payload()?;
        let mut registry = canary_registry()?;
        // batched (the default) rides the collapsed node-class engine;
        // --per-rank forces the per-node reference walk
        let mut fleet = DeployEngine::new(
            FleetConfig {
                domains: ctx.cfg.domains,
                ..FleetConfig::hpc(c.nodes)
            },
            ctx.cfg.batched,
        );

        // the fleet runs r1 before the chaos starts (fault-free warmup)
        let baseline = fleet.deploy(&mut registry, V1_REFERENCE)?;
        anyhow::ensure!(
            baseline.containers_started == c.nodes,
            "baseline r1 deploy must reach every node"
        );

        // the cell's two private streams: where the faults land, and
        // the retry jitter reacting to them
        let fault_cfg = FaultConfig::new(
            c.nodes,
            registry.shard_count(),
            CHAOS_HORIZON,
            c.intensity,
        );
        let mut schedule_rng = SimRng::new(cell.id.seed(ctx.cfg.seed), "fault-schedule");
        let schedule = FaultSchedule::generate(&fault_cfg, &mut schedule_rng).shifted(fleet.now());
        registry.apply_faults(&schedule);
        let mut jitter_rng = SimRng::new(cell.id.seed(ctx.cfg.seed), "retry-jitter");

        // ring 1: the canary; ring 2 only if a majority of the canary
        // ring came up on r2
        let ring = canary_ring(c.nodes);
        let canary = fleet.deploy_with_faults(
            &mut registry,
            V2_REFERENCE,
            0..ring,
            &schedule,
            &c.policy,
            &mut jitter_rng,
        )?;
        ensure_conserved(&canary)?;
        anyhow::ensure!(
            canary.containers_started + canary.permanently_failed == ring,
            "canary ring must end deployed or permanently failed"
        );
        let aborted = canary.permanently_failed * 2 > ring;
        let rest = if aborted {
            None
        } else {
            let r = fleet.deploy_with_faults(
                &mut registry,
                V2_REFERENCE,
                ring..c.nodes,
                &schedule,
                &c.policy,
                &mut jitter_rng,
            )?;
            ensure_conserved(&r)?;
            anyhow::ensure!(
                r.containers_started + r.permanently_failed == c.nodes - ring,
                "rest ring must end deployed or permanently failed"
            );
            Some(r)
        };

        // injected stats once over the whole rollout span (the ring
        // reports each count the schedule's events globally, so they
        // must not simply be merged), reaction counters summed from
        // the rings
        let end = match &rest {
            Some(r) => r.started_at + r.makespan,
            None => canary.started_at + canary.makespan,
        };
        let span = end.since(canary.started_at);
        let mut fault = schedule.stats_over(canary.started_at, end);
        fault.retries = canary.retries + rest.as_ref().map_or(0, |r| r.retries);
        fault.failovers = canary.failovers + rest.as_ref().map_or(0, |r| r.failovers);
        fault.transfers_dropped = canary.fault.transfers_dropped
            + rest.as_ref().map_or(0, |r| r.fault.transfers_dropped);
        let permanent =
            canary.permanently_failed + rest.as_ref().map_or(0, |r| r.permanently_failed);
        fault.permanent_failures = permanent as u64;

        let availability = fault.availability(c.nodes, span);
        let wasted = canary.retried_bytes + rest.as_ref().map_or(0, |r| r.retried_bytes);
        let wan = canary.wan_bytes + rest.as_ref().map_or(0, |r| r.wan_bytes);
        let delivered =
            canary.delivered_bytes() + rest.as_ref().map_or(0, |r| r.delivered_bytes());

        Ok(CellResult::values(vec![
            span.as_secs_f64(),
            availability,
            wasted as f64 / 1e6,
            fault.retries as f64,
        ])
        .with_breakdown(vec![
            ("make:canary ring s".into(), canary.makespan.as_secs_f64()),
            (
                "make:fleet ring s".into(),
                rest.as_ref().map_or(0.0, |r| r.makespan.as_secs_f64()),
            ),
            ("make:retries".into(), fault.retries as f64),
            ("make:failovers".into(), fault.failovers as f64),
            ("make:permanently failed".into(), permanent as f64),
            ("avail:downtime s".into(), fault.downtime.as_secs_f64()),
            ("avail:mttr s".into(), fault.mttr().as_secs_f64()),
            ("avail:crashes".into(), fault.node_crashes as f64),
            ("avail:aborted".into(), if aborted { 1.0 } else { 0.0 }),
            ("waste:wan MB".into(), wan as f64 / 1e6),
            ("waste:delivered MB".into(), delivered as f64 / 1e6),
        ]))
    }

    fn assemble(
        &self,
        _ctx: &SimContext<'_>,
        cells: &[Cell],
        rows: Vec<CellResult>,
    ) -> Result<Vec<Figure>> {
        let mut make_fig = Figure::new(
            "Chaos canary — rolling-upgrade makespan under faults",
            "makespan [s]",
            false,
        );
        let mut avail_fig = Figure::new(
            "Chaos canary — fleet availability over the upgrade",
            "availability",
            false,
        );
        let mut waste_fig = Figure::new(
            "Chaos canary — WAN/fabric bytes wasted on lost transfers",
            "re-sent [MB]",
            false,
        );
        for r in &rows {
            let c: &ChaosCell = cells[r.cell].payload()?;
            let label = c.label();
            let part = |prefix: &str| -> Vec<(String, f64)> {
                r.breakdown
                    .iter()
                    .filter_map(|(k, v)| k.strip_prefix(prefix).map(|k| (k.to_string(), *v)))
                    .collect()
            };
            make_fig.push(
                Row::new(label.clone(), Stats::from_samples(vec![r.values[0]]))
                    .with_breakdown(part("make:")),
            );
            avail_fig.push(
                Row::new(label.clone(), Stats::from_samples(vec![r.values[1]]))
                    .with_breakdown(part("avail:")),
            );
            waste_fig.push(
                Row::new(label, Stats::from_samples(vec![r.values[2]]))
                    .with_breakdown(part("waste:")),
            );
        }
        make_fig.note(
            "r2 rolls to a 1/16th canary ring first; the rest of the fleet \
             follows only if a majority of the ring survives (aborted \
             rollouts report the canary ring alone)",
        );
        avail_fig.note(
            "availability = 1 - node downtime / (nodes x upgrade span); \
             intensity 0.0 is the fault-free control and must sit at 1.0",
        );
        waste_fig.note(
            "conservation: bytes moved == bytes admitted to caches + \
             re-sent bytes (checked per ring while the cells ran)",
        );
        Ok(vec![make_fig, avail_fig, waste_fig])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::CalibrationTable;
    use crate::scenario::CellId;

    fn ctx_cfg() -> ExperimentConfig {
        ExperimentConfig {
            nodes: vec![64],
            ..ExperimentConfig::paper_default("chaos-canary").unwrap()
        }
    }

    #[test]
    fn cells_sweep_intensity_times_policy() {
        let cfg = ctx_cfg();
        let cells = ChaosCanary.cells(&cfg).unwrap();
        assert_eq!(cells.len(), INTENSITIES.len() * 2);
        assert!(cells[0].label.contains("intensity 0.0"));
        assert!(cells[0].label.contains("no-retry"));
        assert!(cells[1].label.contains("hpc"));
        assert!(ChaosCanary
            .cells(&ExperimentConfig {
                nodes: vec![],
                ..cfg.clone()
            })
            .is_err());
        assert!(ChaosCanary
            .cells(&ExperimentConfig {
                nodes: vec![1],
                ..cfg
            })
            .is_err());
    }

    #[test]
    fn canary_registry_serves_both_releases_and_shares_layers() {
        let registry = canary_registry().unwrap();
        let v1 = registry.registry().image(V1_REFERENCE).unwrap();
        let v2 = registry.registry().image(V2_REFERENCE).unwrap();
        // r2 = r1 plus exactly one hotpatch layer, sharing the r1 chain
        assert_eq!(v2.layers.len(), v1.layers.len() + 1);
        assert_eq!(&v2.layers[..v1.layers.len()], &v1.layers[..]);
    }

    #[test]
    fn ring_is_a_sixteenth_with_a_floor_of_one() {
        assert_eq!(canary_ring(16384), 1024);
        assert_eq!(canary_ring(64), 4);
        assert_eq!(canary_ring(2), 1);
    }

    fn run(nodes: usize, intensity: f64, policy_idx: usize, index: usize) -> CellResult {
        let cfg = ExperimentConfig {
            nodes: vec![nodes],
            ..ExperimentConfig::paper_default("chaos-canary").unwrap()
        };
        let table = CalibrationTable::builtin_fallback();
        let ctx = SimContext {
            cfg: &cfg,
            table: &table,
        };
        let (name, policy) = policies()[policy_idx];
        let mut cell = Cell::new(
            "test",
            ChaosCell {
                nodes,
                intensity,
                policy_name: name,
                policy,
            },
        );
        cell.id = CellId {
            scenario: "chaos-canary",
            index,
        };
        ChaosCanary.run_cell(&ctx, &cell).unwrap()
    }

    #[test]
    fn zero_intensity_cell_is_fully_available_and_waste_free() {
        let r = run(64, 0.0, 0, 0);
        assert_eq!(r.values[1], 1.0, "availability");
        assert_eq!(r.values[2], 0.0, "wasted MB");
        assert_eq!(r.values[3], 0.0, "retries");
        assert!(r.values[0] > 0.0, "upgrade takes virtual time");
    }

    #[test]
    fn chaotic_cell_is_deterministic_for_a_fixed_seed() {
        let a = run(64, 0.8, 1, 5);
        let b = run(64, 0.8, 1, 5);
        assert_eq!(a.values, b.values);
        assert_eq!(a.breakdown, b.breakdown);
        // a different cell index reseeds the schedule
        let c = run(64, 0.8, 1, 4);
        assert!(a.values != c.values || a.breakdown != c.breakdown);
    }
}
