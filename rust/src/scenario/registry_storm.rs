//! `registry-storm`: open-loop heavy-tailed traffic against the
//! registry front door, swept over offered load × shard count.
//!
//! The paper's distribution story measures a quiet "pull everywhere"
//! step; a public registry instead absorbs millions of requests a day
//! from CI farms and deploy fleets at once — an *open-loop* arrival
//! process (clients do not wait for each other) with heavy-tailed
//! inter-arrival gaps.  This scenario drives the
//! [`FrontDoor`] protocol tier with a bounded-Pareto arrival stream of
//! blob pull/push sessions and reports what an SRE would ask of a
//! production registry: steady-state p50/p99/p999 session latency and
//! the **saturation knee** — the offered load beyond which queues (and
//! tail latency) grow without bound.
//!
//! Calibration: one *offered load* unit is the arrival rate at which
//! the requested work exactly fills the shard frontends, counting the
//! per-chunk RTT overhead (`service = bytes/β + ceil(bytes/chunk)·α`).
//! Cells at load < 1 reach steady state (the [`is_stationary`] check
//! passes after [`warmup_trim`]); cells past 1.0 sit beyond the knee
//! and their tails diverge with the horizon — which is the figure.
//!
//! Determinism: arrivals, layer choices and push/pull mixing come from
//! one [`SimRng`] stream seeded by
//! [`CellId::seed`](super::CellId::seed), and the percentile estimator
//! is the integer-binned [`LatencyHistogram`] — the matrix renders
//! byte-identically at every `--jobs` setting.

use anyhow::Result;

use crate::bench::{Figure, Row};
use crate::config::ExperimentConfig;
use crate::container::{
    Builder, Buildfile, FrontDoor, LayerStore, Registry, RetryPolicy, SessionRequest,
    ShardedRegistry, TransferKind,
};
use crate::coordinator::FENICS_BUILDFILE;
use crate::des::{
    is_stationary, warmup_trim, Duration, FaultConfig, FaultSchedule, LatencyHistogram, SimRng,
    VirtualTime,
};
use crate::metrics::Stats;

use super::{Cell, CellResult, Scenario, SimContext};

/// Offered-load multipliers the matrix sweeps: two comfortably
/// subcritical points, one just under the knee, one past it.
pub const LOADS: [f64; 4] = [0.25, 0.5, 0.9, 1.2];

/// Open-loop sessions per cell.
pub const STORM_REQUESTS: usize = 2000;

/// Fraction of sessions that are blob pushes (CI farms re-uploading);
/// the rest are pulls.
pub const PUSH_FRACTION: f64 = 0.1;

/// Pareto shape of the inter-arrival gaps (α < 2 ⇒ bursty,
/// infinite-variance-like tails within the bound).
const PARETO_ALPHA: f64 = 1.5;

/// Bound of the Pareto gap distribution relative to its floor
/// (gaps span two orders of magnitude).
const PARETO_SPAN: f64 = 100.0;

/// The published image whose blobs the storm requests.
pub const STORM_REFERENCE: &str = "quay.io/fenicsproject/stable:2016.1.0";

/// Inverse CDF of a bounded Pareto on `[1, PARETO_SPAN]`.
fn bounded_pareto(u: f64) -> f64 {
    let tail = 1.0 - PARETO_SPAN.powf(-PARETO_ALPHA);
    (1.0 - u * tail).powf(-1.0 / PARETO_ALPHA)
}

/// Closed-form mean of [`bounded_pareto`] (used to normalise gaps so
/// their mean is exactly the calibrated inter-arrival time).
fn bounded_pareto_mean() -> f64 {
    let a = PARETO_ALPHA;
    let tail = 1.0 - PARETO_SPAN.powf(-a);
    a / (a - 1.0) / tail * (1.0 - PARETO_SPAN.powf(1.0 - a))
}

/// The open-loop registry-storm scenario.
pub struct RegistryStorm;

/// Fault intensity of the one chaos cell the matrix appends: shard
/// outages and WAN drop windows striking the storm mid-flight.
pub const STORM_CHAOS_INTENSITY: f64 = 0.4;

/// One (shard count × offered load × fault intensity) cell.  The
/// sweep cells run fault-free (`intensity = 0.0`); one extra cell
/// replays the near-knee load under a seeded fault schedule.
#[derive(Debug, Clone, Copy)]
struct StormCell {
    shards: usize,
    load: f64,
    intensity: f64,
}

impl StormCell {
    fn label(&self) -> String {
        if self.intensity > 0.0 {
            format!(
                "{} shard(s), load {:.2}x, chaos {:.1}",
                self.shards, self.load, self.intensity
            )
        } else {
            format!("{} shard(s), load {:.2}x", self.shards, self.load)
        }
    }
}

/// Publish the FEniCS stack behind `shards` frontends and wrap it in a
/// front door with the storm retry policy (the campaign default minus
/// its timeout: a saturated queue is slow, not broken, and timing out
/// every queued chunk would melt a past-the-knee cell into a retry
/// storm — per-session chaos is the ROADMAP follow-up).
pub fn storm_front_door(shards: usize) -> Result<FrontDoor> {
    let mut store = LayerStore::new();
    let built = Builder::new().build(
        &Buildfile::parse(FENICS_BUILDFILE)?,
        STORM_REFERENCE,
        &mut store,
    )?;
    let mut registry = Registry::new();
    registry.push(&built.image, &store)?;
    Ok(
        FrontDoor::new(ShardedRegistry::new(registry, shards)).with_policy(RetryPolicy {
            timeout: None,
            ..RetryPolicy::hpc()
        }),
    )
}

impl Scenario for RegistryStorm {
    fn name(&self) -> &'static str {
        "registry-storm"
    }

    fn describe(&self) -> &'static str {
        "open-loop heavy-tailed (bounded-Pareto) blob pull/push storm \
         against the registry front door; sweeps offered load x shard \
         count, reports steady-state p50/p99/p999 session latency \
         (warmup-trimmed) and locates the saturation knee"
    }

    fn cells(&self, cfg: &ExperimentConfig) -> Result<Vec<Cell>> {
        anyhow::ensure!(
            !cfg.nodes.is_empty(),
            "registry-storm needs at least one shard count in `nodes`"
        );
        anyhow::ensure!(
            cfg.nodes.iter().all(|&s| s >= 1),
            "registry-storm shard counts must be >= 1 (got {:?})",
            cfg.nodes
        );
        let mut cells = Vec::with_capacity(cfg.nodes.len() * LOADS.len() + 1);
        for &shards in &cfg.nodes {
            for &load in &LOADS {
                let c = StormCell {
                    shards,
                    load,
                    intensity: 0.0,
                };
                cells.push(Cell::new(c.label(), c));
            }
        }
        // one chaos cell: the near-knee load on the widest frontend,
        // with shard outages and drop windows striking mid-storm
        let chaos = StormCell {
            shards: *cfg.nodes.iter().max().expect("nodes checked non-empty"),
            load: 0.9,
            intensity: STORM_CHAOS_INTENSITY,
        };
        cells.push(Cell::new(chaos.label(), chaos));
        Ok(cells)
    }

    fn run_cell(&self, ctx: &SimContext<'_>, cell: &Cell) -> Result<CellResult> {
        let c: &StormCell = cell.payload()?;
        let mut fd = storm_front_door(c.shards)?.with_domains(ctx.cfg.domains);

        // calibrate the mean inter-arrival gap so `load` is the exact
        // fraction of aggregate shard capacity the stream requests,
        // RTT overhead included
        let wan = fd.registry().wan();
        let chunk = fd.chunk_bytes();
        let image = fd
            .registry()
            .registry()
            .image(STORM_REFERENCE)
            .ok_or_else(|| anyhow::anyhow!("storm image missing"))?
            .clone();
        let sizes: Vec<u64> = image
            .layers
            .iter()
            .map(|id| fd.registry().registry().layers.get(id).map(|l| l.bytes).unwrap_or(0))
            .collect();
        anyhow::ensure!(!sizes.is_empty(), "storm image has no layers");
        let service = |bytes: u64| {
            bytes as f64 / wan.beta_bytes_per_sec
                + bytes.div_ceil(chunk.max(1)) as f64 * wan.alpha.as_secs_f64()
        };
        let mean_service = sizes.iter().map(|&b| service(b)).sum::<f64>() / sizes.len() as f64;
        let mean_gap = mean_service / (c.load * c.shards as f64);

        // one stream drives arrivals, blob choice, and push/pull mix
        let mut rng = SimRng::new(cell.id.seed(ctx.cfg.seed), "storm-arrivals");
        let pareto_mean = bounded_pareto_mean();
        let mut at = VirtualTime::ZERO;
        let mut requests = Vec::with_capacity(STORM_REQUESTS);
        for _ in 0..STORM_REQUESTS {
            let gap = mean_gap * bounded_pareto(rng.uniform(0.0, 1.0)) / pareto_mean;
            at += crate::des::Duration::from_secs_f64(gap);
            let id = image.layers[rng.index(image.layers.len())].clone();
            if rng.uniform(0.0, 1.0) < PUSH_FRACTION {
                let payload = fd
                    .registry()
                    .registry()
                    .layers
                    .get(&id)
                    .ok_or_else(|| anyhow::anyhow!("storm layer missing"))?
                    .clone();
                requests.push(SessionRequest::push(at, payload));
            } else {
                requests.push(SessionRequest::pull(at, id));
            }
        }
        let offered_span = at.as_secs_f64();

        // the chaos cell replays the storm under a seeded schedule of
        // shard outages and WAN drop windows (no fleet here, so the
        // node-level fault classes stay empty)
        if c.intensity > 0.0 {
            let fault_cfg = FaultConfig::new(
                0,
                c.shards,
                Duration::from_secs_f64(offered_span),
                c.intensity,
            );
            let mut chaos_rng = SimRng::new(cell.id.seed(ctx.cfg.seed), "storm-chaos");
            fd.apply_faults(FaultSchedule::generate(&fault_cfg, &mut chaos_rng));
        }

        let mut jitter = SimRng::new(cell.id.seed(ctx.cfg.seed), "storm-jitter");
        let (sessions, report) = fd.run(requests, Some(&mut jitter));

        // the cells self-check the protocol invariants as they run
        anyhow::ensure!(
            report.wire_bytes == report.payload_bytes + report.resent_bytes,
            "byte conservation violated: {} wire != {} payload + {} resent",
            report.wire_bytes,
            report.payload_bytes,
            report.resent_bytes,
        );
        anyhow::ensure!(
            report.delivered + report.failed == report.sessions,
            "every session must deliver or fail"
        );
        if c.intensity == 0.0 {
            anyhow::ensure!(report.failed == 0, "no faults here: nothing may fail");
        }
        let availability = report.delivered as f64 / report.sessions.max(1) as f64;

        // steady-state percentiles: warmup-trim the arrival-ordered
        // pull latencies, then bin them with the des-level estimator
        let pulls: Vec<f64> = sessions
            .iter()
            .filter(|s| s.kind == TransferKind::Pull && s.delivered)
            .map(|s| s.latency().as_secs_f64())
            .collect();
        anyhow::ensure!(!pulls.is_empty(), "a storm with no pulls measures nothing");
        let skip = warmup_trim(&pulls);
        let steady = &pulls[skip..];
        let stationary = is_stationary(steady, 0.25);
        let mut hist = LatencyHistogram::new();
        for s in sessions
            .iter()
            .filter(|s| s.kind == TransferKind::Pull && s.delivered)
            .skip(skip)
        {
            hist.record(s.latency());
        }

        let end = sessions
            .iter()
            .map(|s| s.done_at)
            .max()
            .unwrap_or(VirtualTime::ZERO);
        let end_s = end.as_secs_f64().max(f64::MIN_POSITIVE);
        let busy: f64 = fd
            .registry()
            .shard_busy()
            .iter()
            .map(|b| b.as_secs_f64())
            .sum();
        let utilisation = busy / (end_s * c.shards as f64);
        let backlog_s = fd
            .registry()
            .shard_backlog(end)
            .iter()
            .map(|b| b.as_secs_f64())
            .fold(0.0, f64::max);
        let delivered_mbps = report.payload_bytes as f64 / 1e6 / end_s;

        Ok(CellResult::values(vec![
            hist.p99().as_secs_f64(),
            hist.p50().as_secs_f64(),
            hist.p999().as_secs_f64(),
            delivered_mbps,
        ])
        .with_breakdown(vec![
            ("lat:p50 s".into(), hist.p50().as_secs_f64()),
            ("lat:p999 s".into(), hist.p999().as_secs_f64()),
            ("lat:mean s".into(), hist.mean().as_secs_f64()),
            ("lat:max s".into(), hist.max().as_secs_f64()),
            ("lat:samples".into(), hist.count() as f64),
            ("lat:warmup trimmed".into(), skip as f64),
            ("sat:offered load x".into(), c.load),
            ("sat:utilisation".into(), utilisation),
            ("sat:stationary".into(), if stationary { 1.0 } else { 0.0 }),
            ("sat:end backlog s".into(), backlog_s),
            ("sat:arrival span s".into(), offered_span),
            ("sat:wire MB".into(), report.wire_bytes as f64 / 1e6),
            ("sat:chunks".into(), report.chunks as f64),
            ("sat:queue hwm".into(), report.queue.depth_hwm as f64),
            ("sat:failed sessions".into(), report.failed as f64),
            ("sat:availability".into(), availability),
            // per-session availability percentiles (fraction of the
            // payload each client actually received, 1 s == 1.0): the
            // tail the scalar availability above averages away
            ("sat:avail p01".into(), report.availability.quantile(0.01).as_secs_f64()),
            ("sat:avail p05".into(), report.availability.quantile(0.05).as_secs_f64()),
            ("sat:avail p50".into(), report.availability.quantile(0.50).as_secs_f64()),
        ]))
    }

    fn assemble(
        &self,
        _ctx: &SimContext<'_>,
        cells: &[Cell],
        rows: Vec<CellResult>,
    ) -> Result<Vec<Figure>> {
        let mut lat_fig = Figure::new(
            "Registry storm — steady-state blob pull latency percentiles",
            "p99 latency [s]",
            false,
        );
        let mut sat_fig = Figure::new(
            "Registry storm — delivered throughput and saturation",
            "delivered [MB/s]",
            false,
        );
        for r in &rows {
            let c: &StormCell = cells[r.cell].payload()?;
            let label = c.label();
            let part = |prefix: &str| -> Vec<(String, f64)> {
                r.breakdown
                    .iter()
                    .filter_map(|(k, v)| k.strip_prefix(prefix).map(|k| (k.to_string(), *v)))
                    .collect()
            };
            lat_fig.push(
                Row::new(label.clone(), Stats::from_samples(vec![r.values[0]]))
                    .with_breakdown(part("lat:")),
            );
            sat_fig.push(
                Row::new(label, Stats::from_samples(vec![r.values[3]]))
                    .with_breakdown(part("sat:")),
            );
        }
        lat_fig.note(
            "open-loop bounded-Pareto arrivals; latencies are warmup-trimmed \
             (MSER) and binned by the deterministic log-spaced estimator, so \
             percentiles are byte-identical across --jobs; the p99 knee sits \
             just past offered load 1.0x",
        );
        sat_fig.note(
            "offered load 1.0x = arrivals exactly fill the shard frontends \
             (per-chunk RTT included); past the knee the backlog and tails \
             grow with the horizon and `stationary` drops to 0",
        );
        Ok(vec![lat_fig, sat_fig])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::CalibrationTable;
    use crate::scenario::CellId;

    #[test]
    fn pareto_inverse_cdf_is_bounded_with_the_closed_form_mean() {
        let mut rng = SimRng::new(9, "pareto-check");
        let mut sum = 0.0;
        let n = 200_000;
        for _ in 0..n {
            let x = bounded_pareto(rng.uniform(0.0, 1.0));
            assert!((1.0..=PARETO_SPAN).contains(&x), "{x}");
            sum += x;
        }
        let sample_mean = sum / n as f64;
        let exact = bounded_pareto_mean();
        assert!(
            (sample_mean - exact).abs() / exact < 0.05,
            "sample mean {sample_mean} vs closed form {exact}"
        );
    }

    #[test]
    fn cells_sweep_shards_times_loads() {
        let cfg = ExperimentConfig::paper_default("registry-storm").unwrap();
        let cells = RegistryStorm.cells(&cfg).unwrap();
        assert_eq!(cells.len(), cfg.nodes.len() * LOADS.len() + 1);
        assert!(cells[0].label.contains("load 0.25x"));
        let chaos = cells.last().unwrap();
        assert!(chaos.label.contains("chaos 0.4"), "{}", chaos.label);
        assert!(RegistryStorm
            .cells(&ExperimentConfig {
                nodes: vec![],
                ..cfg.clone()
            })
            .is_err());
        assert!(RegistryStorm
            .cells(&ExperimentConfig {
                nodes: vec![0],
                ..cfg
            })
            .is_err());
    }

    fn run_chaotic(shards: usize, load: f64, intensity: f64, index: usize) -> CellResult {
        let cfg = ExperimentConfig {
            nodes: vec![shards],
            ..ExperimentConfig::paper_default("registry-storm").unwrap()
        };
        let table = CalibrationTable::builtin_fallback();
        let ctx = SimContext {
            cfg: &cfg,
            table: &table,
        };
        let mut cell = Cell::new(
            "test",
            StormCell {
                shards,
                load,
                intensity,
            },
        );
        cell.id = CellId {
            scenario: "registry-storm",
            index,
        };
        RegistryStorm.run_cell(&ctx, &cell).unwrap()
    }

    fn run(shards: usize, load: f64, index: usize) -> CellResult {
        run_chaotic(shards, load, 0.0, index)
    }

    #[test]
    fn chaos_cell_reports_availability_and_stays_deterministic() {
        let a = run_chaotic(4, 0.9, STORM_CHAOS_INTENSITY, 8);
        let b = run_chaotic(4, 0.9, STORM_CHAOS_INTENSITY, 8);
        assert_eq!(a.values, b.values);
        assert_eq!(a.breakdown, b.breakdown);
        let stat = |r: &CellResult, key: &str| {
            r.breakdown
                .iter()
                .find(|(k, _)| k == key)
                .map(|&(_, v)| v)
                .unwrap()
        };
        let avail = stat(&a, "sat:availability");
        assert!((0.0..=1.0).contains(&avail), "availability {avail}");
        // per-session percentiles: monotone in q, bounded by [0, 1]
        let (p01, p05, p50) = (
            stat(&a, "sat:avail p01"),
            stat(&a, "sat:avail p05"),
            stat(&a, "sat:avail p50"),
        );
        assert!((0.0..=1.0).contains(&p01), "p01 {p01}");
        assert!(p01 <= p05 && p05 <= p50, "quantiles must be monotone");
        // the fault-free sweep cells always sit at exactly 1.0: every
        // session delivers every byte, and the quantile estimator
        // clamps to the exact observed maximum
        let calm = run(4, 0.9, 2);
        assert_eq!(stat(&calm, "sat:availability"), 1.0);
        assert_eq!(stat(&calm, "sat:failed sessions"), 0.0);
        assert_eq!(stat(&calm, "sat:avail p01"), 1.0);
        assert_eq!(stat(&calm, "sat:avail p50"), 1.0);
    }

    #[test]
    fn storm_cell_is_deterministic_for_a_fixed_seed() {
        let a = run(2, 0.5, 1);
        let b = run(2, 0.5, 1);
        assert_eq!(a.values, b.values);
        assert_eq!(a.breakdown, b.breakdown);
        // a different cell index reseeds the arrival stream
        let c = run(2, 0.5, 2);
        assert!(a.values != c.values || a.breakdown != c.breakdown);
    }

    #[test]
    fn saturation_knee_is_visible_past_unit_load() {
        let calm = run(2, 0.25, 0);
        let past = run(2, 1.2, 3);
        let (calm_p99, past_p99) = (calm.values[0], past.values[0]);
        assert!(
            past_p99 > 3.0 * calm_p99,
            "no knee: p99 {past_p99} at 1.2x vs {calm_p99} at 0.25x"
        );
        let stat = |r: &CellResult, key: &str| {
            r.breakdown
                .iter()
                .find(|(k, _)| k == key)
                .map(|&(_, v)| v)
                .unwrap()
        };
        assert_eq!(stat(&calm, "sat:stationary"), 1.0, "calm cell is steady");
        assert_eq!(stat(&past, "sat:stationary"), 0.0, "past the knee it ramps");
        assert!(stat(&past, "sat:end backlog s") > stat(&calm, "sat:end backlog s"));
        assert!(stat(&calm, "sat:utilisation") < stat(&past, "sat:utilisation"));
    }

    #[test]
    fn more_shards_push_the_knee_out() {
        // same 0.9x relative load: absolute arrival rate scales with
        // shard count, and the latency stays of the same order because
        // load is normalised per shard
        let two = run(2, 0.9, 2);
        let eight = run(8, 0.9, 2);
        assert!(two.values[0] > 0.0 && eight.values[0] > 0.0);
        // at fixed *absolute* rate, more shards mean less queueing:
        // run 8 shards at the rate that saturates 2 (load 1.2 * 2/8)
        let relieved = run(8, 1.2 * 2.0 / 8.0, 3);
        let choked = run(2, 1.2, 3);
        assert!(relieved.values[0] < choked.values[0]);
    }
}
