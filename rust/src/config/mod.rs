//! Experiment configuration (JSON-backed).
//!
//! Experiments are reproducible cells of (figure, repetitions, seed,
//! rank counts, problem sizes).  Defaults mirror the paper's setups;
//! `harbor bench --config exp.json` overrides them from a file, and
//! every report embeds the config that produced it.

use std::path::Path;

use anyhow::{Context, Result};

use crate::platform::Platform;
use crate::util::json::{self, Value};

/// Configuration of one figure regeneration.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    /// Which scenario: "fig2", "fig3", "fig4", "fig5a", "fig5b",
    /// "fig1-scale", "mixed-fleet", "build-farm", "chaos-canary",
    /// "registry-storm", "version-churn", "dep-storm" (the live list
    /// is the scenario registry: `harbor bench --list`).
    pub figure: String,
    /// Repetitions per bar (the paper: 5 on the workstation, 3 on Edison).
    pub reps: usize,
    /// Base RNG seed (rep `i` uses `seed + i`).
    pub seed: u64,
    /// MPI rank counts (Figs 3/4 sweep).
    pub ranks: Vec<usize>,
    /// HPGMG problem-size indices (Fig 5 sweep; see `fem::gmg::LADDER`).
    pub sizes: Vec<usize>,
    /// Rank-class batched engine for the modeled workloads (the default;
    /// `false` forces the O(ranks) per-rank reference path).
    pub batched: bool,
    /// Lookahead domains for the container tiers' conservative
    /// parallel DES (`--domains`; see [`crate::des::pdes`]): 1 runs
    /// the serial reference queue, more partitions each cell's event
    /// queue under the WAN lookahead bound.  Renders are
    /// byte-identical for any value — this is a pure parallelism knob.
    pub domains: usize,
    /// Fleet node counts (the `fig1-scale` deployment and
    /// `chaos-canary` upgrade sweeps), CI worker counts (the
    /// `build-farm` sweep), registry shard counts (the
    /// `registry-storm` sweep), or manifest counts (the `dep-storm`
    /// sweep).
    pub nodes: Vec<usize>,
}

/// The Fig 3/4 scale points beyond the paper's sweep (§4.2's ">30 min at
/// ~1000 ranks" regime; Edison had 5576 × 24 cores): 64, 512, and 4096
/// full nodes. Only reachable in reasonable time on the batched engine.
pub const SCALE_RANKS: [usize; 3] = [1536, 12288, 98304];

/// The `fig1-scale` fleet sizes: pull one image onto this many nodes at
/// once (the paper's Fig 1 "pull everywhere" step, grown to the scale
/// PR 1 unlocked for the compute phase).  The 65 536 / 262 144 /
/// 1 048 576 rows run on the collapsed node-class engine
/// (`ClassFleet`), which costs O(classes × layers) events instead of
/// O(nodes × layers) — a per-node walk at 1M nodes is infeasible.
pub const SCALE_NODES: [usize; 7] = [64, 512, 4096, 16384, 65_536, 262_144, 1_048_576];

/// The `build-farm` worker counts: how many CI workers build the
/// per-platform `ARCH_OPT` variant matrix concurrently.
pub const FARM_WORKERS: [usize; 3] = [1, 4, 16];

/// The `chaos-canary` fleet size: the canary upgrade rolls over the
/// full 16k-node fleet (the largest `fig1-scale` point) under faults.
pub const CHAOS_FLEET: usize = 16384;

/// The `registry-storm` shard counts: how many FIFO frontends the
/// front door multiplexes the open-loop session storm onto (`nodes`
/// carries these; the offered-load sweep is built into the scenario).
pub const STORM_SHARDS: [usize; 3] = [2, 4, 8];

/// The `dep-storm` manifest counts: how many randomly drawn root
/// manifests the cold-resolve storm pushes through the resolver and
/// the CI farm (`nodes` carries these).
pub const STORM_MANIFESTS: [usize; 3] = [16, 64, 256];

impl ExperimentConfig {
    /// The paper's setup for each figure.
    pub fn paper_default(figure: &str) -> Result<Self> {
        let cfg = match figure {
            "fig2" => ExperimentConfig {
                figure: "fig2".into(),
                reps: 5,
                seed: 42,
                ranks: vec![1],
                sizes: vec![],
                batched: true,
                domains: 1,
                nodes: vec![],
            },
            "fig3" => ExperimentConfig {
                figure: "fig3".into(),
                reps: 3,
                seed: 42,
                ranks: vec![24, 48, 96, 192],
                sizes: vec![],
                batched: true,
                domains: 1,
                nodes: vec![],
            },
            "fig4" => ExperimentConfig {
                figure: "fig4".into(),
                reps: 3,
                seed: 42,
                ranks: vec![24, 48, 96],
                sizes: vec![],
                batched: true,
                domains: 1,
                nodes: vec![],
            },
            "fig5a" => ExperimentConfig {
                figure: "fig5a".into(),
                reps: 5,
                seed: 42,
                ranks: vec![16],
                sizes: vec![2, 1, 0],
                batched: true,
                domains: 1,
                nodes: vec![],
            },
            "fig5b" => ExperimentConfig {
                figure: "fig5b".into(),
                reps: 5,
                seed: 42,
                ranks: vec![192],
                sizes: vec![2, 1, 0],
                batched: true,
                domains: 1,
                nodes: vec![],
            },
            "fig1-scale" => ExperimentConfig {
                figure: "fig1-scale".into(),
                reps: 1,
                seed: 42,
                ranks: vec![],
                sizes: vec![],
                batched: true,
                domains: 1,
                nodes: SCALE_NODES.to_vec(),
            },
            // co-scheduled tenants on the shared Lustre (the §4
            // discussion case the paper never measures): one figure per
            // rank count, rows per co-tenancy configuration
            "mixed-fleet" => ExperimentConfig {
                figure: "mixed-fleet".into(),
                reps: 3,
                seed: 42,
                ranks: vec![24, 96],
                sizes: vec![],
                batched: true,
                domains: 1,
                nodes: vec![],
            },
            // the CI build farm (the §4.3 per-platform ARCH_OPT matrix
            // at fleet scale): `nodes` carries the worker counts; the
            // scenario is deterministic, so one rep suffices
            "build-farm" => ExperimentConfig {
                figure: "build-farm".into(),
                reps: 1,
                seed: 42,
                ranks: vec![],
                sizes: vec![],
                batched: true,
                domains: 1,
                nodes: FARM_WORKERS.to_vec(),
            },
            // the chaos canary upgrade: `nodes` carries the fleet
            // size(s); the intensity x retry-policy sweep is built into
            // the scenario, and cells are seeded from `CellId::seed`,
            // so one rep suffices
            "chaos-canary" => ExperimentConfig {
                figure: "chaos-canary".into(),
                reps: 1,
                seed: 42,
                ranks: vec![],
                sizes: vec![],
                batched: true,
                domains: 1,
                nodes: vec![CHAOS_FLEET],
            },
            // the registry front-door storm: `nodes` carries the shard
            // counts; the offered-load sweep and arrival seeding live
            // in the scenario, so one rep suffices
            "registry-storm" => ExperimentConfig {
                figure: "registry-storm".into(),
                reps: 1,
                seed: 42,
                ranks: vec![],
                sizes: vec![],
                batched: true,
                domains: 1,
                nodes: STORM_SHARDS.to_vec(),
            },
            // the version-churn sweep: cells are the fixed bump
            // targets (see `scenario::version_churn::BUMP_TARGETS`),
            // resolution is seed-invariant and the builder is
            // deterministic, so one rep suffices and no dimension
            // sweeps
            "version-churn" => ExperimentConfig {
                figure: "version-churn".into(),
                reps: 1,
                seed: 42,
                ranks: vec![],
                sizes: vec![],
                batched: true,
                domains: 1,
                nodes: vec![],
            },
            // the cold-resolve storm: `nodes` carries the manifest
            // counts; manifest draws are seeded from `CellId::seed`,
            // so one rep suffices
            "dep-storm" => ExperimentConfig {
                figure: "dep-storm".into(),
                reps: 1,
                seed: 42,
                ranks: vec![],
                sizes: vec![],
                batched: true,
                domains: 1,
                nodes: STORM_MANIFESTS.to_vec(),
            },
            // no name enumeration here: the live list belongs to the
            // scenario registry (`harbor bench --list`), and a second
            // hard-coded copy would go stale
            other => {
                anyhow::bail!(
                    "no paper default for figure `{other}` \
                     (`harbor bench --list` shows the registered scenarios)"
                )
            }
        };
        Ok(cfg)
    }

    /// The paper-scale extension of a figure: same setup, rank counts
    /// from [`SCALE_RANKS`], one rep (each cell is a full Edison-scale
    /// job). Only Figs 3 and 4 sweep ranks.
    pub fn paper_scale(figure: &str) -> Result<Self> {
        let mut cfg = Self::paper_default(figure)?;
        match figure {
            "fig3" | "fig4" => {
                cfg.ranks = SCALE_RANKS.to_vec();
                cfg.reps = 1;
            }
            other => anyhow::bail!("scale points are defined for fig3|fig4 (got `{other}`)"),
        }
        Ok(cfg)
    }

    /// Serialise to the report-embedded JSON form.
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("figure", Value::str(self.figure.clone())),
            ("reps", Value::num(self.reps as f64)),
            ("seed", Value::num(self.seed as f64)),
            (
                "ranks",
                Value::Arr(self.ranks.iter().map(|&r| Value::num(r as f64)).collect()),
            ),
            (
                "sizes",
                Value::Arr(self.sizes.iter().map(|&s| Value::num(s as f64)).collect()),
            ),
            ("batched", Value::Bool(self.batched)),
            ("domains", Value::num(self.domains as f64)),
            (
                "nodes",
                Value::Arr(self.nodes.iter().map(|&n| Value::num(n as f64)).collect()),
            ),
        ])
    }

    /// Parse a config: `figure` selects the paper defaults, any other
    /// present key overrides them.
    pub fn from_json(v: &Value) -> Result<Self> {
        let figure = v
            .get("figure")
            .as_str()
            .context("config missing `figure`")?
            .to_string();
        let mut cfg = Self::paper_default(&figure)?;
        if let Some(r) = v.get("reps").as_u64() {
            cfg.reps = r as usize;
        }
        if let Some(s) = v.get("seed").as_u64() {
            cfg.seed = s;
        }
        if let Some(arr) = v.get("ranks").as_arr() {
            cfg.ranks = arr
                .iter()
                .map(|x| x.as_u64().map(|u| u as usize).context("bad rank"))
                .collect::<Result<_>>()?;
        }
        if let Some(arr) = v.get("sizes").as_arr() {
            cfg.sizes = arr
                .iter()
                .map(|x| x.as_u64().map(|u| u as usize).context("bad size"))
                .collect::<Result<_>>()?;
        }
        if let Some(b) = v.get("batched").as_bool() {
            cfg.batched = b;
        }
        if let Some(d) = v.get("domains").as_u64() {
            anyhow::ensure!(d >= 1, "`domains` must be >= 1 (got {d})");
            cfg.domains = d as usize;
        }
        if let Some(arr) = v.get("nodes").as_arr() {
            cfg.nodes = arr
                .iter()
                .map(|x| x.as_u64().map(|u| u as usize).context("bad node count"))
                .collect::<Result<_>>()?;
        }
        Ok(cfg)
    }

    /// Load a config from a JSON file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json(&json::parse(&text)?)
    }

    /// Write the JSON form to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_pretty())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Expand the evaluation matrix: the cross product
    /// `ranks × sizes × platforms × reps` in deterministic row-major
    /// order (ranks outermost, reps innermost — outer dimensions group
    /// figures, inner dimensions group rows and samples, matching the
    /// paper's figure layout).  Scenarios pass the dimension slices they
    /// actually sweep; an empty `ranks`/`sizes` slice contributes a
    /// single placeholder point (`ranks = 0` / `size = 0`) so
    /// non-sweeping figures still expand.
    ///
    /// `seed` is the historical per-repetition workload seed
    /// (`self.seed + rep`), which keeps the migrated figures
    /// bit-identical to the pre-scenario coordinator; scenarios that
    /// want collision-free per-cell streams use
    /// [`CellId::seed`](crate::scenario::CellId::seed) instead.
    pub fn expand(
        &self,
        platforms: &[Platform],
        ranks: &[usize],
        sizes: &[usize],
    ) -> Vec<MatrixPoint> {
        let ranks_dim: &[usize] = if ranks.is_empty() { &[0] } else { ranks };
        let sizes_dim: &[usize] = if sizes.is_empty() { &[0] } else { sizes };
        let mut points =
            Vec::with_capacity(ranks_dim.len() * sizes_dim.len() * platforms.len() * self.reps);
        for (ranks_idx, &ranks) in ranks_dim.iter().enumerate() {
            for (size_idx, &size) in sizes_dim.iter().enumerate() {
                for (platform_idx, &platform) in platforms.iter().enumerate() {
                    for rep in 0..self.reps {
                        points.push(MatrixPoint {
                            ranks,
                            ranks_idx,
                            size,
                            size_idx,
                            platform,
                            platform_idx,
                            rep,
                            seed: self.seed + rep as u64,
                        });
                    }
                }
            }
        }
        points
    }
}

/// One cell of the `(ranks × sizes × platforms × reps)` evaluation
/// matrix, produced by [`ExperimentConfig::expand`].  Carries both the
/// dimension values and their indices so scenarios can group rows and
/// figures without re-deriving positions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MatrixPoint {
    /// MPI rank count (0 when the scenario does not sweep ranks).
    pub ranks: usize,
    /// Index of `ranks` in the swept slice.
    pub ranks_idx: usize,
    /// Problem-size index (0 when the scenario does not sweep sizes).
    pub size: usize,
    /// Index of `size` in the swept slice.
    pub size_idx: usize,
    /// Execution platform.
    pub platform: Platform,
    /// Index of `platform` in the swept slice.
    pub platform_idx: usize,
    /// Repetition index.
    pub rep: usize,
    /// Workload seed for this repetition (`cfg.seed + rep`).
    pub seed: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_the_paper() {
        let f3 = ExperimentConfig::paper_default("fig3").unwrap();
        assert_eq!(f3.ranks, vec![24, 48, 96, 192]);
        assert_eq!(f3.reps, 3);
        let f2 = ExperimentConfig::paper_default("fig2").unwrap();
        assert_eq!(f2.reps, 5);
        assert!(ExperimentConfig::paper_default("fig9").is_err());
    }

    #[test]
    fn json_round_trip() {
        let mut cfg = ExperimentConfig::paper_default("fig4").unwrap();
        cfg.batched = false;
        cfg.domains = 4;
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn domains_default_to_serial_and_reject_zero() {
        let cfg = ExperimentConfig::paper_default("fig1-scale").unwrap();
        assert_eq!(cfg.domains, 1, "serial reference queue by default");
        let v = json::parse(r#"{"figure": "fig1-scale", "domains": 4}"#).unwrap();
        assert_eq!(ExperimentConfig::from_json(&v).unwrap().domains, 4);
        let bad = json::parse(r#"{"figure": "fig1-scale", "domains": 0}"#).unwrap();
        assert!(ExperimentConfig::from_json(&bad).is_err());
    }

    #[test]
    fn fig1_scale_sweeps_fleet_sizes() {
        let cfg = ExperimentConfig::paper_default("fig1-scale").unwrap();
        assert_eq!(cfg.nodes, SCALE_NODES.to_vec());
        assert_eq!(*cfg.nodes.last().unwrap(), 1_048_576);
        assert!(cfg.nodes.len() >= 7);
        assert!(cfg.ranks.is_empty());
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn scale_points_extend_fig34_only() {
        let f3 = ExperimentConfig::paper_scale("fig3").unwrap();
        assert_eq!(f3.ranks, SCALE_RANKS.to_vec());
        assert_eq!(f3.reps, 1);
        assert!(f3.batched);
        let f4 = ExperimentConfig::paper_scale("fig4").unwrap();
        assert_eq!(f4.ranks, vec![1536, 12288, 98304]);
        assert!(ExperimentConfig::paper_scale("fig2").is_err());
        assert!(ExperimentConfig::paper_scale("fig5a").is_err());
    }

    #[test]
    fn overrides_apply_over_defaults() {
        let v = json::parse(r#"{"figure": "fig3", "reps": 7, "ranks": [24]}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(cfg.reps, 7);
        assert_eq!(cfg.ranks, vec![24]);
        assert_eq!(cfg.seed, 42); // default survives
    }

    #[test]
    fn mixed_fleet_defaults() {
        let cfg = ExperimentConfig::paper_default("mixed-fleet").unwrap();
        assert_eq!(cfg.ranks, vec![24, 96]);
        assert_eq!(cfg.reps, 3);
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn build_farm_sweeps_worker_counts() {
        let cfg = ExperimentConfig::paper_default("build-farm").unwrap();
        assert_eq!(cfg.nodes, FARM_WORKERS.to_vec());
        assert_eq!(cfg.reps, 1);
        assert!(cfg.ranks.is_empty());
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn chaos_canary_targets_the_full_fleet() {
        let cfg = ExperimentConfig::paper_default("chaos-canary").unwrap();
        assert_eq!(cfg.nodes, vec![CHAOS_FLEET]);
        assert_eq!(cfg.reps, 1);
        assert!(cfg.ranks.is_empty() && cfg.sizes.is_empty());
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn registry_storm_sweeps_shard_counts() {
        let cfg = ExperimentConfig::paper_default("registry-storm").unwrap();
        assert_eq!(cfg.nodes, STORM_SHARDS.to_vec());
        assert_eq!(cfg.reps, 1);
        assert!(cfg.ranks.is_empty() && cfg.sizes.is_empty());
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn version_churn_and_dep_storm_defaults() {
        let churn = ExperimentConfig::paper_default("version-churn").unwrap();
        assert_eq!(churn.reps, 1);
        assert!(churn.ranks.is_empty() && churn.sizes.is_empty() && churn.nodes.is_empty());
        let back = ExperimentConfig::from_json(&churn.to_json()).unwrap();
        assert_eq!(churn, back);
        let storm = ExperimentConfig::paper_default("dep-storm").unwrap();
        assert_eq!(storm.nodes, STORM_MANIFESTS.to_vec());
        assert_eq!(storm.reps, 1);
        assert!(storm.ranks.is_empty() && storm.sizes.is_empty());
        let back = ExperimentConfig::from_json(&storm.to_json()).unwrap();
        assert_eq!(storm, back);
    }

    #[test]
    fn expand_orders_ranks_sizes_platforms_reps() {
        let cfg = ExperimentConfig {
            reps: 2,
            seed: 7,
            ..ExperimentConfig::paper_default("fig3").unwrap()
        };
        let platforms = [Platform::Native, Platform::Docker];
        let pts = cfg.expand(&platforms, &[24, 48], &[]);
        assert_eq!(pts.len(), 8); // 2 ranks x 1 size x 2 platforms x 2 reps
        // innermost dimension: reps
        assert_eq!((pts[0].rep, pts[1].rep), (0, 1));
        assert_eq!(pts[0].platform, Platform::Native);
        assert_eq!(pts[2].platform, Platform::Docker);
        // outermost dimension: ranks
        assert_eq!(pts[0].ranks, 24);
        assert_eq!(pts[4].ranks, 48);
        assert_eq!(pts[4].ranks_idx, 1);
        // per-rep workload seeds are the historical `seed + rep`
        assert_eq!((pts[0].seed, pts[1].seed), (7, 8));
        // empty dims collapse to one placeholder point
        let no_dims = cfg.expand(&platforms, &[], &[]);
        assert_eq!(no_dims.len(), 4); // 2 platforms x 2 reps
        assert_eq!((no_dims[0].ranks, no_dims[0].size), (0, 0));
    }

    #[test]
    fn file_round_trip() {
        let cfg = ExperimentConfig::paper_default("fig5a").unwrap();
        let path = std::env::temp_dir().join("harbor-exp-test.json");
        cfg.save(&path).unwrap();
        let back = ExperimentConfig::load(&path).unwrap();
        assert_eq!(cfg, back);
        let _ = std::fs::remove_file(&path);
    }
}
