//! The PJRT execution engine.
//!
//! One process-wide CPU client; executables are compiled from HLO text
//! on first use and cached by entry name.  All tensors are f32 (the
//! dtype the L2 layer exports); [`TensorBuf`] carries shape + data.
//!
//! Interchange is HLO *text* — see `python/compile/aot.py` for why the
//! serialized-proto path is a dead end with this xla_extension build.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{EntryMeta, Manifest};

/// A host-side f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorBuf {
    /// Dimension extents (empty = scalar).
    pub shape: Vec<usize>,
    /// Row-major element data.
    pub data: Vec<f32>,
}

impl TensorBuf {
    /// A tensor from parts (length must match the shape).
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        TensorBuf { shape, data }
    }

    /// A zero tensor of the given shape.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product::<usize>().max(1);
        TensorBuf {
            shape,
            data: vec![0.0; n],
        }
    }

    /// A rank-1 tensor holding one value.
    pub fn scalar1(v: f32) -> Self {
        TensorBuf {
            shape: vec![1],
            data: vec![v],
        }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Compiled-executable cache over the PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Total `execute` calls (performance accounting).
    pub calls: u64,
}

impl Engine {
    /// Open the artifacts directory (compiles lazily, per entry).
    pub fn open(dir: PathBuf) -> Result<Engine> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine {
            client,
            dir,
            manifest,
            cache: HashMap::new(),
            calls: 0,
        })
    }

    /// Open the default artifacts location.
    pub fn open_default() -> Result<Engine> {
        Engine::open(super::artifacts_dir())
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn compile(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let entry = self
                .manifest
                .entry(name)
                .with_context(|| format!("no such artifact `{name}`"))?
                .clone();
            let path = self.dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Pre-compile an entry (warm-up; e.g. before timing).
    pub fn warm(&mut self, name: &str) -> Result<()> {
        self.compile(name).map(|_| ())
    }

    /// Execute `name` with `inputs`; returns the tuple elements.
    pub fn execute(&mut self, name: &str, inputs: &[TensorBuf]) -> Result<Vec<TensorBuf>> {
        let entry = self
            .manifest
            .entry(name)
            .with_context(|| format!("no such artifact `{name}`"))?
            .clone();
        self.check_inputs(&entry, inputs)?;

        self.compile(name)?;
        let exe = &self.cache[name];

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let lit = xla::Literal::vec1(&t.data);
                if t.shape.len() == 1 {
                    Ok(lit)
                } else {
                    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                    lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
                }
            })
            .collect::<Result<_>>()?;

        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple literal.
        let elems = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untupling result of {name}: {e:?}"))?;
        if elems.len() != entry.outputs.len() {
            bail!(
                "{name}: manifest promises {} outputs, executable returned {}",
                entry.outputs.len(),
                elems.len()
            );
        }
        self.calls += 1;
        elems
            .into_iter()
            .zip(&entry.outputs)
            .map(|(lit, meta)| {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("reading output of {name}: {e:?}"))?;
                Ok(TensorBuf::new(meta.shape.clone(), data))
            })
            .collect()
    }

    fn check_inputs(&self, entry: &EntryMeta, inputs: &[TensorBuf]) -> Result<()> {
        if inputs.len() != entry.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                entry.name,
                entry.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, meta)) in inputs.iter().zip(&entry.inputs).enumerate() {
            if t.shape != meta.shape {
                bail!(
                    "{} input {i}: expected shape {:?}, got {:?}",
                    entry.name,
                    meta.shape,
                    t.shape
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, artifacts_dir};

    fn engine() -> Option<Engine> {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(Engine::open(artifacts_dir()).unwrap())
    }

    #[test]
    fn tensorbuf_basics() {
        let z = TensorBuf::zeros(vec![2, 3]);
        assert_eq!(z.len(), 6);
        assert_eq!(TensorBuf::scalar1(2.5).data, vec![2.5]);
    }

    #[test]
    fn dot_entry_computes_a_dot_product() {
        let Some(mut e) = engine() else { return };
        let n = 4096;
        let a = TensorBuf::new(vec![n], (0..n).map(|i| (i % 7) as f32 * 0.1).collect());
        let b = TensorBuf::new(vec![n], (0..n).map(|i| (i % 5) as f32 * 0.2).collect());
        let out = e.execute("dot_L4096", &[a.clone(), b.clone()]).unwrap();
        assert_eq!(out.len(), 1);
        let want: f32 = a.data.iter().zip(&b.data).map(|(x, y)| x * y).sum();
        let got = out[0].data[0];
        assert!(
            (got - want).abs() <= 1e-2 * want.abs().max(1.0),
            "got {got}, want {want}"
        );
        assert_eq!(e.calls, 1);
    }

    #[test]
    fn laplacian_entry_matches_manual_stencil() {
        let Some(mut e) = engine() else { return };
        let n = 16usize;
        let np = n + 2;
        // u = linear ramp in x: interior Laplacian of the *scaled* operator
        // is -h^2 lap = 0 in the interior away from the zero-halo boundary
        let mut u = vec![0.0f32; np * np * np];
        for z in 0..np {
            for y in 0..np {
                for x in 0..np {
                    u[(z * np + y) * np + x] = x as f32;
                }
            }
        }
        let out = e
            .execute("cg_apdot_p3d_n16", &[TensorBuf::new(vec![np, np, np], u)])
            .unwrap();
        assert_eq!(out.len(), 2);
        let ap = &out[0];
        assert_eq!(ap.len(), n * n * n);
        // interior cell well away from the boundary: 6c - sum(neigh) = 0
        let idx = |z: usize, y: usize, x: usize| (z * n + y) * n + x;
        assert!(ap.data[idx(7, 7, 7)].abs() < 1e-4);
    }

    #[test]
    fn wrong_shape_is_rejected_before_pjrt() {
        let Some(mut e) = engine() else { return };
        let bad = TensorBuf::zeros(vec![3, 3]);
        let err = e.execute("dot_L4096", &[bad.clone(), bad]).unwrap_err();
        assert!(err.to_string().contains("expected shape"));
    }

    #[test]
    fn wrong_arity_is_rejected() {
        let Some(mut e) = engine() else { return };
        let err = e
            .execute("dot_L4096", &[TensorBuf::zeros(vec![4096])])
            .unwrap_err();
        assert!(err.to_string().contains("expected 2 inputs"));
    }

    #[test]
    fn unknown_entry_is_an_error() {
        let Some(mut e) = engine() else { return };
        assert!(e.execute("nonexistent", &[]).is_err());
    }

    #[test]
    fn executables_are_cached() {
        let Some(mut e) = engine() else { return };
        let a = TensorBuf::zeros(vec![4096]);
        e.execute("dot_L4096", &[a.clone(), a.clone()]).unwrap();
        e.execute("dot_L4096", &[a.clone(), a]).unwrap();
        assert_eq!(e.cache.len(), 1);
        assert_eq!(e.calls, 2);
    }
}
