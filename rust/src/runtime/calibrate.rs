//! Compute-cost calibration.
//!
//! The simulation charges compute segments with *measured* per-call
//! costs: for each artifact we execute it `reps` times on this machine
//! (after a warm-up compile + run) and store the median wall time.  The
//! table is persisted as JSON so `cargo bench` runs don't re-measure.
//!
//! A built-in fallback table (measured on the development machine) keeps
//! the simulation usable in environments where PJRT is unavailable; the
//! `source` field records which one a run used.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::des::Duration;
use crate::util::json::{self, Value};

use super::engine::{Engine, TensorBuf};

/// Per-entry measured execution costs.
#[derive(Debug, Clone)]
pub struct CalibrationTable {
    /// entry name -> median seconds per call.
    costs: BTreeMap<String, f64>,
    /// "measured" or "builtin-fallback".
    pub source: String,
}

impl CalibrationTable {
    /// Cost per call of `entry`; falls back to a size-derived estimate
    /// for names missing from the table (e.g. newly added entries).
    pub fn cost(&self, entry: &str) -> Duration {
        if let Some(&s) = self.costs.get(entry) {
            return Duration::from_secs_f64(s);
        }
        // crude estimate from the built-in table's closest sibling
        let prefix = entry.split("_n").next().unwrap_or(entry);
        let sibling = self
            .costs
            .iter()
            .find(|(k, _)| k.starts_with(prefix))
            .map(|(_, &v)| v)
            .unwrap_or(1e-4);
        Duration::from_secs_f64(sibling)
    }

    /// Whether a measured cost exists for `entry`.
    pub fn contains(&self, entry: &str) -> bool {
        self.costs.contains_key(entry)
    }

    /// Number of calibrated entries.
    pub fn len(&self) -> usize {
        self.costs.len()
    }

    /// Whether the table is empty.
    pub fn is_empty(&self) -> bool {
        self.costs.is_empty()
    }

    // ---- persistence -----------------------------------------------------

    /// Serialise to the calibration.json form.
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("source", Value::str(self.source.clone())),
            (
                "costs_s",
                Value::Obj(
                    self.costs
                        .iter()
                        .map(|(k, &v)| (k.clone(), Value::Num(v)))
                        .collect(),
                ),
            ),
        ])
    }

    /// Write the JSON form to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_pretty())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Load a table from `path`.
    pub fn load(path: &Path) -> Result<CalibrationTable> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text)?;
        let mut costs = BTreeMap::new();
        if let Some(o) = v.get("costs_s").as_obj() {
            for (k, val) in o {
                if let Some(f) = val.as_f64() {
                    costs.insert(k.clone(), f);
                }
            }
        }
        Ok(CalibrationTable {
            costs,
            source: v.get("source").as_str().unwrap_or("unknown").to_string(),
        })
    }

    /// Load `artifacts/calibration.json` if present, else measure if PJRT
    /// artifacts exist, else use the built-in fallback.
    pub fn load_or_default(engine: Option<&mut Engine>) -> CalibrationTable {
        let path = super::artifacts_dir().join("calibration.json");
        if let Ok(t) = CalibrationTable::load(&path) {
            if !t.is_empty() {
                return t;
            }
        }
        if let Some(engine) = engine {
            if let Ok(t) = calibrate(engine, 5) {
                let _ = t.save(&path);
                return t;
            }
        }
        Self::builtin_fallback()
    }

    /// Conservative per-entry costs measured once on the development
    /// machine (Xeon-class CPU, interpret-lowered HLO via PJRT CPU).
    pub fn builtin_fallback() -> CalibrationTable {
        let entries: &[(&str, f64)] = &[
            ("assemble_rhs3d_n16", 3.0e-5),
            ("assemble_rhs3d_n32", 1.6e-4),
            ("cg_apdot_el3d_n16", 4.5e-4),
            ("cg_apdot_p3d_n16", 3.5e-5),
            ("cg_apdot_p3d_n32", 2.4e-4),
            ("cg_pupdate_L12288", 1.2e-5),
            ("cg_pupdate_L32768", 2.6e-5),
            ("cg_pupdate_L4096", 6.0e-6),
            ("cg_update_L12288", 2.2e-5),
            ("cg_update_L32768", 5.2e-5),
            ("cg_update_L4096", 1.0e-5),
            ("coarse_solve3d_n4", 1.5e-4),
            ("dot_L12288", 8.0e-6),
            ("dot_L32768", 1.6e-5),
            ("dot_L4096", 4.0e-6),
            ("lu_poisson2d_n32", 2.4e-2),
            ("norm2_n16", 6.0e-6),
            ("norm2_n32", 1.8e-5),
            ("norm2_n4", 3.0e-6),
            ("norm2_n8", 4.0e-6),
            ("precond_vcycle_n32", 3.0e-3),
            ("prolong_add3d_n16", 1.3e-4),
            ("prolong_add3d_n4", 8.0e-6),
            ("prolong_add3d_n8", 2.4e-5),
            ("resid3d_n16", 3.2e-5),
            ("resid3d_n32", 2.2e-4),
            ("resid3d_n4", 5.0e-6),
            ("resid3d_n8", 9.0e-6),
            ("restrict3d_n16", 1.6e-5),
            ("restrict3d_n32", 9.0e-5),
            ("restrict3d_n8", 6.0e-6),
            ("smooth3d_n16", 3.6e-5),
            ("smooth3d_n32", 2.6e-4),
            ("smooth3d_n4", 4.0e-6),
            ("smooth3d_n8", 1.0e-5),
        ];
        CalibrationTable {
            costs: entries.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
            source: "builtin-fallback".into(),
        }
    }
}

/// Measure every manifest entry: warm-up, then median of `reps` calls
/// with zero-filled (shape-correct) inputs.
pub fn calibrate(engine: &mut Engine, reps: usize) -> Result<CalibrationTable> {
    let names: Vec<String> = engine.manifest().names().map(String::from).collect();
    let mut costs = BTreeMap::new();
    for name in names {
        let entry = engine.manifest().entry(&name).unwrap().clone();
        let inputs: Vec<TensorBuf> = entry
            .inputs
            .iter()
            .map(|m| {
                let mut t = TensorBuf::zeros(m.shape.clone());
                // keep scalars away from 0 (alpha=0 still executes the
                // same graph, but e.g. h=0 keeps values finite anyway;
                // timing does not depend on values for these kernels)
                if t.len() == 1 {
                    t.data[0] = 0.5;
                }
                t
            })
            .collect();
        engine.warm(&name)?;
        engine.execute(&name, &inputs)?; // first-call noise out of the way
        let mut samples = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t0 = Instant::now();
            engine.execute(&name, &inputs)?;
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        costs.insert(name, samples[samples.len() / 2]);
    }
    Ok(CalibrationTable {
        costs,
        source: "measured".into(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fallback_covers_all_entry_families() {
        let t = CalibrationTable::builtin_fallback();
        assert!(t.len() >= 30);
        assert!(t.contains("cg_apdot_p3d_n32"));
        assert!(t.cost("cg_apdot_p3d_n32") > Duration::ZERO);
    }

    #[test]
    fn missing_entry_estimates_from_sibling() {
        let t = CalibrationTable::builtin_fallback();
        let est = t.cost("cg_apdot_p3d_n64"); // not in the table
        assert!(est > Duration::ZERO);
    }

    #[test]
    fn json_round_trip() {
        let t = CalibrationTable::builtin_fallback();
        let text = t.to_json().to_pretty();
        let dir = std::env::temp_dir().join("harbor-calib-test.json");
        std::fs::write(&dir, &text).unwrap();
        let back = CalibrationTable::load(&dir).unwrap();
        assert_eq!(back.len(), t.len());
        assert_eq!(back.source, "builtin-fallback");
        assert_eq!(back.cost("dot_L4096"), t.cost("dot_L4096"));
        let _ = std::fs::remove_file(&dir);
    }

    #[test]
    fn measured_calibration_when_artifacts_present() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut e = Engine::open_default().unwrap();
        // calibrate a copy of the manifest subset quickly: just verify the
        // full pass works and produces sane positive costs
        let t = calibrate(&mut e, 3).unwrap();
        assert_eq!(t.source, "measured");
        assert!(t.len() >= 30);
        for name in ["dot_L4096", "cg_apdot_p3d_n32", "lu_poisson2d_n32"] {
            let c = t.cost(name).as_secs_f64();
            assert!(c > 0.0 && c < 5.0, "{name}: {c}");
        }
        // bigger problems cost more
        assert!(t.cost("cg_apdot_p3d_n32") > t.cost("cg_apdot_p3d_n16"));
    }
}
