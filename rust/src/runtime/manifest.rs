//! The AOT artifact manifest (written by `python/compile/aot.py`).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json;

/// Input/output tensor description.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorMeta {
    /// Dimension extents (empty = scalar).
    pub shape: Vec<usize>,
    /// Element type name (always "f32" in this artifact set).
    pub dtype: String,
}

impl TensorMeta {
    /// Number of elements a tensor of this shape holds.
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(v: &json::Value) -> Result<Self> {
        let shape = v
            .get("shape")
            .as_arr()
            .context("tensor meta missing `shape`")?
            .iter()
            .map(|d| d.as_u64().map(|u| u as usize).context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = v
            .get("dtype")
            .as_str()
            .context("tensor meta missing `dtype`")?
            .to_string();
        Ok(TensorMeta { shape, dtype })
    }
}

/// One AOT-exported entry point.
#[derive(Debug, Clone)]
pub struct EntryMeta {
    /// Entry-point name (what `Engine::execute` looks up).
    pub name: String,
    /// HLO text file relative to the artifact directory.
    pub file: String,
    /// Content hash of the HLO text.
    pub sha256: String,
    /// Input tensor descriptions, in call order.
    pub inputs: Vec<TensorMeta>,
    /// Output tensor descriptions.
    pub outputs: Vec<TensorMeta>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Manifest format version string.
    pub format: String,
    /// Exported entry points.
    pub entries: Vec<EntryMeta>,
}

impl Manifest {
    /// Load `manifest.json` from `dir`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = json::parse(text).context("parsing manifest.json")?;
        let format = v
            .get("format")
            .as_str()
            .context("manifest missing `format`")?
            .to_string();
        if format != "hlo-text/return-tuple" {
            bail!("unsupported artifact format `{format}` (want hlo-text/return-tuple)");
        }
        let mut entries = Vec::new();
        for e in v.get("entries").as_arr().context("manifest missing `entries`")? {
            let name = e.get("name").as_str().context("entry missing name")?;
            let file = e.get("file").as_str().context("entry missing file")?;
            let sha256 = e.get("sha256").as_str().unwrap_or("").to_string();
            let inputs = e
                .get("inputs")
                .as_arr()
                .context("entry missing inputs")?
                .iter()
                .map(TensorMeta::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .get("outputs")
                .as_arr()
                .context("entry missing outputs")?
                .iter()
                .map(TensorMeta::from_json)
                .collect::<Result<Vec<_>>>()?;
            entries.push(EntryMeta {
                name: name.to_string(),
                file: file.to_string(),
                sha256,
                inputs,
                outputs,
            });
        }
        Ok(Manifest { format, entries })
    }

    /// Look an entry point up by name.
    pub fn entry(&self, name: &str) -> Option<&EntryMeta> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// All entry-point names.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|e| e.name.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text/return-tuple",
      "entries": [
        {"name": "dot_L4096", "file": "dot_L4096.hlo.txt", "sha256": "ab",
         "inputs": [{"shape": [4096], "dtype": "float32"},
                    {"shape": [4096], "dtype": "float32"}],
         "outputs": [{"shape": [1], "dtype": "float32"}],
         "elapsed_s": 0.1}
      ]}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.entry("dot_L4096").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].shape, vec![4096]);
        assert_eq!(e.inputs[0].element_count(), 4096);
        assert_eq!(e.outputs[0].shape, vec![1]);
        assert_eq!(e.file, "dot_L4096.hlo.txt");
    }

    #[test]
    fn unknown_entry_is_none() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.entry("nope").is_none());
    }

    #[test]
    fn rejects_wrong_format() {
        let bad = SAMPLE.replace("hlo-text/return-tuple", "proto");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"format": "hlo-text/return-tuple"}"#).is_err());
        assert!(Manifest::parse("{}").is_err());
    }

    #[test]
    fn scalar_shape_counts_one() {
        let t = TensorMeta {
            shape: vec![],
            dtype: "float32".into(),
        };
        assert_eq!(t.element_count(), 1);
    }

    #[test]
    fn loads_real_manifest_when_present() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&crate::runtime::artifacts_dir()).unwrap();
        assert!(m.entries.len() >= 30, "expected the full entry set");
        assert!(m.entry("cg_apdot_p3d_n16").is_some());
        assert!(m.entry("lu_poisson2d_n32").is_some());
        for e in &m.entries {
            assert!(!e.inputs.is_empty() || e.name.starts_with("const"), "{}", e.name);
            assert!(!e.outputs.is_empty(), "{}", e.name);
        }
    }
}
