//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` (the only step that runs Python) lowers every L2
//! entry point to HLO *text* plus a `manifest.json`.  This module is the
//! request-path side: it parses the manifest, compiles each artifact
//! once on the PJRT CPU client ([`Engine`]), caches the loaded
//! executables, and exposes a typed `execute` over f32 buffers.
//!
//! [`calibrate`] measures the wall-clock cost of each entry point —
//! those per-call costs are what the discrete-event simulation charges
//! for compute segments at scale (DESIGN.md §3), so the simulated
//! figures rest on *measured* compute times, not guesses.

mod calibrate;
mod engine;
mod manifest;

pub use calibrate::{calibrate, CalibrationTable};
pub use engine::{Engine, TensorBuf};
pub use manifest::{EntryMeta, Manifest};

use std::path::{Path, PathBuf};

/// Locate the artifacts directory: `$HARBOR_ARTIFACTS` or `./artifacts`
/// relative to the workspace root (walking up from cwd).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("HARBOR_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

/// True if the AOT artifacts are present (tests that need PJRT skip
/// politely when they are not).
pub fn artifacts_available() -> bool {
    Path::new(&artifacts_dir()).join("manifest.json").exists()
}
