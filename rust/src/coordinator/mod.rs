//! Experiment orchestration — the paper's Fig 1 pipeline plus the
//! evaluation matrix.
//!
//! [`deploy_pipeline`] walks the full image lifecycle the paper
//! describes (§3.4): parse the Dockerfile → build (layer cache, content
//! hashes) → push to the registry → pull onto the workstation and onto
//! Edison (Shifter's `shifterimg pull`), reporting layer reuse and
//! transfer times.
//!
//! [`Coordinator`] regenerates the evaluation figures: each
//! `ExperimentConfig` expands into the (platform × ranks × size × rep)
//! matrix, every cell runs the corresponding workload through the
//! simulated deployment, and the results aggregate into paper-style
//! [`Figure`]s.

use anyhow::Result;

use crate::bench::{repeat, Figure, Row};
use crate::config::ExperimentConfig;
use crate::container::{
    Builder, Buildfile, Fleet, FleetConfig, FleetReport, LayerStore, PullReport, Registry,
    ShardedRegistry,
};
use crate::des::Duration;
use crate::fem::exec::Exec;
use crate::metrics::Stats;
use crate::platform::Platform;
use crate::runtime::CalibrationTable;
use crate::workload::{
    run_fig2, run_hpgmg, run_poisson_app, AppConfig, Fig2Test, HpgmgConfig,
};

/// The FEniCS-stack buildfile the pipeline builds (the project's real
/// Dockerfile collapsed to our DSL).
pub const FENICS_BUILDFILE: &str = "\
FROM ubuntu:16.04
USER root
RUN apt-get -y update && apt-get -y install petsc slepc openmpi-bin mpich
RUN apt-get -y install python-numpy python-scipy python-sympy swig
RUN pip install ufl ffc fiat instant
RUN git clone dolfin && cmake dolfin && make -j install
ENV FENICS_HOME=/home/fenics
USER fenics
WORKDIR /home/fenics
ENTRYPOINT /bin/bash
";

/// One machine's pull in the deployment trace.
#[derive(Debug, Clone)]
pub struct DeployTarget {
    /// Which machine pulled.
    pub machine: String,
    /// The pull's transfer report.
    pub pull: PullReport,
}

/// The full §3.4 pipeline record.
#[derive(Debug, Clone)]
pub struct DeploymentTrace {
    /// Content hash of the deployed image.
    pub image_id: String,
    /// Layers built fresh by the CI build.
    pub layers_built: usize,
    /// Layers answered from the build cache.
    pub layers_cached: usize,
    /// Modelled build wall time.
    pub build_time: Duration,
    /// Compressed image size in bytes.
    pub image_bytes: u64,
    /// Files across all layers.
    pub image_files: usize,
    /// Per-machine pulls, in deployment order.
    pub targets: Vec<DeployTarget>,
}

impl DeploymentTrace {
    /// Human-readable trace (the Fig 1 pipeline table).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "image {} ({} MB, {} files): {} layers built, {} cached, build {}\n",
            &self.image_id[..12],
            self.image_bytes / 1_000_000,
            self.image_files,
            self.layers_built,
            self.layers_cached,
            self.build_time,
        ));
        for t in &self.targets {
            s.push_str(&format!(
                "  pull -> {:12} {} layers ({} reused), {} MB in {}\n",
                t.machine,
                t.pull.layers_transferred,
                t.pull.layers_reused,
                t.pull.bytes_transferred / 1_000_000,
                t.pull.time,
            ));
        }
        s
    }
}

/// Run the Fig 1 pipeline: build → push → pull on each target machine.
/// `second_build` demonstrates layer caching (a config-only change).
pub fn deploy_pipeline() -> Result<DeploymentTrace> {
    let bf = Buildfile::parse(FENICS_BUILDFILE)?;
    let mut builder = Builder::new();
    let mut ci_store = LayerStore::new();
    let report = builder.build(&bf, "quay.io/fenicsproject/stable:2016.1.0r1", &mut ci_store)?;

    let mut registry = Registry::new();
    registry.push(&report.image, &ci_store)?;

    let mut targets = Vec::new();
    for machine in ["workstation", "edison"] {
        let mut local = LayerStore::new();
        let (_, pull) = registry.pull("quay.io/fenicsproject/stable:2016.1.0r1", &mut local)?;
        targets.push(DeployTarget {
            machine: machine.to_string(),
            pull,
        });
    }

    Ok(DeploymentTrace {
        image_id: report.image.id.0.clone(),
        layers_built: report.layers_built,
        layers_cached: report.layers_cached,
        build_time: report.build_time,
        image_bytes: report.image.size_bytes(&registry.layers),
        image_files: report.image.file_count(&registry.layers),
        targets,
    })
}

/// Build the paper's FEniCS image and publish it behind four shard
/// frontends — the registry side of a fleet deployment campaign.
pub fn fleet_registry(reference: &str) -> Result<ShardedRegistry> {
    let bf = Buildfile::parse(FENICS_BUILDFILE)?;
    let mut store = LayerStore::new();
    let report = Builder::new().build(&bf, reference, &mut store)?;
    let mut registry = Registry::new();
    registry.push(&report.image, &store)?;
    Ok(ShardedRegistry::new(registry, 4))
}

/// Figure runner over the modeled (calibrated) execution mode.
pub struct Coordinator {
    /// Calibration table driving modeled execution times.
    pub table: CalibrationTable,
}

impl Coordinator {
    /// Load the measured calibration table if available (else the
    /// built-in fallback — reports record which).
    pub fn new() -> Self {
        Coordinator {
            table: CalibrationTable::load_or_default(None),
        }
    }

    /// A coordinator over an explicit calibration table.
    pub fn with_table(table: CalibrationTable) -> Self {
        Coordinator { table }
    }

    /// Regenerate the figures selected by `cfg`.
    pub fn run(&self, cfg: &ExperimentConfig) -> Result<Vec<Figure>> {
        match cfg.figure.as_str() {
            "fig1-scale" => self.fig1_scale(cfg),
            "fig2" => self.fig2(cfg),
            "fig3" => self.fig3(cfg),
            "fig4" => self.fig4(cfg),
            "fig5a" => self.fig5(cfg, true),
            "fig5b" => self.fig5(cfg, false),
            other => anyhow::bail!("unknown figure `{other}`"),
        }
    }

    /// Deploy `reference` onto every node of `fleet` concurrently
    /// through `registry`'s shard frontends, in virtual time.  This is
    /// the fleet-scale version of the Fig 1 "pull everywhere" step:
    /// node caches are consulted first, cache-missing layers cross the
    /// WAN once each (peer fan-out) or once per node (direct), and the
    /// report records makespan, WAN/intra-cluster bytes, and cache
    /// accounting.
    ///
    /// # Example
    ///
    /// A cold deploy moves the image once over the WAN; the warm
    /// re-deploy that follows moves nothing:
    ///
    /// ```
    /// use harbor::container::{Builder, Buildfile, LayerStore, Registry};
    /// use harbor::container::{Fleet, FleetConfig, ShardedRegistry};
    /// use harbor::coordinator::Coordinator;
    ///
    /// let bf = Buildfile::parse("FROM ubuntu:16.04\nRUN echo x").unwrap();
    /// let mut store = LayerStore::new();
    /// let image = Builder::new().build(&bf, "app:1", &mut store).unwrap().image;
    /// let mut registry = Registry::new();
    /// registry.push(&image, &store).unwrap();
    ///
    /// let mut sharded = ShardedRegistry::new(registry, 4);
    /// let mut fleet = Fleet::new(FleetConfig::hpc(64));
    /// let coordinator = Coordinator::new();
    ///
    /// let cold = coordinator.deploy_fleet(&mut sharded, &mut fleet, "app:1").unwrap();
    /// let warm = coordinator.deploy_fleet(&mut sharded, &mut fleet, "app:1").unwrap();
    /// assert!(cold.wan_bytes > 0);
    /// assert_eq!(warm.wan_bytes + warm.intra_bytes, 0);
    /// assert!(warm.makespan < cold.makespan);
    /// ```
    pub fn deploy_fleet(
        &self,
        registry: &mut ShardedRegistry,
        fleet: &mut Fleet,
        reference: &str,
    ) -> Result<FleetReport> {
        Ok(fleet.deploy(registry, reference)?)
    }

    /// The `fig1-scale` figure pair: cold pull makespan and warm
    /// re-deploy makespan for each fleet size in `cfg.nodes`.
    fn fig1_scale(&self, cfg: &ExperimentConfig) -> Result<Vec<Figure>> {
        anyhow::ensure!(
            !cfg.nodes.is_empty(),
            "fig1-scale needs at least one fleet size in `nodes`"
        );
        anyhow::ensure!(
            cfg.nodes.iter().all(|&n| n >= 1),
            "fig1-scale fleet sizes must be >= 1 (got {:?})",
            cfg.nodes
        );
        let reference = "quay.io/fenicsproject/stable:2016.1.0r1";
        let mut cold_fig = Figure::new(
            "Fig 1 at fleet scale — cold pull makespan",
            "makespan [s]",
            false,
        );
        let mut warm_fig = Figure::new(
            "Fig 1 at fleet scale — warm re-deploy makespan",
            "makespan [s]",
            false,
        );
        let mut worst_ratio = 0.0f64;
        for &n in &cfg.nodes {
            let mut sharded = fleet_registry(reference)?;
            let mut fleet = Fleet::new(FleetConfig::hpc(n));
            let cold = self.deploy_fleet(&mut sharded, &mut fleet, reference)?;
            let warm = self.deploy_fleet(&mut sharded, &mut fleet, reference)?;
            worst_ratio =
                worst_ratio.max(warm.makespan.as_secs_f64() / cold.makespan.as_secs_f64());
            cold_fig.push(
                Row::new(
                    format!("{n} nodes"),
                    Stats::from_samples(vec![cold.makespan.as_secs_f64()]),
                )
                .with_breakdown(vec![
                    ("wan MB".into(), cold.wan_bytes as f64 / 1e6),
                    ("intra MB".into(), cold.intra_bytes as f64 / 1e6),
                ]),
            );
            warm_fig.push(
                Row::new(
                    format!("{n} nodes"),
                    Stats::from_samples(vec![warm.makespan.as_secs_f64()]),
                )
                .with_breakdown(vec![("cache hit rate".into(), warm.cache.hit_rate())]),
            );
        }
        cold_fig.note(
            "each unique layer crosses the WAN once (4 shards), then peer fan-out \
             (arity 2) over the Aries fabric",
        );
        warm_fig.note(format!(
            "warm/cold makespan ratio {worst_ratio:.5} (acceptance bar: < 0.10)"
        ));
        Ok(vec![cold_fig, warm_fig])
    }

    fn exec(&self) -> Exec<'_> {
        Exec::Modeled { table: &self.table }
    }

    fn fig2(&self, cfg: &ExperimentConfig) -> Result<Vec<Figure>> {
        let mut figures = Vec::new();
        for test in Fig2Test::ALL {
            let mut fig = Figure::new(
                format!("Fig 2 — {} (workstation)", test.label()),
                "run time [s]",
                false,
            );
            for platform in Platform::workstation_set() {
                let stats = repeat(cfg.reps, |rep| {
                    let mut exec = self.exec();
                    run_fig2(test, platform, &mut exec, cfg.seed + rep as u64)
                        .expect("fig2 run")
                        .as_secs_f64()
                });
                fig.push(Row::new(platform.label(), stats));
            }
            fig.note(format!("calibration: {}", self.table.source));
            figures.push(fig);
        }
        Ok(figures)
    }

    fn fig3(&self, cfg: &ExperimentConfig) -> Result<Vec<Figure>> {
        let mut figures = Vec::new();
        for &ranks in &cfg.ranks {
            let mut fig = Figure::new(
                format!("Fig 3 — C++ benchmark, Edison, {ranks} MPI processes"),
                "run time [s]",
                false,
            );
            for platform in Platform::edison_cpp_set() {
                let mut breakdown_acc: Vec<(String, f64)> = Vec::new();
                let stats = repeat(cfg.reps, |rep| {
                    let mut exec = self.exec();
                    let mut app = AppConfig::cpp(ranks, cfg.seed + rep as u64);
                    app.batched = cfg.batched;
                    let b = run_poisson_app(platform, &mut exec, &app).expect("fig3 run");
                    if rep == 0 {
                        breakdown_acc = b
                            .phase_names()
                            .iter()
                            .map(|p| (p.clone(), b.get(p)))
                            .collect();
                    }
                    b.total()
                });
                fig.push(Row::new(platform.label(), stats).with_breakdown(breakdown_acc));
            }
            if ranks > 96 {
                fig.note("container-MPI bar is off-scale in the paper (truncated x-axis)");
            }
            figures.push(fig);
        }
        Ok(figures)
    }

    fn fig4(&self, cfg: &ExperimentConfig) -> Result<Vec<Figure>> {
        let mut figures = Vec::new();
        for &ranks in &cfg.ranks {
            let mut fig = Figure::new(
                format!("Fig 4 — Python benchmark, Edison, {ranks} MPI processes"),
                "run time [s]",
                false,
            );
            for platform in Platform::edison_python_set() {
                let mut breakdown_acc: Vec<(String, f64)> = Vec::new();
                let stats = repeat(cfg.reps, |rep| {
                    let mut exec = self.exec();
                    let mut app = AppConfig::python(ranks, cfg.seed + rep as u64);
                    app.batched = cfg.batched;
                    let b = run_poisson_app(platform, &mut exec, &app).expect("fig4 run");
                    if rep == 0 {
                        breakdown_acc = b
                            .phase_names()
                            .iter()
                            .map(|p| (p.clone(), b.get(p)))
                            .collect();
                    }
                    b.total()
                });
                fig.push(Row::new(platform.label(), stats).with_breakdown(breakdown_acc));
            }
            fig.note("native total dominated by the Python import phase (MDS contention)");
            figures.push(fig);
        }
        Ok(figures)
    }

    fn fig5(&self, cfg: &ExperimentConfig, workstation: bool) -> Result<Vec<Figure>> {
        let platforms: Vec<Platform> = if workstation {
            vec![Platform::Docker, Platform::Rkt, Platform::Native]
        } else {
            vec![Platform::Native, Platform::ShifterSystemMpi]
        };
        let mut figures = Vec::new();
        for &size in &cfg.sizes {
            let (which, ranks) = if workstation {
                ("5a — 16-core workstation", cfg.ranks[0])
            } else {
                ("5b — Edison, 192 cores", cfg.ranks[0])
            };
            let dofs_per_rank = crate::fem::gmg::LADDER[size].pow(3);
            let mut fig = Figure::new(
                format!("Fig {which}: HPGMG-FE, {dofs_per_rank} DOF/rank"),
                "DOF/s",
                true,
            );
            for &platform in &platforms {
                let stats = repeat(cfg.reps, |rep| {
                    let mut exec = self.exec();
                    let mut hc = if workstation {
                        HpgmgConfig::workstation(size, cfg.seed + rep as u64)
                    } else {
                        HpgmgConfig::edison(size, cfg.seed + rep as u64)
                    };
                    hc.ranks = ranks;
                    hc.batched = cfg.batched;
                    run_hpgmg(platform, &mut exec, &hc)
                        .expect("hpgmg run")
                        .dofs_per_second
                });
                fig.push(Row::new(platform.label(), stats));
            }
            figures.push(fig);
        }
        Ok(figures)
    }
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

/// Aggregate stats for one platform column across figures (used by the
/// summary table in reports).
pub fn column_summary(figures: &[Figure], label: &str) -> Option<Stats> {
    let samples: Vec<f64> = figures
        .iter()
        .flat_map(|f| f.rows.iter())
        .filter(|r| r.label == label)
        .flat_map(|r| r.stats.samples.iter().copied())
        .collect();
    if samples.is_empty() {
        None
    } else {
        Some(Stats::from_samples(samples))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deploy_pipeline_round_trips() {
        let trace = deploy_pipeline().unwrap();
        assert!(trace.layers_built >= 5);
        assert!(trace.image_bytes > 100_000_000);
        assert_eq!(trace.targets.len(), 2);
        // both pulls move the full image (fresh stores)
        for t in &trace.targets {
            assert_eq!(t.pull.layers_reused, 0);
            assert!(t.pull.time > Duration::ZERO);
        }
        let text = trace.render();
        assert!(text.contains("edison"));
        assert!(text.contains("layers built"));
    }

    #[test]
    fn fig1_scale_reports_cold_and_warm() {
        let cfg = ExperimentConfig {
            nodes: vec![4, 16],
            ..ExperimentConfig::paper_default("fig1-scale").unwrap()
        };
        let figs = Coordinator::new().run(&cfg).unwrap();
        assert_eq!(figs.len(), 2, "cold + warm figures");
        for f in &figs {
            assert_eq!(f.rows.len(), 2, "one row per fleet size");
        }
        for (cold, warm) in figs[0].rows.iter().zip(&figs[1].rows) {
            assert!(
                warm.stats.mean() < 0.1 * cold.stats.mean(),
                "warm {} !< 10% of cold {}",
                warm.stats.mean(),
                cold.stats.mean()
            );
        }
        assert!(figs[1].notes[0].contains("acceptance bar"));
    }

    #[test]
    fn fig2_produces_four_figures_with_four_bars() {
        let cfg = ExperimentConfig {
            reps: 2,
            ..ExperimentConfig::paper_default("fig2").unwrap()
        };
        let figs = Coordinator::new().run(&cfg).unwrap();
        assert_eq!(figs.len(), 4);
        for f in &figs {
            assert_eq!(f.rows.len(), 4);
            assert!(f.rows.iter().all(|r| r.stats.mean() > 0.0));
        }
    }

    #[test]
    fn fig3_has_ranks_sweep_and_breakdowns() {
        let cfg = ExperimentConfig {
            reps: 1,
            ranks: vec![24, 48],
            ..ExperimentConfig::paper_default("fig3").unwrap()
        };
        let figs = Coordinator::new().run(&cfg).unwrap();
        assert_eq!(figs.len(), 2);
        for f in &figs {
            assert_eq!(f.rows.len(), 3);
            assert!(!f.rows[0].breakdown.is_empty());
        }
    }

    #[test]
    fn fig5a_higher_is_better() {
        let cfg = ExperimentConfig {
            reps: 1,
            sizes: vec![0],
            ..ExperimentConfig::paper_default("fig5a").unwrap()
        };
        let figs = Coordinator::new().run(&cfg).unwrap();
        assert_eq!(figs.len(), 1);
        assert!(figs[0].higher_better);
        assert_eq!(figs[0].rows.len(), 3);
    }

    #[test]
    fn column_summary_collects_across_figures() {
        let cfg = ExperimentConfig {
            reps: 2,
            ..ExperimentConfig::paper_default("fig2").unwrap()
        };
        let figs = Coordinator::new().run(&cfg).unwrap();
        let native = column_summary(&figs, "native").unwrap();
        assert_eq!(native.n(), 8); // 4 tests x 2 reps
        assert!(column_summary(&figs, "slurm").is_none());
    }
}
