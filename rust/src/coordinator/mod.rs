//! Experiment orchestration — the paper's Fig 1 pipeline plus the
//! dispatch layer over the scenario engine.
//!
//! [`deploy_pipeline`] walks the full image lifecycle the paper
//! describes (§3.4): parse the Dockerfile → build (layer cache, content
//! hashes) → push to the registry → pull onto the workstation and onto
//! Edison (Shifter's `shifterimg pull`), reporting layer reuse and
//! transfer times.
//!
//! [`Coordinator`] is now a thin shell: it resolves
//! `ExperimentConfig::figure` through a [`ScenarioRegistry`] and hands
//! the matched [`Scenario`](crate::scenario::Scenario) to the
//! deterministic [`MatrixRunner`] — every figure implementation lives
//! in `crate::scenario`, and new experiments register there instead of
//! editing this module.

use anyhow::Result;

use crate::bench::Figure;
use crate::config::ExperimentConfig;
use crate::container::{
    Builder, Buildfile, Fleet, FleetReport, LayerStore, PullReport, Registry, ShardedRegistry,
};
use crate::des::Duration;
use crate::metrics::Stats;
use crate::runtime::CalibrationTable;
use crate::scenario::{MatrixRunner, ScenarioRegistry};

/// The FEniCS-stack buildfile the pipeline builds (the project's real
/// Dockerfile collapsed to our DSL).
pub const FENICS_BUILDFILE: &str = "\
FROM ubuntu:16.04
USER root
RUN apt-get -y update && apt-get -y install petsc slepc openmpi-bin mpich
RUN apt-get -y install python-numpy python-scipy python-sympy swig
RUN pip install ufl ffc fiat instant
RUN git clone dolfin && cmake dolfin && make -j install
ENV FENICS_HOME=/home/fenics
USER fenics
WORKDIR /home/fenics
ENTRYPOINT /bin/bash
";

/// One machine's pull in the deployment trace.
#[derive(Debug, Clone)]
pub struct DeployTarget {
    /// Which machine pulled.
    pub machine: String,
    /// The pull's transfer report.
    pub pull: PullReport,
}

/// The full §3.4 pipeline record.
#[derive(Debug, Clone)]
pub struct DeploymentTrace {
    /// Content hash of the deployed image.
    pub image_id: String,
    /// Layers built fresh by the CI build.
    pub layers_built: usize,
    /// Layers answered from the build cache.
    pub layers_cached: usize,
    /// Modelled build wall time.
    pub build_time: Duration,
    /// Compressed image size in bytes.
    pub image_bytes: u64,
    /// Files across all layers.
    pub image_files: usize,
    /// Per-machine pulls, in deployment order.
    pub targets: Vec<DeployTarget>,
}

impl DeploymentTrace {
    /// Human-readable trace (the Fig 1 pipeline table).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "image {} ({} MB, {} files): {} layers built, {} cached, build {}\n",
            &self.image_id[..12],
            self.image_bytes / 1_000_000,
            self.image_files,
            self.layers_built,
            self.layers_cached,
            self.build_time,
        ));
        for t in &self.targets {
            s.push_str(&format!(
                "  pull -> {:12} {} layers ({} reused), {} MB in {}\n",
                t.machine,
                t.pull.layers_transferred,
                t.pull.layers_reused,
                t.pull.bytes_transferred / 1_000_000,
                t.pull.time,
            ));
        }
        s
    }
}

/// Run the Fig 1 pipeline: build → push → pull on each target machine.
/// `second_build` demonstrates layer caching (a config-only change).
pub fn deploy_pipeline() -> Result<DeploymentTrace> {
    let bf = Buildfile::parse(FENICS_BUILDFILE)?;
    let mut builder = Builder::new();
    let mut ci_store = LayerStore::new();
    let report = builder.build(&bf, "quay.io/fenicsproject/stable:2016.1.0r1", &mut ci_store)?;

    let mut registry = Registry::new();
    registry.push(&report.image, &ci_store)?;

    let mut targets = Vec::new();
    for machine in ["workstation", "edison"] {
        let mut local = LayerStore::new();
        let (_, pull) = registry.pull("quay.io/fenicsproject/stable:2016.1.0r1", &mut local)?;
        targets.push(DeployTarget {
            machine: machine.to_string(),
            pull,
        });
    }

    Ok(DeploymentTrace {
        image_id: report.image.id.0.clone(),
        layers_built: report.layers_built,
        layers_cached: report.layers_cached,
        build_time: report.build_time,
        image_bytes: report.image.size_bytes(&registry.layers),
        image_files: report.image.file_count(&registry.layers),
        targets,
    })
}

/// Build the paper's FEniCS image and publish it behind four shard
/// frontends — the registry side of a fleet deployment campaign.
pub fn fleet_registry(reference: &str) -> Result<ShardedRegistry> {
    let bf = Buildfile::parse(FENICS_BUILDFILE)?;
    let mut store = LayerStore::new();
    let report = Builder::new().build(&bf, reference, &mut store)?;
    let mut registry = Registry::new();
    registry.push(&report.image, &store)?;
    Ok(ShardedRegistry::new(registry, 4))
}

/// Figure runner over the modeled (calibrated) execution mode:
/// scenario registry + deterministic matrix runner.
pub struct Coordinator {
    /// Calibration table driving modeled execution times.
    pub table: CalibrationTable,
    /// The scenario catalogue `run` dispatches through.
    registry: ScenarioRegistry,
    /// Worker threads for the cell matrix (1 = serial; any value
    /// produces bit-identical figures).
    jobs: usize,
}

impl Coordinator {
    /// Load the measured calibration table if available (else the
    /// built-in fallback — reports record which), over the built-in
    /// scenario registry, serial execution.
    pub fn new() -> Self {
        Self::with_table(CalibrationTable::load_or_default(None))
    }

    /// A coordinator over an explicit calibration table.
    pub fn with_table(table: CalibrationTable) -> Self {
        Coordinator {
            table,
            registry: ScenarioRegistry::builtin(),
            jobs: 1,
        }
    }

    /// Set the matrix worker count (builder-style).  Figures are
    /// bit-identical for every value; >1 only changes wall-clock time.
    pub fn with_jobs(mut self, jobs: usize) -> Self {
        self.jobs = jobs.max(1);
        self
    }

    /// The scenario catalogue.
    pub fn registry(&self) -> &ScenarioRegistry {
        &self.registry
    }

    /// Mutable access to the catalogue — the plug-in point for custom
    /// scenarios (see `examples/scenario_matrix.rs`).
    pub fn registry_mut(&mut self) -> &mut ScenarioRegistry {
        &mut self.registry
    }

    /// Regenerate the figures selected by `cfg`: resolve the scenario
    /// by name and run its cell matrix.  An unknown name lists every
    /// registered scenario — the list comes from the registry, so it
    /// can never go stale.
    pub fn run(&self, cfg: &ExperimentConfig) -> Result<Vec<Figure>> {
        let scenario = self.registry.get(&cfg.figure).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown figure `{}` (registered scenarios: {})",
                cfg.figure,
                self.registry.names().join(", ")
            )
        })?;
        MatrixRunner::new(self.jobs).run(scenario, cfg, &self.table)
    }

    /// Deploy `reference` onto every node of `fleet` concurrently
    /// through `registry`'s shard frontends, in virtual time.  This is
    /// the fleet-scale version of the Fig 1 "pull everywhere" step:
    /// node caches are consulted first, cache-missing layers cross the
    /// WAN once each (peer fan-out) or once per node (direct), and the
    /// report records makespan, WAN/intra-cluster bytes, and cache
    /// accounting.
    ///
    /// # Example
    ///
    /// A cold deploy moves the image once over the WAN; the warm
    /// re-deploy that follows moves nothing:
    ///
    /// ```
    /// use harbor::container::{Builder, Buildfile, LayerStore, Registry};
    /// use harbor::container::{Fleet, FleetConfig, ShardedRegistry};
    /// use harbor::coordinator::Coordinator;
    ///
    /// let bf = Buildfile::parse("FROM ubuntu:16.04\nRUN echo x").unwrap();
    /// let mut store = LayerStore::new();
    /// let image = Builder::new().build(&bf, "app:1", &mut store).unwrap().image;
    /// let mut registry = Registry::new();
    /// registry.push(&image, &store).unwrap();
    ///
    /// let mut sharded = ShardedRegistry::new(registry, 4);
    /// let mut fleet = Fleet::new(FleetConfig::hpc(64));
    /// let coordinator = Coordinator::new();
    ///
    /// let cold = coordinator.deploy_fleet(&mut sharded, &mut fleet, "app:1").unwrap();
    /// let warm = coordinator.deploy_fleet(&mut sharded, &mut fleet, "app:1").unwrap();
    /// assert!(cold.wan_bytes > 0);
    /// assert_eq!(warm.wan_bytes + warm.intra_bytes, 0);
    /// assert!(warm.makespan < cold.makespan);
    /// ```
    pub fn deploy_fleet(
        &self,
        registry: &mut ShardedRegistry,
        fleet: &mut Fleet,
        reference: &str,
    ) -> Result<FleetReport> {
        Ok(fleet.deploy(registry, reference)?)
    }
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

/// Aggregate stats for one platform column across figures (used by the
/// summary table in reports).
pub fn column_summary(figures: &[Figure], label: &str) -> Option<Stats> {
    let samples: Vec<f64> = figures
        .iter()
        .flat_map(|f| f.rows.iter())
        .filter(|r| r.label == label)
        .flat_map(|r| r.stats.samples.iter().copied())
        .collect();
    if samples.is_empty() {
        None
    } else {
        Some(Stats::from_samples(samples))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    #[test]
    fn deploy_pipeline_round_trips() {
        let trace = deploy_pipeline().unwrap();
        assert!(trace.layers_built >= 5);
        assert!(trace.image_bytes > 100_000_000);
        assert_eq!(trace.targets.len(), 2);
        // both pulls move the full image (fresh stores)
        for t in &trace.targets {
            assert_eq!(t.pull.layers_reused, 0);
            assert!(t.pull.time > Duration::ZERO);
        }
        let text = trace.render();
        assert!(text.contains("edison"));
        assert!(text.contains("layers built"));
    }

    #[test]
    fn unknown_figure_error_lists_the_registry() {
        let cfg = ExperimentConfig {
            figure: "fig9".into(),
            ..ExperimentConfig::paper_default("fig2").unwrap()
        };
        let err = Coordinator::new().run(&cfg).unwrap_err().to_string();
        assert!(err.contains("unknown figure `fig9`"), "{err}");
        // the list is generated from the registry — every scenario,
        // including ones added after this test was written
        for name in ScenarioRegistry::builtin().names() {
            assert!(err.contains(name), "error must list `{name}`: {err}");
        }
    }

    #[test]
    fn fig1_scale_reports_cold_and_warm() {
        let cfg = ExperimentConfig {
            nodes: vec![4, 16],
            ..ExperimentConfig::paper_default("fig1-scale").unwrap()
        };
        let figs = Coordinator::new().run(&cfg).unwrap();
        assert_eq!(figs.len(), 2, "cold + warm figures");
        for f in &figs {
            assert_eq!(f.rows.len(), 2, "one row per fleet size");
        }
        for (cold, warm) in figs[0].rows.iter().zip(&figs[1].rows) {
            assert!(
                warm.stats.mean() < 0.1 * cold.stats.mean(),
                "warm {} !< 10% of cold {}",
                warm.stats.mean(),
                cold.stats.mean()
            );
        }
        assert!(figs[1].notes[0].contains("acceptance bar"));
    }

    #[test]
    fn fig2_produces_four_figures_with_four_bars() {
        let cfg = ExperimentConfig {
            reps: 2,
            ..ExperimentConfig::paper_default("fig2").unwrap()
        };
        let figs = Coordinator::new().run(&cfg).unwrap();
        assert_eq!(figs.len(), 4);
        for f in &figs {
            assert_eq!(f.rows.len(), 4);
            assert!(f.rows.iter().all(|r| r.stats.mean() > 0.0));
        }
    }

    #[test]
    fn fig3_has_ranks_sweep_and_breakdowns() {
        let cfg = ExperimentConfig {
            reps: 1,
            ranks: vec![24, 48],
            ..ExperimentConfig::paper_default("fig3").unwrap()
        };
        let figs = Coordinator::new().run(&cfg).unwrap();
        assert_eq!(figs.len(), 2);
        for f in &figs {
            assert_eq!(f.rows.len(), 3);
            assert!(!f.rows[0].breakdown.is_empty());
        }
    }

    #[test]
    fn fig5a_higher_is_better() {
        let cfg = ExperimentConfig {
            reps: 1,
            sizes: vec![0],
            ..ExperimentConfig::paper_default("fig5a").unwrap()
        };
        let figs = Coordinator::new().run(&cfg).unwrap();
        assert_eq!(figs.len(), 1);
        assert!(figs[0].higher_better);
        assert_eq!(figs[0].rows.len(), 3);
    }

    #[test]
    fn column_summary_collects_across_figures() {
        let cfg = ExperimentConfig {
            reps: 2,
            ..ExperimentConfig::paper_default("fig2").unwrap()
        };
        let figs = Coordinator::new().run(&cfg).unwrap();
        let native = column_summary(&figs, "native").unwrap();
        assert_eq!(native.n(), 8); // 4 tests x 2 reps
        assert!(column_summary(&figs, "slurm").is_none());
    }

    #[test]
    fn custom_scenarios_plug_in_through_the_registry() {
        use crate::bench::Row;
        use crate::scenario::{Cell, CellResult, Scenario, SimContext};

        struct Constant;
        impl Scenario for Constant {
            fn name(&self) -> &'static str {
                "constant"
            }
            fn describe(&self) -> &'static str {
                "one cell, one bar"
            }
            fn default_config(&self) -> Result<ExperimentConfig> {
                ExperimentConfig::paper_default("fig2")
            }
            fn cells(&self, _cfg: &ExperimentConfig) -> Result<Vec<Cell>> {
                Ok(vec![Cell::new("the cell", ())])
            }
            fn run_cell(&self, _ctx: &SimContext<'_>, _cell: &Cell) -> Result<CellResult> {
                Ok(CellResult::value(1.0))
            }
            fn assemble(
                &self,
                _ctx: &SimContext<'_>,
                _cells: &[Cell],
                rows: Vec<CellResult>,
            ) -> Result<Vec<Figure>> {
                let mut fig = Figure::new("constant", "x", false);
                fig.push(Row::new("bar", Stats::from_samples(vec![rows[0].primary()])));
                Ok(vec![fig])
            }
        }

        let mut c = Coordinator::new();
        c.registry_mut().register(Box::new(Constant));
        let cfg = ExperimentConfig {
            figure: "constant".into(),
            ..ExperimentConfig::paper_default("fig2").unwrap()
        };
        let figs = c.run(&cfg).unwrap();
        assert_eq!(figs.len(), 1);
        assert_eq!(figs[0].rows[0].stats.mean(), 1.0);
        // and the unknown-figure error now mentions it
        let bad = ExperimentConfig {
            figure: "nope".into(),
            ..cfg
        };
        assert!(c.run(&bad).unwrap_err().to_string().contains("constant"));
    }
}
