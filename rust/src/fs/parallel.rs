//! Lustre-like parallel filesystem: contended MDS + striped OSTs.
//!
//! HPC filesystems serve *data* fast (striped across object storage
//! targets) but serialise *metadata* through a small pool of MDS request
//! handlers.  When N ranks each open M small files — exactly what
//! `import fenics` does on every rank — N*M lookups contend for those
//! handlers, and service times degrade further under load (lock
//! contention, seeks); we model that with a heavy-tail noise factor
//! whose magnitude grows with the queue backlog.  This is the mechanism
//! the paper's reference [17] measured on ARCHER and the cause of the
//! "30 minutes to import at 1000 ranks" anecdote.

use super::{FileSystem, FsOp};
use crate::des::{Duration, FifoResource, QueueStats, SimRng, VirtualTime};

/// Parallel filesystem model. `edison()` gives Lustre-on-Edison-like
/// parameters; all knobs are public for experiment configuration.
#[derive(Debug)]
pub struct ParallelFs {
    /// Base MDS service time per metadata op (uncontended).
    pub meta_service: Duration,
    /// Heavy-tail noise amplitude applied to metadata service times as
    /// the backlog grows (0 disables).
    pub meta_noise_sigma: f64,
    /// Aggregate OST bandwidth, bytes/s.
    pub ost_bytes_per_sec: f64,
    mds: FifoResource,
    ost: FifoResource,
    rng: SimRng,
}

impl ParallelFs {
    /// A parallel filesystem with `mds_handlers` metadata RPC slots
    /// and the given per-op costs.
    pub fn new(
        mds_handlers: usize,
        meta_service: Duration,
        ost_bytes_per_sec: f64,
        meta_noise_sigma: f64,
        seed: u64,
    ) -> Self {
        ParallelFs {
            meta_service,
            meta_noise_sigma,
            ost_bytes_per_sec,
            mds: FifoResource::new(mds_handlers),
            ost: FifoResource::new(4), // a few parallel OST streams
            rng: SimRng::new(seed, "parallel-fs"),
        }
    }

    /// Lustre as deployed on the modelled Cray XC30: a modest handler
    /// pool and ~100 us per lookup uncontended, tens of GB/s of data.
    pub fn edison(seed: u64) -> Self {
        Self::new(16, Duration::from_micros(100), 48.0e9, 0.6, seed)
    }

    /// Backlog-dependent service time for one metadata op.
    fn meta_cost(&mut self, at: VirtualTime) -> Duration {
        let backlog = self
            .mds
            .next_free()
            .max(at)
            .since(at)
            .as_secs_f64();
        // noise grows with backlog: contention begets contention
        let load_factor = 1.0 + (backlog / 0.01).min(20.0) * 0.25;
        let noise = if self.meta_noise_sigma > 0.0 {
            self.rng.spike(self.meta_noise_sigma)
        } else {
            1.0
        };
        self.meta_service.scale(load_factor * noise)
    }

    /// Utilisation counters (for reports/tests).
    pub fn mds_served(&self) -> u64 {
        self.mds.served()
    }

    /// Calendar-queue counters of the MDS handler tokens (see
    /// `des::stats`): every metadata burst a rank class submits moves
    /// through this scheduler, so the push/pop totals count the RPC
    /// traffic the import storm actually generated.
    pub fn mds_scheduler_stats(&self) -> QueueStats {
        self.mds.scheduler_stats()
    }
}

impl FileSystem for ParallelFs {
    fn submit(&mut self, at: VirtualTime, _node: usize, op: FsOp) -> VirtualTime {
        match op {
            FsOp::Open | FsOp::Stat => {
                let cost = self.meta_cost(at);
                self.mds.submit(at, cost)
            }
            // one queue entry of ops x (load-adjusted) service: same rank
            // total and MDS busy time as `ops` sequential entries
            FsOp::MetaBatch { ops } => {
                let cost = self.meta_cost(at);
                self.mds.submit(at, Duration::from_nanos(cost.as_nanos() * ops as u64))
            }
            FsOp::Read { bytes } | FsOp::Write { bytes } => {
                // data ops still need one metadata round-trip worth of
                // RPC, then stream through the OSTs
                let t = self.mds.submit(at, self.meta_service);
                let service = Duration::from_secs_f64(bytes as f64 / self.ost_bytes_per_sec);
                self.ost.submit(t, service)
            }
        }
    }

    /// Class-batched burst: `count` symmetric clients hitting the MDS at
    /// once. The queueing is exact (`submit_many` places the same
    /// `count` FIFO entries as `count` sequential submissions, and the
    /// served/busy accounting matches); the approximation is that the
    /// load factor and the heavy-tail noise are sampled **once per
    /// burst** instead of once per client, and the burst completes
    /// together at its last member — the collapsed view a rank class
    /// needs. Contention across nodes (and its growth with rank count)
    /// is preserved because every burst still occupies the same MDS
    /// handler time.
    fn submit_batch(&mut self, at: VirtualTime, node: usize, count: u32, op: FsOp) -> VirtualTime {
        let _ = node;
        if count == 0 {
            return at;
        }
        match op {
            FsOp::Open | FsOp::Stat => {
                let cost = self.meta_cost(at);
                self.mds.submit_many(at, cost, count)
            }
            FsOp::MetaBatch { ops } => {
                let cost = self.meta_cost(at);
                let per_client = Duration::from_nanos(cost.as_nanos() * ops as u64);
                self.mds.submit_many(at, per_client, count)
            }
            FsOp::Read { bytes } | FsOp::Write { bytes } => {
                let t = self.mds.submit_many(at, self.meta_service, count);
                let service = Duration::from_secs_f64(bytes as f64 / self.ost_bytes_per_sec);
                self.ost.submit_many(t, service, count)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet_fs() -> ParallelFs {
        // deterministic: no noise
        ParallelFs::new(4, Duration::from_micros(100), 48.0e9, 0.0, 1)
    }

    #[test]
    fn metadata_contention_serialises() {
        let mut fs = quiet_fs();
        let t0 = VirtualTime::ZERO;
        // 400 simultaneous opens on 4 handlers: last one waits ~100 slots
        let mut last = VirtualTime::ZERO;
        for _ in 0..400 {
            last = last.max(fs.submit(t0, 0, FsOp::Open));
        }
        // >= 100 sequential service times (plus load factor growth)
        assert!(last.as_secs_f64() >= 100.0 * 100e-6);
        assert_eq!(fs.mds_served(), 400);
    }

    #[test]
    fn uncontended_open_is_fast() {
        let mut fs = quiet_fs();
        let done = fs.submit(VirtualTime::ZERO, 0, FsOp::Open);
        assert!(done.as_secs_f64() <= 150e-6);
    }

    #[test]
    fn load_factor_degrades_under_backlog() {
        let mut fs = quiet_fs();
        let t0 = VirtualTime::ZERO;
        let first = fs.submit(t0, 0, FsOp::Open) - t0;
        let mut last = Duration::ZERO;
        for _ in 0..1000 {
            let done = fs.submit(t0, 0, FsOp::Open);
            last = done - t0;
        }
        // per-op effective latency grew by more than pure queueing
        // (1000 ops / 4 handlers * 100us = 25 ms without load factor)
        assert!(last.as_secs_f64() > 0.025, "got {}", last.as_secs_f64());
        assert!(first < Duration::from_millis(1));
    }

    #[test]
    fn bulk_read_is_bandwidth_bound_not_mds_bound() {
        let mut fs = quiet_fs();
        // 4.8 GB at 48 GB/s = 100 ms >> metadata cost
        let done = fs.submit(VirtualTime::ZERO, 0, FsOp::Read { bytes: 4_800_000_000 });
        let s = done.as_secs_f64();
        assert!((0.09..0.12).contains(&s), "got {s}");
    }

    #[test]
    fn batched_burst_conserves_mds_accounting() {
        // quiet FS: the only difference vs per-client submission is the
        // collapsed completion view; handler time and counts must match
        let mut batched = quiet_fs();
        let mut per_client = quiet_fs();
        let t0 = VirtualTime::ZERO;
        let b = batched.submit_batch(t0, 0, 24, FsOp::MetaBatch { ops: 4 });
        let mut last = t0;
        for _ in 0..24 {
            last = last.max(per_client.submit(t0, 0, FsOp::MetaBatch { ops: 4 }));
        }
        assert_eq!(batched.mds_served(), per_client.mds_served());
        // load factor is sampled once per burst vs per client: completion
        // agrees to within the load-factor growth band
        let (bs, ps) = (b.as_secs_f64(), last.as_secs_f64());
        assert!(bs <= ps * 1.01, "batched {bs} should not exceed per-client {ps}");
        assert!(bs > ps * 0.5, "batched {bs} lost the contention vs {ps}");
    }

    #[test]
    fn batched_reads_stream_through_ost() {
        let mut fs = quiet_fs();
        // 24 x 200 MB at 48 GB/s through 4 OST streams ~= 25 ms
        let done = fs.submit_batch(VirtualTime::ZERO, 0, 24, FsOp::Read { bytes: 200_000_000 });
        let s = done.as_secs_f64();
        assert!(s > 0.02, "expected OST serialisation, got {s}");
    }

    #[test]
    fn scheduler_stats_count_the_metadata_traffic() {
        let mut fs = quiet_fs();
        fs.submit_batch(VirtualTime::ZERO, 0, 24, FsOp::Open);
        let s = fs.mds_scheduler_stats();
        assert_eq!(s.depth, 4, "one token per MDS handler");
        assert!(s.pushes > 4, "the burst moved tokens through the calendar");
    }

    #[test]
    fn noise_is_reproducible_per_seed() {
        let mut a = ParallelFs::edison(7);
        let mut b = ParallelFs::edison(7);
        for _ in 0..50 {
            assert_eq!(
                a.submit(VirtualTime::ZERO, 0, FsOp::Open),
                b.submit(VirtualTime::ZERO, 0, FsOp::Open)
            );
        }
    }
}
