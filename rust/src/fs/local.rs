//! Workstation-local filesystem: SSD + warm page cache.
//!
//! Metadata operations are in-memory dentry-cache hits (~2 us); data
//! moves at SSD bandwidth with a single queue (one device) shared by
//! however many processes the workstation runs.

use super::{FileSystem, FsOp};
use crate::des::{Duration, FifoResource, VirtualTime};

/// Local disk model. `Default` gives a typical SATA-SSD workstation.
#[derive(Debug, Clone)]
pub struct LocalFs {
    /// Metadata (dentry cache) service time.
    pub meta: Duration,
    /// Device bandwidth, bytes/s.
    pub bytes_per_sec: f64,
    device: FifoResource,
}

impl Default for LocalFs {
    fn default() -> Self {
        LocalFs {
            meta: Duration::from_micros(2),
            bytes_per_sec: 500.0e6,
            device: FifoResource::new(1),
        }
    }
}

impl LocalFs {
    /// A local filesystem with the given metadata latency and
    /// streaming bandwidth.
    pub fn new(meta: Duration, bytes_per_sec: f64) -> Self {
        LocalFs {
            meta,
            bytes_per_sec,
            device: FifoResource::new(1),
        }
    }
}

impl FileSystem for LocalFs {
    fn submit(&mut self, at: VirtualTime, _node: usize, op: FsOp) -> VirtualTime {
        match op {
            FsOp::Open | FsOp::Stat => at + self.meta,
            FsOp::MetaBatch { ops } => {
                at + Duration::from_nanos(self.meta.as_nanos() * ops as u64)
            }
            FsOp::Read { bytes } | FsOp::Write { bytes } => {
                let service = Duration::from_secs_f64(bytes as f64 / self.bytes_per_sec);
                self.device.submit(at, service)
            }
        }
    }

    /// Class-batched burst: metadata is an unqueued cache hit (every
    /// client completes identically — exact), data serialises the whole
    /// burst through the single device queue (`submit_many` is exactly
    /// `count` sequential submissions).
    fn submit_batch(&mut self, at: VirtualTime, node: usize, count: u32, op: FsOp) -> VirtualTime {
        match op {
            FsOp::Open | FsOp::Stat | FsOp::MetaBatch { .. } => {
                if count == 0 {
                    at
                } else {
                    self.submit(at, node, op)
                }
            }
            FsOp::Read { bytes } | FsOp::Write { bytes } => {
                let service = Duration::from_secs_f64(bytes as f64 / self.bytes_per_sec);
                self.device.submit_many(at, service, count)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metadata_is_cheap_and_unqueued() {
        let mut fs = LocalFs::default();
        let t0 = VirtualTime::ZERO;
        // many opens at the same instant all finish at meta time: no queue
        for _ in 0..100 {
            assert_eq!(fs.submit(t0, 0, FsOp::Open), t0 + Duration::from_micros(2));
        }
    }

    #[test]
    fn reads_queue_on_the_device() {
        let mut fs = LocalFs::default();
        let t0 = VirtualTime::ZERO;
        let a = fs.submit(t0, 0, FsOp::Read { bytes: 500_000_000 }); // 1 s
        let b = fs.submit(t0, 0, FsOp::Read { bytes: 500_000_000 }); // queued
        assert!((a.as_secs_f64() - 1.0).abs() < 1e-6);
        assert!((b.as_secs_f64() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn write_and_read_share_device() {
        let mut fs = LocalFs::default();
        let t0 = VirtualTime::ZERO;
        fs.submit(t0, 0, FsOp::Write { bytes: 250_000_000 }); // 0.5 s
        let r = fs.submit(t0, 0, FsOp::Read { bytes: 250_000_000 });
        assert!((r.as_secs_f64() - 1.0).abs() < 1e-6);
    }
}
