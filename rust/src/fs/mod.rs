//! Filesystem models.
//!
//! Fig 4's result — Python programs start *faster* inside a container on
//! an HPC machine — is a filesystem story.  Natively, every MPI rank
//! `import`s thousands of small files through the parallel filesystem's
//! metadata server (MDS), which serialises; inside Shifter the image is a
//! single loop-mounted file, so after one bulk read per node every
//! metadata operation is a page-cache hit.  We model three filesystems:
//!
//! * [`LocalFs`] — workstation disk + warm page cache.
//! * [`ParallelFs`] — Lustre-like: a contended MDS ([`FifoResource`])
//!   for metadata plus aggregate OST bandwidth for data.
//! * [`ImageFs`] — loop-mounted image: one bulk blob fetch per node
//!   through the backing store, then page-cache service times.
//!
//! All operations take an arrival instant and return a completion
//! instant in virtual time; contention emerges from the shared queues.

mod image;
mod local;
mod parallel;

pub use image::ImageFs;
pub use local::LocalFs;
pub use parallel::ParallelFs;

use crate::des::VirtualTime;

/// A filesystem operation issued by one rank.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsOp {
    /// Path lookup + open (pure metadata).
    Open,
    /// `stat()` (pure metadata).
    Stat,
    /// Read `bytes` of data (metadata already done).
    Read { bytes: u64 },
    /// Write `bytes` of data.
    Write { bytes: u64 },
}

/// Common interface: submit an op from a node, get the completion instant.
pub trait FileSystem {
    fn submit(&mut self, at: VirtualTime, node: usize, op: FsOp) -> VirtualTime;

    /// `count` back-to-back metadata ops from one client. The default
    /// loops over [`FsOp::Open`]; models with a queueing fast path
    /// (ParallelFs) override it to enqueue one batched entry.
    fn submit_meta_batch(&mut self, at: VirtualTime, node: usize, count: u32) -> VirtualTime {
        let mut t = at;
        for _ in 0..count {
            t = self.submit(t, node, FsOp::Open);
        }
        t
    }

    /// Convenience: open + read in sequence.
    fn open_read(&mut self, at: VirtualTime, node: usize, bytes: u64) -> VirtualTime {
        let t = self.submit(at, node, FsOp::Open);
        self.submit(t, node, FsOp::Read { bytes })
    }

    /// Convenience: open + write in sequence.
    fn open_write(&mut self, at: VirtualTime, node: usize, bytes: u64) -> VirtualTime {
        let t = self.submit(at, node, FsOp::Open);
        self.submit(t, node, FsOp::Write { bytes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::Duration;

    #[test]
    fn open_read_composes() {
        let mut fs = LocalFs::default();
        let t0 = VirtualTime::ZERO;
        let t_open = fs.submit(t0, 0, FsOp::Open);
        let mut fs2 = LocalFs::default();
        let t_both = fs2.open_read(t0, 0, 4096);
        assert!(t_both > t_open);
        assert!(t_both - t0 < Duration::from_millis(10));
    }
}
