//! Filesystem models.
//!
//! Fig 4's result — Python programs start *faster* inside a container on
//! an HPC machine — is a filesystem story.  Natively, every MPI rank
//! `import`s thousands of small files through the parallel filesystem's
//! metadata server (MDS), which serialises; inside Shifter the image is a
//! single loop-mounted file, so after one bulk read per node every
//! metadata operation is a page-cache hit.  We model three filesystems:
//!
//! * [`LocalFs`] — workstation disk + warm page cache.
//! * [`ParallelFs`] — Lustre-like: a contended MDS ([`FifoResource`])
//!   for metadata plus aggregate OST bandwidth for data.
//! * [`ImageFs`] — loop-mounted image: one bulk blob fetch per node
//!   through the backing store, then page-cache service times.
//!
//! All operations take an arrival instant and return a completion
//! instant in virtual time; contention emerges from the shared queues.

mod image;
mod local;
mod parallel;

pub use image::ImageFs;
pub use local::LocalFs;
pub use parallel::ParallelFs;

use crate::des::VirtualTime;

/// A filesystem operation issued by one rank.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsOp {
    /// Path lookup + open (pure metadata).
    Open,
    /// `stat()` (pure metadata).
    Stat,
    /// `ops` back-to-back metadata operations from one client (what a
    /// module import issues: path-entry stats, `.py`/`.pyc` lookups).
    /// One queue entry of `ops × service` — same client total and
    /// server busy time as `ops` sequential [`FsOp::Open`]s.
    MetaBatch {
        /// Number of metadata operations in the batch.
        ops: u32,
    },
    /// Read `bytes` of data (metadata already done).
    Read {
        /// Payload size.
        bytes: u64,
    },
    /// Write `bytes` of data.
    Write {
        /// Payload size.
        bytes: u64,
    },
}

/// Common interface: submit an op from a node, get the completion instant.
pub trait FileSystem {
    /// Submit `op` from a client on `node` at `at`; returns the
    /// completion instant.
    fn submit(&mut self, at: VirtualTime, node: usize, op: FsOp) -> VirtualTime;

    /// `count` back-to-back metadata ops from one client (one
    /// [`FsOp::MetaBatch`] queue entry).
    fn submit_meta_batch(&mut self, at: VirtualTime, node: usize, count: u32) -> VirtualTime {
        self.submit(at, node, FsOp::MetaBatch { ops: count })
    }

    /// `count` clients on `node`, all submitting `op` at `at`; returns
    /// the completion instant of the *last* client — the rank-class view
    /// of a symmetric per-node access burst (every MPI rank of a node
    /// importing the same module, writing the same-sized chunk, ...).
    ///
    /// The default replays `count` independent submissions, which is
    /// exact but O(count); models specialise it with a closed form or a
    /// single service-time draw per batch (see each model's notes on
    /// where that is exact vs an approximation).
    fn submit_batch(&mut self, at: VirtualTime, node: usize, count: u32, op: FsOp) -> VirtualTime {
        let mut last = at;
        for _ in 0..count {
            last = last.max(self.submit(at, node, op));
        }
        last
    }

    /// Convenience: open + read in sequence.
    fn open_read(&mut self, at: VirtualTime, node: usize, bytes: u64) -> VirtualTime {
        let t = self.submit(at, node, FsOp::Open);
        self.submit(t, node, FsOp::Read { bytes })
    }

    /// Convenience: open + write in sequence.
    fn open_write(&mut self, at: VirtualTime, node: usize, bytes: u64) -> VirtualTime {
        let t = self.submit(at, node, FsOp::Open);
        self.submit(t, node, FsOp::Write { bytes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::Duration;

    #[test]
    fn open_read_composes() {
        let mut fs = LocalFs::default();
        let t0 = VirtualTime::ZERO;
        let t_open = fs.submit(t0, 0, FsOp::Open);
        let mut fs2 = LocalFs::default();
        let t_both = fs2.open_read(t0, 0, 4096);
        assert!(t_both > t_open);
        assert!(t_both - t0 < Duration::from_millis(10));
    }

    #[test]
    fn meta_batch_matches_sequential_opens_on_localfs() {
        let mut a = LocalFs::default();
        let mut b = LocalFs::default();
        let t0 = VirtualTime::ZERO;
        let batched = a.submit_meta_batch(t0, 0, 7);
        let mut seq = t0;
        for _ in 0..7 {
            seq = b.submit(seq, 0, FsOp::Open);
        }
        assert_eq!(batched, seq);
    }

    #[test]
    fn default_submit_batch_returns_last_of_count_clients() {
        // LocalFs reads serialise on one device: last of 3 = 3x one
        let mut fs = LocalFs::default();
        let one = LocalFs::default().submit(VirtualTime::ZERO, 0, FsOp::Read { bytes: 50_000_000 });
        let last = fs.submit_batch(VirtualTime::ZERO, 0, 3, FsOp::Read { bytes: 50_000_000 });
        let one_s = (one - VirtualTime::ZERO).as_secs_f64();
        let last_s = (last - VirtualTime::ZERO).as_secs_f64();
        assert!((last_s - 3.0 * one_s).abs() < 1e-9, "{last_s} vs 3x{one_s}");
    }
}
