//! Loop-mounted image filesystem (the Shifter trick).
//!
//! Shifter converts the container image into a single large file on the
//! parallel filesystem and loop-mounts it on each compute node.  The
//! *first* access on a node streams the blob through the backing store
//! (one big sequential read — the access pattern Lustre is good at);
//! every subsequent metadata or data operation on that node is served
//! from the node-local page cache at memory speed.  This converts
//! N_ranks * M_files metadata storms into N_nodes bulk reads, which is
//! why Fig 4's containerised Python starts so much faster.

use std::collections::HashSet;

use super::{FileSystem, FsOp, ParallelFs};
use crate::des::{Duration, VirtualTime};

/// Image mount over a backing parallel filesystem.
#[derive(Debug)]
pub struct ImageFs {
    /// Size of the image blob (bytes) fetched once per node.
    pub blob_bytes: u64,
    /// Page-cache metadata service time (in-memory lookup).
    pub cached_meta: Duration,
    /// Page-cache data bandwidth (bytes/s).
    pub cached_bytes_per_sec: f64,
    backing: ParallelFs,
    warm_nodes: HashSet<usize>,
    /// Completion time of each node's warm-up fetch.
    warm_done: Vec<(usize, VirtualTime)>,
}

impl ImageFs {
    /// A loop-mounted image of `blob_bytes` served from `backing`.
    pub fn new(blob_bytes: u64, backing: ParallelFs) -> Self {
        ImageFs {
            blob_bytes,
            cached_meta: Duration::from_micros(1),
            cached_bytes_per_sec: 8.0e9,
            backing,
            warm_nodes: HashSet::new(),
            warm_done: Vec::new(),
        }
    }

    /// Ensure the node has the blob; returns when it is available.
    fn warm(&mut self, at: VirtualTime, node: usize) -> VirtualTime {
        if self.warm_nodes.contains(&node) {
            // already fetched (or in flight): ready at the recorded time
            let done = self
                .warm_done
                .iter()
                .find(|(n, _)| *n == node)
                .map(|(_, t)| *t)
                .unwrap_or(at);
            return done.max(at);
        }
        let done = self
            .backing
            .submit(at, node, FsOp::Read { bytes: self.blob_bytes });
        self.warm_nodes.insert(node);
        self.warm_done.push((node, done));
        done
    }

    /// Nodes that have already paid the one-time mount cost.
    pub fn nodes_warm(&self) -> usize {
        self.warm_nodes.len()
    }
}

impl FileSystem for ImageFs {
    fn submit(&mut self, at: VirtualTime, node: usize, op: FsOp) -> VirtualTime {
        let ready = self.warm(at, node);
        match op {
            FsOp::Open | FsOp::Stat => ready + self.cached_meta,
            FsOp::MetaBatch { ops } => {
                ready + Duration::from_nanos(self.cached_meta.as_nanos() * ops as u64)
            }
            FsOp::Read { bytes } => {
                ready + Duration::from_secs_f64(bytes as f64 / self.cached_bytes_per_sec)
            }
            // writes go to a host-visible scratch path, not the read-only
            // image: charge backing-store cost (Shifter images are RO)
            FsOp::Write { bytes } => self.backing.submit(ready, node, FsOp::Write { bytes }),
        }
    }

    /// Class-batched burst: page-cache hits do not queue, so all `count`
    /// clients of the node complete at the identical instant — the
    /// batched view is **exact** here (this is the containerised case
    /// behind Fig 4). Writes fall through to the backing store's burst.
    fn submit_batch(&mut self, at: VirtualTime, node: usize, count: u32, op: FsOp) -> VirtualTime {
        if count == 0 {
            return at;
        }
        match op {
            FsOp::Open | FsOp::Stat | FsOp::MetaBatch { .. } | FsOp::Read { .. } => {
                self.submit(at, node, op)
            }
            FsOp::Write { .. } => {
                let ready = self.warm(at, node);
                self.backing.submit_batch(ready, node, count, op)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn image() -> ImageFs {
        // 1.2 GB image on a quiet Lustre
        ImageFs::new(
            1_200_000_000,
            ParallelFs::new(16, Duration::from_micros(100), 48.0e9, 0.0, 3),
        )
    }

    #[test]
    fn first_access_pays_blob_fetch() {
        let mut fs = image();
        let done = fs.submit(VirtualTime::ZERO, 0, FsOp::Open);
        // 1.2 GB / 48 GB/s = 25 ms, plus trivial cache hit
        assert!(done.as_secs_f64() > 0.02, "got {}", done.as_secs_f64());
    }

    #[test]
    fn subsequent_metadata_is_page_cache_fast() {
        let mut fs = image();
        let t1 = fs.submit(VirtualTime::ZERO, 0, FsOp::Open);
        let t2 = fs.submit(t1, 0, FsOp::Open);
        assert_eq!(t2 - t1, Duration::from_micros(1));
        // 5000 opens cost ~5 ms total, not 5000 MDS round-trips
        let mut t = t2;
        for _ in 0..5000 {
            t = fs.submit(t, 0, FsOp::Open);
        }
        assert!((t - t2).as_secs_f64() < 0.01);
    }

    #[test]
    fn each_node_warms_once() {
        let mut fs = image();
        for node in 0..8 {
            fs.submit(VirtualTime::ZERO, node, FsOp::Open);
        }
        assert_eq!(fs.nodes_warm(), 8);
        // re-touch: no new fetches
        for node in 0..8 {
            fs.submit(VirtualTime::ZERO, node, FsOp::Stat);
        }
        assert_eq!(fs.nodes_warm(), 8);
    }

    #[test]
    fn many_ranks_one_node_share_the_fetch() {
        let mut fs = image();
        let first = fs.submit(VirtualTime::ZERO, 0, FsOp::Open);
        // 23 more ranks on the same node: all ready right after the fetch
        let mut worst = first;
        for _ in 0..23 {
            worst = worst.max(fs.submit(VirtualTime::ZERO, 0, FsOp::Open));
        }
        assert!((worst - first) < Duration::from_millis(1));
    }

    #[test]
    fn writes_bypass_the_readonly_image() {
        let mut fs = image();
        let w = fs.submit(VirtualTime::ZERO, 0, FsOp::Write { bytes: 480_000_000 });
        // 10 ms of OST time + warm fetch; must exceed pure cache speed
        assert!(w.as_secs_f64() > 0.03);
    }
}
