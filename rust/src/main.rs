//! `harbor` — CLI for the container-deployment simulator.
//!
//! Subcommands mirror the workflows in the paper:
//!
//! * `build`     — build an image from a Buildfile (§2.2's `docker build`)
//! * `pipeline`  — the Fig 1 pipeline: build → push → pull everywhere
//! * `resolve`   — show the MPI ABI resolution for a platform (§4.2)
//! * `run`       — run the Edison test program once, print the breakdown
//! * `bench`     — regenerate a scenario's figures (`--list` shows the
//!   registry; `--jobs N` runs the cell matrix in parallel,
//!   bit-identically)
//! * `calibrate` — measure per-artifact PJRT costs into calibration.json
//! * `artifacts` — list the AOT artifacts the runtime can execute

use std::process::ExitCode;

use harbor::cluster::MachineSpec;
use harbor::config::ExperimentConfig;
use harbor::container::{Builder, Buildfile, LayerStore, RuntimeKind};
use harbor::coordinator::{deploy_pipeline, Coordinator};
use harbor::fem::exec::Exec;
use harbor::mpi::AbiResolver;
use harbor::platform::Platform;
use harbor::runtime::{calibrate, CalibrationTable, Engine};
use harbor::util::cli::{parse_count, parse_workers, Args};
use harbor::util::json::Value;
use harbor::workload::{run_poisson_app, AppConfig};

const ABOUT: &str = "\
harbor — reproduction of 'Containers for portable, productive and
performant scientific computing' (Hale, Li, Richardson, Wells; 2016)

A container-deployment simulator in virtual time: layered images, a
sharded registry with node-local caches, four container runtimes, an
Edison-like HPC cluster model, and the paper's FEM workloads driven
through AOT-compiled kernels.

USAGE:  harbor <COMMAND> [ARGS]

COMMANDS:
  build      build an image from a Buildfile (the paper's §2.2 docker build)
  pipeline   run the Fig 1 deployment pipeline (build -> push -> pull)
  resolve    show MPI ABI resolution for a machine/platform (the §4.2 trick)
  run        run the Edison test program once, print phase breakdown
  bench      regenerate a figure (see FIGURES below)
  calibrate  measure per-artifact PJRT costs (writes calibration.json)
  ablate     sensitivity sweeps: mds | nic | nu | layers | all
  fenicsproject  demo the §3.2 wrapper workflows (notebook/start/stop)
  artifacts  list AOT artifacts

SCENARIOS (harbor bench <scenario>; `harbor bench --list` prints the
live registry — the same table lives in EXPERIMENTS.md):
  fig1-scale  the Fig 1 workflow's deployment phase (§3.4: build ->
              push -> pull everywhere) at fleet scale: one image pulled
              onto 64..1,048,576 nodes through 4 registry shards, with
              node-local layer caches and Trow-style peer fan-out;
              reports cold-pull vs warm re-deploy makespan (node-class
              collapsed engine; --per-rank = per-node reference)
  fig2        Fig 2 (§4) — workstation benchmarks (Poisson LU/AMG, I/O,
              elasticity) across native / Docker / rkt / VirtualBox
  fig3        Fig 3 (§4) — C++ Poisson solver on Edison, 24..192 ranks:
              native vs Shifter+host-MPI vs container MPI (TCP fallback)
  fig4        Fig 4 (§4) — Python Poisson on Edison: the import
              problem; containers beat native via fewer metadata RPCs
  fig5a       Fig 5a (§4) — HPGMG-FE throughput, 16-core workstation
  fig5b       Fig 5b (§4) — HPGMG-FE throughput, Edison at 192 cores
  mixed-fleet co-scheduled C++ checkpoint writer and Python import
              storm on the shared Lustre (§4 discussion, unmeasured in
              the paper); containerising the Python tenant returns the
              writer to solo time
  build-farm  CI fleet building the §4.3 per-platform ARCH_OPT variant
              matrix as multi-stage buildfiles on 1..16 workers: one
              shared build/blob cache, pushes through 4 registry
              shards, non-terminal stages pruned; cold vs warm farm
              makespan and cache-hit ratios
  chaos-canary  rolling canary upgrade (r1 -> r2, one hotpatch layer)
              of the 16k-node fleet under seeded fault injection: node
              crashes, shard outages, WAN drop windows, cache storms
              vs retry/backoff/failover; sweeps fault intensity x
              retry policy, reports tail makespan, availability and
              wasted WAN bytes
  registry-storm  open-loop heavy-tailed (bounded-Pareto) blob
              pull/push session storm against the registry front door
              (resumable chunked transfers on 2..8 shard frontends);
              sweeps offered load x shard count, reports warmup-trimmed
              p50/p99/p999 latency and the saturation knee
  version-churn  bump one pinned package of the resolved FEniCS stack
              and rebuild the ARCH_OPT variant matrix warm; asserts the
              lockfile-diff rebuild frontier equals the stages actually
              rebuilt and reports the cache-invalidation %
  dep-storm   cold-resolve storm: N random manifests over the FEniCS
              package universe resolved, pinned, fetched through one
              shared package cache and built through a CI farm pass
  all         every registered scenario

Scenarios expand into independent cells run across `--jobs N` worker
threads; output is bit-identical for every N.  Custom scenarios plug in
through harbor::scenario::ScenarioRegistry (docs/ARCHITECTURE.md §5).

Run `harbor <COMMAND> --help` for details.";

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        println!("{ABOUT}");
        return ExitCode::SUCCESS;
    };
    let result = match cmd.as_str() {
        "build" => cmd_build(rest),
        "pipeline" => cmd_pipeline(rest),
        "resolve" => cmd_resolve(rest),
        "run" => cmd_run(rest),
        "bench" => cmd_bench(rest),
        "calibrate" => cmd_calibrate(rest),
        "ablate" => cmd_ablate(rest),
        "fenicsproject" => cmd_fenicsproject(rest),
        "artifacts" => cmd_artifacts(rest),
        "--help" | "-h" | "help" => {
            println!("{ABOUT}");
            Ok(())
        }
        other => Err(anyhow::anyhow!("unknown command `{other}`\n\n{ABOUT}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("{e}");
            ExitCode::FAILURE
        }
    }
}

fn cmd_build(raw: &[String]) -> anyhow::Result<()> {
    let args = Args::new("build", "build an image from a Buildfile")
        .positional("buildfile", "path to the Buildfile")
        .opt("tag", "image reference to tag", Some("local/image:latest"));
    let p = args.parse(raw)?;
    let text = std::fs::read_to_string(p.pos(0))?;
    let bf = Buildfile::parse(&text)?;
    let mut store = LayerStore::new();
    let report = Builder::new().build(&bf, p.req("tag"), &mut store)?;
    println!(
        "built {} -> image {} ({} layers new, {} cached, {} MB, {} files) in {}",
        p.pos(0),
        report.image.id,
        report.layers_built,
        report.layers_cached,
        report.image.size_bytes(&store) / 1_000_000,
        report.image.file_count(&store),
        report.build_time,
    );
    if report.graph.stage_count() > 1 {
        println!(
            "  stages: {} built, {} skipped; critical path {} (stage-parallel)",
            report.stages_built, report.stages_skipped, report.critical_path,
        );
    }
    for (i, layer) in report.image.layers.iter().enumerate() {
        let l = store.get(layer).unwrap();
        println!("  layer {i}: {} <- {}", layer, l.directive);
    }
    Ok(())
}

fn cmd_pipeline(raw: &[String]) -> anyhow::Result<()> {
    let args = Args::new("pipeline", "build -> push -> pull deployment pipeline");
    args.parse(raw)?;
    let trace = deploy_pipeline()?;
    print!("{}", trace.render());
    Ok(())
}

fn cmd_resolve(raw: &[String]) -> anyhow::Result<()> {
    let args = Args::new("resolve", "show MPI ABI resolution (the §4.2 trick)")
        .opt("machine", "workstation | edison", Some("edison"))
        .opt("runtime", "native | docker | rkt | shifter | vm", Some("shifter"))
        .switch("inject", "inject the host MPI via LD_LIBRARY_PATH");
    let p = args.parse(raw)?;
    let machine = machine_by_name(p.req("machine"))?;
    let runtime = match p.req("runtime") {
        "native" => RuntimeKind::Native,
        "docker" => RuntimeKind::Docker,
        "rkt" => RuntimeKind::Rkt,
        "shifter" => RuntimeKind::Shifter,
        "vm" => RuntimeKind::Vm,
        other => anyhow::bail!("unknown runtime `{other}`"),
    };
    let res = AbiResolver {
        machine: &machine,
        runtime,
        inject_host_mpi: p.flag("inject"),
    }
    .resolve();
    println!(
        "machine: {}  runtime: {runtime}  inject: {}",
        machine.name,
        p.flag("inject")
    );
    for step in &res.steps {
        println!("  {step}");
    }
    println!("=> library: {}  fabric: {:?}", res.library, res.fabric);
    Ok(())
}

fn cmd_run(raw: &[String]) -> anyhow::Result<()> {
    let args = Args::new("run", "run the Edison test program once")
        .opt(
            "platform",
            "native | shifter | shifter-container-mpi",
            Some("native"),
        )
        .opt("ranks", "MPI ranks", Some("24"))
        .opt("seed", "simulation seed", Some("42"))
        .switch("python", "Python driver (adds the import phase)")
        .switch("per-rank", "force the O(ranks) per-rank engine (default: class-batched)");
    let p = args.parse(raw)?;
    let platform: Platform = p.req("platform").parse().map_err(anyhow::Error::msg)?;
    let ranks: usize = p.parse_num("ranks")?;
    let seed: u64 = p.parse_num("seed")?;
    let mut cfg = if p.flag("python") {
        AppConfig::python(ranks, seed)
    } else {
        AppConfig::cpp(ranks, seed)
    };
    if p.flag("per-rank") {
        cfg = cfg.per_rank();
    }
    let table = CalibrationTable::load_or_default(None);
    let breakdown = run_poisson_app(platform, &mut Exec::Modeled { table: &table }, &cfg)?;
    println!(
        "poisson app on edison: platform={platform} ranks={ranks} driver={}",
        if p.flag("python") { "python" } else { "c++" }
    );
    for phase in breakdown.phase_names() {
        println!("  {phase:10} {:10.4} s", breakdown.get(phase));
    }
    println!("  {:10} {:10.4} s", "total", breakdown.total());
    Ok(())
}

fn cmd_bench(raw: &[String]) -> anyhow::Result<()> {
    let args = Args::new("bench", "regenerate a scenario's figures")
        .positional_opt(
            "scenario",
            "a registered scenario name or `all` (see `harbor bench --list`)",
        )
        .opt("reps", "repetitions per bar (paper: 5 ws / 3 hpc)", None)
        .opt("seed", "base simulation seed", None)
        .opt("config", "experiment config JSON (overrides defaults)", None)
        .opt("out", "also write a JSON report to this path", None)
        .opt(
            "nodes",
            "comma-separated fleet sizes (fig1-scale, chaos-canary), workers (build-farm), \
             registry shards (registry-storm) or manifest counts (dep-storm); binary \
             suffixes accepted (64k = 65536, 1m = 1048576)",
            None,
        )
        .opt(
            "jobs",
            "matrix workers; `auto` = available parallelism (bit-identical)",
            Some("auto"),
        )
        .opt(
            "domains",
            "lookahead domains per cell's event queue; 1 = serial reference \
             (bit-identical for any value)",
            Some("1"),
        )
        .switch("list", "list the registered scenarios and exit")
        .switch("json", "print JSON instead of ASCII bars")
        .switch("scale", "paper-scale rank counts (fig3/fig4: 1536, 12288, 98304)")
        .switch("per-rank", "force the O(ranks) per-rank engine (default: class-batched)");
    let p = args.parse(raw)?;
    let jobs = parse_workers(
        "jobs",
        p.req("jobs"),
        Some(harbor::scenario::MatrixRunner::available_jobs()),
    )?;
    let domains = parse_workers("domains", p.req("domains"), None)?;
    let coordinator = Coordinator::new().with_jobs(jobs);
    if p.flag("list") {
        println!("SCENARIOS (harbor bench <scenario>):");
        for (name, describe) in coordinator.registry().table() {
            println!("  {name:12} {describe}");
        }
        println!("\nThe same table lives in EXPERIMENTS.md's figure index.");
        return Ok(());
    }
    let Some(selected) = p.pos_opt(0) else {
        anyhow::bail!(
            "missing <scenario> (one of: {}, or `all`; `harbor bench --list` describes them)",
            coordinator.registry().names().join(", ")
        );
    };
    if p.flag("scale") && p.get("config").is_some() {
        anyhow::bail!("--scale conflicts with --config (set the scale ranks in the config file)");
    }
    let figures: Vec<String> = match selected {
        // `all` comes from the registry, so it can never go stale;
        // --scale keeps only the scenarios that define scale points
        "all" if p.flag("scale") => coordinator
            .registry()
            .names()
            .into_iter()
            .filter(|n| ExperimentConfig::paper_scale(n).is_ok())
            .map(|s| s.to_string())
            .collect(),
        "all" => coordinator
            .registry()
            .names()
            .iter()
            .map(|s| s.to_string())
            .collect(),
        one => vec![one.to_string()],
    };
    let takes_nodes = |f: &str| {
        f == "fig1-scale"
            || f == "build-farm"
            || f == "chaos-canary"
            || f == "registry-storm"
            || f == "dep-storm"
    };
    if p.get("nodes").is_some() && !figures.iter().any(|f| takes_nodes(f)) {
        anyhow::bail!(
            "--nodes only applies to fig1-scale, build-farm, chaos-canary, registry-storm \
             and dep-storm"
        );
    }
    let mut all_json = Vec::new();
    for figure in &figures {
        let mut cfg = match p.get("config") {
            Some(path) => ExperimentConfig::load(std::path::Path::new(path))?,
            None if p.flag("scale") => ExperimentConfig::paper_scale(figure)?,
            // defaults come from the scenario itself, so plug-ins that
            // override Scenario::default_config work through the CLI
            None => coordinator
                .registry()
                .get(figure)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "unknown scenario `{figure}` (registered: {})",
                        coordinator.registry().names().join(", ")
                    )
                })?
                .default_config()?,
        };
        cfg.figure = figure.clone();
        cfg.domains = domains;
        if p.flag("per-rank") {
            cfg.batched = false;
        }
        if let Some(reps) = p.get("reps") {
            cfg.reps = reps.parse()?;
        }
        if let Some(seed) = p.get("seed") {
            cfg.seed = seed.parse()?;
        }
        if let Some(nodes) = p.get("nodes") {
            if takes_nodes(figure) {
                // fleet-shaped scenarios run the collapsed engine, so
                // they take million-node rows; the shard/worker-shaped
                // ones stay per-entity and keep a tight ceiling
                let ceiling: usize = match figure.as_str() {
                    "fig1-scale" | "chaos-canary" => 1 << 20,
                    _ => 1024, // build-farm workers, registry-storm shards,
                               // dep-storm manifest counts
                };
                let parsed = nodes
                    .split(',')
                    .map(|s| parse_count(s.trim()))
                    .collect::<Result<Vec<_>, _>>()?;
                for &n in &parsed {
                    anyhow::ensure!(
                        n <= ceiling,
                        "--nodes {n} exceeds the {figure} ceiling of {ceiling} \
                         (suffixes: 64k = 65536, 1m = 1048576)"
                    );
                }
                cfg.nodes = parsed;
            }
        }
        let figs = coordinator.run(&cfg)?;
        for f in &figs {
            if p.flag("json") {
                println!("{}", f.to_json().to_pretty());
            } else {
                println!("{}", f.render());
            }
            all_json.push(f.to_json());
        }
    }
    if let Some(out) = p.get("out") {
        std::fs::write(out, Value::Arr(all_json).to_pretty())?;
        eprintln!("wrote JSON report to {out}");
    }
    Ok(())
}

fn cmd_calibrate(raw: &[String]) -> anyhow::Result<()> {
    let args = Args::new("calibrate", "measure per-artifact PJRT execution costs")
        .opt("out", "output path", Some("artifacts/calibration.json"))
        .opt("reps", "measurement repetitions per entry", Some("5"));
    let p = args.parse(raw)?;
    let mut engine = Engine::open_default()?;
    let reps: usize = p.parse_num("reps")?;
    eprintln!(
        "calibrating {} artifacts x {reps} reps ...",
        engine.manifest().entries.len()
    );
    let table = calibrate(&mut engine, reps)?;
    table.save(std::path::Path::new(p.req("out")))?;
    println!(
        "wrote {} entries to {} (source: {})",
        table.len(),
        p.req("out"),
        table.source
    );
    Ok(())
}

fn cmd_ablate(raw: &[String]) -> anyhow::Result<()> {
    let args = Args::new("ablate", "sensitivity sweeps over modelling choices")
        .positional("study", "mds | nic | nu | layers | all");
    let p = args.parse(raw)?;
    let studies: Vec<&str> = match p.pos(0) {
        "all" => harbor::workload::ablate::STUDIES.to_vec(),
        one => vec![one],
    };
    for s in studies {
        let a = harbor::workload::ablate::by_name(s)
            .ok_or_else(|| anyhow::anyhow!("unknown study `{s}` (mds|nic|nu|layers)"))?;
        println!("{}", a.render());
    }
    Ok(())
}

fn cmd_fenicsproject(raw: &[String]) -> anyhow::Result<()> {
    use harbor::container::{RuntimeKind, SessionManager};
    let args = Args::new(
        "fenicsproject",
        "walk through the §3.2 wrapper workflows in virtual time",
    )
    .opt("name", "project name", Some("my-project"))
    .opt("dir", "host directory shared into the container", Some("$(pwd)"));
    let p = args.parse(raw)?;
    let name = p.req("name");
    let dir = p.req("dir");
    let (image, _) = harbor::workload::fenics_image();
    let mut m = SessionManager::new(image, RuntimeKind::Docker);

    println!("$ fenicsproject notebook {name} {dir}");
    m.notebook(name, dir).map_err(anyhow::Error::msg)?;
    println!(
        "  notebook running at {}  (shared volume: {dir} -> /home/fenics/shared)",
        m.notebook_url(name).unwrap()
    );

    println!("$ fenicsproject stop {name}");
    m.stop(name).map_err(anyhow::Error::msg)?;
    println!("$ fenicsproject start {name}");
    m.start(name).map_err(anyhow::Error::msg)?;
    m.exec(name, "python3 demo_poisson.py").map_err(anyhow::Error::msg)?;
    println!("  resumed with its writable layer intact; ran demo_poisson.py");

    println!("$ fenicsproject list");
    for (session, state) in m.list() {
        println!("  {session:12} {state}");
    }
    println!("(virtual elapsed: {})", m.now());
    Ok(())
}

fn cmd_artifacts(raw: &[String]) -> anyhow::Result<()> {
    let args = Args::new("artifacts", "list AOT artifacts");
    args.parse(raw)?;
    let dir = harbor::runtime::artifacts_dir();
    let manifest = harbor::runtime::Manifest::load(&dir)?;
    println!(
        "{} artifacts in {} (format {})",
        manifest.entries.len(),
        dir.display(),
        manifest.format
    );
    for e in &manifest.entries {
        let ins: Vec<String> = e.inputs.iter().map(|t| format!("{:?}", t.shape)).collect();
        let outs: Vec<String> = e.outputs.iter().map(|t| format!("{:?}", t.shape)).collect();
        println!("  {:28} {} -> {}", e.name, ins.join(", "), outs.join(", "));
    }
    Ok(())
}

fn machine_by_name(name: &str) -> anyhow::Result<MachineSpec> {
    match name {
        "workstation" => Ok(MachineSpec::workstation()),
        "edison" => Ok(MachineSpec::edison()),
        other => anyhow::bail!("unknown machine `{other}` (workstation|edison)"),
    }
}
