//! Simulated MPI.
//!
//! The FEM drivers are bulk-synchronous: local compute, halo exchange,
//! allreduce, repeat.  [`Comm`] tracks one virtual clock per rank and
//! advances them through those phases using the α-β fabric models plus
//! per-node NIC serialisation — enough to reproduce the communication
//! behaviour behind Figs 3–5 without packet-level simulation.
//!
//! [`RankClasses`] collapses symmetric ranks into equivalence classes
//! and [`HaloPattern`] pre-compiles a uniform halo phase against them,
//! so the bulk-synchronous hot loops run in O(classes) instead of
//! O(ranks) — the refactor that makes paper-scale (1k–100k rank)
//! figure regeneration tractable (EXPERIMENTS.md §Perf).
//!
//! [`AbiResolver`] models the paper's central deployment trick (§4.2):
//! swapping the container's MPICH for the ABI-compatible Cray library at
//! load time via `LD_LIBRARY_PATH`, which is what decides whether a job
//! gets the Aries fabric or the TCP fallback.

mod abi;
mod classes;
mod comm;

pub use abi::{AbiResolver, McaResolution};
pub use classes::{HaloPattern, RankClasses};
pub use comm::{Comm, CommStats};
