//! MPICH ABI resolution — the paper's deployment trick, §4.2.
//!
//! On Edison the job script copies the Cray MPI shared objects to a
//! container-visible path and prepends it to `LD_LIBRARY_PATH`; because
//! Cray MPI implements the MPICH ABI, the container's dynamically linked
//! application transparently picks up the host library and with it the
//! Aries fabric.  [`AbiResolver`] models that load-time search: which
//! `libmpi.so` wins, whether its ABI matches what the binary was linked
//! against, and therefore which fabric the job runs on.  The recorded
//! steps double as the explanation users see in traces.

use crate::cluster::MachineSpec;
use crate::container::RuntimeKind;
use crate::net::FabricKind;

/// Outcome of resolving `libmpi.so.12` at container start.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct McaResolution {
    /// The fabric the resolved library can drive.
    pub fabric: FabricKind,
    /// Which library won the search.
    pub library: String,
    /// Human-readable resolution trace (one line per search step).
    pub steps: Vec<String>,
}

/// Models the dynamic-linker search for the MPI library.
pub struct AbiResolver<'m> {
    /// Machine whose system MPI is (maybe) visible.
    pub machine: &'m MachineSpec,
    /// Runtime the application runs under.
    pub runtime: RuntimeKind,
    /// `LD_LIBRARY_PATH` injection of the host MPI (the Bahls trick).
    pub inject_host_mpi: bool,
}

impl<'m> AbiResolver<'m> {
    /// Walk the linker search order and report every step plus the
    /// resulting library and fabric.
    pub fn resolve(&self) -> McaResolution {
        let mut steps = Vec::new();

        if self.runtime == RuntimeKind::Native {
            steps.push(format!(
                "native binary linked against system MPI on {}",
                self.machine.name
            ));
            return McaResolution {
                fabric: self.machine.host_fabric,
                library: "system libmpi.so.12".into(),
                steps,
            };
        }

        if self.inject_host_mpi {
            steps.push("LD_LIBRARY_PATH=$SCRATCH/hpc-mpich/lib prepended".into());
            if self.machine.system_mpi_abi_compatible {
                steps.push("host libmpi.so.12 found; ABI check: MPICH-compatible ✓".into());
                steps.push(format!(
                    "binding to host MPI -> {:?} fabric",
                    self.machine.host_fabric
                ));
                return McaResolution {
                    fabric: self.machine.host_fabric,
                    library: "host (Cray) libmpi.so.12".into(),
                    steps,
                };
            }
            steps.push(
                "host libmpi.so.12 found but ABI-incompatible; loader falls through".into(),
            );
        } else {
            steps.push("no host-library injection requested".into());
        }

        steps.push("container's bundled MPICH (Ubuntu package) resolved".into());
        let fabric = if self.machine.num_nodes == 1 {
            steps.push("single node: nemesis shared-memory channel".into());
            FabricKind::SharedMem
        } else {
            steps.push("multi-node: MPICH has no Aries netmod -> TCP over management Ethernet".into());
            FabricKind::TcpEthernet
        };
        McaResolution {
            fabric,
            library: "container libmpi.so.12 (MPICH)".into(),
            steps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shifter_with_injection_gets_aries() {
        let edison = MachineSpec::edison();
        let r = AbiResolver {
            machine: &edison,
            runtime: RuntimeKind::Shifter,
            inject_host_mpi: true,
        }
        .resolve();
        assert_eq!(r.fabric, FabricKind::Aries);
        assert!(r.library.contains("Cray"));
        assert!(r.steps.iter().any(|s| s.contains("ABI check")));
    }

    #[test]
    fn shifter_without_injection_falls_to_tcp() {
        let edison = MachineSpec::edison();
        let r = AbiResolver {
            machine: &edison,
            runtime: RuntimeKind::Shifter,
            inject_host_mpi: false,
        }
        .resolve();
        assert_eq!(r.fabric, FabricKind::TcpEthernet);
        assert!(r.steps.iter().any(|s| s.contains("TCP")));
    }

    #[test]
    fn abi_mismatch_defeats_injection() {
        let mut m = MachineSpec::edison();
        m.system_mpi_abi_compatible = false;
        let r = AbiResolver {
            machine: &m,
            runtime: RuntimeKind::Shifter,
            inject_host_mpi: true,
        }
        .resolve();
        assert_eq!(r.fabric, FabricKind::TcpEthernet);
        assert!(r.steps.iter().any(|s| s.contains("ABI-incompatible")));
    }

    #[test]
    fn workstation_container_mpi_is_shared_mem() {
        let ws = MachineSpec::workstation();
        let r = AbiResolver {
            machine: &ws,
            runtime: RuntimeKind::Docker,
            inject_host_mpi: false,
        }
        .resolve();
        assert_eq!(r.fabric, FabricKind::SharedMem);
    }

    #[test]
    fn native_short_circuits() {
        let edison = MachineSpec::edison();
        let r = AbiResolver {
            machine: &edison,
            runtime: RuntimeKind::Native,
            inject_host_mpi: false,
        }
        .resolve();
        assert_eq!(r.fabric, FabricKind::Aries);
        assert_eq!(r.steps.len(), 1);
    }

    #[test]
    fn resolution_matches_runtime_adapter() {
        // AbiResolver and ContainerRuntime::resolve_fabric must agree
        use crate::container::runtime::by_kind;
        let edison = MachineSpec::edison();
        for kind in [RuntimeKind::Shifter, RuntimeKind::Docker, RuntimeKind::Native] {
            for inject in [false, true] {
                let via_resolver = AbiResolver {
                    machine: &edison,
                    runtime: kind,
                    inject_host_mpi: inject,
                }
                .resolve()
                .fabric;
                let via_adapter = by_kind(kind).resolve_fabric(&edison, inject);
                assert_eq!(via_resolver, via_adapter, "{kind:?} inject={inject}");
            }
        }
    }
}
