//! The communicator: per-rank virtual clocks + costed collectives,
//! with an optional class-batched representation for symmetric jobs.
//!
//! When a [`RankClasses`] partition is installed (`set_classes`), the
//! communicator keeps **one clock per class** instead of one per rank,
//! and the phase operations run in O(classes).  The representation is
//! exact — `clock(rank)` reads identically in either mode — and it
//! *falls back transparently*: any operation whose result would not be
//! uniform within a class (a per-rank `advance`, an arbitrary message
//! list, a batched exchange from non-uniform entry clocks) first
//! materialises the per-rank clocks and proceeds on them.  Synchronising
//! collectives re-enter batched mode, since they leave every clock
//! equal.  This is what lets the modeled solvers run at paper-scale rank
//! counts (see EXPERIMENTS.md §Perf) without changing a single
//! `VirtualTime` on the sizes the per-rank path can still reach.

use crate::cluster::Allocation;
use crate::des::{Duration, VirtualTime};
use crate::net::Fabric;

use super::{HaloPattern, RankClasses};

/// Cumulative communication statistics (for reports and tests).
#[derive(Debug, Clone, Copy, Default)]
pub struct CommStats {
    /// Point-to-point messages sent.
    pub p2p_messages: u64,
    /// Point-to-point payload bytes.
    pub p2p_bytes: u64,
    /// All-reduce collectives performed.
    pub allreduces: u64,
    /// Barriers performed.
    pub barriers: u64,
}

/// A simulated communicator over an allocation's ranks.
///
/// All operations are *phase* operations: they read the clocks as they
/// stand at entry, compute arrival times, and write the updated clocks.
/// This snapshot semantics makes the result independent of rank
/// iteration order, which keeps simulations deterministic.
#[derive(Debug, Clone)]
pub struct Comm {
    alloc: Allocation,
    fabric: Fabric,
    /// Per-rank clocks; authoritative when `!batched`.
    clocks: Vec<VirtualTime>,
    /// Installed partition (kept even while running per-rank, so
    /// synchronising collectives can re-enter batched mode).
    classes: Option<RankClasses>,
    /// Per-class clocks; authoritative when `batched`.
    class_clocks: Vec<VirtualTime>,
    batched: bool,
    stats: CommStats,
    // reusable scratch (see `exchange`)
    entry_scratch: Vec<VirtualTime>,
    node_bytes_scratch: Vec<u64>,
}

impl Comm {
    /// A communicator over `alloc` using `fabric` costs.
    pub fn new(alloc: Allocation, fabric: Fabric) -> Self {
        let n = alloc.ranks();
        Comm {
            alloc,
            fabric,
            clocks: vec![VirtualTime::ZERO; n],
            classes: None,
            class_clocks: Vec::new(),
            batched: false,
            stats: CommStats::default(),
            entry_scratch: Vec::with_capacity(n),
            node_bytes_scratch: Vec::new(),
        }
    }

    /// Number of ranks.
    pub fn size(&self) -> usize {
        self.clocks.len()
    }

    /// The fabric this communicator resolves to.
    pub fn fabric(&self) -> &Fabric {
        &self.fabric
    }

    /// The job allocation (rank → node placement).
    pub fn allocation(&self) -> &Allocation {
        &self.alloc
    }

    /// Install a rank partition and enter class-batched mode if the
    /// current clocks are uniform within every class. Returns whether
    /// batched mode is engaged now (if not, it engages at the next
    /// synchronising collective).
    pub fn set_classes(&mut self, classes: RankClasses) -> bool {
        assert_eq!(
            classes.ranks(),
            self.size(),
            "partition covers {} ranks, communicator has {}",
            classes.ranks(),
            self.size()
        );
        self.materialize();
        self.class_clocks.clear();
        self.class_clocks
            .extend((0..classes.len()).map(|c| self.clocks[classes.representative(c)]));
        let uniform = (0..self.size())
            .all(|r| self.clocks[r] == self.class_clocks[classes.class_of(r) as usize]);
        self.batched = uniform;
        self.classes = Some(classes);
        self.batched
    }

    /// The installed partition, if any.
    pub fn classes(&self) -> Option<&RankClasses> {
        self.classes.as_ref()
    }

    /// Whether phase operations currently run on class clocks.
    pub fn is_batched(&self) -> bool {
        self.batched
    }

    /// Leave batched mode: write each class clock through to its member
    /// ranks. Idempotent; the partition stays installed.
    fn materialize(&mut self) {
        if !self.batched {
            return;
        }
        let classes = self.classes.as_ref().expect("batched implies classes");
        for (r, c) in self.clocks.iter_mut().zip(classes.map()) {
            *r = self.class_clocks[*c as usize];
        }
        self.batched = false;
    }

    /// Set every clock to exactly `t` (synchronising collectives); if a
    /// partition is installed this re-enters batched mode, since a
    /// globally uniform state is trivially class-uniform.
    fn sync_all_to(&mut self, t: VirtualTime) {
        if let Some(classes) = &self.classes {
            self.class_clocks.clear();
            self.class_clocks.resize(classes.len(), t);
            self.batched = true;
        } else {
            for c in &mut self.clocks {
                *c = t;
            }
        }
    }

    /// The virtual clock of `rank`.
    pub fn clock(&self, rank: usize) -> VirtualTime {
        if self.batched {
            let classes = self.classes.as_ref().expect("batched implies classes");
            self.class_clocks[classes.class_of(rank) as usize]
        } else {
            self.clocks[rank]
        }
    }

    /// The job's wall clock: the furthest-ahead rank.
    pub fn max_clock(&self) -> VirtualTime {
        let clocks = if self.batched { &self.class_clocks } else { &self.clocks };
        clocks.iter().copied().max().unwrap_or(VirtualTime::ZERO)
    }

    /// Cumulative communication statistics.
    pub fn stats(&self) -> CommStats {
        self.stats
    }

    /// Advance one rank's clock by local (compute / IO) work. Breaks
    /// class uniformity, so batched mode falls back to per-rank clocks.
    pub fn advance(&mut self, rank: usize, d: Duration) {
        self.materialize();
        self.clocks[rank] += d;
    }

    /// Advance every member of class `c` by `d` (O(1) when batched).
    pub fn advance_class(&mut self, c: usize, d: Duration) {
        if self.batched {
            self.class_clocks[c] += d;
            return;
        }
        let Some(classes) = &self.classes else {
            panic!("advance_class needs a partition (set_classes)");
        };
        for (r, &cls) in classes.map().iter().enumerate() {
            if cls as usize == c {
                self.clocks[r] += d;
            }
        }
    }

    /// Advance every rank by the same `d` (uniform compute phase):
    /// O(classes) when batched, O(ranks) otherwise.
    pub fn advance_uniform(&mut self, d: Duration) {
        let clocks = if self.batched { &mut self.class_clocks } else { &mut self.clocks };
        for c in clocks {
            *c += d;
        }
    }

    /// Set every clock to at least `t` (e.g. after a containerised
    /// process start completes at different times per rank).
    pub fn advance_all_to(&mut self, t: VirtualTime) {
        let clocks = if self.batched { &mut self.class_clocks } else { &mut self.clocks };
        for c in clocks {
            *c = (*c).max(t);
        }
    }

    /// One phase of point-to-point messages `(src, dst, bytes)`.
    ///
    /// Every message is timed from the *sender's* phase-entry clock;
    /// each node's off-node bytes serialise through its NIC; a receiver
    /// completes when its last incoming message lands (and not before
    /// its own phase-entry clock).
    pub fn exchange(&mut self, msgs: &[(usize, usize, u64)]) {
        self.materialize();
        // PERF: `entry` snapshot and the per-node byte tally are flat
        // vectors (a HashMap here was ~15% of large modeled runs; see
        // EXPERIMENTS.md §Perf). The scratch buffers live on self so a
        // solver iterating thousands of phases does not reallocate.
        self.entry_scratch.clear();
        self.entry_scratch.extend_from_slice(&self.clocks);
        let entry = &self.entry_scratch;

        if self.node_bytes_scratch.len() < self.alloc.nodes_used {
            self.node_bytes_scratch.resize(self.alloc.nodes_used, 0);
        }
        for b in &mut self.node_bytes_scratch {
            *b = 0;
        }
        for &(src, dst, bytes) in msgs {
            if !self.alloc.same_node(src, dst) {
                self.node_bytes_scratch[self.alloc.node_of[src]] += bytes;
            }
        }

        // PERF: halo phases are uniform-payload, so the four possible
        // path costs are computed once instead of per message (float ->
        // Duration conversions were ~40% of a modeled exchange).
        let uniform = msgs.first().map(|&(_, _, b)| b).filter(|&b| {
            msgs.iter().all(|&(_, _, bytes)| bytes == b)
        });
        let pre = uniform.map(|b| {
            (
                self.fabric.p2p(b, true),
                self.fabric.p2p(b, false),
                self.fabric.p2p(0, true),
                self.fabric.p2p(0, false),
            )
        });

        for &(src, dst, bytes) in msgs {
            let same = self.alloc.same_node(src, dst);
            let (transfer, send_overhead) = match &pre {
                Some((t_same, t_diff, o_same, o_diff)) => {
                    if same {
                        (*t_same, *o_same)
                    } else {
                        (*t_diff, *o_diff)
                    }
                }
                None => (self.fabric.p2p(bytes, same), self.fabric.p2p(0, same)),
            };
            let mut arrive = entry[src] + transfer;
            if !same {
                let injected = self.node_bytes_scratch[self.alloc.node_of[src]];
                arrive += self.fabric.nic_serialisation(injected);
            }
            self.clocks[dst] = self.clocks[dst].max(arrive);
            // sending occupies the sender briefly (library overhead)
            self.clocks[src] = self.clocks[src].max(entry[src] + send_overhead);
        }
        self.stats.p2p_messages += msgs.len() as u64;
        self.stats.p2p_bytes += msgs.iter().map(|&(_, _, b)| b).sum::<u64>();
    }

    /// A uniform-payload halo phase, class-batched when exact.
    ///
    /// The O(classes) path runs when (a) a partition matching the
    /// pattern is installed and (b) all clocks currently stand at one
    /// instant — the state every synchronising collective leaves behind,
    /// and the state the bulk-synchronous solvers are in at every halo
    /// phase. From a uniform entry `t`, the per-rank exchange advances
    /// each rank to a value that depends only on its one-hop signature
    /// (shared faces, same-node flags, sender-node NIC load), which is
    /// exactly what [`HaloPattern`] records per class — so the batched
    /// update is bit-identical to replaying `pattern.messages`. From any
    /// other state it simply replays the messages per rank.
    pub fn exchange_uniform(&mut self, pattern: &HaloPattern) {
        if self.batched && pattern.class_edges.len() == self.class_clocks.len() {
            let t0 = self.class_clocks.first().copied().unwrap_or(VirtualTime::ZERO);
            if self.class_clocks.iter().all(|&c| c == t0) {
                let t_same = self.fabric.p2p(pattern.bytes, true);
                let t_diff = self.fabric.p2p(pattern.bytes, false);
                let o_same = self.fabric.p2p(0, true);
                let o_diff = self.fabric.p2p(0, false);
                for (c, edges) in pattern.class_edges.iter().enumerate() {
                    let mut new = t0;
                    for &(same, src_node_msgs) in edges {
                        // outgoing: the sender-side library overhead
                        new = new.max(t0 + if same { o_same } else { o_diff });
                        // incoming: transfer + the sender's NIC backlog
                        let mut arrive = t0 + if same { t_same } else { t_diff };
                        if !same {
                            arrive += self
                                .fabric
                                .nic_serialisation(pattern.bytes * src_node_msgs as u64);
                        }
                        new = new.max(arrive);
                    }
                    self.class_clocks[c] = new;
                }
                self.stats.p2p_messages += pattern.messages.len() as u64;
                self.stats.p2p_bytes += pattern.total_bytes();
                return;
            }
        }
        self.exchange(&pattern.messages);
    }

    /// Allreduce of `bytes` per rank (recursive-doubling model):
    /// a synchronising collective costing `2 ceil(log2 p) (α + s/β)` on
    /// the worst path in the allocation.
    pub fn allreduce(&mut self, bytes: u64) {
        let p = self.size() as u64;
        if p <= 1 {
            return;
        }
        let start = self.max_clock();
        let rounds = 64 - (p - 1).leading_zeros() as u64; // ceil(log2 p)
        let multi_node = self.alloc.nodes_used > 1;
        let per_round = self.fabric.p2p(bytes, !multi_node);
        let cost = per_round * (2 * rounds);
        self.sync_all_to(start + cost);
        self.stats.allreduces += 1;
    }

    /// Barrier: synchronise all clocks (tree of empty messages).
    pub fn barrier(&mut self) {
        let p = self.size() as u64;
        let start = self.max_clock();
        let rounds = if p <= 1 { 0 } else { 64 - (p - 1).leading_zeros() as u64 };
        let multi_node = self.alloc.nodes_used > 1;
        self.sync_all_to(start + self.fabric.p2p(0, !multi_node) * rounds);
        self.stats.barriers += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{launch, MachineSpec};
    use crate::fem::grid::Decomp;
    use crate::net::FabricKind;

    fn comm(ranks: usize, fabric: FabricKind) -> Comm {
        let m = MachineSpec::edison();
        Comm::new(launch(&m, ranks).unwrap(), Fabric::by_kind(fabric))
    }

    #[test]
    fn advance_moves_one_clock() {
        let mut c = comm(4, FabricKind::Aries);
        c.advance(2, Duration::from_millis(10));
        assert_eq!(c.clock(2).as_secs_f64(), 0.010);
        assert_eq!(c.clock(0), VirtualTime::ZERO);
        assert_eq!(c.max_clock().as_secs_f64(), 0.010);
    }

    #[test]
    fn exchange_order_independent() {
        // same messages, different order => same clocks
        let msgs_a = [(0usize, 1usize, 1000u64), (1, 0, 1000), (2, 3, 500)];
        let mut msgs_b = msgs_a;
        msgs_b.reverse();
        let mut ca = comm(4, FabricKind::Aries);
        let mut cb = comm(4, FabricKind::Aries);
        ca.advance(1, Duration::from_millis(3));
        cb.advance(1, Duration::from_millis(3));
        ca.exchange(&msgs_a);
        cb.exchange(&msgs_b);
        for r in 0..4 {
            assert_eq!(ca.clock(r), cb.clock(r), "rank {r}");
        }
    }

    #[test]
    fn receiver_waits_for_slow_sender() {
        let mut c = comm(2, FabricKind::Aries);
        c.advance(0, Duration::from_millis(50)); // rank 0 is behind in compute
        c.exchange(&[(0, 1, 8)]);
        assert!(c.clock(1).as_secs_f64() >= 0.050);
    }

    #[test]
    fn tcp_cross_node_is_much_slower_than_aries() {
        // ranks 0 and 47 are on different Edison nodes (24 cores/node)
        let msg = [(0usize, 47usize, 1_000_000u64)];
        let mut aries = comm(48, FabricKind::Aries);
        let mut tcp = comm(48, FabricKind::TcpEthernet);
        aries.exchange(&msg);
        tcp.exchange(&msg);
        let ratio = tcp.clock(47).as_secs_f64() / aries.clock(47).as_secs_f64();
        assert!(ratio > 20.0, "expected order-of-magnitude gap, got {ratio}");
    }

    #[test]
    fn same_node_exchange_fabric_insensitive() {
        let msg = [(0usize, 1usize, 1_000_000u64)];
        let mut aries = comm(24, FabricKind::Aries);
        let mut tcp = comm(24, FabricKind::TcpEthernet);
        aries.exchange(&msg);
        tcp.exchange(&msg);
        let ratio = tcp.clock(1).as_secs_f64() / aries.clock(1).as_secs_f64();
        assert!(ratio < 2.0, "single-node should not depend on fabric: {ratio}");
    }

    #[test]
    fn nic_contention_compounds() {
        // all 24 ranks of node 0 send off-node simultaneously over TCP:
        // the shared GbE NIC serialises ~24 MB -> ~0.2 s extra
        let msgs: Vec<_> = (0..24).map(|r| (r, 24 + r, 1_000_000u64)).collect();
        let mut c = comm(48, FabricKind::TcpEthernet);
        c.exchange(&msgs);
        let worst = c.max_clock().as_secs_f64();
        assert!(worst > 0.2, "expected NIC serialisation, got {worst}");
    }

    #[test]
    fn allreduce_synchronises_everyone() {
        let mut c = comm(8, FabricKind::Aries);
        c.advance(3, Duration::from_millis(20));
        c.allreduce(8);
        let t = c.clock(0);
        assert!(t.as_secs_f64() > 0.020);
        for r in 0..8 {
            assert_eq!(c.clock(r), t);
        }
        assert_eq!(c.stats().allreduces, 1);
    }

    #[test]
    fn allreduce_cost_grows_with_ranks_and_fabric() {
        let mut small = comm(24, FabricKind::Aries);
        let mut large = comm(192, FabricKind::Aries);
        small.allreduce(8);
        large.allreduce(8);
        assert!(large.max_clock() > small.max_clock());

        let mut tcp = comm(192, FabricKind::TcpEthernet);
        tcp.allreduce(8);
        let ratio = tcp.max_clock().as_secs_f64() / large.max_clock().as_secs_f64();
        assert!(ratio > 10.0, "TCP allreduce should dominate: {ratio}");
    }

    #[test]
    fn single_rank_collectives_are_free() {
        let mut c = comm(1, FabricKind::Aries);
        c.allreduce(1 << 20);
        c.barrier();
        assert_eq!(c.max_clock(), VirtualTime::ZERO);
    }

    #[test]
    fn barrier_counts_and_syncs() {
        let mut c = comm(4, FabricKind::Aries);
        c.advance(0, Duration::from_millis(1));
        c.barrier();
        assert_eq!(c.stats().barriers, 1);
        let t = c.clock(0);
        assert!((1..4).all(|r| c.clock(r) == t));
    }

    #[test]
    fn stats_accumulate() {
        let mut c = comm(4, FabricKind::Aries);
        c.exchange(&[(0, 1, 100), (2, 3, 200)]);
        assert_eq!(c.stats().p2p_messages, 2);
        assert_eq!(c.stats().p2p_bytes, 300);
    }

    // ---- class-batched mode -------------------------------------------

    fn classed_pair(ranks: usize, kind: FabricKind) -> (Comm, Comm, Decomp) {
        let decomp = Decomp::new(ranks, 16);
        let mut batched = comm(ranks, kind);
        let per_rank = comm(ranks, kind);
        assert!(batched.set_classes(decomp.rank_classes(batched.allocation())));
        (batched, per_rank, decomp)
    }

    #[test]
    fn batched_exchange_matches_per_rank_bit_for_bit() {
        for ranks in [1usize, 2, 8, 24, 48, 96, 192] {
            for kind in [FabricKind::Aries, FabricKind::TcpEthernet] {
                let (mut b, mut p, decomp) = classed_pair(ranks, kind);
                let pat = decomp.halo_pattern_for(&b, decomp.face_bytes());
                b.exchange_uniform(&pat);
                p.exchange(&decomp.halo_messages(decomp.face_bytes()));
                for r in 0..ranks {
                    assert_eq!(b.clock(r), p.clock(r), "ranks {ranks} {kind:?} rank {r}");
                }
                assert_eq!(b.stats().p2p_messages, p.stats().p2p_messages);
                assert_eq!(b.stats().p2p_bytes, p.stats().p2p_bytes);
                assert!(b.is_batched(), "exchange from uniform entry stays batched");
            }
        }
    }

    #[test]
    fn batched_collectives_match_per_rank() {
        let (mut b, mut p, _) = classed_pair(96, FabricKind::Aries);
        b.advance_uniform(Duration::from_millis(2));
        p.advance_uniform(Duration::from_millis(2));
        b.allreduce(8);
        p.allreduce(8);
        b.barrier();
        p.barrier();
        for r in 0..96 {
            assert_eq!(b.clock(r), p.clock(r));
        }
        assert!(b.is_batched());
    }

    #[test]
    fn per_rank_advance_falls_back_and_collective_recovers() {
        let (mut b, _, _) = classed_pair(48, FabricKind::Aries);
        assert!(b.is_batched());
        b.advance(7, Duration::from_millis(1));
        assert!(!b.is_batched(), "per-rank advance must leave batched mode");
        assert_eq!(b.clock(7).as_secs_f64(), 0.001);
        assert_eq!(b.clock(6), VirtualTime::ZERO);
        b.barrier();
        assert!(b.is_batched(), "barrier re-enters batched mode");
    }

    #[test]
    fn batched_exchange_from_nonuniform_entry_falls_back() {
        let (mut b, mut p, decomp) = classed_pair(48, FabricKind::Aries);
        b.advance(0, Duration::from_millis(5));
        p.advance(0, Duration::from_millis(5));
        let pat = decomp.halo_pattern_for(&b, decomp.face_bytes());
        b.exchange_uniform(&pat);
        p.exchange(&decomp.halo_messages(decomp.face_bytes()));
        assert!(!b.is_batched());
        for r in 0..48 {
            assert_eq!(b.clock(r), p.clock(r), "rank {r}");
        }
    }

    #[test]
    fn advance_class_moves_whole_class_only() {
        let (mut b, _, decomp) = classed_pair(27, FabricKind::Aries);
        let classes = decomp.rank_classes(b.allocation());
        let c = classes.class_of(13) as usize; // an interior-ish rank
        b.advance_class(c, Duration::from_millis(3));
        for r in 0..27 {
            let expect = if classes.class_of(r) as usize == c { 0.003 } else { 0.0 };
            assert_eq!(b.clock(r).as_secs_f64(), expect, "rank {r}");
        }
        assert!(b.is_batched());
    }

    #[test]
    fn set_classes_on_divergent_clocks_defers_batching() {
        let decomp = Decomp::new(8, 16);
        let mut c = comm(8, FabricKind::Aries);
        c.advance(3, Duration::from_millis(1)); // breaks class uniformity
        assert!(!c.set_classes(decomp.rank_classes(c.allocation())));
        assert!(!c.is_batched());
        c.allreduce(8);
        assert!(c.is_batched(), "sync re-engages the partition");
    }
}
