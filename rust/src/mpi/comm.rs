//! The communicator: per-rank virtual clocks + costed collectives.

use crate::cluster::Allocation;
use crate::des::{Duration, VirtualTime};
use crate::net::Fabric;

/// Cumulative communication statistics (for reports and tests).
#[derive(Debug, Clone, Copy, Default)]
pub struct CommStats {
    pub p2p_messages: u64,
    pub p2p_bytes: u64,
    pub allreduces: u64,
    pub barriers: u64,
}

/// A simulated communicator over an allocation's ranks.
///
/// All operations are *phase* operations: they read the clocks as they
/// stand at entry, compute arrival times, and write the updated clocks.
/// This snapshot semantics makes the result independent of rank
/// iteration order, which keeps simulations deterministic.
#[derive(Debug, Clone)]
pub struct Comm {
    alloc: Allocation,
    fabric: Fabric,
    clocks: Vec<VirtualTime>,
    stats: CommStats,
    // reusable scratch (see `exchange`)
    entry_scratch: Vec<VirtualTime>,
    node_bytes_scratch: Vec<u64>,
}

impl Comm {
    pub fn new(alloc: Allocation, fabric: Fabric) -> Self {
        let n = alloc.ranks();
        Comm {
            alloc,
            fabric,
            clocks: vec![VirtualTime::ZERO; n],
            stats: CommStats::default(),
            entry_scratch: Vec::with_capacity(n),
            node_bytes_scratch: Vec::new(),
        }
    }

    pub fn size(&self) -> usize {
        self.clocks.len()
    }

    pub fn fabric(&self) -> &Fabric {
        &self.fabric
    }

    pub fn allocation(&self) -> &Allocation {
        &self.alloc
    }

    pub fn clock(&self, rank: usize) -> VirtualTime {
        self.clocks[rank]
    }

    /// The job's wall clock: the furthest-ahead rank.
    pub fn max_clock(&self) -> VirtualTime {
        self.clocks.iter().copied().max().unwrap_or(VirtualTime::ZERO)
    }

    pub fn stats(&self) -> CommStats {
        self.stats
    }

    /// Advance one rank's clock by local (compute / IO) work.
    pub fn advance(&mut self, rank: usize, d: Duration) {
        self.clocks[rank] += d;
    }

    /// Set every clock to at least `t` (e.g. after a containerised
    /// process start completes at different times per rank).
    pub fn advance_all_to(&mut self, t: VirtualTime) {
        for c in &mut self.clocks {
            *c = (*c).max(t);
        }
    }

    /// One phase of point-to-point messages `(src, dst, bytes)`.
    ///
    /// Every message is timed from the *sender's* phase-entry clock;
    /// each node's off-node bytes serialise through its NIC; a receiver
    /// completes when its last incoming message lands (and not before
    /// its own phase-entry clock).
    pub fn exchange(&mut self, msgs: &[(usize, usize, u64)]) {
        // PERF: `entry` snapshot and the per-node byte tally are flat
        // vectors (a HashMap here was ~15% of large modeled runs; see
        // EXPERIMENTS.md §Perf). The scratch buffers live on self so a
        // solver iterating thousands of phases does not reallocate.
        self.entry_scratch.clear();
        self.entry_scratch.extend_from_slice(&self.clocks);
        let entry = &self.entry_scratch;

        if self.node_bytes_scratch.len() < self.alloc.nodes_used {
            self.node_bytes_scratch.resize(self.alloc.nodes_used, 0);
        }
        for b in &mut self.node_bytes_scratch {
            *b = 0;
        }
        for &(src, dst, bytes) in msgs {
            if !self.alloc.same_node(src, dst) {
                self.node_bytes_scratch[self.alloc.node_of[src]] += bytes;
            }
        }

        // PERF: halo phases are uniform-payload, so the four possible
        // path costs are computed once instead of per message (float ->
        // Duration conversions were ~40% of a modeled exchange).
        let uniform = msgs.first().map(|&(_, _, b)| b).filter(|&b| {
            msgs.iter().all(|&(_, _, bytes)| bytes == b)
        });
        let pre = uniform.map(|b| {
            (
                self.fabric.p2p(b, true),
                self.fabric.p2p(b, false),
                self.fabric.p2p(0, true),
                self.fabric.p2p(0, false),
            )
        });

        for &(src, dst, bytes) in msgs {
            let same = self.alloc.same_node(src, dst);
            let (transfer, send_overhead) = match &pre {
                Some((t_same, t_diff, o_same, o_diff)) => {
                    if same {
                        (*t_same, *o_same)
                    } else {
                        (*t_diff, *o_diff)
                    }
                }
                None => (self.fabric.p2p(bytes, same), self.fabric.p2p(0, same)),
            };
            let mut arrive = entry[src] + transfer;
            if !same {
                let injected = self.node_bytes_scratch[self.alloc.node_of[src]];
                arrive += self.fabric.nic_serialisation(injected);
            }
            self.clocks[dst] = self.clocks[dst].max(arrive);
            // sending occupies the sender briefly (library overhead)
            self.clocks[src] = self.clocks[src].max(entry[src] + send_overhead);
        }
        self.stats.p2p_messages += msgs.len() as u64;
        self.stats.p2p_bytes += msgs.iter().map(|&(_, _, b)| b).sum::<u64>();
    }

    /// Allreduce of `bytes` per rank (recursive-doubling model):
    /// a synchronising collective costing `2 ceil(log2 p) (α + s/β)` on
    /// the worst path in the allocation.
    pub fn allreduce(&mut self, bytes: u64) {
        let p = self.size() as u64;
        if p <= 1 {
            return;
        }
        let start = self.max_clock();
        let rounds = 64 - (p - 1).leading_zeros() as u64; // ceil(log2 p)
        let multi_node = self.alloc.nodes_used > 1;
        let per_round = self.fabric.p2p(bytes, !multi_node);
        let cost = per_round * (2 * rounds);
        let done = start + cost;
        for c in &mut self.clocks {
            *c = done;
        }
        self.stats.allreduces += 1;
    }

    /// Barrier: synchronise all clocks (tree of empty messages).
    pub fn barrier(&mut self) {
        let p = self.size() as u64;
        let start = self.max_clock();
        let rounds = if p <= 1 { 0 } else { 64 - (p - 1).leading_zeros() as u64 };
        let multi_node = self.alloc.nodes_used > 1;
        let done = start + self.fabric.p2p(0, !multi_node) * rounds;
        for c in &mut self.clocks {
            *c = done;
        }
        self.stats.barriers += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{launch, MachineSpec};
    use crate::net::FabricKind;

    fn comm(ranks: usize, fabric: FabricKind) -> Comm {
        let m = MachineSpec::edison();
        Comm::new(launch(&m, ranks).unwrap(), Fabric::by_kind(fabric))
    }

    #[test]
    fn advance_moves_one_clock() {
        let mut c = comm(4, FabricKind::Aries);
        c.advance(2, Duration::from_millis(10));
        assert_eq!(c.clock(2).as_secs_f64(), 0.010);
        assert_eq!(c.clock(0), VirtualTime::ZERO);
        assert_eq!(c.max_clock().as_secs_f64(), 0.010);
    }

    #[test]
    fn exchange_order_independent() {
        // same messages, different order => same clocks
        let msgs_a = [(0usize, 1usize, 1000u64), (1, 0, 1000), (2, 3, 500)];
        let mut msgs_b = msgs_a;
        msgs_b.reverse();
        let mut ca = comm(4, FabricKind::Aries);
        let mut cb = comm(4, FabricKind::Aries);
        ca.advance(1, Duration::from_millis(3));
        cb.advance(1, Duration::from_millis(3));
        ca.exchange(&msgs_a);
        cb.exchange(&msgs_b);
        for r in 0..4 {
            assert_eq!(ca.clock(r), cb.clock(r), "rank {r}");
        }
    }

    #[test]
    fn receiver_waits_for_slow_sender() {
        let mut c = comm(2, FabricKind::Aries);
        c.advance(0, Duration::from_millis(50)); // rank 0 is behind in compute
        c.exchange(&[(0, 1, 8)]);
        assert!(c.clock(1).as_secs_f64() >= 0.050);
    }

    #[test]
    fn tcp_cross_node_is_much_slower_than_aries() {
        // ranks 0 and 47 are on different Edison nodes (24 cores/node)
        let msg = [(0usize, 47usize, 1_000_000u64)];
        let mut aries = comm(48, FabricKind::Aries);
        let mut tcp = comm(48, FabricKind::TcpEthernet);
        aries.exchange(&msg);
        tcp.exchange(&msg);
        let ratio = tcp.clock(47).as_secs_f64() / aries.clock(47).as_secs_f64();
        assert!(ratio > 20.0, "expected order-of-magnitude gap, got {ratio}");
    }

    #[test]
    fn same_node_exchange_fabric_insensitive() {
        let msg = [(0usize, 1usize, 1_000_000u64)];
        let mut aries = comm(24, FabricKind::Aries);
        let mut tcp = comm(24, FabricKind::TcpEthernet);
        aries.exchange(&msg);
        tcp.exchange(&msg);
        let ratio = tcp.clock(1).as_secs_f64() / aries.clock(1).as_secs_f64();
        assert!(ratio < 2.0, "single-node should not depend on fabric: {ratio}");
    }

    #[test]
    fn nic_contention_compounds() {
        // all 24 ranks of node 0 send off-node simultaneously over TCP:
        // the shared GbE NIC serialises ~24 MB -> ~0.2 s extra
        let msgs: Vec<_> = (0..24).map(|r| (r, 24 + r, 1_000_000u64)).collect();
        let mut c = comm(48, FabricKind::TcpEthernet);
        c.exchange(&msgs);
        let worst = c.max_clock().as_secs_f64();
        assert!(worst > 0.2, "expected NIC serialisation, got {worst}");
    }

    #[test]
    fn allreduce_synchronises_everyone() {
        let mut c = comm(8, FabricKind::Aries);
        c.advance(3, Duration::from_millis(20));
        c.allreduce(8);
        let t = c.clock(0);
        assert!(t.as_secs_f64() > 0.020);
        for r in 0..8 {
            assert_eq!(c.clock(r), t);
        }
        assert_eq!(c.stats().allreduces, 1);
    }

    #[test]
    fn allreduce_cost_grows_with_ranks_and_fabric() {
        let mut small = comm(24, FabricKind::Aries);
        let mut large = comm(192, FabricKind::Aries);
        small.allreduce(8);
        large.allreduce(8);
        assert!(large.max_clock() > small.max_clock());

        let mut tcp = comm(192, FabricKind::TcpEthernet);
        tcp.allreduce(8);
        let ratio = tcp.max_clock().as_secs_f64() / large.max_clock().as_secs_f64();
        assert!(ratio > 10.0, "TCP allreduce should dominate: {ratio}");
    }

    #[test]
    fn single_rank_collectives_are_free() {
        let mut c = comm(1, FabricKind::Aries);
        c.allreduce(1 << 20);
        c.barrier();
        assert_eq!(c.max_clock(), VirtualTime::ZERO);
    }

    #[test]
    fn barrier_counts_and_syncs() {
        let mut c = comm(4, FabricKind::Aries);
        c.advance(0, Duration::from_millis(1));
        c.barrier();
        assert_eq!(c.stats().barriers, 1);
        let t = c.clock(0);
        assert!((1..4).all(|r| c.clock(r) == t));
    }

    #[test]
    fn stats_accumulate() {
        let mut c = comm(4, FabricKind::Aries);
        c.exchange(&[(0, 1, 100), (2, 3, 200)]);
        assert_eq!(c.stats().p2p_messages, 2);
        assert_eq!(c.stats().p2p_bytes, 300);
    }
}
