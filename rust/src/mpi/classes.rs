//! Rank equivalence classes and class-batched halo patterns.
//!
//! The FEM drivers are bulk-synchronous and their communication is
//! *symmetric*: after every synchronising collective all ranks stand at
//! the same instant, and a halo phase advances each rank by an amount
//! that depends only on its local signature — which faces it shares,
//! whether each neighbour is on the same node, and how loaded the
//! neighbour's NIC is.  Grouping ranks by that signature collapses the
//! per-phase cost from O(ranks) to O(classes): a 98304-rank Edison job
//! has ~340 classes (measured; see EXPERIMENTS.md §Perf), so the
//! simulator's hot loops shrink by ~300×.
//!
//! [`RankClasses`] is the partition; [`HaloPattern`] is a uniform-payload
//! halo phase pre-compiled against it.  `fem::grid::Decomp::rank_classes`
//! builds the partition; `Comm::exchange_uniform` consumes the pattern,
//! falling back transparently to the per-rank message list whenever the
//! clocks are not in a state the batched update is exact for.

/// A partition of `0..ranks` into equivalence classes with contiguous
/// ids `0..len()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RankClasses {
    class_of: Vec<u32>,
    counts: Vec<u32>,
    /// Lowest-numbered member of each class.
    reps: Vec<usize>,
}

impl RankClasses {
    /// Build from a `rank -> class id` map. Ids must be dense: every id
    /// in `0..max+1` occurs (guaranteed by hash-consing construction).
    pub fn new(class_of: Vec<u32>) -> Self {
        let n_classes = class_of.iter().copied().max().map_or(0, |m| m as usize + 1);
        let mut counts = vec![0u32; n_classes];
        let mut reps = vec![usize::MAX; n_classes];
        for (rank, &c) in class_of.iter().enumerate() {
            let c = c as usize;
            counts[c] += 1;
            if reps[c] == usize::MAX {
                reps[c] = rank;
            }
        }
        assert!(
            counts.iter().all(|&c| c > 0),
            "class ids must be dense (every class non-empty)"
        );
        RankClasses {
            class_of,
            counts,
            reps,
        }
    }

    /// One class per rank (the degenerate partition; batching degrades
    /// gracefully to per-rank behaviour).
    pub fn identity(ranks: usize) -> Self {
        Self::new((0..ranks as u32).collect())
    }

    /// Number of classes.
    pub fn len(&self) -> usize {
        self.counts.len()
    }

    /// Whether the partition holds no classes.
    pub fn is_empty(&self) -> bool {
        self.counts.is_empty()
    }

    /// Number of ranks partitioned.
    pub fn ranks(&self) -> usize {
        self.class_of.len()
    }

    /// Class id of `rank`.
    pub fn class_of(&self, rank: usize) -> u32 {
        self.class_of[rank]
    }

    /// Member count of class `c`.
    pub fn count(&self, c: usize) -> u32 {
        self.counts[c]
    }

    /// Lowest-numbered member of class `c` (the representative rank).
    pub fn representative(&self, c: usize) -> usize {
        self.reps[c]
    }

    /// The full `rank -> class` map.
    pub fn map(&self) -> &[u32] {
        &self.class_of
    }
}

/// A uniform-payload halo phase pre-compiled against a [`RankClasses`]
/// partition.
///
/// For every class it records the incoming messages a member receives:
/// `(same_node, sender_node_offnode_msgs)` per shared face. Because the
/// halo graph is symmetric (every shared face carries a message each
/// way), a class's incoming edges are also its outgoing ones, which is
/// all the batched update needs. `messages` keeps the flat per-rank list
/// for the transparent fallback (and for stats parity with it).
#[derive(Debug, Clone)]
pub struct HaloPattern {
    /// Payload per face message.
    pub bytes: u64,
    /// Per class: one entry per shared face of a member rank —
    /// `(neighbour on same node?, off-node message count of the
    /// neighbour's node)`. The latter sizes the sender-side NIC
    /// serialisation term exactly as the per-rank path computes it.
    pub class_edges: Vec<Vec<(bool, u32)>>,
    /// The flat `(src, dst, bytes)` list the per-rank path consumes.
    pub messages: Vec<(usize, usize, u64)>,
}

impl HaloPattern {
    /// Total bytes moved by the phase.
    pub fn total_bytes(&self) -> u64 {
        self.messages.len() as u64 * self.bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_ids_round_trip() {
        let c = RankClasses::new(vec![0, 1, 0, 2, 1]);
        assert_eq!(c.len(), 3);
        assert_eq!(c.ranks(), 5);
        assert_eq!(c.count(0), 2);
        assert_eq!(c.count(1), 2);
        assert_eq!(c.count(2), 1);
        assert_eq!(c.representative(0), 0);
        assert_eq!(c.representative(1), 1);
        assert_eq!(c.representative(2), 3);
        assert_eq!(c.class_of(3), 2);
    }

    #[test]
    fn identity_partition() {
        let c = RankClasses::identity(4);
        assert_eq!(c.len(), 4);
        assert!((0..4).all(|r| c.class_of(r) == r as u32 && c.representative(r) == r));
    }

    #[test]
    #[should_panic]
    fn sparse_ids_rejected() {
        RankClasses::new(vec![0, 2]); // id 1 missing
    }
}
