//! Concrete fabric models: shared memory, Cray Aries, TCP/GbE.
//!
//! Parameters are taken from published microbenchmarks of the modelled
//! hardware (Edison's Aries: ~1.3 us / ~8 GB/s per NIC; MPICH over the
//! XC30 management GbE: ~50 us / ~110 MB/s; intra-node shared memory:
//! ~0.4 us / ~5 GB/s).  Absolute values matter less than the ratios —
//! DESIGN.md §3 explains how they flow into the figure shapes.


use super::PathCost;
use crate::des::Duration;

/// Which transport a communicator was resolved to (see `mpi::AbiResolver`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FabricKind {
    /// Intra-node shared-memory transport (all MPIs use this on-node).
    SharedMem,
    /// Cray Aries via the host (system) MPI library.
    Aries,
    /// The container's stock MPICH falling back to TCP over Ethernet.
    TcpEthernet,
}

/// A fabric: per-path costs for on-node and off-node communication, plus
/// a NIC serialisation bandwidth for modelling contention when many ranks
/// on one node talk off-node at once.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fabric {
    /// Which transport this fabric models.
    pub kind: FabricKind,
    /// Cost of a path between two ranks on the same node.
    pub intra_node: PathCost,
    /// Cost of a path between ranks on different nodes.
    pub inter_node: PathCost,
    /// Per-node NIC injection bandwidth (bytes/s). All off-node bytes a
    /// node sends in one communication phase serialise through this.
    pub nic_bytes_per_sec: f64,
}

impl Fabric {
    /// Intra-node shared-memory fabric (single workstation, or the
    /// on-node part of any MPI).
    pub fn shared_mem() -> Self {
        Fabric {
            kind: FabricKind::SharedMem,
            intra_node: PathCost {
                alpha: Duration::from_nanos(400),
                beta_bytes_per_sec: 5.0e9,
            },
            // A pure shared-memory fabric has no off-node path; model it
            // as same-cost so single-node jobs never pay a penalty.
            inter_node: PathCost {
                alpha: Duration::from_nanos(400),
                beta_bytes_per_sec: 5.0e9,
            },
            nic_bytes_per_sec: f64::INFINITY,
        }
    }

    /// Cray Aries (Edison) through the system MPI library.
    pub fn aries() -> Self {
        Fabric {
            kind: FabricKind::Aries,
            intra_node: PathCost {
                alpha: Duration::from_nanos(400),
                beta_bytes_per_sec: 5.0e9,
            },
            inter_node: PathCost {
                alpha: Duration::from_nanos(1300),
                beta_bytes_per_sec: 8.0e9,
            },
            nic_bytes_per_sec: 10.0e9,
        }
    }

    /// Container MPICH falling back to TCP over the management GbE.
    /// Latency is three orders of magnitude worse than Aries and the
    /// shared 1 Gb NIC saturates immediately — this is the mechanism
    /// behind Fig 3(c)'s blow-up past one node.
    pub fn tcp_ethernet() -> Self {
        Fabric {
            kind: FabricKind::TcpEthernet,
            intra_node: PathCost {
                // nemesis shared-memory still works inside a node
                alpha: Duration::from_nanos(600),
                beta_bytes_per_sec: 4.0e9,
            },
            inter_node: PathCost {
                alpha: Duration::from_micros(50),
                beta_bytes_per_sec: 110.0e6,
            },
            nic_bytes_per_sec: 117.0e6,
        }
    }

    /// The canonical fabric parameters for `kind`.
    pub fn by_kind(kind: FabricKind) -> Self {
        match kind {
            FabricKind::SharedMem => Self::shared_mem(),
            FabricKind::Aries => Self::aries(),
            FabricKind::TcpEthernet => Self::tcp_ethernet(),
        }
    }

    /// Point-to-point transfer time for `bytes` between two ranks.
    pub fn p2p(&self, bytes: u64, same_node: bool) -> Duration {
        if same_node {
            self.intra_node.transfer(bytes)
        } else {
            self.inter_node.transfer(bytes)
        }
    }

    /// Extra serialisation delay when one node injects `total_bytes`
    /// off-node within a single communication phase.
    pub fn nic_serialisation(&self, total_bytes: u64) -> Duration {
        if self.nic_bytes_per_sec.is_infinite() {
            Duration::ZERO
        } else {
            Duration::from_secs_f64(total_bytes as f64 / self.nic_bytes_per_sec)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aries_beats_tcp_off_node() {
        let a = Fabric::aries();
        let t = Fabric::tcp_ethernet();
        for bytes in [0u64, 1 << 10, 1 << 20] {
            assert!(a.p2p(bytes, false) < t.p2p(bytes, false), "bytes={bytes}");
        }
    }

    #[test]
    fn intra_node_is_fabric_independent_cheap() {
        // on-node messaging must be comparable across fabrics (the paper:
        // single-node container MPI is fine)
        let a = Fabric::aries().p2p(1 << 16, true);
        let t = Fabric::tcp_ethernet().p2p(1 << 16, true);
        let ratio = t.as_secs_f64() / a.as_secs_f64();
        assert!(ratio < 2.0, "on-node TCP fallback should not blow up: {ratio}");
    }

    #[test]
    fn shared_mem_has_no_nic_penalty() {
        assert_eq!(
            Fabric::shared_mem().nic_serialisation(1 << 30),
            Duration::ZERO
        );
    }

    #[test]
    fn tcp_nic_saturates() {
        let t = Fabric::tcp_ethernet();
        // 117 MB through a ~117 MB/s NIC ~= 1 s
        let d = t.nic_serialisation(117_000_000);
        assert!((d.as_secs_f64() - 1.0).abs() < 0.01);
    }

    #[test]
    fn p2p_monotone_in_bytes() {
        let f = Fabric::aries();
        let mut last = Duration::ZERO;
        for bytes in [0u64, 1, 1 << 10, 1 << 20, 1 << 24] {
            let d = f.p2p(bytes, false);
            assert!(d >= last);
            last = d;
        }
    }

    #[test]
    fn by_kind_round_trips() {
        for k in [FabricKind::SharedMem, FabricKind::Aries, FabricKind::TcpEthernet] {
            assert_eq!(Fabric::by_kind(k).kind, k);
        }
    }
}
