//! Interconnect fabrics.
//!
//! Fig 3's central result — container MPI collapses across nodes while
//! host-MPI injection matches native — is entirely a fabric story: the
//! Cray MPI library drives the Aries interconnect, the container's stock
//! MPICH falls back to TCP over the management Ethernet.  We model each
//! fabric with the standard α-β (latency/bandwidth) cost model plus a
//! per-node NIC serialisation term for off-node traffic, which is what
//! produces the super-linear blow-up the paper observes at 96/192 ranks.

mod fabric;

pub use fabric::{Fabric, FabricKind};

use crate::des::Duration;

/// α-β parameters for one transport path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PathCost {
    /// One-way message latency.
    pub alpha: Duration,
    /// Bandwidth in bytes/second.
    pub beta_bytes_per_sec: f64,
}

impl PathCost {
    /// Time to move `bytes` point-to-point on this path.
    pub fn transfer(&self, bytes: u64) -> Duration {
        self.alpha + Duration::from_secs_f64(bytes as f64 / self.beta_bytes_per_sec)
    }

    /// One registry shard's WAN link, as a cluster sees it: the
    /// quay.io-class ~30 MB/s download bandwidth and ~120 ms per-request
    /// latency the flat [`Registry`] model used, now expressed as a path
    /// so sharded pulls contend per-shard instead of sharing one number
    /// (see `container::distribute`).
    ///
    /// [`Registry`]: crate::container::Registry
    pub fn registry_wan() -> Self {
        PathCost {
            alpha: Duration::from_millis(120),
            beta_bytes_per_sec: 30.0e6,
        }
    }
}

/// The natural lookahead bound for the container tiers' conservative
/// parallel DES ([`crate::des::pdes`]): no cross-domain effect — a
/// pull served by another domain's shard, a peer hand-off, a retried
/// chunk — can land sooner than one WAN registry round trip, so every
/// lookahead domain may safely advance [`PathCost::registry_wan`]'s
/// `alpha` (120 ms of virtual time) past the global minimum.  A larger
/// bound would admit more parallelism but claim causal independence
/// the WAN model does not guarantee; this is the conservative floor.
pub fn wan_lookahead() -> Duration {
    PathCost::registry_wan().alpha
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_is_alpha_plus_size_over_beta() {
        let p = PathCost {
            alpha: Duration::from_micros(10),
            beta_bytes_per_sec: 1e9,
        };
        let t = p.transfer(1_000_000); // 1 MB at 1 GB/s = 1 ms
        assert_eq!(t, Duration::from_micros(10) + Duration::from_millis(1));
    }

    #[test]
    fn registry_wan_matches_flat_registry_numbers() {
        let w = PathCost::registry_wan();
        // 30 MB at 30 MB/s + 120 ms request latency ≈ 1.12 s
        let t = w.transfer(30_000_000);
        assert!((t.as_secs_f64() - 1.12).abs() < 0.01);
    }

    #[test]
    fn wan_lookahead_is_the_registry_latency() {
        assert_eq!(wan_lookahead(), Duration::from_millis(120));
        assert_eq!(wan_lookahead(), PathCost::registry_wan().alpha);
    }

    #[test]
    fn zero_bytes_costs_latency_only() {
        let p = PathCost {
            alpha: Duration::from_micros(3),
            beta_bytes_per_sec: 1e9,
        };
        assert_eq!(p.transfer(0), Duration::from_micros(3));
    }
}
