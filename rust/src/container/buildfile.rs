//! Buildfile (Dockerfile-DSL) parser, multi-stage aware.
//!
//! Supports the directives the paper's own Dockerfiles use (§2.2, §3.4):
//! `FROM`, `RUN`, `ENV`, `USER`, `WORKDIR`, `COPY`, `ENTRYPOINT`,
//! `LABEL`, plus `ARCH_OPT` — our explicit spelling of the paper's
//! "provision the container with scripts to build performance-critical
//! binaries on the host" recommendation (§4.3): images built with
//! `ARCH_OPT` use host-architecture instruction sets (AVX) and do not
//! pay the Fig 5a penalty.
//!
//! Multi-stage builds (§4.3's per-platform rebuild guidance at CI
//! scale) follow Docker's rules:
//!
//! * `FROM <base> AS <stage>` opens a new build stage; `<base>` is a
//!   catalogue reference or the *name of an earlier stage* (the stage
//!   then continues that stage's layer chain);
//! * `COPY --from=<stage> <src> <dst>` copies out of an earlier stage,
//!   referenced by `AS` name or by decimal index;
//! * the **last** stage is the build target — layers of earlier stages
//!   exist only in the layer store (they are the build cache) and are
//!   pruned from the final image.
//!
//! Stage references can only point backwards, so the stage-dependency
//! graph a [`Buildfile`] parses into is acyclic by construction; the
//! planner over it lives in [`super::builder::BuildGraph`].
//!
//! Syntax: one directive per line, `\` continuations, `#` comments.

/// A parsed build directive.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Directive {
    /// Open a build stage from a base reference (`FROM <base>
    /// [AS <stage>]`).  `base` may name an earlier stage.
    From {
        /// Catalogue reference, or the `AS` name of an earlier stage.
        base: String,
        /// Stage alias introduced with `AS` (anonymous stages have
        /// none and are referenced by decimal index).
        stage: Option<String>,
    },
    /// Shell command whose filesystem effect becomes a layer.
    Run(String),
    /// Environment variable for the image config (no layer).
    Env {
        /// Variable name.
        key: String,
        /// Variable value.
        value: String,
    },
    /// User subsequent directives (and the entrypoint) run as.
    User(String),
    /// Working directory for the entrypoint.
    Workdir(String),
    /// Copy files into the image — from the host build context, or
    /// from an earlier stage (`COPY --from=<stage>`).
    Copy {
        /// Source stage (`--from=`): an earlier stage's `AS` name or
        /// decimal index; `None` copies from the host build context.
        from: Option<String>,
        /// Source path (host-side, or inside the source stage).
        src: String,
        /// Destination path inside the image.
        dst: String,
    },
    /// Command the container runs by default.
    Entrypoint(String),
    /// Image metadata label (no layer).
    Label {
        /// Label name.
        key: String,
        /// Label value.
        value: String,
    },
    /// Build performance-critical binaries for the host architecture.
    ArchOpt,
}

impl Directive {
    /// The canonical text form — a lossless round-trip of the parsed
    /// directive (`parse(canonical)` reproduces the directive).  Layer
    /// hashes commit to the builder's *cache-canonical* form instead,
    /// which strips stage aliases and substitutes `COPY --from` stage
    /// names with content digests (see `builder`).
    pub fn canonical(&self) -> String {
        match self {
            Directive::From { base, stage: None } => format!("FROM {base}"),
            Directive::From { base, stage: Some(s) } => format!("FROM {base} AS {s}"),
            Directive::Run(c) => format!("RUN {c}"),
            Directive::Env { key, value } => format!("ENV {key}={value}"),
            Directive::User(u) => format!("USER {u}"),
            Directive::Workdir(w) => format!("WORKDIR {w}"),
            Directive::Copy { from: None, src, dst } => format!("COPY {src} {dst}"),
            Directive::Copy { from: Some(f), src, dst } => {
                format!("COPY --from={f} {src} {dst}")
            }
            Directive::Entrypoint(e) => format!("ENTRYPOINT {e}"),
            Directive::Label { key, value } => format!("LABEL {key}={value}"),
            Directive::ArchOpt => "ARCH_OPT".to_string(),
        }
    }
}

/// One `FROM …` section of a buildfile — a borrowed view produced by
/// [`Buildfile::stages`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Stage<'a> {
    /// Position in file order (also the stage's decimal `--from=N`
    /// reference).
    pub index: usize,
    /// The `AS` alias, if the stage was named.
    pub name: Option<&'a str>,
    /// The `FROM` reference the stage starts from (catalogue image or
    /// an earlier stage's name).
    pub base: &'a str,
    /// The stage's directives, its `FROM` first.
    pub directives: &'a [Directive],
}

/// A parsed buildfile.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Buildfile {
    /// Parsed directives, in file order.
    pub directives: Vec<Directive>,
}

/// Parse failure with line context.
#[derive(Debug)]
pub struct ParseError {
    /// 1-based line of the offending directive.
    pub line: usize,
    /// What was wrong with it.
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "buildfile line {}: {}", self.line, self.message)
    }
}
impl std::error::Error for ParseError {}

impl Buildfile {
    /// Parse buildfile text.
    pub fn parse(text: &str) -> Result<Buildfile, ParseError> {
        // 1. splice continuations, track original line numbers
        let mut logical: Vec<(usize, String)> = Vec::new();
        let mut pending: Option<(usize, String)> = None;
        for (i, raw) in text.lines().enumerate() {
            let line_no = i + 1;
            let line = raw.trim_end();
            let stripped = line.trim_start();
            if pending.is_none() && (stripped.is_empty() || stripped.starts_with('#')) {
                continue;
            }
            let (start, mut acc) = pending.take().unwrap_or((line_no, String::new()));
            let (frag, cont) = match line.strip_suffix('\\') {
                Some(f) => (f, true),
                None => (line, false),
            };
            if !acc.is_empty() {
                acc.push(' ');
            }
            acc.push_str(frag.trim());
            if cont {
                pending = Some((start, acc));
            } else {
                logical.push((start, acc));
            }
        }
        if let Some((start, _)) = pending {
            return Err(ParseError {
                line: start,
                message: "dangling line continuation".into(),
            });
        }

        // 2. parse directives, validating stage structure as we go:
        // stage names must be unique and stage references (`FROM
        // <earlier stage>`, `COPY --from=`) may only point backwards —
        // which is what makes the stage graph acyclic by construction
        let mut directives = Vec::new();
        let mut stage_names: Vec<Option<String>> = Vec::new();
        for (line, text) in logical {
            let (word, rest) = match text.split_once(char::is_whitespace) {
                Some((w, r)) => (w, r.trim()),
                None => (text.as_str(), ""),
            };
            let need = |what: &str| -> Result<(), ParseError> {
                if rest.is_empty() {
                    Err(ParseError {
                        line,
                        message: format!("{word} requires {what}"),
                    })
                } else {
                    Ok(())
                }
            };
            let kv = |what: &str| -> Result<(String, String), ParseError> {
                rest.split_once('=')
                    .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
                    .ok_or_else(|| ParseError {
                        line,
                        message: format!("{word} requires {what} as KEY=VALUE"),
                    })
            };
            let d = match word.to_ascii_uppercase().as_str() {
                "FROM" => {
                    need("a base reference")?;
                    let toks: Vec<&str> = rest.split_whitespace().collect();
                    let (base, stage) = match toks.as_slice() {
                        [base] => (base.to_string(), None),
                        [base, kw, name] if kw.eq_ignore_ascii_case("as") => {
                            (base.to_string(), Some(name.to_string()))
                        }
                        _ => {
                            return Err(ParseError {
                                line,
                                message: "FROM takes `<base>` or `<base> AS <stage>`".into(),
                            })
                        }
                    };
                    if let Some(name) = &stage {
                        let dup = stage_names.iter().any(|n| n.as_deref() == Some(name.as_str()));
                        if dup {
                            return Err(ParseError {
                                line,
                                message: format!("duplicate stage name `{name}`"),
                            });
                        }
                        if name.parse::<usize>().is_ok() {
                            return Err(ParseError {
                                line,
                                message: format!(
                                    "stage name `{name}` is numeric (reserved for \
                                     `--from=<index>` references)"
                                ),
                            });
                        }
                    }
                    stage_names.push(stage.clone());
                    Directive::From { base, stage }
                }
                "RUN" => {
                    need("a command")?;
                    Directive::Run(rest.to_string())
                }
                "ENV" => {
                    let (key, value) = kv("an assignment")?;
                    Directive::Env { key, value }
                }
                "USER" => {
                    need("a user name")?;
                    Directive::User(rest.to_string())
                }
                "WORKDIR" => {
                    need("a path")?;
                    Directive::Workdir(rest.to_string())
                }
                "COPY" => {
                    need("source and destination")?;
                    let (from, paths) = match rest.strip_prefix("--from=") {
                        Some(tail) => {
                            let (stage, tail) =
                                tail.split_once(char::is_whitespace).ok_or(ParseError {
                                    line,
                                    message: "COPY --from=<stage> requires source and destination"
                                        .into(),
                                })?;
                            if stage.is_empty() {
                                return Err(ParseError {
                                    line,
                                    message: "COPY --from= requires a stage name or index".into(),
                                });
                            }
                            (Some(stage.to_string()), tail.trim())
                        }
                        None => (None, rest),
                    };
                    let (src, dst) = paths.split_once(char::is_whitespace).ok_or(ParseError {
                        line,
                        message: "COPY requires source and destination".into(),
                    })?;
                    if let Some(stage) = &from {
                        // the current stage is stage_names.len() - 1;
                        // --from must resolve strictly before it
                        let current = stage_names.len().saturating_sub(1);
                        let earlier: Vec<Option<&str>> =
                            stage_names[..current].iter().map(|n| n.as_deref()).collect();
                        if resolve_among(&earlier, stage).is_none() {
                            return Err(ParseError {
                                line,
                                message: format!(
                                    "COPY --from=`{stage}` does not name an earlier stage"
                                ),
                            });
                        }
                    }
                    Directive::Copy {
                        from,
                        src: src.trim().to_string(),
                        dst: dst.trim().to_string(),
                    }
                }
                "ENTRYPOINT" => {
                    need("a command")?;
                    Directive::Entrypoint(rest.to_string())
                }
                "LABEL" => {
                    let (key, value) = kv("a label")?;
                    Directive::Label { key, value }
                }
                "ARCH_OPT" => Directive::ArchOpt,
                other => {
                    return Err(ParseError {
                        line,
                        message: format!("unknown directive `{other}`"),
                    })
                }
            };
            directives.push(d);
        }

        // 3. structural checks
        match directives.first() {
            Some(Directive::From { .. }) => {}
            _ => {
                return Err(ParseError {
                    line: 1,
                    message: "buildfile must start with FROM".into(),
                })
            }
        }
        Ok(Buildfile { directives })
    }

    /// The canonical text form: every directive's
    /// [`canonical`](Directive::canonical) spelling, one per line, with
    /// a trailing newline.  A lossless round-trip
    /// (`parse(canonical()) == self`), and a fixed point for text that
    /// is already canonical — which the resolver's emitted buildfiles
    /// are, so goldens diff byte-for-byte (`tests/resolver.rs`).
    pub fn canonical(&self) -> String {
        let mut out = String::new();
        for d in &self.directives {
            out.push_str(&d.canonical());
            out.push('\n');
        }
        out
    }

    /// The base reference of the first `FROM`.
    pub fn base(&self) -> &str {
        match &self.directives[0] {
            Directive::From { base, .. } => base,
            _ => unreachable!("parse() guarantees FROM first"),
        }
    }

    /// The buildfile's stages, in file order.  Single-stage files
    /// return exactly one entry covering every directive.
    pub fn stages(&self) -> Vec<Stage<'_>> {
        let mut bounds: Vec<usize> = self
            .directives
            .iter()
            .enumerate()
            .filter(|(_, d)| matches!(d, Directive::From { .. }))
            .map(|(i, _)| i)
            .collect();
        bounds.push(self.directives.len());
        bounds
            .windows(2)
            .enumerate()
            .map(|(index, w)| {
                let directives = &self.directives[w[0]..w[1]];
                let (base, name) = match &directives[0] {
                    Directive::From { base, stage } => (base.as_str(), stage.as_deref()),
                    _ => unreachable!("stage bounds start at FROM"),
                };
                Stage {
                    index,
                    name,
                    base,
                    directives,
                }
            })
            .collect()
    }

    /// Number of stages (`FROM` directives).
    pub fn stage_count(&self) -> usize {
        self.directives
            .iter()
            .filter(|d| matches!(d, Directive::From { .. }))
            .count()
    }

    /// The `AS` names of all stages, in stage order (`None` for
    /// anonymous stages) — the vector [`resolve_stage`] resolves
    /// against.  The builder and planner derive the same vector from
    /// the [`stages`] list they already hold and pass slices of it to
    /// the crate-internal `resolve_among`, so resolution rules live in
    /// exactly one place.
    ///
    /// [`resolve_stage`]: Self::resolve_stage
    /// [`stages`]: Self::stages
    pub fn stage_names(&self) -> Vec<Option<&str>> {
        self.directives
            .iter()
            .filter_map(|d| match d {
                Directive::From { stage, .. } => Some(stage.as_deref()),
                _ => None,
            })
            .collect()
    }

    /// Resolve a stage reference (an `AS` name or decimal index) among
    /// the stages *strictly before* `before`.  This is the rule both
    /// `COPY --from=` and stage-base `FROM`s obey, so references can
    /// only point backwards.
    pub fn resolve_stage(&self, reference: &str, before: usize) -> Option<usize> {
        let names = self.stage_names();
        resolve_among(&names[..before.min(names.len())], reference)
    }
}

/// Resolve `reference` (an `AS` name, else a decimal index) against the
/// given earlier-stage names (`None` = anonymous).
pub(crate) fn resolve_among(earlier: &[Option<&str>], reference: &str) -> Option<usize> {
    if let Some(i) = earlier.iter().position(|n| *n == Some(reference)) {
        return Some(i);
    }
    match reference.parse::<usize>() {
        Ok(i) if i < earlier.len() => Some(i),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PAPER_EXAMPLE: &str = r#"
# The paper's §2.2 example
FROM ubuntu:16.04
USER root
RUN apt-get -y update && \
 apt-get -y upgrade && \
 apt-get -y install python-scipy && \
 rm -rf /var/lib/apt/lists/* /tmp/* /var/tmp/*
"#;

    #[test]
    fn parses_the_papers_example() {
        let bf = Buildfile::parse(PAPER_EXAMPLE).unwrap();
        assert_eq!(bf.base(), "ubuntu:16.04");
        assert_eq!(bf.directives.len(), 3);
        assert_eq!(bf.stage_count(), 1);
        match &bf.directives[2] {
            Directive::Run(cmd) => {
                assert!(cmd.contains("apt-get -y update"));
                assert!(cmd.contains("python-scipy"));
                assert!(!cmd.contains('\\'));
            }
            other => panic!("expected RUN, got {other:?}"),
        }
    }

    #[test]
    fn env_label_parsing() {
        let bf = Buildfile::parse("FROM a:b\nENV FOO=bar baz\nLABEL org.x=1").unwrap();
        assert_eq!(
            bf.directives[1],
            Directive::Env {
                key: "FOO".into(),
                value: "bar baz".into()
            }
        );
        assert_eq!(
            bf.directives[2],
            Directive::Label {
                key: "org.x".into(),
                value: "1".into()
            }
        );
    }

    #[test]
    fn copy_and_arch_opt() {
        let bf = Buildfile::parse("FROM a:b\nCOPY ./src /app\nARCH_OPT").unwrap();
        assert_eq!(
            bf.directives[1],
            Directive::Copy {
                from: None,
                src: "./src".into(),
                dst: "/app".into()
            }
        );
        assert_eq!(bf.directives[2], Directive::ArchOpt);
    }

    #[test]
    fn canonical_is_a_lossless_round_trip_and_fixed_point() {
        let text = "FROM ubuntu:16.04 AS build\nRUN make -j app\nENV A=1\n\
                    FROM ubuntu:16.04\nCOPY --from=build /out /app\nARCH_OPT\nENTRYPOINT /app\n";
        let bf = Buildfile::parse(text).unwrap();
        let canon = bf.canonical();
        assert_eq!(Buildfile::parse(&canon).unwrap(), bf);
        // `text` is already in canonical spelling, so canonical() is a
        // byte-level fixed point on it
        assert_eq!(canon, text);
        // messy spacing/continuations normalise to the same canonical
        let messy = "FROM   ubuntu:16.04   AS build\nRUN make \\\n    -j app\nENV A=1\n\
                     FROM ubuntu:16.04\nCOPY --from=build   /out   /app\nARCH_OPT\nENTRYPOINT /app\n";
        assert_eq!(Buildfile::parse(messy).unwrap().canonical(), canon);
    }

    #[test]
    fn must_start_with_from() {
        let err = Buildfile::parse("RUN echo hi").unwrap_err();
        assert!(err.message.contains("must start with FROM"));
    }

    #[test]
    fn parses_multistage_with_named_stages() {
        let text = "FROM a:1 AS build\nRUN make\nFROM b:2\nCOPY --from=build /out /app";
        let bf = Buildfile::parse(text).unwrap();
        let stages = bf.stages();
        assert_eq!(stages.len(), 2);
        assert_eq!(stages[0].name, Some("build"));
        assert_eq!(stages[0].base, "a:1");
        assert_eq!(stages[0].directives.len(), 2);
        assert_eq!(stages[1].name, None);
        assert_eq!(stages[1].base, "b:2");
        assert_eq!(
            bf.directives[3],
            Directive::Copy {
                from: Some("build".into()),
                src: "/out".into(),
                dst: "/app".into()
            }
        );
    }

    #[test]
    fn stage_base_may_name_an_earlier_stage() {
        let bf = Buildfile::parse("FROM a:1 AS base\nFROM base AS derived\nRUN x").unwrap();
        let stages = bf.stages();
        assert_eq!(stages[1].base, "base");
        assert_eq!(bf.resolve_stage("base", 1), Some(0));
        // a stage cannot resolve itself or later stages
        assert_eq!(bf.resolve_stage("derived", 1), None);
        assert_eq!(bf.resolve_stage("derived", 2), Some(1));
    }

    #[test]
    fn copy_from_resolves_by_index_too() {
        let text = "FROM a:1\nRUN make\nFROM b:2\nCOPY --from=0 /out /app";
        let bf = Buildfile::parse(text).unwrap();
        assert_eq!(bf.resolve_stage("0", 1), Some(0));
        assert_eq!(bf.resolve_stage("1", 1), None);
    }

    #[test]
    fn rejects_forward_and_unknown_copy_from() {
        let err = Buildfile::parse("FROM a:1\nCOPY --from=ghost /x /y").unwrap_err();
        assert!(err.message.contains("earlier stage"), "{}", err.message);
        // self-reference is a forward reference
        let err = Buildfile::parse("FROM a:1 AS me\nCOPY --from=me /x /y").unwrap_err();
        assert!(err.message.contains("earlier stage"));
        // numeric self/forward index
        let err = Buildfile::parse("FROM a:1\nCOPY --from=0 /x /y").unwrap_err();
        assert!(err.message.contains("earlier stage"));
    }

    #[test]
    fn rejects_duplicate_and_numeric_stage_names() {
        let err = Buildfile::parse("FROM a:1 AS s\nFROM b:2 AS s").unwrap_err();
        assert!(err.message.contains("duplicate stage name"));
        assert_eq!(err.line, 2);
        let err = Buildfile::parse("FROM a:1 AS 3").unwrap_err();
        assert!(err.message.contains("numeric"));
    }

    #[test]
    fn rejects_malformed_from_and_copy_from() {
        let err = Buildfile::parse("FROM a:1 AS").unwrap_err();
        assert!(err.message.contains("FROM takes"));
        let err = Buildfile::parse("FROM a:1 AS x y").unwrap_err();
        assert!(err.message.contains("FROM takes"));
        let err = Buildfile::parse("FROM a:1\nFROM b:2\nCOPY --from= /x /y").unwrap_err();
        assert!(err.message.contains("requires a stage"));
        let err = Buildfile::parse("FROM a:1\nFROM b:2\nCOPY --from=0 /only").unwrap_err();
        assert!(err.message.contains("source and destination"));
    }

    #[test]
    fn rejects_unknown_directive() {
        let err = Buildfile::parse("FROM a:1\nVOLUME /data").unwrap_err();
        assert!(err.message.contains("unknown directive"));
        assert_eq!(err.line, 2);
    }

    #[test]
    fn rejects_dangling_continuation() {
        let err = Buildfile::parse("FROM a:1\nRUN x \\").unwrap_err();
        assert!(err.message.contains("dangling"));
    }

    #[test]
    fn rejects_empty_run() {
        let err = Buildfile::parse("FROM a:1\nRUN").unwrap_err();
        assert!(err.message.contains("requires"));
    }

    #[test]
    fn canonical_round_trip() {
        let text = "FROM u:1 AS build\nENV A=b\nRUN make -j\nFROM u:1\n\
                    COPY --from=build /out /app\nCOPY ./src /app/src";
        let bf = Buildfile::parse(text).unwrap();
        let canon: Vec<_> = bf.directives.iter().map(|d| d.canonical()).collect();
        assert_eq!(
            canon,
            vec![
                "FROM u:1 AS build",
                "ENV A=b",
                "RUN make -j",
                "FROM u:1",
                "COPY --from=build /out /app",
                "COPY ./src /app/src",
            ]
        );
        // canonical() is lossless: reparsing reproduces the directives
        let back = Buildfile::parse(&canon.join("\n")).unwrap();
        assert_eq!(back, bf);
    }

    #[test]
    fn case_insensitive_directives() {
        let bf = Buildfile::parse("from u:1\nrun echo").unwrap();
        assert_eq!(bf.directives.len(), 2);
        let bf = Buildfile::parse("FROM u:1 as build\nRUN echo").unwrap();
        assert_eq!(bf.stages()[0].name, Some("build"));
    }
}
