//! Buildfile (Dockerfile-DSL) parser.
//!
//! Supports the directives the paper's own Dockerfiles use (§2.2, §3.4):
//! `FROM`, `RUN`, `ENV`, `USER`, `WORKDIR`, `COPY`, `ENTRYPOINT`,
//! `LABEL`, plus `ARCH_OPT` — our explicit spelling of the paper's
//! "provision the container with scripts to build performance-critical
//! binaries on the host" recommendation (§4.3): images built with
//! `ARCH_OPT` use host-architecture instruction sets (AVX) and do not
//! pay the Fig 5a penalty.
//!
//! Syntax: one directive per line, `\` continuations, `#` comments.

/// A parsed build directive.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Directive {
    /// Base image to start from.
    From(String),
    /// Shell command whose filesystem effect becomes a layer.
    Run(String),
    /// Environment variable for the image config (no layer).
    Env {
        /// Variable name.
        key: String,
        /// Variable value.
        value: String,
    },
    /// User subsequent directives (and the entrypoint) run as.
    User(String),
    /// Working directory for the entrypoint.
    Workdir(String),
    /// Copy project files into the image.
    Copy {
        /// Host-side source path.
        src: String,
        /// Destination path inside the image.
        dst: String,
    },
    /// Command the container runs by default.
    Entrypoint(String),
    /// Image metadata label (no layer).
    Label {
        /// Label name.
        key: String,
        /// Label value.
        value: String,
    },
    /// Build performance-critical binaries for the host architecture.
    ArchOpt,
}

impl Directive {
    /// The canonical text form (what layer hashes commit to).
    pub fn canonical(&self) -> String {
        match self {
            Directive::From(b) => format!("FROM {b}"),
            Directive::Run(c) => format!("RUN {c}"),
            Directive::Env { key, value } => format!("ENV {key}={value}"),
            Directive::User(u) => format!("USER {u}"),
            Directive::Workdir(w) => format!("WORKDIR {w}"),
            Directive::Copy { src, dst } => format!("COPY {src} {dst}"),
            Directive::Entrypoint(e) => format!("ENTRYPOINT {e}"),
            Directive::Label { key, value } => format!("LABEL {key}={value}"),
            Directive::ArchOpt => "ARCH_OPT".to_string(),
        }
    }
}

/// A parsed buildfile.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Buildfile {
    /// Parsed directives, in file order.
    pub directives: Vec<Directive>,
}

/// Parse failure with line context.
#[derive(Debug)]
pub struct ParseError {
    /// 1-based line of the offending directive.
    pub line: usize,
    /// What was wrong with it.
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "buildfile line {}: {}", self.line, self.message)
    }
}
impl std::error::Error for ParseError {}

impl Buildfile {
    /// Parse buildfile text.
    pub fn parse(text: &str) -> Result<Buildfile, ParseError> {
        // 1. splice continuations, track original line numbers
        let mut logical: Vec<(usize, String)> = Vec::new();
        let mut pending: Option<(usize, String)> = None;
        for (i, raw) in text.lines().enumerate() {
            let line_no = i + 1;
            let line = raw.trim_end();
            let stripped = line.trim_start();
            if pending.is_none() && (stripped.is_empty() || stripped.starts_with('#')) {
                continue;
            }
            let (start, mut acc) = pending.take().unwrap_or((line_no, String::new()));
            let (frag, cont) = match line.strip_suffix('\\') {
                Some(f) => (f, true),
                None => (line, false),
            };
            if !acc.is_empty() {
                acc.push(' ');
            }
            acc.push_str(frag.trim());
            if cont {
                pending = Some((start, acc));
            } else {
                logical.push((start, acc));
            }
        }
        if let Some((start, _)) = pending {
            return Err(ParseError {
                line: start,
                message: "dangling line continuation".into(),
            });
        }

        // 2. parse directives
        let mut directives = Vec::new();
        for (line, text) in logical {
            let (word, rest) = match text.split_once(char::is_whitespace) {
                Some((w, r)) => (w, r.trim()),
                None => (text.as_str(), ""),
            };
            let need = |what: &str| -> Result<(), ParseError> {
                if rest.is_empty() {
                    Err(ParseError {
                        line,
                        message: format!("{word} requires {what}"),
                    })
                } else {
                    Ok(())
                }
            };
            let kv = |what: &str| -> Result<(String, String), ParseError> {
                rest.split_once('=')
                    .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
                    .ok_or_else(|| ParseError {
                        line,
                        message: format!("{word} requires {what} as KEY=VALUE"),
                    })
            };
            let d = match word.to_ascii_uppercase().as_str() {
                "FROM" => {
                    need("a base reference")?;
                    Directive::From(rest.to_string())
                }
                "RUN" => {
                    need("a command")?;
                    Directive::Run(rest.to_string())
                }
                "ENV" => {
                    let (key, value) = kv("an assignment")?;
                    Directive::Env { key, value }
                }
                "USER" => {
                    need("a user name")?;
                    Directive::User(rest.to_string())
                }
                "WORKDIR" => {
                    need("a path")?;
                    Directive::Workdir(rest.to_string())
                }
                "COPY" => {
                    need("source and destination")?;
                    let (src, dst) = rest.split_once(char::is_whitespace).ok_or(ParseError {
                        line,
                        message: "COPY requires source and destination".into(),
                    })?;
                    Directive::Copy {
                        src: src.trim().to_string(),
                        dst: dst.trim().to_string(),
                    }
                }
                "ENTRYPOINT" => {
                    need("a command")?;
                    Directive::Entrypoint(rest.to_string())
                }
                "LABEL" => {
                    let (key, value) = kv("a label")?;
                    Directive::Label { key, value }
                }
                "ARCH_OPT" => Directive::ArchOpt,
                other => {
                    return Err(ParseError {
                        line,
                        message: format!("unknown directive `{other}`"),
                    })
                }
            };
            directives.push(d);
        }

        // 3. structural checks
        match directives.first() {
            Some(Directive::From(_)) => {}
            _ => {
                return Err(ParseError {
                    line: 1,
                    message: "buildfile must start with FROM".into(),
                })
            }
        }
        if directives
            .iter()
            .skip(1)
            .any(|d| matches!(d, Directive::From(_)))
        {
            return Err(ParseError {
                line: 0,
                message: "multi-stage builds (second FROM) are not supported".into(),
            });
        }
        Ok(Buildfile { directives })
    }

    /// The base reference of the first FROM.
    pub fn base(&self) -> &str {
        match &self.directives[0] {
            Directive::From(b) => b,
            _ => unreachable!("parse() guarantees FROM first"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PAPER_EXAMPLE: &str = r#"
# The paper's §2.2 example
FROM ubuntu:16.04
USER root
RUN apt-get -y update && \
 apt-get -y upgrade && \
 apt-get -y install python-scipy && \
 rm -rf /var/lib/apt/lists/* /tmp/* /var/tmp/*
"#;

    #[test]
    fn parses_the_papers_example() {
        let bf = Buildfile::parse(PAPER_EXAMPLE).unwrap();
        assert_eq!(bf.base(), "ubuntu:16.04");
        assert_eq!(bf.directives.len(), 3);
        match &bf.directives[2] {
            Directive::Run(cmd) => {
                assert!(cmd.contains("apt-get -y update"));
                assert!(cmd.contains("python-scipy"));
                assert!(!cmd.contains('\\'));
            }
            other => panic!("expected RUN, got {other:?}"),
        }
    }

    #[test]
    fn env_label_parsing() {
        let bf = Buildfile::parse("FROM a:b\nENV FOO=bar baz\nLABEL org.x=1").unwrap();
        assert_eq!(
            bf.directives[1],
            Directive::Env {
                key: "FOO".into(),
                value: "bar baz".into()
            }
        );
        assert_eq!(
            bf.directives[2],
            Directive::Label {
                key: "org.x".into(),
                value: "1".into()
            }
        );
    }

    #[test]
    fn copy_and_arch_opt() {
        let bf = Buildfile::parse("FROM a:b\nCOPY ./src /app\nARCH_OPT").unwrap();
        assert_eq!(
            bf.directives[1],
            Directive::Copy {
                src: "./src".into(),
                dst: "/app".into()
            }
        );
        assert_eq!(bf.directives[2], Directive::ArchOpt);
    }

    #[test]
    fn must_start_with_from() {
        let err = Buildfile::parse("RUN echo hi").unwrap_err();
        assert!(err.message.contains("must start with FROM"));
    }

    #[test]
    fn rejects_multistage() {
        let err = Buildfile::parse("FROM a:1\nFROM b:2").unwrap_err();
        assert!(err.message.contains("multi-stage"));
    }

    #[test]
    fn rejects_unknown_directive() {
        let err = Buildfile::parse("FROM a:1\nVOLUME /data").unwrap_err();
        assert!(err.message.contains("unknown directive"));
        assert_eq!(err.line, 2);
    }

    #[test]
    fn rejects_dangling_continuation() {
        let err = Buildfile::parse("FROM a:1\nRUN x \\").unwrap_err();
        assert!(err.message.contains("dangling"));
    }

    #[test]
    fn rejects_empty_run() {
        let err = Buildfile::parse("FROM a:1\nRUN").unwrap_err();
        assert!(err.message.contains("requires"));
    }

    #[test]
    fn canonical_round_trip() {
        let bf = Buildfile::parse("FROM u:1\nENV A=b\nRUN make -j").unwrap();
        let canon: Vec<_> = bf.directives.iter().map(|d| d.canonical()).collect();
        assert_eq!(canon, vec!["FROM u:1", "ENV A=b", "RUN make -j"]);
    }

    #[test]
    fn case_insensitive_directives() {
        let bf = Buildfile::parse("from u:1\nrun echo").unwrap();
        assert_eq!(bf.directives.len(), 2);
    }
}
