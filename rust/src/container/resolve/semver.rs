//! Semantic versions and version ranges.
//!
//! The resolver's arithmetic layer: a [`Version`] is a `major.minor.patch`
//! triple with the usual lexicographic total order, and a [`Range`] is a
//! half-open interval `[lo, hi)` over that order.  Every range the
//! manifest syntax can express (`*`, `=`, `^`, `~`, `>=`, `>`, `<`,
//! `<=`, and comma-conjunctions) normalises into one interval, which
//! makes intersection — the only operation resolution needs — a
//! two-comparison `max(lo) / min(hi)`.
//!
//! There are no pre-release or build tags: versions are exactly triples,
//! so the successor of `1.2.3` in the order is `1.2.4`.  That is what
//! lets `>v` desugar to `>= v.bump_patch()` and `<=v` to
//! `< v.bump_patch()` without a separate bound-kind flag, and it is the
//! property the brute-force oracle in `tests/resolver.rs` checks over an
//! enumerated version universe.

use std::fmt;
use std::str::FromStr;

/// A `major.minor.patch` version triple, totally ordered
/// lexicographically (derived `Ord` on the field order).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Version {
    /// Incompatible-change counter.
    pub major: u64,
    /// Feature counter.
    pub minor: u64,
    /// Fix counter.
    pub patch: u64,
}

impl Version {
    /// Construct a version from its three components.
    pub fn new(major: u64, minor: u64, patch: u64) -> Self {
        Version { major, minor, patch }
    }

    /// The immediate successor in the total order (`1.2.3` → `1.2.4`).
    /// With no pre-release tags, `> v` is exactly `>= v.bump_patch()`.
    pub fn bump_patch(self) -> Self {
        Version::new(self.major, self.minor, self.patch + 1)
    }

    /// The first version of the next minor series (`1.2.3` → `1.3.0`);
    /// the exclusive upper bound a tilde range commits to.
    pub fn bump_minor(self) -> Self {
        Version::new(self.major, self.minor + 1, 0)
    }

    /// The first version of the next major series (`1.2.3` → `2.0.0`);
    /// the exclusive upper bound a caret range commits to.
    pub fn bump_major(self) -> Self {
        Version::new(self.major + 1, 0, 0)
    }
}

impl fmt::Display for Version {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}.{}.{}", self.major, self.minor, self.patch)
    }
}

/// A malformed version or range literal, with the offending text.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SemverError {
    /// The literal that failed to parse.
    pub text: String,
    /// What was wrong with it.
    pub message: String,
}

impl fmt::Display for SemverError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "bad version syntax `{}`: {}", self.text, self.message)
    }
}
impl std::error::Error for SemverError {}

fn err(text: &str, message: impl Into<String>) -> SemverError {
    SemverError {
        text: text.to_string(),
        message: message.into(),
    }
}

impl FromStr for Version {
    type Err = SemverError;

    fn from_str(s: &str) -> Result<Self, SemverError> {
        let mut parts = s.split('.');
        let mut component = |name: &str| -> Result<u64, SemverError> {
            let p = parts
                .next()
                .ok_or_else(|| err(s, format!("missing {name} component")))?;
            if p.is_empty() || !p.bytes().all(|b| b.is_ascii_digit()) {
                return Err(err(s, format!("{name} component `{p}` is not a number")));
            }
            p.parse()
                .map_err(|_| err(s, format!("{name} component `{p}` overflows")))
        };
        let v = Version::new(component("major")?, component("minor")?, component("patch")?);
        if parts.next().is_some() {
            return Err(err(s, "more than three components"));
        }
        Ok(v)
    }
}

/// A half-open version interval `[lo, hi)`; `hi = None` means unbounded
/// above.  This is the normal form every piece of range syntax reduces
/// to, so intersection and emptiness are interval arithmetic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Range {
    /// Inclusive lower bound.
    pub lo: Version,
    /// Exclusive upper bound (`None` = unbounded).
    pub hi: Option<Version>,
}

impl Range {
    /// The full range `*` — every version.
    pub fn any() -> Self {
        Range {
            lo: Version::new(0, 0, 0),
            hi: None,
        }
    }

    /// The single-version range `[v, v.bump_patch())`.
    pub fn exact(v: Version) -> Self {
        Range {
            lo: v,
            hi: Some(v.bump_patch()),
        }
    }

    /// The caret range of `v`: compatible within the leftmost non-zero
    /// component (`^1.2.3` = `[1.2.3, 2.0.0)`, `^0.2.3` = `[0.2.3,
    /// 0.3.0)`, `^0.0.3` = `[0.0.3, 0.0.4)`).
    pub fn caret(v: Version) -> Self {
        let hi = if v.major > 0 {
            v.bump_major()
        } else if v.minor > 0 {
            v.bump_minor()
        } else {
            v.bump_patch()
        };
        Range { lo: v, hi: Some(hi) }
    }

    /// The tilde range of `v`: patch-level flexibility (`~1.2.3` =
    /// `[1.2.3, 1.3.0)`).
    pub fn tilde(v: Version) -> Self {
        Range {
            lo: v,
            hi: Some(v.bump_minor()),
        }
    }

    /// Whether `v` lies in the interval.
    pub fn contains(&self, v: Version) -> bool {
        self.lo <= v && self.hi.map_or(true, |hi| v < hi)
    }

    /// The interval common to both ranges: `[max(lo), min(hi))`.  May
    /// be empty — check [`is_empty`](Range::is_empty).
    pub fn intersect(&self, other: &Range) -> Range {
        let lo = self.lo.max(other.lo);
        let hi = match (self.hi, other.hi) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (h, None) | (None, h) => h,
        };
        Range { lo, hi }
    }

    /// Whether the interval contains no version at all.
    pub fn is_empty(&self) -> bool {
        matches!(self.hi, Some(hi) if hi <= self.lo)
    }

    /// Parse range syntax: `*`, `1.2.3` / `=1.2.3`, `^1.2.3`, `~1.2.3`,
    /// `>=1.2.3`, `>1.2.3`, `<2.0.0`, `<=2.0.0`, and comma- or
    /// whitespace-separated conjunctions thereof (intersected).
    pub fn parse(s: &str) -> Result<Range, SemverError> {
        let text = s.trim();
        if text.is_empty() {
            return Err(err(s, "empty range"));
        }
        let mut range = Range::any();
        for clause in text.split(',').flat_map(|c| c.split_whitespace()) {
            range = range.intersect(&Self::parse_clause(clause)?);
        }
        Ok(range)
    }

    fn parse_clause(clause: &str) -> Result<Range, SemverError> {
        let version = |rest: &str| -> Result<Version, SemverError> { rest.parse() };
        Ok(match clause {
            "*" => Range::any(),
            _ if clause.starts_with(">=") => Range {
                lo: version(&clause[2..])?,
                hi: None,
            },
            _ if clause.starts_with("<=") => Range {
                lo: Version::new(0, 0, 0),
                hi: Some(version(&clause[2..])?.bump_patch()),
            },
            _ if clause.starts_with('>') => Range {
                lo: version(&clause[1..])?.bump_patch(),
                hi: None,
            },
            _ if clause.starts_with('<') => Range {
                lo: Version::new(0, 0, 0),
                hi: Some(version(&clause[1..])?),
            },
            _ if clause.starts_with('^') => Range::caret(version(&clause[1..])?),
            _ if clause.starts_with('~') => Range::tilde(version(&clause[1..])?),
            _ if clause.starts_with('=') => Range::exact(version(&clause[1..])?),
            _ => Range::exact(version(clause)?),
        })
    }
}

impl fmt::Display for Range {
    /// Canonical form: `*` for the full range, else `>=lo` /
    /// `>=lo, <hi`.  Idempotent under [`Range::parse`] — re-parsing the
    /// printed form reproduces the interval exactly.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.hi {
            None if self.lo == Version::new(0, 0, 0) => write!(f, "*"),
            None => write!(f, ">={}", self.lo),
            Some(hi) => write!(f, ">={}, <{}", self.lo, hi),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(ma: u64, mi: u64, pa: u64) -> Version {
        Version::new(ma, mi, pa)
    }

    #[test]
    fn version_parse_print_round_trip() {
        for text in ["0.0.0", "1.2.3", "2016.1.0", "10.20.30"] {
            let ver: Version = text.parse().unwrap();
            assert_eq!(ver.to_string(), text);
        }
    }

    #[test]
    fn version_parse_rejects_malformed() {
        for bad in ["", "1", "1.2", "1.2.3.4", "1.2.x", "a.b.c", "1..3", "-1.0.0"] {
            assert!(bad.parse::<Version>().is_err(), "`{bad}` parsed");
        }
    }

    #[test]
    fn order_is_lexicographic() {
        assert!(v(1, 0, 0) < v(1, 0, 1));
        assert!(v(1, 0, 9) < v(1, 1, 0));
        assert!(v(1, 9, 9) < v(2, 0, 0));
        assert_eq!(v(3, 7, 2), v(3, 7, 2));
    }

    #[test]
    fn caret_follows_leftmost_nonzero() {
        assert_eq!(Range::caret(v(1, 2, 3)).hi, Some(v(2, 0, 0)));
        assert_eq!(Range::caret(v(0, 2, 3)).hi, Some(v(0, 3, 0)));
        assert_eq!(Range::caret(v(0, 0, 3)).hi, Some(v(0, 0, 4)));
    }

    #[test]
    fn sugar_desugars_to_intervals() {
        assert_eq!(Range::parse("*").unwrap(), Range::any());
        assert_eq!(Range::parse("1.2.3").unwrap(), Range::exact(v(1, 2, 3)));
        assert_eq!(Range::parse("=1.2.3").unwrap(), Range::exact(v(1, 2, 3)));
        assert_eq!(Range::parse("~3.7.2").unwrap().hi, Some(v(3, 8, 0)));
        assert_eq!(Range::parse(">1.2.3").unwrap().lo, v(1, 2, 4));
        assert_eq!(Range::parse("<=1.2.3").unwrap().hi, Some(v(1, 2, 4)));
        assert_eq!(
            Range::parse(">=1.10.0, <2.0.0").unwrap(),
            Range {
                lo: v(1, 10, 0),
                hi: Some(v(2, 0, 0))
            }
        );
    }

    #[test]
    fn intersection_is_max_lo_min_hi() {
        let a = Range::parse("^3.7.0").unwrap();
        let b = Range::parse("~3.7.2").unwrap();
        let i = a.intersect(&b);
        assert_eq!(i.lo, v(3, 7, 2));
        assert_eq!(i.hi, Some(v(3, 8, 0)));
        assert!(!i.is_empty());
        let disjoint = Range::caret(v(1, 10, 2)).intersect(&Range::caret(v(2, 0, 0)));
        assert!(disjoint.is_empty());
    }

    #[test]
    fn display_round_trips_through_parse() {
        for text in ["*", "^1.2.3", "~3.7.2", ">=1.0.0", ">=1.10.0, <2.0.0", "=2016.1.0"] {
            let r = Range::parse(text).unwrap();
            assert_eq!(Range::parse(&r.to_string()).unwrap(), r, "via `{text}`");
        }
    }
}
