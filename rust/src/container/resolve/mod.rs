//! The package-resolver tier: semver ranges → lockfile → generated
//! multi-stage buildfiles.
//!
//! The paper assembles the FEniCS stack from dozens of versioned
//! packages (§2.2) but our buildfiles were hand-written, so the build
//! farm could only replay fixed stacks.  This module closes the gap:
//!
//! * [`semver`] — versions, total order, half-open ranges, intersection;
//! * [`manifest`] — root package declarations and the registry's
//!   [`PackageIndex`] of published `(package, version, deps)`;
//! * [`resolver`] — seeded, deterministic resolution to a pinned set
//!   with a topological build order (conflict/cycle errors carry
//!   context);
//! * [`lockfile`] — canonical byte-stable serialisation whose diff
//!   *predicts* the rebuild frontier;
//! * [`cache`] — a content-addressed package cache on [`LayerStore`]
//!   hashing.
//!
//! [`emit_stack_buildfile`] renders a lockfile as a multi-stage
//! buildfile the PR 5 DAG builder consumes unchanged: one stage per
//! package in topological order (`FROM <first-dep> AS pkg-<name>`,
//! `COPY --from=` the remaining dependency stages, `RUN pip install
//! name==version`), then a terminal stage that copies the root
//! dependencies out and optionally `ARCH_OPT`s an arch-specific build.
//! Because layer cache keys commit to the parent chain, the canonical
//! `RUN` text (which embeds the pinned version) and `COPY --from`
//! source digests, *the set of stages a version bump invalidates equals
//! the lockfile-diff frontier* — the equality the `version-churn`
//! scenario asserts per cell and `tests/build_graph.rs` sweeps across
//! the variant matrix.
//!
//! [`LayerStore`]: crate::container::store::LayerStore

pub mod cache;
pub mod lockfile;
pub mod manifest;
pub mod resolver;
pub mod semver;

pub use cache::PackageCache;
pub use lockfile::{LockDiff, Lockfile, LockedPackage};
pub use manifest::{Dependency, Manifest, PackageIndex};
pub use resolver::{resolve, Resolution, ResolveError};
pub use semver::{Range, SemverError, Version};

use std::collections::BTreeSet;

use anyhow::Result;

use crate::container::buildfile::Buildfile;
use crate::container::builder::BuildReport;
use crate::des::Duration;

/// The stage-name prefix package stages carry in emitted buildfiles
/// (`pkg-<package>`); the terminal stage is anonymous.
pub const PKG_STAGE_PREFIX: &str = "pkg-";

/// Render a pinned stack as a multi-stage buildfile (see the module
/// docs for the shape).  `base` is the catalogue base image every
/// chain bottoms out in; `arch` adds the per-microarchitecture
/// `RUN make -j ARCH=<arch>` + `ARCH_OPT` pair to the terminal stage
/// (the §4.3 variant axis).  The output is in canonical directive
/// spelling, so it round-trips losslessly through
/// [`Buildfile::canonical`].
pub fn emit_stack_buildfile(
    manifest: &Manifest,
    lock: &Lockfile,
    base: &str,
    arch: Option<&str>,
) -> Result<String> {
    let order = lock_topo_order(lock)?;
    let mut out = String::new();
    for name in &order {
        let p = &lock.packages[name];
        match p.deps.first() {
            None => out.push_str(&format!("FROM {base} AS {PKG_STAGE_PREFIX}{name}\n")),
            Some((first, _)) => {
                out.push_str(&format!(
                    "FROM {PKG_STAGE_PREFIX}{first} AS {PKG_STAGE_PREFIX}{name}\n"
                ));
                for (dep, _) in &p.deps[1..] {
                    out.push_str(&format!(
                        "COPY --from={PKG_STAGE_PREFIX}{dep} /opt/pkgs/{dep} /opt/pkgs/{dep}\n"
                    ));
                }
            }
        }
        out.push_str(&format!("RUN pip install {name}=={}\n", p.version));
    }
    out.push_str(&format!("FROM {base}\n"));
    let mut roots: Vec<&str> = manifest.deps.iter().map(|d| d.name.as_str()).collect();
    roots.sort_unstable();
    roots.dedup();
    for root in roots {
        anyhow::ensure!(
            lock.packages.contains_key(root),
            "manifest root dependency `{root}` is not pinned by the lockfile"
        );
        out.push_str(&format!(
            "COPY --from={PKG_STAGE_PREFIX}{root} /opt/pkgs/{root} /opt/pkgs/{root}\n"
        ));
    }
    if let Some(arch) = arch {
        out.push_str(&format!("RUN make -j ARCH={arch} {}\n", manifest.name));
        out.push_str("ARCH_OPT\n");
    }
    out.push_str(&format!("ENTRYPOINT /opt/{}/bin/run\n", manifest.name));
    Ok(out)
}

/// Kahn topological order over a lockfile's pinned edge set,
/// dependencies first, ties broken by name — the same rule the
/// resolver uses, recomputed here so a parsed lockfile can be emitted
/// without re-resolving.  Errors on a cyclic lockfile.
fn lock_topo_order(lock: &Lockfile) -> Result<Vec<String>> {
    let mut indegree: std::collections::BTreeMap<&String, usize> = std::collections::BTreeMap::new();
    let mut dependents: std::collections::BTreeMap<&String, Vec<&String>> =
        std::collections::BTreeMap::new();
    for (name, p) in &lock.packages {
        let pinned_deps: Vec<&String> = p
            .deps
            .iter()
            .map(|(d, _)| d)
            .filter(|d| lock.packages.contains_key(*d))
            .collect();
        indegree.insert(name, pinned_deps.len());
        for d in pinned_deps {
            dependents.entry(d).or_default().push(name);
        }
    }
    let mut ready: BTreeSet<&String> = indegree
        .iter()
        .filter(|(_, &d)| d == 0)
        .map(|(&n, _)| n)
        .collect();
    let mut order = Vec::with_capacity(lock.packages.len());
    while let Some(&name) = ready.iter().next() {
        ready.remove(name);
        order.push(name.clone());
        for &dep in dependents.get(name).map(|v| v.as_slice()).unwrap_or(&[]) {
            let d = indegree.get_mut(dep).expect("dependent is a lock package");
            *d -= 1;
            if *d == 0 {
                ready.insert(dep);
            }
        }
    }
    anyhow::ensure!(
        order.len() == lock.packages.len(),
        "lockfile contains a dependency cycle ({} of {} packages orderable)",
        order.len(),
        lock.packages.len()
    );
    Ok(order)
}

/// The package stages a build actually rebuilt: stage names with the
/// [`PKG_STAGE_PREFIX`] stripped whose stage time is non-zero (skipped
/// and fully-cached stages cost zero).  Compared against
/// [`LockDiff::rebuild_frontier`] by `version-churn` and
/// `tests/build_graph.rs`.
pub fn rebuilt_packages(bf: &Buildfile, report: &BuildReport) -> BTreeSet<String> {
    bf.stages()
        .iter()
        .zip(&report.stage_times)
        .filter(|(_, &t)| t > Duration::ZERO)
        .filter_map(|(s, _)| s.name.and_then(|n| n.strip_prefix(PKG_STAGE_PREFIX)))
        .map(String::from)
        .collect()
}

/// Whether a build's terminal (anonymous) stage rebuilt — the lockfile
/// diff predicts this too: the terminal stage copies from every root
/// dependency, so it rebuilds iff the frontier is non-empty.
pub fn terminal_rebuilt(report: &BuildReport) -> bool {
    report
        .stage_times
        .last()
        .map(|&t| t > Duration::ZERO)
        .unwrap_or(false)
}

/// The published package universe behind the paper's §2.2 FEniCS
/// stack: MPI + linear algebra (openmpi, petsc, slepc and their Python
/// bindings), the Python scientific tier (numpy, scipy, sympy), the
/// form-compiler chain (fiat, ufl, dijitso, ffc), build glue (swig,
/// instant, boost, eigen) and dolfin on top.  Version sets are small
/// but real enough that caret/tilde ranges have non-trivial choices.
pub fn fenics_index() -> PackageIndex {
    let v = Version::new;
    let dep = |name: &str, range: &str| Dependency::new(name, range).expect("static range parses");
    let mut idx = PackageIndex::new();
    idx.add("openmpi", v(1, 10, 2), vec![]);
    idx.add("openmpi", v(2, 0, 0), vec![]);
    idx.add("boost", v(1, 61, 0), vec![]);
    idx.add("eigen", v(3, 2, 8), vec![]);
    idx.add("eigen", v(3, 2, 9), vec![]);
    idx.add("swig", v(3, 0, 10), vec![]);
    idx.add("numpy", v(1, 11, 0), vec![]);
    idx.add("numpy", v(1, 11, 1), vec![]);
    idx.add("sympy", v(1, 0, 0), vec![]);
    idx.add("scipy", v(0, 17, 0), vec![dep("numpy", "^1.11.0")]);
    idx.add("scipy", v(0, 17, 1), vec![dep("numpy", "^1.11.0")]);
    idx.add("petsc", v(3, 7, 2), vec![dep("openmpi", "^1.10.0")]);
    idx.add("petsc", v(3, 7, 3), vec![dep("openmpi", "^1.10.0")]);
    idx.add("slepc", v(3, 7, 1), vec![dep("petsc", "~3.7.2")]);
    idx.add(
        "petsc4py",
        v(3, 7, 0),
        vec![dep("numpy", "^1.11.0"), dep("petsc", "~3.7.0")],
    );
    idx.add(
        "slepc4py",
        v(3, 7, 0),
        vec![dep("petsc4py", "~3.7.0"), dep("slepc", "~3.7.0")],
    );
    idx.add("fiat", v(2016, 1, 0), vec![dep("sympy", "^1.0.0")]);
    idx.add("ufl", v(2016, 1, 0), vec![dep("numpy", "^1.11.0")]);
    idx.add("dijitso", v(2016, 1, 0), vec![dep("numpy", "^1.11.0")]);
    idx.add("instant", v(2016, 1, 0), vec![dep("swig", "^3.0.0")]);
    idx.add(
        "ffc",
        v(2016, 1, 0),
        vec![
            dep("dijitso", "~2016.1.0"),
            dep("fiat", "~2016.1.0"),
            dep("ufl", "~2016.1.0"),
        ],
    );
    idx.add(
        "dolfin",
        v(2016, 1, 0),
        vec![
            dep("boost", "^1.61.0"),
            dep("eigen", "^3.2.8"),
            dep("ffc", "~2016.1.0"),
            dep("instant", "~2016.1.0"),
            dep("openmpi", "^1.10.0"),
            dep("petsc4py", "~3.7.0"),
            dep("slepc4py", "~3.7.0"),
            dep("swig", "^3.0.0"),
        ],
    );
    idx
}

/// The paper's §2.2 stack as a root manifest: dolfin (which pulls the
/// whole FEM chain) plus scipy for the Python driver scripts.
pub fn fenics_manifest() -> Manifest {
    Manifest::new("fenics-stack", Version::new(2016, 1, 0))
        .with_dep("dolfin", "~2016.1.0")
        .expect("static range parses")
        .with_dep("scipy", "^0.17.0")
        .expect("static range parses")
}

/// The base image emitted FEniCS stacks build on (§2.2 builds on
/// Ubuntu 16.04).
pub const STACK_BASE: &str = "ubuntu:16.04";

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::builder::Builder;
    use crate::container::store::LayerStore;

    #[test]
    fn fenics_stack_resolves_and_emits_a_valid_buildfile() {
        let index = fenics_index();
        let manifest = fenics_manifest();
        let res = resolve(&manifest, &index, 42).unwrap();
        assert_eq!(res.pinned.len(), 17);
        assert_eq!(res.pinned["numpy"], Version::new(1, 11, 1));
        assert_eq!(res.pinned["petsc"], Version::new(3, 7, 3));
        assert_eq!(res.pinned["openmpi"], Version::new(1, 10, 2));
        let lock = Lockfile::from_resolution(&res, &index);
        let text = emit_stack_buildfile(&manifest, &lock, STACK_BASE, Some("haswell")).unwrap();
        let bf = Buildfile::parse(&text).expect("emitted buildfile parses");
        // lossless canonical round-trip: emission is already canonical
        assert_eq!(bf.canonical(), text);
        // one stage per package plus the terminal stage
        assert_eq!(bf.stage_count(), 18);
    }

    #[test]
    fn emitted_stack_builds_and_rebuild_matches_frontier() {
        let mut index = fenics_index();
        let manifest = fenics_manifest();
        let res = resolve(&manifest, &index, 1).unwrap();
        let lock = Lockfile::from_resolution(&res, &index);
        let text = emit_stack_buildfile(&manifest, &lock, STACK_BASE, None).unwrap();
        let bf = Buildfile::parse(&text).unwrap();
        let mut builder = Builder::new();
        let mut store = LayerStore::new();
        let cold = builder.build(&bf, "stack:r1", &mut store).unwrap();
        assert!(cold.layers_built > 0);

        // bump sympy: the frontier is the fiat -> ffc -> dolfin chain
        index.bump_patch("sympy").unwrap();
        let res2 = resolve(&manifest, &index, 1).unwrap();
        let lock2 = Lockfile::from_resolution(&res2, &index);
        let frontier = lock.diff(&lock2).rebuild_frontier(&lock2);
        let expect: BTreeSet<String> = ["sympy", "fiat", "ffc", "dolfin"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(frontier, expect);

        let text2 = emit_stack_buildfile(&manifest, &lock2, STACK_BASE, None).unwrap();
        let bf2 = Buildfile::parse(&text2).unwrap();
        let warm = builder.build(&bf2, "stack:r2", &mut store).unwrap();
        assert_eq!(rebuilt_packages(&bf2, &warm), frontier);
        assert!(terminal_rebuilt(&warm));
    }

    #[test]
    fn rebuilding_the_same_lock_is_fully_cached() {
        let index = fenics_index();
        let manifest = fenics_manifest();
        let res = resolve(&manifest, &index, 7).unwrap();
        let lock = Lockfile::from_resolution(&res, &index);
        let text = emit_stack_buildfile(&manifest, &lock, STACK_BASE, Some("knl")).unwrap();
        let bf = Buildfile::parse(&text).unwrap();
        let mut builder = Builder::new();
        let mut store = LayerStore::new();
        builder.build(&bf, "stack:a", &mut store).unwrap();
        let warm = builder.build(&bf, "stack:b", &mut store).unwrap();
        assert_eq!(warm.layers_built, 0);
        assert!(rebuilt_packages(&bf, &warm).is_empty());
        assert!(!terminal_rebuilt(&warm));
    }

    #[test]
    fn lockfile_canonical_bytes_are_seed_invariant() {
        let index = fenics_index();
        let manifest = fenics_manifest();
        let reference =
            Lockfile::from_resolution(&resolve(&manifest, &index, 0).unwrap(), &index).canonical();
        for seed in [1, 7, 42, 1234, u64::MAX] {
            let lock =
                Lockfile::from_resolution(&resolve(&manifest, &index, seed).unwrap(), &index);
            assert_eq!(lock.canonical(), reference, "seed {seed}");
        }
    }
}
