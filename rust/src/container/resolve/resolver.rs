//! Deterministic dependency resolution: semver ranges → a pinned set.
//!
//! [`resolve`] turns a [`Manifest`] plus a [`PackageIndex`] into a
//! [`Resolution`]: one pinned [`Version`] per reachable package and a
//! topological build order (dependencies first).  The algorithm is a
//! Jacobi-style fixed point: each round recomputes, *from the previous
//! round's selection only*, the constraint on every reachable package
//! (the intersection of the root's range and every selected dependent's
//! range) and picks the newest published version satisfying it.  A
//! round is a pure function of the previous selection, so the result is
//! independent of evaluation order — the `seed` parameter shuffles the
//! within-round evaluation order precisely to *exercise* that claim
//! (same manifest + index ⇒ byte-identical lockfile for every seed;
//! property-swept in `tests/resolver.rs`).
//!
//! Failures carry context: a [`ResolveError::Conflict`] names every
//! dependent whose ranges intersected to nothing, and a
//! [`ResolveError::Cycle`] prints the dependency cycle path.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::fmt;

use crate::des::SimRng;

use super::manifest::{Manifest, PackageIndex};
use super::semver::{Range, Version};

/// Why resolution failed, with enough context to fix the manifest.
#[derive(Debug, Clone)]
pub enum ResolveError {
    /// A required package has no published version at all.
    UnknownPackage {
        /// The missing package.
        name: String,
        /// Who required it (`<root>` or `name version`).
        dependents: Vec<String>,
    },
    /// The dependents' ranges intersect to an empty interval.
    Conflict {
        /// The contested package.
        name: String,
        /// Every `(dependent, range)` constraint on it.
        constraints: Vec<(String, Range)>,
    },
    /// The combined range is satisfiable but no published version
    /// falls inside it.
    NoMatchingVersion {
        /// The package without a matching version.
        name: String,
        /// The combined interval.
        range: Range,
        /// Every `(dependent, range)` constraint on it.
        constraints: Vec<(String, Range)>,
    },
    /// The pinned set contains a dependency cycle.
    Cycle {
        /// The cycle, first node repeated at the end.
        path: Vec<String>,
    },
    /// The fixed point did not settle within the round bound
    /// (pathological index; never reachable from a finite acyclic one).
    NoConverge {
        /// Rounds attempted.
        rounds: usize,
    },
}

impl fmt::Display for ResolveError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let list = |cs: &[(String, Range)]| {
            cs.iter()
                .map(|(who, r)| format!("{who} wants `{r}`"))
                .collect::<Vec<_>>()
                .join("; ")
        };
        match self {
            ResolveError::UnknownPackage { name, dependents } => write!(
                f,
                "unknown package `{name}` (required by {})",
                dependents.join(", ")
            ),
            ResolveError::Conflict { name, constraints } => write!(
                f,
                "conflicting requirements on `{name}`: {}",
                list(constraints)
            ),
            ResolveError::NoMatchingVersion {
                name,
                range,
                constraints,
            } => write!(
                f,
                "no published version of `{name}` satisfies `{range}` ({})",
                list(constraints)
            ),
            ResolveError::Cycle { path } => {
                write!(f, "dependency cycle: {}", path.join(" -> "))
            }
            ResolveError::NoConverge { rounds } => {
                write!(f, "resolution did not converge after {rounds} rounds")
            }
        }
    }
}
impl std::error::Error for ResolveError {}

/// A successful resolution: the pinned set and a build order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Resolution {
    /// Pinned version per reachable package, name-ordered.
    pub pinned: BTreeMap<String, Version>,
    /// Topological order, dependencies before dependents (ties broken
    /// lexicographically) — the emitted buildfile's stage order.
    pub order: Vec<String>,
}

/// The label constraints from the manifest itself carry.
const ROOT: &str = "<root>";

/// Resolve `manifest` against `index`.  `seed` shuffles within-round
/// evaluation order only; the returned resolution is identical for
/// every seed (see the module docs).
pub fn resolve(
    manifest: &Manifest,
    index: &PackageIndex,
    seed: u64,
) -> Result<Resolution, ResolveError> {
    let mut rng = SimRng::new(seed, "resolve-order");
    let mut selection: BTreeMap<String, Version> = BTreeMap::new();
    // Each round either grows the reachable set or settles a version,
    // so |packages| + 2 rounds bound any convergent instance.
    let rounds = index.len() + 2;
    for _ in 0..rounds {
        let constraints = gather_constraints(manifest, index, &selection);

        // Evaluate in seed-shuffled order.  Results and failures land
        // in name-ordered maps, so neither the selection nor the error
        // reported can depend on the shuffle.
        let mut names: Vec<&String> = constraints.keys().collect();
        shuffle(&mut names, &mut rng);
        let mut next: BTreeMap<String, Version> = BTreeMap::new();
        let mut failures: BTreeMap<String, ResolveError> = BTreeMap::new();
        for name in names {
            let entries = &constraints[name];
            match pick(name, entries, index) {
                Ok(v) => {
                    next.insert(name.clone(), v);
                }
                Err(e) => {
                    failures.insert(name.clone(), e);
                }
            }
        }
        if let Some((_, e)) = failures.into_iter().next() {
            return Err(e);
        }
        if next == selection {
            let order = topo_order(&selection, index)?;
            return Ok(Resolution {
                pinned: selection,
                order,
            });
        }
        selection = next;
    }
    Err(ResolveError::NoConverge { rounds })
}

/// The constraints on every package reachable from the root through the
/// previous round's selection: `name → [(dependent, range)]`, both maps
/// name-ordered.
fn gather_constraints(
    manifest: &Manifest,
    index: &PackageIndex,
    selection: &BTreeMap<String, Version>,
) -> BTreeMap<String, Vec<(String, Range)>> {
    let mut constraints: BTreeMap<String, Vec<(String, Range)>> = BTreeMap::new();
    let mut queue: VecDeque<String> = VecDeque::new();
    let mut visited: BTreeSet<String> = BTreeSet::new();
    for d in &manifest.deps {
        constraints
            .entry(d.name.clone())
            .or_default()
            .push((ROOT.to_string(), d.range));
        queue.push_back(d.name.clone());
    }
    while let Some(name) = queue.pop_front() {
        if !visited.insert(name.clone()) {
            continue;
        }
        let Some(&version) = selection.get(&name) else {
            continue; // not selected yet; its deps join next round
        };
        for dep in index.deps(&name, version).unwrap_or(&[]) {
            constraints
                .entry(dep.name.clone())
                .or_default()
                .push((format!("{name} {version}"), dep.range));
            queue.push_back(dep.name.clone());
        }
    }
    constraints
}

/// Pick the newest published version of `name` satisfying every
/// constraint, or say precisely why none exists.
fn pick(
    name: &str,
    entries: &[(String, Range)],
    index: &PackageIndex,
) -> Result<Version, ResolveError> {
    if !index.contains(name) {
        return Err(ResolveError::UnknownPackage {
            name: name.to_string(),
            dependents: entries.iter().map(|(who, _)| who.clone()).collect(),
        });
    }
    let combined = entries
        .iter()
        .fold(Range::any(), |acc, (_, r)| acc.intersect(r));
    if combined.is_empty() {
        return Err(ResolveError::Conflict {
            name: name.to_string(),
            constraints: entries.to_vec(),
        });
    }
    index
        .best_match(name, &combined)
        .ok_or_else(|| ResolveError::NoMatchingVersion {
            name: name.to_string(),
            range: combined,
            constraints: entries.to_vec(),
        })
}

/// Kahn's algorithm over the pinned set, dependencies first, ready set
/// drained in name order — the deterministic stage order the emitter
/// relies on.  A non-empty residue is a cycle; its path is extracted by
/// walking dependency edges inside the residue until a node repeats.
fn topo_order(
    pinned: &BTreeMap<String, Version>,
    index: &PackageIndex,
) -> Result<Vec<String>, ResolveError> {
    let deps_of = |name: &str| -> Vec<String> {
        index
            .deps(name, pinned[name])
            .unwrap_or(&[])
            .iter()
            .filter(|d| pinned.contains_key(&d.name))
            .map(|d| d.name.clone())
            .collect()
    };
    let mut indegree: BTreeMap<&String, usize> = BTreeMap::new();
    let mut dependents: BTreeMap<String, Vec<&String>> = BTreeMap::new();
    for name in pinned.keys() {
        let ds = deps_of(name);
        indegree.insert(name, ds.len());
        for d in ds {
            dependents.entry(d).or_default().push(name);
        }
    }
    let mut ready: BTreeSet<&String> = indegree
        .iter()
        .filter(|(_, &d)| d == 0)
        .map(|(&n, _)| n)
        .collect();
    let mut order = Vec::with_capacity(pinned.len());
    while let Some(&name) = ready.iter().next() {
        ready.remove(name);
        order.push(name.clone());
        for &dep in dependents.get(name).map(|v| v.as_slice()).unwrap_or(&[]) {
            let d = indegree.get_mut(dep).expect("dependent is pinned");
            *d -= 1;
            if *d == 0 {
                ready.insert(dep);
            }
        }
    }
    if order.len() == pinned.len() {
        return Ok(order);
    }
    // extract one cycle from the residue
    let residue: BTreeSet<&String> = pinned
        .keys()
        .filter(|n| !order.contains(*n))
        .collect();
    let start = (*residue.iter().next().expect("residue is non-empty")).clone();
    let mut path = vec![start.clone()];
    let mut seen: BTreeSet<String> = BTreeSet::new();
    seen.insert(start);
    loop {
        let here = path.last().expect("path starts non-empty").clone();
        let next = deps_of(&here)
            .into_iter()
            .find(|d| residue.contains(d))
            .expect("every residue node keeps an in-residue dependency");
        path.push(next.clone());
        if !seen.insert(next) {
            break;
        }
    }
    // trim the lead-in so the path starts at the repeated node
    let repeat = path.last().expect("loop pushed at least one node").clone();
    let from = path.iter().position(|n| *n == repeat).expect("repeat is in path");
    Err(ResolveError::Cycle {
        path: path[from..].to_vec(),
    })
}

/// Fisher–Yates over `SimRng` (no `std` RNG anywhere in the simulator).
fn shuffle<T>(items: &mut [T], rng: &mut SimRng) {
    for i in (1..items.len()).rev() {
        items.swap(i, rng.index(i + 1));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::resolve::manifest::Dependency;

    fn v(ma: u64, mi: u64, pa: u64) -> Version {
        Version::new(ma, mi, pa)
    }

    fn dep(name: &str, range: &str) -> Dependency {
        Dependency::new(name, range).unwrap()
    }

    fn small_index() -> PackageIndex {
        let mut idx = PackageIndex::new();
        idx.add("numpy", v(1, 11, 0), vec![]);
        idx.add("numpy", v(1, 11, 1), vec![]);
        idx.add("scipy", v(0, 17, 1), vec![dep("numpy", "^1.11.0")]);
        idx.add("ufl", v(2016, 1, 0), vec![dep("numpy", "^1.11.0")]);
        idx
    }

    #[test]
    fn resolves_newest_satisfying_and_topo_orders() {
        let m = Manifest::new("app", v(1, 0, 0))
            .with_dep("scipy", "^0.17.0")
            .unwrap()
            .with_dep("ufl", "~2016.1.0")
            .unwrap();
        let r = resolve(&m, &small_index(), 42).unwrap();
        assert_eq!(r.pinned["numpy"], v(1, 11, 1));
        assert_eq!(r.pinned["scipy"], v(0, 17, 1));
        assert_eq!(r.order, vec!["numpy", "scipy", "ufl"]);
    }

    #[test]
    fn seed_does_not_change_the_resolution() {
        let m = Manifest::new("app", v(1, 0, 0))
            .with_dep("scipy", "^0.17.0")
            .unwrap();
        let reference = resolve(&m, &small_index(), 0).unwrap();
        for seed in 1..16 {
            assert_eq!(resolve(&m, &small_index(), seed).unwrap(), reference);
        }
    }

    #[test]
    fn conflict_carries_both_dependents() {
        let mut idx = small_index();
        idx.add("tight", v(1, 0, 0), vec![dep("numpy", "=1.11.0")]);
        let m = Manifest::new("app", v(1, 0, 0))
            .with_dep("tight", "*")
            .unwrap()
            .with_dep("numpy", "=1.11.1")
            .unwrap();
        let e = resolve(&m, &idx, 42).unwrap_err();
        let text = e.to_string();
        assert!(text.contains("conflicting requirements on `numpy`"), "{text}");
        assert!(text.contains("<root>"), "{text}");
        assert!(text.contains("tight 1.0.0"), "{text}");
    }

    #[test]
    fn unknown_package_names_its_dependents() {
        let m = Manifest::new("app", v(1, 0, 0))
            .with_dep("no-such-pkg", "*")
            .unwrap();
        let e = resolve(&m, &small_index(), 42).unwrap_err();
        assert!(matches!(e, ResolveError::UnknownPackage { .. }));
        assert!(e.to_string().contains("<root>"));
    }

    #[test]
    fn no_matching_version_reports_the_interval() {
        let m = Manifest::new("app", v(1, 0, 0))
            .with_dep("numpy", "^2.0.0")
            .unwrap();
        let e = resolve(&m, &small_index(), 42).unwrap_err();
        assert!(matches!(e, ResolveError::NoMatchingVersion { .. }));
        assert!(e.to_string().contains("numpy"));
    }

    #[test]
    fn cycles_are_reported_with_their_path() {
        let mut idx = PackageIndex::new();
        idx.add("a", v(1, 0, 0), vec![dep("b", "*")]);
        idx.add("b", v(1, 0, 0), vec![dep("a", "*")]);
        let m = Manifest::new("app", v(1, 0, 0)).with_dep("a", "*").unwrap();
        let e = resolve(&m, &idx, 42).unwrap_err();
        let ResolveError::Cycle { path } = &e else {
            panic!("expected a cycle, got {e}");
        };
        assert!(path.len() >= 3);
        assert_eq!(path.first(), path.last());
        assert!(e.to_string().contains(" -> "));
    }
}
