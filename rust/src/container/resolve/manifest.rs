//! Package manifests and the registry's package index.
//!
//! A [`Manifest`] is what a stack author writes: a root package name,
//! its version, and semver-ranged dependency declarations.  A
//! [`PackageIndex`] is what the registry knows: every published
//! `(package, version)` with that version's own dependency ranges.
//! Both are plain `nanoserde`-style structs with a line-oriented text
//! form (`parse` / `canonical`) so manifests can be committed as golden
//! files and diffed byte-for-byte.
//!
//! The text form, one declaration per line (`#` comments and blank
//! lines are ignored):
//!
//! ```text
//! # harbor-manifest v1
//! package fenics-stack 2016.1.0
//! dep dolfin ~2016.1.0
//! dep scipy ^0.17.0
//! ```

use std::collections::BTreeMap;
use std::fmt;

use super::semver::{Range, SemverError, Version};

/// One ranged dependency declaration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Dependency {
    /// Depended-on package name.
    pub name: String,
    /// Acceptable version interval.
    pub range: Range,
}

impl Dependency {
    /// Construct a dependency, parsing `range` syntax.
    pub fn new(name: &str, range: &str) -> Result<Self, SemverError> {
        Ok(Dependency {
            name: name.to_string(),
            range: Range::parse(range)?,
        })
    }
}

/// A root package declaration: what the resolver resolves.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    /// Root package name (names the emitted stack image).
    pub name: String,
    /// Root package version.
    pub version: Version,
    /// Direct dependencies, in declaration order.
    pub deps: Vec<Dependency>,
}

/// A malformed manifest line.
#[derive(Debug, Clone)]
pub struct ManifestError {
    /// 1-based line number of the offending declaration.
    pub line: usize,
    /// What was wrong with it.
    pub message: String,
}

impl fmt::Display for ManifestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "manifest line {}: {}", self.line, self.message)
    }
}
impl std::error::Error for ManifestError {}

impl Manifest {
    /// A manifest with no dependencies yet.
    pub fn new(name: &str, version: Version) -> Self {
        Manifest {
            name: name.to_string(),
            version,
            deps: Vec::new(),
        }
    }

    /// Add a dependency declaration (builder-style).
    pub fn with_dep(mut self, name: &str, range: &str) -> Result<Self, SemverError> {
        self.deps.push(Dependency::new(name, range)?);
        Ok(self)
    }

    /// Parse the line-oriented text form.
    pub fn parse(text: &str) -> Result<Manifest, ManifestError> {
        let mut root: Option<(String, Version)> = None;
        let mut deps = Vec::new();
        for (i, raw) in text.lines().enumerate() {
            let line_no = i + 1;
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fail = |message: String| ManifestError {
                line: line_no,
                message,
            };
            let mut words = line.split_whitespace();
            match words.next() {
                Some("package") => {
                    let name = words
                        .next()
                        .ok_or_else(|| fail("`package` needs a name".into()))?;
                    let version: Version = words
                        .next()
                        .ok_or_else(|| fail("`package` needs a version".into()))?
                        .parse()
                        .map_err(|e: SemverError| fail(e.to_string()))?;
                    if root.is_some() {
                        return Err(fail("second `package` declaration".into()));
                    }
                    root = Some((name.to_string(), version));
                }
                Some("dep") => {
                    let name = words
                        .next()
                        .ok_or_else(|| fail("`dep` needs a name".into()))?;
                    let range_text: Vec<&str> = words.collect();
                    if range_text.is_empty() {
                        return Err(fail("`dep` needs a range".into()));
                    }
                    let range = Range::parse(&range_text.join(" "))
                        .map_err(|e| fail(e.to_string()))?;
                    deps.push(Dependency {
                        name: name.to_string(),
                        range,
                    });
                }
                Some(other) => {
                    return Err(fail(format!(
                        "unknown declaration `{other}` (package|dep)"
                    )))
                }
                None => unreachable!("blank lines were skipped"),
            }
        }
        let (name, version) =
            root.ok_or(ManifestError {
                line: 1,
                message: "missing `package <name> <version>` declaration".into(),
            })?;
        Ok(Manifest { name, version, deps })
    }

    /// The canonical text form: header, the `package` line, then one
    /// `dep` line per dependency with ranges in their canonical
    /// interval spelling.  `parse(canonical())` reproduces the manifest
    /// (ranges compare equal as intervals; sugar is desugared).
    pub fn canonical(&self) -> String {
        let mut out = String::from("# harbor-manifest v1\n");
        out.push_str(&format!("package {} {}\n", self.name, self.version));
        for d in &self.deps {
            out.push_str(&format!("dep {} {}\n", d.name, d.range));
        }
        out
    }
}

/// The registry's view of the package universe: every published
/// `(name, version)` and that version's dependency ranges.  Ordered
/// maps throughout, so iteration — and everything resolution derives
/// from it — is deterministic.
#[derive(Debug, Clone, Default)]
pub struct PackageIndex {
    packages: BTreeMap<String, BTreeMap<Version, Vec<Dependency>>>,
}

impl PackageIndex {
    /// An empty index.
    pub fn new() -> Self {
        Self::default()
    }

    /// Publish `(name, version)` with its dependency ranges.
    /// Re-publishing an existing version replaces its declarations.
    pub fn add(&mut self, name: &str, version: Version, deps: Vec<Dependency>) {
        self.packages
            .entry(name.to_string())
            .or_default()
            .insert(version, deps);
    }

    /// Published versions of `name`, ascending (empty if unknown).
    pub fn versions(&self, name: &str) -> Vec<Version> {
        self.packages
            .get(name)
            .map(|v| v.keys().copied().collect())
            .unwrap_or_default()
    }

    /// The newest published version satisfying `range`, if any.
    pub fn best_match(&self, name: &str, range: &Range) -> Option<Version> {
        self.packages
            .get(name)?
            .keys()
            .rev()
            .copied()
            .find(|&v| range.contains(v))
    }

    /// The dependency declarations of one published version.
    pub fn deps(&self, name: &str, version: Version) -> Option<&[Dependency]> {
        self.packages
            .get(name)
            .and_then(|v| v.get(&version))
            .map(|d| d.as_slice())
    }

    /// Whether `name` has any published version.
    pub fn contains(&self, name: &str) -> bool {
        self.packages.contains_key(name)
    }

    /// Package names, ascending.
    pub fn names(&self) -> Vec<&str> {
        self.packages.keys().map(|s| s.as_str()).collect()
    }

    /// Number of distinct packages.
    pub fn len(&self) -> usize {
        self.packages.len()
    }

    /// Whether the index has no packages.
    pub fn is_empty(&self) -> bool {
        self.packages.is_empty()
    }

    /// Publish a patch bump of `name`'s newest version, cloning its
    /// dependency declarations, and return the new version.  This is
    /// the `version-churn` scenario's "one dep bump" primitive: the new
    /// patch still satisfies every caret/tilde range the old one did.
    pub fn bump_patch(&mut self, name: &str) -> Option<Version> {
        let versions = self.packages.get(name)?;
        let (&newest, deps) = versions.iter().next_back()?;
        let deps = deps.clone();
        let bumped = newest.bump_patch();
        self.add(name, bumped, deps);
        Some(bumped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(ma: u64, mi: u64, pa: u64) -> Version {
        Version::new(ma, mi, pa)
    }

    #[test]
    fn manifest_parse_and_canonical_round_trip() {
        let text = "# note\npackage app 1.0.0\ndep numpy ^1.11.0\ndep petsc ~3.7.2\n";
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.name, "app");
        assert_eq!(m.version, v(1, 0, 0));
        assert_eq!(m.deps.len(), 2);
        let back = Manifest::parse(&m.canonical()).unwrap();
        assert_eq!(m, back);
        // canonical is a fixed point
        assert_eq!(back.canonical(), m.canonical());
    }

    #[test]
    fn manifest_rejects_malformed_lines() {
        assert!(Manifest::parse("dep numpy ^1.0.0\n").is_err()); // no package
        assert!(Manifest::parse("package a 1.0.0\npackage b 1.0.0\n").is_err());
        assert!(Manifest::parse("package a 1.0.0\ndep numpy\n").is_err());
        assert!(Manifest::parse("package a 1.0.0\nfrobnicate x\n").is_err());
        let e = Manifest::parse("package a 1.0.0\ndep numpy ^bad\n").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn index_best_match_is_newest_satisfying() {
        let mut idx = PackageIndex::new();
        idx.add("numpy", v(1, 11, 0), vec![]);
        idx.add("numpy", v(1, 11, 1), vec![]);
        idx.add("numpy", v(2, 0, 0), vec![]);
        let caret = Range::parse("^1.11.0").unwrap();
        assert_eq!(idx.best_match("numpy", &caret), Some(v(1, 11, 1)));
        assert_eq!(idx.best_match("numpy", &Range::any()), Some(v(2, 0, 0)));
        assert_eq!(idx.best_match("scipy", &Range::any()), None);
        let nothing = Range::parse("^3.0.0").unwrap();
        assert_eq!(idx.best_match("numpy", &nothing), None);
    }

    #[test]
    fn bump_patch_clones_the_newest_deps() {
        let mut idx = PackageIndex::new();
        idx.add(
            "scipy",
            v(0, 17, 1),
            vec![Dependency::new("numpy", "^1.11.0").unwrap()],
        );
        let bumped = idx.bump_patch("scipy").unwrap();
        assert_eq!(bumped, v(0, 17, 2));
        assert_eq!(idx.versions("scipy"), vec![v(0, 17, 1), v(0, 17, 2)]);
        assert_eq!(idx.deps("scipy", bumped).unwrap().len(), 1);
        assert!(idx.bump_patch("missing").is_none());
    }
}
