//! Canonical, byte-stable lockfiles — and the diffs that predict
//! rebuild frontiers.
//!
//! A [`Lockfile`] records the pinned outcome of one resolution: every
//! package at its exact version, with its (pinned) dependency edges.
//! [`Lockfile::canonical`] is byte-stable — packages and dependency
//! lines in name order, one spelling per line, trailing newline — so
//! two lockfiles are semantically equal iff their bytes are equal, and
//! golden files diff cleanly.
//!
//! The payoff is [`Lockfile::diff`] + [`LockDiff::rebuild_frontier`]:
//! because the emitted buildfile gives every package a stage whose
//! layer keys commit to its own pinned version and its dependencies'
//! stage digests (see the `resolve` module docs), the set of stages a
//! bump invalidates is exactly *changed ∪ added, closed under
//! dependents* — computable from two lockfiles alone, before any build
//! runs.  `version-churn` asserts that prediction equals the stages the
//! builder actually rebuilds.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

use super::manifest::PackageIndex;
use super::resolver::Resolution;
use super::semver::Version;

/// The header line every lockfile starts with.
const HEADER: &str = "# harbor-lock v1";

/// One pinned package: its version and its pinned dependency edges.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LockedPackage {
    /// The pinned version.
    pub version: Version,
    /// Pinned `(dependency, version)` edges, name-ordered.
    pub deps: Vec<(String, Version)>,
}

/// A resolved, pinned package set (name-ordered).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Lockfile {
    /// Every pinned package, keyed by name.
    pub packages: BTreeMap<String, LockedPackage>,
}

/// A malformed lockfile line.
#[derive(Debug, Clone)]
pub struct LockParseError {
    /// 1-based line number.
    pub line: usize,
    /// What was wrong.
    pub message: String,
}

impl fmt::Display for LockParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "lockfile line {}: {}", self.line, self.message)
    }
}
impl std::error::Error for LockParseError {}

impl Lockfile {
    /// Pin a [`Resolution`]: record each package's version and its
    /// dependency edges at their resolved versions.
    pub fn from_resolution(res: &Resolution, index: &PackageIndex) -> Self {
        let mut packages = BTreeMap::new();
        for (name, &version) in &res.pinned {
            let mut deps: Vec<(String, Version)> = index
                .deps(name, version)
                .unwrap_or(&[])
                .iter()
                .filter_map(|d| res.pinned.get(&d.name).map(|&v| (d.name.clone(), v)))
                .collect();
            deps.sort();
            packages.insert(
                name.clone(),
                LockedPackage { version, deps },
            );
        }
        Lockfile { packages }
    }

    /// The canonical byte form (see the module docs).  Stable under
    /// `parse` ∘ `canonical`.
    pub fn canonical(&self) -> String {
        let mut out = String::from(HEADER);
        out.push('\n');
        for (name, p) in &self.packages {
            out.push_str(&format!("package {} {}\n", name, p.version));
            for (dep, version) in &p.deps {
                out.push_str(&format!("  dep {dep} {version}\n"));
            }
        }
        out
    }

    /// Parse the canonical text form (tolerates comments, blank lines,
    /// and any indentation).
    pub fn parse(text: &str) -> Result<Lockfile, LockParseError> {
        let mut packages: BTreeMap<String, LockedPackage> = BTreeMap::new();
        let mut current: Option<String> = None;
        for (i, raw) in text.lines().enumerate() {
            let line_no = i + 1;
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fail = |message: String| LockParseError {
                line: line_no,
                message,
            };
            let words: Vec<&str> = line.split_whitespace().collect();
            match words.as_slice() {
                ["package", name, version] => {
                    let version: Version = version
                        .parse()
                        .map_err(|e: super::semver::SemverError| fail(e.to_string()))?;
                    if packages
                        .insert(
                            name.to_string(),
                            LockedPackage {
                                version,
                                deps: Vec::new(),
                            },
                        )
                        .is_some()
                    {
                        return Err(fail(format!("duplicate package `{name}`")));
                    }
                    current = Some(name.to_string());
                }
                ["dep", name, version] => {
                    let version: Version = version
                        .parse()
                        .map_err(|e: super::semver::SemverError| fail(e.to_string()))?;
                    let owner = current
                        .as_ref()
                        .ok_or_else(|| fail("`dep` before any `package`".into()))?;
                    packages
                        .get_mut(owner)
                        .expect("current tracks an inserted package")
                        .deps
                        .push((name.to_string(), version));
                }
                _ => return Err(fail(format!("unrecognised line `{line}`"))),
            }
        }
        for p in packages.values_mut() {
            p.deps.sort();
        }
        Ok(Lockfile { packages })
    }

    /// What changed between two lockfiles, by package name.
    pub fn diff(&self, new: &Lockfile) -> LockDiff {
        let old_names: BTreeSet<&String> = self.packages.keys().collect();
        let new_names: BTreeSet<&String> = new.packages.keys().collect();
        LockDiff {
            added: new_names
                .difference(&old_names)
                .map(|s| (*s).clone())
                .collect(),
            removed: old_names
                .difference(&new_names)
                .map(|s| (*s).clone())
                .collect(),
            changed: old_names
                .intersection(&new_names)
                .filter(|n| self.packages[**n].version != new.packages[**n].version)
                .map(|s| (*s).clone())
                .collect(),
        }
    }
}

/// The package-level difference between two lockfiles.  All three
/// lists are name-sorted.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LockDiff {
    /// Packages only the new lockfile pins.
    pub added: Vec<String>,
    /// Packages only the old lockfile pins.
    pub removed: Vec<String>,
    /// Packages pinned by both at different versions.
    pub changed: Vec<String>,
}

impl LockDiff {
    /// Whether the two lockfiles pin identical sets.
    pub fn is_empty(&self) -> bool {
        self.added.is_empty() && self.removed.is_empty() && self.changed.is_empty()
    }

    /// The predicted rebuild frontier under `new`: every added or
    /// changed package, closed under *dependents* in the new lockfile's
    /// edge set.  This is exactly the set of package stages whose
    /// cache keys change in the emitted buildfile (stage layers commit
    /// to the package's own version and to dependency stage digests),
    /// so the builder must rebuild precisely these stages — the
    /// equality `version-churn` asserts per cell.
    pub fn rebuild_frontier(&self, new: &Lockfile) -> BTreeSet<String> {
        let mut frontier: BTreeSet<String> = self
            .added
            .iter()
            .chain(self.changed.iter())
            .cloned()
            .collect();
        loop {
            let grown: Vec<String> = new
                .packages
                .iter()
                .filter(|(name, p)| {
                    !frontier.contains(*name)
                        && p.deps.iter().any(|(d, _)| frontier.contains(d))
                })
                .map(|(name, _)| name.clone())
                .collect();
            if grown.is_empty() {
                return frontier;
            }
            frontier.extend(grown);
        }
    }
}

impl fmt::Display for LockDiff {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "+{} -{} ~{}",
            self.added.join(","),
            self.removed.join(","),
            self.changed.join(",")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lock(entries: &[(&str, &str, &[(&str, &str)])]) -> Lockfile {
        let mut packages = BTreeMap::new();
        for (name, version, deps) in entries {
            let mut deps: Vec<(String, Version)> = deps
                .iter()
                .map(|(n, v)| (n.to_string(), v.parse().unwrap()))
                .collect();
            deps.sort();
            packages.insert(
                name.to_string(),
                LockedPackage {
                    version: version.parse().unwrap(),
                    deps,
                },
            );
        }
        Lockfile { packages }
    }

    #[test]
    fn canonical_parse_round_trip_is_byte_stable() {
        let l = lock(&[
            ("numpy", "1.11.1", &[]),
            ("scipy", "0.17.1", &[("numpy", "1.11.1")]),
        ]);
        let text = l.canonical();
        assert!(text.starts_with("# harbor-lock v1\n"));
        assert!(text.ends_with('\n'));
        let back = Lockfile::parse(&text).unwrap();
        assert_eq!(back, l);
        assert_eq!(back.canonical(), text);
    }

    #[test]
    fn parse_rejects_garbage_and_duplicates() {
        assert!(Lockfile::parse("package a 1.0.0\npackage a 1.0.0\n").is_err());
        assert!(Lockfile::parse("dep x 1.0.0\n").is_err());
        assert!(Lockfile::parse("wat\n").is_err());
        assert!(Lockfile::parse("package a not-a-version\n").is_err());
    }

    #[test]
    fn diff_classifies_added_removed_changed() {
        let old = lock(&[("a", "1.0.0", &[]), ("b", "1.0.0", &[]), ("c", "1.0.0", &[])]);
        let new = lock(&[("a", "1.0.1", &[]), ("b", "1.0.0", &[]), ("d", "2.0.0", &[])]);
        let d = old.diff(&new);
        assert_eq!(d.added, vec!["d"]);
        assert_eq!(d.removed, vec!["c"]);
        assert_eq!(d.changed, vec!["a"]);
        assert!(!d.is_empty());
        assert!(old.diff(&old).is_empty());
    }

    #[test]
    fn frontier_closes_over_dependents() {
        // chain: app -> mid -> leaf, plus a bystander
        let old = lock(&[
            ("leaf", "1.0.0", &[]),
            ("mid", "1.0.0", &[("leaf", "1.0.0")]),
            ("app", "1.0.0", &[("mid", "1.0.0")]),
            ("bystander", "1.0.0", &[]),
        ]);
        let new = lock(&[
            ("leaf", "1.0.1", &[]),
            ("mid", "1.0.0", &[("leaf", "1.0.1")]),
            ("app", "1.0.0", &[("mid", "1.0.0")]),
            ("bystander", "1.0.0", &[]),
        ]);
        let frontier = old.diff(&new).rebuild_frontier(&new);
        let expect: BTreeSet<String> =
            ["leaf", "mid", "app"].iter().map(|s| s.to_string()).collect();
        assert_eq!(frontier, expect);
    }
}
