//! Content-addressed package cache.
//!
//! Resolved `(package, version)` artifacts are materialised as
//! [`Layer`]s in a [`LayerStore`] — the same sha256 content addressing
//! the image store uses, so identical package blobs dedup across
//! manifests exactly like shared base layers do (§2.2's compactness
//! argument, applied to the package tier).  The `dep-storm` scenario
//! drives a cold-resolve storm through one shared cache and reports the
//! hit rate and dedup ratio this bookkeeping exposes.

use std::collections::BTreeMap;

use crate::container::image::{FileEntry, Layer, LayerId};
use crate::container::store::LayerStore;
use crate::util::rng::fnv1a;

use super::semver::Version;

/// A content-addressed store of fetched package artifacts with
/// hit/miss accounting.
#[derive(Debug, Default)]
pub struct PackageCache {
    store: LayerStore,
    by_package: BTreeMap<(String, Version), LayerId>,
    hits: u64,
    misses: u64,
}

impl PackageCache {
    /// An empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch `(name, version)`: a hit returns the cached layer id, a
    /// miss synthesises the package blob deterministically from its
    /// coordinates and stores it.
    pub fn fetch(&mut self, name: &str, version: Version) -> LayerId {
        let key = (name.to_string(), version);
        if let Some(id) = self.by_package.get(&key) {
            self.hits += 1;
            return id.clone();
        }
        self.misses += 1;
        let layer = package_layer(name, version);
        let id = layer.id.clone();
        self.store.insert(layer);
        self.by_package.insert(key, id.clone());
        id
    }

    /// Cache hits so far.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Cache misses (synthesised fetches) so far.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Hits over total fetches (0 when nothing was fetched).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Distinct packages resident.
    pub fn len(&self) -> usize {
        self.by_package.len()
    }

    /// Whether the cache holds no packages.
    pub fn is_empty(&self) -> bool {
        self.by_package.is_empty()
    }

    /// The backing layer store (for byte/dedup accounting).
    pub fn store(&self) -> &LayerStore {
        &self.store
    }
}

/// The deterministic blob of one `(package, version)`: a handful of
/// files whose count and sizes derive from the coordinates, wrapped in
/// a [`Layer`] so its identity is the usual content hash.
fn package_layer(name: &str, version: Version) -> Layer {
    let tag = format!("pkg {name} {version}");
    let h = fnv1a(tag.bytes());
    let n = 3 + (h % 9) as usize;
    let files = (0..n)
        .map(|i| FileEntry {
            path: format!("/opt/pkgs/{name}/f{i}"),
            bytes: 100_000 + (fnv1a(format!("{tag}:{i}").bytes()) % 8_000_000),
        })
        .collect();
    Layer::derive(None, &tag, files)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(ma: u64, mi: u64, pa: u64) -> Version {
        Version::new(ma, mi, pa)
    }

    #[test]
    fn refetch_hits_and_ids_are_stable() {
        let mut c = PackageCache::new();
        let a = c.fetch("numpy", v(1, 11, 1));
        let b = c.fetch("numpy", v(1, 11, 1));
        assert_eq!(a, b);
        assert_eq!((c.hits(), c.misses()), (1, 1));
        assert_eq!(c.len(), 1);
        // a second cache derives the same content address
        let mut c2 = PackageCache::new();
        assert_eq!(c2.fetch("numpy", v(1, 11, 1)), a);
    }

    #[test]
    fn versions_are_distinct_blobs() {
        let mut c = PackageCache::new();
        let a = c.fetch("petsc", v(3, 7, 3));
        let b = c.fetch("petsc", v(3, 7, 4));
        assert_ne!(a, b);
        assert_eq!(c.misses(), 2);
        assert_eq!(c.hit_rate(), 0.0);
        assert!(c.store().physical_bytes() > 0);
    }
}
