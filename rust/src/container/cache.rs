//! Node-local, content-addressed layer cache — the tier between the
//! registry and the runtime.
//!
//! Every compute node in a fleet keeps a [`LayerCache`]: a bounded
//! [`LayerStore`] with least-recently-used eviction and hit/miss/eviction
//! accounting.  A fleet deployment (see [`distribute`]) consults each
//! node's cache before any transfer is scheduled, which is what turns a
//! warm re-deploy into a metadata-only operation — the mechanism behind
//! Shifter's node-local image cache and the `squashfs` per-node loopback
//! mounts the paper's HPC side relies on.
//!
//! [`distribute`]: super::distribute

use std::collections::HashMap;

use super::image::{Layer, LayerId};
use super::store::LayerStore;

/// Hit/miss/eviction counters for one cache (or, merged, for a fleet).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that required a transfer.
    pub misses: u64,
    /// Layers evicted to stay under the byte capacity.
    pub evictions: u64,
    /// Bytes served from the cache (transfers avoided).
    pub bytes_hit: u64,
    /// Bytes admitted into the cache.
    pub bytes_inserted: u64,
    /// Bytes evicted from the cache.
    pub bytes_evicted: u64,
}

impl CacheStats {
    /// hits / (hits + misses); 0.0 for an untouched cache.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Accumulate another cache's counters into this one (fleet totals).
    pub fn merge(&mut self, other: &CacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
        self.bytes_hit += other.bytes_hit;
        self.bytes_inserted += other.bytes_inserted;
        self.bytes_evicted += other.bytes_evicted;
    }

    /// Accumulate `delta` scaled by a node-class multiplicity: one
    /// representative cache performed `delta` worth of work on behalf
    /// of `multiplicity` identical nodes (see `NodeClass` in
    /// [`distribute`](super::distribute)).  `merge` is the
    /// `multiplicity == 1` special case.
    pub fn add_scaled(&mut self, delta: &CacheStats, multiplicity: u64) {
        self.hits += delta.hits * multiplicity;
        self.misses += delta.misses * multiplicity;
        self.evictions += delta.evictions * multiplicity;
        self.bytes_hit += delta.bytes_hit * multiplicity;
        self.bytes_inserted += delta.bytes_inserted * multiplicity;
        self.bytes_evicted += delta.bytes_evicted * multiplicity;
    }

    /// One-line summary for reports and bench output.
    pub fn render(&self) -> String {
        format!(
            "cache: {}/{} hit(s) ({:.0}% hit rate), {} eviction(s), \
             {:.1} MB hit / {:.1} MB inserted / {:.1} MB evicted",
            self.hits,
            self.hits + self.misses,
            self.hit_rate() * 100.0,
            self.evictions,
            self.bytes_hit as f64 / 1e6,
            self.bytes_inserted as f64 / 1e6,
            self.bytes_evicted as f64 / 1e6,
        )
    }

    /// Counter delta since an `earlier` snapshot of the same cache set
    /// (all fields are monotone, so plain subtraction is exact).
    pub fn since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            evictions: self.evictions - earlier.evictions,
            bytes_hit: self.bytes_hit - earlier.bytes_hit,
            bytes_inserted: self.bytes_inserted - earlier.bytes_inserted,
            bytes_evicted: self.bytes_evicted - earlier.bytes_evicted,
        }
    }
}

/// A bounded, LRU-evicting, content-addressed layer cache.
///
/// Wraps a [`LayerStore`] with a byte capacity, a recency order, and
/// [`CacheStats`] accounting.  `u64::MAX` capacity (the
/// [`unbounded`](LayerCache::unbounded) constructor) disables eviction.
#[derive(Debug, Clone)]
pub struct LayerCache {
    store: LayerStore,
    capacity_bytes: u64,
    /// Logical access clock; higher = more recently used.
    tick: u64,
    /// Last-access tick per resident layer.
    recency: HashMap<LayerId, u64>,
    stats: CacheStats,
}

impl LayerCache {
    /// A cache holding at most `capacity_bytes` of layer data.
    pub fn new(capacity_bytes: u64) -> Self {
        LayerCache {
            store: LayerStore::new(),
            capacity_bytes,
            tick: 0,
            recency: HashMap::new(),
            stats: CacheStats::default(),
        }
    }

    /// A cache that never evicts (fleet nodes with ample local disk).
    pub fn unbounded() -> Self {
        Self::new(u64::MAX)
    }

    /// Look `id` up, recording a hit or a miss and touching recency on
    /// a hit.  This is the accounting entry point a deployment uses;
    /// [`contains`](Self::contains) peeks without accounting.
    pub fn lookup(&mut self, id: &LayerId) -> Option<&Layer> {
        self.tick += 1;
        match self.store.get(id) {
            Some(layer) => {
                self.stats.hits += 1;
                self.stats.bytes_hit += layer.bytes;
                self.recency.insert(id.clone(), self.tick);
                Some(layer)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Whether `id` is resident (no accounting, no recency touch).
    pub fn contains(&self, id: &LayerId) -> bool {
        self.store.contains(id)
    }

    /// Admit a layer, evicting least-recently-used layers until the
    /// cache fits its capacity.  The just-admitted layer is never the
    /// eviction victim (it is the most recent by construction), but a
    /// single layer larger than the whole capacity is admitted and then
    /// becomes the only resident — the cache degrades to pass-through
    /// rather than refusing work.
    pub fn admit(&mut self, layer: Layer) {
        self.tick += 1;
        if self.store.contains(&layer.id) {
            // refresh recency only; re-admitting resident content is free
            self.recency.insert(layer.id.clone(), self.tick);
            return;
        }
        self.stats.bytes_inserted += layer.bytes;
        self.recency.insert(layer.id.clone(), self.tick);
        self.store.insert(layer);
        while self.store.physical_bytes() > self.capacity_bytes && self.store.len() > 1 {
            let victim = self
                .recency
                .iter()
                .min_by_key(|&(id, &t)| (t, id))
                .map(|(id, _)| id.clone())
                .expect("non-empty cache has a victim");
            self.recency.remove(&victim);
            if let Some(evicted) = self.store.remove(&victim) {
                self.stats.evictions += 1;
                self.stats.bytes_evicted += evicted.bytes;
            }
        }
    }

    /// Eviction-storm pressure hook: force out least-recently-used
    /// layers until at least `bytes` have been freed (or the cache is
    /// empty).  Models a co-tenant filling the node-local disk — the
    /// `CacheEvictStorm` fault — so the next deploy wave re-fetches
    /// what the storm destroyed.  Evictions are charged to
    /// [`CacheStats`] exactly like capacity evictions.  Returns
    /// `(layers_evicted, bytes_evicted)`.
    pub fn shed(&mut self, bytes: u64) -> (usize, u64) {
        let mut layers = 0usize;
        let mut freed = 0u64;
        while freed < bytes && !self.store.is_empty() {
            let victim = self
                .recency
                .iter()
                .min_by_key(|&(id, &t)| (t, id))
                .map(|(id, _)| id.clone())
                .expect("non-empty cache has a victim");
            self.recency.remove(&victim);
            if let Some(evicted) = self.store.remove(&victim) {
                self.stats.evictions += 1;
                self.stats.bytes_evicted += evicted.bytes;
                layers += 1;
                freed += evicted.bytes;
            }
        }
        (layers, freed)
    }

    /// Which of `wanted` a transfer must supply (no accounting).
    pub fn missing<'a>(&self, wanted: &'a [LayerId]) -> Vec<&'a LayerId> {
        self.store.missing(wanted)
    }

    /// The accounted form of [`missing`](Self::missing): look every id
    /// up through [`lookup`](Self::lookup) — recording hits, misses,
    /// and recency — and return the ids a transfer must supply, in
    /// `wanted` order.  One call per deploy/push wave keeps the
    /// hit-rate accounting honest without per-caller loops.
    pub fn filter_missing(&mut self, wanted: &[LayerId]) -> Vec<LayerId> {
        wanted
            .iter()
            .filter(|id| self.lookup(id).is_none())
            .cloned()
            .collect()
    }

    /// Accumulated hit/miss/eviction counters.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Bytes currently resident.
    pub fn used_bytes(&self) -> u64 {
        self.store.physical_bytes()
    }

    /// Configured byte capacity (`u64::MAX` = unbounded).
    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Number of resident layers.
    pub fn len(&self) -> usize {
        self.store.len()
    }

    /// Whether the cache holds no layers.
    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    /// Read-only view of the backing store (for runtime mounting).
    pub fn store(&self) -> &LayerStore {
        &self.store
    }

    /// Resident layer ids in recency order, least-recently-used first
    /// (ties broken by id, matching the eviction victim order).  Two
    /// caches with equal signatures hold the same content *and* evict
    /// in the same order under any future pressure — the reconvergence
    /// test node-class re-merging relies on.
    pub fn recency_signature(&self) -> Vec<LayerId> {
        let mut order: Vec<(u64, LayerId)> = self
            .recency
            .iter()
            .map(|(id, &t)| (t, id.clone()))
            .collect();
        order.sort();
        order.into_iter().map(|(_, id)| id).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::image::FileEntry;

    fn layer(tag: &str, bytes: u64) -> Layer {
        Layer::derive(
            None,
            tag,
            vec![FileEntry {
                path: format!("/{tag}"),
                bytes,
            }],
        )
    }

    #[test]
    fn hit_miss_accounting() {
        let mut c = LayerCache::unbounded();
        let a = layer("a", 100);
        assert!(c.lookup(&a.id).is_none());
        c.admit(a.clone());
        assert_eq!(c.lookup(&a.id).unwrap().bytes, 100);
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.bytes_hit, 100);
        assert_eq!(s.bytes_inserted, 100);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
        let text = s.render();
        assert!(text.contains("1/2 hit(s)"), "{text}");
        assert!(text.contains("50% hit rate"), "{text}");
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = LayerCache::new(250);
        let (a, b, d) = (layer("a", 100), layer("b", 100), layer("d", 100));
        c.admit(a.clone());
        c.admit(b.clone());
        // touch `a` so `b` becomes the LRU victim
        assert!(c.lookup(&a.id).is_some());
        c.admit(d.clone());
        assert!(c.contains(&a.id));
        assert!(!c.contains(&b.id), "LRU layer evicted");
        assert!(c.contains(&d.id));
        assert_eq!(c.stats().evictions, 1);
        assert_eq!(c.stats().bytes_evicted, 100);
        assert!(c.used_bytes() <= 250);
    }

    #[test]
    fn oversized_layer_degrades_to_pass_through() {
        let mut c = LayerCache::new(50);
        c.admit(layer("big", 500));
        assert_eq!(c.len(), 1, "oversized layer still admitted");
        c.admit(layer("big2", 600));
        assert_eq!(c.len(), 1, "previous oversized layer evicted");
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn readmit_is_free_and_refreshes_recency() {
        let mut c = LayerCache::new(250);
        let (a, b, d) = (layer("a", 100), layer("b", 100), layer("d", 100));
        c.admit(a.clone());
        c.admit(b.clone());
        c.admit(a.clone()); // refresh, not a second insert
        assert_eq!(c.stats().bytes_inserted, 200);
        c.admit(d.clone());
        assert!(!c.contains(&b.id), "b was LRU after a's refresh");
        assert!(c.contains(&a.id));
    }

    #[test]
    fn unbounded_never_evicts() {
        let mut c = LayerCache::unbounded();
        for i in 0..100 {
            c.admit(layer(&format!("l{i}"), 1 << 20));
        }
        assert_eq!(c.len(), 100);
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn shed_evicts_lru_first_and_accounts_bytes() {
        let mut c = LayerCache::unbounded();
        let (a, b, d) = (layer("a", 100), layer("b", 100), layer("d", 100));
        c.admit(a.clone());
        c.admit(b.clone());
        c.admit(d.clone());
        // touch `a` so `b` is the oldest resident
        assert!(c.lookup(&a.id).is_some());
        let (layers, freed) = c.shed(150);
        assert_eq!(layers, 2, "two 100-byte victims cover 150 bytes");
        assert_eq!(freed, 200);
        assert!(!c.contains(&b.id), "LRU victim goes first");
        assert!(!c.contains(&d.id));
        assert!(c.contains(&a.id), "recently touched layer survives");
        assert_eq!(c.stats().evictions, 2);
        assert_eq!(c.stats().bytes_evicted, 200);
    }

    #[test]
    fn shed_stops_at_empty_and_zero_is_a_no_op() {
        let mut c = LayerCache::unbounded();
        assert_eq!(c.shed(1 << 30), (0, 0), "empty cache sheds nothing");
        c.admit(layer("a", 10));
        assert_eq!(c.shed(0), (0, 0), "zero-byte storm is free");
        let (layers, freed) = c.shed(u64::MAX);
        assert_eq!((layers, freed), (1, 10));
        assert!(c.is_empty());
    }

    #[test]
    fn missing_delegates_to_store() {
        let mut c = LayerCache::unbounded();
        let a = layer("a", 1);
        let b = layer("b", 1);
        c.admit(a.clone());
        let wanted = vec![a.id.clone(), b.id.clone()];
        let miss = c.missing(&wanted);
        assert_eq!(miss, vec![&b.id]);
    }

    #[test]
    fn filter_missing_accounts_hits_and_misses() {
        let mut c = LayerCache::unbounded();
        let a = layer("a", 10);
        let b = layer("b", 20);
        c.admit(a.clone());
        let wanted = vec![a.id.clone(), b.id.clone()];
        let miss = c.filter_missing(&wanted);
        assert_eq!(miss, vec![b.id.clone()]);
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.bytes_hit, 10);
    }

    #[test]
    fn add_scaled_multiplies_every_counter() {
        let mut c = LayerCache::new(15);
        c.admit(layer("a", 10));
        c.lookup(&layer("a", 10).id);
        c.lookup(&layer("b", 20).id);
        c.admit(layer("b", 20)); // evicts a
        let delta = c.stats();
        let mut agg = CacheStats::default();
        agg.add_scaled(&delta, 1000);
        assert_eq!(agg.hits, delta.hits * 1000);
        assert_eq!(agg.misses, delta.misses * 1000);
        assert_eq!(agg.evictions, delta.evictions * 1000);
        assert_eq!(agg.bytes_hit, delta.bytes_hit * 1000);
        assert_eq!(agg.bytes_inserted, delta.bytes_inserted * 1000);
        assert_eq!(agg.bytes_evicted, delta.bytes_evicted * 1000);
        // multiplicity 1 is exactly merge
        let mut one = CacheStats::default();
        one.add_scaled(&delta, 1);
        let mut merged = CacheStats::default();
        merged.merge(&delta);
        assert_eq!(one, merged);
    }

    #[test]
    fn recency_signature_orders_lru_first() {
        let mut c = LayerCache::unbounded();
        let (a, b, d) = (layer("a", 1), layer("b", 1), layer("d", 1));
        c.admit(a.clone());
        c.admit(b.clone());
        c.admit(d.clone());
        assert!(c.lookup(&a.id).is_some()); // a becomes most recent
        assert_eq!(c.recency_signature(), vec![b.id.clone(), d.id.clone(), a.id.clone()]);
        // an identically-treated clone reconverges to the same signature
        let e = c.clone();
        assert_eq!(c.recency_signature(), e.recency_signature());
    }

    #[test]
    fn merged_stats_accumulate() {
        let mut total = CacheStats::default();
        let mut c1 = LayerCache::unbounded();
        let mut c2 = LayerCache::unbounded();
        c1.admit(layer("a", 10));
        c1.lookup(&layer("a", 10).id);
        c2.lookup(&layer("b", 20).id);
        total.merge(&c1.stats());
        total.merge(&c2.stats());
        assert_eq!((total.hits, total.misses), (1, 1));
        assert_eq!(total.bytes_inserted, 10);
    }
}
