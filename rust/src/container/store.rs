//! Content-addressed layer store — "the layered file system".
//!
//! Layers are stored by content hash, so two images `FROM` the same base
//! share its layers physically.  [`LayerStore::dedup_ratio`] quantifies
//! §2.2's compactness claim (a pipeline of images over a common base
//! stores the base once).

use std::collections::HashMap;

use super::image::{Layer, LayerId};

/// Content-addressed store of layers.
///
/// # Example
///
/// Two inserts of identical content store one physical copy; the
/// logical/physical ratio quantifies the sharing:
///
/// ```
/// use harbor::container::image::{FileEntry, Layer};
/// use harbor::container::LayerStore;
///
/// let base = Layer::derive(
///     None,
///     "FROM ubuntu:16.04",
///     vec![FileEntry { path: "/bin/sh".into(), bytes: 100 }],
/// );
/// let mut store = LayerStore::new();
/// assert!(store.insert(base.clone()));   // new content
/// assert!(!store.insert(base.clone()));  // dedup: same hash, no new copy
/// assert_eq!(store.len(), 1);
/// assert_eq!(store.physical_bytes(), 100);
/// assert_eq!(store.logical_bytes(), 200);
/// assert!(store.dedup_ratio() > 1.9);
/// ```
#[derive(Debug, Clone, Default)]
pub struct LayerStore {
    layers: HashMap<LayerId, Layer>,
    /// Total logical bytes ever inserted (including duplicates).
    logical_bytes: u64,
    /// Bytes currently resident (kept in sync by insert/remove so
    /// `physical_bytes` is O(1) — cache eviction loops poll it).
    resident_bytes: u64,
    inserts: u64,
}

impl LayerStore {
    /// An empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert a layer; returns `true` if it was new (a store miss).
    pub fn insert(&mut self, layer: Layer) -> bool {
        self.logical_bytes += layer.bytes;
        self.inserts += 1;
        let bytes = layer.bytes;
        match self.layers.insert(layer.id.clone(), layer) {
            None => {
                self.resident_bytes += bytes;
                true
            }
            // same content hash ⇒ same bytes; resident total unchanged
            Some(_) => false,
        }
    }

    /// The layer stored under `id`, if present.
    pub fn get(&self, id: &LayerId) -> Option<&Layer> {
        self.layers.get(id)
    }

    /// Whether `id` is resident.
    pub fn contains(&self, id: &LayerId) -> bool {
        self.layers.contains_key(id)
    }

    /// Physical bytes actually stored (deduplicated). O(1).
    pub fn physical_bytes(&self) -> u64 {
        self.resident_bytes
    }

    /// Logical bytes inserted over the store's lifetime.
    pub fn logical_bytes(&self) -> u64 {
        self.logical_bytes
    }

    /// logical / physical; > 1 means sharing is paying off.
    pub fn dedup_ratio(&self) -> f64 {
        let p = self.physical_bytes();
        if p == 0 {
            1.0
        } else {
            self.logical_bytes as f64 / p as f64
        }
    }

    /// Number of resident layers.
    pub fn len(&self) -> usize {
        self.layers.len()
    }

    /// Whether the store holds no layers.
    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Which of `wanted` are *not* present (what a pull must transfer).
    pub fn missing<'a>(&self, wanted: &'a [LayerId]) -> Vec<&'a LayerId> {
        wanted.iter().filter(|id| !self.contains(id)).collect()
    }

    /// Remove a layer (cache eviction); returns it if it was present.
    /// Lifetime counters (`logical_bytes`, insert count) are monotone
    /// and unaffected — only the resident set shrinks.
    pub fn remove(&mut self, id: &LayerId) -> Option<Layer> {
        let removed = self.layers.remove(id);
        if let Some(layer) = &removed {
            self.resident_bytes -= layer.bytes;
        }
        removed
    }

    /// Ids of all resident layers (unspecified order).
    pub fn ids(&self) -> impl Iterator<Item = &LayerId> {
        self.layers.keys()
    }

    /// Garbage-collect: drop every resident layer `keep` rejects,
    /// returning `(layers_freed, bytes_freed)`.  A build farm calls
    /// this between passes with "reachable from a pushed image" as the
    /// predicate — intermediate stage layers that no image references
    /// are the collectable garbage.  Lifetime counters are monotone
    /// and unaffected, exactly as with [`remove`](Self::remove).
    pub fn retain(&mut self, keep: impl Fn(&LayerId) -> bool) -> (usize, u64) {
        let mut freed = 0usize;
        let mut bytes = 0u64;
        self.layers.retain(|id, layer| {
            if keep(id) {
                true
            } else {
                freed += 1;
                bytes += layer.bytes;
                false
            }
        });
        self.resident_bytes -= bytes;
        (freed, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::image::FileEntry;

    fn layer(tag: &str, bytes: u64) -> Layer {
        Layer::derive(
            None,
            tag,
            vec![FileEntry {
                path: format!("/{tag}"),
                bytes,
            }],
        )
    }

    #[test]
    fn insert_dedups_by_content() {
        let mut s = LayerStore::new();
        assert!(s.insert(layer("a", 100)));
        assert!(!s.insert(layer("a", 100))); // identical content: miss=false
        assert!(s.insert(layer("b", 50)));
        assert_eq!(s.len(), 2);
        assert_eq!(s.physical_bytes(), 150);
        assert_eq!(s.logical_bytes(), 250);
        assert!((s.dedup_ratio() - 250.0 / 150.0).abs() < 1e-12);
    }

    #[test]
    fn missing_reports_what_a_pull_needs() {
        let mut s = LayerStore::new();
        let a = layer("a", 1);
        let b = layer("b", 1);
        s.insert(a.clone());
        let wanted = vec![a.id.clone(), b.id.clone()];
        let miss = s.missing(&wanted);
        assert_eq!(miss.len(), 1);
        assert_eq!(miss[0], &b.id);
    }

    #[test]
    fn empty_store() {
        let s = LayerStore::new();
        assert!(s.is_empty());
        assert_eq!(s.dedup_ratio(), 1.0);
        assert_eq!(s.physical_bytes(), 0);
    }

    #[test]
    fn physical_bytes_counter_stays_consistent() {
        let mut s = LayerStore::new();
        let a = layer("a", 100);
        let b = layer("b", 50);
        s.insert(a.clone());
        s.insert(a.clone()); // duplicate content: resident unchanged
        s.insert(b);
        assert_eq!(s.physical_bytes(), 150);
        s.remove(&a.id);
        assert_eq!(s.physical_bytes(), 50);
        s.remove(&a.id); // double-remove is a no-op
        assert_eq!(s.physical_bytes(), 50);
        s.insert(a);
        assert_eq!(s.physical_bytes(), 150);
    }

    #[test]
    fn remove_and_ids() {
        let mut s = LayerStore::new();
        let a = layer("a", 5);
        s.insert(a.clone());
        assert_eq!(s.ids().collect::<Vec<_>>(), vec![&a.id]);
        let back = s.remove(&a.id).unwrap();
        assert_eq!(back.bytes, 5);
        assert!(s.is_empty());
        assert_eq!(s.logical_bytes(), 5); // lifetime counter is monotone
        assert!(s.remove(&a.id).is_none());
    }

    #[test]
    fn retain_frees_unreachable_layers() {
        let mut s = LayerStore::new();
        let a = layer("a", 100);
        let b = layer("b", 50);
        let c = layer("c", 25);
        s.insert(a.clone());
        s.insert(b.clone());
        s.insert(c.clone());
        let (freed, bytes) = s.retain(|id| *id == a.id);
        assert_eq!((freed, bytes), (2, 75));
        assert!(s.contains(&a.id));
        assert_eq!(s.physical_bytes(), 100);
        assert_eq!(s.logical_bytes(), 175, "lifetime counter is monotone");
        // retaining everything is a no-op
        assert_eq!(s.retain(|_| true), (0, 0));
    }

    #[test]
    fn get_round_trips() {
        let mut s = LayerStore::new();
        let l = layer("x", 7);
        s.insert(l.clone());
        assert_eq!(s.get(&l.id).unwrap().bytes, 7);
        assert!(s.get(&LayerId("nope".into())).is_none());
    }
}
