//! Content-addressed layer store — "the layered file system".
//!
//! Layers are stored by content hash, so two images `FROM` the same base
//! share its layers physically.  [`LayerStore::dedup_ratio`] quantifies
//! §2.2's compactness claim (a pipeline of images over a common base
//! stores the base once).

use std::collections::HashMap;

use super::image::{Layer, LayerId};

/// Content-addressed store of layers.
#[derive(Debug, Clone, Default)]
pub struct LayerStore {
    layers: HashMap<LayerId, Layer>,
    /// Total logical bytes ever inserted (including duplicates).
    logical_bytes: u64,
    inserts: u64,
}

impl LayerStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert a layer; returns `true` if it was new (a store miss).
    pub fn insert(&mut self, layer: Layer) -> bool {
        self.logical_bytes += layer.bytes;
        self.inserts += 1;
        self.layers.insert(layer.id.clone(), layer).is_none()
    }

    pub fn get(&self, id: &LayerId) -> Option<&Layer> {
        self.layers.get(id)
    }

    pub fn contains(&self, id: &LayerId) -> bool {
        self.layers.contains_key(id)
    }

    /// Physical bytes actually stored (deduplicated).
    pub fn physical_bytes(&self) -> u64 {
        self.layers.values().map(|l| l.bytes).sum()
    }

    /// Logical bytes inserted over the store's lifetime.
    pub fn logical_bytes(&self) -> u64 {
        self.logical_bytes
    }

    /// logical / physical; > 1 means sharing is paying off.
    pub fn dedup_ratio(&self) -> f64 {
        let p = self.physical_bytes();
        if p == 0 {
            1.0
        } else {
            self.logical_bytes as f64 / p as f64
        }
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Which of `wanted` are *not* present (what a pull must transfer).
    pub fn missing<'a>(&self, wanted: &'a [LayerId]) -> Vec<&'a LayerId> {
        wanted.iter().filter(|id| !self.contains(id)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::image::FileEntry;

    fn layer(tag: &str, bytes: u64) -> Layer {
        Layer::derive(
            None,
            tag,
            vec![FileEntry {
                path: format!("/{tag}"),
                bytes,
            }],
        )
    }

    #[test]
    fn insert_dedups_by_content() {
        let mut s = LayerStore::new();
        assert!(s.insert(layer("a", 100)));
        assert!(!s.insert(layer("a", 100))); // identical content: miss=false
        assert!(s.insert(layer("b", 50)));
        assert_eq!(s.len(), 2);
        assert_eq!(s.physical_bytes(), 150);
        assert_eq!(s.logical_bytes(), 250);
        assert!((s.dedup_ratio() - 250.0 / 150.0).abs() < 1e-12);
    }

    #[test]
    fn missing_reports_what_a_pull_needs() {
        let mut s = LayerStore::new();
        let a = layer("a", 1);
        let b = layer("b", 1);
        s.insert(a.clone());
        let wanted = vec![a.id.clone(), b.id.clone()];
        let miss = s.missing(&wanted);
        assert_eq!(miss.len(), 1);
        assert_eq!(miss[0], &b.id);
    }

    #[test]
    fn empty_store() {
        let s = LayerStore::new();
        assert!(s.is_empty());
        assert_eq!(s.dedup_ratio(), 1.0);
        assert_eq!(s.physical_bytes(), 0);
    }

    #[test]
    fn get_round_trips() {
        let mut s = LayerStore::new();
        let l = layer("x", 7);
        s.insert(l.clone());
        assert_eq!(s.get(&l.id).unwrap().bytes, 7);
        assert!(s.get(&LayerId("nope".into())).is_none());
    }
}
