//! Container lifecycle: the state machine a runtime drives.
//!
//! Mirrors §2.1's image/container distinction: a [`Container`] is a
//! runtime instantiation of an image, with its own (thin) writable layer
//! and a Created → Running → Exited life, timestamped in virtual time.

use crate::des::VirtualTime;

use super::image::ImageId;

/// Lifecycle states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ContainerState {
    /// Instantiated but not started.
    Created,
    /// Entrypoint running.
    Running,
    /// Finished with an exit code.
    Exited {
        /// Process exit code (0 = success).
        code: i32,
    },
}

/// A runtime instantiation of an image.
#[derive(Debug, Clone)]
pub struct Container {
    /// Runtime-assigned container id.
    pub id: u64,
    /// Image this container instantiates.
    pub image: ImageId,
    /// Current lifecycle state.
    pub state: ContainerState,
    /// When the container was created.
    pub created_at: VirtualTime,
    /// When it entered `Running`, if ever.
    pub started_at: Option<VirtualTime>,
    /// When it exited, if finished.
    pub exited_at: Option<VirtualTime>,
    /// Bytes written to the container's writable layer.
    pub scratch_bytes: u64,
    /// Commands exec'd inside (provenance for experiment traces).
    pub exec_log: Vec<String>,
}

/// Invalid state transition.
#[derive(Debug, PartialEq, Eq)]
pub struct StateError {
    /// State the container was in.
    pub from: &'static str,
    /// Action that was attempted.
    pub action: &'static str,
}
impl std::fmt::Display for StateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cannot {} a container in state {}", self.action, self.from)
    }
}
impl std::error::Error for StateError {}

impl Container {
    /// A new container in the `Created` state.
    pub fn create(id: u64, image: ImageId, at: VirtualTime) -> Self {
        Container {
            id,
            image,
            state: ContainerState::Created,
            created_at: at,
            started_at: None,
            exited_at: None,
            scratch_bytes: 0,
            exec_log: Vec::new(),
        }
    }

    /// Created → Running.
    pub fn start(&mut self, at: VirtualTime) -> Result<(), StateError> {
        match self.state {
            ContainerState::Created => {
                self.state = ContainerState::Running;
                self.started_at = Some(at);
                Ok(())
            }
            ContainerState::Running => Err(StateError {
                from: "running",
                action: "start",
            }),
            ContainerState::Exited { .. } => Err(StateError {
                from: "exited",
                action: "start",
            }),
        }
    }

    /// Record a command exec'd inside a running container.
    pub fn exec(&mut self, cmd: &str) -> Result<(), StateError> {
        if self.state != ContainerState::Running {
            return Err(StateError {
                from: self.state_name(),
                action: "exec in",
            });
        }
        self.exec_log.push(cmd.to_string());
        Ok(())
    }

    /// Running → Exited with `code`.
    pub fn exit(&mut self, code: i32, at: VirtualTime) -> Result<(), StateError> {
        if self.state != ContainerState::Running {
            return Err(StateError {
                from: self.state_name(),
                action: "stop",
            });
        }
        self.state = ContainerState::Exited { code };
        self.exited_at = Some(at);
        Ok(())
    }

    /// Account bytes written to the writable layer.
    pub fn write_scratch(&mut self, bytes: u64) {
        self.scratch_bytes += bytes;
    }

    fn state_name(&self) -> &'static str {
        match self.state {
            ContainerState::Created => "created",
            ContainerState::Running => "running",
            ContainerState::Exited { .. } => "exited",
        }
    }

    /// Wall time spent running (if finished).
    pub fn runtime(&self) -> Option<crate::des::Duration> {
        Some(self.exited_at? - self.started_at?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::Duration;

    fn t(ms: u64) -> VirtualTime {
        VirtualTime::ZERO + Duration::from_millis(ms)
    }

    fn new_container() -> Container {
        Container::create(1, ImageId("abc".into()), t(0))
    }

    #[test]
    fn happy_path() {
        let mut c = new_container();
        assert_eq!(c.state, ContainerState::Created);
        c.start(t(10)).unwrap();
        c.exec("./demo_poisson").unwrap();
        c.exit(0, t(500)).unwrap();
        assert_eq!(c.state, ContainerState::Exited { code: 0 });
        assert_eq!(c.runtime(), Some(Duration::from_millis(490)));
        assert_eq!(c.exec_log, vec!["./demo_poisson"]);
    }

    #[test]
    fn cannot_start_twice() {
        let mut c = new_container();
        c.start(t(1)).unwrap();
        assert!(c.start(t(2)).is_err());
    }

    #[test]
    fn cannot_exec_before_start() {
        let mut c = new_container();
        let err = c.exec("ls").unwrap_err();
        assert_eq!(err.from, "created");
    }

    #[test]
    fn cannot_stop_created() {
        let mut c = new_container();
        assert!(c.exit(0, t(1)).is_err());
    }

    #[test]
    fn cannot_restart_exited() {
        let mut c = new_container();
        c.start(t(1)).unwrap();
        c.exit(1, t(2)).unwrap();
        assert!(c.start(t(3)).is_err());
        assert!(c.exec("x").is_err());
    }

    #[test]
    fn scratch_accounting() {
        let mut c = new_container();
        c.write_scratch(4096);
        c.write_scratch(100);
        assert_eq!(c.scratch_bytes, 4196);
    }
}
