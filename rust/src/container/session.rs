//! The `fenicsproject` wrapper (§3.2): user-friendly workflows over the
//! raw container runtime.
//!
//! The paper's wrapper script hides the Docker CLI's sharp edges behind
//! three workflows the tutorials use: `notebook` (a Jupyter session with
//! port mapping and a shared volume), `start`/`stop` (a persistent named
//! project container), and `run` (one-shot command).  [`SessionManager`]
//! reproduces those semantics — named sessions, persistence across
//! start/stop, shared-volume bookkeeping, port allocation — on top of
//! [`super::lifecycle`] and the runtime adapters, in virtual time.

use std::collections::HashMap;

use crate::des::{Duration, VirtualTime};

use super::image::Image;
use super::lifecycle::{Container, ContainerState};
use super::runtime::{by_kind, RuntimeKind};

/// What kind of session a project runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionKind {
    /// `fenicsproject notebook <name>`: Jupyter + port map.
    Notebook,
    /// `fenicsproject start <name>`: interactive shell container.
    Shell,
}

/// One named project session.
#[derive(Debug)]
pub struct Session {
    /// Session name (the `fenicsproject <cmd> <name>` argument).
    pub name: String,
    /// Notebook or plain session.
    pub kind: SessionKind,
    /// The backing container (holds the writable layer).
    pub container: Container,
    /// Host port mapped to the container's 8888 (notebooks only).
    pub port: Option<u16>,
    /// Host path shared at /home/fenics/shared.
    pub shared_volume: String,
    /// Times the session was resumed (`start` after `stop`).
    pub resumes: u32,
}

/// Errors the wrapper reports to users.
#[derive(Debug, PartialEq, Eq)]
pub enum SessionError {
    /// A session of that name already exists.
    AlreadyExists(String),
    /// No session of that name.
    NoSuchSession(String),
    /// The session is not running.
    NotRunning(String),
    /// The session is already running.
    AlreadyRunning(String),
    /// All notebook ports are taken.
    NoFreePorts,
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionError::AlreadyExists(n) => {
                write!(f, "project `{n}` already exists (use start to resume)")
            }
            SessionError::NoSuchSession(n) => write!(f, "no project named `{n}`"),
            SessionError::NotRunning(n) => write!(f, "project `{n}` is not running"),
            SessionError::AlreadyRunning(n) => write!(f, "project `{n}` is already running"),
            SessionError::NoFreePorts => write!(f, "no free ports in the notebook range"),
        }
    }
}
impl std::error::Error for SessionError {}

/// The `fenicsproject` wrapper state (one per user machine).
pub struct SessionManager {
    image: Image,
    runtime: RuntimeKind,
    sessions: HashMap<String, Session>,
    next_id: u64,
    ports: Vec<u16>,
    clock: VirtualTime,
}

impl SessionManager {
    /// A manager running sessions of `image` under `runtime`.
    pub fn new(image: Image, runtime: RuntimeKind) -> Self {
        SessionManager {
            image,
            runtime,
            sessions: HashMap::new(),
            next_id: 1,
            // the wrapper allocates 127.0.0.1:8888.. upward
            ports: (8888..8898).collect(),
            clock: VirtualTime::ZERO,
        }
    }

    /// The manager's virtual clock.
    pub fn now(&self) -> VirtualTime {
        self.clock
    }

    fn advance(&mut self, d: Duration) {
        self.clock += d;
    }

    /// `fenicsproject notebook <name> [dir]`
    pub fn notebook(&mut self, name: &str, host_dir: &str) -> Result<&Session, SessionError> {
        self.create(name, SessionKind::Notebook, host_dir)
    }

    /// `fenicsproject create <name>` + `start`
    pub fn start_new(&mut self, name: &str, host_dir: &str) -> Result<&Session, SessionError> {
        self.create(name, SessionKind::Shell, host_dir)
    }

    fn create(
        &mut self,
        name: &str,
        kind: SessionKind,
        host_dir: &str,
    ) -> Result<&Session, SessionError> {
        if self.sessions.contains_key(name) {
            return Err(SessionError::AlreadyExists(name.to_string()));
        }
        let port = match kind {
            SessionKind::Notebook => Some(self.ports.pop().ok_or(SessionError::NoFreePorts)?),
            SessionKind::Shell => None,
        };
        let rt = by_kind(self.runtime);
        let start_cost = rt.startup_overhead(&self.image);
        let mut container = Container::create(self.next_id, self.image.id.clone(), self.clock);
        self.next_id += 1;
        self.advance(start_cost);
        container.start(self.clock).expect("fresh container starts");
        if kind == SessionKind::Notebook {
            container
                .exec("jupyter-notebook --ip=0.0.0.0")
                .expect("running container");
            // jupyter's own startup
            self.advance(Duration::from_millis(1800));
        }
        let session = Session {
            name: name.to_string(),
            kind,
            container,
            port,
            shared_volume: host_dir.to_string(),
            resumes: 0,
        };
        self.sessions.insert(name.to_string(), session);
        Ok(&self.sessions[name])
    }

    /// `fenicsproject stop <name>` — persists state (the writable layer
    /// survives; docker `stop`, not `rm`).
    pub fn stop(&mut self, name: &str) -> Result<(), SessionError> {
        self.advance(Duration::from_millis(300));
        let now = self.clock;
        let s = self
            .sessions
            .get_mut(name)
            .ok_or_else(|| SessionError::NoSuchSession(name.to_string()))?;
        s.container
            .exit(0, now)
            .map_err(|_| SessionError::NotRunning(name.to_string()))
    }

    /// `fenicsproject start <name>` — resume a stopped project.
    pub fn start(&mut self, name: &str) -> Result<(), SessionError> {
        self.advance(Duration::from_millis(350));
        let now = self.clock;
        let next_id = self.next_id;
        let s = self
            .sessions
            .get_mut(name)
            .ok_or_else(|| SessionError::NoSuchSession(name.to_string()))?;
        match s.container.state {
            ContainerState::Running => Err(SessionError::AlreadyRunning(name.to_string())),
            _ => {
                // docker start reuses the same container (and its
                // writable layer); we model that as a fresh lifecycle
                // that inherits scratch bytes
                let scratch = s.container.scratch_bytes;
                let mut c = Container::create(next_id, s.container.image.clone(), now);
                c.start(now).expect("fresh container starts");
                c.scratch_bytes = scratch;
                s.container = c;
                s.resumes += 1;
                self.next_id += 1;
                Ok(())
            }
        }
    }

    /// Run a command inside a running session.
    pub fn exec(&mut self, name: &str, cmd: &str) -> Result<(), SessionError> {
        let s = self
            .sessions
            .get_mut(name)
            .ok_or_else(|| SessionError::NoSuchSession(name.to_string()))?;
        s.container
            .exec(cmd)
            .map_err(|_| SessionError::NotRunning(name.to_string()))
    }

    /// The notebook URL the wrapper prints for the user.
    pub fn notebook_url(&self, name: &str) -> Option<String> {
        let s = self.sessions.get(name)?;
        s.port.map(|p| format!("http://127.0.0.1:{p}/?token=fenics"))
    }

    /// `(name, state)` pairs, sorted by name (the `list` command).
    pub fn list(&self) -> Vec<(&str, &'static str)> {
        let mut out: Vec<_> = self
            .sessions
            .values()
            .map(|s| {
                let state = match s.container.state {
                    ContainerState::Running => "running",
                    ContainerState::Created => "created",
                    ContainerState::Exited { .. } => "stopped",
                };
                (s.name.as_str(), state)
            })
            .collect();
        out.sort();
        out
    }

    /// Look a session up by name.
    pub fn get(&self, name: &str) -> Option<&Session> {
        self.sessions.get(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::fenics_image;

    fn manager() -> SessionManager {
        let (image, _) = fenics_image();
        SessionManager::new(image, RuntimeKind::Docker)
    }

    #[test]
    fn notebook_workflow() {
        let mut m = manager();
        let s = m.notebook("my-project", "/home/user/work").unwrap();
        assert_eq!(s.kind, SessionKind::Notebook);
        assert_eq!(s.port, Some(8897)); // allocated from the top
        assert_eq!(s.container.state, ContainerState::Running);
        assert_eq!(s.container.exec_log[0], "jupyter-notebook --ip=0.0.0.0");
        assert!(m.notebook_url("my-project").unwrap().contains("8897"));
        // startup (docker + jupyter) took simulated seconds
        assert!(m.now().as_secs_f64() > 1.0);
    }

    #[test]
    fn start_stop_resume_persists() {
        let mut m = manager();
        m.start_new("thesis", "/home/user/thesis").unwrap();
        m.exec("thesis", "python demo.py").unwrap();
        m.sessions.get_mut("thesis").unwrap().container.write_scratch(4096);
        m.stop("thesis").unwrap();
        assert_eq!(m.list(), vec![("thesis", "stopped")]);
        m.start("thesis").unwrap();
        let s = m.get("thesis").unwrap();
        assert_eq!(s.container.state, ContainerState::Running);
        assert_eq!(s.resumes, 1);
        assert_eq!(s.container.scratch_bytes, 4096, "writable layer persisted");
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut m = manager();
        m.start_new("p", "/w").unwrap();
        assert_eq!(
            m.start_new("p", "/w").unwrap_err(),
            SessionError::AlreadyExists("p".into())
        );
    }

    #[test]
    fn lifecycle_errors_are_user_errors() {
        let mut m = manager();
        assert!(matches!(m.stop("ghost"), Err(SessionError::NoSuchSession(_))));
        m.start_new("p", "/w").unwrap();
        assert!(matches!(m.start("p"), Err(SessionError::AlreadyRunning(_))));
        m.stop("p").unwrap();
        assert!(matches!(m.stop("p"), Err(SessionError::NotRunning(_))));
        assert!(matches!(m.exec("p", "ls"), Err(SessionError::NotRunning(_))));
    }

    #[test]
    fn ports_are_finite_and_unique() {
        let mut m = manager();
        let mut ports = std::collections::HashSet::new();
        for i in 0..10 {
            let s = m.notebook(&format!("n{i}"), "/w").unwrap();
            assert!(ports.insert(s.port.unwrap()));
        }
        assert!(matches!(
            m.notebook("overflow", "/w"),
            Err(SessionError::NoFreePorts)
        ));
    }

    #[test]
    fn shell_sessions_have_no_port() {
        let mut m = manager();
        m.start_new("s", "/w").unwrap();
        assert_eq!(m.get("s").unwrap().port, None);
        assert!(m.notebook_url("s").is_none());
    }
}
