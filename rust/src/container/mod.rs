//! The container substrate.
//!
//! Everything §2–§3 of the paper describes is implemented here as a
//! simulated-but-mechanically-faithful stack:
//!
//! * [`image`] — immutable images built from content-addressed layers;
//!   every layer and image carries the sha256 of its build inputs, so
//!   identical builds dedup and "every image is associated with a
//!   mathematical hash" (§3.1) holds literally.
//! * [`store`] — the layered file system: a content-addressed store in
//!   which shared base layers are stored once (§2.2's compactness
//!   argument is measurable via [`store::LayerStore::dedup_ratio`]).
//! * [`buildfile`] — parser for the Dockerfile-like build DSL
//!   (`FROM [... AS <stage>]` / `RUN` / `ENV` / `COPY [--from=<stage>]`
//!   / `USER` / `WORKDIR` / `ENTRYPOINT` / `LABEL` / `ARCH_OPT`);
//!   multi-stage files parse into a stage-dependency DAG.
//! * [`builder`] — executes a buildfile into an image: a
//!   [`BuildGraph`] planner walks the stage DAG in topological order,
//!   every layer is keyed by a content hash of (parent chain,
//!   cache-canonical directive, `COPY --from` source digests) — the
//!   same cache rule Docker uses — and non-terminal stages are pruned
//!   from the final image.
//! * [`registry`] — a quay.io-like registry: push/pull move only the
//!   layers the other side is missing, with transfer times from a
//!   bandwidth model (pull times show up in the deployment pipeline
//!   example and coordinator traces).
//! * [`cache`] — the node-local tier between registry and runtime: a
//!   bounded, LRU-evicting [`LayerCache`] per compute node with
//!   hit/miss/eviction accounting.
//! * [`distribute`] — fleet-scale layer distribution: the registry
//!   sharded behind per-shard FIFO frontends, DES-scheduled concurrent
//!   pulls, and Trow-style peer fan-out so a layer crosses the WAN once
//!   and rides the cluster fabric to thousands of nodes.
//! * [`protocol`] — the registry front door: the OCI distribution API
//!   as sessions — per-upload UUIDs, chunked resumable transfers with
//!   byte-range progress, retry-after-disconnect resume — multiplexed
//!   onto the sharded frontends and interruptible per session by a
//!   fault schedule.
//! * [`resolve`] — the package-resolver tier: semver ranges resolved
//!   against a published package index into a byte-stable lockfile,
//!   emitted as multi-stage buildfiles the builder consumes unchanged;
//!   a lockfile diff predicts exactly which stages a version bump
//!   rebuilds.
//! * [`lifecycle`] — the container state machine (Created → Running →
//!   Exited) a runtime drives.
//! * [`session`] — the `fenicsproject` wrapper script (§3.2): notebook /
//!   start / stop workflows over the raw runtime.
//! * [`runtime`] — the four runtime adapters the paper benchmarks:
//!   Docker, rkt, Shifter, and a VirtualBox-style VM, each expressed as
//!   the overheads/filesystem/MPI-resolution behaviours that distinguish
//!   them in the figures.

pub mod buildfile;
pub mod builder;
pub mod cache;
pub mod distribute;
pub mod image;
pub mod lifecycle;
pub mod protocol;
pub mod registry;
pub mod resolve;
pub mod runtime;
pub mod session;
pub mod store;

pub use buildfile::{Buildfile, Directive, Stage};
pub use builder::{BuildGraph, BuildReport, Builder};
pub use cache::{CacheStats, LayerCache};
pub use distribute::{
    ClassFleet, DeployEngine, FanOut, Fleet, FleetConfig, FleetReport, NodeClass, NodeSet,
    RetryPolicy, ShardAttempt, ShardedRegistry,
};
pub use image::{Image, ImageId, Layer, LayerId};
pub use lifecycle::{Container, ContainerState};
pub use protocol::{
    FrontDoor, FrontDoorReport, SessionId, SessionRequest, TransferKind, TransferSession,
};
pub use registry::{PullReport, Registry};
pub use resolve::{
    Lockfile, Manifest, PackageCache, PackageIndex, Range, Resolution, ResolveError, Version,
};
pub use runtime::{ContainerRuntime, RuntimeKind};
pub use session::{SessionKind, SessionManager};
pub use store::LayerStore;
