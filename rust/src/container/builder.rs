//! Image builder: executes a buildfile into an image, as a stage DAG.
//!
//! We cannot run real shell commands, so `RUN` effects are *modelled*
//! deterministically: the builder recognises package-manager invocations
//! (`apt-get install`, `pip install`) and synthesises a plausible file
//! manifest per package (count and bytes derived from the package name's
//! hash), which is exactly the information the rest of the system needs
//! (layer sizes for pull-time, file counts for the import problem).  The
//! synthesis is a pure function of the directive text, so the layer
//! *cache* behaves exactly like Docker's: same parent + same directive
//! ⇒ same layer id ⇒ cache hit.
//!
//! Multi-stage buildfiles parse into a stage-dependency DAG
//! ([`BuildGraph`]): a stage depends on the stage its `FROM` continues
//! and on every stage its `COPY --from=` reads.  The builder walks the
//! DAG in topological order, skips stages the target does not need, and
//! seals only the **terminal** stage's layers into the image — earlier
//! stages' layers stay in the [`LayerStore`] as build cache but are
//! pruned from what gets pushed and pulled.
//!
//! Every layer is keyed by a content hash of its full build inputs:
//! the parent chain (the parent's [`LayerId`] commits to it
//! recursively), the directive's *cache-canonical* text, and — for
//! `COPY --from` — the **digest of the source stage's final layer**, so
//! renaming a stage never invalidates the cache but changing what the
//! source stage produces always does.
//!
//! Base images come from a small built-in catalogue (the `ubuntu:16.04`
//! and FEniCS-stack bases the paper uses).

use std::collections::HashMap;
use std::sync::Arc;

use sha2::{Digest, Sha256};

use super::buildfile::{Buildfile, Directive, resolve_among};
use super::image::{FileEntry, Image, Layer, LayerId};
use super::store::LayerStore;
use crate::des::Duration;

/// The stage-dependency DAG of a buildfile: which stages feed which,
/// how deep every stage sits, and which stages the terminal stage
/// actually needs.  Acyclic by construction — the parser only accepts
/// backward stage references.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BuildGraph {
    deps: Vec<Vec<usize>>,
    levels: Vec<usize>,
    needed: Vec<bool>,
}

impl BuildGraph {
    /// Plan the stage DAG of `bf`: resolve every `FROM <stage>` and
    /// `COPY --from=` edge, compute dependency levels, and mark the
    /// stages reachable from the terminal (last) stage.
    pub fn plan(bf: &Buildfile) -> BuildGraph {
        let stages = bf.stages();
        let names: Vec<Option<&str>> = stages.iter().map(|s| s.name).collect();
        let mut deps: Vec<Vec<usize>> = vec![Vec::new(); stages.len()];
        for s in &stages {
            let earlier = &names[..s.index];
            let mut d = Vec::new();
            if let Some(base) = resolve_among(earlier, s.base) {
                d.push(base);
            }
            for dir in s.directives {
                if let Directive::Copy { from: Some(f), .. } = dir {
                    if let Some(src) = resolve_among(earlier, f) {
                        d.push(src);
                    }
                }
            }
            d.sort_unstable();
            d.dedup();
            deps[s.index] = d;
        }
        // deps point strictly backwards, so index order is topological
        let mut levels = vec![0usize; deps.len()];
        for i in 0..deps.len() {
            levels[i] = deps[i].iter().map(|&d| levels[d] + 1).max().unwrap_or(0);
        }
        let mut needed = vec![false; deps.len()];
        if let Some(last) = deps.len().checked_sub(1) {
            let mut stack = vec![last];
            while let Some(i) = stack.pop() {
                if !needed[i] {
                    needed[i] = true;
                    stack.extend(deps[i].iter().copied());
                }
            }
        }
        BuildGraph {
            deps,
            levels,
            needed,
        }
    }

    /// Number of stages in the graph.
    pub fn stage_count(&self) -> usize {
        self.deps.len()
    }

    /// The stages `stage` depends on (sorted, deduplicated).
    pub fn deps(&self, stage: usize) -> &[usize] {
        &self.deps[stage]
    }

    /// Dependency depth of `stage` (0 = no stage dependencies).
    pub fn level(&self, stage: usize) -> usize {
        self.levels[stage]
    }

    /// Whether the terminal stage (transitively) needs `stage`.
    pub fn is_needed(&self, stage: usize) -> bool {
        self.needed[stage]
    }

    /// Needed stages grouped by level — each wave's stages have all
    /// their dependencies in earlier waves, so a parallel builder can
    /// run a whole wave concurrently.
    pub fn schedule(&self) -> Vec<Vec<usize>> {
        let max_level = self
            .levels
            .iter()
            .enumerate()
            .filter(|&(i, _)| self.needed[i])
            .map(|(_, &l)| l)
            .max();
        let Some(max_level) = max_level else {
            return Vec::new();
        };
        let mut waves: Vec<Vec<usize>> = vec![Vec::new(); max_level + 1];
        for i in 0..self.deps.len() {
            if self.needed[i] {
                waves[self.levels[i]].push(i);
            }
        }
        waves.retain(|w| !w.is_empty());
        waves
    }

    /// The longest dependency chain through the needed stages, given
    /// each stage's build cost — the makespan of a builder with
    /// unlimited stage parallelism (what a CI farm worker running
    /// stages concurrently pays, vs the serial `build_time`).
    pub fn critical_path(&self, stage_times: &[Duration]) -> Duration {
        let mut finish = vec![Duration::ZERO; self.deps.len()];
        for i in 0..self.deps.len() {
            if !self.needed[i] {
                continue;
            }
            let ready = self.deps[i]
                .iter()
                .map(|&d| finish[d])
                .fold(Duration::ZERO, Duration::max);
            finish[i] = ready + stage_times.get(i).copied().unwrap_or(Duration::ZERO);
        }
        finish.last().copied().unwrap_or(Duration::ZERO)
    }
}

/// Result of a build: the image plus provenance/caching info.
#[derive(Debug, Clone)]
pub struct BuildReport {
    /// The built image (terminal stage only; earlier stages pruned).
    pub image: Image,
    /// Layers that were produced by this build (vs. cache hits).
    pub layers_built: usize,
    /// Directives answered from the layer cache.
    pub layers_cached: usize,
    /// Modelled wall time of a *serial* build: the sum of every built
    /// layer's cost across all needed stages.
    pub build_time: Duration,
    /// Modelled wall time of a *stage-parallel* build: the longest
    /// dependency chain of per-stage costs (see
    /// [`BuildGraph::critical_path`]).  Equals `build_time` for
    /// single-stage files.
    pub critical_path: Duration,
    /// Stages executed (reachable from the terminal stage).
    pub stages_built: usize,
    /// Stages skipped as unreachable from the terminal stage.
    pub stages_skipped: usize,
    /// Per-stage build cost, indexed by stage (zero for skipped
    /// stages and for fully-cached stages).
    pub stage_times: Vec<Duration>,
    /// The stage DAG the build was scheduled from.
    pub graph: BuildGraph,
}

/// Everything a finished stage hands to the stages that depend on it.
#[derive(Debug, Clone, Default)]
struct StageState {
    layers: Vec<LayerId>,
    env: Vec<(String, String)>,
    labels: Vec<(String, String)>,
    entrypoint: Option<String>,
    arch_optimized: bool,
    time: Duration,
}

/// Builds images into a shared [`LayerStore`], with Docker-style layer
/// caching keyed on (parent id, cache-canonical directive text).
///
/// Cloning a builder forks its cache (see [`fork`](Builder::fork));
/// [`absorb`](Builder::absorb) merges a fork back — the pair is what a
/// build farm uses to commit a worker's cache entries only when its
/// build completes.
#[derive(Debug, Default, Clone)]
pub struct Builder {
    /// (parent id, cache-canonical directive) → the full cached layer.
    /// Holding the `Layer` (not just its id) lets a cache hit re-insert
    /// the blob into a store that has never seen it — a fresh store, or
    /// one garbage-collected between build-farm passes — so an image's
    /// layers are always resident wherever it was built.  Entries are
    /// immutable and content-addressed, so they sit behind `Arc`s:
    /// [`fork`](Builder::fork) clones the map of pointers, not the
    /// manifests.
    cache: HashMap<(Option<LayerId>, String), Arc<Layer>>,
}

impl Builder {
    /// A builder with an empty layer cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// A copy of this builder sharing nothing: the fork's cache starts
    /// as a snapshot and diverges (a farm worker builds against the
    /// committed cache without publishing half-done entries).
    pub fn fork(&self) -> Builder {
        self.clone()
    }

    /// Merge another builder's cache entries into this one (a farm
    /// commits a worker's fork when its build completes).  Entries are
    /// content-derived, so collisions are identical and last-write-wins
    /// is sound.
    pub fn absorb(&mut self, other: Builder) {
        self.cache.extend(other.cache);
    }

    /// Number of (parent, directive) → layer entries in the cache.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Execute `bf`, tagging the result as `reference`.
    ///
    /// Stages run in topological order (file order is one, since stage
    /// references only point backwards); stages the terminal stage does
    /// not need are skipped entirely.  The returned image stacks only
    /// the terminal stage's layers.
    pub fn build(
        &mut self,
        bf: &Buildfile,
        reference: &str,
        store: &mut LayerStore,
    ) -> Result<BuildReport, UnknownBase> {
        let stages = bf.stages();
        let names: Vec<Option<&str>> = stages.iter().map(|s| s.name).collect();
        let graph = BuildGraph::plan(bf);
        let mut states: Vec<Option<StageState>> = vec![None; stages.len()];
        let mut built = 0usize;
        let mut cached = 0usize;
        let mut build_time = Duration::ZERO;

        for stage in &stages {
            if !graph.is_needed(stage.index) {
                continue;
            }
            // seed the chain and config: either from an earlier stage
            // (FROM <stage> continues its layers and inherits its
            // config, as Docker does) or fresh from a catalogue base
            let base_stage = resolve_among(&names[..stage.index], stage.base);
            let mut st = match base_stage {
                Some(src) => {
                    let mut s = states[src].clone().expect("deps built in topo order");
                    s.time = Duration::ZERO;
                    s
                }
                None => StageState::default(),
            };

            for d in stage.directives {
                // config-only directives do not create layers
                match d {
                    Directive::From { .. } if base_stage.is_some() => continue,
                    Directive::Env { key, value } => {
                        st.env.push((key.clone(), value.clone()));
                        continue;
                    }
                    Directive::Label { key, value } => {
                        st.labels.push((key.clone(), value.clone()));
                        continue;
                    }
                    Directive::Entrypoint(e) => {
                        st.entrypoint = Some(e.clone());
                        continue;
                    }
                    Directive::User(_) | Directive::Workdir(_) => continue,
                    Directive::ArchOpt => {
                        st.arch_optimized = true;
                        // ARCH_OPT recompiles hot binaries: costs build
                        // time, produces a small layer of rebuilt objects
                    }
                    _ => {}
                }

                // the digest a COPY --from commits to: the source
                // stage's final layer id (renaming the stage changes
                // nothing; changing what it built changes everything)
                let copy_digest = match d {
                    Directive::Copy { from: Some(f), .. } => {
                        let src = resolve_among(&names[..stage.index], f)
                            .expect("parse() validated stage references");
                        let state = states[src].as_ref().expect("deps built in topo order");
                        let last = state.layers.last().cloned();
                        Some(last.expect("every stage chain has at least a base layer"))
                    }
                    _ => None,
                };

                let parent = st.layers.last().cloned();
                let canon = cache_canonical(d, copy_digest.as_ref());
                let key = (parent.clone(), canon.clone());
                if let Some(hit) = self.cache.get(&key) {
                    // self-heal: this store may never have seen the
                    // blob (fresh store, or GC'd between farm passes)
                    if !store.contains(&hit.id) {
                        store.insert(Layer::clone(hit));
                    }
                    st.layers.push(hit.id.clone());
                    cached += 1;
                    continue;
                }
                let (files, cost) = synth_effects(d, copy_digest.as_ref())?;
                let layer = Layer::derive(parent.as_ref(), &canon, files);
                st.layers.push(layer.id.clone());
                let layer = Arc::new(layer);
                self.cache.insert(key, Arc::clone(&layer));
                store.insert(Layer::clone(&layer));
                built += 1;
                build_time += cost;
                st.time += cost;
            }
            states[stage.index] = Some(st);
        }

        let stage_times: Vec<Duration> = states
            .iter()
            .map(|s| s.as_ref().map(|s| s.time).unwrap_or(Duration::ZERO))
            .collect();
        let critical_path = graph.critical_path(&stage_times);
        let stages_built = states.iter().filter(|s| s.is_some()).count();
        let terminal = states
            .last()
            .and_then(|s| s.clone())
            .expect("parse() guarantees at least one stage");

        Ok(BuildReport {
            image: Image::seal(
                reference,
                terminal.layers,
                terminal.env,
                terminal.entrypoint,
                terminal.labels,
                terminal.arch_optimized,
            ),
            layers_built: built,
            layers_cached: cached,
            build_time,
            critical_path,
            stages_built,
            stages_skipped: stages.len() - stages_built,
            stage_times,
            graph,
        })
    }
}

/// Unknown base image reference.
#[derive(Debug)]
pub struct UnknownBase(
    /// The reference that is not in the catalogue.
    pub String,
);

impl std::fmt::Display for UnknownBase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "unknown base image `{}` (not in the catalogue)", self.0)
    }
}
impl std::error::Error for UnknownBase {}

/// The directive text a layer hash and cache key commit to.  Identical
/// to [`Directive::canonical`] except that stage *names* are erased:
/// `FROM base AS x` hashes as `FROM base`, and `COPY --from=<stage>`
/// substitutes the source stage's content digest for its name.
fn cache_canonical(d: &Directive, copy_digest: Option<&LayerId>) -> String {
    match (d, copy_digest) {
        (Directive::From { base, .. }, _) => format!("FROM {base}"),
        (Directive::Copy { src, dst, .. }, Some(digest)) => {
            format!("COPY --from=@{} {src} {dst}", digest.0)
        }
        _ => d.canonical(),
    }
}

/// Deterministic pseudo-random u64 from a string.
fn det(s: &str) -> u64 {
    let d = Sha256::digest(s.as_bytes());
    u64::from_le_bytes(d[..8].try_into().unwrap())
}

/// Synthesise the filesystem effect + wall cost of one directive.
fn synth_effects(
    d: &Directive,
    copy_digest: Option<&LayerId>,
) -> Result<(Vec<FileEntry>, Duration), UnknownBase> {
    Ok(match d {
        Directive::From { base, .. } => base_manifest(base)?,
        Directive::Run(cmd) => run_effects(cmd),
        Directive::Copy {
            from: Some(_),
            src,
            dst,
        } => {
            // built artifacts out of the source stage: a few larger
            // files, derived from the source digest so the manifest
            // changes whenever the source stage does
            let digest = &copy_digest.expect("COPY --from resolved before synthesis").0;
            let h = det(&format!("{digest}:{src}"));
            let n = 2 + (h % 6) as usize;
            let files = (0..n)
                .map(|i| FileEntry {
                    path: format!("{dst}/a{i}"),
                    bytes: 64_000 + (det(&format!("{digest}:{src}:{i}")) % 2_000_000),
                })
                .collect();
            (files, Duration::from_millis(180))
        }
        Directive::Copy {
            from: None,
            src,
            dst,
        } => {
            // a handful of project files
            let h = det(src);
            let n = 3 + (h % 8) as usize;
            let files = (0..n)
                .map(|i| FileEntry {
                    path: format!("{dst}/f{i}"),
                    bytes: 4_096 + (det(&format!("{src}{i}")) % 1_000_000),
                })
                .collect();
            (files, Duration::from_millis(120))
        }
        Directive::ArchOpt => {
            // rebuilt hot binaries (OpenBLAS-style arch dispatch objects)
            let files = (0..24)
                .map(|i| FileEntry {
                    path: format!("/usr/local/lib/arch/obj{i}.o"),
                    bytes: 200_000 + (det(&format!("arch{i}")) % 400_000),
                })
                .collect();
            (files, Duration::from_secs_f64(95.0))
        }
        // config-only directives never reach here
        _ => (Vec::new(), Duration::ZERO),
    })
}

/// The built-in base-image catalogue: (files, per-file-ish sizes).
fn base_manifest(base: &str) -> Result<(Vec<FileEntry>, Duration), UnknownBase> {
    // name -> (file count, total bytes)
    let (count, total): (usize, u64) = match base {
        "scratch" => (0, 0),
        "ubuntu:16.04" => (1_300, 122_000_000),
        "alpine:3.4" => (120, 4_800_000),
        "phusion/baseimage:0.9.19" => (1_500, 180_000_000),
        // the FEniCS project's published hierarchy (§3.4)
        "quay.io/fenicsproject/base" => (2_100, 310_000_000),
        "quay.io/fenicsproject/stable" | "quay.io/fenicsproject/stable:2016.1.0r1" => {
            (5_400, 1_150_000_000)
        }
        "quay.io/fenicsproject/dev" => (6_100, 1_400_000_000),
        other => return Err(UnknownBase(other.to_string())),
    };
    let files = synth_files(base, "/", count, total);
    Ok((files, Duration::from_secs_f64(1.0))) // unpack time
}

/// `count` files under `root` summing to ~`total` bytes, deterministic in `seed`.
fn synth_files(seed: &str, root: &str, count: usize, total: u64) -> Vec<FileEntry> {
    if count == 0 {
        return Vec::new();
    }
    let mean = total / count as u64;
    (0..count)
        .map(|i| {
            let h = det(&format!("{seed}/{i}"));
            // sizes spread around the mean, min 512 bytes
            let bytes = (mean / 2 + h % mean.max(1)).max(512);
            FileEntry {
                path: format!("{root}{seed}/f{i}"),
                bytes,
            }
        })
        .collect()
}

/// Model the filesystem effect of a RUN command.
fn run_effects(cmd: &str) -> (Vec<FileEntry>, Duration) {
    let mut files = Vec::new();
    let mut cost = Duration::from_millis(300); // shell + apt update etc.

    // apt-get ... install pkg1 pkg2 ...
    for segment in cmd.split("&&") {
        let seg = segment.trim();
        let (mgr, per_file, per_pkg_files, per_pkg_secs) =
            if seg.starts_with("apt-get") && seg.contains("install") {
                ("apt", 90_000u64, 160usize, 6.0f64)
            } else if (seg.starts_with("pip") || seg.starts_with("pip3")) && seg.contains("install")
            {
                ("pip", 30_000u64, 70usize, 4.0f64)
            } else {
                continue;
            };
        let pkgs = seg
            .split_whitespace()
            .skip_while(|w| *w != "install")
            .skip(1)
            .filter(|w| !w.starts_with('-'))
            .collect::<Vec<_>>();
        for pkg in pkgs {
            let h = det(pkg);
            let nfiles = per_pkg_files / 2 + (h % per_pkg_files as u64) as usize;
            let total = nfiles as u64 * (per_file / 2 + h % per_file);
            files.extend(synth_files(pkg, &format!("/usr/{mgr}/"), nfiles, total));
            cost += Duration::from_secs_f64(per_pkg_secs);
        }
    }

    // source builds (FEniCS-style): `make`, `cmake`, `python setup.py`
    if cmd.contains("make") || cmd.contains("setup.py") || cmd.contains("cmake") {
        let h = det(cmd);
        let n = 200 + (h % 400) as usize;
        files.extend(synth_files(cmd, "/usr/local/", n, n as u64 * 60_000));
        cost += Duration::from_secs_f64(240.0);
    }

    if files.is_empty() {
        // generic command: small scratch output
        files = synth_files(cmd, "/tmp/", 4, 64_000);
    }
    (files, cost)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bf(text: &str) -> Buildfile {
        Buildfile::parse(text).unwrap()
    }

    #[test]
    fn build_produces_content_addressed_image() {
        let mut b = Builder::new();
        let mut s = LayerStore::new();
        let f = bf("FROM ubuntu:16.04\nRUN apt-get -y install python-scipy");
        let r1 = b.build(&f, "scipy:1", &mut s).unwrap();
        let r2 = Builder::new().build(&f, "scipy:1", &mut LayerStore::new()).unwrap();
        assert_eq!(r1.image.id, r2.image.id, "builds are reproducible");
        assert_eq!(r1.layers_built, 2);
        assert_eq!(r1.critical_path, r1.build_time, "single stage: no parallelism");
    }

    #[test]
    fn layer_cache_hits_on_shared_prefix() {
        let mut b = Builder::new();
        let mut s = LayerStore::new();
        b.build(
            &bf("FROM ubuntu:16.04\nRUN apt-get install python-scipy"),
            "a:1",
            &mut s,
        )
        .unwrap();
        let r2 = b
            .build(
                &bf("FROM ubuntu:16.04\nRUN apt-get install python-scipy\nRUN pip install fenics"),
                "b:1",
                &mut s,
            )
            .unwrap();
        assert_eq!(r2.layers_cached, 2, "FROM and first RUN come from cache");
        assert_eq!(r2.layers_built, 1);
    }

    #[test]
    fn store_dedups_across_images() {
        // two *independent* builders (e.g. two CI workers) pushing into
        // one store: the store dedups the shared base layer by content
        let mut s = LayerStore::new();
        Builder::new()
            .build(&bf("FROM ubuntu:16.04\nRUN echo a"), "a:1", &mut s)
            .unwrap();
        let before = s.physical_bytes();
        Builder::new()
            .build(&bf("FROM ubuntu:16.04\nRUN echo b"), "b:1", &mut s)
            .unwrap();
        // second image only added its tiny RUN layer, not another base
        let added = s.physical_bytes() - before;
        assert!(added < 1_000_000, "base layer was re-stored: {added}");
        assert!(s.dedup_ratio() > 1.5);
    }

    #[test]
    fn package_installs_grow_the_image() {
        let mut b = Builder::new();
        let mut s = LayerStore::new();
        let small = b
            .build(&bf("FROM alpine:3.4\nRUN echo hi"), "s:1", &mut s)
            .unwrap();
        let big = b
            .build(
                &bf("FROM alpine:3.4\nRUN apt-get install petsc slepc dolfin"),
                "b:1",
                &mut s,
            )
            .unwrap();
        assert!(big.image.size_bytes(&s) > 3 * small.image.size_bytes(&s));
        assert!(big.build_time > small.build_time);
    }

    #[test]
    fn config_directives_make_no_layers() {
        let mut b = Builder::new();
        let mut s = LayerStore::new();
        let r = b
            .build(
                &bf("FROM alpine:3.4\nENV A=1\nUSER root\nWORKDIR /w\nLABEL k=v\nENTRYPOINT sh"),
                "c:1",
                &mut s,
            )
            .unwrap();
        assert_eq!(r.image.layers.len(), 1); // just the base
        assert_eq!(r.image.env, vec![("A".to_string(), "1".to_string())]);
        assert_eq!(r.image.entrypoint.as_deref(), Some("sh"));
    }

    #[test]
    fn arch_opt_flags_image_and_costs_time() {
        let mut b = Builder::new();
        let mut s = LayerStore::new();
        let plain = b.build(&bf("FROM alpine:3.4"), "p:1", &mut s).unwrap();
        let opt = b.build(&bf("FROM alpine:3.4\nARCH_OPT"), "o:1", &mut s).unwrap();
        assert!(!plain.image.arch_optimized);
        assert!(opt.image.arch_optimized);
        assert!(opt.build_time > plain.build_time);
    }

    #[test]
    fn unknown_base_is_an_error() {
        let mut b = Builder::new();
        let err = b
            .build(&bf("FROM centos:7"), "x:1", &mut LayerStore::new())
            .unwrap_err();
        assert!(err.to_string().contains("centos:7"));
    }

    #[test]
    fn fenics_stable_base_is_fat() {
        let mut b = Builder::new();
        let mut s = LayerStore::new();
        let r = b
            .build(
                &bf("FROM quay.io/fenicsproject/stable:2016.1.0r1"),
                "f:1",
                &mut s,
            )
            .unwrap();
        assert!(r.image.size_bytes(&s) > 500_000_000);
        assert!(r.image.file_count(&s) > 4_000);
    }

    const TWO_STAGE: &str = "\
FROM ubuntu:16.04 AS build
RUN make -j app
FROM alpine:3.4
COPY --from=build /usr/local/app /opt/app
ENTRYPOINT /opt/app/run
";

    #[test]
    fn multistage_prunes_builder_layers_from_the_image() {
        let mut b = Builder::new();
        let mut s = LayerStore::new();
        let r = b.build(&bf(TWO_STAGE), "app:1", &mut s).unwrap();
        // image: alpine base + the COPY layer; ubuntu + make pruned
        assert_eq!(r.image.layers.len(), 2);
        assert_eq!(r.stages_built, 2);
        assert_eq!(r.stages_skipped, 0);
        assert_eq!(r.layers_built, 4, "pruned stages are still built");
        // the pruned layers are in the store (they are the cache) ...
        assert_eq!(s.len(), 4);
        // ... but the image is dramatically smaller than the store
        assert!(r.image.size_bytes(&s) * 3 < s.physical_bytes());
        assert_eq!(r.image.entrypoint.as_deref(), Some("/opt/app/run"));
    }

    #[test]
    fn multistage_critical_path_is_under_serial_time() {
        // two independent builder stages feeding a final COPY stage:
        // the critical path excludes the cheaper branch
        let text = "\
FROM ubuntu:16.04 AS heavy
RUN make -j everything
FROM alpine:3.4 AS light
RUN echo done
FROM alpine:3.4
COPY --from=heavy /usr/local/a /opt/a
COPY --from=light /tmp/b /opt/b
";
        let mut b = Builder::new();
        let mut s = LayerStore::new();
        let r = b.build(&bf(text), "par:1", &mut s).unwrap();
        assert!(r.critical_path < r.build_time);
        assert_eq!(r.stage_times.len(), 3);
        assert!(r.stage_times[0] > r.stage_times[1]);
    }

    #[test]
    fn from_stage_continues_the_chain_and_inherits_config() {
        let text = "\
FROM alpine:3.4 AS base
ENV A=1
RUN echo tool
FROM base AS derived
RUN echo more
";
        let mut b = Builder::new();
        let mut s = LayerStore::new();
        let r = b.build(&bf(text), "d:1", &mut s).unwrap();
        // chain: alpine base, tool RUN, more RUN
        assert_eq!(r.image.layers.len(), 3);
        assert_eq!(r.image.env, vec![("A".to_string(), "1".to_string())]);
        // the derived stage's chain shares the base stage's prefix
        let base_only = Builder::new()
            .build(&bf("FROM alpine:3.4 AS base\nENV A=1\nRUN echo tool"), "b:1", &mut s)
            .unwrap();
        assert_eq!(r.image.layers[..2], base_only.image.layers[..]);
    }

    #[test]
    fn unreachable_stages_are_skipped() {
        let text = "\
FROM ubuntu:16.04 AS unused
RUN make -j never-needed
FROM alpine:3.4
RUN echo hi
";
        let mut b = Builder::new();
        let mut s = LayerStore::new();
        let r = b.build(&bf(text), "skip:1", &mut s).unwrap();
        assert_eq!(r.stages_built, 1);
        assert_eq!(r.stages_skipped, 1);
        assert_eq!(r.layers_built, 2, "only the target stage was built");
        assert_eq!(r.stage_times[0], Duration::ZERO);
    }

    #[test]
    fn renaming_a_stage_keeps_every_layer_id() {
        let renamed = TWO_STAGE.replace("build", "compile");
        let mut s1 = LayerStore::new();
        let mut s2 = LayerStore::new();
        let a = Builder::new().build(&bf(TWO_STAGE), "app:1", &mut s1).unwrap();
        let b = Builder::new().build(&bf(&renamed), "app:1", &mut s2).unwrap();
        assert_eq!(a.image.layers, b.image.layers, "stage names are not hashed");
    }

    #[test]
    fn copy_from_invalidates_when_the_source_stage_changes() {
        let changed = TWO_STAGE.replace("make -j app", "make -j app V2");
        let mut b = Builder::new();
        let mut s = LayerStore::new();
        let first = b.build(&bf(TWO_STAGE), "app:1", &mut s).unwrap();
        // identical rebuild: everything cached
        let again = b.build(&bf(TWO_STAGE), "app:1", &mut s).unwrap();
        assert_eq!(again.layers_built, 0);
        assert_eq!(again.layers_cached, first.layers_built);
        // changing the source stage rebuilds it AND the COPY layer,
        // even though the COPY directive's text is unchanged
        let v2 = b.build(&bf(&changed), "app:2", &mut s).unwrap();
        assert_eq!(v2.layers_cached, 2, "both FROM bases still hit");
        assert_eq!(v2.layers_built, 2, "changed RUN + dependent COPY rebuilt");
        assert_ne!(v2.image.layers.last(), first.image.layers.last());
    }

    #[test]
    fn diamond_graph_plans_levels_and_builds() {
        let text = "\
FROM ubuntu:16.04 AS common
RUN apt-get install gcc
FROM common AS left
RUN make -j left
FROM common AS right
RUN make -j right
FROM alpine:3.4
COPY --from=left /usr/local/l /opt/l
COPY --from=right /usr/local/r /opt/r
";
        let parsed = bf(text);
        let g = BuildGraph::plan(&parsed);
        assert_eq!(g.stage_count(), 4);
        assert_eq!(g.deps(1), &[0]);
        assert_eq!(g.deps(2), &[0]);
        assert_eq!(g.deps(3), &[1, 2]);
        assert_eq!(
            (g.level(0), g.level(1), g.level(2), g.level(3)),
            (0, 1, 1, 2)
        );
        assert_eq!(g.schedule(), vec![vec![0], vec![1, 2], vec![3]]);
        let mut b = Builder::new();
        let mut s = LayerStore::new();
        let r = b.build(&parsed, "diamond:1", &mut s).unwrap();
        assert_eq!(r.stages_built, 4);
        // the common stage was built once, not once per branch
        assert_eq!(r.layers_built, 2 + 1 + 1 + 3);
    }

    #[test]
    fn fork_and_absorb_share_cache_entries() {
        let f = bf("FROM alpine:3.4\nRUN echo a");
        let mut committed = Builder::new();
        let mut store = LayerStore::new();
        let mut fork = committed.fork();
        fork.build(&f, "a:1", &mut store).unwrap();
        assert_eq!(committed.cache_len(), 0, "fork does not leak back");
        committed.absorb(fork);
        assert_eq!(committed.cache_len(), 2);
        let warm = committed.build(&f, "a:2", &mut store).unwrap();
        assert_eq!(warm.layers_built, 0);
        assert_eq!(warm.layers_cached, 2);
    }
}
