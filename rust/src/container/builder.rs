//! Image builder: executes a buildfile into an image.
//!
//! We cannot run real shell commands, so `RUN` effects are *modelled*
//! deterministically: the builder recognises package-manager invocations
//! (`apt-get install`, `pip install`) and synthesises a plausible file
//! manifest per package (count and bytes derived from the package name's
//! hash), which is exactly the information the rest of the system needs
//! (layer sizes for pull-time, file counts for the import problem).  The
//! synthesis is a pure function of the directive text, so the layer
//! *cache* behaves exactly like Docker's: same parent + same directive
//! ⇒ same layer id ⇒ cache hit.
//!
//! Base images come from a small built-in catalogue (the `ubuntu:16.04`
//! and FEniCS-stack bases the paper uses).

use std::collections::HashMap;

use sha2::{Digest, Sha256};

use super::buildfile::{Buildfile, Directive};
use super::image::{FileEntry, Image, Layer, LayerId};
use super::store::LayerStore;
use crate::des::Duration;

/// Result of a build: the image plus provenance/caching info.
#[derive(Debug, Clone)]
pub struct BuildReport {
    /// The built image.
    pub image: Image,
    /// Layers that were produced by this build (vs. cache hits).
    pub layers_built: usize,
    /// Directives answered from the layer cache.
    pub layers_cached: usize,
    /// Modelled wall time of the build (package installs dominate).
    pub build_time: Duration,
}

/// Builds images into a shared [`LayerStore`], with Docker-style layer
/// caching keyed on (parent id, directive canonical text).
#[derive(Debug, Default)]
pub struct Builder {
    cache: HashMap<(Option<LayerId>, String), LayerId>,
}

impl Builder {
    /// A builder with an empty layer cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Execute `bf`, tagging the result as `reference`.
    pub fn build(
        &mut self,
        bf: &Buildfile,
        reference: &str,
        store: &mut LayerStore,
    ) -> Result<BuildReport, UnknownBase> {
        let mut layers: Vec<LayerId> = Vec::new();
        let mut env: Vec<(String, String)> = Vec::new();
        let mut labels: Vec<(String, String)> = Vec::new();
        let mut entrypoint: Option<String> = None;
        let mut arch_optimized = false;
        let mut built = 0usize;
        let mut cached = 0usize;
        let mut build_time = Duration::ZERO;

        for d in &bf.directives {
            // config-only directives do not create layers
            match d {
                Directive::Env { key, value } => {
                    env.push((key.clone(), value.clone()));
                    continue;
                }
                Directive::Label { key, value } => {
                    labels.push((key.clone(), value.clone()));
                    continue;
                }
                Directive::Entrypoint(e) => {
                    entrypoint = Some(e.clone());
                    continue;
                }
                Directive::User(_) | Directive::Workdir(_) => continue,
                Directive::ArchOpt => {
                    arch_optimized = true;
                    // ARCH_OPT recompiles hot binaries: costs build time,
                    // produces a small layer of rebuilt objects
                }
                _ => {}
            }

            let parent = layers.last().cloned();
            let canon = d.canonical();
            let key = (parent.clone(), canon.clone());
            if let Some(hit) = self.cache.get(&key) {
                layers.push(hit.clone());
                cached += 1;
                continue;
            }
            let (files, cost) = synth_effects(d)?;
            let layer = Layer::derive(parent.as_ref(), &canon, files);
            self.cache.insert(key, layer.id.clone());
            layers.push(layer.id.clone());
            store.insert(layer);
            built += 1;
            build_time += cost;
        }

        Ok(BuildReport {
            image: Image::seal(reference, layers, env, entrypoint, labels, arch_optimized),
            layers_built: built,
            layers_cached: cached,
            build_time,
        })
    }
}

/// Unknown base image reference.
#[derive(Debug)]
pub struct UnknownBase(
    /// The reference that is not in the catalogue.
    pub String,
);

impl std::fmt::Display for UnknownBase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "unknown base image `{}` (not in the catalogue)", self.0)
    }
}
impl std::error::Error for UnknownBase {}

/// Deterministic pseudo-random u64 from a string.
fn det(s: &str) -> u64 {
    let d = Sha256::digest(s.as_bytes());
    u64::from_le_bytes(d[..8].try_into().unwrap())
}

/// Synthesise the filesystem effect + wall cost of one directive.
fn synth_effects(d: &Directive) -> Result<(Vec<FileEntry>, Duration), UnknownBase> {
    Ok(match d {
        Directive::From(base) => base_manifest(base)?,
        Directive::Run(cmd) => run_effects(cmd),
        Directive::Copy { src, dst } => {
            // a handful of project files
            let h = det(src);
            let n = 3 + (h % 8) as usize;
            let files = (0..n)
                .map(|i| FileEntry {
                    path: format!("{dst}/f{i}"),
                    bytes: 4_096 + (det(&format!("{src}{i}")) % 1_000_000),
                })
                .collect();
            (files, Duration::from_millis(120))
        }
        Directive::ArchOpt => {
            // rebuilt hot binaries (OpenBLAS-style arch dispatch objects)
            let files = (0..24)
                .map(|i| FileEntry {
                    path: format!("/usr/local/lib/arch/obj{i}.o"),
                    bytes: 200_000 + (det(&format!("arch{i}")) % 400_000),
                })
                .collect();
            (files, Duration::from_secs_f64(95.0))
        }
        // config-only directives never reach here
        _ => (Vec::new(), Duration::ZERO),
    })
}

/// The built-in base-image catalogue: (files, per-file-ish sizes).
fn base_manifest(base: &str) -> Result<(Vec<FileEntry>, Duration), UnknownBase> {
    // name -> (file count, total bytes)
    let (count, total): (usize, u64) = match base {
        "scratch" => (0, 0),
        "ubuntu:16.04" => (1_300, 122_000_000),
        "alpine:3.4" => (120, 4_800_000),
        "phusion/baseimage:0.9.19" => (1_500, 180_000_000),
        // the FEniCS project's published hierarchy (§3.4)
        "quay.io/fenicsproject/base" => (2_100, 310_000_000),
        "quay.io/fenicsproject/stable" | "quay.io/fenicsproject/stable:2016.1.0r1" => {
            (5_400, 1_150_000_000)
        }
        "quay.io/fenicsproject/dev" => (6_100, 1_400_000_000),
        other => return Err(UnknownBase(other.to_string())),
    };
    let files = synth_files(base, "/", count, total);
    Ok((files, Duration::from_secs_f64(1.0))) // unpack time
}

/// `count` files under `root` summing to ~`total` bytes, deterministic in `seed`.
fn synth_files(seed: &str, root: &str, count: usize, total: u64) -> Vec<FileEntry> {
    if count == 0 {
        return Vec::new();
    }
    let mean = total / count as u64;
    (0..count)
        .map(|i| {
            let h = det(&format!("{seed}/{i}"));
            // sizes spread around the mean, min 512 bytes
            let bytes = (mean / 2 + h % mean.max(1)).max(512);
            FileEntry {
                path: format!("{root}{seed}/f{i}"),
                bytes,
            }
        })
        .collect()
}

/// Model the filesystem effect of a RUN command.
fn run_effects(cmd: &str) -> (Vec<FileEntry>, Duration) {
    let mut files = Vec::new();
    let mut cost = Duration::from_millis(300); // shell + apt update etc.

    // apt-get ... install pkg1 pkg2 ...
    for segment in cmd.split("&&") {
        let seg = segment.trim();
        let (mgr, per_file, per_pkg_files, per_pkg_secs) =
            if seg.starts_with("apt-get") && seg.contains("install") {
                ("apt", 90_000u64, 160usize, 6.0f64)
            } else if (seg.starts_with("pip") || seg.starts_with("pip3")) && seg.contains("install")
            {
                ("pip", 30_000u64, 70usize, 4.0f64)
            } else {
                continue;
            };
        let pkgs = seg
            .split_whitespace()
            .skip_while(|w| *w != "install")
            .skip(1)
            .filter(|w| !w.starts_with('-'))
            .collect::<Vec<_>>();
        for pkg in pkgs {
            let h = det(pkg);
            let nfiles = per_pkg_files / 2 + (h % per_pkg_files as u64) as usize;
            let total = nfiles as u64 * (per_file / 2 + h % per_file);
            files.extend(synth_files(pkg, &format!("/usr/{mgr}/"), nfiles, total));
            cost += Duration::from_secs_f64(per_pkg_secs);
        }
    }

    // source builds (FEniCS-style): `make`, `cmake`, `python setup.py`
    if cmd.contains("make") || cmd.contains("setup.py") || cmd.contains("cmake") {
        let h = det(cmd);
        let n = 200 + (h % 400) as usize;
        files.extend(synth_files(cmd, "/usr/local/", n, n as u64 * 60_000));
        cost += Duration::from_secs_f64(240.0);
    }

    if files.is_empty() {
        // generic command: small scratch output
        files = synth_files(cmd, "/tmp/", 4, 64_000);
    }
    (files, cost)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bf(text: &str) -> Buildfile {
        Buildfile::parse(text).unwrap()
    }

    #[test]
    fn build_produces_content_addressed_image() {
        let mut b = Builder::new();
        let mut s = LayerStore::new();
        let f = bf("FROM ubuntu:16.04\nRUN apt-get -y install python-scipy");
        let r1 = b.build(&f, "scipy:1", &mut s).unwrap();
        let r2 = Builder::new().build(&f, "scipy:1", &mut LayerStore::new()).unwrap();
        assert_eq!(r1.image.id, r2.image.id, "builds are reproducible");
        assert_eq!(r1.layers_built, 2);
    }

    #[test]
    fn layer_cache_hits_on_shared_prefix() {
        let mut b = Builder::new();
        let mut s = LayerStore::new();
        b.build(
            &bf("FROM ubuntu:16.04\nRUN apt-get install python-scipy"),
            "a:1",
            &mut s,
        )
        .unwrap();
        let r2 = b
            .build(
                &bf("FROM ubuntu:16.04\nRUN apt-get install python-scipy\nRUN pip install fenics"),
                "b:1",
                &mut s,
            )
            .unwrap();
        assert_eq!(r2.layers_cached, 2, "FROM and first RUN come from cache");
        assert_eq!(r2.layers_built, 1);
    }

    #[test]
    fn store_dedups_across_images() {
        // two *independent* builders (e.g. two CI workers) pushing into
        // one store: the store dedups the shared base layer by content
        let mut s = LayerStore::new();
        Builder::new()
            .build(&bf("FROM ubuntu:16.04\nRUN echo a"), "a:1", &mut s)
            .unwrap();
        let before = s.physical_bytes();
        Builder::new()
            .build(&bf("FROM ubuntu:16.04\nRUN echo b"), "b:1", &mut s)
            .unwrap();
        // second image only added its tiny RUN layer, not another base
        let added = s.physical_bytes() - before;
        assert!(added < 1_000_000, "base layer was re-stored: {added}");
        assert!(s.dedup_ratio() > 1.5);
    }

    #[test]
    fn package_installs_grow_the_image() {
        let mut b = Builder::new();
        let mut s = LayerStore::new();
        let small = b
            .build(&bf("FROM alpine:3.4\nRUN echo hi"), "s:1", &mut s)
            .unwrap();
        let big = b
            .build(
                &bf("FROM alpine:3.4\nRUN apt-get install petsc slepc dolfin"),
                "b:1",
                &mut s,
            )
            .unwrap();
        assert!(big.image.size_bytes(&s) > 3 * small.image.size_bytes(&s));
        assert!(big.build_time > small.build_time);
    }

    #[test]
    fn config_directives_make_no_layers() {
        let mut b = Builder::new();
        let mut s = LayerStore::new();
        let r = b
            .build(
                &bf("FROM alpine:3.4\nENV A=1\nUSER root\nWORKDIR /w\nLABEL k=v\nENTRYPOINT sh"),
                "c:1",
                &mut s,
            )
            .unwrap();
        assert_eq!(r.image.layers.len(), 1); // just the base
        assert_eq!(r.image.env, vec![("A".to_string(), "1".to_string())]);
        assert_eq!(r.image.entrypoint.as_deref(), Some("sh"));
    }

    #[test]
    fn arch_opt_flags_image_and_costs_time() {
        let mut b = Builder::new();
        let mut s = LayerStore::new();
        let plain = b.build(&bf("FROM alpine:3.4"), "p:1", &mut s).unwrap();
        let opt = b.build(&bf("FROM alpine:3.4\nARCH_OPT"), "o:1", &mut s).unwrap();
        assert!(!plain.image.arch_optimized);
        assert!(opt.image.arch_optimized);
        assert!(opt.build_time > plain.build_time);
    }

    #[test]
    fn unknown_base_is_an_error() {
        let mut b = Builder::new();
        let err = b
            .build(&bf("FROM centos:7"), "x:1", &mut LayerStore::new())
            .unwrap_err();
        assert!(err.to_string().contains("centos:7"));
    }

    #[test]
    fn fenics_stable_base_is_fat() {
        let mut b = Builder::new();
        let mut s = LayerStore::new();
        let r = b
            .build(
                &bf("FROM quay.io/fenicsproject/stable:2016.1.0r1"),
                "f:1",
                &mut s,
            )
            .unwrap();
        assert!(r.image.size_bytes(&s) > 500_000_000);
        assert!(r.image.file_count(&s) > 4_000);
    }
}
