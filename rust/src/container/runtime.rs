//! Runtime adapters: Docker, rkt, Shifter, VM — plus a Native
//! pass-through so every experiment runs through the same code path.
//!
//! The four runtimes the paper benchmarks differ in exactly the ways the
//! figures expose, and those differences are what each adapter encodes:
//!
//! | runtime | start cost | app filesystem | compute factor | MPI story |
//! |---|---|---|---|---|
//! | native  | none       | host FS        | 1.0            | system MPI |
//! | docker  | ~0.5 s     | overlay        | 1.0 (same kernel) | container MPI unless host lib mounted |
//! | rkt     | ~0.3 s     | overlay        | 1.0            | as docker |
//! | shifter | ~0.4 s     | loop-mounted image (RO) | 1.0   | host MPI via MPICH ABI if `LD_LIBRARY_PATH` injected |
//! | vm      | ~45 s boot | virtual block device | ~1.15 (Fig 2) | n/a (single node) |
//!
//! The `arch_penalty` models Fig 5a: binaries compiled for a generic
//! architecture (no `ARCH_OPT` in the buildfile) forfeit AVX and pay ~3 %
//! on the tuned HPGMG hot loops; natively compiled code never does.


use crate::cluster::MachineSpec;
use crate::des::Duration;
use crate::net::FabricKind;

use super::image::Image;

/// Which runtime instantiates the container.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RuntimeKind {
    /// No container at all (bare metal).
    Native,
    /// Docker daemon (the workstation default).
    Docker,
    /// CoreOS rkt.
    Rkt,
    /// NERSC's Shifter (the HPC runtime).
    Shifter,
    /// Docker inside a VirtualBox-style VM.
    Vm,
}

impl std::fmt::Display for RuntimeKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            RuntimeKind::Native => "native",
            RuntimeKind::Docker => "docker",
            RuntimeKind::Rkt => "rkt",
            RuntimeKind::Shifter => "shifter",
            RuntimeKind::Vm => "vm",
        };
        write!(f, "{s}")
    }
}

/// The filesystem the application sees at runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsPolicy {
    /// Host filesystem directly (native).
    Host,
    /// Overlay/union FS over the layer store (docker/rkt): metadata hits
    /// the page cache, data mildly indirected.
    Overlay,
    /// Read-only loop-mounted image (Shifter): see [`crate::fs::ImageFs`].
    ImageMount,
    /// Virtual block device through the hypervisor (VM).
    VmDisk,
}

/// A container runtime adapter.
pub trait ContainerRuntime {
    /// Which runtime this adapter models.
    fn kind(&self) -> RuntimeKind;

    /// Time from `run` to the entrypoint executing (excludes pull).
    fn startup_overhead(&self, image: &Image) -> Duration;

    /// Multiplicative penalty on compute segments (1.0 = none).
    fn compute_factor(&self) -> f64;

    /// Filesystem the contained application sees.
    fn fs_policy(&self) -> FsPolicy;

    /// Which fabric MPI resolves to on `machine`.
    ///
    /// `inject_host_mpi` models the paper's `LD_LIBRARY_PATH` trick: the
    /// MPICH-ABI-compatible system library is bind-mounted and the
    /// dynamic linker picks it up (§4.2 / Bahls [8]).  Containers that
    /// do not inject fall back to their bundled MPICH, which can only
    /// drive TCP off-node.
    fn resolve_fabric(&self, machine: &MachineSpec, inject_host_mpi: bool) -> FabricKind;

    /// Multiplicative penalty on *tuned* compute kernels when the image
    /// binaries were not built for the host architecture (Fig 5a).
    fn arch_penalty(&self, image: &Image) -> f64 {
        if self.kind() == RuntimeKind::Native || image.arch_optimized {
            1.0
        } else {
            1.03
        }
    }
}

/// Native execution (no container) expressed as a runtime adapter so the
/// whole experiment matrix shares one code path.
pub struct NativeRuntime;

impl ContainerRuntime for NativeRuntime {
    fn kind(&self) -> RuntimeKind {
        RuntimeKind::Native
    }
    fn startup_overhead(&self, _image: &Image) -> Duration {
        Duration::ZERO
    }
    fn compute_factor(&self) -> f64 {
        1.0
    }
    fn fs_policy(&self) -> FsPolicy {
        FsPolicy::Host
    }
    fn resolve_fabric(&self, machine: &MachineSpec, _inject: bool) -> FabricKind {
        machine.host_fabric
    }
}

/// Docker engine.
pub struct DockerRuntime;

impl ContainerRuntime for DockerRuntime {
    fn kind(&self) -> RuntimeKind {
        RuntimeKind::Docker
    }
    fn startup_overhead(&self, image: &Image) -> Duration {
        // daemon round-trip + namespace/cgroup setup + overlay mount;
        // grows weakly with layer count
        Duration::from_millis(450) + Duration::from_millis(5) * image.layers.len() as u64
    }
    fn compute_factor(&self) -> f64 {
        1.0 // same kernel, no virtualisation of CPU
    }
    fn fs_policy(&self) -> FsPolicy {
        FsPolicy::Overlay
    }
    fn resolve_fabric(&self, machine: &MachineSpec, inject_host_mpi: bool) -> FabricKind {
        if machine.num_nodes == 1 {
            // single machine: all MPI is shared memory anyway
            FabricKind::SharedMem
        } else if inject_host_mpi && machine.system_mpi_abi_compatible {
            machine.host_fabric
        } else {
            FabricKind::TcpEthernet
        }
    }
}

/// rkt (CoreOS).
pub struct RktRuntime;

impl ContainerRuntime for RktRuntime {
    fn kind(&self) -> RuntimeKind {
        RuntimeKind::Rkt
    }
    fn startup_overhead(&self, image: &Image) -> Duration {
        // no daemon: exec into stage1, slightly cheaper than docker
        Duration::from_millis(280) + Duration::from_millis(4) * image.layers.len() as u64
    }
    fn compute_factor(&self) -> f64 {
        1.0
    }
    fn fs_policy(&self) -> FsPolicy {
        FsPolicy::Overlay
    }
    fn resolve_fabric(&self, machine: &MachineSpec, inject_host_mpi: bool) -> FabricKind {
        DockerRuntime.resolve_fabric(machine, inject_host_mpi)
    }
}

/// Shifter (NERSC).
pub struct ShifterRuntime;

impl ContainerRuntime for ShifterRuntime {
    fn kind(&self) -> RuntimeKind {
        RuntimeKind::Shifter
    }
    fn startup_overhead(&self, _image: &Image) -> Duration {
        // loop-mount an already-pulled flattened image + chroot
        Duration::from_millis(400)
    }
    fn compute_factor(&self) -> f64 {
        1.0
    }
    fn fs_policy(&self) -> FsPolicy {
        FsPolicy::ImageMount
    }
    fn resolve_fabric(&self, machine: &MachineSpec, inject_host_mpi: bool) -> FabricKind {
        if inject_host_mpi && machine.system_mpi_abi_compatible {
            // the MPICH ABI initiative at work: swap libmpi at load time
            machine.host_fabric
        } else if machine.num_nodes == 1 {
            FabricKind::SharedMem
        } else {
            FabricKind::TcpEthernet
        }
    }
}

/// VirtualBox-style full virtualisation (the macOS/Windows Docker path
/// of 2016, and Fig 2's "VM" bars).
pub struct VmRuntime;

impl ContainerRuntime for VmRuntime {
    fn kind(&self) -> RuntimeKind {
        RuntimeKind::Vm
    }
    fn startup_overhead(&self, _image: &Image) -> Duration {
        // boot the guest kernel (amortised across a session, but the
        // paper's workflow pays it at least once)
        Duration::from_secs_f64(45.0)
    }
    fn compute_factor(&self) -> f64 {
        1.15 // Fig 2: "up to a 15% performance penalty"
    }
    fn fs_policy(&self) -> FsPolicy {
        FsPolicy::VmDisk
    }
    fn resolve_fabric(&self, _machine: &MachineSpec, _inject: bool) -> FabricKind {
        FabricKind::SharedMem // VMs are a workstation story in the paper
    }
}

/// Instantiate an adapter by kind.
pub fn by_kind(kind: RuntimeKind) -> Box<dyn ContainerRuntime> {
    match kind {
        RuntimeKind::Native => Box::new(NativeRuntime),
        RuntimeKind::Docker => Box::new(DockerRuntime),
        RuntimeKind::Rkt => Box::new(RktRuntime),
        RuntimeKind::Shifter => Box::new(ShifterRuntime),
        RuntimeKind::Vm => Box::new(VmRuntime),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::image::Image;

    fn image(arch: bool) -> Image {
        Image::seal("t:1", vec![], vec![], None, vec![], arch)
    }

    #[test]
    fn startup_ordering_matches_the_paper() {
        let img = image(false);
        let native = NativeRuntime.startup_overhead(&img);
        let rkt = RktRuntime.startup_overhead(&img);
        let docker = DockerRuntime.startup_overhead(&img);
        let vm = VmRuntime.startup_overhead(&img);
        assert!(native < rkt && rkt < docker && docker < vm);
        // containers start in "fractions of a second" (§1)
        assert!(docker < Duration::from_secs_f64(1.0));
        // VMs take "on the order of minutes" (§2.1) — tens of seconds here
        assert!(vm > Duration::from_secs_f64(10.0));
    }

    #[test]
    fn only_vm_slows_compute() {
        assert_eq!(NativeRuntime.compute_factor(), 1.0);
        assert_eq!(DockerRuntime.compute_factor(), 1.0);
        assert_eq!(RktRuntime.compute_factor(), 1.0);
        assert_eq!(ShifterRuntime.compute_factor(), 1.0);
        assert!(VmRuntime.compute_factor() > 1.1);
    }

    #[test]
    fn shifter_resolves_host_mpi_with_injection() {
        let edison = MachineSpec::edison();
        assert_eq!(
            ShifterRuntime.resolve_fabric(&edison, true),
            FabricKind::Aries
        );
        assert_eq!(
            ShifterRuntime.resolve_fabric(&edison, false),
            FabricKind::TcpEthernet
        );
    }

    #[test]
    fn abi_incompatible_host_cannot_inject() {
        let mut weird = MachineSpec::edison();
        weird.system_mpi_abi_compatible = false;
        assert_eq!(
            ShifterRuntime.resolve_fabric(&weird, true),
            FabricKind::TcpEthernet,
            "no ABI compatibility -> injection fails -> TCP fallback"
        );
    }

    #[test]
    fn single_node_container_mpi_is_fine() {
        let ws = MachineSpec::workstation();
        assert_eq!(
            DockerRuntime.resolve_fabric(&ws, false),
            FabricKind::SharedMem,
            "Fig 2/5a: container MPI on one node uses shared memory"
        );
    }

    #[test]
    fn native_always_uses_host_fabric() {
        assert_eq!(
            NativeRuntime.resolve_fabric(&MachineSpec::edison(), false),
            FabricKind::Aries
        );
    }

    #[test]
    fn arch_penalty_only_for_generic_container_builds() {
        assert_eq!(NativeRuntime.arch_penalty(&image(false)), 1.0);
        assert!(DockerRuntime.arch_penalty(&image(false)) > 1.0);
        assert_eq!(DockerRuntime.arch_penalty(&image(true)), 1.0);
        assert!(ShifterRuntime.arch_penalty(&image(false)) > 1.0);
    }

    #[test]
    fn by_kind_dispatch() {
        for k in [
            RuntimeKind::Native,
            RuntimeKind::Docker,
            RuntimeKind::Rkt,
            RuntimeKind::Shifter,
            RuntimeKind::Vm,
        ] {
            assert_eq!(by_kind(k).kind(), k);
        }
    }
}
