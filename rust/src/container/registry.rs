//! Image registry (quay.io-like).
//!
//! Holds tagged images plus their layers; `push`/`pull` move only the
//! layers the receiving side is missing (the layered-filesystem dedup of
//! §2.2), with transfer time from a bandwidth model.  `pull` is what the
//! coordinator calls when deploying to a machine, and what `shifterimg
//! pull` maps to on the HPC side.

use std::collections::HashMap;

use crate::des::Duration;

use super::image::{Image, LayerId};
use super::store::LayerStore;

/// What a pull did (for traces/README tables).
#[derive(Debug, Clone)]
pub struct PullReport {
    /// Image reference pulled.
    pub reference: String,
    /// Layers that crossed the wire.
    pub layers_transferred: usize,
    /// Layers already present at the destination.
    pub layers_reused: usize,
    /// Compressed bytes moved.
    pub bytes_transferred: u64,
    /// Modelled transfer time.
    pub time: Duration,
}

/// A registry: tag → image, plus the layer blobs.
#[derive(Debug, Default)]
pub struct Registry {
    images: HashMap<String, Image>,
    /// Blob store backing every served image.
    pub layers: LayerStore,
    /// Download bandwidth clients see (bytes/s).
    pub bytes_per_sec: f64,
    /// Per-layer request latency.
    pub per_layer_rtt: Duration,
}

impl Registry {
    /// An empty registry with the default WAN bandwidth model.
    pub fn new() -> Self {
        Registry {
            images: HashMap::new(),
            layers: LayerStore::new(),
            bytes_per_sec: 30.0e6, // a decent WAN link to quay.io
            per_layer_rtt: Duration::from_millis(120),
        }
    }

    /// Push an image (and any layers the registry is missing).
    pub fn push(&mut self, image: &Image, source: &LayerStore) -> Result<(), MissingLayer> {
        for id in &image.layers {
            if !self.layers.contains(id) {
                let layer = source.get(id).ok_or_else(|| MissingLayer(id.clone()))?;
                self.layers.insert(layer.clone());
            }
        }
        self.images.insert(image.reference.clone(), image.clone());
        Ok(())
    }

    /// Pull `reference` into `dest`, transferring only missing layers.
    ///
    /// This is the *flat* bandwidth model: one shared link, transfer
    /// time `layers × rtt + bytes / bandwidth`, no queueing.  It is
    /// what single-machine workflows (the Fig 1 pipeline's workstation
    /// and Edison pulls) use.  Fleet-scale concurrent pulls go through
    /// [`distribute::ShardedRegistry::pull_at`], which schedules the
    /// same byte movement through per-shard queues in virtual time.
    ///
    /// # Example
    ///
    /// ```
    /// use harbor::container::{Builder, Buildfile, LayerStore, Registry};
    ///
    /// // build an image and push it
    /// let bf = Buildfile::parse("FROM ubuntu:16.04\nRUN echo hi").unwrap();
    /// let mut ci_store = LayerStore::new();
    /// let image = Builder::new().build(&bf, "app:1", &mut ci_store).unwrap().image;
    /// let mut registry = Registry::new();
    /// registry.push(&image, &ci_store).unwrap();
    ///
    /// // a fresh machine pulls everything ...
    /// let mut machine = LayerStore::new();
    /// let (_, first) = registry.pull("app:1", &mut machine).unwrap();
    /// assert_eq!(first.layers_transferred, 2);
    ///
    /// // ... and a second pull of the same image moves nothing
    /// let (_, again) = registry.pull("app:1", &mut machine).unwrap();
    /// assert_eq!(again.layers_transferred, 0);
    /// assert_eq!(again.layers_reused, 2);
    /// assert_eq!(again.bytes_transferred, 0);
    /// ```
    ///
    /// [`distribute::ShardedRegistry::pull_at`]: super::distribute::ShardedRegistry::pull_at
    pub fn pull(&self, reference: &str, dest: &mut LayerStore) -> Result<(Image, PullReport), PullError> {
        let image = self
            .images
            .get(reference)
            .ok_or_else(|| PullError::UnknownReference(reference.to_string()))?;
        let missing: Vec<LayerId> = dest
            .missing(&image.layers)
            .into_iter()
            .cloned()
            .collect();
        let mut bytes = 0u64;
        for id in &missing {
            let layer = self
                .layers
                .get(id)
                .ok_or_else(|| PullError::CorruptRegistry(id.clone()))?;
            bytes += layer.bytes;
            dest.insert(layer.clone());
        }
        let time = self.per_layer_rtt * missing.len() as u64
            + Duration::from_secs_f64(bytes as f64 / self.bytes_per_sec);
        Ok((
            image.clone(),
            PullReport {
                reference: reference.to_string(),
                layers_transferred: missing.len(),
                layers_reused: image.layers.len() - missing.len(),
                bytes_transferred: bytes,
                time,
            },
        ))
    }

    /// All image references the registry serves.
    pub fn tags(&self) -> impl Iterator<Item = &str> {
        self.images.keys().map(|s| s.as_str())
    }

    /// Whether `reference` is served.
    pub fn contains(&self, reference: &str) -> bool {
        self.images.contains_key(reference)
    }

    /// The image tagged `reference`, if served (manifest lookup — the
    /// control-plane half of a pull; blob movement is separate).
    pub fn image(&self, reference: &str) -> Option<&Image> {
        self.images.get(reference)
    }
}

/// Push failed: the source store lacks a layer the image references.
#[derive(Debug)]
pub struct MissingLayer(
    /// Id of the layer the source store lacks.
    pub LayerId,
);
impl std::fmt::Display for MissingLayer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "source store is missing layer {}", self.0)
    }
}
impl std::error::Error for MissingLayer {}

/// Pull failures.
#[derive(Debug)]
pub enum PullError {
    /// No image tagged with the requested reference.
    UnknownReference(String),
    /// The catalogue references a blob the store lost.
    CorruptRegistry(LayerId),
}
impl std::fmt::Display for PullError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PullError::UnknownReference(r) => write!(f, "no such image: {r}"),
            PullError::CorruptRegistry(l) => write!(f, "registry lost layer {l}"),
        }
    }
}
impl std::error::Error for PullError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::buildfile::Buildfile;
    use crate::container::builder::Builder;

    fn built(reference: &str, text: &str) -> (Image, LayerStore) {
        let mut store = LayerStore::new();
        let image = Builder::new()
            .build(&Buildfile::parse(text).unwrap(), reference, &mut store)
            .unwrap()
            .image;
        (image, store)
    }

    #[test]
    fn push_pull_round_trip() {
        let (image, store) = built("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let mut reg = Registry::new();
        reg.push(&image, &store).unwrap();
        let mut dest = LayerStore::new();
        let (pulled, report) = reg.pull("a:1", &mut dest).unwrap();
        assert_eq!(pulled.id, image.id);
        assert_eq!(report.layers_transferred, 2);
        assert_eq!(report.layers_reused, 0);
        assert!(report.time > Duration::ZERO);
        assert_eq!(dest.len(), 2);
    }

    #[test]
    fn second_pull_reuses_base_layers() {
        let mut builder = Builder::new();
        let mut store = LayerStore::new();
        let bf = |t| Buildfile::parse(t).unwrap();
        let a = builder
            .build(&bf("FROM ubuntu:16.04\nRUN echo a"), "a:1", &mut store)
            .unwrap()
            .image;
        let b = builder
            .build(&bf("FROM ubuntu:16.04\nRUN echo b"), "b:1", &mut store)
            .unwrap()
            .image;
        let mut reg = Registry::new();
        reg.push(&a, &store).unwrap();
        reg.push(&b, &store).unwrap();

        let mut dest = LayerStore::new();
        let (_, r1) = reg.pull("a:1", &mut dest).unwrap();
        let (_, r2) = reg.pull("b:1", &mut dest).unwrap();
        assert_eq!(r1.layers_transferred, 2);
        assert_eq!(r2.layers_transferred, 1, "base came from the local store");
        assert_eq!(r2.layers_reused, 1);
        assert!(r2.bytes_transferred < r1.bytes_transferred / 10);
    }

    #[test]
    fn pull_time_scales_with_bytes() {
        let (big, store) = built("big:1", "FROM quay.io/fenicsproject/stable");
        let (small, store2) = built("small:1", "FROM alpine:3.4");
        let mut reg = Registry::new();
        reg.push(&big, &store).unwrap();
        reg.push(&small, &store2).unwrap();
        let t_big = reg.pull("big:1", &mut LayerStore::new()).unwrap().1.time;
        let t_small = reg.pull("small:1", &mut LayerStore::new()).unwrap().1.time;
        assert!(t_big.as_secs_f64() > 5.0 * t_small.as_secs_f64());
    }

    #[test]
    fn unknown_reference() {
        let reg = Registry::new();
        assert!(matches!(
            reg.pull("ghost:1", &mut LayerStore::new()),
            Err(PullError::UnknownReference(_))
        ));
    }

    #[test]
    fn push_requires_source_layers() {
        let (image, _) = built("a:1", "FROM alpine:3.4");
        let empty = LayerStore::new();
        let mut reg = Registry::new();
        assert!(reg.push(&image, &empty).is_err());
    }

    #[test]
    fn tags_listing() {
        let (image, store) = built("repo/app:2.0", "FROM alpine:3.4");
        let mut reg = Registry::new();
        reg.push(&image, &store).unwrap();
        assert!(reg.contains("repo/app:2.0"));
        assert_eq!(reg.tags().collect::<Vec<_>>(), vec!["repo/app:2.0"]);
    }

    #[test]
    fn image_lookup() {
        let (image, store) = built("repo/app:2.0", "FROM alpine:3.4");
        let mut reg = Registry::new();
        reg.push(&image, &store).unwrap();
        assert_eq!(reg.image("repo/app:2.0").unwrap().id, image.id);
        assert!(reg.image("ghost:1").is_none());
    }
}
