//! Fleet-scale layer distribution: sharded registry frontends,
//! node-local caches, and DES-scheduled concurrent pulls.
//!
//! The paper's Fig 1 workflow ends with "pull everywhere" — and at HPC
//! scale *everywhere* is thousands of nodes hitting the registry at
//! once.  This module replaces the flat-bandwidth [`Registry::pull`]
//! model with a distribution tier whose mechanisms mirror what real
//! registries (Trow's sharded blob store) and HPC runtimes (Shifter's
//! node-local image cache) do:
//!
//! * [`ShardedRegistry`] — the registry catalogue fronted by `S` shard
//!   frontends, one [`FifoResource`] per shard.  A layer's shard is a
//!   pure function of its content hash, so every client agrees where a
//!   blob lives without coordination, and `N` concurrent pullers
//!   contend realistically per shard instead of sharing one bandwidth
//!   number.  Transfer times come from [`PathCost::registry_wan`].
//! * [`Fleet`] — `N` nodes, each with a content-addressed
//!   [`LayerCache`], connected by an intra-cluster [`Fabric`].
//! * [`Fleet::deploy`] — the DES-scheduled concurrent pull of one image
//!   onto every node.  With [`FanOut::Peer`] (Trow's distribution
//!   model) each layer missing everywhere crosses the WAN **once**,
//!   through its shard, to a seeder node; holders then serve `arity`
//!   siblings per fan-out wave, so the cluster-internal copies ride the
//!   fast fabric and the WAN sees `O(unique layers)` bytes rather than
//!   `O(nodes × layers)`.  [`FanOut::Direct`] is the contention
//!   baseline: every node pulls every missing layer from its shard.
//! * **Fault awareness** — [`Fleet::deploy_with_faults`] threads a
//!   [`FaultSchedule`] through the same wave machinery: WAN transfers
//!   retry under a [`RetryPolicy`] (capped exponential backoff with
//!   [`SimRng`] jitter and a per-transfer timeout), pulls fail over to
//!   surviving registry shards during outage windows
//!   ([`ShardedRegistry::apply_faults`]), fan-out re-parents around
//!   crashed peers, and the report grows
//!   [`retried_bytes`](FleetReport::retried_bytes)/availability
//!   columns instead of assuming every transfer lands.  An empty
//!   schedule is invisible: [`Fleet::deploy`] is the zero-fault
//!   wrapper and stays bit-identical to the fault-free model.
//!
//! A warm re-deploy — every layer already resident in every node cache
//! — transfers zero registry bytes and zero intra-cluster bytes; each
//! node pays only the local per-layer metadata check, which is why the
//! `fig1-scale` figure shows warm makespans orders of magnitude under
//! cold ones.
//!
//! # Node-class collapsing: the O(classes × layers) engine
//!
//! [`Fleet`] walks every node per layer, which caps `fig1-scale` at
//! ~16 384 nodes.  [`ClassFleet`] is the collapsed engine: nodes with
//! identical (cached-layer set, shard assignment, fan-out wave
//! position, retry/fault state) form a [`NodeClass`] — a [`NodeSet`]
//! of members plus **one** representative [`LayerCache`] whose
//! accounting is charged at class multiplicity
//! ([`CacheStats::add_scaled`]).  Classes split lazily when something
//! differentiates members (a deploy-scope boundary, a fault or
//! eviction storm striking one node, a fan-out wave consuming part of
//! a class) and re-merge after each wave when representative states
//! reconverge ([`LayerCache::recency_signature`]), so a fault-free
//! million-node deploy costs O(waves × layers) events through the same
//! calendar [`EventQueue`](crate::des::EventQueue) (class-level completions enter via
//! `push_batch`).  [`Fleet`] is retained as the per-node reference
//! implementation — the same pattern as `HeapEventQueue` — and for
//! fleets of any size the collapsed path renders byte-identically
//! (`class_equivalence` tests + the CI golden diff gate enforce it at
//! ≤ 16 384 nodes).  [`DeployEngine`] dispatches between the two:
//! [`FanOut::Direct`] is inherently O(nodes) and always runs per-node.
//!
//! [`Registry::pull`]: super::registry::Registry::pull
//! [`FifoResource`]: crate::des::FifoResource
//! [`PathCost::registry_wan`]: crate::net::PathCost::registry_wan

use std::ops::Range;

use crate::des::{
    CellQueue, Duration, Fault, FaultSchedule, FaultStats, FifoResource, QueueStats, SimRng,
    VirtualTime,
};
use crate::net::{wan_lookahead, Fabric, PathCost};
use crate::util::human;

use super::cache::{CacheStats, LayerCache};
use super::image::{Image, Layer, LayerId};
use super::lifecycle::Container;
use super::registry::{MissingLayer, PullError, PullReport, Registry};
use super::store::LayerStore;

/// One shard outage window: `(from, until)`; `None` = never recovers.
type OutageWindow = (VirtualTime, Option<VirtualTime>);

/// The registry catalogue fronted by per-shard transfer queues.
///
/// Wraps a [`Registry`] (tags + blobs) and schedules every blob
/// transfer through the [`FifoResource`] frontend owning that blob's
/// content hash, in virtual time.  This is the DES-scheduled
/// replacement for the flat [`Registry::pull`] bandwidth model.
///
/// [`Registry::pull`]: super::registry::Registry::pull
#[derive(Debug)]
pub struct ShardedRegistry {
    registry: Registry,
    shards: Vec<FifoResource>,
    wan: PathCost,
    /// Outage windows per shard, installed by
    /// [`apply_faults`](Self::apply_faults).
    outages: Vec<Vec<OutageWindow>>,
}

/// What one failover-aware transfer submission did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardAttempt {
    /// A live shard accepted the transfer.
    Served {
        /// Shard that served the transfer (the owner, or a failover
        /// target when the owner was down).
        shard: usize,
        /// Completion instant under FIFO contention on that shard.
        done: VirtualTime,
        /// Whether the owner shard was down and the pull was
        /// re-hashed to a surviving shard.
        failover: bool,
    },
    /// Every shard was inside an outage window at submission time.
    AllDown {
        /// Earliest instant any shard recovers (`None` if no shard
        /// ever does).
        next_up: Option<VirtualTime>,
    },
}

impl ShardedRegistry {
    /// Front `registry` with `shards` single-server WAN frontends
    /// (each with the [`PathCost::registry_wan`] link cost).
    ///
    /// [`PathCost::registry_wan`]: crate::net::PathCost::registry_wan
    pub fn new(registry: Registry, shards: usize) -> Self {
        assert!(shards >= 1, "registry needs at least one shard");
        ShardedRegistry {
            registry,
            shards: vec![FifoResource::new(1); shards],
            wan: PathCost::registry_wan(),
            outages: vec![Vec::new(); shards],
        }
    }

    /// Override the per-shard WAN link cost.
    pub fn with_wan(mut self, wan: PathCost) -> Self {
        self.wan = wan;
        self
    }

    /// The wrapped catalogue (tags, blobs).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Mutable catalogue access (for pushes outside [`push`](Self::push)).
    pub fn registry_mut(&mut self) -> &mut Registry {
        &mut self.registry
    }

    /// Number of shard frontends.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The per-shard WAN link cost.
    pub fn wan(&self) -> PathCost {
        self.wan
    }

    /// Push an image into the catalogue (instantaneous control-plane
    /// operation; only pulls are scheduled in virtual time here).
    pub fn push(&mut self, image: &Image, source: &LayerStore) -> Result<(), MissingLayer> {
        self.registry.push(image, source)
    }

    /// Install the shard outage windows of `schedule`, replacing any
    /// previous set.  Windows targeting shards this registry does not
    /// have are ignored (schedules are generated against a fleet
    /// config, not a specific registry).
    pub fn apply_faults(&mut self, schedule: &FaultSchedule) {
        self.clear_outages();
        for &(shard, from, until) in schedule.shard_windows() {
            if shard < self.shards.len() {
                self.outages[shard].push((from, until));
            }
        }
    }

    /// Drop all installed outage windows (every shard healthy again).
    pub fn clear_outages(&mut self) {
        for windows in &mut self.outages {
            windows.clear();
        }
    }

    /// Whether `shard` is inside an installed outage window at `t`.
    pub fn shard_down_at(&self, shard: usize, t: VirtualTime) -> bool {
        self.outages[shard].iter().any(|&(from, until)| {
            from <= t
                && match until {
                    None => true,
                    Some(u) => t < u,
                }
        })
    }

    /// Earliest instant at or after `t` when `shard` is up (`None` if
    /// it is inside a window that never closes).
    pub fn shard_next_up(&self, shard: usize, t: VirtualTime) -> Option<VirtualTime> {
        let mut t = t;
        loop {
            let covering = self.outages[shard].iter().find(|&&(from, until)| {
                from <= t
                    && match until {
                        None => true,
                        Some(u) => t < u,
                    }
            });
            match covering {
                None => return Some(t),
                Some(&(_, None)) => return None,
                Some(&(_, Some(u))) => t = u,
            }
        }
    }

    /// Which shard owns `id` — a pure function of the content hash, so
    /// every client agrees without coordination (rendezvous placement,
    /// as in Trow's blob store).
    pub fn shard_of(&self, id: &LayerId) -> usize {
        let take = id.0.len().min(16);
        let h = id
            .0
            .get(..take)
            .and_then(|prefix| u64::from_str_radix(prefix, 16).ok())
            // non-hex ids (hand-built in tests) fall back to a byte fold
            .unwrap_or_else(|| {
                id.0.bytes()
                    .fold(0u64, |acc, b| acc.wrapping_mul(31).wrapping_add(b as u64))
            });
        (h % self.shards.len() as u64) as usize
    }

    /// Schedule the transfer of `bytes` of blob `id` starting no
    /// earlier than `arrival`; returns the completion instant under
    /// FIFO contention on the owning shard.  Ignores outage windows —
    /// the fault-aware path is
    /// [`submit_transfer_failover`](Self::submit_transfer_failover).
    pub fn submit_transfer(
        &mut self,
        arrival: VirtualTime,
        id: &LayerId,
        bytes: u64,
    ) -> VirtualTime {
        let shard = self.shard_of(id);
        let service = self.wan.transfer(bytes);
        self.shards[shard].submit(arrival, service)
    }

    /// Outage-aware transfer submission: the owning shard serves when
    /// up; otherwise the pull re-hashes around the ring to the first
    /// surviving shard (every replica holds the blob — the shards
    /// front one catalogue).  With no outage windows installed this is
    /// byte-identical to [`submit_transfer`](Self::submit_transfer).
    pub fn submit_transfer_failover(
        &mut self,
        arrival: VirtualTime,
        id: &LayerId,
        bytes: u64,
    ) -> ShardAttempt {
        let owner = self.shard_of(id);
        let count = self.shards.len();
        for k in 0..count {
            let shard = (owner + k) % count;
            if self.shard_down_at(shard, arrival) {
                continue;
            }
            let service = self.wan.transfer(bytes);
            let done = self.shards[shard].submit(arrival, service);
            return ShardAttempt::Served {
                shard,
                done,
                failover: k > 0,
            };
        }
        let next_up = (0..count)
            .filter_map(|shard| self.shard_next_up(shard, arrival))
            .min();
        ShardAttempt::AllDown { next_up }
    }

    /// Fetch one blob: returns the layer plus its completion instant.
    pub fn fetch(
        &mut self,
        arrival: VirtualTime,
        id: &LayerId,
    ) -> Result<(Layer, VirtualTime), PullError> {
        let layer = self
            .registry
            .layers
            .get(id)
            .cloned()
            .ok_or_else(|| PullError::CorruptRegistry(id.clone()))?;
        let done = self.submit_transfer(arrival, id, layer.bytes);
        Ok((layer, done))
    }

    /// DES-scheduled single-client pull of `reference` into `dest`
    /// starting at `now`: each missing layer is fetched concurrently
    /// through its shard; the report's `time` is the span until the
    /// last layer lands.  Byte/layer accounting matches the flat
    /// [`Registry::pull`] exactly — only the timing model differs.
    ///
    /// [`Registry::pull`]: super::registry::Registry::pull
    pub fn pull_at(
        &mut self,
        now: VirtualTime,
        reference: &str,
        dest: &mut LayerStore,
    ) -> Result<(Image, PullReport), PullError> {
        let image = self
            .registry
            .image(reference)
            .cloned()
            .ok_or_else(|| PullError::UnknownReference(reference.to_string()))?;
        let missing: Vec<LayerId> = dest.missing(&image.layers).into_iter().cloned().collect();
        let mut bytes = 0u64;
        let mut done_at = now;
        for id in &missing {
            let (layer, done) = self.fetch(now, id)?;
            bytes += layer.bytes;
            done_at = done_at.max(done);
            dest.insert(layer);
        }
        let report = PullReport {
            reference: reference.to_string(),
            layers_transferred: missing.len(),
            layers_reused: image.layers.len() - missing.len(),
            bytes_transferred: bytes,
            time: done_at.since(now),
        };
        Ok((image, report))
    }

    /// Cumulative busy time per shard frontend.
    pub fn shard_busy(&self) -> Vec<Duration> {
        self.shards.iter().map(|s| s.busy_time()).collect()
    }

    /// Queueing delay a request arriving at `at` would see on each
    /// shard frontend (see [`FifoResource::backlog`]) — the saturation
    /// view an open-loop storm reports alongside latency percentiles.
    pub fn shard_backlog(&self, at: VirtualTime) -> Vec<Duration> {
        self.shards.iter().map(|s| s.backlog(at)).collect()
    }

    /// Aggregate WAN drain rate over all shard frontends, in bytes per
    /// second — the capacity an offered-load sweep is calibrated
    /// against (per-request RTT overhead comes on top).
    pub fn aggregate_bandwidth(&self) -> f64 {
        self.wan.beta_bytes_per_sec * self.shards.len() as f64
    }

    /// Per-shard utilisation over `horizon`, counting only service
    /// delivered beyond the `busy_before` snapshot (a prior
    /// [`shard_busy`](Self::shard_busy) result).
    pub fn shard_utilisation(&self, busy_before: &[Duration], horizon: Duration) -> Vec<f64> {
        self.shards
            .iter()
            .zip(busy_before)
            .map(|(s, &b)| s.utilisation(b, horizon))
            .collect()
    }

    /// Forget all shard queue state (fresh deployment campaign).
    /// Installed outage windows are kept — they belong to the fault
    /// schedule, not the queues; see
    /// [`clear_outages`](Self::clear_outages).
    pub fn reset_clocks(&mut self) {
        for s in &mut self.shards {
            s.reset();
        }
    }
}

/// How layers spread inside the cluster once a copy exists there.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FanOut {
    /// Every node fetches every missing layer from the registry shard
    /// itself — the no-dedup baseline that exposes WAN contention
    /// (`O(nodes × layers)` registry bytes).
    Direct,
    /// Trow-style peer distribution: the first puller seeds the layer
    /// over the WAN (once per layer, through its shard), then every
    /// holder serves `arity` sibling nodes per fan-out wave over the
    /// cluster fabric — holders grow geometrically, so full coverage
    /// takes `O(log nodes)` waves.
    Peer {
        /// Siblings each holder serves per wave (≥ 1).
        arity: usize,
    },
}

/// Retry discipline for fault-aware transfers: capped exponential
/// backoff with deterministic [`SimRng`] jitter plus an optional
/// per-transfer timeout.
///
/// A transfer that starts inside a WAN drop window is lost and backed
/// off *blindly* (the client cannot sense the window), so a long
/// enough window exhausts `max_attempts` and the target is reported
/// permanently failed rather than retried forever.  When every
/// registry shard is down the front door *can* publish a recovery
/// instant, so those retries aim at `max(recovery, backoff)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts per transfer, the first included (≥ 1).
    pub max_attempts: u32,
    /// Backoff before the first retry; doubles each further retry.
    pub base_backoff: Duration,
    /// Ceiling the exponential backoff saturates at.
    pub max_backoff: Duration,
    /// Multiplicative jitter half-width applied to each backoff
    /// (`0.2` = ±20%); `0.0` draws nothing from the rng stream.
    pub jitter: f64,
    /// Abandon a transfer whose completion lies further than this
    /// beyond its start (`None` = wait forever).
    pub timeout: Option<Duration>,
}

impl RetryPolicy {
    /// No retries at all: one attempt, no backoff, no timeout.  The
    /// policy [`Fleet::deploy`] runs with — it never consults the rng,
    /// which keeps the fault-free path bit-identical.
    pub fn none() -> Self {
        RetryPolicy {
            max_attempts: 1,
            base_backoff: Duration::ZERO,
            max_backoff: Duration::ZERO,
            jitter: 0.0,
            timeout: None,
        }
    }

    /// The deployment-campaign default: 6 attempts, 50 ms base backoff
    /// doubling to a 5 s cap, ±20% jitter, 5-minute per-transfer
    /// timeout.
    pub fn hpc() -> Self {
        RetryPolicy {
            max_attempts: 6,
            base_backoff: Duration::from_millis(50),
            max_backoff: Duration::from_secs_f64(5.0),
            jitter: 0.2,
            timeout: Some(Duration::from_secs_f64(300.0)),
        }
    }

    /// Backoff before attempt `attempt` (attempt 1 is the first try,
    /// so its "backoff" is the base; attempt `k` waits
    /// `base × 2^(k-1)`, saturating at [`max_backoff`]).  Jitter is
    /// drawn from `rng` only when one is supplied and
    /// [`jitter`](Self::jitter) is non-zero.
    ///
    /// [`max_backoff`]: Self::max_backoff
    pub fn backoff(&self, attempt: u32, rng: Option<&mut SimRng>) -> Duration {
        let exp = attempt.saturating_sub(1).min(20);
        let capped = Duration::from_nanos(
            self.base_backoff
                .as_nanos()
                .saturating_mul(1u64 << exp)
                .min(self.max_backoff.as_nanos()),
        );
        match rng {
            Some(r) if self.jitter > 0.0 => capped.scale(r.jitter(self.jitter)),
            _ => capped,
        }
    }
}

/// Static description of a deployment fleet.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of compute nodes pulling the image.
    pub nodes: usize,
    /// Intra-cluster distribution strategy.
    pub fan_out: FanOut,
    /// Per-node layer-cache capacity in bytes (`u64::MAX` = unbounded).
    pub cache_capacity_bytes: u64,
    /// Fabric carrying intra-cluster fan-out hops.
    pub fabric: Fabric,
    /// Local metadata check a node pays per image layer on every
    /// deploy, hit or miss (the `shifterimg`-style verify/mount cost —
    /// what a fully warm deploy still costs).
    pub per_layer_check: Duration,
    /// Lookahead domains for the wave scheduler (see
    /// [`crate::des::pdes`]): 1 runs the serial reference
    /// [`EventQueue`](crate::des::EventQueue), more partitions the
    /// fleet's completion events
    /// by node index under the WAN lookahead bound
    /// ([`crate::net::wan_lookahead`]).  Renders are byte-identical
    /// for any value — this is a pure parallelism knob (`--domains`).
    pub domains: usize,
}

impl FleetConfig {
    /// An Edison-like deployment target: Aries fabric, binary peer
    /// fan-out, unbounded node caches, 2 ms local metadata check per
    /// layer, serial scheduling.  (The registry shard count lives on
    /// the [`ShardedRegistry`] the fleet pulls through.)
    pub fn hpc(nodes: usize) -> Self {
        FleetConfig {
            nodes,
            fan_out: FanOut::Peer { arity: 2 },
            cache_capacity_bytes: u64::MAX,
            fabric: Fabric::aries(),
            per_layer_check: Duration::from_millis(2),
            domains: 1,
        }
    }
}

/// What one fleet deployment did (the fleet analogue of [`PullReport`]).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetReport {
    /// Image reference deployed.
    pub reference: String,
    /// Nodes targeted by this wave (the deploy scope).
    pub nodes: usize,
    /// Layers in the image (with duplicates, if any).
    pub layers_total: usize,
    /// Distinct layers considered for transfer.
    pub unique_layers: usize,
    /// WAN transfers performed (shard → cluster), lost attempts
    /// included.
    pub wan_transfers: usize,
    /// Bytes that crossed the WAN from registry shards.
    pub wan_bytes: u64,
    /// Bytes copied node-to-node inside the cluster.
    pub intra_bytes: u64,
    /// Bytes that crossed a link but never landed in a cache: WAN
    /// attempts lost to drop windows or timeouts, plus copies that
    /// arrived while their target node was down.  The conservation
    /// invariant is `total_bytes() == bytes admitted + retried_bytes`
    /// (for unbounded caches).
    pub retried_bytes: u64,
    /// Transfer re-attempts scheduled (WAN retries + re-deliveries).
    pub retries: u64,
    /// Pulls re-hashed to a surviving shard during an outage.
    pub failovers: u64,
    /// Scope nodes newly given up on this wave (crashed and never
    /// rejoining, or out of retry budget).
    pub permanently_failed: usize,
    /// Virtual instant the deployment started.
    pub started_at: VirtualTime,
    /// Span from start until the slowest node finished (transfers +
    /// per-layer local checks).
    pub makespan: Duration,
    /// Cache accounting for this wave only (summed over nodes).
    pub cache: CacheStats,
    /// Per-shard utilisation over the makespan (busy / makespan).
    pub shard_utilisation: Vec<f64>,
    /// Containers created and started on the fleet after the pull.
    pub containers_started: usize,
    /// Fault accounting: injected side from the schedule's windows,
    /// reaction side from this wave's counters.  All-zero for a
    /// fault-free wave.
    pub fault: FaultStats,
    /// Calendar-queue counters of the wave's transfer scheduler (one
    /// ready event per node per transferred layer; a fully warm
    /// re-deploy schedules none).  See `des::stats`.
    pub queue: QueueStats,
}

impl FleetReport {
    /// All bytes moved anywhere: WAN plus intra-cluster.
    pub fn total_bytes(&self) -> u64 {
        self.wan_bytes + self.intra_bytes
    }

    /// Bytes that actually landed in a node cache:
    /// [`total_bytes`](Self::total_bytes) minus the wasted
    /// [`retried_bytes`](Self::retried_bytes).
    pub fn delivered_bytes(&self) -> u64 {
        self.total_bytes().saturating_sub(self.retried_bytes)
    }

    /// Fleet availability over this wave's makespan:
    /// `1 - downtime / (nodes × makespan)` (see
    /// [`FaultStats::availability`]).
    pub fn availability(&self) -> f64 {
        self.fault.availability(self.nodes, self.makespan)
    }

    /// One-paragraph trace line for CLI output.  Fault-free waves
    /// render exactly as before; the retry/failover tail appears only
    /// when something went wrong.
    pub fn render(&self) -> String {
        let mut text = format!(
            "deploy {} -> {} nodes: makespan {}, WAN {} in {} transfer(s), \
             intra-cluster {}, cache hit rate {:.0}%, shard util {}, \
             {} ready events (queue depth hwm {})",
            self.reference,
            human::thousands(self.nodes as u64),
            self.makespan,
            human::bytes(self.wan_bytes),
            self.wan_transfers,
            human::bytes(self.intra_bytes),
            self.cache.hit_rate() * 100.0,
            self.shard_utilisation
                .iter()
                .map(|u| format!("{:.0}%", u * 100.0))
                .collect::<Vec<_>>()
                .join("/"),
            self.queue.pushes,
            self.queue.depth_hwm,
        );
        if self.retries != 0
            || self.failovers != 0
            || self.retried_bytes != 0
            || self.permanently_failed != 0
        {
            text.push_str(&format!(
                ", {} retry(ies), {} failover(s), {} re-sent, \
                 {} node(s) permanently failed, availability {:.4}",
                self.retries,
                self.failovers,
                human::bytes(self.retried_bytes),
                self.permanently_failed,
                self.availability(),
            ));
        }
        text
    }
}

/// Reaction-side counters one fault-aware wave accumulates.
#[derive(Default)]
struct FaultAccum {
    wan_bytes: u64,
    wan_transfers: usize,
    retried_bytes: u64,
    retries: u64,
    failovers: u64,
    transfers_dropped: u64,
}

/// Borrowed fault context threaded through one deployment wave; its
/// methods keep the retry loops (and their accounting) in one place.
struct WaveCtx<'a> {
    faults: &'a FaultSchedule,
    policy: &'a RetryPolicy,
    rng: &'a mut SimRng,
    acc: FaultAccum,
}

impl WaveCtx<'_> {
    /// One WAN transfer of `bytes` of `id` starting no earlier than
    /// `start`, with shard failover plus drop-window/timeout retries
    /// under the policy.  Returns the completion instant of the first
    /// surviving attempt, or `None` once the retry budget is spent
    /// (or no shard ever recovers).
    fn wan(
        &mut self,
        registry: &mut ShardedRegistry,
        id: &LayerId,
        bytes: u64,
        start: VirtualTime,
    ) -> Option<VirtualTime> {
        let mut at = start;
        let mut attempt = 1u32;
        loop {
            match registry.submit_transfer_failover(at, id, bytes) {
                ShardAttempt::Served { done, failover, .. } => {
                    self.acc.wan_bytes += bytes;
                    self.acc.wan_transfers += 1;
                    if failover {
                        self.acc.failovers += 1;
                    }
                    // a transfer started inside a drop window is lost;
                    // one running past the per-transfer timeout is
                    // abandoned at start + timeout
                    let lost = self.faults.drop_until(at).is_some();
                    let gave_up_at = match self.policy.timeout {
                        Some(limit) if !lost && done.since(at) > limit => Some(at + limit),
                        _ => None,
                    };
                    if !lost && gave_up_at.is_none() {
                        return Some(done);
                    }
                    self.acc.retried_bytes += bytes;
                    self.acc.transfers_dropped += 1;
                    if attempt >= self.policy.max_attempts {
                        return None;
                    }
                    attempt += 1;
                    self.acc.retries += 1;
                    // the client cannot sense a drop window, so a lost
                    // transfer backs off blindly; a timeout is only
                    // known once the limit fires
                    let pause = self.policy.backoff(attempt, Some(&mut *self.rng));
                    at = match gave_up_at {
                        Some(abandoned) => abandoned + pause,
                        None => at + pause,
                    };
                }
                ShardAttempt::AllDown { next_up } => {
                    let up = next_up?;
                    if attempt >= self.policy.max_attempts {
                        return None;
                    }
                    attempt += 1;
                    self.acc.retries += 1;
                    // the registry front door redirects, so this retry
                    // can aim at the published recovery instant
                    let pause = self.policy.backoff(attempt, Some(&mut *self.rng));
                    at = up.max(at + pause);
                }
            }
        }
    }

    /// Direct-mode delivery to one node: WAN transfer, then re-pull
    /// whenever the bytes arrive while the node is down.  `None` =
    /// the node (or the registry) is a lost cause.
    fn deliver_direct(
        &mut self,
        registry: &mut ShardedRegistry,
        id: &LayerId,
        bytes: u64,
        node: usize,
        start: VirtualTime,
    ) -> Option<VirtualTime> {
        let mut done = self.wan(registry, id, bytes, start)?;
        loop {
            match self.faults.node_next_up(node, done) {
                Some(up) if up == done => return Some(done),
                Some(up) => {
                    // arrived while the node was down: wasted transfer,
                    // pull again once it rejoins
                    self.acc.retried_bytes += bytes;
                    self.acc.retries += 1;
                    done = self.wan(registry, id, bytes, up)?;
                }
                None => {
                    self.acc.retried_bytes += bytes;
                    return None;
                }
            }
        }
    }
}

/// `N` nodes with node-local layer caches, deploying images pulled
/// through a [`ShardedRegistry`].  Successive [`deploy`](Fleet::deploy)
/// calls share the caches (that is the point: the second deploy is
/// warm) and advance the fleet's virtual clock.
#[derive(Debug)]
pub struct Fleet {
    config: FleetConfig,
    caches: Vec<LayerCache>,
    containers: Vec<Container>,
    clock: VirtualTime,
    next_container_id: u64,
    /// Nodes given up on by a previous fault-injected wave.
    dead: Vec<bool>,
    /// Latest wave start whose eviction storms have been applied
    /// (`None` = no wave ran yet); keeps each storm a one-shot.
    storm_mark: Option<VirtualTime>,
}

impl Fleet {
    /// A cold fleet (every node cache empty) at virtual time zero.
    pub fn new(config: FleetConfig) -> Self {
        assert!(config.nodes >= 1, "fleet needs at least one node");
        if let FanOut::Peer { arity } = config.fan_out {
            assert!(arity >= 1, "peer fan-out needs arity >= 1");
        }
        let caches = (0..config.nodes)
            .map(|_| LayerCache::new(config.cache_capacity_bytes))
            .collect();
        let dead = vec![false; config.nodes];
        Fleet {
            config,
            caches,
            containers: Vec::new(),
            clock: VirtualTime::ZERO,
            next_container_id: 0,
            dead,
            storm_mark: None,
        }
    }

    /// The fleet's configuration.
    pub fn config(&self) -> &FleetConfig {
        &self.config
    }

    /// Node-local caches, indexed by node.
    pub fn caches(&self) -> &[LayerCache] {
        &self.caches
    }

    /// Mutable cache access (tests pre-warm subsets of the fleet).
    pub fn caches_mut(&mut self) -> &mut [LayerCache] {
        &mut self.caches
    }

    /// Containers created by the most recent deployment wave.
    pub fn containers(&self) -> &[Container] {
        &self.containers
    }

    /// The fleet's virtual clock (advances with each deploy wave).
    pub fn now(&self) -> VirtualTime {
        self.clock
    }

    /// Per-node permanent-failure flags (`true` = given up on by a
    /// previous fault-injected wave; the node takes no further part
    /// in deployments).
    pub fn failed_nodes(&self) -> &[bool] {
        &self.dead
    }

    /// Sum of every node cache's lifetime counters.
    pub fn cache_totals(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for c in &self.caches {
            total.merge(&c.stats());
        }
        total
    }

    /// Deploy `reference` onto every node concurrently, in virtual
    /// time: consult each node cache, seed cache-missing layers from
    /// the owning registry shard, fan copies out across the cluster
    /// fabric, admit them into the node caches, then create and start
    /// one container per node.  Returns the wave's [`FleetReport`].
    ///
    /// This is the fault-free wrapper around
    /// [`deploy_with_faults`](Self::deploy_with_faults): empty
    /// schedule, [`RetryPolicy::none`], full node scope — and the rng
    /// stream is never consulted, so reports are bit-identical to the
    /// pre-fault model.
    pub fn deploy(
        &mut self,
        registry: &mut ShardedRegistry,
        reference: &str,
    ) -> Result<FleetReport, PullError> {
        let nodes = self.config.nodes;
        let mut rng = SimRng::new(0, "fault-free");
        self.deploy_with_faults(
            registry,
            reference,
            0..nodes,
            &FaultSchedule::none(),
            &RetryPolicy::none(),
            &mut rng,
        )
    }

    /// Deploy `reference` onto the nodes in `scope` under a fault
    /// schedule and retry policy.
    ///
    /// Semantics on top of the fault-free wave:
    ///
    /// * **Eviction storms** at or before the wave start shed bytes
    ///   from the struck node's cache before lookups run (each storm
    ///   fires once across a campaign).
    /// * **WAN transfers** go through [`WaveCtx::wan`]: shard
    ///   failover, drop-window/timeout loss, capped backoff retries.
    /// * **Crashed nodes**: a copy arriving during a down window is
    ///   wasted (`retried_bytes`) and re-sent after the rejoin — from
    ///   a live holder over the fabric when one exists, else from the
    ///   registry.  Nodes that never rejoin (or exhaust the retry
    ///   budget) are marked permanently failed, skipped by later
    ///   waves, and reported in
    ///   [`permanently_failed`](FleetReport::permanently_failed).
    /// * **Scope** restricts which nodes deploy (rolling upgrades
    ///   target rings); caches and failure flags are fleet-wide, so
    ///   nodes outside the scope still serve as fan-out holders.
    ///
    /// Every retry loop either consumes retry budget or strictly
    /// advances virtual time past a finite fault window, so the wave
    /// always terminates: each scope node ends deployed or is
    /// reported permanently failed.
    pub fn deploy_with_faults(
        &mut self,
        registry: &mut ShardedRegistry,
        reference: &str,
        scope: Range<usize>,
        faults: &FaultSchedule,
        policy: &RetryPolicy,
        rng: &mut SimRng,
    ) -> Result<FleetReport, PullError> {
        let t0 = self.clock;
        let n = self.config.nodes;
        assert!(!scope.is_empty(), "deploy scope must name at least one node");
        assert!(scope.end <= n, "deploy scope exceeds the fleet");
        assert!(policy.max_attempts >= 1, "retry policy needs one attempt");
        let image = registry
            .registry()
            .image(reference)
            .cloned()
            .ok_or_else(|| PullError::UnknownReference(reference.to_string()))?;

        // distinct layers, first-appearance order (image stacks are
        // normally duplicate-free; dedup keeps the accounting honest)
        let mut unique: Vec<&LayerId> = Vec::new();
        for id in &image.layers {
            if !unique.contains(&id) {
                unique.push(id);
            }
        }

        let stats_before = self.cache_totals();
        // eviction storms that struck since the last wave land before
        // this wave's lookups, so the cache delta shows the damage
        let mark = self.storm_mark;
        for &(at, node, bytes) in faults.evict_storms() {
            let fresh = at <= t0
                && match mark {
                    None => true,
                    Some(m) => at > m,
                };
            if fresh && node < n {
                self.caches[node].shed(bytes);
            }
        }
        self.storm_mark = Some(t0);

        let busy_before = registry.shard_busy();
        let mut failed = self.dead.clone();
        let mut ctx = WaveCtx {
            faults,
            policy,
            rng,
            acc: FaultAccum::default(),
        };
        let mut intra_bytes = 0u64;
        // instant each node has all its layers (before local checks)
        let mut node_ready = vec![t0; n];
        // every transfer-completion instant is scheduled through one
        // cell queue (fan-out waves enter as batches) and drained
        // in time order at the end of its layer, so the depth
        // high-water mark in the report is the peak of concurrently
        // in-flight completions, not a lifetime push count.  With
        // --domains > 1 the completions partition by node index under
        // the WAN lookahead bound; the pop stream (and therefore the
        // report) is byte-identical either way.
        let mut sched: CellQueue<usize> =
            CellQueue::new(self.config.domains, wan_lookahead(), scope.len());

        for &id in &unique {
            let mut needers: Vec<usize> = Vec::new();
            for node in scope.clone() {
                if failed[node] {
                    continue;
                }
                if self.caches[node].lookup(id).is_none() {
                    needers.push(node);
                }
            }
            if needers.is_empty() {
                continue; // fully warm layer: no transfer anywhere
            }
            // node caches hold the blob (id + bytes + provenance), not
            // the file manifest — that stays in the catalogue, exactly
            // as a compressed blob cache on a real node would
            let blob = registry
                .registry()
                .layers
                .get(id)
                .ok_or_else(|| PullError::CorruptRegistry(id.clone()))?
                .blob();

            match self.config.fan_out {
                FanOut::Direct => {
                    let mut arrivals = Vec::with_capacity(needers.len());
                    for &node in &needers {
                        match ctx.deliver_direct(registry, id, blob.bytes, node, t0) {
                            Some(done) => {
                                arrivals.push((node, done, node));
                                self.caches[node].admit(blob.clone());
                            }
                            None => failed[node] = true,
                        }
                    }
                    sched.push_batch(arrivals);
                }
                FanOut::Peer { arity } => {
                    // live holders anywhere in the fleet can serve the
                    // fan-out, scope or not
                    let mut holder_nodes: Vec<usize> = (0..n)
                        .filter(|&node| !failed[node] && self.caches[node].contains(id))
                        .collect();

                    let (start, rest) = if holder_nodes.is_empty() {
                        // no holder anywhere: seed one copy over the
                        // WAN onto the first needer that is (or comes
                        // back) up
                        let mut remaining = needers.clone();
                        let mut seed: Option<(usize, VirtualTime)> = None;
                        let mut t_seed = t0;
                        while seed.is_none() && !remaining.is_empty() {
                            // earliest-available candidate; prune ones
                            // that never rejoin
                            let mut best: Option<(usize, VirtualTime)> = None;
                            let mut dead_idx: Vec<usize> = Vec::new();
                            for (idx, &node) in remaining.iter().enumerate() {
                                match ctx.faults.node_next_up(node, t_seed) {
                                    None => dead_idx.push(idx),
                                    Some(up) => {
                                        let better = match best {
                                            None => true,
                                            Some((_, b)) => up < b,
                                        };
                                        if better {
                                            best = Some((idx, up));
                                        }
                                    }
                                }
                            }
                            for &idx in dead_idx.iter().rev() {
                                let node = remaining.remove(idx);
                                failed[node] = true;
                                if let Some((b, _)) = best.as_mut() {
                                    if *b > idx {
                                        *b -= 1;
                                    }
                                }
                            }
                            let Some((idx, up)) = best else { break };
                            match ctx.wan(registry, id, blob.bytes, up) {
                                None => {
                                    // registry unreachable for good (or
                                    // budget spent): nobody in scope can
                                    // get this layer
                                    for node in remaining.drain(..) {
                                        failed[node] = true;
                                    }
                                    break;
                                }
                                Some(done) => {
                                    if ctx.faults.node_down_at(remaining[idx], done) {
                                        // seed arrived mid-crash: wasted
                                        ctx.acc.retried_bytes += blob.bytes;
                                        match ctx.faults.node_next_up(remaining[idx], done) {
                                            Some(up2) => {
                                                ctx.acc.retries += 1;
                                                t_seed = up2;
                                            }
                                            None => {
                                                let node = remaining.remove(idx);
                                                failed[node] = true;
                                            }
                                        }
                                    } else {
                                        seed = Some((idx, done));
                                    }
                                }
                            }
                        }
                        let Some((idx, done)) = seed else {
                            // every candidate died or the registry was
                            // unreachable: layer undeliverable in scope
                            continue;
                        };
                        let seeder = remaining.remove(idx);
                        sched.push(seeder, done, seeder);
                        self.caches[seeder].admit(blob.clone());
                        holder_nodes.push(seeder);
                        (done, remaining)
                    } else {
                        (t0, needers.clone())
                    };

                    let hop = self.config.fabric.p2p(blob.bytes, false);
                    let mut served = 0usize;
                    let mut t = start;
                    let mut resend: Vec<(VirtualTime, usize)> = Vec::new();
                    while served < rest.len() {
                        let live = holder_nodes
                            .iter()
                            .filter(|&&h| !ctx.faults.node_down_at(h, t))
                            .count();
                        if live == 0 {
                            // every holder is down: wait for the first
                            // rejoin, or fall back to the registry for
                            // everyone still waiting
                            let next = holder_nodes
                                .iter()
                                .filter_map(|&h| ctx.faults.node_next_up(h, t))
                                .min();
                            match next {
                                Some(up) => {
                                    t = up;
                                }
                                None => {
                                    for &node in &rest[served..] {
                                        ctx.acc.retries += 1;
                                        resend.push((t, node));
                                    }
                                    served = rest.len();
                                }
                            }
                            continue;
                        }
                        let wave = (live * arity).min(rest.len() - served);
                        t += hop;
                        let mut arrivals = Vec::with_capacity(wave);
                        for &node in &rest[served..served + wave] {
                            intra_bytes += blob.bytes;
                            if ctx.faults.node_down_at(node, t) {
                                // copy arrived mid-crash: wasted hop
                                ctx.acc.retried_bytes += blob.bytes;
                                if ctx.faults.node_next_up(node, t).is_some() {
                                    ctx.acc.retries += 1;
                                    resend.push((t, node));
                                } else {
                                    failed[node] = true;
                                }
                            } else {
                                arrivals.push((node, t, node));
                                self.caches[node].admit(blob.clone());
                                holder_nodes.push(node);
                            }
                        }
                        sched.push_batch(arrivals);
                        served += wave;
                    }

                    // second pass: nodes that were down when their copy
                    // arrived re-pull once they rejoin — from a live
                    // holder over the fabric when one exists, else from
                    // the registry
                    for (when, node) in resend {
                        if failed[node] {
                            continue;
                        }
                        let mut when = when;
                        loop {
                            let Some(up) = ctx.faults.node_next_up(node, when) else {
                                failed[node] = true;
                                break;
                            };
                            let src_live = holder_nodes
                                .iter()
                                .any(|&h| !ctx.faults.node_down_at(h, up));
                            let arrival = if src_live {
                                intra_bytes += blob.bytes;
                                up + hop
                            } else {
                                match ctx.wan(registry, id, blob.bytes, up) {
                                    Some(done) => done,
                                    None => {
                                        failed[node] = true;
                                        break;
                                    }
                                }
                            };
                            if ctx.faults.node_down_at(node, arrival) {
                                ctx.acc.retried_bytes += blob.bytes;
                                ctx.acc.retries += 1;
                                when = arrival;
                                continue;
                            }
                            sched.push(node, arrival, node);
                            self.caches[node].admit(blob.clone());
                            holder_nodes.push(node);
                            break;
                        }
                    }
                }
            }

            // drain this layer's completions in time order; a node's
            // readiness is its last event across all layers
            while let Some((ready, node)) = sched.pop() {
                node_ready[node] = node_ready[node].max(ready);
            }
        }
        let queue = sched.stats();

        // local per-layer verify/mount, then create + start a container
        // on every surviving node in scope
        let check = self.config.per_layer_check * image.layers.len() as u64;
        self.containers.clear();
        let mut finish = t0;
        let mut started = 0usize;
        for node in scope.clone() {
            if failed[node] {
                continue;
            }
            let done = node_ready[node] + check;
            finish = finish.max(done);
            let mut c = Container::create(self.next_container_id, image.id.clone(), done);
            self.next_container_id += 1;
            c.start(done).expect("fresh container starts");
            self.containers.push(c);
            started += 1;
        }
        let makespan = finish.since(t0);
        self.clock = finish;

        let shard_utilisation = registry.shard_utilisation(&busy_before, makespan);

        let newly_failed = failed.iter().filter(|&&f| f).count()
            - self.dead.iter().filter(|&&f| f).count();
        self.dead = failed;
        let mut fault = faults.stats_over(t0, finish);
        fault.retries = ctx.acc.retries;
        fault.failovers = ctx.acc.failovers;
        fault.transfers_dropped = ctx.acc.transfers_dropped;
        fault.permanent_failures = newly_failed as u64;

        Ok(FleetReport {
            reference: reference.to_string(),
            nodes: scope.len(),
            layers_total: image.layers.len(),
            unique_layers: unique.len(),
            wan_transfers: ctx.acc.wan_transfers,
            wan_bytes: ctx.acc.wan_bytes,
            intra_bytes,
            retried_bytes: ctx.acc.retried_bytes,
            retries: ctx.acc.retries,
            failovers: ctx.acc.failovers,
            permanently_failed: newly_failed,
            started_at: t0,
            makespan,
            cache: self.cache_totals().since(&stats_before),
            shard_utilisation,
            containers_started: started,
            fault,
            queue,
        })
    }
}

// ===================================================================
// Node-class collapsing: the O(classes × layers) deploy engine
// ===================================================================

/// A set of node indices stored as sorted, disjoint, coalesced
/// half-open runs — class membership for [`NodeClass`].
///
/// A fresh fleet is one run `[0, n)`; splits carve runs and merges
/// coalesce them back, so a fault-free campaign keeps the
/// representation O(classes), never O(nodes).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeSet {
    runs: Vec<(usize, usize)>,
}

impl NodeSet {
    /// The contiguous set `[range.start, range.end)`.
    pub fn from_range(range: Range<usize>) -> Self {
        if range.is_empty() {
            NodeSet { runs: Vec::new() }
        } else {
            NodeSet {
                runs: vec![(range.start, range.end)],
            }
        }
    }

    /// The one-node set `{node}`.
    pub fn singleton(node: usize) -> Self {
        NodeSet {
            runs: vec![(node, node + 1)],
        }
    }

    /// Number of member nodes.
    pub fn len(&self) -> usize {
        self.runs.iter().map(|&(s, e)| e - s).sum()
    }

    /// Whether the set has no members.
    pub fn is_empty(&self) -> bool {
        self.runs.is_empty()
    }

    /// Smallest member, if any.
    pub fn first(&self) -> Option<usize> {
        self.runs.first().map(|&(s, _)| s)
    }

    /// The backing runs, sorted and disjoint.
    pub fn runs(&self) -> &[(usize, usize)] {
        &self.runs
    }

    /// Members in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.runs.iter().flat_map(|&(s, e)| s..e)
    }

    fn run_of(&self, node: usize) -> Option<usize> {
        self.runs
            .binary_search_by(|&(s, e)| {
                if node < s {
                    std::cmp::Ordering::Greater
                } else if node >= e {
                    std::cmp::Ordering::Less
                } else {
                    std::cmp::Ordering::Equal
                }
            })
            .ok()
    }

    /// Whether `node` is a member.
    pub fn contains(&self, node: usize) -> bool {
        self.run_of(node).is_some()
    }

    /// Remove one member; returns whether it was present.
    pub fn remove(&mut self, node: usize) -> bool {
        let Some(i) = self.run_of(node) else {
            return false;
        };
        let (s, e) = self.runs[i];
        match (node == s, node + 1 == e) {
            (true, true) => {
                self.runs.remove(i);
            }
            (true, false) => self.runs[i] = (s + 1, e),
            (false, true) => self.runs[i] = (s, e - 1),
            (false, false) => {
                self.runs[i] = (s, node);
                self.runs.insert(i + 1, (node + 1, e));
            }
        }
        true
    }

    /// Remove every member of `other` (set difference, in place).
    pub fn subtract(&mut self, other: &NodeSet) {
        let mut out = Vec::with_capacity(self.runs.len() + other.runs.len());
        for &(start, end) in &self.runs {
            let mut s = start;
            for &(os, oe) in &other.runs {
                if oe <= s {
                    continue;
                }
                if os >= end {
                    break;
                }
                if os > s {
                    out.push((s, os));
                }
                s = s.max(oe);
                if s >= end {
                    break;
                }
            }
            if s < end {
                out.push((s, end));
            }
        }
        self.runs = out;
    }

    /// Merge `other` in (the sets are disjoint in every caller; the
    /// merge coalesces adjacent runs so reconverged classes shrink
    /// back to few runs).
    pub fn union(&mut self, other: &NodeSet) {
        let mut merged: Vec<(usize, usize)> =
            Vec::with_capacity(self.runs.len() + other.runs.len());
        let mut a = self.runs.iter().copied().peekable();
        let mut b = other.runs.iter().copied().peekable();
        loop {
            let next = match (a.peek(), b.peek()) {
                (Some(&x), Some(&y)) => {
                    if x.0 <= y.0 {
                        a.next()
                    } else {
                        b.next()
                    }
                }
                (Some(_), None) => a.next(),
                (None, Some(_)) => b.next(),
                (None, None) => break,
            };
            let (s, e) = next.expect("peeked run exists");
            match merged.last_mut() {
                Some(last) if s <= last.1 => last.1 = last.1.max(e),
                _ => merged.push((s, e)),
            }
        }
        self.runs = merged;
    }

    /// Split off and return every member `< bound`, keeping the rest.
    pub fn split_below(&mut self, bound: usize) -> NodeSet {
        let mut below = Vec::new();
        let mut above = Vec::new();
        for &(s, e) in &self.runs {
            if e <= bound {
                below.push((s, e));
            } else if s >= bound {
                above.push((s, e));
            } else {
                below.push((s, bound));
                above.push((bound, e));
            }
        }
        self.runs = above;
        NodeSet { runs: below }
    }
}

/// An equivalence class of fleet nodes in identical deploy state:
/// same cached-layer set (hence same shard assignments — shards are a
/// pure function of layer content), same fan-out wave position, same
/// retry/fault state.  One representative [`LayerCache`] stands in
/// for every member; its accounting is charged at multiplicity by the
/// owning [`ClassFleet`].
#[derive(Debug, Clone)]
pub struct NodeClass {
    /// Member nodes.
    members: NodeSet,
    /// The representative's cache (identical on every member).
    cache: LayerCache,
    /// Instant the members hold all layers so far this wave.
    ready: VirtualTime,
    /// Whether the members are permanently failed.
    dead: bool,
}

impl NodeClass {
    /// Member nodes.
    pub fn members(&self) -> &NodeSet {
        &self.members
    }

    /// Number of nodes this class stands in for.
    pub fn multiplicity(&self) -> u64 {
        self.members.len() as u64
    }

    /// The representative's cache.
    pub fn cache(&self) -> &LayerCache {
        &self.cache
    }

    /// Whether the members are permanently failed.
    pub fn is_dead(&self) -> bool {
        self.dead
    }
}

/// Charge a representative-cache operation to the fleet accumulator
/// at class multiplicity: the rep performs `op` once, the delta counts
/// once per member.
fn charge<R>(
    agg: &mut CacheStats,
    class: &mut NodeClass,
    op: impl FnOnce(&mut LayerCache) -> R,
) -> R {
    let before = class.cache.stats();
    let out = op(&mut class.cache);
    agg.add_scaled(&class.cache.stats().since(&before), class.members.len() as u64);
    out
}

/// The collapsed deploy engine: a [`Fleet`] whose nodes are held as
/// [`NodeClass`]es, so `deploy`/`deploy_with_faults` cost
/// O(classes × layers) events instead of O(nodes × layers).
///
/// Peer fan-out only ([`FanOut::Direct`] is inherently O(nodes) — use
/// [`DeployEngine`] for automatic fallback).  Reports are
/// byte-identical to the per-node [`Fleet`] on the same inputs: the
/// wave walk visits classes in ascending member order, so WAN
/// submissions and rng draws happen in the per-node order, and the
/// report's queue counters are the node-equivalent push/pop/high-water
/// numbers (its geometry fields describe the class-level calendar the
/// engine actually ran).
#[derive(Debug)]
pub struct ClassFleet {
    config: FleetConfig,
    classes: Vec<NodeClass>,
    /// Fleet-lifetime cache counters over multiplicities (the
    /// collapsed stand-in for summing per-node cache stats).
    agg_cache: CacheStats,
    /// One representative container per surviving class.
    containers: Vec<Container>,
    clock: VirtualTime,
    next_container_id: u64,
    storm_mark: Option<VirtualTime>,
    /// Class count at the end of the latest wave, before re-merge.
    peak_classes: usize,
    /// Class-level completion events the latest wave scheduled.
    class_events: u64,
}

impl ClassFleet {
    /// A cold collapsed fleet: every node in one class.  Panics on
    /// [`FanOut::Direct`] — that path has no symmetry to exploit.
    pub fn new(config: FleetConfig) -> Self {
        assert!(config.nodes >= 1, "fleet needs at least one node");
        match config.fan_out {
            FanOut::Peer { arity } => assert!(arity >= 1, "peer fan-out needs arity >= 1"),
            FanOut::Direct => panic!("ClassFleet models peer fan-out only (use DeployEngine)"),
        }
        let all = NodeClass {
            members: NodeSet::from_range(0..config.nodes),
            cache: LayerCache::new(config.cache_capacity_bytes),
            ready: VirtualTime::ZERO,
            dead: false,
        };
        ClassFleet {
            config,
            classes: vec![all],
            agg_cache: CacheStats::default(),
            containers: Vec::new(),
            clock: VirtualTime::ZERO,
            next_container_id: 0,
            storm_mark: None,
            peak_classes: 1,
            class_events: 0,
        }
    }

    /// The fleet's configuration.
    pub fn config(&self) -> &FleetConfig {
        &self.config
    }

    /// The fleet's virtual clock (advances with each deploy wave).
    pub fn now(&self) -> VirtualTime {
        self.clock
    }

    /// Current classes (after the latest wave's re-merge).
    pub fn classes(&self) -> &[NodeClass] {
        &self.classes
    }

    /// Class count right now.
    pub fn class_count(&self) -> usize {
        self.classes.len()
    }

    /// Peak class count over the latest wave, before re-merge — the
    /// `classes` in O(classes × layers).
    pub fn peak_classes(&self) -> usize {
        self.peak_classes
    }

    /// Class-level completion events the latest wave pushed through
    /// the calendar queue (the per-node engine pushes one per node per
    /// transferred layer).
    pub fn class_events(&self) -> u64 {
        self.class_events
    }

    /// Representative containers (one per surviving class) from the
    /// latest wave.
    pub fn containers(&self) -> &[Container] {
        &self.containers
    }

    /// Nodes permanently failed so far, over multiplicities.
    pub fn failed_count(&self) -> usize {
        self.classes.iter().filter(|c| c.dead).map(|c| c.members.len()).sum()
    }

    /// Fleet-lifetime cache counters (the collapsed analogue of
    /// [`Fleet::cache_totals`]).
    pub fn cache_totals(&self) -> CacheStats {
        self.agg_cache
    }

    fn class_of(&self, node: usize) -> usize {
        (0..self.classes.len())
            .find(|&ci| self.classes[ci].members.contains(node))
            .expect("every node belongs to exactly one class")
    }

    /// Split classes straddling `bound` so no class crosses it.
    fn split_at(&mut self, bound: usize) {
        for ci in 0..self.classes.len() {
            let below = self.classes[ci].members.split_below(bound);
            if below.is_empty() {
                continue;
            }
            if self.classes[ci].members.is_empty() {
                // entire class below the boundary: put it back
                self.classes[ci].members = below;
                continue;
            }
            let twin = NodeClass {
                members: below,
                cache: self.classes[ci].cache.clone(),
                ready: self.classes[ci].ready,
                dead: self.classes[ci].dead,
            };
            self.classes.push(twin);
        }
    }

    /// Make `node` a singleton class; returns its class index.
    fn isolate(&mut self, node: usize) -> usize {
        let ci = self.class_of(node);
        if self.classes[ci].members.len() == 1 {
            return ci;
        }
        self.classes[ci].members.remove(node);
        let twin = NodeClass {
            members: NodeSet::singleton(node),
            cache: self.classes[ci].cache.clone(),
            ready: self.classes[ci].ready,
            dead: self.classes[ci].dead,
        };
        self.classes.push(twin);
        self.classes.len() - 1
    }

    /// Split the run `[s, e)` out of class `ci` into a new class;
    /// returns the new class index.  `[s, e)` must be a strict subset
    /// of the class.
    fn split_run(&mut self, ci: usize, s: usize, e: usize) -> usize {
        let chunk = NodeSet { runs: vec![(s, e)] };
        self.classes[ci].members.subtract(&chunk);
        debug_assert!(!self.classes[ci].members.is_empty(), "split leaves a remainder");
        let twin = NodeClass {
            members: chunk,
            cache: self.classes[ci].cache.clone(),
            ready: self.classes[ci].ready,
            dead: self.classes[ci].dead,
        };
        self.classes.push(twin);
        self.classes.len() - 1
    }

    /// Re-merge classes whose representative states reconverged: same
    /// liveness and the same cache content in the same recency order
    /// mean identical behaviour under any future wave, so the classes
    /// are indistinguishable again.  Canonical (ascending first
    /// member) order keeps campaigns deterministic.
    fn remerge(&mut self) {
        use std::collections::HashMap;
        let mut order = std::mem::take(&mut self.classes);
        order.sort_by_key(|c| c.members.first());
        let mut groups: HashMap<(bool, Vec<LayerId>), usize> = HashMap::new();
        let mut out: Vec<NodeClass> = Vec::new();
        for class in order {
            let key = (class.dead, class.cache.recency_signature());
            match groups.get(&key) {
                Some(&i) => out[i].members.union(&class.members),
                None => {
                    groups.insert(key, out.len());
                    out.push(class);
                }
            }
        }
        self.classes = out;
    }

    /// Collapsed equivalent of [`Fleet::deploy`]: full scope, empty
    /// schedule, no retries, rng never consulted.
    pub fn deploy(
        &mut self,
        registry: &mut ShardedRegistry,
        reference: &str,
    ) -> Result<FleetReport, PullError> {
        let nodes = self.config.nodes;
        let mut rng = SimRng::new(0, "fault-free");
        self.deploy_with_faults(
            registry,
            reference,
            0..nodes,
            &FaultSchedule::none(),
            &RetryPolicy::none(),
            &mut rng,
        )
    }

    /// Collapsed equivalent of [`Fleet::deploy_with_faults`] — same
    /// semantics, same report, O(classes × layers) events.
    ///
    /// The walk preserves the reference engine's WAN submission order
    /// and rng draw order exactly: fault-touched nodes are isolated
    /// into singleton classes up front (so multi-member classes are
    /// never down and never consult the schedule), needers are visited
    /// in ascending node order via run segments, and every per-node
    /// accounting step is applied once at class multiplicity.
    #[allow(clippy::needless_range_loop)]
    pub fn deploy_with_faults(
        &mut self,
        registry: &mut ShardedRegistry,
        reference: &str,
        scope: Range<usize>,
        faults: &FaultSchedule,
        policy: &RetryPolicy,
        rng: &mut SimRng,
    ) -> Result<FleetReport, PullError> {
        let t0 = self.clock;
        let n = self.config.nodes;
        assert!(!scope.is_empty(), "deploy scope must name at least one node");
        assert!(scope.end <= n, "deploy scope exceeds the fleet");
        assert!(policy.max_attempts >= 1, "retry policy needs one attempt");
        let FanOut::Peer { arity } = self.config.fan_out else {
            unreachable!("ClassFleet::new rejects direct fan-out");
        };
        let image = registry
            .registry()
            .image(reference)
            .cloned()
            .ok_or_else(|| PullError::UnknownReference(reference.to_string()))?;

        let mut unique: Vec<&LayerId> = Vec::new();
        for id in &image.layers {
            if !unique.contains(&id) {
                unique.push(id);
            }
        }

        // pre-split: scope boundaries plus every fault-touched node.
        // After this, any class with more than one member is untouched
        // by every node-level fault in the schedule — it is never
        // down, never struck by a storm, and `node_next_up` would
        // return "up right now" for each member — so only singletons
        // ever consult the schedule.
        self.split_at(scope.start);
        self.split_at(scope.end);
        for &(_, fault) in faults.events() {
            let touched = match fault {
                Fault::NodeCrash { node }
                | Fault::NodeRejoin { node }
                | Fault::CacheEvictStorm { node, .. } => Some(node),
                _ => None,
            };
            if let Some(node) = touched {
                if node < n {
                    self.isolate(node);
                }
            }
        }
        for class in &mut self.classes {
            class.ready = t0;
        }
        let dead_before = self.failed_count();
        let stats_before = self.agg_cache;

        // eviction storms land before lookups, exactly as per-node
        let mark = self.storm_mark;
        for &(at, node, bytes) in faults.evict_storms() {
            let fresh = at <= t0
                && match mark {
                    None => true,
                    Some(m) => at > m,
                };
            if fresh && node < n {
                let ci = self.class_of(node);
                debug_assert_eq!(self.classes[ci].members.len(), 1, "storm node is isolated");
                charge(&mut self.agg_cache, &mut self.classes[ci], |c| c.shed(bytes));
            }
        }
        self.storm_mark = Some(t0);

        let busy_before = registry.shard_busy();
        let mut ctx = WaveCtx {
            faults,
            policy,
            rng,
            acc: FaultAccum::default(),
        };
        let mut intra_bytes = 0u64;
        // class-level completions ride one calendar queue; the
        // node-equivalent counters the per-node engine would report
        // are synthesized alongside (its queue fully drains between
        // layers, so the node-level high-water mark is the largest
        // per-layer multiplicity sum)
        let mut sched: CellQueue<(usize, u64)> = CellQueue::new(
            self.config.domains,
            wan_lookahead(),
            self.classes.len().max(16),
        );
        let mut v_pushes = 0u64;
        let mut v_hwm = 0u64;

        for &id in &unique {
            // scaled lookups are the accounting: one representative
            // lookup stands in for `multiplicity` per-node lookups
            let mut needer_cls: Vec<usize> = Vec::new();
            for ci in 0..self.classes.len() {
                let in_scope = {
                    let c = &self.classes[ci];
                    !c.dead && c.members.first().is_some_and(|f| scope.contains(&f))
                };
                if !in_scope {
                    continue;
                }
                let miss = charge(&mut self.agg_cache, &mut self.classes[ci], |c| {
                    c.lookup(id).is_none()
                });
                if miss {
                    needer_cls.push(ci);
                }
            }
            if needer_cls.is_empty() {
                continue; // fully warm layer: no transfer anywhere
            }
            needer_cls.sort_by_key(|&ci| self.classes[ci].members.first());
            let blob = registry
                .registry()
                .layers
                .get(id)
                .ok_or_else(|| PullError::CorruptRegistry(id.clone()))?
                .blob();

            let mut holder_cls: Vec<usize> = (0..self.classes.len())
                .filter(|&ci| !self.classes[ci].dead && self.classes[ci].cache.contains(id))
                .collect();

            let mut layer_inflight = 0u64;
            let (start, pool) = if holder_cls.is_empty() {
                // no holder anywhere: seed one copy over the WAN onto
                // the earliest-available needer.  Candidates are
                // classes; a multi-member class is "up right now" by
                // the pre-split invariant, a singleton asks the
                // schedule — and ascending-first order with a strict
                // minimum reproduces the per-node first-minimum walk.
                let mut remaining = needer_cls.clone();
                let mut seed: Option<(usize, VirtualTime)> = None;
                let mut t_seed = t0;
                while seed.is_none() && !remaining.is_empty() {
                    let mut best: Option<(usize, VirtualTime)> = None;
                    let mut dead_idx: Vec<usize> = Vec::new();
                    for (idx, &ci) in remaining.iter().enumerate() {
                        let c = &self.classes[ci];
                        let up = if c.members.len() > 1 {
                            Some(t_seed)
                        } else {
                            ctx.faults
                                .node_next_up(c.members.first().expect("class non-empty"), t_seed)
                        };
                        match up {
                            None => dead_idx.push(idx),
                            Some(up) => {
                                let better = match best {
                                    None => true,
                                    Some((_, b)) => up < b,
                                };
                                if better {
                                    best = Some((idx, up));
                                }
                            }
                        }
                    }
                    for &idx in dead_idx.iter().rev() {
                        let ci = remaining.remove(idx);
                        self.classes[ci].dead = true;
                        if let Some((b, _)) = best.as_mut() {
                            if *b > idx {
                                *b -= 1;
                            }
                        }
                    }
                    let Some((idx, up)) = best else { break };
                    match ctx.wan(registry, id, blob.bytes, up) {
                        None => {
                            for ci in remaining.drain(..) {
                                self.classes[ci].dead = true;
                            }
                            break;
                        }
                        Some(done) => {
                            let ci = remaining[idx];
                            let seed_node =
                                self.classes[ci].members.first().expect("class non-empty");
                            let down = self.classes[ci].members.len() == 1
                                && ctx.faults.node_down_at(seed_node, done);
                            if down {
                                // seed arrived mid-crash: wasted
                                ctx.acc.retried_bytes += blob.bytes;
                                match ctx.faults.node_next_up(seed_node, done) {
                                    Some(up2) => {
                                        ctx.acc.retries += 1;
                                        t_seed = up2;
                                    }
                                    None => {
                                        let ci = remaining.remove(idx);
                                        self.classes[ci].dead = true;
                                    }
                                }
                            } else {
                                seed = Some((idx, done));
                            }
                        }
                    }
                }
                let Some((idx, done)) = seed else {
                    continue; // layer undeliverable in scope
                };
                let origin = remaining.remove(idx);
                let first = self.classes[origin].members.first().expect("class non-empty");
                let seeder_ci = if self.classes[origin].members.len() == 1 {
                    origin
                } else {
                    // the seeder leaves its class; the rest ride waves
                    let si = self.isolate(first);
                    remaining.insert(idx, origin);
                    si
                };
                sched.push(seeder_ci, done, (seeder_ci, 1));
                v_pushes += 1;
                layer_inflight += 1;
                v_hwm = v_hwm.max(layer_inflight);
                charge(&mut self.agg_cache, &mut self.classes[seeder_ci], |c| {
                    c.admit(blob.clone())
                });
                holder_cls.push(seeder_ci);
                (done, remaining)
            } else {
                (t0, needer_cls.clone())
            };

            // ascending-order run segments snapshot the pool; waves
            // consume them left to right, exactly the per-node order
            let mut segments: Vec<(usize, usize, usize)> = Vec::new();
            for &ci in &pool {
                for &(s, e) in self.classes[ci].members.runs() {
                    segments.push((s, e, ci));
                }
            }
            segments.sort_unstable();
            let total: usize = segments.iter().map(|&(s, e, _)| e - s).sum();
            let mut cur_seg = 0usize;
            let mut cur_off = 0usize;

            let hop = self.config.fabric.p2p(blob.bytes, false);
            let mut served = 0usize;
            let mut t = start;
            let mut resend: Vec<(VirtualTime, usize)> = Vec::new();
            while served < total {
                let live: usize = holder_cls
                    .iter()
                    .map(|&ci| {
                        let c = &self.classes[ci];
                        let m = c.members.len();
                        if m > 1 {
                            m
                        } else if ctx
                            .faults
                            .node_down_at(c.members.first().expect("class non-empty"), t)
                        {
                            0
                        } else {
                            1
                        }
                    })
                    .sum();
                if live == 0 {
                    // every holder is a down singleton: wait for the
                    // first rejoin, or fall back to the registry for
                    // everyone still waiting (the reference's own
                    // O(scope) path — all holders are permanently
                    // gone, so each survivor re-pulls individually)
                    let next = holder_cls
                        .iter()
                        .filter_map(|&ci| {
                            let c = &self.classes[ci];
                            debug_assert_eq!(c.members.len(), 1, "live holders counted above");
                            ctx.faults
                                .node_next_up(c.members.first().expect("class non-empty"), t)
                        })
                        .min();
                    match next {
                        Some(up) => {
                            t = up;
                        }
                        None => {
                            for seg_i in cur_seg..segments.len() {
                                let (s, e, _ci) = segments[seg_i];
                                let s = if seg_i == cur_seg { s + cur_off } else { s };
                                for node in s..e {
                                    let si = self.isolate(node);
                                    ctx.acc.retries += 1;
                                    resend.push((t, si));
                                }
                            }
                            cur_seg = segments.len();
                            cur_off = 0;
                            served = total;
                        }
                    }
                    continue;
                }
                let wave = (live * arity).min(total - served);
                t += hop;
                let mut arrivals: Vec<(usize, VirtualTime, (usize, u64))> = Vec::new();
                let mut need = wave;
                while need > 0 {
                    let (s, e, ci) = segments[cur_seg];
                    let s2 = s + cur_off;
                    let take = (e - s2).min(need);
                    intra_bytes += blob.bytes * take as u64;
                    let class_len = self.classes[ci].members.len();
                    if class_len == 1 {
                        debug_assert_eq!(take, 1, "singleton segments are one node");
                        let node = s2;
                        if ctx.faults.node_down_at(node, t) {
                            // copy arrived mid-crash: wasted hop
                            ctx.acc.retried_bytes += blob.bytes;
                            if ctx.faults.node_next_up(node, t).is_some() {
                                ctx.acc.retries += 1;
                                resend.push((t, ci));
                            } else {
                                self.classes[ci].dead = true;
                            }
                        } else {
                            arrivals.push((ci, t, (ci, 1)));
                            charge(&mut self.agg_cache, &mut self.classes[ci], |c| {
                                c.admit(blob.clone())
                            });
                            holder_cls.push(ci);
                        }
                    } else {
                        // multi-member classes are never down (the
                        // pre-split invariant): the chunk lands whole
                        let target = if take == class_len {
                            ci
                        } else {
                            self.split_run(ci, s2, s2 + take)
                        };
                        arrivals.push((target, t, (target, take as u64)));
                        charge(&mut self.agg_cache, &mut self.classes[target], |c| {
                            c.admit(blob.clone())
                        });
                        holder_cls.push(target);
                    }
                    cur_off += take;
                    need -= take;
                    if s2 + take == e {
                        cur_seg += 1;
                        cur_off = 0;
                    }
                }
                for &(_, _, (_, m)) in &arrivals {
                    v_pushes += m;
                    layer_inflight += m;
                }
                v_hwm = v_hwm.max(layer_inflight);
                sched.push_batch(arrivals);
                served += wave;
            }

            // second pass: singletons whose copy arrived while they
            // were down re-pull once they rejoin
            for (when, ci) in resend {
                if self.classes[ci].dead {
                    continue;
                }
                let node = self.classes[ci].members.first().expect("class non-empty");
                let mut when = when;
                loop {
                    let Some(up) = ctx.faults.node_next_up(node, when) else {
                        self.classes[ci].dead = true;
                        break;
                    };
                    let src_live = holder_cls.iter().any(|&h| {
                        let c = &self.classes[h];
                        c.members.len() > 1
                            || !ctx
                                .faults
                                .node_down_at(c.members.first().expect("class non-empty"), up)
                    });
                    let arrival = if src_live {
                        intra_bytes += blob.bytes;
                        up + hop
                    } else {
                        match ctx.wan(registry, id, blob.bytes, up) {
                            Some(done) => done,
                            None => {
                                self.classes[ci].dead = true;
                                break;
                            }
                        }
                    };
                    if ctx.faults.node_down_at(node, arrival) {
                        ctx.acc.retried_bytes += blob.bytes;
                        ctx.acc.retries += 1;
                        when = arrival;
                        continue;
                    }
                    sched.push(ci, arrival, (ci, 1));
                    v_pushes += 1;
                    layer_inflight += 1;
                    v_hwm = v_hwm.max(layer_inflight);
                    charge(&mut self.agg_cache, &mut self.classes[ci], |c| {
                        c.admit(blob.clone())
                    });
                    holder_cls.push(ci);
                    break;
                }
            }

            // drain this layer's class completions in time order
            while let Some((ready, (ci, _m))) = sched.pop() {
                self.classes[ci].ready = self.classes[ci].ready.max(ready);
            }
        }
        let class_queue = sched.stats();
        self.class_events = class_queue.pushes;
        self.peak_classes = self.classes.len();

        // local per-layer verify/mount, then one representative
        // container per surviving in-scope class
        let check = self.config.per_layer_check * image.layers.len() as u64;
        self.containers.clear();
        let mut finish = t0;
        let mut started = 0usize;
        for ci in 0..self.classes.len() {
            let in_scope = {
                let c = &self.classes[ci];
                !c.dead && c.members.first().is_some_and(|f| scope.contains(&f))
            };
            if !in_scope {
                continue;
            }
            let m = self.classes[ci].members.len();
            let done = self.classes[ci].ready + check;
            finish = finish.max(done);
            let mut c = Container::create(self.next_container_id, image.id.clone(), done);
            // ids stay node-dense so engines allocate the same space
            self.next_container_id += m as u64;
            c.start(done).expect("fresh container starts");
            self.containers.push(c);
            started += m;
        }
        let makespan = finish.since(t0);
        self.clock = finish;

        let shard_utilisation = registry.shard_utilisation(&busy_before, makespan);

        let newly_failed = self.failed_count() - dead_before;
        let mut fault = faults.stats_over(t0, finish);
        fault.retries = ctx.acc.retries;
        fault.failovers = ctx.acc.failovers;
        fault.transfers_dropped = ctx.acc.transfers_dropped;
        fault.permanent_failures = newly_failed as u64;

        // reconverged classes collapse back before the next wave
        self.remerge();

        let mut queue = class_queue;
        queue.pushes = v_pushes;
        queue.pops = v_pushes;
        queue.depth = 0;
        queue.depth_hwm = v_hwm as usize;

        Ok(FleetReport {
            reference: reference.to_string(),
            nodes: scope.len(),
            layers_total: image.layers.len(),
            unique_layers: unique.len(),
            wan_transfers: ctx.acc.wan_transfers,
            wan_bytes: ctx.acc.wan_bytes,
            intra_bytes,
            retried_bytes: ctx.acc.retried_bytes,
            retries: ctx.acc.retries,
            failovers: ctx.acc.failovers,
            permanently_failed: newly_failed,
            started_at: t0,
            makespan,
            cache: self.agg_cache.since(&stats_before),
            shard_utilisation,
            containers_started: started,
            fault,
            queue,
        })
    }
}

/// Engine dispatch: the collapsed [`ClassFleet`] where its symmetry
/// argument applies (peer fan-out), the per-node reference [`Fleet`]
/// otherwise — one `match` instead of every scenario re-deciding.
#[derive(Debug)]
pub enum DeployEngine {
    /// The O(nodes × layers) per-node reference implementation.
    PerNode(Fleet),
    /// The O(classes × layers) collapsed implementation.
    Collapsed(ClassFleet),
}

impl DeployEngine {
    /// `collapsed = true` selects [`ClassFleet`] when the config
    /// allows it ([`FanOut::Peer`]); [`FanOut::Direct`] — inherently
    /// O(nodes) — and `collapsed = false` run the per-node reference.
    pub fn new(config: FleetConfig, collapsed: bool) -> Self {
        match config.fan_out {
            FanOut::Peer { .. } if collapsed => DeployEngine::Collapsed(ClassFleet::new(config)),
            _ => DeployEngine::PerNode(Fleet::new(config)),
        }
    }

    /// See [`Fleet::deploy`] / [`ClassFleet::deploy`].
    pub fn deploy(
        &mut self,
        registry: &mut ShardedRegistry,
        reference: &str,
    ) -> Result<FleetReport, PullError> {
        match self {
            DeployEngine::PerNode(f) => f.deploy(registry, reference),
            DeployEngine::Collapsed(f) => f.deploy(registry, reference),
        }
    }

    /// See [`Fleet::deploy_with_faults`] /
    /// [`ClassFleet::deploy_with_faults`].
    pub fn deploy_with_faults(
        &mut self,
        registry: &mut ShardedRegistry,
        reference: &str,
        scope: Range<usize>,
        faults: &FaultSchedule,
        policy: &RetryPolicy,
        rng: &mut SimRng,
    ) -> Result<FleetReport, PullError> {
        match self {
            DeployEngine::PerNode(f) => {
                f.deploy_with_faults(registry, reference, scope, faults, policy, rng)
            }
            DeployEngine::Collapsed(f) => {
                f.deploy_with_faults(registry, reference, scope, faults, policy, rng)
            }
        }
    }

    /// The engine's virtual clock.
    pub fn now(&self) -> VirtualTime {
        match self {
            DeployEngine::PerNode(f) => f.now(),
            DeployEngine::Collapsed(f) => f.now(),
        }
    }

    /// Peak class count over the latest wave (`None` for the per-node
    /// engine, which has no classes).
    pub fn peak_classes(&self) -> Option<usize> {
        match self {
            DeployEngine::PerNode(_) => None,
            DeployEngine::Collapsed(f) => Some(f.peak_classes()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::buildfile::Buildfile;
    use crate::container::builder::Builder;
    use crate::des::Fault;

    fn registry_with(reference: &str, text: &str) -> (ShardedRegistry, u64, usize) {
        let mut store = LayerStore::new();
        let image = Builder::new()
            .build(&Buildfile::parse(text).unwrap(), reference, &mut store)
            .unwrap()
            .image;
        let bytes = image.size_bytes(&store);
        let layers = image.layers.len();
        let mut reg = Registry::new();
        reg.push(&image, &store).unwrap();
        (ShardedRegistry::new(reg, 4), bytes, layers)
    }

    #[test]
    fn shard_of_is_deterministic_and_in_range() {
        let (reg, _, _) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        for id in reg.registry().layers.ids().cloned().collect::<Vec<_>>() {
            let s = reg.shard_of(&id);
            assert!(s < reg.shard_count());
            assert_eq!(s, reg.shard_of(&id));
        }
        // non-hex ids use the fallback fold and stay in range
        assert!(reg.shard_of(&LayerId("not-hex!".into())) < 4);
    }

    #[test]
    fn pull_at_matches_flat_pull_accounting() {
        let (mut sharded, bytes, layers) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let mut dest = LayerStore::new();
        let (_, report) = sharded
            .pull_at(VirtualTime::ZERO, "a:1", &mut dest)
            .unwrap();
        assert_eq!(report.layers_transferred, layers);
        assert_eq!(report.bytes_transferred, bytes);
        assert!(report.time > Duration::ZERO);
        assert_eq!(dest.len(), layers);
        // re-pull into the same store: nothing to move
        let (_, again) = sharded
            .pull_at(VirtualTime::ZERO, "a:1", &mut dest)
            .unwrap();
        assert_eq!(again.layers_transferred, 0);
        assert_eq!(again.bytes_transferred, 0);
        assert_eq!(again.time, Duration::ZERO);
    }

    #[test]
    fn backlog_and_bandwidth_views() {
        let (mut sharded, _, _) = registry_with("a:1", "FROM alpine:3.4");
        let wan = sharded.wan();
        assert_eq!(sharded.aggregate_bandwidth(), wan.beta_bytes_per_sec * 4.0);
        assert!(
            sharded
                .shard_backlog(VirtualTime::ZERO)
                .iter()
                .all(|&b| b == Duration::ZERO),
            "idle shards have no backlog"
        );
        let id = sharded
            .registry()
            .layers
            .ids()
            .next()
            .cloned()
            .expect("image has layers");
        let shard = sharded.shard_of(&id);
        let done = sharded.submit_transfer(VirtualTime::ZERO, &id, 64_000_000);
        let backlog = sharded.shard_backlog(VirtualTime::ZERO);
        assert_eq!(backlog[shard], done.since(VirtualTime::ZERO));
        for (s, &b) in backlog.iter().enumerate() {
            if s != shard {
                assert_eq!(b, Duration::ZERO, "other shards stay idle");
            }
        }
    }

    #[test]
    fn concurrent_pulls_contend_per_shard() {
        let (mut sharded, _, _) = registry_with("a:1", "FROM alpine:3.4");
        let mut d1 = LayerStore::new();
        let mut d2 = LayerStore::new();
        let (_, r1) = sharded.pull_at(VirtualTime::ZERO, "a:1", &mut d1).unwrap();
        let (_, r2) = sharded.pull_at(VirtualTime::ZERO, "a:1", &mut d2).unwrap();
        // same arrival, same single-layer shard queue: the second
        // client queues behind the first
        assert!(r2.time > r1.time, "{:?} !> {:?}", r2.time, r1.time);
    }

    #[test]
    fn unknown_reference_errors() {
        let (mut sharded, _, _) = registry_with("a:1", "FROM alpine:3.4");
        assert!(matches!(
            sharded.pull_at(VirtualTime::ZERO, "ghost:1", &mut LayerStore::new()),
            Err(PullError::UnknownReference(_))
        ));
        let mut fleet = Fleet::new(FleetConfig::hpc(2));
        assert!(matches!(
            fleet.deploy(&mut sharded, "ghost:1"),
            Err(PullError::UnknownReference(_))
        ));
    }

    #[test]
    fn peer_deploy_wan_bytes_are_unique_layers_once() {
        let (mut sharded, bytes, layers) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let n = 64;
        let mut fleet = Fleet::new(FleetConfig::hpc(n));
        let cold = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(cold.unique_layers, layers);
        assert_eq!(cold.wan_transfers, layers, "each layer seeded once");
        assert_eq!(cold.wan_bytes, bytes, "each layer crossed the WAN once");
        assert_eq!(cold.intra_bytes, bytes * (n as u64 - 1), "fan-out copies");
        assert_eq!(cold.cache.misses, (n * layers) as u64);
        assert_eq!(cold.cache.hits, 0);
        assert_eq!(cold.containers_started, n);
        assert!(cold.makespan > Duration::ZERO);
    }

    #[test]
    fn warm_redeploy_moves_zero_bytes() {
        let (mut sharded, _, layers) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let mut fleet = Fleet::new(FleetConfig::hpc(128));
        let cold = fleet.deploy(&mut sharded, "a:1").unwrap();
        let warm = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(warm.wan_bytes, 0);
        assert_eq!(warm.intra_bytes, 0);
        assert_eq!(warm.wan_transfers, 0);
        assert_eq!(warm.cache.hits, (128 * layers) as u64);
        assert_eq!(warm.cache.misses, 0);
        // warm cost is only the local per-layer checks
        assert_eq!(warm.makespan, Duration::from_millis(2) * layers as u64);
        assert!(warm.makespan.as_secs_f64() < 0.1 * cold.makespan.as_secs_f64());
        assert!(warm.started_at > cold.started_at, "clock advanced");
    }

    #[test]
    fn direct_deploy_pays_wan_per_node() {
        let (mut sharded, bytes, layers) = registry_with("a:1", "FROM alpine:3.4\nRUN echo x");
        let n = 16;
        let mut cfg = FleetConfig::hpc(n);
        cfg.fan_out = FanOut::Direct;
        let mut fleet = Fleet::new(cfg);
        let cold = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(cold.wan_bytes, bytes * n as u64);
        assert_eq!(cold.wan_transfers, layers * n);
        assert_eq!(cold.intra_bytes, 0);
    }

    #[test]
    fn direct_contention_grows_with_fleet_size() {
        let make = |n: usize| {
            let (mut sharded, _, _) = registry_with("a:1", "FROM alpine:3.4");
            let mut cfg = FleetConfig::hpc(n);
            cfg.fan_out = FanOut::Direct;
            let mut fleet = Fleet::new(cfg);
            fleet.deploy(&mut sharded, "a:1").unwrap().makespan
        };
        let small = make(8);
        let large = make(64);
        assert!(
            large.as_secs_f64() > 4.0 * small.as_secs_f64(),
            "direct pulls serialise on the shards: {small} vs {large}"
        );
    }

    #[test]
    fn peer_beats_direct_at_scale() {
        let run = |fan_out| {
            let (mut sharded, _, _) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
            let mut cfg = FleetConfig::hpc(256);
            cfg.fan_out = fan_out;
            let mut fleet = Fleet::new(cfg);
            fleet.deploy(&mut sharded, "a:1").unwrap().makespan
        };
        let peer = run(FanOut::Peer { arity: 2 });
        let direct = run(FanOut::Direct);
        assert!(
            peer.as_secs_f64() < direct.as_secs_f64() / 4.0,
            "peer {peer} should be far under direct {direct}"
        );
    }

    #[test]
    fn prewarmed_holders_skip_the_wan() {
        let (mut sharded, bytes, _) = registry_with("a:1", "FROM alpine:3.4\nRUN echo x");
        let mut fleet = Fleet::new(FleetConfig::hpc(8));
        // warm node 0 only
        let ids: Vec<LayerId> = sharded.registry().layers.ids().cloned().collect();
        for id in &ids {
            let l = sharded.registry().layers.get(id).unwrap().clone();
            fleet.caches_mut()[0].admit(l);
        }
        let report = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(report.wan_bytes, 0, "existing holder seeds the cluster");
        assert_eq!(report.intra_bytes, bytes * 7);
    }

    #[test]
    fn fan_out_wave_timing_doubles_holders() {
        // 4 nodes, arity 1, single layer: seeder at t_seed, then waves
        // serve 1, then 2 nodes — two hops after the seed
        let (mut sharded, _, _) = registry_with("one:1", "FROM alpine:3.4");
        let mut cfg = FleetConfig::hpc(4);
        cfg.fan_out = FanOut::Peer { arity: 1 };
        cfg.per_layer_check = Duration::ZERO;
        let layers = sharded.registry().image("one:1").unwrap().layers.len();
        assert_eq!(layers, 1, "alpine base is a single layer");
        let bytes = sharded
            .registry()
            .layers
            .ids()
            .map(|id| sharded.registry().layers.get(id).unwrap().bytes)
            .sum::<u64>();
        let mut fleet = Fleet::new(cfg);
        let report = fleet.deploy(&mut sharded, "one:1").unwrap();
        let seed = PathCost::registry_wan().transfer(bytes);
        let hop = Fabric::aries().p2p(bytes, false);
        assert_eq!(report.makespan, seed + hop + hop);
    }

    #[test]
    fn report_renders_key_numbers() {
        let (mut sharded, _, _) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let mut fleet = Fleet::new(FleetConfig::hpc(32));
        let r = fleet.deploy(&mut sharded, "a:1").unwrap();
        let text = r.render();
        assert!(text.contains("32 nodes"));
        assert!(text.contains("WAN"));
        assert!(text.contains("hit rate"));
        assert!(text.contains("ready events"));
        // the fault tail only appears when something went wrong
        assert!(!text.contains("retry(ies)"));
    }

    #[test]
    fn deploy_schedules_one_ready_event_per_node_per_layer() {
        let (mut sharded, _, layers) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let n = 64;
        let mut fleet = Fleet::new(FleetConfig::hpc(n));
        let cold = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(cold.queue.pushes, (n * layers) as u64);
        assert_eq!(cold.queue.pops, cold.queue.pushes, "drained to empty");
        assert_eq!(cold.queue.depth, 0);
        // drained per layer: the high-water mark is one layer's worth
        // of in-flight completions, not the lifetime push count
        assert_eq!(cold.queue.depth_hwm, n);
        // a fully warm wave schedules nothing at all
        let warm = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(warm.queue.pushes, 0);
        assert_eq!(warm.queue.depth_hwm, 0);
    }

    #[test]
    fn bounded_caches_evict_and_refetch() {
        let (mut sharded, bytes, _) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let mut cfg = FleetConfig::hpc(4);
        // caches too small for the whole image: something must go
        cfg.cache_capacity_bytes = bytes / 2;
        let mut fleet = Fleet::new(cfg);
        let cold = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert!(cold.cache.evictions > 0, "capacity forces eviction");
        let warm = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert!(
            warm.total_bytes() > 0,
            "evicted layers must be transferred again"
        );
    }

    // ---- fault-aware path ------------------------------------------

    #[test]
    fn retry_policy_backoff_caps_and_jitters() {
        let p = RetryPolicy::hpc();
        assert_eq!(p.backoff(1, None), Duration::from_millis(50));
        assert_eq!(p.backoff(2, None), Duration::from_millis(100));
        assert_eq!(p.backoff(20, None), Duration::from_secs_f64(5.0), "capped");
        assert_eq!(p.backoff(0, None), Duration::from_millis(50), "0 clamps");
        let mut rng = SimRng::new(7, "backoff");
        let jittered = p.backoff(3, Some(&mut rng));
        let base = p.backoff(3, None);
        let ratio = jittered.as_secs_f64() / base.as_secs_f64();
        assert!((0.8..=1.2).contains(&ratio), "{ratio}");
        // no-retry policy never waits
        assert_eq!(RetryPolicy::none().backoff(5, None), Duration::ZERO);
    }

    #[test]
    fn faultless_deploy_with_faults_matches_deploy_bit_for_bit() {
        let text = "FROM ubuntu:16.04\nRUN echo x";
        let (mut reg_a, _, _) = registry_with("a:1", text);
        let (mut reg_b, _, _) = registry_with("a:1", text);
        let mut fleet_a = Fleet::new(FleetConfig::hpc(48));
        let mut fleet_b = Fleet::new(FleetConfig::hpc(48));
        let base = fleet_a.deploy(&mut reg_a, "a:1").unwrap();
        let mut rng = SimRng::new(99, "chaos");
        let chaos = fleet_b
            .deploy_with_faults(
                &mut reg_b,
                "a:1",
                0..48,
                &FaultSchedule::none(),
                &RetryPolicy::hpc(),
                &mut rng,
            )
            .unwrap();
        assert_eq!(base, chaos, "empty schedule must be invisible");
        assert_eq!(base.render(), chaos.render());
        // and the rng stream was never consumed
        let mut fresh = SimRng::new(99, "chaos");
        assert_eq!(
            rng.uniform(0.0, 1.0).to_bits(),
            fresh.uniform(0.0, 1.0).to_bits()
        );
    }

    #[test]
    fn shard_outage_fails_over_to_surviving_shard() {
        let (mut sharded, bytes, _) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let ids: Vec<LayerId> = sharded.registry().layers.ids().cloned().collect();
        let down = sharded.shard_of(&ids[0]);
        let hour = VirtualTime(3_600_000_000_000);
        let schedule = FaultSchedule::from_events(vec![
            (VirtualTime::ZERO, Fault::ShardOutage { shard: down }),
            (hour, Fault::ShardRecover { shard: down }),
        ]);
        sharded.apply_faults(&schedule);
        assert!(sharded.shard_down_at(down, VirtualTime::ZERO));
        assert_eq!(sharded.shard_next_up(down, VirtualTime::ZERO), Some(hour));
        let mut fleet = Fleet::new(FleetConfig::hpc(16));
        let mut rng = SimRng::new(1, "failover");
        let report = fleet
            .deploy_with_faults(
                &mut sharded,
                "a:1",
                0..16,
                &schedule,
                &RetryPolicy::hpc(),
                &mut rng,
            )
            .unwrap();
        assert!(report.failovers >= 1, "owner shard down => failover");
        assert_eq!(report.permanently_failed, 0);
        assert_eq!(report.wan_bytes, bytes, "failover still seeds each layer once");
        assert_eq!(report.retried_bytes, 0);
        assert_eq!(report.containers_started, 16);
        assert_eq!(report.fault.failovers, report.failovers);
    }

    #[test]
    fn drop_window_forces_retry_and_bytes_stay_conserved() {
        let (mut sharded, _, _) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        // every WAN transfer started before 200 ms is lost
        let schedule = FaultSchedule::from_events(vec![(
            VirtualTime::ZERO,
            Fault::TransferDrop {
                until: VirtualTime(200_000_000),
            },
        )]);
        let n = 8;
        let mut fleet = Fleet::new(FleetConfig::hpc(n));
        let mut rng = SimRng::new(3, "drops");
        let report = fleet
            .deploy_with_faults(
                &mut sharded,
                "a:1",
                0..n,
                &schedule,
                &RetryPolicy::hpc(),
                &mut rng,
            )
            .unwrap();
        assert!(report.retries >= 1, "transfers inside the window are lost");
        assert!(report.retried_bytes > 0);
        assert_eq!(report.permanently_failed, 0, "backoff escapes the window");
        // conservation: everything moved is either admitted into a
        // cache or accounted as wasted
        assert_eq!(
            report.total_bytes(),
            report.cache.bytes_inserted + report.retried_bytes
        );
        assert_eq!(report.delivered_bytes(), report.cache.bytes_inserted);
        let text = report.render();
        assert!(text.contains("retry(ies)"));
        // a warm re-deploy after the chaos is still free
        let warm = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(warm.total_bytes(), 0);
    }

    #[test]
    fn crashed_receiver_is_reserved_after_rejoin() {
        // 4 nodes, arity 1, single-layer image: node 1 is the seeder's
        // first fan-out target but is down when the copy arrives
        let (mut sharded, bytes, _) = registry_with("one:1", "FROM alpine:3.4");
        let mut cfg = FleetConfig::hpc(4);
        cfg.fan_out = FanOut::Peer { arity: 1 };
        cfg.per_layer_check = Duration::ZERO;
        let seed_t = PathCost::registry_wan().transfer(bytes);
        let hop = Fabric::aries().p2p(bytes, false);
        let rejoin = VirtualTime::ZERO + seed_t + hop + hop + hop;
        let schedule = FaultSchedule::from_events(vec![
            (VirtualTime::ZERO, Fault::NodeCrash { node: 1 }),
            (rejoin, Fault::NodeRejoin { node: 1 }),
        ]);
        let mut fleet = Fleet::new(cfg);
        let mut rng = SimRng::new(5, "rejoin");
        let report = fleet
            .deploy_with_faults(
                &mut sharded,
                "one:1",
                0..4,
                &schedule,
                &RetryPolicy::hpc(),
                &mut rng,
            )
            .unwrap();
        assert_eq!(report.permanently_failed, 0);
        assert_eq!(report.retried_bytes, bytes, "one wasted fan-out copy");
        assert!(report.retries >= 1);
        assert_eq!(report.containers_started, 4);
        assert_eq!(
            report.total_bytes(),
            report.cache.bytes_inserted + report.retried_bytes
        );
        for cache in fleet.caches() {
            assert_eq!(cache.len(), 1, "every node ends with the layer");
        }
    }

    #[test]
    fn never_rejoining_node_fails_permanently_without_hanging() {
        let (mut sharded, _, _) = registry_with("a:1", "FROM alpine:3.4\nRUN echo x");
        let schedule = FaultSchedule::from_events(vec![(
            VirtualTime::ZERO,
            Fault::NodeCrash { node: 2 },
        )]);
        let n = 4;
        let mut fleet = Fleet::new(FleetConfig::hpc(n));
        let mut rng = SimRng::new(6, "dead-node");
        let report = fleet
            .deploy_with_faults(
                &mut sharded,
                "a:1",
                0..n,
                &schedule,
                &RetryPolicy::hpc(),
                &mut rng,
            )
            .unwrap();
        assert_eq!(report.permanently_failed, 1);
        assert_eq!(report.containers_started, 3);
        assert!(fleet.failed_nodes()[2]);
        // a later wave remembers the corpse instead of re-counting it
        let again = fleet
            .deploy_with_faults(
                &mut sharded,
                "a:1",
                0..n,
                &schedule,
                &RetryPolicy::hpc(),
                &mut rng,
            )
            .unwrap();
        assert_eq!(again.permanently_failed, 0);
        assert_eq!(again.containers_started, 3);
    }

    #[test]
    fn endless_drop_window_terminates_with_permanent_failures() {
        let (mut sharded, bytes, layers) = registry_with("one:1", "FROM alpine:3.4");
        assert_eq!(layers, 1);
        // every WAN transfer for the next hour is lost; hpc backoff
        // sums to ~4 s, so all attempts burn out inside the window
        let schedule = FaultSchedule::from_events(vec![(
            VirtualTime::ZERO,
            Fault::TransferDrop {
                until: VirtualTime(3_600_000_000_000),
            },
        )]);
        let n = 4;
        let mut fleet = Fleet::new(FleetConfig::hpc(n));
        let mut rng = SimRng::new(8, "endless");
        let report = fleet
            .deploy_with_faults(
                &mut sharded,
                "one:1",
                0..n,
                &schedule,
                &RetryPolicy::hpc(),
                &mut rng,
            )
            .unwrap();
        let attempts = RetryPolicy::hpc().max_attempts as u64;
        assert_eq!(report.permanently_failed, n, "nobody can be seeded");
        assert_eq!(report.containers_started, 0);
        assert_eq!(report.wan_transfers as u64, attempts);
        assert_eq!(report.retried_bytes, bytes * attempts);
        assert_eq!(report.cache.bytes_inserted, 0);
        assert_eq!(
            report.total_bytes(),
            report.cache.bytes_inserted + report.retried_bytes
        );
    }

    #[test]
    fn scoped_deploy_targets_a_ring_and_later_rings_reuse_it() {
        let (mut sharded, bytes, _) = registry_with("one:1", "FROM alpine:3.4");
        let n = 8;
        let mut fleet = Fleet::new(FleetConfig::hpc(n));
        let mut rng = SimRng::new(9, "rings");
        let none = FaultSchedule::none();
        let canary = fleet
            .deploy_with_faults(&mut sharded, "one:1", 0..2, &none, &RetryPolicy::none(), &mut rng)
            .unwrap();
        assert_eq!(canary.nodes, 2);
        assert_eq!(canary.wan_bytes, bytes, "ring seeds over the WAN");
        assert_eq!(canary.intra_bytes, bytes, "one fan-out copy in the ring");
        assert_eq!(canary.containers_started, 2);
        let rest = fleet
            .deploy_with_faults(&mut sharded, "one:1", 2..n, &none, &RetryPolicy::none(), &mut rng)
            .unwrap();
        assert_eq!(rest.nodes, 6);
        assert_eq!(rest.wan_bytes, 0, "canary ring already holds the layer");
        assert_eq!(rest.intra_bytes, bytes * 6, "peers serve the fleet ring");
        assert_eq!(rest.containers_started, 6);
    }

    #[test]
    fn evict_storm_sheds_cache_and_forces_refetch() {
        let (mut sharded, bytes, _) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let n = 4;
        let mut fleet = Fleet::new(FleetConfig::hpc(n));
        fleet.deploy(&mut sharded, "a:1").unwrap();
        // a storm strikes node 0 between the waves, wiping its cache
        let schedule = FaultSchedule::from_events(vec![(
            fleet.now(),
            Fault::CacheEvictStorm {
                node: 0,
                bytes: u64::MAX,
            },
        )]);
        let mut rng = SimRng::new(11, "storm");
        let report = fleet
            .deploy_with_faults(
                &mut sharded,
                "a:1",
                0..n,
                &schedule,
                &RetryPolicy::hpc(),
                &mut rng,
            )
            .unwrap();
        assert!(report.cache.evictions > 0, "storm shed the resident layers");
        assert_eq!(report.wan_bytes, 0, "peers re-serve the struck node");
        assert_eq!(report.intra_bytes, bytes, "refetch rides the fabric");
        // the storm fires once: a third wave is fully warm again
        let warm = fleet
            .deploy_with_faults(
                &mut sharded,
                "a:1",
                0..n,
                &schedule,
                &RetryPolicy::hpc(),
                &mut rng,
            )
            .unwrap();
        assert_eq!(warm.total_bytes(), 0);
        assert_eq!(warm.cache.evictions, 0);
    }

    // --- node-class collapsing ---

    /// The golden-diff contract: the collapsed engine renders
    /// byte-identically and matches every semantic field; only the
    /// queue *geometry* (buckets/width/resizes) may differ, because
    /// the collapsed calendar holds class events, not node events.
    fn assert_equivalent(per_node: &FleetReport, collapsed: &FleetReport) {
        assert_eq!(per_node.render(), collapsed.render(), "renders must be byte-identical");
        let mut norm = collapsed.clone();
        norm.queue.buckets = per_node.queue.buckets;
        norm.queue.occupied_buckets = per_node.queue.occupied_buckets;
        norm.queue.bucket_width_ns = per_node.queue.bucket_width_ns;
        norm.queue.resizes = per_node.queue.resizes;
        norm.queue.sparse_jumps = per_node.queue.sparse_jumps;
        assert_eq!(per_node, &norm, "semantic fields must match exactly");
    }

    #[test]
    fn node_set_algebra_round_trips() {
        let mut s = NodeSet::from_range(0..10);
        assert_eq!(s.len(), 10);
        assert_eq!(s.first(), Some(0));
        assert!(s.contains(9) && !s.contains(10));
        // remove splits a run in two
        assert!(s.remove(4));
        assert!(!s.remove(4));
        assert_eq!(s.runs(), &[(0, 4), (5, 10)]);
        assert_eq!(s.len(), 9);
        // subtract carves across runs
        let mut t = s.clone();
        t.subtract(&NodeSet::from_range(2..7));
        assert_eq!(t.runs(), &[(0, 2), (7, 10)]);
        // union coalesces back (multiplicity sums preserved)
        let mut u = t.clone();
        let mut carved = s.clone();
        carved.subtract(&t);
        u.union(&carved);
        assert_eq!(u, s, "subtract + union round-trips");
        assert_eq!(u.len(), t.len() + carved.len());
        // adjacent runs coalesce into one
        let mut a = NodeSet::from_range(0..4);
        a.union(&NodeSet::from_range(4..8));
        assert_eq!(a.runs(), &[(0, 8)]);
        // split_below cuts at the boundary
        let mut rest = NodeSet::from_range(0..8);
        let below = rest.split_below(3);
        assert_eq!(below.runs(), &[(0, 3)]);
        assert_eq!(rest.runs(), &[(3, 8)]);
        assert_eq!(below.iter().collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn collapsed_cold_deploy_matches_per_node_render() {
        let text = "FROM ubuntu:16.04\nRUN echo x";
        let (mut reg_a, _, _) = registry_with("a:1", text);
        let (mut reg_b, _, _) = registry_with("a:1", text);
        let n = 64;
        let mut per_node = Fleet::new(FleetConfig::hpc(n));
        let mut collapsed = ClassFleet::new(FleetConfig::hpc(n));
        let cold_a = per_node.deploy(&mut reg_a, "a:1").unwrap();
        let cold_b = collapsed.deploy(&mut reg_b, "a:1").unwrap();
        assert_equivalent(&cold_a, &cold_b);
        // the fault-free campaign reconverges into one class
        assert_eq!(collapsed.class_count(), 1, "cohorts re-merge after the wave");
        assert!(collapsed.peak_classes() < n, "never one class per node");
        assert!(
            collapsed.class_events() < cold_a.queue.pushes,
            "class events ({}) undercut node events ({})",
            collapsed.class_events(),
            cold_a.queue.pushes
        );
        // warm re-deploys stay equivalent (and free)
        let warm_a = per_node.deploy(&mut reg_a, "a:1").unwrap();
        let warm_b = collapsed.deploy(&mut reg_b, "a:1").unwrap();
        assert_equivalent(&warm_a, &warm_b);
        assert_eq!(warm_b.total_bytes(), 0);
    }

    #[test]
    fn collapsed_faulted_deploy_matches_per_node() {
        let text = "FROM ubuntu:16.04\nRUN echo x\nRUN echo y";
        let (mut reg_a, _, _) = registry_with("a:1", text);
        let (mut reg_b, _, _) = registry_with("a:1", text);
        let n = 48;
        let rejoin = VirtualTime(400_000_000);
        let schedule = FaultSchedule::from_events(vec![
            (VirtualTime::ZERO, Fault::NodeCrash { node: 3 }),
            (rejoin, Fault::NodeRejoin { node: 3 }),
            (VirtualTime::ZERO, Fault::NodeCrash { node: 17 }), // permanent
            (
                VirtualTime::ZERO,
                Fault::TransferDrop {
                    until: VirtualTime(150_000),
                },
            ),
            (
                VirtualTime::ZERO,
                Fault::CacheEvictStorm {
                    node: 9,
                    bytes: u64::MAX,
                },
            ),
        ]);
        let mut per_node = Fleet::new(FleetConfig::hpc(n));
        let mut collapsed = ClassFleet::new(FleetConfig::hpc(n));
        let mut rng_a = SimRng::new(7, "chaos");
        let mut rng_b = SimRng::new(7, "chaos");
        let rep_a = per_node
            .deploy_with_faults(&mut reg_a, "a:1", 0..n, &schedule, &RetryPolicy::hpc(), &mut rng_a)
            .unwrap();
        let rep_b = collapsed
            .deploy_with_faults(&mut reg_b, "a:1", 0..n, &schedule, &RetryPolicy::hpc(), &mut rng_b)
            .unwrap();
        assert_equivalent(&rep_a, &rep_b);
        assert_eq!(rep_b.permanently_failed, 1, "node 17 never rejoins");
        // conservation over multiplicities
        assert_eq!(
            rep_b.total_bytes(),
            rep_b.cache.bytes_inserted + rep_b.retried_bytes
        );
        // both rng streams advanced identically
        assert_eq!(
            rng_a.uniform(0.0, 1.0).to_bits(),
            rng_b.uniform(0.0, 1.0).to_bits()
        );
        // a second, fault-free wave stays equivalent (per-wave state —
        // dead nodes, caches, storm marks — carried over identically)
        let none = FaultSchedule::none();
        let warm_a = per_node
            .deploy_with_faults(&mut reg_a, "a:1", 0..n, &none, &RetryPolicy::hpc(), &mut rng_a)
            .unwrap();
        let warm_b = collapsed
            .deploy_with_faults(&mut reg_b, "a:1", 0..n, &none, &RetryPolicy::hpc(), &mut rng_b)
            .unwrap();
        assert_equivalent(&warm_a, &warm_b);
    }

    #[test]
    fn collapsed_scoped_deploy_matches_per_node() {
        let text = "FROM alpine:3.4\nRUN echo z";
        let (mut reg_a, _, _) = registry_with("a:1", text);
        let (mut reg_b, _, _) = registry_with("a:1", text);
        let n = 32;
        let none = FaultSchedule::none();
        let mut per_node = Fleet::new(FleetConfig::hpc(n));
        let mut collapsed = ClassFleet::new(FleetConfig::hpc(n));
        let mut rng_a = SimRng::new(13, "canary");
        let mut rng_b = SimRng::new(13, "canary");
        // canary ring first, then the rest — scope boundaries split
        // classes and the fleet-wide holders serve the second ring
        let ring_a = per_node
            .deploy_with_faults(&mut reg_a, "a:1", 0..4, &none, &RetryPolicy::none(), &mut rng_a)
            .unwrap();
        let ring_b = collapsed
            .deploy_with_faults(&mut reg_b, "a:1", 0..4, &none, &RetryPolicy::none(), &mut rng_b)
            .unwrap();
        assert_equivalent(&ring_a, &ring_b);
        let rest_a = per_node
            .deploy_with_faults(&mut reg_a, "a:1", 4..n, &none, &RetryPolicy::none(), &mut rng_a)
            .unwrap();
        let rest_b = collapsed
            .deploy_with_faults(&mut reg_b, "a:1", 4..n, &none, &RetryPolicy::none(), &mut rng_b)
            .unwrap();
        assert_equivalent(&rest_a, &rest_b);
        assert_eq!(rest_b.wan_bytes, 0, "the ring already seeded the fleet");
    }

    #[test]
    fn collapsed_deploy_is_o_classes_at_scale() {
        let (mut sharded, bytes, layers) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let n = 65_536;
        let mut fleet = ClassFleet::new(FleetConfig::hpc(n));
        let cold = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(cold.containers_started, n);
        assert_eq!(cold.wan_bytes, bytes);
        assert_eq!(cold.intra_bytes, bytes * (n as u64 - 1));
        // the per-node engine would schedule n × layers = 131 072
        // events; the collapsed engine schedules one per class chunk
        // per wave — orders of magnitude fewer
        let node_events = (n * layers) as u64;
        assert_eq!(cold.queue.pushes, node_events, "report stays node-equivalent");
        assert!(
            fleet.class_events() < node_events / 100,
            "O(classes) events: {} vs {}",
            fleet.class_events(),
            node_events
        );
        assert!(
            fleet.peak_classes() < 128,
            "peak classes stay near waves x layers: {}",
            fleet.peak_classes()
        );
        assert_eq!(fleet.class_count(), 1, "fault-free fleet reconverges");
    }

    #[test]
    #[should_panic(expected = "peer fan-out only")]
    fn class_fleet_rejects_direct_fan_out() {
        let cfg = FleetConfig {
            fan_out: FanOut::Direct,
            ..FleetConfig::hpc(8)
        };
        let _ = ClassFleet::new(cfg);
    }

    #[test]
    fn deploy_engine_dispatches_and_falls_back() {
        let direct = FleetConfig {
            fan_out: FanOut::Direct,
            ..FleetConfig::hpc(8)
        };
        assert!(matches!(
            DeployEngine::new(direct, true),
            DeployEngine::PerNode(_)
        ));
        assert!(matches!(
            DeployEngine::new(FleetConfig::hpc(8), false),
            DeployEngine::PerNode(_)
        ));
        let mut engine = DeployEngine::new(FleetConfig::hpc(8), true);
        assert!(matches!(engine, DeployEngine::Collapsed(_)));
        assert_eq!(engine.peak_classes(), Some(1));
        let (mut sharded, bytes, _) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let report = engine.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(report.wan_bytes, bytes);
        assert_eq!(report.containers_started, 8);
        assert!(engine.now() > VirtualTime::ZERO);
    }
}
