//! Fleet-scale layer distribution: sharded registry frontends,
//! node-local caches, and DES-scheduled concurrent pulls.
//!
//! The paper's Fig 1 workflow ends with "pull everywhere" — and at HPC
//! scale *everywhere* is thousands of nodes hitting the registry at
//! once.  This module replaces the flat-bandwidth [`Registry::pull`]
//! model with a distribution tier whose mechanisms mirror what real
//! registries (Trow's sharded blob store) and HPC runtimes (Shifter's
//! node-local image cache) do:
//!
//! * [`ShardedRegistry`] — the registry catalogue fronted by `S` shard
//!   frontends, one [`FifoResource`] per shard.  A layer's shard is a
//!   pure function of its content hash, so every client agrees where a
//!   blob lives without coordination, and `N` concurrent pullers
//!   contend realistically per shard instead of sharing one bandwidth
//!   number.  Transfer times come from [`PathCost::registry_wan`].
//! * [`Fleet`] — `N` nodes, each with a content-addressed
//!   [`LayerCache`], connected by an intra-cluster [`Fabric`].
//! * [`Fleet::deploy`] — the DES-scheduled concurrent pull of one image
//!   onto every node.  With [`FanOut::Peer`] (Trow's distribution
//!   model) each layer missing everywhere crosses the WAN **once**,
//!   through its shard, to a seeder node; holders then serve `arity`
//!   siblings per fan-out wave, so the cluster-internal copies ride the
//!   fast fabric and the WAN sees `O(unique layers)` bytes rather than
//!   `O(nodes × layers)`.  [`FanOut::Direct`] is the contention
//!   baseline: every node pulls every missing layer from its shard.
//!
//! A warm re-deploy — every layer already resident in every node cache
//! — transfers zero registry bytes and zero intra-cluster bytes; each
//! node pays only the local per-layer metadata check, which is why the
//! `fig1-scale` figure shows warm makespans orders of magnitude under
//! cold ones.
//!
//! [`Registry::pull`]: super::registry::Registry::pull
//! [`FifoResource`]: crate::des::FifoResource
//! [`PathCost::registry_wan`]: crate::net::PathCost::registry_wan

use crate::des::{Duration, EventQueue, FifoResource, QueueStats, VirtualTime};
use crate::net::{Fabric, PathCost};

use super::cache::{CacheStats, LayerCache};
use super::image::{Image, Layer, LayerId};
use super::lifecycle::Container;
use super::registry::{MissingLayer, PullError, PullReport, Registry};
use super::store::LayerStore;

/// The registry catalogue fronted by per-shard transfer queues.
///
/// Wraps a [`Registry`] (tags + blobs) and schedules every blob
/// transfer through the [`FifoResource`] frontend owning that blob's
/// content hash, in virtual time.  This is the DES-scheduled
/// replacement for the flat [`Registry::pull`] bandwidth model.
///
/// [`Registry::pull`]: super::registry::Registry::pull
#[derive(Debug)]
pub struct ShardedRegistry {
    registry: Registry,
    shards: Vec<FifoResource>,
    wan: PathCost,
}

impl ShardedRegistry {
    /// Front `registry` with `shards` single-server WAN frontends
    /// (each with the [`PathCost::registry_wan`] link cost).
    ///
    /// [`PathCost::registry_wan`]: crate::net::PathCost::registry_wan
    pub fn new(registry: Registry, shards: usize) -> Self {
        assert!(shards >= 1, "registry needs at least one shard");
        ShardedRegistry {
            registry,
            shards: vec![FifoResource::new(1); shards],
            wan: PathCost::registry_wan(),
        }
    }

    /// Override the per-shard WAN link cost.
    pub fn with_wan(mut self, wan: PathCost) -> Self {
        self.wan = wan;
        self
    }

    /// The wrapped catalogue (tags, blobs).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Mutable catalogue access (for pushes outside [`push`](Self::push)).
    pub fn registry_mut(&mut self) -> &mut Registry {
        &mut self.registry
    }

    /// Number of shard frontends.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The per-shard WAN link cost.
    pub fn wan(&self) -> PathCost {
        self.wan
    }

    /// Push an image into the catalogue (instantaneous control-plane
    /// operation; only pulls are scheduled in virtual time here).
    pub fn push(&mut self, image: &Image, source: &LayerStore) -> Result<(), MissingLayer> {
        self.registry.push(image, source)
    }

    /// Which shard owns `id` — a pure function of the content hash, so
    /// every client agrees without coordination (rendezvous placement,
    /// as in Trow's blob store).
    pub fn shard_of(&self, id: &LayerId) -> usize {
        let take = id.0.len().min(16);
        let h = id
            .0
            .get(..take)
            .and_then(|prefix| u64::from_str_radix(prefix, 16).ok())
            // non-hex ids (hand-built in tests) fall back to a byte fold
            .unwrap_or_else(|| {
                id.0.bytes()
                    .fold(0u64, |acc, b| acc.wrapping_mul(31).wrapping_add(b as u64))
            });
        (h % self.shards.len() as u64) as usize
    }

    /// Schedule the transfer of `bytes` of blob `id` starting no
    /// earlier than `arrival`; returns the completion instant under
    /// FIFO contention on the owning shard.
    pub fn submit_transfer(
        &mut self,
        arrival: VirtualTime,
        id: &LayerId,
        bytes: u64,
    ) -> VirtualTime {
        let shard = self.shard_of(id);
        let service = self.wan.transfer(bytes);
        self.shards[shard].submit(arrival, service)
    }

    /// Fetch one blob: returns the layer plus its completion instant.
    pub fn fetch(
        &mut self,
        arrival: VirtualTime,
        id: &LayerId,
    ) -> Result<(Layer, VirtualTime), PullError> {
        let layer = self
            .registry
            .layers
            .get(id)
            .cloned()
            .ok_or_else(|| PullError::CorruptRegistry(id.clone()))?;
        let done = self.submit_transfer(arrival, id, layer.bytes);
        Ok((layer, done))
    }

    /// DES-scheduled single-client pull of `reference` into `dest`
    /// starting at `now`: each missing layer is fetched concurrently
    /// through its shard; the report's `time` is the span until the
    /// last layer lands.  Byte/layer accounting matches the flat
    /// [`Registry::pull`] exactly — only the timing model differs.
    ///
    /// [`Registry::pull`]: super::registry::Registry::pull
    pub fn pull_at(
        &mut self,
        now: VirtualTime,
        reference: &str,
        dest: &mut LayerStore,
    ) -> Result<(Image, PullReport), PullError> {
        let image = self
            .registry
            .image(reference)
            .cloned()
            .ok_or_else(|| PullError::UnknownReference(reference.to_string()))?;
        let missing: Vec<LayerId> = dest.missing(&image.layers).into_iter().cloned().collect();
        let mut bytes = 0u64;
        let mut done_at = now;
        for id in &missing {
            let (layer, done) = self.fetch(now, id)?;
            bytes += layer.bytes;
            done_at = done_at.max(done);
            dest.insert(layer);
        }
        let report = PullReport {
            reference: reference.to_string(),
            layers_transferred: missing.len(),
            layers_reused: image.layers.len() - missing.len(),
            bytes_transferred: bytes,
            time: done_at.since(now),
        };
        Ok((image, report))
    }

    /// Cumulative busy time per shard frontend.
    pub fn shard_busy(&self) -> Vec<Duration> {
        self.shards.iter().map(|s| s.busy_time()).collect()
    }

    /// Per-shard utilisation over `horizon`, counting only service
    /// delivered beyond the `busy_before` snapshot (a prior
    /// [`shard_busy`](Self::shard_busy) result).
    pub fn shard_utilisation(&self, busy_before: &[Duration], horizon: Duration) -> Vec<f64> {
        self.shards
            .iter()
            .zip(busy_before)
            .map(|(s, &b)| s.utilisation(b, horizon))
            .collect()
    }

    /// Forget all shard queue state (fresh deployment campaign).
    pub fn reset_clocks(&mut self) {
        for s in &mut self.shards {
            s.reset();
        }
    }
}

/// How layers spread inside the cluster once a copy exists there.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FanOut {
    /// Every node fetches every missing layer from the registry shard
    /// itself — the no-dedup baseline that exposes WAN contention
    /// (`O(nodes × layers)` registry bytes).
    Direct,
    /// Trow-style peer distribution: the first puller seeds the layer
    /// over the WAN (once per layer, through its shard), then every
    /// holder serves `arity` sibling nodes per fan-out wave over the
    /// cluster fabric — holders grow geometrically, so full coverage
    /// takes `O(log nodes)` waves.
    Peer {
        /// Siblings each holder serves per wave (≥ 1).
        arity: usize,
    },
}

/// Static description of a deployment fleet.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of compute nodes pulling the image.
    pub nodes: usize,
    /// Intra-cluster distribution strategy.
    pub fan_out: FanOut,
    /// Per-node layer-cache capacity in bytes (`u64::MAX` = unbounded).
    pub cache_capacity_bytes: u64,
    /// Fabric carrying intra-cluster fan-out hops.
    pub fabric: Fabric,
    /// Local metadata check a node pays per image layer on every
    /// deploy, hit or miss (the `shifterimg`-style verify/mount cost —
    /// what a fully warm deploy still costs).
    pub per_layer_check: Duration,
}

impl FleetConfig {
    /// An Edison-like deployment target: Aries fabric, binary peer
    /// fan-out, unbounded node caches, 2 ms local metadata check per
    /// layer.  (The registry shard count lives on the
    /// [`ShardedRegistry`] the fleet pulls through.)
    pub fn hpc(nodes: usize) -> Self {
        FleetConfig {
            nodes,
            fan_out: FanOut::Peer { arity: 2 },
            cache_capacity_bytes: u64::MAX,
            fabric: Fabric::aries(),
            per_layer_check: Duration::from_millis(2),
        }
    }
}

/// What one fleet deployment did (the fleet analogue of [`PullReport`]).
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Image reference deployed.
    pub reference: String,
    /// Nodes in the fleet.
    pub nodes: usize,
    /// Layers in the image (with duplicates, if any).
    pub layers_total: usize,
    /// Distinct layers considered for transfer.
    pub unique_layers: usize,
    /// WAN transfers performed (shard → cluster).
    pub wan_transfers: usize,
    /// Bytes that crossed the WAN from registry shards.
    pub wan_bytes: u64,
    /// Bytes copied node-to-node inside the cluster.
    pub intra_bytes: u64,
    /// Virtual instant the deployment started.
    pub started_at: VirtualTime,
    /// Span from start until the slowest node finished (transfers +
    /// per-layer local checks).
    pub makespan: Duration,
    /// Cache accounting for this wave only (summed over nodes).
    pub cache: CacheStats,
    /// Per-shard utilisation over the makespan (busy / makespan).
    pub shard_utilisation: Vec<f64>,
    /// Containers created and started on the fleet after the pull.
    pub containers_started: usize,
    /// Calendar-queue counters of the wave's transfer scheduler (one
    /// ready event per node per transferred layer; a fully warm
    /// re-deploy schedules none).  See `des::stats`.
    pub queue: QueueStats,
}

impl FleetReport {
    /// All bytes moved anywhere: WAN plus intra-cluster.
    pub fn total_bytes(&self) -> u64 {
        self.wan_bytes + self.intra_bytes
    }

    /// One-paragraph trace line for CLI output.
    pub fn render(&self) -> String {
        format!(
            "deploy {} -> {} nodes: makespan {}, WAN {:.1} MB in {} transfer(s), \
             intra-cluster {:.1} MB, cache hit rate {:.0}%, shard util {}, \
             {} ready events (queue depth hwm {})",
            self.reference,
            self.nodes,
            self.makespan,
            self.wan_bytes as f64 / 1e6,
            self.wan_transfers,
            self.intra_bytes as f64 / 1e6,
            self.cache.hit_rate() * 100.0,
            self.shard_utilisation
                .iter()
                .map(|u| format!("{:.0}%", u * 100.0))
                .collect::<Vec<_>>()
                .join("/"),
            self.queue.pushes,
            self.queue.depth_hwm,
        )
    }
}

/// `N` nodes with node-local layer caches, deploying images pulled
/// through a [`ShardedRegistry`].  Successive [`deploy`](Fleet::deploy)
/// calls share the caches (that is the point: the second deploy is
/// warm) and advance the fleet's virtual clock.
#[derive(Debug)]
pub struct Fleet {
    config: FleetConfig,
    caches: Vec<LayerCache>,
    containers: Vec<Container>,
    clock: VirtualTime,
    next_container_id: u64,
}

impl Fleet {
    /// A cold fleet (every node cache empty) at virtual time zero.
    pub fn new(config: FleetConfig) -> Self {
        assert!(config.nodes >= 1, "fleet needs at least one node");
        if let FanOut::Peer { arity } = config.fan_out {
            assert!(arity >= 1, "peer fan-out needs arity >= 1");
        }
        let caches = (0..config.nodes)
            .map(|_| LayerCache::new(config.cache_capacity_bytes))
            .collect();
        Fleet {
            config,
            caches,
            containers: Vec::new(),
            clock: VirtualTime::ZERO,
            next_container_id: 0,
        }
    }

    /// The fleet's configuration.
    pub fn config(&self) -> &FleetConfig {
        &self.config
    }

    /// Node-local caches, indexed by node.
    pub fn caches(&self) -> &[LayerCache] {
        &self.caches
    }

    /// Mutable cache access (tests pre-warm subsets of the fleet).
    pub fn caches_mut(&mut self) -> &mut [LayerCache] {
        &mut self.caches
    }

    /// Containers created by the most recent deployment wave.
    pub fn containers(&self) -> &[Container] {
        &self.containers
    }

    /// The fleet's virtual clock (advances with each deploy wave).
    pub fn now(&self) -> VirtualTime {
        self.clock
    }

    /// Sum of every node cache's lifetime counters.
    pub fn cache_totals(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for c in &self.caches {
            total.merge(&c.stats());
        }
        total
    }

    /// Deploy `reference` onto every node concurrently, in virtual
    /// time: consult each node cache, seed cache-missing layers from
    /// the owning registry shard, fan copies out across the cluster
    /// fabric, admit them into the node caches, then create and start
    /// one container per node.  Returns the wave's [`FleetReport`].
    pub fn deploy(
        &mut self,
        registry: &mut ShardedRegistry,
        reference: &str,
    ) -> Result<FleetReport, PullError> {
        let t0 = self.clock;
        let n = self.config.nodes;
        let image = registry
            .registry()
            .image(reference)
            .cloned()
            .ok_or_else(|| PullError::UnknownReference(reference.to_string()))?;

        // distinct layers, first-appearance order (image stacks are
        // normally duplicate-free; dedup keeps the accounting honest)
        let mut unique: Vec<&LayerId> = Vec::new();
        for id in &image.layers {
            if !unique.contains(&id) {
                unique.push(id);
            }
        }

        let stats_before = self.cache_totals();
        let busy_before = registry.shard_busy();
        let mut wan_bytes = 0u64;
        let mut intra_bytes = 0u64;
        let mut wan_transfers = 0usize;
        // instant each node has all its layers (before local checks)
        let mut node_ready = vec![t0; n];
        // every transfer-completion instant is scheduled through one
        // calendar queue (fan-out waves enter as batches) and drained
        // in time order at the end of its layer, so the depth
        // high-water mark in the report is the peak of concurrently
        // in-flight completions, not a lifetime push count
        let mut sched: EventQueue<usize> = EventQueue::with_capacity(n);

        for &id in &unique {
            let mut needers: Vec<usize> = Vec::new();
            for (node, cache) in self.caches.iter_mut().enumerate() {
                if cache.lookup(id).is_none() {
                    needers.push(node);
                }
            }
            if needers.is_empty() {
                continue; // fully warm layer: no transfer anywhere
            }
            let layer = registry
                .registry()
                .layers
                .get(id)
                .ok_or_else(|| PullError::CorruptRegistry(id.clone()))?;
            // node caches hold the blob (id + bytes + provenance), not
            // the file manifest — that stays in the catalogue, exactly
            // as a compressed blob cache on a real node would
            let blob = layer.blob();

            match self.config.fan_out {
                FanOut::Direct => {
                    let mut arrivals = Vec::with_capacity(needers.len());
                    for &node in &needers {
                        let done = registry.submit_transfer(t0, id, blob.bytes);
                        wan_bytes += blob.bytes;
                        wan_transfers += 1;
                        arrivals.push((done, node));
                        self.caches[node].admit(blob.clone());
                    }
                    sched.push_batch(arrivals);
                }
                FanOut::Peer { arity } => {
                    let holders = n - needers.len();
                    // seed over the WAN only if no node holds the layer
                    let (start, mut have, rest) = if holders == 0 {
                        let done = registry.submit_transfer(t0, id, blob.bytes);
                        wan_bytes += blob.bytes;
                        wan_transfers += 1;
                        let seeder = needers[0];
                        sched.push(done, seeder);
                        self.caches[seeder].admit(blob.clone());
                        (done, 1usize, &needers[1..])
                    } else {
                        (t0, holders, &needers[..])
                    };
                    intra_bytes += blob.bytes * rest.len() as u64;
                    let hop = self.config.fabric.p2p(blob.bytes, false);
                    let mut served = 0usize;
                    let mut t = start;
                    while served < rest.len() {
                        let wave = (have * arity).min(rest.len() - served);
                        t += hop;
                        let mut arrivals = Vec::with_capacity(wave);
                        for &node in &rest[served..served + wave] {
                            arrivals.push((t, node));
                            self.caches[node].admit(blob.clone());
                        }
                        sched.push_batch(arrivals);
                        served += wave;
                        have += wave;
                    }
                }
            }

            // drain this layer's completions in time order; a node's
            // readiness is its last event across all layers
            while let Some((ready, node)) = sched.pop() {
                node_ready[node] = node_ready[node].max(ready);
            }
        }
        let queue = sched.stats();

        // local per-layer verify/mount, then create + start a container
        let check = self.config.per_layer_check * image.layers.len() as u64;
        self.containers.clear();
        let mut finish = t0;
        for ready in &node_ready {
            let done = *ready + check;
            finish = finish.max(done);
            let mut c = Container::create(self.next_container_id, image.id.clone(), done);
            self.next_container_id += 1;
            c.start(done).expect("fresh container starts");
            self.containers.push(c);
        }
        let makespan = finish.since(t0);
        self.clock = finish;

        let shard_utilisation = registry.shard_utilisation(&busy_before, makespan);

        Ok(FleetReport {
            reference: reference.to_string(),
            nodes: n,
            layers_total: image.layers.len(),
            unique_layers: unique.len(),
            wan_transfers,
            wan_bytes,
            intra_bytes,
            started_at: t0,
            makespan,
            cache: self.cache_totals().since(&stats_before),
            shard_utilisation,
            containers_started: n,
            queue,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::buildfile::Buildfile;
    use crate::container::builder::Builder;

    fn registry_with(reference: &str, text: &str) -> (ShardedRegistry, u64, usize) {
        let mut store = LayerStore::new();
        let image = Builder::new()
            .build(&Buildfile::parse(text).unwrap(), reference, &mut store)
            .unwrap()
            .image;
        let bytes = image.size_bytes(&store);
        let layers = image.layers.len();
        let mut reg = Registry::new();
        reg.push(&image, &store).unwrap();
        (ShardedRegistry::new(reg, 4), bytes, layers)
    }

    #[test]
    fn shard_of_is_deterministic_and_in_range() {
        let (reg, _, _) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        for id in reg.registry().layers.ids().cloned().collect::<Vec<_>>() {
            let s = reg.shard_of(&id);
            assert!(s < reg.shard_count());
            assert_eq!(s, reg.shard_of(&id));
        }
        // non-hex ids use the fallback fold and stay in range
        assert!(reg.shard_of(&LayerId("not-hex!".into())) < 4);
    }

    #[test]
    fn pull_at_matches_flat_pull_accounting() {
        let (mut sharded, bytes, layers) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let mut dest = LayerStore::new();
        let (_, report) = sharded
            .pull_at(VirtualTime::ZERO, "a:1", &mut dest)
            .unwrap();
        assert_eq!(report.layers_transferred, layers);
        assert_eq!(report.bytes_transferred, bytes);
        assert!(report.time > Duration::ZERO);
        assert_eq!(dest.len(), layers);
        // re-pull into the same store: nothing to move
        let (_, again) = sharded
            .pull_at(VirtualTime::ZERO, "a:1", &mut dest)
            .unwrap();
        assert_eq!(again.layers_transferred, 0);
        assert_eq!(again.bytes_transferred, 0);
        assert_eq!(again.time, Duration::ZERO);
    }

    #[test]
    fn concurrent_pulls_contend_per_shard() {
        let (mut sharded, _, _) = registry_with("a:1", "FROM alpine:3.4");
        let mut d1 = LayerStore::new();
        let mut d2 = LayerStore::new();
        let (_, r1) = sharded.pull_at(VirtualTime::ZERO, "a:1", &mut d1).unwrap();
        let (_, r2) = sharded.pull_at(VirtualTime::ZERO, "a:1", &mut d2).unwrap();
        // same arrival, same single-layer shard queue: the second
        // client queues behind the first
        assert!(r2.time > r1.time, "{:?} !> {:?}", r2.time, r1.time);
    }

    #[test]
    fn unknown_reference_errors() {
        let (mut sharded, _, _) = registry_with("a:1", "FROM alpine:3.4");
        assert!(matches!(
            sharded.pull_at(VirtualTime::ZERO, "ghost:1", &mut LayerStore::new()),
            Err(PullError::UnknownReference(_))
        ));
        let mut fleet = Fleet::new(FleetConfig::hpc(2));
        assert!(matches!(
            fleet.deploy(&mut sharded, "ghost:1"),
            Err(PullError::UnknownReference(_))
        ));
    }

    #[test]
    fn peer_deploy_wan_bytes_are_unique_layers_once() {
        let (mut sharded, bytes, layers) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let n = 64;
        let mut fleet = Fleet::new(FleetConfig::hpc(n));
        let cold = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(cold.unique_layers, layers);
        assert_eq!(cold.wan_transfers, layers, "each layer seeded once");
        assert_eq!(cold.wan_bytes, bytes, "each layer crossed the WAN once");
        assert_eq!(cold.intra_bytes, bytes * (n as u64 - 1), "fan-out copies");
        assert_eq!(cold.cache.misses, (n * layers) as u64);
        assert_eq!(cold.cache.hits, 0);
        assert_eq!(cold.containers_started, n);
        assert!(cold.makespan > Duration::ZERO);
    }

    #[test]
    fn warm_redeploy_moves_zero_bytes() {
        let (mut sharded, _, layers) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let mut fleet = Fleet::new(FleetConfig::hpc(128));
        let cold = fleet.deploy(&mut sharded, "a:1").unwrap();
        let warm = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(warm.wan_bytes, 0);
        assert_eq!(warm.intra_bytes, 0);
        assert_eq!(warm.wan_transfers, 0);
        assert_eq!(warm.cache.hits, (128 * layers) as u64);
        assert_eq!(warm.cache.misses, 0);
        // warm cost is only the local per-layer checks
        assert_eq!(warm.makespan, Duration::from_millis(2) * layers as u64);
        assert!(warm.makespan.as_secs_f64() < 0.1 * cold.makespan.as_secs_f64());
        assert!(warm.started_at > cold.started_at, "clock advanced");
    }

    #[test]
    fn direct_deploy_pays_wan_per_node() {
        let (mut sharded, bytes, layers) = registry_with("a:1", "FROM alpine:3.4\nRUN echo x");
        let n = 16;
        let mut cfg = FleetConfig::hpc(n);
        cfg.fan_out = FanOut::Direct;
        let mut fleet = Fleet::new(cfg);
        let cold = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(cold.wan_bytes, bytes * n as u64);
        assert_eq!(cold.wan_transfers, layers * n);
        assert_eq!(cold.intra_bytes, 0);
    }

    #[test]
    fn direct_contention_grows_with_fleet_size() {
        let make = |n: usize| {
            let (mut sharded, _, _) = registry_with("a:1", "FROM alpine:3.4");
            let mut cfg = FleetConfig::hpc(n);
            cfg.fan_out = FanOut::Direct;
            let mut fleet = Fleet::new(cfg);
            fleet.deploy(&mut sharded, "a:1").unwrap().makespan
        };
        let small = make(8);
        let large = make(64);
        assert!(
            large.as_secs_f64() > 4.0 * small.as_secs_f64(),
            "direct pulls serialise on the shards: {small} vs {large}"
        );
    }

    #[test]
    fn peer_beats_direct_at_scale() {
        let run = |fan_out| {
            let (mut sharded, _, _) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
            let mut cfg = FleetConfig::hpc(256);
            cfg.fan_out = fan_out;
            let mut fleet = Fleet::new(cfg);
            fleet.deploy(&mut sharded, "a:1").unwrap().makespan
        };
        let peer = run(FanOut::Peer { arity: 2 });
        let direct = run(FanOut::Direct);
        assert!(
            peer.as_secs_f64() < direct.as_secs_f64() / 4.0,
            "peer {peer} should be far under direct {direct}"
        );
    }

    #[test]
    fn prewarmed_holders_skip_the_wan() {
        let (mut sharded, bytes, _) = registry_with("a:1", "FROM alpine:3.4\nRUN echo x");
        let mut fleet = Fleet::new(FleetConfig::hpc(8));
        // warm node 0 only
        let ids: Vec<LayerId> = sharded.registry().layers.ids().cloned().collect();
        for id in &ids {
            let l = sharded.registry().layers.get(id).unwrap().clone();
            fleet.caches_mut()[0].admit(l);
        }
        let report = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(report.wan_bytes, 0, "existing holder seeds the cluster");
        assert_eq!(report.intra_bytes, bytes * 7);
    }

    #[test]
    fn fan_out_wave_timing_doubles_holders() {
        // 4 nodes, arity 1, single layer: seeder at t_seed, then waves
        // serve 1, then 2 nodes — two hops after the seed
        let (mut sharded, _, _) = registry_with("one:1", "FROM alpine:3.4");
        let mut cfg = FleetConfig::hpc(4);
        cfg.fan_out = FanOut::Peer { arity: 1 };
        cfg.per_layer_check = Duration::ZERO;
        let layers = sharded.registry().image("one:1").unwrap().layers.len();
        assert_eq!(layers, 1, "alpine base is a single layer");
        let bytes = sharded
            .registry()
            .layers
            .ids()
            .map(|id| sharded.registry().layers.get(id).unwrap().bytes)
            .sum::<u64>();
        let mut fleet = Fleet::new(cfg);
        let report = fleet.deploy(&mut sharded, "one:1").unwrap();
        let seed = PathCost::registry_wan().transfer(bytes);
        let hop = Fabric::aries().p2p(bytes, false);
        assert_eq!(report.makespan, seed + hop + hop);
    }

    #[test]
    fn report_renders_key_numbers() {
        let (mut sharded, _, _) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let mut fleet = Fleet::new(FleetConfig::hpc(32));
        let r = fleet.deploy(&mut sharded, "a:1").unwrap();
        let text = r.render();
        assert!(text.contains("32 nodes"));
        assert!(text.contains("WAN"));
        assert!(text.contains("hit rate"));
        assert!(text.contains("ready events"));
    }

    #[test]
    fn deploy_schedules_one_ready_event_per_node_per_layer() {
        let (mut sharded, _, layers) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let n = 64;
        let mut fleet = Fleet::new(FleetConfig::hpc(n));
        let cold = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(cold.queue.pushes, (n * layers) as u64);
        assert_eq!(cold.queue.pops, cold.queue.pushes, "drained to empty");
        assert_eq!(cold.queue.depth, 0);
        // drained per layer: the high-water mark is one layer's worth
        // of in-flight completions, not the lifetime push count
        assert_eq!(cold.queue.depth_hwm, n);
        // a fully warm wave schedules nothing at all
        let warm = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(warm.queue.pushes, 0);
        assert_eq!(warm.queue.depth_hwm, 0);
    }

    #[test]
    fn bounded_caches_evict_and_refetch() {
        let (mut sharded, bytes, _) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let mut cfg = FleetConfig::hpc(4);
        // caches too small for the whole image: something must go
        cfg.cache_capacity_bytes = bytes / 2;
        let mut fleet = Fleet::new(cfg);
        let cold = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert!(cold.cache.evictions > 0, "capacity forces eviction");
        let warm = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert!(
            warm.total_bytes() > 0,
            "evicted layers must be transferred again"
        );
    }
}
